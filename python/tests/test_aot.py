"""AOT path tests: HLO-text emission, manifest consistency, weight packing.

These run the same lowering pipeline as `make artifacts` against a tiny
config, so they are fast and do not depend on artifacts/ being built.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as M
from compile.configs import LM_CONFIGS, ModelConfig, RETRIEVAL_DIM

TINY = ModelConfig("tiny", n_layers=1, d_model=32, n_heads=2, d_ff=64,
                   vocab=64, max_ctx=64, prefill_len=64)


def test_hlo_text_is_parseable_format():
    """Lowered text must be HLO text (not proto bytes) with an ENTRY."""
    lowered = jax.jit(lambda x: (x * 2.0,)).lower(
        jax.ShapeDtypeStruct((4,), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text and "ENTRY" in text
    assert "parameter(0)" in text


def test_hlo_param_order_matches_arg_order():
    """HLO parameter(i) must follow jit positional-arg order: the Rust
    runtime feeds buffers strictly by manifest order."""
    def fn(a, b, c):
        return (a + b[0] + c[0, 0],)
    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((), jnp.float32),
        jax.ShapeDtypeStruct((3,), jnp.float32),
        jax.ShapeDtypeStruct((2, 2), jnp.float32))
    text = aot.to_hlo_text(lowered)
    i0 = text.index("parameter(0)")
    i1 = text.index("parameter(1)")
    i2 = text.index("parameter(2)")
    # shapes appear on the same line as the parameter decl
    line0 = text[:i0].rsplit("\n", 1)[-1] + text[i0:].split("\n", 1)[0]
    line1 = text[:i1].rsplit("\n", 1)[-1] + text[i1:].split("\n", 1)[0]
    line2 = text[:i2].rsplit("\n", 1)[-1] + text[i2:].split("\n", 1)[0]
    assert "f32[]" in line0
    assert "f32[3]" in line1
    assert "f32[2,2]" in line2


def test_pack_weights_roundtrip(tmp_path):
    specs = M.lm_weight_specs(TINY)
    weights = M.init_weights(specs, seed=5)
    path = tmp_path / "w.bin"
    entries = aot.pack_weights(weights, str(path))
    blob = path.read_bytes()
    assert len(entries) == len(specs)
    total = sum(e["nbytes"] for e in entries)
    assert len(blob) == total
    for e, (name, w) in zip(entries, weights):
        assert e["name"] == name
        arr = np.frombuffer(blob[e["offset"]:e["offset"] + e["nbytes"]],
                            dtype="<f4").reshape(e["shape"])
        np.testing.assert_array_equal(arr, np.asarray(w))


def test_full_artifact_emission_tiny(tmp_path):
    emitted = []
    aot.build_encoder(TINY.vocab, str(tmp_path), emitted)
    aot.build_score(str(tmp_path), emitted)
    aot.build_lm(TINY, str(tmp_path), emitted)
    assert set(emitted) == {"encode_q", "encode_batch", "score_dense",
                            "prefill_tiny", "decode_tiny",
                            "decode_chunk_tiny"}
    for name in emitted:
        hlo = (tmp_path / f"{name}.hlo.txt").read_text()
        assert "ENTRY" in hlo
        man = json.loads((tmp_path / f"{name}.manifest.json").read_text())
        assert man["artifact"] == name
        # every input has shape/dtype; weights also carry blob coordinates
        for inp in man["inputs"]:
            assert inp["dtype"] in ("f32", "i32")
            if inp["kind"] == "weight":
                assert "offset" in inp and "nbytes" in inp
        # parameter count in the HLO matches the manifest
        n_params = hlo.count("= parameter(")
        if n_params == 0:  # some printers use 'parameter(n)' without '= '
            n_params = hlo.count("parameter(")
        assert n_params >= len(man["inputs"])


def test_manifest_input_count_matches_hlo_entry(tmp_path):
    emitted = []
    aot.build_lm(TINY, str(tmp_path), emitted)
    man = json.loads((tmp_path / "decode_tiny.manifest.json").read_text())
    hlo = (tmp_path / "decode_tiny.hlo.txt").read_text()
    # every manifest input exists as parameter(i) in the HLO text
    for i in range(len(man["inputs"])):
        assert f"parameter({i})" in hlo
    assert f"parameter({len(man['inputs'])})" not in hlo
    n_weights = sum(1 for i in man["inputs"] if i["kind"] == "weight")
    specs = M.lm_weight_specs(TINY)
    assert n_weights == len(specs)
    # decode has token/pos/kv on top of the weights
    assert [i["name"] for i in man["inputs"][n_weights:]] == ["token", "pos",
                                                              "kv"]


def test_all_real_configs_have_valid_dims():
    for cfg in LM_CONFIGS.values():
        assert cfg.d_model % cfg.n_heads == 0
        assert cfg.prefill_len % 64 == 0, "prefill must align to block_q"
        assert cfg.max_ctx % 64 == 0, "ctx must align to block_k"
        assert cfg.prefill_len <= cfg.max_ctx
        assert cfg.vocab >= 256


@pytest.mark.skipif(not os.path.exists(
    os.path.join(os.path.dirname(__file__), "../../artifacts/index.json")),
    reason="artifacts/ not built (run `make artifacts`)")
def test_built_artifacts_index_consistent():
    root = os.path.join(os.path.dirname(__file__), "../../artifacts")
    with open(os.path.join(root, "index.json")) as f:
        index = json.load(f)
    assert index["retrieval_dim"] == RETRIEVAL_DIM
    for name in index["artifacts"]:
        assert os.path.exists(os.path.join(root, f"{name}.hlo.txt")), name
        assert os.path.exists(os.path.join(root, f"{name}.manifest.json")), name
        with open(os.path.join(root, f"{name}.manifest.json")) as f:
            man = json.load(f)
        if man["weights_bin"]:
            bin_path = os.path.join(root, man["weights_bin"])
            assert os.path.exists(bin_path)
            need = max((i["offset"] + i["nbytes"]
                        for i in man["inputs"] if i["kind"] == "weight"),
                       default=0)
            assert os.path.getsize(bin_path) >= need
