"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes, valid lengths, and positions; every case asserts
allclose against kernels/ref.py. This is the core correctness signal for the
compute layer — the AOT artifacts embed exactly these kernels.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import mha_decode, mha_prefill
from compile.kernels.scoring import score_batch

settings.register_profile("kernels", deadline=None, max_examples=25,
                          derandomize=True)
settings.load_profile("kernels")


def _rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------------------
# prefill attention
# ---------------------------------------------------------------------------

@given(h=st.integers(1, 5),
       t_blocks=st.integers(1, 4),
       dh=st.sampled_from([16, 32, 64]),
       len_frac=st.floats(0.05, 1.0),
       seed=st.integers(0, 2**16))
def test_prefill_matches_ref(h, t_blocks, dh, len_frac, seed):
    t = 64 * t_blocks
    valid = max(1, int(t * len_frac))
    q, k, v = (_rand(seed + i, (h, t, dh)) for i in range(3))
    vl = jnp.array(valid, jnp.int32)
    got = mha_prefill(q, k, v, vl)
    exp = ref.mha_prefill_ref(q, k, v, vl)
    # Only rows < valid are consumed downstream (padded rows attend to the
    # valid prefix only in the oracle, but never feed the logits).
    np.testing.assert_allclose(np.asarray(got)[:, :valid],
                               np.asarray(exp)[:, :valid],
                               rtol=2e-5, atol=2e-5)


@given(bq=st.sampled_from([16, 32, 64]), bk=st.sampled_from([16, 32, 64]),
       seed=st.integers(0, 2**16))
def test_prefill_block_size_invariance(bq, bk, seed):
    """The tiling schedule must not change the numbers."""
    h, t, dh = 2, 128, 32
    q, k, v = (_rand(seed + i, (h, t, dh)) for i in range(3))
    vl = jnp.array(100, jnp.int32)
    base = mha_prefill(q, k, v, vl)
    tiled = mha_prefill(q, k, v, vl, block_q=bq, block_k=bk)
    np.testing.assert_allclose(np.asarray(base)[:, :100],
                               np.asarray(tiled)[:, :100],
                               rtol=2e-5, atol=2e-5)


def test_prefill_causality():
    """Perturbing future tokens must not change past outputs."""
    h, t, dh = 2, 128, 32
    q, k, v = (_rand(i, (h, t, dh)) for i in range(3))
    vl = jnp.array(t, jnp.int32)
    base = np.asarray(mha_prefill(q, k, v, vl))
    k2 = k.at[:, 80:].add(5.0)
    v2 = v.at[:, 80:].add(-3.0)
    pert = np.asarray(mha_prefill(q, k2, v2, vl))
    np.testing.assert_allclose(base[:, :80], pert[:, :80], rtol=1e-6, atol=1e-6)
    assert not np.allclose(base[:, 80:], pert[:, 80:])


def test_prefill_length_mask():
    """Tokens beyond valid_len must be invisible to valid positions."""
    h, t, dh = 2, 64, 16
    q, k, v = (_rand(i + 10, (h, t, dh)) for i in range(3))
    vl = jnp.array(40, jnp.int32)
    base = np.asarray(mha_prefill(q, k, v, vl))
    k2 = k.at[:, 40:].set(99.0)
    v2 = v.at[:, 40:].set(-99.0)
    pert = np.asarray(mha_prefill(q, k2, v2, vl))
    np.testing.assert_allclose(base[:, :40], pert[:, :40], rtol=1e-6, atol=1e-6)


def test_prefill_rejects_unaligned():
    q = jnp.zeros((1, 100, 16))
    with pytest.raises(AssertionError):
        mha_prefill(q, q, q, jnp.array(10))


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@given(h=st.integers(1, 6),
       t_blocks=st.integers(1, 4),
       dh=st.sampled_from([16, 32, 64]),
       pos_frac=st.floats(0.0, 1.0),
       seed=st.integers(0, 2**16))
def test_decode_matches_ref(h, t_blocks, dh, pos_frac, seed):
    t = 64 * t_blocks
    pos = min(t - 1, int(t * pos_frac))
    q = _rand(seed, (h, dh))
    k = _rand(seed + 1, (h, t, dh))
    v = _rand(seed + 2, (h, t, dh))
    p = jnp.array(pos, jnp.int32)
    got = mha_decode(q, k, v, p)
    exp = ref.mha_decode_ref(q, k, v, p)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               rtol=2e-5, atol=2e-5)


def test_decode_ignores_future_cache_slots():
    h, t, dh = 2, 128, 32
    q = _rand(0, (h, dh))
    k = _rand(1, (h, t, dh))
    v = _rand(2, (h, t, dh))
    pos = jnp.array(17, jnp.int32)
    base = np.asarray(mha_decode(q, k, v, pos))
    k2 = k.at[:, 18:].set(123.0)
    v2 = v.at[:, 18:].set(-123.0)
    pert = np.asarray(mha_decode(q, k2, v2, pos))
    np.testing.assert_allclose(base, pert, rtol=1e-6, atol=1e-6)


def test_decode_pos0_attends_only_slot0():
    h, t, dh = 1, 64, 8
    q = _rand(3, (h, dh))
    k = _rand(4, (h, t, dh))
    v = _rand(5, (h, t, dh))
    got = np.asarray(mha_decode(q, k, v, jnp.array(0, jnp.int32)))
    np.testing.assert_allclose(got, np.asarray(v[:, 0, :]), rtol=1e-5,
                               atol=1e-5)


# ---------------------------------------------------------------------------
# dense scoring
# ---------------------------------------------------------------------------

@given(b=st.sampled_from([1, 4, 16]),
       n_tiles=st.integers(1, 4),
       dr=st.sampled_from([16, 64, 128]),
       seed=st.integers(0, 2**16))
def test_score_matches_ref(b, n_tiles, dr, seed):
    n = 512 * n_tiles
    q = _rand(seed, (b, dr))
    c = _rand(seed + 1, (n, dr))
    got = score_batch(q, c)
    exp = ref.score_ref(q, c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               rtol=1e-5, atol=1e-5)


def test_score_tile_invariance():
    q = _rand(0, (8, 64))
    c = _rand(1, (2048, 64))
    a = np.asarray(score_batch(q, c, tile_n=512))
    b = np.asarray(score_batch(q, c, tile_n=256))
    np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)


def test_score_rejects_dim_mismatch():
    with pytest.raises(AssertionError):
        score_batch(jnp.zeros((4, 32)), jnp.zeros((512, 64)))
