"""L2 model correctness: prefill/decode consistency + oracle cross-check.

Uses a tiny ad-hoc config so the full forward stays fast; the same code paths
are what aot.py lowers for the real configs.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.configs import ModelConfig, RETRIEVAL_DIM
from compile.kernels import ref

TINY = ModelConfig("tiny", n_layers=2, d_model=64, n_heads=2, d_ff=128,
                   vocab=128, max_ctx=64, prefill_len=64)


@pytest.fixture(scope="module")
def weights():
    return [w for _, w in M.init_weights(M.lm_weight_specs(TINY), seed=7)]


def _pad_tokens(tokens, n):
    out = np.zeros(n, np.int32)
    out[:len(tokens)] = tokens
    return jnp.asarray(out)


def _oracle_forward(weights, tokens_valid):
    """Full-precision forward using only ref.py attention (no Pallas)."""
    w = {name: a for (name, _), a in zip(M.lm_weight_specs(TINY), weights)}
    t = len(tokens_valid)
    x = w["tok_emb"][jnp.asarray(tokens_valid)] + w["pos_emb"][:t]
    for i in range(TINY.n_layers):
        p = f"layer{i}."
        a = M._layer_norm(x, w[p + "ln1_w"], w[p + "ln1_b"])
        q = M._split_heads(a @ w[p + "wq"], TINY.n_heads)
        k = M._split_heads(a @ w[p + "wk"], TINY.n_heads)
        v = M._split_heads(a @ w[p + "wv"], TINY.n_heads)
        attn = ref.mha_prefill_ref(q, k, v, jnp.array(t))
        x = x + M._merge_heads(attn) @ w[p + "wo"]
        m = M._layer_norm(x, w[p + "ln2_w"], w[p + "ln2_b"])
        x = x + (jax.nn.gelu(m @ w[p + "w1"] + w[p + "b1"])) @ w[p + "w2"] \
            + w[p + "b2"]
    x = M._layer_norm(x, w["lnf_w"], w["lnf_b"])
    return x[-1] @ w["tok_emb"].T


def test_prefill_matches_oracle(weights):
    tokens = [5, 9, 100, 3, 42, 17, 64, 2, 2, 33, 71]
    kv, logits, qproj = M.lm_prefill(
        TINY, *weights, _pad_tokens(tokens, TINY.prefill_len),
        jnp.array(len(tokens), jnp.int32))
    exp = _oracle_forward(weights, tokens)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(exp),
                               rtol=5e-4, atol=5e-4)
    assert kv.shape == (2, 2, 2, 64, 32)
    np.testing.assert_allclose(float(jnp.linalg.norm(qproj)), 1.0, rtol=1e-4)


def test_prefill_padding_invariance(weights):
    """Garbage in the padded tail must not change the logits."""
    tokens = [1, 2, 3, 4, 5]
    base = _pad_tokens(tokens, TINY.prefill_len)
    noisy = np.asarray(base).copy()
    noisy[len(tokens):] = 77
    vl = jnp.array(len(tokens), jnp.int32)
    _, l1, q1 = M.lm_prefill(TINY, *weights, base, vl)
    _, l2, q2 = M.lm_prefill(TINY, *weights, jnp.asarray(noisy), vl)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(q1), np.asarray(q2), rtol=1e-5,
                               atol=1e-5)


def test_decode_consistent_with_prefill(weights):
    """prefill(n) followed by decode(tokens[n..m]) == prefill(m)."""
    tokens = [5, 9, 100, 3, 42, 17, 64, 2, 2, 33, 71, 8, 90, 11]
    n = 10
    vl = jnp.array(n, jnp.int32)
    kv, _, _ = M.lm_prefill(TINY, *weights, _pad_tokens(tokens, TINY.prefill_len), vl)
    logits = None
    for pos in range(n, len(tokens)):
        logits, kv, qproj = M.lm_decode(
            TINY, *weights, jnp.array(tokens[pos], jnp.int32),
            jnp.array(pos, jnp.int32), kv)
    _, exp_logits, exp_qproj = M.lm_prefill(
        TINY, *weights, _pad_tokens(tokens, TINY.prefill_len),
        jnp.array(len(tokens), jnp.int32))
    np.testing.assert_allclose(np.asarray(logits), np.asarray(exp_logits),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(qproj), np.asarray(exp_qproj),
                               rtol=2e-3, atol=2e-3)


def test_decode_chunk_matches_stepwise_greedy(weights):
    """decode_chunk's in-graph argmax must equal stepwise decode+argmax."""
    tokens = [5, 9, 100, 3, 42, 17, 64]
    n = len(tokens)
    vl = jnp.array(n, jnp.int32)
    kv0, logits0, _ = M.lm_prefill(
        TINY, *weights, _pad_tokens(tokens, TINY.prefill_len), vl)
    first = jnp.argmax(logits0).astype(jnp.int32)

    # stepwise reference
    kv, tok = kv0, first
    step_tokens = []
    step_logits = None
    for j in range(4):
        step_logits, kv, _ = M.lm_decode(
            TINY, *weights, tok, jnp.array(n + j, jnp.int32), kv)
        step_tokens.append(int(tok))
        tok = jnp.argmax(step_logits).astype(jnp.int32)

    out_toks, out_logits, out_kv, qproj = M.lm_decode_chunk(
        TINY, 4, *weights, first, jnp.array(n, jnp.int32), kv0)
    assert [int(t) for t in out_toks] == step_tokens
    np.testing.assert_allclose(np.asarray(out_logits),
                               np.asarray(step_logits), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(out_kv), np.asarray(kv),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(float(jnp.linalg.norm(qproj)), 1.0, rtol=1e-4)


def test_hidden_positions_match_prefill_qproj(weights):
    """hidden[i] == prefill qproj when the context is tokens[..=i]."""
    tokens = [4, 8, 15, 16, 23, 42]
    vl = jnp.array(len(tokens), jnp.int32)
    (hiddens,) = M.lm_hidden(TINY, *weights,
                             _pad_tokens(tokens, TINY.prefill_len), vl)
    assert hiddens.shape == (TINY.prefill_len, RETRIEVAL_DIM)
    for i in (2, 5):
        _, _, qproj = M.lm_prefill(
            TINY, *weights, _pad_tokens(tokens[:i + 1], TINY.prefill_len),
            jnp.array(i + 1, jnp.int32))
        np.testing.assert_allclose(np.asarray(hiddens[i]), np.asarray(qproj),
                                   rtol=5e-4, atol=5e-4)


def test_encoder_normalized_and_length_sensitive():
    specs = M.encoder_weight_specs(128)
    weights = [w for _, w in M.init_weights(specs, seed=3)]
    toks = jnp.asarray(np.arange(32, dtype=np.int32) % 128)
    (v1,) = M.encode_query(128, *weights, toks, jnp.array(10, jnp.int32))
    (v2,) = M.encode_query(128, *weights, toks, jnp.array(20, jnp.int32))
    np.testing.assert_allclose(float(jnp.linalg.norm(v1)), 1.0, rtol=1e-5)
    assert not np.allclose(np.asarray(v1), np.asarray(v2))


def test_encode_batch_matches_single():
    specs = M.encoder_weight_specs(128)
    weights = [w for _, w in M.init_weights(specs, seed=3)]
    rng = np.random.RandomState(0)
    toks = rng.randint(0, 128, size=(64, 32)).astype(np.int32)
    lens = rng.randint(1, 33, size=(64,)).astype(np.int32)
    (batch,) = M.encode_batch(128, *weights, jnp.asarray(toks),
                              jnp.asarray(lens))
    for i in (0, 17, 63):
        (single,) = M.encode_query(128, *weights, jnp.asarray(toks[i]),
                                   jnp.array(lens[i], jnp.int32))
        np.testing.assert_allclose(np.asarray(batch[i]), np.asarray(single),
                                   rtol=1e-5, atol=1e-5)


def test_weight_specs_deterministic():
    a = M.init_weights(M.lm_weight_specs(TINY), seed=11)
    b = M.init_weights(M.lm_weight_specs(TINY), seed=11)
    for (na, wa), (nb, wb) in zip(a, b):
        assert na == nb
        np.testing.assert_array_equal(np.asarray(wa), np.asarray(wb))
