"""Model-size configurations shared between the JAX (L2) layer and aot.py.

Each config is a stand-in for one of the paper's language models (see
DESIGN.md §2 "Substitutions"): the reproduction measures latency trade-offs,
so what must be preserved is the *ordering and rough ratio* of LM-generation
cost across model classes, not parameter counts.

The same numbers are mirrored on the Rust side via the per-artifact
manifest.json — Rust never hardcodes them.
"""

from dataclasses import dataclass, asdict


@dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    vocab: int
    # Maximum total context (doc prefix + question + generated tokens).
    max_ctx: int
    # Fixed (padded) prefill input length; must be <= max_ctx.
    prefill_len: int

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def to_dict(self) -> dict:
        d = asdict(self)
        d["d_head"] = self.d_head
        return d


# Retrieval embedding dimensionality (dense retrievers + KNN-LM datastore).
RETRIEVAL_DIM = 64
# Number of tokens of context the query/passage encoder consumes.
ENCODER_LEN = 32
# Batch size of the batched passage-encoder artifact.
ENCODER_BATCH = 64
# Batched dense-scoring artifact shapes (Pallas scoring kernel).
SCORE_BATCH = 16
SCORE_TILE = 512
# Tokens per decode_chunk artifact call (= the paper's generation stride:
# Ram et al. retrieve every 4 generated tokens).
GEN_CHUNK = 4

LM_CONFIGS = {
    # GPT2-medium stand-in.
    "gpt2m": ModelConfig("gpt2m", n_layers=4, d_model=256, n_heads=4,
                         d_ff=1024, vocab=4096, max_ctx=320, prefill_len=320),
    # OPT-1.3B stand-in.
    "opt1b": ModelConfig("opt1b", n_layers=6, d_model=320, n_heads=5,
                         d_ff=1280, vocab=4096, max_ctx=320, prefill_len=320),
    # LLaMA-2-7B stand-in.
    "llama7b": ModelConfig("llama7b", n_layers=8, d_model=384, n_heads=6,
                           d_ff=1536, vocab=4096, max_ctx=320, prefill_len=320),
    # LLaMA-2-13B stand-in (Table 3 only).
    "llama13b": ModelConfig("llama13b", n_layers=10, d_model=512, n_heads=8,
                            d_ff=2048, vocab=4096, max_ctx=320, prefill_len=320),
    # 16-layer / 247M KNN-LM transformer stand-in (Khandelwal et al.).
    "knnlm": ModelConfig("knnlm", n_layers=6, d_model=320, n_heads=5,
                         d_ff=1280, vocab=4096, max_ctx=320, prefill_len=320),
}

# Length of a document slice processed by the KNN-LM datastore builder
# (`hidden_knnlm` artifact) in one call.
DATASTORE_CHUNK = 256

WEIGHT_SEED = 20240131  # deterministic weight init across rebuilds
