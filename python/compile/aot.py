"""AOT compile path: lower every L2 function to HLO *text* + pack weights.

Run once via `make artifacts` (Python never runs on the request path):

    cd python && python -m compile.aot --out-dir ../artifacts

Emits, per LM config C in configs.LM_CONFIGS:
    prefill_<C>.hlo.txt, decode_<C>.hlo.txt  (+ hidden_knnlm.hlo.txt)
    <C>.weights.bin          little-endian f32 concat, order = lm_weight_specs
    prefill_<C>.manifest.json / decode_<C>.manifest.json  (ordered I/O specs)
plus the shared encoder (encode_q / encode_batch + encoder.weights.bin), the
Pallas dense-scoring artifact (score_dense), and a top-level index.json.

Interchange format is HLO TEXT, not `.serialize()`: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the runtime's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .configs import (DATASTORE_CHUNK, ENCODER_BATCH, ENCODER_LEN,
                      LM_CONFIGS, RETRIEVAL_DIM, SCORE_BATCH, SCORE_TILE,
                      WEIGHT_SEED)

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _dtype_tag(dt) -> str:
    return {"float32": "f32", "int32": "i32"}[np.dtype(dt).name]


def _spec_entry(name, kind, shape, dtype, **extra):
    e = {"name": name, "kind": kind, "shape": list(int(s) for s in shape),
         "dtype": _dtype_tag(dtype)}
    e.update(extra)
    return e


def pack_weights(weights, path):
    """Write ordered (name, array) f32 weights as one little-endian blob.

    Returns manifest weight entries with byte offsets into the blob.
    """
    entries, offset = [], 0
    with open(path, "wb") as f:
        for name, w in weights:
            arr = np.asarray(w, dtype="<f4")
            f.write(arr.tobytes())
            entries.append(_spec_entry(name, "weight", arr.shape, arr.dtype,
                                       offset=offset, nbytes=arr.nbytes))
            offset += arr.nbytes
    return entries


def write_artifact(out_dir, name, lowered, weight_entries, weights_bin,
                   arg_entries, out_entries, config=None):
    hlo = to_hlo_text(lowered)
    with open(os.path.join(out_dir, f"{name}.hlo.txt"), "w") as f:
        f.write(hlo)
    manifest = {
        "artifact": name,
        "weights_bin": weights_bin,
        "inputs": list(weight_entries) + list(arg_entries),
        "outputs": list(out_entries),
        "config": config or {},
    }
    with open(os.path.join(out_dir, f"{name}.manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"  {name}: hlo={len(hlo) // 1024}KiB inputs={len(manifest['inputs'])}")
    return name


def build_lm(cfg, out_dir, emitted):
    print(f"[aot] LM {cfg.name}")
    specs = M.lm_weight_specs(cfg)
    weights = M.init_weights(specs, WEIGHT_SEED + hash(cfg.name) % 10000)
    weights_bin = f"{cfg.name}.weights.bin"
    wentries = pack_weights(weights, os.path.join(out_dir, weights_bin))
    wspecs = [jax.ShapeDtypeStruct(s, F32) for _, s in specs]
    kv_shape = (cfg.n_layers, 2, cfg.n_heads, cfg.max_ctx, cfg.d_head)
    ccfg = cfg.to_dict()
    ccfg.update(retrieval_dim=RETRIEVAL_DIM, encoder_len=ENCODER_LEN)

    # prefill
    lowered = jax.jit(functools.partial(M.lm_prefill, cfg)).lower(
        *wspecs,
        jax.ShapeDtypeStruct((cfg.prefill_len,), I32),
        jax.ShapeDtypeStruct((), I32))
    emitted.append(write_artifact(
        out_dir, f"prefill_{cfg.name}", lowered, wentries, weights_bin,
        [_spec_entry("tokens", "arg", (cfg.prefill_len,), np.int32),
         _spec_entry("valid_len", "arg", (), np.int32)],
        [_spec_entry("kv", "state", kv_shape, np.float32),
         _spec_entry("logits", "out", (cfg.vocab,), np.float32),
         _spec_entry("qproj", "out", (RETRIEVAL_DIM,), np.float32)],
        config=ccfg))

    # decode
    lowered = jax.jit(functools.partial(M.lm_decode, cfg)).lower(
        *wspecs,
        jax.ShapeDtypeStruct((), I32),
        jax.ShapeDtypeStruct((), I32),
        jax.ShapeDtypeStruct(kv_shape, F32))
    emitted.append(write_artifact(
        out_dir, f"decode_{cfg.name}", lowered, wentries, weights_bin,
        [_spec_entry("token", "arg", (), np.int32),
         _spec_entry("pos", "arg", (), np.int32),
         _spec_entry("kv", "state", kv_shape, np.float32)],
        [_spec_entry("logits", "out", (cfg.vocab,), np.float32),
         _spec_entry("kv", "state", kv_shape, np.float32),
         _spec_entry("qproj", "out", (RETRIEVAL_DIM,), np.float32)],
        config=ccfg))

    # decode_chunk: greedy 4-token interval in one call (QA hot path)
    from .configs import GEN_CHUNK
    lowered = jax.jit(functools.partial(M.lm_decode_chunk, cfg, GEN_CHUNK)).lower(
        *wspecs,
        jax.ShapeDtypeStruct((), I32),
        jax.ShapeDtypeStruct((), I32),
        jax.ShapeDtypeStruct(kv_shape, F32))
    emitted.append(write_artifact(
        out_dir, f"decode_chunk_{cfg.name}", lowered, wentries, weights_bin,
        [_spec_entry("first_token", "arg", (), np.int32),
         _spec_entry("pos", "arg", (), np.int32),
         _spec_entry("kv", "state", kv_shape, np.float32)],
        [_spec_entry("tokens", "out", (GEN_CHUNK,), np.int32),
         _spec_entry("logits", "out", (cfg.vocab,), np.float32),
         _spec_entry("kv", "state", kv_shape, np.float32),
         _spec_entry("qproj", "out", (RETRIEVAL_DIM,), np.float32)],
        config=dict(ccfg, gen_chunk=GEN_CHUNK)))

    # per-position hidden states (KNN-LM datastore builder)
    if cfg.name == "knnlm":
        lowered = jax.jit(functools.partial(M.lm_hidden, cfg)).lower(
            *wspecs,
            jax.ShapeDtypeStruct((cfg.prefill_len,), I32),
            jax.ShapeDtypeStruct((), I32))
        emitted.append(write_artifact(
            out_dir, f"hidden_{cfg.name}", lowered, wentries, weights_bin,
            [_spec_entry("tokens", "arg", (cfg.prefill_len,), np.int32),
             _spec_entry("valid_len", "arg", (), np.int32)],
            [_spec_entry("hiddens", "out", (cfg.prefill_len, RETRIEVAL_DIM),
                         np.float32)],
            config=ccfg))


def build_encoder(vocab, out_dir, emitted):
    print("[aot] encoder")
    specs = M.encoder_weight_specs(vocab)
    weights = M.init_weights(specs, WEIGHT_SEED + 777)
    weights_bin = "encoder.weights.bin"
    wentries = pack_weights(weights, os.path.join(out_dir, weights_bin))
    wspecs = [jax.ShapeDtypeStruct(s, F32) for _, s in specs]
    cfg = {"vocab": vocab, "encoder_len": ENCODER_LEN,
           "encoder_batch": ENCODER_BATCH, "retrieval_dim": RETRIEVAL_DIM}

    lowered = jax.jit(functools.partial(M.encode_query, vocab)).lower(
        *wspecs,
        jax.ShapeDtypeStruct((ENCODER_LEN,), I32),
        jax.ShapeDtypeStruct((), I32))
    emitted.append(write_artifact(
        out_dir, "encode_q", lowered, wentries, weights_bin,
        [_spec_entry("tokens", "arg", (ENCODER_LEN,), np.int32),
         _spec_entry("length", "arg", (), np.int32)],
        [_spec_entry("qvec", "out", (RETRIEVAL_DIM,), np.float32)],
        config=cfg))

    lowered = jax.jit(functools.partial(M.encode_batch, vocab)).lower(
        *wspecs,
        jax.ShapeDtypeStruct((ENCODER_BATCH, ENCODER_LEN), I32),
        jax.ShapeDtypeStruct((ENCODER_BATCH,), I32))
    emitted.append(write_artifact(
        out_dir, "encode_batch", lowered, wentries, weights_bin,
        [_spec_entry("tokens", "arg", (ENCODER_BATCH, ENCODER_LEN), np.int32),
         _spec_entry("lens", "arg", (ENCODER_BATCH,), np.int32)],
        [_spec_entry("qvecs", "out", (ENCODER_BATCH, RETRIEVAL_DIM),
                     np.float32)],
        config=cfg))


def build_score(out_dir, emitted):
    print("[aot] score_dense (Pallas scoring kernel)")
    lowered = jax.jit(M.score_dense).lower(
        jax.ShapeDtypeStruct((SCORE_BATCH, RETRIEVAL_DIM), F32),
        jax.ShapeDtypeStruct((SCORE_TILE, RETRIEVAL_DIM), F32))
    emitted.append(write_artifact(
        out_dir, "score_dense", lowered, [], None,
        [_spec_entry("queries", "arg", (SCORE_BATCH, RETRIEVAL_DIM),
                     np.float32),
         _spec_entry("corpus_tile", "arg", (SCORE_TILE, RETRIEVAL_DIM),
                     np.float32)],
        [_spec_entry("scores", "out", (SCORE_BATCH, SCORE_TILE), np.float32)],
        config={"score_batch": SCORE_BATCH, "score_tile": SCORE_TILE,
                "retrieval_dim": RETRIEVAL_DIM}))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", nargs="*", default=sorted(LM_CONFIGS),
                    help="subset of LM configs to build")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    emitted = []
    vocab = next(iter(LM_CONFIGS.values())).vocab
    build_encoder(vocab, args.out_dir, emitted)
    build_score(args.out_dir, emitted)
    for name in args.models:
        build_lm(LM_CONFIGS[name], args.out_dir, emitted)

    index = {
        "artifacts": emitted,
        "lm_configs": {n: c.to_dict() for n, c in LM_CONFIGS.items()
                       if n in args.models},
        "retrieval_dim": RETRIEVAL_DIM,
        "encoder_len": ENCODER_LEN,
        "encoder_batch": ENCODER_BATCH,
        "score_batch": SCORE_BATCH,
        "score_tile": SCORE_TILE,
        "datastore_chunk": DATASTORE_CHUNK,
        "weight_seed": WEIGHT_SEED,
    }
    with open(os.path.join(args.out_dir, "index.json"), "w") as f:
        json.dump(index, f, indent=1)
    print(f"[aot] wrote {len(emitted)} artifacts to {args.out_dir}")


if __name__ == "__main__":
    main()
