"""L2: JAX model definitions (decoder-only LM + query/passage encoder).

Everything here is build-time only: `aot.py` lowers these functions once to
HLO text; the Rust runtime executes them via PJRT. Weights are *runtime
inputs* (uploaded once by Rust as device buffers), not HLO constants, so the
HLO stays small and one graph serves any seed.

The attention hot-spot is the L1 Pallas kernel (`kernels.attention`); the
dense-retrieval scoring artifact uses `kernels.scoring`.

Weight layout: `lm_weight_specs(cfg)` / `encoder_weight_specs()` return an
*ordered* list of (name, shape) — the single source of truth for the
manifest, the packed `.weights.bin`, and the HLO parameter order.
"""

import jax
import jax.numpy as jnp

from .configs import RETRIEVAL_DIM, ModelConfig
from .kernels.attention import mha_decode, mha_prefill
from .kernels.scoring import score_batch

# ---------------------------------------------------------------------------
# Weight specs (ordered; shared by init, packing, manifest, HLO params)
# ---------------------------------------------------------------------------

ENCODER_D = 128
ENCODER_HIDDEN = 256


def lm_weight_specs(cfg: ModelConfig):
    """Ordered (name, shape) list for one LM config."""
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab
    specs = [
        ("tok_emb", (v, d)),
        ("pos_emb", (cfg.max_ctx, d)),
    ]
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        specs += [
            (p + "ln1_w", (d,)), (p + "ln1_b", (d,)),
            (p + "wq", (d, d)), (p + "wk", (d, d)),
            (p + "wv", (d, d)), (p + "wo", (d, d)),
            (p + "ln2_w", (d,)), (p + "ln2_b", (d,)),
            (p + "w1", (d, f)), (p + "b1", (f,)),
            (p + "w2", (f, d)), (p + "b2", (d,)),
        ]
    specs += [
        ("lnf_w", (d,)), ("lnf_b", (d,)),
        # Retrieval-space projection of the final hidden state (KNN-LM
        # datastore keys / per-token query embeddings).
        ("w_proj", (d, RETRIEVAL_DIM)),
    ]
    return specs


def encoder_weight_specs(vocab: int):
    """Ordered (name, shape) list for the shared query/passage encoder."""
    return [
        ("enc_emb", (vocab, ENCODER_D)),
        ("enc_w1", (ENCODER_D, ENCODER_HIDDEN)),
        ("enc_b1", (ENCODER_HIDDEN,)),
        ("enc_w2", (ENCODER_HIDDEN, RETRIEVAL_DIM)),
        ("enc_b2", (RETRIEVAL_DIM,)),
    ]


def init_weights(specs, seed: int):
    """Deterministic init; LN weights 1 / biases 0 / matrices N(0, 1/fan_in)."""
    key = jax.random.PRNGKey(seed)
    out = []
    for name, shape in specs:
        key, sub = jax.random.split(key)
        base = name.split(".")[-1]
        if base in ("ln1_b", "ln2_b", "lnf_b", "b1", "b2", "enc_b1", "enc_b2"):
            w = jnp.zeros(shape, jnp.float32)
        elif base in ("ln1_w", "ln2_w", "lnf_w"):
            w = jnp.ones(shape, jnp.float32)
        else:
            sigma = (1.0 / shape[0]) ** 0.5
            w = jax.random.normal(sub, shape, jnp.float32) * sigma
        out.append((name, w))
    return out


def _as_dict(specs, args):
    assert len(specs) == len(args), (len(specs), len(args))
    return {name: a for (name, _), a in zip(specs, args)}


# ---------------------------------------------------------------------------
# Transformer blocks
# ---------------------------------------------------------------------------

def _layer_norm(x, w, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * w + b


def _split_heads(x, n_heads):
    # [T, D] -> [H, T, Dh]
    t, d = x.shape
    return x.reshape(t, n_heads, d // n_heads).transpose(1, 0, 2)


def _merge_heads(x):
    # [H, T, Dh] -> [T, D]
    h, t, dh = x.shape
    return x.transpose(1, 0, 2).reshape(t, h * dh)


def _block_prefill(w, i, x, valid_len, cfg, interpret):
    p = f"layer{i}."
    a = _layer_norm(x, w[p + "ln1_w"], w[p + "ln1_b"])
    q = _split_heads(a @ w[p + "wq"], cfg.n_heads)
    k = _split_heads(a @ w[p + "wk"], cfg.n_heads)
    v = _split_heads(a @ w[p + "wv"], cfg.n_heads)
    attn = mha_prefill(q, k, v, valid_len, interpret=interpret)
    x = x + _merge_heads(attn) @ w[p + "wo"]
    m = _layer_norm(x, w[p + "ln2_w"], w[p + "ln2_b"])
    x = x + (jax.nn.gelu(m @ w[p + "w1"] + w[p + "b1"])) @ w[p + "w2"] \
        + w[p + "b2"]
    return x, k, v


def lm_prefill(cfg: ModelConfig, *args, interpret=True):
    """Prefill over a padded token window.

    args = (*weights, tokens i32[prefill_len], valid_len i32[]).
    Returns (kv f32[L, 2, H, max_ctx, Dh], logits f32[vocab], qproj f32[dr]):
    the KV cache (padded out to max_ctx slots), next-token logits at the last
    valid position, and the retrieval-space projection of its hidden state.
    """
    specs = lm_weight_specs(cfg)
    w = _as_dict(specs, args[:len(specs)])
    tokens, valid_len = args[len(specs):]
    t = cfg.prefill_len
    x = w["tok_emb"][tokens] + w["pos_emb"][:t]
    kv_layers = []
    for i in range(cfg.n_layers):
        x, k, v = _block_prefill(w, i, x, valid_len, cfg, interpret)
        kv_layers.append(jnp.stack([k, v]))  # [2, H, T, Dh]
    kv = jnp.stack(kv_layers)  # [L, 2, H, T, Dh]
    if cfg.max_ctx > t:
        pad = jnp.zeros((cfg.n_layers, 2, cfg.n_heads, cfg.max_ctx - t,
                         cfg.d_head), kv.dtype)
        kv = jnp.concatenate([kv, pad], axis=3)
    x = _layer_norm(x, w["lnf_w"], w["lnf_b"])
    last = x[valid_len - 1]  # [D]
    logits = last @ w["tok_emb"].T
    qproj = last @ w["w_proj"]
    qproj = qproj / jnp.maximum(jnp.linalg.norm(qproj), 1e-9)
    return kv, logits, qproj


def lm_decode(cfg: ModelConfig, *args, interpret=True):
    """One decode step against the KV cache.

    args = (*weights, token i32[], pos i32[], kv f32[L,2,H,max_ctx,Dh]).
    Writes the new K/V at slot `pos`, attends over 0..=pos, and returns
    (logits f32[vocab], kv' f32[L,2,H,max_ctx,Dh], qproj f32[dr]).
    """
    specs = lm_weight_specs(cfg)
    w = _as_dict(specs, args[:len(specs)])
    token, pos, kv = args[len(specs):]
    x = w["tok_emb"][token] + w["pos_emb"][pos]  # [D]
    new_kv = []
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        a = _layer_norm(x, w[p + "ln1_w"], w[p + "ln1_b"])
        q = (a @ w[p + "wq"]).reshape(cfg.n_heads, cfg.d_head)
        k = (a @ w[p + "wk"]).reshape(cfg.n_heads, cfg.d_head)
        v = (a @ w[p + "wv"]).reshape(cfg.n_heads, cfg.d_head)
        k_cache = jax.lax.dynamic_update_slice(
            kv[i, 0], k[:, None, :], (0, pos, 0))
        v_cache = jax.lax.dynamic_update_slice(
            kv[i, 1], v[:, None, :], (0, pos, 0))
        new_kv.append(jnp.stack([k_cache, v_cache]))
        attn = mha_decode(q, k_cache, v_cache, pos, interpret=interpret)
        x = x + attn.reshape(cfg.d_model) @ w[p + "wo"]
        m = _layer_norm(x, w[p + "ln2_w"], w[p + "ln2_b"])
        x = x + (jax.nn.gelu(m @ w[p + "w1"] + w[p + "b1"])) @ w[p + "w2"] \
            + w[p + "b2"]
    kv_out = jnp.stack(new_kv)
    x = _layer_norm(x, w["lnf_w"], w["lnf_b"])
    logits = x @ w["tok_emb"].T
    qproj = x @ w["w_proj"]
    qproj = qproj / jnp.maximum(jnp.linalg.norm(qproj), 1e-9)
    return logits, kv_out, qproj


def _decode_core(cfg, w, token, pos, kv, interpret):
    """Shared single-step decode: returns (logits, kv', hidden)."""
    x = w["tok_emb"][token] + w["pos_emb"][pos]  # [D]
    new_kv = []
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        a = _layer_norm(x, w[p + "ln1_w"], w[p + "ln1_b"])
        q = (a @ w[p + "wq"]).reshape(cfg.n_heads, cfg.d_head)
        k = (a @ w[p + "wk"]).reshape(cfg.n_heads, cfg.d_head)
        v = (a @ w[p + "wv"]).reshape(cfg.n_heads, cfg.d_head)
        k_cache = jax.lax.dynamic_update_slice(
            kv[i, 0], k[:, None, :], (0, pos, 0))
        v_cache = jax.lax.dynamic_update_slice(
            kv[i, 1], v[:, None, :], (0, pos, 0))
        new_kv.append(jnp.stack([k_cache, v_cache]))
        attn = mha_decode(q, k_cache, v_cache, pos, interpret=interpret)
        x = x + attn.reshape(cfg.d_model) @ w[p + "wo"]
        m = _layer_norm(x, w[p + "ln2_w"], w[p + "ln2_b"])
        x = x + (jax.nn.gelu(m @ w[p + "w1"] + w[p + "b1"])) @ w[p + "w2"] \
            + w[p + "b2"]
    kv_out = jnp.stack(new_kv)
    x = _layer_norm(x, w["lnf_w"], w["lnf_b"])
    logits = x @ w["tok_emb"].T
    return logits, kv_out, x


def lm_decode_chunk(cfg: ModelConfig, chunk: int, *args, interpret=True):
    """Greedy-decode a chunk of `chunk` tokens in one call.

    args = (*weights, first_token i32[], pos i32[], kv).
    Appends `first_token` at `pos`, then greedily (argmax, ties -> lowest id,
    matching `util::argmax` on the Rust side) selects and appends chunk-1
    more tokens. Returns (tokens i32[chunk] — the appended tokens, with
    tokens[0] == first_token — logits f32[vocab] at the last position,
    kv', qproj f32[dr]).

    This is the serving hot path for the QA pipelines: one PJRT call (and
    one KV round-trip) per generation interval instead of per token — see
    EXPERIMENTS.md §Perf.
    """
    specs = lm_weight_specs(cfg)
    w = _as_dict(specs, args[:len(specs)])
    first_token, pos, kv = args[len(specs):]
    token = first_token
    toks = []
    logits = None
    hidden = None
    for j in range(chunk):
        logits, kv, hidden = _decode_core(cfg, w, token, pos + j, kv,
                                          interpret)
        toks.append(token)
        token = jnp.argmax(logits).astype(jnp.int32)
    qproj = hidden @ w["w_proj"]
    qproj = qproj / jnp.maximum(jnp.linalg.norm(qproj), 1e-9)
    return jnp.stack(toks), logits, kv, qproj


def lm_hidden(cfg: ModelConfig, *args, interpret=True):
    """Per-position retrieval-space hidden states (KNN-LM datastore builder).

    args = (*weights, tokens i32[prefill_len], valid_len i32[]).
    Runs a causal forward over the chunk and returns the *projected,
    normalized* hidden state at every position: f32[prefill_len, dr].
    Position i's vector is the KNN-LM key whose value is token i+1.
    """
    specs = lm_weight_specs(cfg)
    w = _as_dict(specs, args[:len(specs)])
    tokens, valid_len = args[len(specs):]
    t = tokens.shape[0]
    x = w["tok_emb"][tokens] + w["pos_emb"][:t]
    for i in range(cfg.n_layers):
        x, _, _ = _block_prefill(w, i, x, valid_len, cfg, interpret)
    x = _layer_norm(x, w["lnf_w"], w["lnf_b"])
    proj = x @ w["w_proj"]  # [T, dr]
    norm = jnp.maximum(jnp.linalg.norm(proj, axis=-1, keepdims=True), 1e-9)
    return (proj / norm,)


# ---------------------------------------------------------------------------
# Query / passage encoder (shared embedding space, DPR stand-in)
# ---------------------------------------------------------------------------

def _encode_one(w, tokens, length):
    emb = w["enc_emb"][tokens]  # [Tq, De]
    mask = (jnp.arange(tokens.shape[0]) < length)[:, None]
    pooled = jnp.sum(emb * mask, axis=0) / jnp.maximum(length, 1)
    h = jax.nn.gelu(pooled @ w["enc_w1"] + w["enc_b1"])
    out = h @ w["enc_w2"] + w["enc_b2"]
    return out / jnp.maximum(jnp.linalg.norm(out), 1e-9)


def encode_query(vocab: int, *args):
    """args = (*enc_weights, tokens i32[ENCODER_LEN], length i32[]) -> (f32[dr],)."""
    specs = encoder_weight_specs(vocab)
    w = _as_dict(specs, args[:len(specs)])
    tokens, length = args[len(specs):]
    return (_encode_one(w, tokens, length),)


def encode_batch(vocab: int, *args):
    """args = (*enc_weights, tokens i32[B, Tq], lens i32[B]) -> (f32[B, dr],)."""
    specs = encoder_weight_specs(vocab)
    w = _as_dict(specs, args[:len(specs)])
    tokens, lens = args[len(specs):]
    return (jax.vmap(lambda t, l: _encode_one(w, t, l))(tokens, lens),)


# ---------------------------------------------------------------------------
# Dense scoring artifact (Pallas scoring kernel)
# ---------------------------------------------------------------------------

def score_dense(queries, corpus_tile, interpret=True):
    """queries f32[B, dr] x corpus_tile f32[N, dr] -> (scores f32[B, N],)."""
    return (score_batch(queries, corpus_tile, interpret=interpret),)
