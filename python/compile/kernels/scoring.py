"""L1 Pallas dense-retrieval scoring kernel.

Computes inner-product scores between a batch of query embeddings and a tile
of corpus/passage embeddings: ``scores[b, n] = <q[b], c[n]>``. This is the
hot inner loop of the exact dense retriever (the role FAISS IndexFlatIP plays
in the paper) and of batched verification, expressed as an MXU-friendly
``[B, dr] x [dr, tile]`` matmul.

TPU mapping: the grid streams corpus tiles HBM→VMEM (one
``[tile_n, dr]`` block per step, BlockSpec-indexed) while the query block
stays VMEM-resident — the BlockSpec version of the corpus-chunk streaming
FAISS does with CUDA threadblocks. ``interpret=True`` on this image.

Oracle: ``ref.score_ref``; swept by hypothesis in test_kernels.py.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _score_kernel(q_ref, c_ref, o_ref):
    # q_ref: [batch, dr]; c_ref: [tile_n, dr]; o_ref: [batch, tile_n]
    q = q_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)
    o_ref[...] = (q @ c.T).astype(o_ref.dtype)


def score_batch(queries, corpus, *, tile_n=512, interpret=True):
    """Inner-product scores: queries [B, dr] x corpus [N, dr] -> [B, N].

    N must be divisible by tile_n (the AOT artifact fixes N = SCORE_TILE and
    the Rust side chunks + pads the corpus).
    """
    b, dr = queries.shape
    n, dr2 = corpus.shape
    assert dr == dr2, f"dim mismatch {dr} vs {dr2}"
    assert n % tile_n == 0, f"N={n} not divisible by tile_n={tile_n}"
    return pl.pallas_call(
        _score_kernel,
        grid=(n // tile_n,),
        in_specs=[
            pl.BlockSpec((b, dr), lambda j: (0, 0)),
            pl.BlockSpec((tile_n, dr), lambda j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((b, tile_n), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        interpret=interpret,
    )(queries, corpus)
