"""Pure-jnp correctness oracles for the L1 Pallas kernels.

These are the CORE correctness signal: every kernel must match its oracle
to float32 tolerance over hypothesis-swept shapes (test_kernels.py), and the
L2 model is *also* cross-checked against a full oracle-only forward pass
(test_model.py), so a kernel bug cannot hide behind the model.
"""

import jax.numpy as jnp

NEG_INF = -1e30


def mha_prefill_ref(q, k, v, valid_len):
    """Causal masked MHA. q,k,v: [H, T, Dh]; valid_len: scalar. -> [H, T, Dh]."""
    h, t, dh = q.shape
    scale = 1.0 / (dh ** 0.5)
    s = jnp.einsum("htd,hsd->hts", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(t)[:, None]
    k_pos = jnp.arange(t)[None, :]
    mask = (k_pos <= q_pos) & (k_pos < valid_len)
    s = jnp.where(mask[None, :, :], s, NEG_INF)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("hts,hsd->htd", p, v.astype(jnp.float32)).astype(q.dtype)


def mha_decode_ref(q, k_cache, v_cache, pos):
    """Single-query MHA over cache slots 0..=pos.

    q: [H, Dh]; caches: [H, T, Dh]; pos: scalar. -> [H, Dh].
    """
    h, t, dh = k_cache.shape
    scale = 1.0 / (dh ** 0.5)
    s = jnp.einsum("hd,htd->ht", q.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    mask = jnp.arange(t)[None, :] <= pos
    s = jnp.where(mask, s, NEG_INF)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("ht,htd->hd", p, v_cache.astype(jnp.float32)).astype(q.dtype)


def score_ref(queries, corpus):
    """Inner-product scores. queries: [B, dr]; corpus: [N, dr] -> [B, N]."""
    return (queries.astype(jnp.float32) @ corpus.astype(jnp.float32).T)
