"""L1 Pallas attention kernels (flash-attention-style, VMEM-tiled).

Two kernels, both with an online-softmax accumulator so only
O(block_q x block_k) score tiles ever materialize:

* ``mha_prefill``   — full causal multi-head attention over a padded
  sequence (used by the ``prefill_*`` artifacts).
* ``mha_decode``    — single-query attention against the KV cache (used by
  the ``decode_*`` artifacts, one call per generated token).

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid iterates
(head, q-block); each step holds one `[block_q, d_head]` Q tile plus one
`[block_k, d_head]` K/V tile in VMEM and drives the MXU with
`[block_q, block_k]` score matmuls — the TPU analogue of the GPU
flash-attention threadblock schedule. On this image the kernels run with
``interpret=True`` (CPU PJRT cannot execute Mosaic custom-calls); structure,
not interpret-mode wallclock, is what carries to real hardware.

Correctness oracle: ``kernels/ref.py`` (pure jnp), enforced by
``python/tests/test_kernels.py`` with hypothesis shape sweeps.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30
DEFAULT_BLOCK_Q = 64
DEFAULT_BLOCK_K = 64


def _prefill_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, *, block_q, block_k,
                    seq_len, scale):
    """One (head, q-block) grid step of causal prefill attention.

    q_ref: [block_q, d_head]   (this head / q-block tile)
    k_ref, v_ref: [seq_len, d_head]  (this head, full sequence)
    len_ref: [1]               (valid prefix length; tokens >= len are pad)
    o_ref: [block_q, d_head]
    """
    iq = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32) * scale
    d_head = q.shape[-1]
    valid_len = len_ref[0]
    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0)

    n_kb = seq_len // block_k

    def body(j, carry):
        m_prev, l_prev, acc = carry
        k = pl.load(k_ref, (pl.dslice(j * block_k, block_k), slice(None)))
        v = pl.load(v_ref, (pl.dslice(j * block_k, block_k), slice(None)))
        s = q @ k.astype(jnp.float32).T  # [block_q, block_k]
        k_pos = j * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_k), 1)
        mask = (k_pos <= q_pos) & (k_pos < valid_len)
        s = jnp.where(mask, s, NEG_INF)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_cur)
        alpha = jnp.exp(m_prev - m_cur)
        l_cur = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + p @ v.astype(jnp.float32)
        return m_cur, l_cur, acc

    m0 = jnp.full((block_q, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q, 1), jnp.float32)
    acc0 = jnp.zeros((block_q, d_head), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, n_kb, body, (m0, l0, acc0))
    # Rows that saw no valid key (can't happen for q_pos < valid_len, but
    # padded rows may) would divide by ~0; clamp to keep numerics finite.
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def mha_prefill(q, k, v, valid_len, *, block_q=DEFAULT_BLOCK_Q,
                block_k=DEFAULT_BLOCK_K, interpret=True):
    """Causal MHA over a padded sequence.

    q, k, v: [n_heads, seq_len, d_head]; valid_len: int32 scalar array.
    Returns [n_heads, seq_len, d_head]. seq_len must be divisible by the
    block sizes (the AOT layer always pads to prefill_len).
    """
    n_heads, seq_len, d_head = q.shape
    assert seq_len % block_q == 0 and seq_len % block_k == 0, (
        f"seq_len={seq_len} not divisible by blocks ({block_q},{block_k})")
    scale = 1.0 / (d_head ** 0.5)
    len_arr = jnp.reshape(valid_len.astype(jnp.int32), (1,))
    grid = (n_heads, seq_len // block_q)
    kernel = functools.partial(_prefill_kernel, block_q=block_q,
                               block_k=block_k, seq_len=seq_len, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, d_head), lambda h, i: (h, i, 0)),
            pl.BlockSpec((None, seq_len, d_head), lambda h, i: (h, 0, 0)),
            pl.BlockSpec((None, seq_len, d_head), lambda h, i: (h, 0, 0)),
            pl.BlockSpec((1,), lambda h, i: (0,)),
        ],
        out_specs=pl.BlockSpec((None, block_q, d_head), lambda h, i: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(q, k, v, len_arr)


def _decode_kernel(q_ref, k_ref, v_ref, pos_ref, o_ref, *, block_k, seq_len,
                   scale):
    """One head's single-query attention against the KV cache.

    q_ref: [1, d_head]; k_ref, v_ref: [seq_len, d_head]; pos_ref: [1]
    o_ref: [1, d_head].  Attends over cache slots 0..=pos (the new token's
    K/V has already been written at slot `pos` by the L2 graph).
    """
    q = q_ref[...].astype(jnp.float32) * scale  # [1, d_head]
    d_head = q.shape[-1]
    pos = pos_ref[0]
    n_kb = seq_len // block_k

    def body(j, carry):
        m_prev, l_prev, acc = carry
        k = pl.load(k_ref, (pl.dslice(j * block_k, block_k), slice(None)))
        v = pl.load(v_ref, (pl.dslice(j * block_k, block_k), slice(None)))
        s = q @ k.astype(jnp.float32).T  # [1, block_k]
        k_pos = j * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_k), 1)
        s = jnp.where(k_pos <= pos, s, NEG_INF)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_cur)
        alpha = jnp.exp(m_prev - m_cur)
        l_cur = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + p @ v.astype(jnp.float32)
        return m_cur, l_cur, acc

    m0 = jnp.full((1, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((1, 1), jnp.float32)
    acc0 = jnp.zeros((1, d_head), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, n_kb, body, (m0, l0, acc0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def mha_decode(q, k_cache, v_cache, pos, *, block_k=DEFAULT_BLOCK_K,
               interpret=True):
    """Single-token MHA against the KV cache.

    q: [n_heads, d_head]; k_cache, v_cache: [n_heads, seq_len, d_head];
    pos: int32 scalar array (index of the token being decoded).
    Returns [n_heads, d_head].
    """
    n_heads, seq_len, d_head = k_cache.shape
    assert seq_len % block_k == 0
    scale = 1.0 / (d_head ** 0.5)
    pos_arr = jnp.reshape(pos.astype(jnp.int32), (1,))
    q3 = q[:, None, :]  # [n_heads, 1, d_head]
    kernel = functools.partial(_decode_kernel, block_k=block_k,
                               seq_len=seq_len, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(n_heads,),
        in_specs=[
            pl.BlockSpec((None, 1, d_head), lambda h: (h, 0, 0)),
            pl.BlockSpec((None, seq_len, d_head), lambda h: (h, 0, 0)),
            pl.BlockSpec((None, seq_len, d_head), lambda h: (h, 0, 0)),
            pl.BlockSpec((1,), lambda h: (0,)),
        ],
        out_specs=pl.BlockSpec((None, 1, d_head), lambda h: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_heads, 1, d_head), q.dtype),
        interpret=interpret,
    )(q3, k_cache, v_cache, pos_arr)
    return out[:, 0, :]
