//! cargo-bench driver for paper artifact "table3" (see DESIGN.md §5).
//! Small default scale; env RALMSPEC_BENCH_* overrides. The full-scale
//! reproduction is `ralmspec bench table3`.
fn main() {
    if let Err(e) = ralmspec::eval::drivers::bench_entry("table3") {
        eprintln!("bench table3 failed: {e:#}");
        std::process::exit(1);
    }
}
