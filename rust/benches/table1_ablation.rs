//! cargo-bench driver for paper artifact "table1" (see DESIGN.md §5).
//! Small default scale; env RALMSPEC_BENCH_* overrides. The full-scale
//! reproduction is `ralmspec bench table1`.
fn main() {
    if let Err(e) = ralmspec::eval::drivers::bench_entry("table1") {
        eprintln!("bench table1 failed: {e:#}");
        std::process::exit(1);
    }
}
