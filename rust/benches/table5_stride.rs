//! cargo-bench driver for paper artifact "table5" (see DESIGN.md §5).
//! Small default scale; env RALMSPEC_BENCH_* overrides. The full-scale
//! reproduction is `ralmspec bench table5`.
fn main() {
    if let Err(e) = ralmspec::eval::drivers::bench_entry("table5") {
        eprintln!("bench table5 failed: {e:#}");
        std::process::exit(1);
    }
}
