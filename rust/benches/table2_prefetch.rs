//! cargo-bench driver for paper artifact "table2" (see DESIGN.md §5).
//! Small default scale; env RALMSPEC_BENCH_* overrides. The full-scale
//! reproduction is `ralmspec bench table2`.
fn main() {
    if let Err(e) = ralmspec::eval::drivers::bench_entry("table2") {
        eprintln!("bench table2 failed: {e:#}");
        std::process::exit(1);
    }
}
