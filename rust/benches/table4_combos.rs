//! cargo-bench driver for paper artifact "table4" (see DESIGN.md §5).
//! Small default scale; env RALMSPEC_BENCH_* overrides. The full-scale
//! reproduction is `ralmspec bench table4`.
fn main() {
    if let Err(e) = ralmspec::eval::drivers::bench_entry("table4") {
        eprintln!("bench table4 failed: {e:#}");
        std::process::exit(1);
    }
}
