//! cargo-bench driver for paper artifact "fig6" (see DESIGN.md §5).
//! Small default scale; env RALMSPEC_BENCH_* overrides. The full-scale
//! reproduction is `ralmspec bench fig6`.
fn main() {
    if let Err(e) = ralmspec::eval::drivers::bench_entry("fig6") {
        eprintln!("bench fig6 failed: {e:#}");
        std::process::exit(1);
    }
}
