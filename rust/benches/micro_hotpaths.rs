//! Micro-benchmarks of the L3 hot paths (in-tree harness — no criterion on
//! this image): dense scan, HNSW walk, BM25 postings, cache lookup, top-k.
//! Run via `cargo bench micro` or directly.
//!
//! The per-kernel cells up front are the *same* measurement
//! `ralmspec bench-gate --kernel-out` gates in CI
//! (`ralmspec::eval::kernel_bench`): one implementation, two surfaces —
//! tune here, gate there.

use ralmspec::cache::LocalCache;
use ralmspec::config::{Config, CorpusConfig, RetrieverKind};
use ralmspec::datagen::{Encoder, HashEncoder};
use ralmspec::eval::TestBed;
use ralmspec::retriever::{kernels, Retriever, SpecQuery};
use ralmspec::util::{topk_from_scores, Rng};
use std::time::Instant;

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) {
    // warmup
    for _ in 0..iters.div_ceil(10) {
        f();
    }
    let t = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t.elapsed().as_secs_f64() / iters as f64;
    let (v, unit) = if per >= 1e-3 {
        (per * 1e3, "ms")
    } else {
        (per * 1e6, "us")
    };
    println!("{name:<40} {v:>10.2} {unit}/iter  ({iters} iters)");
}

fn main() {
    // Shared per-kernel cells (the bench-gate BENCH_PR6.json trajectory).
    println!("kernel cells (simd_active={}):", kernels::simd_active());
    ralmspec::eval::kernel_bench::print_cells(
        &ralmspec::eval::kernel_bench::run_kernel_cells());
    println!();

    // Shared SQ8 quantization cells (the BENCH_PR9.json trajectory):
    // quantized vs full-precision end-to-end flat scan per row count.
    let (_, quant) = ralmspec::eval::kernel_bench::run_quant_cells();
    ralmspec::eval::kernel_bench::print_quant_cells(&quant);
    println!();

    let mut cfg = Config::default();
    cfg.corpus = CorpusConfig { n_docs: 60_000, n_topics: 256,
                                ..CorpusConfig::default() };
    let enc = HashEncoder::new(ralmspec::runtime::RETRIEVAL_DIM, 1);
    eprintln!("building testbed (60k docs)...");
    let bed = TestBed::build(&cfg, &enc);
    let mut rng = Rng::new(2);
    let qd = SpecQuery::dense_only(enc.encode(&bed.corpus.doc(7).tokens));
    let qs = SpecQuery::sparse_only(bed.corpus.doc(7).tokens[..12].to_vec());

    let edr = bed.retriever(RetrieverKind::Edr);
    bench("EDR flat scan top-20 (60k x 64)", 50, || {
        let _ = edr.retrieve_topk(&qd, 20);
    });
    let batch: Vec<SpecQuery> = (0..8).map(|_| qd.clone()).collect();
    bench("EDR batched scan top-20 (batch 8)", 50, || {
        let _ = edr.retrieve_batch(&batch, 20);
    });

    let adr = bed.retriever(RetrieverKind::Adr);
    bench("ADR HNSW top-20", 2000, || {
        let _ = adr.retrieve_topk(&qd, 20);
    });

    let sr = bed.retriever(RetrieverKind::Sr);
    bench("SR BM25 top-20", 500, || {
        let _ = sr.retrieve_topk(&qs, 20);
    });
    let sbatch: Vec<SpecQuery> = (0..8).map(|_| qs.clone()).collect();
    bench("SR BM25 batched (batch 8)", 200, || {
        let _ = sr.retrieve_batch(&sbatch, 20);
    });

    let mut cache = LocalCache::new(4096);
    let ids: Vec<u32> = (0..256).map(|_| rng.gen_range(60_000) as u32).collect();
    cache.insert_ids(&ids);
    bench("cache lookup (256 entries, dense)", 5000, || {
        let _ = cache.retrieve(&qd, edr.as_ref());
    });

    let scores: Vec<f32> = (0..60_000).map(|_| rng.next_f32()).collect();
    bench("top-20 select over 60k scores", 500, || {
        let _ = topk_from_scores(&scores, 20);
    });

    bench("HashEncoder encode (32 tokens)", 5000, || {
        let _ = enc.encode(&bed.corpus.doc(9).tokens);
    });
}
