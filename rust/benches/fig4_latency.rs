//! cargo-bench driver for paper artifact "fig4" (see DESIGN.md §5).
//! Small default scale; env RALMSPEC_BENCH_* overrides. The full-scale
//! reproduction is `ralmspec bench fig4`.
fn main() {
    if let Err(e) = ralmspec::eval::drivers::bench_entry("fig4") {
        eprintln!("bench fig4 failed: {e:#}");
        std::process::exit(1);
    }
}
