//! cargo-bench driver for paper artifact "fig5" (see DESIGN.md §5).
//! Small default scale; env RALMSPEC_BENCH_* overrides. The full-scale
//! reproduction is `ralmspec bench fig5`.
fn main() {
    if let Err(e) = ralmspec::eval::drivers::bench_entry("fig5") {
        eprintln!("bench fig5 failed: {e:#}");
        std::process::exit(1);
    }
}
