//! Offline stand-in for the `anyhow` crate: the API subset this workspace
//! uses (`Result`, `Error`, `anyhow!`, `bail!`, `ensure!`), implemented on
//! std only so the build needs no registry access.
//!
//! Semantics mirror anyhow 1.x where it matters here:
//!   * `Error` is a cheap opaque box with a `Display` message;
//!   * any `std::error::Error + Send + Sync + 'static` converts via `?`
//!     (the blanket `From` below — which is also why `Error` itself must
//!     not implement `std::error::Error`);
//!   * `{:#}` (alternate) formatting appends the source chain.

use std::fmt;

/// `Result` with a defaulted error type, as in anyhow.
pub type Result<T, E = Error> = std::result::Result<T, E>;

pub struct Error(Box<ErrorImpl>);

struct ErrorImpl {
    msg: String,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    /// Construct from any displayable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error(Box::new(ErrorImpl { msg: message.to_string(), source: None }))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0.msg)?;
        if f.alternate() {
            // The stored root's own message already IS `msg`; append only
            // the transitive sources.
            if let Some(root) = self.0.source.as_deref() {
                let mut src = root.source();
                while let Some(s) = src {
                    write!(f, ": {s}")?;
                    src = s.source();
                }
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0.msg)?;
        if let Some(root) = self.0.source.as_deref() {
            let mut src = root.source();
            if src.is_some() {
                write!(f, "\n\nCaused by:")?;
            }
            while let Some(s) = src {
                write!(f, "\n    {s}")?;
                src = s.source();
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error(Box::new(ErrorImpl { msg: e.to_string(), source: Some(Box::new(e)) }))
    }
}

/// Construct an [`Error`] from a format string (or any displayable expr).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an [`Error`] if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(concat!(
                "Condition failed: `", stringify!($cond), "`")));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read_to_string("/definitely/not/a/path/ever")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn macros_compose() {
        fn inner(x: usize) -> Result<usize> {
            ensure!(x > 1, "x too small: {x}");
            if x > 10 {
                bail!("x too big: {}", x);
            }
            Ok(x)
        }
        assert_eq!(inner(5).unwrap(), 5);
        assert!(inner(0).unwrap_err().to_string().contains("too small"));
        assert!(inner(11).unwrap_err().to_string().contains("too big"));
        let e = anyhow!("plain {} message", 7);
        assert_eq!(e.to_string(), "plain 7 message");
    }

    #[test]
    fn alternate_formatting_includes_sources() {
        let e = io_fail().unwrap_err();
        // No panic; the plain and alternate forms both render.
        let _ = format!("{e} / {e:#} / {e:?}");
    }
}
