//! Offline stub of the `xla` crate (PJRT bindings) — the exact API
//! surface `ralmspec::runtime` consumes, with no native XLA behind it.
//!
//! The real bindings need the `xla_extension` C++ distribution, which the
//! offline image does not carry. This stub lets the whole crate build and
//! every mock-mode path run; anything that actually needs PJRT fails at
//! the single entry point (`PjRtClient::cpu`) with a clear error. All
//! downstream types are uninhabited, so the compiler itself proves no
//! stubbed compute path can be reached. Swap this path dependency for the
//! real `xla` crate to enable PJRT execution.

use std::fmt;

/// Uninhabited core: no value of any device-side type can exist.
#[derive(Debug, Clone, Copy)]
enum Void {}

#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable() -> Error {
    Error(
        "xla/PJRT is unavailable: this build uses the offline stub \
         (rust/vendor/xla). Mock mode (--mock) runs everything without \
         artifacts; for real PJRT execution, point the `xla` dependency \
         at the actual bindings."
            .to_string(),
    )
}

/// Element types PJRT buffers/literals can carry.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u32 {}

#[derive(Debug, Clone)]
pub struct PjRtClient(Void);

#[derive(Debug)]
pub struct PjRtBuffer(Void);

#[derive(Debug)]
pub struct PjRtLoadedExecutable(Void);

#[derive(Debug)]
pub struct Literal(Void);

#[derive(Debug)]
pub struct HloModuleProto(Void);

#[derive(Debug)]
pub struct XlaComputation(Void);

impl PjRtClient {
    /// The single runtime entry point — and the single failure point of
    /// the stub.
    pub fn cpu() -> Result<Self, Error> {
        Err(unavailable())
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self, _data: &[T], _dims: &[usize], _device: Option<usize>)
        -> Result<PjRtBuffer, Error> {
        match self.0 {}
    }

    pub fn buffer_from_host_literal(&self, _device: Option<usize>,
                                    _lit: &Literal)
                                    -> Result<PjRtBuffer, Error> {
        match self.0 {}
    }

    pub fn compile(&self, _comp: &XlaComputation)
                   -> Result<PjRtLoadedExecutable, Error> {
        match self.0 {}
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        match self.0 {}
    }
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer])
                     -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        match self.0 {}
    }
}

impl Literal {
    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        match self.0 {}
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        match self.0 {}
    }
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self, Error> {
        Err(unavailable())
    }
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> Self {
        match proto.0 {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn entry_points_report_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("offline stub"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
