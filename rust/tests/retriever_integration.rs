//! Cross-retriever integration: rank preservation through the local cache,
//! batched-vs-sequential consistency, HNSW quality on the real synthetic
//! corpus, and the Fig-6 batching profiles (shape, not absolute time).

use ralmspec::cache::LocalCache;
use ralmspec::config::{Config, CorpusConfig, RetrieverKind};
use ralmspec::datagen::HashEncoder;
use ralmspec::eval::TestBed;
use ralmspec::retriever::{Retriever, SpecQuery};
use ralmspec::util::Rng;

fn bed(seed: u64, n_docs: usize) -> (Config, TestBed, HashEncoder) {
    let mut cfg = Config::default();
    cfg.corpus = CorpusConfig {
        n_docs,
        n_topics: 24,
        seed,
        ..CorpusConfig::default()
    };
    cfg.retriever.hnsw_ef_construction = 60;
    cfg.retriever.hnsw_ef_search = 48;
    let enc = HashEncoder::new(ralmspec::runtime::RETRIEVAL_DIM, seed);
    let b = TestBed::build(&cfg, &enc);
    (cfg, b, enc)
}

fn queries(bed: &TestBed, enc: &HashEncoder, n: usize, seed: u64)
           -> Vec<(SpecQuery, SpecQuery)> {
    use ralmspec::datagen::Encoder;
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            let topic = (i % bed.corpus.n_topics) as u32;
            let toks = bed.corpus.topic_tokens(topic, 12, &mut rng);
            (SpecQuery::dense_only(enc.encode(&toks)),
             SpecQuery::sparse_only(toks))
        })
        .collect()
}

/// Rank preservation (§3): whenever the KB top-1 document is inside the
/// cache, a cache lookup must return exactly that document — for all three
/// retriever classes, including HNSW (whose `score_doc` is exact).
#[test]
fn rank_preservation_all_retrievers() {
    let (_, bed, enc) = bed(1, 2_000);
    let qs = queries(&bed, &enc, 24, 2);
    let mut rng = Rng::new(3);
    for kind in RetrieverKind::all() {
        let kb = bed.retriever(kind);
        for (dense_q, sparse_q) in &qs {
            let q = match kind {
                RetrieverKind::Sr => sparse_q,
                _ => dense_q,
            };
            let truth = kb.retrieve_topk(q, 8);
            if truth.is_empty() {
                continue;
            }
            let mut cache = LocalCache::new(128);
            cache.insert(&truth);
            // plus random distractors
            let distract: Vec<u32> =
                (0..16).map(|_| rng.gen_range(bed.corpus.len()) as u32)
                       .collect();
            cache.insert_ids(&distract);
            let got = cache.retrieve(q, kb.as_ref()).unwrap();
            assert_eq!(got.id, truth[0].id, "kind={kind:?}");
        }
    }
}

/// Batched retrieval must return exactly the sequential results (the
/// verification step depends on it for output equivalence).
#[test]
fn batch_equals_sequential_all_retrievers() {
    let (_, bed, enc) = bed(4, 1_500);
    let qs = queries(&bed, &enc, 8, 5);
    for kind in RetrieverKind::all() {
        let kb = bed.retriever(kind);
        let batch: Vec<SpecQuery> = qs
            .iter()
            .map(|(d, s)| match kind {
                RetrieverKind::Sr => s.clone(),
                _ => d.clone(),
            })
            .collect();
        let together = kb.retrieve_batch(&batch, 6);
        for (q, t) in batch.iter().zip(&together) {
            let alone = kb.retrieve_topk(q, 6);
            assert_eq!(alone.iter().map(|s| s.id).collect::<Vec<_>>(),
                       t.iter().map(|s| s.id).collect::<Vec<_>>(),
                       "kind={kind:?}");
        }
    }
}

/// HNSW over the real synthetic corpus embeddings: recall@10 >= 0.8 vs the
/// flat scan (the paper's ADR trades exactly this accuracy for speed).
#[test]
fn hnsw_recall_on_corpus() {
    let (_, bed, enc) = bed(6, 4_000);
    let flat = bed.retriever(RetrieverKind::Edr);
    let hnsw = bed.retriever(RetrieverKind::Adr);
    let qs = queries(&bed, &enc, 30, 7);
    let mut hits = 0usize;
    let mut total = 0usize;
    for (dense_q, _) in &qs {
        let truth: std::collections::HashSet<u32> =
            flat.retrieve_topk(dense_q, 10).iter().map(|s| s.id).collect();
        for s in hnsw.retrieve_topk(dense_q, 10) {
            total += 1;
            hits += truth.contains(&s.id) as usize;
        }
    }
    let recall = hits as f64 / total as f64;
    assert!(recall >= 0.8, "recall@10 = {recall}");
}

/// Dense retrieval should surface on-topic documents (the locality the
/// speculation cache exploits).
#[test]
fn dense_retrieval_is_topical() {
    let (_, bed, enc) = bed(8, 3_000);
    let kb = bed.retriever(RetrieverKind::Edr);
    let mut rng = Rng::new(9);
    use ralmspec::datagen::Encoder;
    let mut on_topic = 0;
    let trials = 30;
    for i in 0..trials {
        let topic = (i % bed.corpus.n_topics) as u32;
        let toks = bed.corpus.topic_tokens(topic, 12, &mut rng);
        let q = SpecQuery::dense_only(enc.encode(&toks));
        let top = kb.retrieve(&q).unwrap();
        if bed.corpus.doc(top.id).topic == topic {
            on_topic += 1;
        }
    }
    assert!(on_topic * 2 >= trials,
            "only {on_topic}/{trials} retrievals on-topic");
}

/// Fig 6 *shape*: EDR batched retrieval amortizes — per-query latency at
/// batch 16 is measurably below the single-query latency. Only meaningful
/// with optimizations on; debug builds skip (timing there reflects
/// overhead, not the memory-vs-compute trade-off).
#[test]
fn fig6_batching_shapes() {
    if cfg!(debug_assertions) {
        eprintln!("skipped in debug build (timing-sensitive)");
        return;
    }
    let (_, bed, enc) = bed(10, 20_000);
    let qs = queries(&bed, &enc, 16, 11);
    let dense: Vec<SpecQuery> = qs.iter().map(|(d, _)| d.clone()).collect();
    let time_batch = |kb: &dyn Retriever, queries: &[SpecQuery]| -> f64 {
        // median of 5 trials for stability
        let mut ts: Vec<f64> = (0..5)
            .map(|_| {
                let t = std::time::Instant::now();
                let r = kb.retrieve_batch(queries, 10);
                assert_eq!(r.len(), queries.len());
                t.elapsed().as_secs_f64()
            })
            .collect();
        ts.sort_by(|a, b| a.partial_cmp(b).unwrap());
        ts[2]
    };
    let edr = bed.retriever(RetrieverKind::Edr);
    let t1 = time_batch(edr.as_ref(), &dense[..1]);
    let t16 = time_batch(edr.as_ref(), &dense[..16]);
    // EDR: one corpus pass for the whole batch — per-query cost must drop.
    let per_query_16 = t16 / 16.0;
    assert!(per_query_16 < t1 * 0.8,
            "EDR batch16 per-query {per_query_16:.6}s vs single {t1:.6}s — \
             no amortization");
}
