//! ADR-007 equivalence pins: the dispatched scoring kernels (SIMD when
//! active, scalar otherwise) must be **bitwise** identical to the chunked
//! scalar reference for every dimension shape — multiples of the lane
//! width, sub-lane vectors, and tails — and the dense batch path must be
//! bitwise reproducible from a hand-packed `scan_block_scalar` walk.
//!
//! These tests are the reason the `simd` feature can default on: on a
//! SIMD-capable host they pin `dispatch == scalar`, and the CI
//! `scalar-fallback` leg re-runs the whole suite with `simd` off, so
//! both sides of the feature gate produce one set of bits.

use ralmspec::retriever::dense::{DenseExact, EmbeddingMatrix};
use ralmspec::retriever::kernels::{self, LANES};
use ralmspec::retriever::{Retriever, SpecQuery};
use ralmspec::util::{Rng, TopK};
use std::sync::Arc;

/// Dimension sweep: sub-lane (7), exact lane (8), multiple (64),
/// multiple + 1 tail (65), larger multiple (128).
const DIMS: [usize; 5] = [7, 8, 64, 65, 128];

fn random_vec(rng: &mut Rng, d: usize) -> Vec<f32> {
    rng.unit_vector(d)
}

#[test]
fn dot_dispatch_bitwise_matches_scalar_across_dims() {
    let mut rng = Rng::new(0xE0_01);
    for &d in &DIMS {
        for _ in 0..32 {
            let a = random_vec(&mut rng, d);
            let b = random_vec(&mut rng, d);
            assert_eq!(
                kernels::dot(&a, &b).to_bits(),
                kernels::dot_scalar(&a, &b).to_bits(),
                "dot dispatch != scalar at d={d} (simd_active={})",
                kernels::simd_active()
            );
        }
    }
}

#[test]
fn l2_dispatch_bitwise_matches_scalar_across_dims() {
    let mut rng = Rng::new(0xE0_02);
    for &d in &DIMS {
        for _ in 0..32 {
            let a = random_vec(&mut rng, d);
            let b = random_vec(&mut rng, d);
            assert_eq!(
                kernels::l2_sq(&a, &b).to_bits(),
                kernels::l2_sq_scalar(&a, &b).to_bits(),
                "l2_sq dispatch != scalar at d={d} (simd_active={})",
                kernels::simd_active()
            );
        }
    }
}

/// Column-major query-block pack (lane `bi` holds query `bi`), the layout
/// `scan_block` consumes; padding lanes stay zero.
fn pack_qt(queries: &[Vec<f32>], d: usize) -> Vec<f32> {
    assert!(queries.len() <= LANES);
    let mut qt = vec![0.0f32; d * LANES];
    for (bi, q) in queries.iter().enumerate() {
        for (j, &v) in q.iter().enumerate() {
            qt[j * LANES + bi] = v;
        }
    }
    qt
}

#[test]
fn scan_block_dispatch_bitwise_matches_scalar_across_dims() {
    let mut rng = Rng::new(0xE0_03);
    // 97 rows: not a multiple of anything interesting, so heap contents
    // depend on every row being scored.
    let n_rows = 97usize;
    for &d in &DIMS {
        let mut data = Vec::with_capacity(n_rows * d);
        for _ in 0..n_rows {
            data.extend(random_vec(&mut rng, d));
        }
        // Partial (3-query) and full (LANES-query) blocks.
        for b in [3usize, LANES] {
            let queries: Vec<Vec<f32>> =
                (0..b).map(|_| random_vec(&mut rng, d)).collect();
            let qt = pack_qt(&queries, d);

            let mut heaps: Vec<TopK> =
                (0..b).map(|_| TopK::new(10)).collect();
            kernels::scan_block(&data, d, 0, &qt, &mut heaps);

            let mut ref_heaps: Vec<TopK> =
                (0..b).map(|_| TopK::new(10)).collect();
            kernels::scan_block_scalar(&data, d, 0, &qt, &mut ref_heaps);

            for (hi, (h, r)) in
                heaps.into_iter().zip(ref_heaps).enumerate()
            {
                let got = h.into_sorted();
                let want = r.into_sorted();
                assert_eq!(got.len(), want.len(),
                           "lane {hi} length at d={d} b={b}");
                for (g, w) in got.iter().zip(&want) {
                    assert_eq!(g.id, w.id, "lane {hi} id at d={d} b={b}");
                    assert_eq!(g.score.to_bits(), w.score.to_bits(),
                               "lane {hi} score bits at d={d} b={b}");
                }
            }
        }
    }
}

/// End-to-end: `DenseExact::retrieve_batch` (which packs query blocks and
/// calls the dispatched `scan_block`) must be bitwise reproducible from a
/// hand-packed `scan_block_scalar` pass over the same matrix — including
/// a batch size that crosses a block boundary (one full block + a
/// partial one).
#[test]
fn dense_batch_matches_hand_packed_scalar_reference() {
    let mut rng = Rng::new(0xE0_04);
    let n_docs = 500usize;
    let k = 20usize;
    for &d in &DIMS {
        let mut data = Vec::with_capacity(n_docs * d);
        for _ in 0..n_docs {
            data.extend(random_vec(&mut rng, d));
        }
        let emb = Arc::new(EmbeddingMatrix::new(d, data));
        let kb = DenseExact::new(Arc::clone(&emb));

        // LANES + 3 queries: full block then a 3-wide partial block.
        let raw: Vec<Vec<f32>> =
            (0..LANES + 3).map(|_| random_vec(&mut rng, d)).collect();
        let qs: Vec<SpecQuery> =
            raw.iter().cloned().map(SpecQuery::dense_only).collect();
        let got = kb.retrieve_batch(&qs, k);
        assert_eq!(got.len(), qs.len());

        for (block_start, chunk) in
            raw.chunks(LANES).enumerate().map(|(ci, c)| (ci * LANES, c))
        {
            let qt = pack_qt(chunk, d);
            let mut heaps: Vec<TopK> =
                (0..chunk.len()).map(|_| TopK::new(k)).collect();
            kernels::scan_block_scalar(&emb.data, d, 0, &qt, &mut heaps);
            for (bi, h) in heaps.into_iter().enumerate() {
                let want = h.into_sorted();
                let g = &got[block_start + bi];
                assert_eq!(g.len(), want.len(), "query {} at d={d}",
                           block_start + bi);
                for (gs, ws) in g.iter().zip(&want) {
                    assert_eq!(gs.id, ws.id,
                               "query {} id at d={d}", block_start + bi);
                    assert_eq!(gs.score.to_bits(), ws.score.to_bits(),
                               "query {} score bits at d={d}",
                               block_start + bi);
                }
            }
        }
    }
}

/// The dispatch decision is a process-wide constant: repeated calls agree
/// (the sharded scatter-gather merge relies on every worker thread
/// scoring with the same kernel form).
#[test]
fn dispatch_decision_is_stable() {
    let first = kernels::simd_active();
    for _ in 0..8 {
        assert_eq!(kernels::simd_active(), first);
    }
    #[cfg(not(feature = "simd"))]
    assert!(!first, "simd_active must be false with the feature off");
}
