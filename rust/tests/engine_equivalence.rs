//! THE serving-layer correctness property (DESIGN.md ADR-003/ADR-005):
//! the concurrent engine may interleave N requests' speculation steps,
//! coalesce their verification queries into shared `retrieve_batch`
//! calls, and — with `kb_parallel >= 1` — run those calls asynchronously
//! on background workers with out-of-order completion, but every
//! request's token output must stay **bit-identical** to a sequential
//! `SpecPipeline::run` of that request alone — across mixed stride
//! policies / prefetch sizes / OS³ / async verification, sharded and
//! unsharded knowledge bases, concurrency 1 / 8 / 32, and
//! `kb_parallel` {0 (sync inline), 1, 2, 4}.
//!
//! Also pins the throughput directions: coalescing must not be a
//! regression (more requests/s at concurrency 8 than 1), and under
//! injected KB latency the asynchronous executor must beat the
//! synchronous inline flush at concurrency 8. And the failure contract:
//! a panicking KB call must surface as an error on exactly the requests
//! whose queries rode the poisoned call, never wedge the engine.

use ralmspec::config::{Config, CorpusConfig, RetrieverKind};
use ralmspec::datagen::{generate_questions, Dataset, HashEncoder};
use ralmspec::eval::{run_engine_cell, run_qa_cell, serve_throughput,
                     serve_throughput_kb, QaMethod, TestBed};
use ralmspec::lm::MockLm;
use ralmspec::retriever::{InjectedLatency, Retriever, SpecQuery};
use ralmspec::serving::EngineOptions;
use ralmspec::util::Scored;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

fn small_config(seed: u64) -> Config {
    let mut cfg = Config::default();
    cfg.corpus = CorpusConfig {
        n_docs: 600,
        n_topics: 12,
        doc_len: (24, 80),
        seed,
        ..CorpusConfig::default()
    };
    cfg.retriever.hnsw_ef_construction = 40;
    cfg.retriever.hnsw_ef_search = 32;
    cfg.spec.max_new_tokens = 28;
    cfg
}

/// A deliberately heterogeneous request mix: plain spec, prefetching,
/// OS³, async verification, and a long fixed stride — so one coalesced
/// flush carries queries from requests with different strides and
/// different top-k (prefetch) requirements.
fn mixed_methods(n: usize) -> Vec<QaMethod> {
    (0..n)
        .map(|i| match i % 5 {
            0 => QaMethod::plain_spec(),
            1 => QaMethod::spec(20, false, false),
            2 => QaMethod::spec(1, true, false),
            3 => QaMethod::spec(1, false, true),
            _ => QaMethod::Spec {
                prefetch: 1,
                os3: false,
                async_verify: false,
                stride: 8,
            },
        })
        .collect()
}

/// Engine output vs per-request sequential `SpecPipeline::run`, swept
/// over `kb_parallel` settings (0 = synchronous inline flush; >= 1 =
/// async background execution with that in-flight cap). The sequential
/// reference is computed once — the whole point is that no engine
/// execution mode may perturb any request's tokens.
fn check_equivalence(seed: u64, kind: RetrieverKind, shards: usize,
                     concurrency: usize, n: usize,
                     kb_parallels: &[usize]) {
    let mut cfg = small_config(seed);
    cfg.retriever.shards = shards;
    let enc = HashEncoder::new(ralmspec::runtime::RETRIEVAL_DIM, seed ^ 0xEC);
    let bed = TestBed::build(&cfg, &enc);
    let lm = MockLm::new(cfg.corpus.vocab, 320, seed ^ 0x11);
    let questions = generate_questions(Dataset::WikiQa, &bed.corpus, n, seed);
    let methods = mixed_methods(n);

    // Sequential reference: each request alone through SpecPipeline::run
    // (itself equivalence-pinned against the baseline).
    let mut expected: Vec<Vec<u32>> = Vec::with_capacity(n);
    for (q, method) in questions.iter().zip(&methods) {
        let ms = run_qa_cell(&lm, &enc, &bed, kind,
                             std::slice::from_ref(q), *method, &cfg)
            .unwrap();
        expected.push(ms.into_iter().next().unwrap().tokens_out);
    }

    for &kb_parallel in kb_parallels {
        let opts = EngineOptions {
            max_batch: 64,
            flush_us: 200,
            max_inflight: concurrency,
            kb_parallel,
            ..EngineOptions::default()
        };
        let (got, stats) =
            run_engine_cell(&lm, &enc, &bed, kind, &questions, &methods,
                            &cfg, opts)
            .unwrap();
        assert_eq!(got.len(), n);
        for (i, (g, e)) in got.iter().zip(&expected).enumerate() {
            assert_eq!(
                g.tokens_out, *e,
                "ENGINE OUTPUT DIVERGED: seed={seed} kind={kind:?} \
                 shards={shards} conc={concurrency} \
                 kb_parallel={kb_parallel} req={i} \
                 method={:?}", methods[i]);
        }
        if concurrency >= 8 && n >= 8 {
            assert!(stats.mean_coalesced() > 1.0,
                    "concurrency {concurrency} kb_parallel {kb_parallel} \
                     never coalesced (mean batch {:.2})",
                    stats.mean_coalesced());
        }
    }
}

#[test]
fn engine_matches_sequential_edr_conc_1() {
    check_equivalence(1, RetrieverKind::Edr, 1, 1, 10, &[0, 2]);
}

#[test]
fn engine_matches_sequential_edr_conc_8() {
    // The full ADR-005 sweep: synchronous inline plus async in-flight
    // caps 1, 2, 4 — bit-identical across all of them.
    check_equivalence(2, RetrieverKind::Edr, 1, 8, 12, &[0, 1, 2, 4]);
}

#[test]
fn engine_matches_sequential_edr_conc_32() {
    check_equivalence(3, RetrieverKind::Edr, 1, 32, 32, &[0, 4]);
}

#[test]
fn engine_matches_sequential_sr() {
    check_equivalence(4, RetrieverKind::Sr, 1, 8, 10, &[0, 2]);
}

#[test]
fn engine_matches_sequential_adr() {
    check_equivalence(5, RetrieverKind::Adr, 1, 8, 10, &[0, 2]);
}

#[test]
fn engine_matches_sequential_sharded() {
    // Coalescing composes with the scatter-gather sharded KB: each
    // coalesced batch fans out over shard views and k-way-merges back —
    // and with kb_parallel >= 1 the scatter itself runs on a worker —
    // still bit-identical per request.
    for kind in [RetrieverKind::Edr, RetrieverKind::Adr, RetrieverKind::Sr] {
        check_equivalence(6, kind, 2, 8, 8, &[0, 2]);
    }
}

#[test]
fn engine_smoke_32_concurrent() {
    // CI throughput smoke: 32 concurrent mock requests through the
    // scheduler/flush/async-completion path must all complete (no hang,
    // no starvation).
    let cfg = small_config(0x5E42);
    let enc = HashEncoder::new(ralmspec::runtime::RETRIEVAL_DIM, 0x5E42);
    let bed = TestBed::build(&cfg, &enc);
    let lm = MockLm::new(cfg.corpus.vocab, 320, 0x5E43);
    let n = 32;
    let questions = generate_questions(Dataset::Nq, &bed.corpus, n, 9);
    let methods = mixed_methods(n);
    let opts = EngineOptions { max_batch: 64, flush_us: 200,
                               max_inflight: 32, kb_parallel: 4,
                               ..EngineOptions::default() };
    let (ms, stats) = run_engine_cell(&lm, &enc, &bed, RetrieverKind::Edr,
                                      &questions, &methods, &cfg, opts)
        .unwrap();
    assert_eq!(ms.len(), n);
    for (i, m) in ms.iter().enumerate() {
        assert!(!m.tokens_out.is_empty(),
                "request {i} produced no tokens");
        assert!(m.total.as_nanos() > 0);
    }
    assert!(stats.kb_calls > 0);
    assert!(stats.mean_coalesced() > 1.0,
            "32 concurrent requests should coalesce (mean {:.2})",
            stats.mean_coalesced());
    assert!(stats.kb_dispatches >= stats.kb_calls,
            "async mode must account every dispatched call");
}

#[test]
fn serve_scenario_concurrency_8_beats_1() {
    // Acceptance: coalescing must not be a throughput regression — the
    // serve scenario reports more requests/s at concurrency 8 than 1.
    // Retrieval-heavy setup (EDR flat scan over a larger corpus) so the
    // coalesced KB calls are what the measurement sees; best-of-3 per
    // level damps scheduler noise (the structural gap — ~8x fewer KB
    // calls at concurrency 8 — is far larger than run-to-run jitter).
    let mut cfg = small_config(0xBEEF);
    cfg.corpus.n_docs = 4000;
    cfg.corpus.n_topics = 32;
    cfg.spec.max_new_tokens = 24;
    // A roomy coalescing window so the deadline never splits a wave of 8
    // concurrent strides (the size/drain conditions do the flushing).
    cfg.engine.flush_us = 5_000;
    let enc = HashEncoder::new(ralmspec::runtime::RETRIEVAL_DIM, 0xBEEF);
    let bed = TestBed::build(&cfg, &enc);
    let lm = MockLm::new(cfg.corpus.vocab, 320, 0xBEF0);
    let questions = generate_questions(Dataset::WikiQa, &bed.corpus, 16, 3);
    let method = QaMethod::plain_spec();
    let best = |concurrency: usize| {
        let mut best_rps = 0.0f64;
        let mut coalesced = 0.0f64;
        for _ in 0..3 {
            let s = serve_throughput(&lm, &enc, &bed, RetrieverKind::Edr,
                                     &questions, method, &cfg, concurrency)
                .unwrap();
            assert_eq!(s.requests, questions.len());
            if s.rps > best_rps {
                best_rps = s.rps;
                coalesced = s.mean_coalesced;
            }
        }
        (best_rps, coalesced)
    };
    let (rps_1, _) = best(1);
    let (rps_8, coalesced_8) = best(8);
    assert!(coalesced_8 > 1.5,
            "concurrency 8 should coalesce verification batches \
             (mean {coalesced_8:.2})");
    assert!(rps_8 > rps_1,
            "coalescing must not be a throughput regression: \
             conc8={rps_8:.2} req/s vs conc1={rps_1:.2} req/s");
}

#[test]
fn async_execution_beats_sync_under_injected_kb_latency() {
    // The ADR-005 acceptance direction, deterministically: wrap the KB in
    // a fixed 2 ms per-call latency injection (dwarfing both the toy
    // corpus' real retrieval cost and any scheduler jitter) and serve the
    // heterogeneous mix at concurrency 8. The mix carries two distinct
    // top-k's (prefetch 1 and 20), and per-k groups cannot share a
    // coalesced call — so every verification era has (at least) two KB
    // calls that the synchronous inline engine pays the injected RTT for
    // back to back while the async executor holds them in flight
    // together. The advantage is structural (≈ the number of distinct
    // k's), not a wall-clock coincidence.
    let mut cfg = small_config(0xA51C);
    cfg.spec.max_new_tokens = 24;
    let enc = HashEncoder::new(ralmspec::runtime::RETRIEVAL_DIM, 0xA51C);
    let bed = TestBed::build(&cfg, &enc);
    let lm = MockLm::new(cfg.corpus.vocab, 320, 0xA51D);
    let n = 16;
    let questions = generate_questions(Dataset::WikiQa, &bed.corpus, n, 5);
    let methods = mixed_methods(n);
    let kb: Arc<dyn Retriever> = Arc::new(InjectedLatency::new(
        bed.unsharded(RetrieverKind::Edr), Duration::from_millis(2)));
    let best = |kb_parallel: usize| {
        let mut run_cfg = cfg.clone();
        run_cfg.engine.kb_parallel = kb_parallel;
        let mut best_rps = 0.0f64;
        let mut depth = 0.0f64;
        for _ in 0..2 {
            let s = serve_throughput_kb(&lm, &enc, &bed,
                                        RetrieverKind::Edr, &kb,
                                        &questions, &methods, &run_cfg, 8)
                .unwrap();
            assert_eq!(s.requests, n);
            if s.rps > best_rps {
                best_rps = s.rps;
                depth = s.mean_inflight_depth;
            }
        }
        (best_rps, depth)
    };
    let (sync_rps, sync_depth) = best(0);
    let (async_rps, _) = best(4);
    assert!(sync_depth <= 1.0 + 1e-9,
            "sync mode must serialize KB calls (depth {sync_depth:.2})");
    assert!(async_rps > sync_rps,
            "async retrieval execution must beat the blocking flush under \
             KB latency: async={async_rps:.2} req/s vs \
             sync={sync_rps:.2} req/s");
}

/// A KB wrapper whose first `retrieve_batch` call panics; later calls
/// delegate. Coalescing makes the first flush carry the first admitted
/// wave, so exactly those requests must fail while the engine survives
/// and serves the rest.
struct PanicOnce {
    inner: Arc<dyn Retriever>,
    fired: AtomicBool,
}

impl Retriever for PanicOnce {
    fn retrieve_batch(&self, qs: &[SpecQuery], k: usize) -> Vec<Vec<Scored>> {
        if !self.fired.swap(true, Ordering::SeqCst) {
            panic!("poisoned knowledge-base call");
        }
        self.inner.retrieve_batch(qs, k)
    }

    fn score_doc(&self, q: &SpecQuery, doc: u32) -> f32 {
        self.inner.score_doc(q, doc)
    }

    fn len(&self) -> usize {
        self.inner.len()
    }

    fn name(&self) -> &'static str {
        "panic-once"
    }
}

#[test]
fn panicking_kb_call_fails_only_owning_requests() {
    // Regression (ADR-005 satellite): a panicking KB job must surface as
    // an error on the requests whose queries rode the poisoned call and
    // free their slots — not wedge the engine or take down the healthy
    // requests. max_inflight 2 over 8 requests: the first coalesced flush
    // (the first admitted pair's primes) panics; the remaining 6 must
    // complete bit-identically to their sequential runs.
    use ralmspec::serving::ServeEngine;
    use ralmspec::spec::{QueryBuilder, QueryMode, SpecTask};

    let cfg = small_config(0xDEAD);
    let enc = HashEncoder::new(ralmspec::runtime::RETRIEVAL_DIM, 0xDEAD);
    let bed = TestBed::build(&cfg, &enc);
    let lm = MockLm::new(cfg.corpus.vocab, 320, 0xDEA1);
    let n = 8;
    let questions = generate_questions(Dataset::WikiQa, &bed.corpus, n, 7);
    let method = QaMethod::plain_spec();
    let expected: Vec<Vec<u32>> = questions
        .iter()
        .map(|q| {
            run_qa_cell(&lm, &enc, &bed, RetrieverKind::Edr,
                        std::slice::from_ref(q), method, &cfg)
                .unwrap()
                .pop()
                .unwrap()
                .tokens_out
        })
        .collect();

    for kb_parallel in [0usize, 2] {
        let kb: Arc<dyn Retriever> = Arc::new(PanicOnce {
            inner: bed.unsharded(RetrieverKind::Edr),
            fired: AtomicBool::new(false),
        });
        let queries = QueryBuilder {
            encoder: &enc,
            mode: QueryMode::Dense,
            dense_len: cfg.retriever.dense_query_len,
            sparse_len: cfg.retriever.sparse_query_len,
        };
        let mut engine: ServeEngine<SpecTask<MockLm>> = ServeEngine::new(
            kb.clone(),
            EngineOptions { max_batch: 64, flush_us: 200, max_inflight: 2,
                            kb_parallel,
                            ..EngineOptions::default() });
        let opts = ralmspec::eval::build_spec_options(&cfg, 1, false,
                                                      false, 3);
        for (i, q) in questions.iter().enumerate() {
            engine.submit(i as u64,
                          SpecTask::new(&lm, kb.as_ref(), &bed.corpus,
                                        queries, opts.clone(), &q.tokens));
        }
        let done = engine.run().unwrap();
        let failed = engine.take_failed();
        assert!(!failed.is_empty(),
                "kb_parallel={kb_parallel}: the poisoned call must fail \
                 its requests");
        assert_eq!(done.len() + failed.len(), n,
                   "kb_parallel={kb_parallel}: every request resolves \
                    exactly once");
        for (id, msg) in &failed {
            assert!(msg.contains("poisoned knowledge-base call"),
                    "kb_parallel={kb_parallel}: failure #{id} must carry \
                     the panic payload, got: {msg}");
        }
        for (id, m) in &done {
            assert_eq!(m.tokens_out, expected[*id as usize],
                       "kb_parallel={kb_parallel}: surviving request \
                        {id} diverged after the poisoned call");
        }
        assert_eq!(engine.stats().kb_failures, 1);
    }
}
