//! THE serving-layer correctness property (DESIGN.md ADR-003): the
//! concurrent engine may interleave N requests' speculation steps and
//! coalesce their verification queries into shared `retrieve_batch`
//! calls, but every request's token output must stay **bit-identical** to
//! a sequential `SpecPipeline::run` of that request alone — across mixed
//! stride policies / prefetch sizes / OS³ / async verification, sharded
//! and unsharded knowledge bases, and concurrency 1 / 8 / 32.
//!
//! Also pins the throughput direction: coalescing must not be a
//! regression — the `serve` scenario must report more requests/s at
//! concurrency 8 than at concurrency 1 on the mock LM.

use ralmspec::config::{Config, CorpusConfig, RetrieverKind};
use ralmspec::datagen::{generate_questions, Dataset, HashEncoder};
use ralmspec::eval::{run_engine_cell, run_qa_cell, serve_throughput,
                     QaMethod, TestBed};
use ralmspec::lm::MockLm;
use ralmspec::serving::EngineOptions;

fn small_config(seed: u64) -> Config {
    let mut cfg = Config::default();
    cfg.corpus = CorpusConfig {
        n_docs: 600,
        n_topics: 12,
        doc_len: (24, 80),
        seed,
        ..CorpusConfig::default()
    };
    cfg.retriever.hnsw_ef_construction = 40;
    cfg.retriever.hnsw_ef_search = 32;
    cfg.spec.max_new_tokens = 28;
    cfg
}

/// A deliberately heterogeneous request mix: plain spec, prefetching,
/// OS³, async verification, and a long fixed stride — so one coalesced
/// flush carries queries from requests with different strides and
/// different top-k (prefetch) requirements.
fn mixed_methods(n: usize) -> Vec<QaMethod> {
    (0..n)
        .map(|i| match i % 5 {
            0 => QaMethod::plain_spec(),
            1 => QaMethod::spec(20, false, false),
            2 => QaMethod::spec(1, true, false),
            3 => QaMethod::spec(1, false, true),
            _ => QaMethod::Spec {
                prefetch: 1,
                os3: false,
                async_verify: false,
                stride: 8,
            },
        })
        .collect()
}

fn check_equivalence(seed: u64, kind: RetrieverKind, shards: usize,
                     concurrency: usize, n: usize) {
    let mut cfg = small_config(seed);
    cfg.retriever.shards = shards;
    let enc = HashEncoder::new(ralmspec::runtime::RETRIEVAL_DIM, seed ^ 0xEC);
    let bed = TestBed::build(&cfg, &enc);
    let lm = MockLm::new(cfg.corpus.vocab, 320, seed ^ 0x11);
    let questions = generate_questions(Dataset::WikiQa, &bed.corpus, n, seed);
    let methods = mixed_methods(n);

    // Sequential reference: each request alone through SpecPipeline::run
    // (itself equivalence-pinned against the baseline).
    let mut expected: Vec<Vec<u32>> = Vec::with_capacity(n);
    for (q, method) in questions.iter().zip(&methods) {
        let ms = run_qa_cell(&lm, &enc, &bed, kind,
                             std::slice::from_ref(q), *method, &cfg)
            .unwrap();
        expected.push(ms.into_iter().next().unwrap().tokens_out);
    }

    let opts = EngineOptions {
        max_batch: 64,
        flush_us: 200,
        max_inflight: concurrency,
    };
    let (got, stats) =
        run_engine_cell(&lm, &enc, &bed, kind, &questions, &methods, &cfg,
                        opts)
        .unwrap();
    assert_eq!(got.len(), n);
    for (i, (g, e)) in got.iter().zip(&expected).enumerate() {
        assert_eq!(
            g.tokens_out, *e,
            "ENGINE OUTPUT DIVERGED: seed={seed} kind={kind:?} \
             shards={shards} conc={concurrency} req={i} \
             method={:?}", methods[i]);
    }
    if concurrency >= 8 && n >= 8 {
        assert!(stats.mean_coalesced() > 1.0,
                "concurrency {concurrency} never coalesced \
                 (mean batch {:.2})", stats.mean_coalesced());
    }
}

#[test]
fn engine_matches_sequential_edr_conc_1() {
    check_equivalence(1, RetrieverKind::Edr, 1, 1, 10);
}

#[test]
fn engine_matches_sequential_edr_conc_8() {
    check_equivalence(2, RetrieverKind::Edr, 1, 8, 12);
}

#[test]
fn engine_matches_sequential_edr_conc_32() {
    check_equivalence(3, RetrieverKind::Edr, 1, 32, 32);
}

#[test]
fn engine_matches_sequential_sr() {
    check_equivalence(4, RetrieverKind::Sr, 1, 8, 10);
}

#[test]
fn engine_matches_sequential_adr() {
    check_equivalence(5, RetrieverKind::Adr, 1, 8, 10);
}

#[test]
fn engine_matches_sequential_sharded() {
    // Coalescing composes with the scatter-gather sharded KB: each
    // coalesced batch fans out over shard views and k-way-merges back,
    // still bit-identical per request.
    for kind in [RetrieverKind::Edr, RetrieverKind::Adr, RetrieverKind::Sr] {
        check_equivalence(6, kind, 2, 8, 8);
    }
}

#[test]
fn engine_smoke_32_concurrent() {
    // CI throughput smoke: 32 concurrent mock requests through the
    // scheduler/flush path must all complete (no hang, no starvation).
    let cfg = small_config(0x5E42);
    let enc = HashEncoder::new(ralmspec::runtime::RETRIEVAL_DIM, 0x5E42);
    let bed = TestBed::build(&cfg, &enc);
    let lm = MockLm::new(cfg.corpus.vocab, 320, 0x5E43);
    let n = 32;
    let questions = generate_questions(Dataset::Nq, &bed.corpus, n, 9);
    let methods = mixed_methods(n);
    let opts = EngineOptions { max_batch: 64, flush_us: 200,
                               max_inflight: 32 };
    let (ms, stats) = run_engine_cell(&lm, &enc, &bed, RetrieverKind::Edr,
                                      &questions, &methods, &cfg, opts)
        .unwrap();
    assert_eq!(ms.len(), n);
    for (i, m) in ms.iter().enumerate() {
        assert!(!m.tokens_out.is_empty(),
                "request {i} produced no tokens");
        assert!(m.total.as_nanos() > 0);
    }
    assert!(stats.kb_calls > 0);
    assert!(stats.mean_coalesced() > 1.0,
            "32 concurrent requests should coalesce (mean {:.2})",
            stats.mean_coalesced());
}

#[test]
fn serve_scenario_concurrency_8_beats_1() {
    // Acceptance: coalescing must not be a throughput regression — the
    // serve scenario reports more requests/s at concurrency 8 than 1.
    // Retrieval-heavy setup (EDR flat scan over a larger corpus) so the
    // coalesced KB calls are what the measurement sees; best-of-3 per
    // level damps scheduler noise (the structural gap — ~8x fewer KB
    // calls at concurrency 8 — is far larger than run-to-run jitter).
    let mut cfg = small_config(0xBEEF);
    cfg.corpus.n_docs = 4000;
    cfg.corpus.n_topics = 32;
    cfg.spec.max_new_tokens = 24;
    // A roomy coalescing window so the deadline never splits a wave of 8
    // concurrent strides (the size/drain conditions do the flushing).
    cfg.engine.flush_us = 5_000;
    let enc = HashEncoder::new(ralmspec::runtime::RETRIEVAL_DIM, 0xBEEF);
    let bed = TestBed::build(&cfg, &enc);
    let lm = MockLm::new(cfg.corpus.vocab, 320, 0xBEF0);
    let questions = generate_questions(Dataset::WikiQa, &bed.corpus, 16, 3);
    let method = QaMethod::plain_spec();
    let best = |concurrency: usize| {
        let mut best_rps = 0.0f64;
        let mut coalesced = 0.0f64;
        for _ in 0..3 {
            let s = serve_throughput(&lm, &enc, &bed, RetrieverKind::Edr,
                                     &questions, method, &cfg, concurrency)
                .unwrap();
            assert_eq!(s.requests, questions.len());
            if s.rps > best_rps {
                best_rps = s.rps;
                coalesced = s.mean_coalesced;
            }
        }
        (best_rps, coalesced)
    };
    let (rps_1, _) = best(1);
    let (rps_8, coalesced_8) = best(8);
    assert!(coalesced_8 > 1.5,
            "concurrency 8 should coalesce verification batches \
             (mean {coalesced_8:.2})");
    assert!(rps_8 > rps_1,
            "coalescing must not be a throughput regression: \
             conc8={rps_8:.2} req/s vs conc1={rps_1:.2} req/s");
}
