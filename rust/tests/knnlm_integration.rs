//! KNN-LM serving integration (§5.3): output equivalence under relaxed
//! verification, datastore/cache interplay, and interpolation effects —
//! all on the mock LM + mock datastore (shared HashEncoder space).

use ralmspec::config::CorpusConfig;
use ralmspec::datagen::generate_stream;
use ralmspec::knnlm::{Datastore, KnnLmBaseline, KnnLmSpec, KnnServeOptions};
use ralmspec::lm::MockLm;
use ralmspec::retriever::dense::DenseExact;
use ralmspec::retriever::hnsw::Hnsw;
use ralmspec::spec::{Os3Config, StridePolicy};
use ralmspec::util::Rng;

const DIM: usize = ralmspec::runtime::RETRIEVAL_DIM;

struct Fixture {
    ds: Datastore,
    lm: MockLm,
    prompts: Vec<Vec<u32>>,
}

fn fixture(seed: u64, n_entries: usize) -> Fixture {
    let cfg = CorpusConfig { seed, ..CorpusConfig::default() };
    let stream = generate_stream(&cfg, n_entries + 400, seed);
    // MockLm's qproj is HashEncoder(seed ^ 0xE over lm seed space); the
    // datastore keys must live in the SAME space, so use the same seed.
    let lm_seed = seed ^ 0x11;
    let ds = Datastore::build_mock(&stream, DIM, lm_seed ^ 0xE, n_entries);
    let lm = MockLm::new(cfg.vocab, 320, lm_seed);
    let mut rng = Rng::new(seed ^ 0x77);
    let prompts = (0..4)
        .map(|_| {
            let start = rng.gen_range(stream.len() - 40);
            stream.tokens[start..start + 20].to_vec()
        })
        .collect();
    Fixture { ds, lm, prompts }
}

fn opts(k: usize, stride: StridePolicy) -> KnnServeOptions {
    KnnServeOptions {
        k,
        stride,
        max_new: 24,
        ..KnnServeOptions::default()
    }
}

/// Relaxed verification preserves the baseline output token-for-token.
#[test]
fn knn_spec_matches_baseline_output() {
    for seed in [1u64, 3] {
        let f = fixture(seed, 6_000);
        let kb = DenseExact::new(f.ds.keys.clone());
        for k in [1usize, 8] {
            for stride in [StridePolicy::Fixed(2),
                           StridePolicy::Os3(Os3Config::default())] {
                for p in &f.prompts {
                    let base = KnnLmBaseline {
                        lm: &f.lm, kb: &kb, ds: &f.ds,
                        opts: opts(k, StridePolicy::Fixed(1)),
                    }.run(p).unwrap();
                    let spec = KnnLmSpec {
                        lm: &f.lm, kb: &kb, ds: &f.ds,
                        opts: opts(k, stride.clone()),
                    }.run(p).unwrap();
                    assert_eq!(spec.tokens_out, base.tokens_out,
                               "seed={seed} k={k} stride={stride:?}");
                }
            }
        }
    }
}

/// With HNSW as the KB retriever the *approximate* results are the ground
/// truth being preserved (paper: same guarantee relative to the retriever).
#[test]
fn knn_spec_matches_baseline_with_hnsw() {
    let f = fixture(5, 6_000);
    let kb = Hnsw::build(f.ds.keys.clone(), 12, 60, 48, 55);
    for p in &f.prompts {
        let base = KnnLmBaseline {
            lm: &f.lm, kb: &kb, ds: &f.ds,
            opts: opts(8, StridePolicy::Fixed(1)),
        }.run(p).unwrap();
        let spec = KnnLmSpec {
            lm: &f.lm, kb: &kb, ds: &f.ds,
            opts: opts(8, StridePolicy::Fixed(3)),
        }.run(p).unwrap();
        assert_eq!(spec.tokens_out, base.tokens_out);
    }
}

/// Speculation must reduce KB calls whenever accuracy is non-trivial, and
/// must never issue fewer verified queries than tokens generated.
#[test]
fn knn_spec_batches_kb_calls() {
    let f = fixture(8, 6_000);
    let kb = DenseExact::new(f.ds.keys.clone());
    for p in &f.prompts {
        let base = KnnLmBaseline {
            lm: &f.lm, kb: &kb, ds: &f.ds,
            opts: opts(16, StridePolicy::Fixed(1)),
        }.run(p).unwrap();
        let spec = KnnLmSpec {
            lm: &f.lm, kb: &kb, ds: &f.ds,
            opts: opts(16, StridePolicy::Fixed(4)),
        }.run(p).unwrap();
        assert!(spec.kb_calls < base.kb_calls,
                "spec {} vs base {}", spec.kb_calls, base.kb_calls);
        assert!(spec.kb_queries + 4 >= base.kb_queries);
    }
}

/// The interpolated distribution must actually differ from the pure LM
/// (lambda > 0 pulls toward datastore continuations) — guards against the
/// KNN path silently degenerating to greedy LM decoding.
#[test]
fn interpolation_changes_some_outputs() {
    let f = fixture(13, 6_000);
    let kb = DenseExact::new(f.ds.keys.clone());
    let mut diffs = 0;
    for p in &f.prompts {
        let with_knn = KnnLmBaseline {
            lm: &f.lm, kb: &kb, ds: &f.ds,
            opts: KnnServeOptions { k: 16, lambda: 0.6, max_new: 24,
                                    ..KnnServeOptions::default() },
        }.run(p).unwrap();
        let pure_lm = KnnLmBaseline {
            lm: &f.lm, kb: &kb, ds: &f.ds,
            opts: KnnServeOptions { k: 16, lambda: 0.0, max_new: 24,
                                    ..KnnServeOptions::default() },
        }.run(p).unwrap();
        if with_knn.tokens_out != pure_lm.tokens_out {
            diffs += 1;
        }
    }
    assert!(diffs > 0, "lambda=0.6 never changed any output");
}

/// Speculation accuracy should be clearly positive thanks to the next-n
/// consecutive-entry cache rule (spatial locality of the stream).
#[test]
fn spatial_locality_gives_nonzero_accuracy() {
    let f = fixture(21, 8_000);
    let kb = DenseExact::new(f.ds.keys.clone());
    let mut steps = 0u64;
    let mut correct = 0u64;
    for p in &f.prompts {
        let m = KnnLmSpec {
            lm: &f.lm, kb: &kb, ds: &f.ds,
            opts: opts(8, StridePolicy::Fixed(3)),
        }.run(p).unwrap();
        steps += m.spec_steps as u64;
        correct += m.spec_correct as u64;
    }
    let acc = correct as f64 / steps.max(1) as f64;
    assert!(acc > 0.2, "speculation accuracy {acc} too low");
}
