//! THE multi-tenant serving correctness property (DESIGN.md ADR-011):
//! tenant namespaces, priority classes, speculation preemption, and the
//! adaptive SLO controller are all **schedule, not semantics** — every
//! request's token output must stay bit-identical to a sequential
//! `SpecPipeline::run` of that request alone against its pinned
//! (tenant, epoch) snapshot, no matter how the engine interleaves,
//! preempts, or retunes around it.
//!
//! Covered here:
//!   - a hand-built two-tenant trace (mixed classes, deferred arrivals,
//!     per-tenant ingestion between waves) swept over preemption on/off ×
//!     (concurrency, kb_parallel) — bit-identity per request;
//!   - preemption determinism: a replayed overload schedule preempts the
//!     same victim at the same boundary and reproduces identical outputs
//!     AND identical engine counters (the trace-replay claim);
//!   - tenant isolation at the flush layer (same (k, epoch), different
//!     tenant → split coalesced calls) and at the failure boundary (a
//!     poisoned tenant KB fails only that tenant's requests);
//!   - the per-tenant ingest quota through the eval-harness ingest path;
//!   - the seeded trace generator replayed end-to-end through
//!     `serve_tenant_trace` (the CI engine-smoke mixed-tenant cell);
//!   - the adaptive flush controller leaving outputs untouched while it
//!     retunes.

use ralmspec::config::{Config, CorpusConfig, RetrieverKind};
use ralmspec::datagen::{embed_corpus, generate_questions, Corpus, Dataset,
                        HashEncoder, Question};
use ralmspec::eval::{build_spec_options, generate_trace, ingest_synthetic,
                     serve_tenant_trace, QaMethod, TraceSpec,
                     TrafficEvent};
use ralmspec::lm::MockLm;
use ralmspec::retriever::epoch::EpochSnapshot;
use ralmspec::retriever::{LiveKb, Retriever, SpecQuery};
use ralmspec::serving::{EngineOptions, Priority, ServeEngine, SloOptions,
                        SubmitOpts, TenantId};
use ralmspec::spec::{QueryBuilder, QueryMode, SpecOptions, SpecPipeline,
                     SpecTask};
use ralmspec::util::Scored;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

const DIM: usize = ralmspec::runtime::RETRIEVAL_DIM;

fn small_config(seed: u64) -> Config {
    let mut cfg = Config::default();
    cfg.corpus = CorpusConfig {
        n_docs: 300,
        n_topics: 10,
        doc_len: (24, 64),
        seed,
        ..CorpusConfig::default()
    };
    cfg.retriever.hnsw_ef_construction = 40;
    cfg.retriever.hnsw_ef_search = 32;
    cfg.spec.max_new_tokens = 18;
    // Small publish batches: one burst = one published epoch.
    cfg.ingest.batch = 4;
    cfg
}

/// One tenant's serving world: its own corpus (distinct seed) and its
/// own live knowledge base / epoch stream.
fn build_tenants(cfg: &Config, enc: &HashEncoder, tenants: usize,
                 n_questions: usize)
                 -> (Vec<Arc<LiveKb>>, Vec<Vec<Question>>) {
    let mut kbs = Vec::new();
    let mut questions = Vec::new();
    for t in 0..tenants {
        let mut ccfg = cfg.corpus.clone();
        ccfg.seed = cfg.corpus.seed ^ ((t as u64 + 1) << 20);
        let corpus = Corpus::generate(&ccfg);
        let emb = embed_corpus(enc, &corpus);
        questions.push(generate_questions(Dataset::WikiQa, &corpus,
                                          n_questions,
                                          ccfg.seed ^ 0x0A));
        kbs.push(LiveKb::build(cfg, RetrieverKind::Edr, corpus, emb, DIM));
    }
    (kbs, questions)
}

/// Heterogeneous speculative options per arrival: distinct prefetch
/// sizes (distinct top-k groups), OS³, async verification, a long
/// stride — so coalesced flushes carry several (tenant, k, epoch)
/// groups at once.
fn opts_for(cfg: &Config, i: usize) -> SpecOptions {
    match i % 5 {
        0 => build_spec_options(cfg, 1, false, false, 3),
        1 => build_spec_options(cfg, 20, false, false, 3),
        2 => build_spec_options(cfg, 1, true, false, 3),
        3 => build_spec_options(cfg, 1, false, true, 3),
        _ => build_spec_options(cfg, 1, false, false, 8),
    }
}

/// A hand-built two-tenant trace: mixed priority classes, deferred
/// arrival gates (sound: the i-th arrival's gate never exceeds i), and
/// per-tenant ingest events between waves so arrivals pin epochs 0..=2
/// for tenant 0 and 0..=1 for tenant 1.
fn two_tenant_trace() -> Vec<TrafficEvent> {
    use Priority::{High, Low, Normal};
    vec![
        TrafficEvent::Arrive { tenant: 0, class: Normal, at: 0 },
        TrafficEvent::Arrive { tenant: 1, class: Normal, at: 0 },
        TrafficEvent::Ingest { tenant: 0, docs: 4, at: 0 },
        TrafficEvent::Ingest { tenant: 1, docs: 4, at: 0 },
        TrafficEvent::Arrive { tenant: 0, class: High, at: 1 },
        TrafficEvent::Arrive { tenant: 1, class: High, at: 0 },
        TrafficEvent::Arrive { tenant: 0, class: Low, at: 2 },
        TrafficEvent::Arrive { tenant: 1, class: Low, at: 2 },
        TrafficEvent::Ingest { tenant: 0, docs: 4, at: 4 },
        TrafficEvent::Arrive { tenant: 0, class: Normal, at: 4 },
        TrafficEvent::Arrive { tenant: 1, class: Normal, at: 3 },
        TrafficEvent::Arrive { tenant: 0, class: High, at: 5 },
        TrafficEvent::Arrive { tenant: 1, class: Low, at: 6 },
        TrafficEvent::Arrive { tenant: 0, class: Low, at: 6 },
    ]
}

/// Replay `trace` against per-tenant writers, pinning every arrival's
/// snapshot (the same two-pass shape as `serve_tenant_trace`, inlined
/// here so the test can keep per-request task handles and compare
/// outputs).
fn resolve_pins(trace: &[TrafficEvent], kbs: &[Arc<LiveKb>],
                enc: &HashEncoder, cfg: &Config)
                -> Vec<(TenantId, Priority, usize, Arc<EpochSnapshot>)> {
    let mut pins = Vec::new();
    for (i, ev) in trace.iter().enumerate() {
        match ev {
            TrafficEvent::Ingest { tenant, docs, .. } => {
                let t = (*tenant as usize).min(kbs.len() - 1);
                ingest_synthetic(&kbs[t], enc, *docs,
                                 cfg.corpus.seed ^ (0x9000 + i as u64),
                                 cfg.corpus.doc_len)
                    .unwrap();
            }
            TrafficEvent::Arrive { tenant, class, at } => {
                let t = (*tenant as usize).min(kbs.len() - 1);
                pins.push((t as TenantId, *class, *at,
                           kbs[t].epochs.snapshot()));
            }
        }
    }
    pins
}

/// One equivalence cell: replay the hand-built trace through a fresh
/// engine (the tenants' knowledge bases keep growing across cells —
/// that is the point) and compare every request against a sequential
/// `SpecPipeline::run` on its pinned snapshot.
fn check_tenant_cell(cfg: &Config, enc: &HashEncoder, lm: &MockLm,
                     kbs: &[Arc<LiveKb>], questions: &[Vec<Question>],
                     preempt: bool, concurrency: usize,
                     kb_parallel: usize) {
    let trace = two_tenant_trace();
    let pins = resolve_pins(&trace, kbs, enc, cfg);
    let n = pins.len();
    let queries = QueryBuilder {
        encoder: enc,
        mode: QueryMode::Dense,
        dense_len: cfg.retriever.dense_query_len,
        sparse_len: cfg.retriever.sparse_query_len,
    };
    let mut engine: ServeEngine<SpecTask<MockLm>> = ServeEngine::new(
        pins[0].3.kb.clone(),
        EngineOptions {
            max_batch: 64,
            flush_us: 200,
            max_inflight: concurrency,
            kb_parallel,
            preempt,
            ..EngineOptions::default()
        });
    for (t, _, _, pin) in &pins {
        engine.register_tenant_epoch(*t, pin.epoch, pin.kb.clone());
    }
    for (i, (t, class, at, pin)) in pins.iter().enumerate() {
        let q = &questions[*t as usize][i % questions[*t as usize].len()];
        engine.submit_opts(
            i as u64,
            SpecTask::new(lm, pin.kb.as_ref(), &*pin.corpus, queries,
                          opts_for(cfg, i), &q.tokens)
                .pin_epoch(pin.epoch)
                .pin_tenant(*t),
            SubmitOpts { tenant: *t, class: *class, after_done: *at });
    }
    let done = engine.run().unwrap();
    let failed = engine.take_failed();
    assert!(failed.is_empty(),
            "preempt={preempt} conc={concurrency} \
             kb_parallel={kb_parallel}: unexpected failures {failed:?}");
    assert_eq!(done.len(), n);
    let stats = engine.stats().clone();
    assert_eq!(stats.tenants_served, 2,
               "both tenants must be seen by the engine");
    assert!(stats.epochs_served >= 2,
            "arrivals span several published epochs \
             (saw {})", stats.epochs_served);

    // THE property: per request, engine output == sequential run against
    // the pinned (tenant, epoch) snapshot — preemption, class weights,
    // and tenant-split flushes change only the schedule.
    for (id, m) in &done {
        let i = *id as usize;
        let (t, class, _, pin) = &pins[i];
        assert_eq!(m.epoch, pin.epoch,
                   "request {i} must report its pinned epoch");
        let q = &questions[*t as usize][i % questions[*t as usize].len()];
        let reference = SpecPipeline {
            lm,
            kb: pin.kb.as_ref(),
            corpus: &*pin.corpus,
            queries,
            opts: opts_for(cfg, i),
        }
        .run(&q.tokens)
        .unwrap();
        assert_eq!(
            m.tokens_out, reference.tokens_out,
            "TENANT SERVING DIVERGED FROM PINNED SNAPSHOT: req={i} \
             tenant={t} class={class:?} epoch={} preempt={preempt} \
             conc={concurrency} kb_parallel={kb_parallel}",
            pin.epoch);
    }
}

#[test]
fn tenant_serving_matches_pinned_snapshots() {
    // The ADR-011 acceptance sweep: preemption on/off × admission caps ×
    // sync/async retrieval execution, all over the same pair of growing
    // tenant knowledge bases.
    let seed = 0x7E4A;
    let cfg = small_config(seed);
    let enc = HashEncoder::new(DIM, seed ^ 0xEC);
    let lm = MockLm::new(cfg.corpus.vocab, 320, seed ^ 0x11);
    let (kbs, questions) = build_tenants(&cfg, &enc, 2, 14);
    for &(preempt, concurrency, kb_parallel) in
        &[(false, 2, 0), (true, 2, 0), (false, 8, 0), (true, 8, 4)]
    {
        check_tenant_cell(&cfg, &enc, &lm, &kbs, &questions, preempt,
                          concurrency, kb_parallel);
    }
}

/// Run one fixed overload schedule: two Low requests admitted first
/// (the High arrivals are gated behind the first resolution), one of
/// them deliberately short so its completion opens the gate while the
/// other Low is still mid-speculation — the second High must then
/// preempt it. Synchronous retrieval + an effectively-infinite flush
/// deadline make the whole schedule a pure function of the submissions.
fn run_preemption_schedule(seed: u64)
                           -> (Vec<(u64, Vec<u32>)>,
                               ralmspec::serving::EngineStats) {
    let cfg = small_config(seed);
    let enc = HashEncoder::new(DIM, seed ^ 0xEC);
    let bed = ralmspec::eval::TestBed::build(&cfg, &enc);
    let kb = bed.retriever(RetrieverKind::Edr);
    let lm = MockLm::new(cfg.corpus.vocab, 320, seed ^ 0x11);
    let questions = generate_questions(Dataset::WikiQa, &bed.corpus, 4, 7);
    let queries = QueryBuilder {
        encoder: &enc,
        mode: QueryMode::Dense,
        dense_len: cfg.retriever.dense_query_len,
        sparse_len: cfg.retriever.sparse_query_len,
    };
    let mut short = build_spec_options(&cfg, 1, false, false, 3);
    short.max_new = 6;
    let mut long = build_spec_options(&cfg, 1, false, false, 3);
    long.max_new = 24;
    let mut engine: ServeEngine<SpecTask<MockLm>> = ServeEngine::new(
        kb.clone(),
        EngineOptions {
            max_batch: 64,
            // Deadline flushes are the one wall-clock input to the
            // schedule; park them out of reach so only the (replayable)
            // size/drain conditions fire.
            flush_us: 1_000_000,
            max_inflight: 2,
            kb_parallel: 0,
            preempt: true,
            ..EngineOptions::default()
        });
    let subs: [(SpecOptions, Priority, usize); 4] = [
        (short.clone(), Priority::Low, 0),
        (long.clone(), Priority::Low, 0),
        (long.clone(), Priority::High, 1),
        (long.clone(), Priority::High, 1),
    ];
    for (i, (opts, class, at)) in subs.iter().enumerate() {
        engine.submit_opts(
            i as u64,
            SpecTask::new(&lm, kb.as_ref(), &*bed.corpus, queries,
                          opts.clone(), &questions[i].tokens),
            SubmitOpts { tenant: 0, class: *class, after_done: *at });
    }
    let done = engine.run().unwrap();
    assert!(engine.take_failed().is_empty());
    let stats = engine.stats().clone();

    // Bit-identity: the preempted Low resumes from its own state and
    // still matches an uninterrupted sequential run.
    for (id, m) in &done {
        let i = *id as usize;
        let reference = SpecPipeline {
            lm: &lm,
            kb: kb.as_ref(),
            corpus: &*bed.corpus,
            queries,
            opts: subs[i].0.clone(),
        }
        .run(&questions[i].tokens)
        .unwrap();
        assert_eq!(m.tokens_out, reference.tokens_out,
                   "PREEMPTION PERTURBED OUTPUT: req={i} \
                    class={:?}", subs[i].1);
    }
    (done.iter().map(|(id, m)| (*id, m.tokens_out.clone())).collect(),
     stats)
}

#[test]
fn preemption_is_deterministic_and_bit_identical() {
    let seed = 0x9E4A;
    let (out_a, stats_a) = run_preemption_schedule(seed);
    assert_eq!(out_a.len(), 4, "every request must resolve");
    assert!(stats_a.preemptions >= 1,
            "the gated High arrivals must preempt the in-flight Low \
             (preemptions = {})", stats_a.preemptions);
    assert_eq!(stats_a.forced_admissions, 0,
               "no gate in this schedule needs the deadlock backstop");

    // Replay determinism: the identical submission sequence reproduces
    // the identical outputs AND the identical schedule counters — the
    // property that makes trace-replay debugging of preemption possible.
    let (out_b, stats_b) = run_preemption_schedule(seed);
    assert_eq!(out_a, out_b, "replayed outputs must match exactly");
    assert_eq!(stats_a.preemptions, stats_b.preemptions);
    assert_eq!(stats_a.kb_calls, stats_b.kb_calls);
    assert_eq!(stats_a.coalesced_queries, stats_b.coalesced_queries);
    assert_eq!(stats_a.size_flushes, stats_b.size_flushes);
    assert_eq!(stats_a.drain_flushes, stats_b.drain_flushes);
    assert_eq!(stats_a.deadline_flushes, 0,
               "a 1 s deadline must never fire in this schedule");
}

#[test]
fn tenant_namespaces_split_coalesced_calls() {
    // Two tenants, identical top-k, identical epoch: without ADR-011 the
    // flush would coalesce all eight requests into one KB call; the
    // tenant namespace must force (at least) one split per flush round —
    // and the isolation price is visible in `tenant_splits` while
    // outputs stay bit-identical.
    let seed = 0xAE4A;
    let cfg = small_config(seed);
    let enc = HashEncoder::new(DIM, seed ^ 0xEC);
    let lm = MockLm::new(cfg.corpus.vocab, 320, seed ^ 0x11);
    let (kbs, questions) = build_tenants(&cfg, &enc, 2, 4);
    let pins: Vec<Arc<EpochSnapshot>> =
        kbs.iter().map(|kb| kb.epochs.snapshot()).collect();
    let queries = QueryBuilder {
        encoder: &enc,
        mode: QueryMode::Dense,
        dense_len: cfg.retriever.dense_query_len,
        sparse_len: cfg.retriever.sparse_query_len,
    };
    let opts = build_spec_options(&cfg, 1, false, false, 3);
    let mut engine: ServeEngine<SpecTask<MockLm>> = ServeEngine::new(
        pins[0].kb.clone(),
        EngineOptions { max_batch: 64, flush_us: 1_000_000,
                        max_inflight: 0, kb_parallel: 0,
                        ..EngineOptions::default() });
    for (t, pin) in pins.iter().enumerate() {
        engine.register_tenant_epoch(t as TenantId, pin.epoch,
                                     pin.kb.clone());
    }
    let n_per = 4usize;
    for t in 0..2usize {
        for j in 0..n_per {
            let q = &questions[t][j];
            engine.submit_opts(
                (t * n_per + j) as u64,
                SpecTask::new(&lm, pins[t].kb.as_ref(), &*pins[t].corpus,
                              queries, opts.clone(), &q.tokens)
                    .pin_epoch(pins[t].epoch)
                    .pin_tenant(t as TenantId),
                SubmitOpts { tenant: t as TenantId,
                             class: Priority::Normal,
                             after_done: 0 });
        }
    }
    let done = engine.run().unwrap();
    assert_eq!(done.len(), 2 * n_per);
    let stats = engine.stats().clone();
    assert_eq!(stats.tenants_served, 2);
    assert!(stats.tenant_splits >= 1,
            "same-(k, epoch) flushes across two tenants must split \
             (tenant_splits = {})", stats.tenant_splits);
    for (id, m) in &done {
        let i = *id as usize;
        let (t, j) = (i / n_per, i % n_per);
        let reference = SpecPipeline {
            lm: &lm,
            kb: pins[t].kb.as_ref(),
            corpus: &*pins[t].corpus,
            queries,
            opts: opts.clone(),
        }
        .run(&questions[t][j].tokens)
        .unwrap();
        assert_eq!(m.tokens_out, reference.tokens_out,
                   "tenant split perturbed output: tenant={t} req={j}");
    }
}

/// A KB wrapper whose first `retrieve_batch` panics; later calls
/// delegate (same shape as the engine_equivalence poison test).
struct PanicOnce {
    inner: Arc<dyn Retriever>,
    fired: AtomicBool,
}

impl Retriever for PanicOnce {
    fn retrieve_batch(&self, qs: &[SpecQuery], k: usize) -> Vec<Vec<Scored>> {
        if !self.fired.swap(true, Ordering::SeqCst) {
            panic!("poisoned tenant knowledge-base call");
        }
        self.inner.retrieve_batch(qs, k)
    }

    fn score_doc(&self, q: &SpecQuery, doc: u32) -> f32 {
        self.inner.score_doc(q, doc)
    }

    fn len(&self) -> usize {
        self.inner.len()
    }

    fn name(&self) -> &'static str {
        "panic-once"
    }
}

#[test]
fn poisoned_tenant_kb_fails_only_that_tenant() {
    // Failure isolation: tenant 1's knowledge base panics on its first
    // coalesced call. Exactly tenant 1's requests (their queries all
    // ride that one call) must fail; tenant 0's requests complete
    // bit-identically — a tenant's outage is its own.
    let seed = 0xBE4A;
    let cfg = small_config(seed);
    let enc = HashEncoder::new(DIM, seed ^ 0xEC);
    let lm = MockLm::new(cfg.corpus.vocab, 320, seed ^ 0x11);
    let (kbs, questions) = build_tenants(&cfg, &enc, 2, 3);
    let pin0 = kbs[0].epochs.snapshot();
    let pin1 = kbs[1].epochs.snapshot();
    let poisoned: Arc<dyn Retriever> = Arc::new(PanicOnce {
        inner: pin1.kb.clone(),
        fired: AtomicBool::new(false),
    });
    let queries = QueryBuilder {
        encoder: &enc,
        mode: QueryMode::Dense,
        dense_len: cfg.retriever.dense_query_len,
        sparse_len: cfg.retriever.sparse_query_len,
    };
    let opts = build_spec_options(&cfg, 1, false, false, 3);
    let mut engine: ServeEngine<SpecTask<MockLm>> = ServeEngine::new(
        pin0.kb.clone(),
        EngineOptions { max_batch: 64, flush_us: 1_000_000,
                        max_inflight: 0, kb_parallel: 0,
                        ..EngineOptions::default() });
    engine.register_tenant_epoch(0, pin0.epoch, pin0.kb.clone());
    engine.register_tenant_epoch(1, pin1.epoch, poisoned.clone());
    let n_per = 3usize;
    for t in 0..2usize {
        let pin = if t == 0 { &pin0 } else { &pin1 };
        let kb: &dyn Retriever = if t == 0 {
            pin0.kb.as_ref()
        } else {
            poisoned.as_ref()
        };
        for j in 0..n_per {
            engine.submit_opts(
                (t * n_per + j) as u64,
                SpecTask::new(&lm, kb, &*pin.corpus, queries, opts.clone(),
                              &questions[t][j].tokens)
                    .pin_epoch(pin.epoch)
                    .pin_tenant(t as TenantId),
                SubmitOpts { tenant: t as TenantId,
                             class: Priority::Normal,
                             after_done: 0 });
        }
    }
    let done = engine.run().unwrap();
    let failed = engine.take_failed();
    assert_eq!(done.len() + failed.len(), 2 * n_per,
               "every request resolves exactly once");
    let failed_ids: Vec<u64> = failed.iter().map(|(id, _)| *id).collect();
    assert_eq!(failed_ids, vec![3, 4, 5],
               "exactly tenant 1's requests must fail");
    for (_, msg) in &failed {
        assert!(msg.contains("poisoned tenant knowledge-base call"),
                "failure must carry the panic payload: {msg}");
    }
    for (id, m) in &done {
        let j = *id as usize;
        assert!(j < n_per, "tenant 0 ids only");
        let reference = SpecPipeline {
            lm: &lm,
            kb: pin0.kb.as_ref(),
            corpus: &*pin0.corpus,
            queries,
            opts: opts.clone(),
        }
        .run(&questions[0][j].tokens)
        .unwrap();
        assert_eq!(m.tokens_out, reference.tokens_out,
                   "tenant 0 req {j} must survive tenant 1's outage \
                    bit-identically");
    }
}

#[test]
fn ingest_quota_bounds_one_tenant_through_the_harness_path() {
    // ADR-011 quota through the eval-harness ingest path: the writer
    // accepts exactly `tenant.quota_docs` documents, rejects the rest
    // with a pointed error, and already-published epochs keep serving.
    let seed = 0xCE4A;
    let mut cfg = small_config(seed);
    cfg.tenant.quota_docs = 6;
    let enc = HashEncoder::new(DIM, seed ^ 0xEC);
    let corpus = Corpus::generate(&cfg.corpus);
    let emb = embed_corpus(&enc, &corpus);
    let questions = generate_questions(Dataset::WikiQa, &corpus, 2, 5);
    let live = LiveKb::build(&cfg, RetrieverKind::Edr, corpus, emb, DIM);

    // First burst fits the quota (4 of 6)...
    ingest_synthetic(&live, &enc, 4, seed ^ 0xD0C1, cfg.corpus.doc_len)
        .unwrap();
    // ...the second burst exhausts it mid-way and must surface the quota.
    let err = ingest_synthetic(&live, &enc, 4, seed ^ 0xD0C2,
                               cfg.corpus.doc_len)
        .expect_err("the 7th document must exceed the quota of 6");
    assert!(err.to_string().contains("quota"),
            "rejection must name the quota: {err:#}");
    {
        let mut w = live.writer.lock().unwrap();
        assert_eq!(w.stats().docs_ingested, 6,
                   "exactly the quota is accepted");
        w.flush().unwrap();
    }
    // Published epochs keep serving after the rejection.
    let pin = live.epochs.snapshot();
    assert!(pin.epoch >= 1, "accepted bursts must have published");
    let lm = MockLm::new(cfg.corpus.vocab, 320, seed ^ 0x11);
    let reference = SpecPipeline {
        lm: &lm,
        kb: pin.kb.as_ref(),
        corpus: &*pin.corpus,
        queries: QueryBuilder {
            encoder: &enc,
            mode: QueryMode::Dense,
            dense_len: cfg.retriever.dense_query_len,
            sparse_len: cfg.retriever.sparse_query_len,
        },
        opts: build_spec_options(&cfg, 1, false, false, 3),
    }
    .run(&questions[0].tokens)
    .unwrap();
    assert!(!reference.tokens_out.is_empty(),
            "the quota-capped tenant must still serve");
}

#[test]
fn mixed_tenant_trace_replay_smoke() {
    // The CI engine-smoke mixed-tenant cell: a seeded generated trace
    // replayed end-to-end through `serve_tenant_trace` — every arrival
    // resolves, both ingest bursts land in some tenant's writer, and the
    // per-(tenant, class) report accounts for every request.
    let seed = 0xDE4A;
    let cfg = small_config(seed);
    let enc = HashEncoder::new(DIM, seed ^ 0xEC);
    let lm = MockLm::new(cfg.corpus.vocab, 320, seed ^ 0x11);
    let spec = TraceSpec {
        seed: seed ^ 0x77,
        tenants: 2,
        requests: 12,
        mix: [1, 2, 1],
        ingest_bursts: 2,
        burst_docs: cfg.ingest.batch,
    };
    let trace = generate_trace(&spec);
    let arrivals = trace
        .iter()
        .filter(|e| matches!(e, TrafficEvent::Arrive { .. }))
        .count();
    assert_eq!(arrivals, spec.requests);
    let tenants_in_trace: std::collections::BTreeSet<TenantId> = trace
        .iter()
        .filter_map(|e| match e {
            TrafficEvent::Arrive { tenant, .. } => Some(*tenant),
            TrafficEvent::Ingest { .. } => None,
        })
        .collect();
    let (kbs, questions) = build_tenants(&cfg, &enc, 2, spec.requests);
    let report = serve_tenant_trace(
        &lm, &enc, RetrieverKind::Edr, &kbs, &questions[0],
        QaMethod::spec(1, false, false), &trace, &cfg, 8, None)
        .unwrap();
    assert_eq!(report.summary.requests, arrivals);
    assert_eq!(report.tenants_served, tenants_in_trace.len() as u64);
    let per_class_total: usize =
        report.per_class.iter().map(|c| c.requests).sum();
    assert_eq!(per_class_total, arrivals,
               "per-(tenant, class) slices must account for every \
                request");
    for c in &report.per_class {
        assert!(c.p50_s <= c.p99_s + 1e-12,
                "percentiles must be ordered per slice");
    }
    assert_eq!(report.docs_ingested,
               (spec.ingest_bursts * spec.burst_docs) as u64,
               "every generated ingest burst lands in a tenant writer");
}

#[test]
fn adaptive_slo_controller_never_perturbs_outputs() {
    // An absurdly tight p99 target forces the controller to retune the
    // flush plan almost immediately; the retuned schedule must still
    // produce bit-identical per-request outputs (schedule, not
    // semantics), and the engine must count its adaptations.
    let seed = 0xEE4A;
    let cfg = small_config(seed);
    let enc = HashEncoder::new(DIM, seed ^ 0xEC);
    let bed = ralmspec::eval::TestBed::build(&cfg, &enc);
    let kb = bed.retriever(RetrieverKind::Edr);
    let lm = MockLm::new(cfg.corpus.vocab, 320, seed ^ 0x11);
    let n = 8;
    let questions = generate_questions(Dataset::WikiQa, &bed.corpus, n, 3);
    let queries = QueryBuilder {
        encoder: &enc,
        mode: QueryMode::Dense,
        dense_len: cfg.retriever.dense_query_len,
        sparse_len: cfg.retriever.sparse_query_len,
    };
    let mut engine: ServeEngine<SpecTask<MockLm>> = ServeEngine::new(
        kb.clone(),
        EngineOptions {
            max_batch: 64,
            flush_us: 500,
            max_inflight: 4,
            kb_parallel: 0,
            slo: Some(SloOptions {
                p99_target_us: 1,
                window: 4,
                min_batch: 1,
                min_flush_us: 50,
                max_kb_parallel: 8,
            }),
            ..EngineOptions::default()
        });
    for (i, q) in questions.iter().enumerate() {
        engine.submit_opts(
            i as u64,
            SpecTask::new(&lm, kb.as_ref(), &*bed.corpus, queries,
                          opts_for(&cfg, i), &q.tokens),
            SubmitOpts::default());
    }
    let done = engine.run().unwrap();
    assert_eq!(done.len(), n);
    let stats = engine.stats().clone();
    assert!(stats.adaptations >= 1,
            "a 1 µs p99 target must force at least one retune \
             (adaptations = {})", stats.adaptations);
    for (id, m) in &done {
        let i = *id as usize;
        let reference = SpecPipeline {
            lm: &lm,
            kb: kb.as_ref(),
            corpus: &*bed.corpus,
            queries,
            opts: opts_for(&cfg, i),
        }
        .run(&questions[i].tokens)
        .unwrap();
        assert_eq!(m.tokens_out, reference.tokens_out,
                   "SLO adaptation perturbed output: req={i}");
    }
}
