//! PJRT end-to-end tests over the real AOT artifacts. Skipped (with a
//! notice) when artifacts/ hasn't been built — run `make artifacts` first.
//!
//! These validate the full three-layer stack: Pallas kernels inside the
//! JAX graphs, lowered to HLO text, executed from Rust — including the
//! paper's output-equivalence property on the real LM.

use ralmspec::config::{Config, CorpusConfig, RetrieverKind};
use ralmspec::datagen::{generate_questions, Dataset, Encoder};
use ralmspec::eval::{run_qa_cell, QaMethod, TestBed};
use ralmspec::lm::LanguageModel;
use ralmspec::runtime::Engine;
use std::path::Path;

fn engine() -> Option<Engine> {
    let dir = Path::new("artifacts");
    if !dir.join("index.json").exists() {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        return None;
    }
    Some(Engine::new(dir).expect("engine"))
}

#[test]
fn encoder_artifact_basics() {
    let Some(engine) = engine() else { return };
    let enc = engine.encoder().unwrap();
    let v1 = enc.encode(&[100, 200, 300]);
    assert_eq!(v1.len(), engine.index.retrieval_dim);
    let norm: f32 = v1.iter().map(|x| x * x).sum::<f32>().sqrt();
    assert!((norm - 1.0).abs() < 1e-3, "unit norm, got {norm}");
    // deterministic + length-sensitive
    assert_eq!(enc.encode(&[100, 200, 300]), v1);
    assert_ne!(enc.encode(&[100, 200]), v1);
    // batch == single
    let windows: Vec<&[u32]> = vec![&[100, 200, 300], &[5, 6]];
    let batch = enc.encode_batch(&windows);
    for (b, w) in batch.iter().zip(&windows) {
        let single = enc.encode(w);
        for (x, y) in b.iter().zip(&single) {
            assert!((x - y).abs() < 1e-4);
        }
    }
}

#[test]
fn lm_prefill_decode_consistency() {
    let Some(engine) = engine() else { return };
    let lm = engine.lm("gpt2m").unwrap();
    let ctx = [50u32, 60, 70, 80, 90];
    let st = lm.prefill(&ctx).unwrap();
    assert_eq!(lm.pos(&st), 5);
    assert_eq!(lm.logits(&st).len(), lm.vocab());
    // prefill(n) + append(t) must equal prefill(n+1) (KV-cache correctness
    // through the PJRT round-trip).
    let st2 = lm.append_token(&st, 123).unwrap();
    let mut ctx2 = ctx.to_vec();
    ctx2.push(123);
    let st_ref = lm.prefill(&ctx2).unwrap();
    let (a, b) = (lm.logits(&st2), lm.logits(&st_ref));
    let max_diff = a.iter().zip(b).map(|(x, y)| (x - y).abs())
        .fold(0f32, f32::max);
    assert!(max_diff < 5e-2, "decode vs prefill logits diff {max_diff}");
    // and the argmax (what generation consumes) must agree exactly
    assert_eq!(ralmspec::util::argmax(a), ralmspec::util::argmax(b));
}

#[test]
fn lm_greedy_deterministic_and_chunked_consistent() {
    let Some(engine) = engine() else { return };
    let lm = engine.lm("gpt2m").unwrap();
    let st = lm.prefill(&[10, 20, 30, 40]).unwrap();
    let (t1, _) = lm.generate_greedy(&st, 8).unwrap();
    let (t2, _) = lm.generate_greedy(&st, 8).unwrap();
    assert_eq!(t1, t2, "greedy generation must be deterministic");
    // chunked (4+4) equals one-by-one appends choosing argmax
    let mut cur = st.clone();
    let mut stepwise = Vec::new();
    for _ in 0..t1.len().min(8) {
        let next = ralmspec::lm::greedy(lm.logits(&cur));
        stepwise.push(next);
        if next == ralmspec::lm::EOS {
            break;
        }
        cur = lm.append_token(&cur, next).unwrap();
    }
    assert_eq!(&t1[..stepwise.len()], &stepwise[..],
               "decode_chunk argmax must match stepwise decode");
}

/// The paper's guarantee on the REAL model: RaLMSpec output ==
/// RaLMSeq output, PJRT LM + PJRT encoder + real retrievers.
#[test]
fn pjrt_output_equivalence() {
    let Some(engine) = engine() else { return };
    let mut cfg = Config::default();
    cfg.corpus = CorpusConfig {
        n_docs: 1_500,
        n_topics: 16,
        seed: 31,
        ..CorpusConfig::default()
    };
    cfg.spec.max_new_tokens = 16;
    cfg.eval.runs = 1;
    let enc = engine.encoder().unwrap();
    let bed = TestBed::build(&cfg, &enc);
    let lm = engine.lm("gpt2m").unwrap();
    let questions = generate_questions(Dataset::WikiQa, &bed.corpus, 2, 3);
    for kind in [RetrieverKind::Edr, RetrieverKind::Sr] {
        let base = run_qa_cell(&lm, &enc, &bed, kind, &questions,
                               QaMethod::Baseline, &cfg).unwrap();
        for method in [QaMethod::plain_spec(), QaMethod::psa(20)] {
            let spec = run_qa_cell(&lm, &enc, &bed, kind, &questions,
                                   method, &cfg).unwrap();
            for (b, s) in base.iter().zip(&spec) {
                assert_eq!(b.tokens_out, s.tokens_out,
                           "kind={kind:?} method={}", method.label());
            }
        }
    }
}

#[test]
fn knnlm_pjrt_datastore_and_equivalence() {
    let Some(engine) = engine() else { return };
    if !engine.index.has_model("knnlm") {
        eprintln!("SKIP: knnlm artifacts not built");
        return;
    }
    use ralmspec::knnlm::{Datastore, KnnLmBaseline, KnnLmSpec,
                          KnnServeOptions};
    use ralmspec::retriever::dense::DenseExact;
    use ralmspec::spec::StridePolicy;
    let cfg = CorpusConfig { seed: 7, ..CorpusConfig::default() };
    let stream = ralmspec::datagen::generate_stream(&cfg, 3_000, 7);
    let ex = ralmspec::runtime::HiddenExtractor::new(&engine, "knnlm")
        .unwrap();
    let ds = Datastore::build_pjrt(&stream, &ex, 2_000).unwrap();
    assert_eq!(ds.len(), 2_000);
    assert!(ralmspec::knnlm::datastore::keys_normalized(&ds));
    let kb = DenseExact::new(ds.keys.clone());
    let lm = engine.lm("knnlm").unwrap();
    let prompt = &stream.tokens[100..120];
    let opts = KnnServeOptions { k: 8, max_new: 10,
                                 ..KnnServeOptions::default() };
    let base = KnnLmBaseline { lm: &lm, kb: &kb, ds: &ds,
                               opts: opts.clone() }.run(prompt).unwrap();
    let spec = KnnLmSpec {
        lm: &lm, kb: &kb, ds: &ds,
        opts: KnnServeOptions { stride: StridePolicy::Fixed(3), ..opts },
    }.run(prompt).unwrap();
    assert_eq!(base.tokens_out, spec.tokens_out);
}

#[test]
fn score_dense_artifact_matches_rust_scan() {
    let Some(engine) = engine() else { return };
    use ralmspec::runtime::ArgValue;
    let art = engine.artifact("score_dense").unwrap();
    let b = engine.index.score_batch;
    let n = engine.index.score_tile;
    let d = engine.index.retrieval_dim;
    let mut rng = ralmspec::util::Rng::new(5);
    let queries: Vec<f32> = (0..b * d).map(|_| rng.next_f32() - 0.5).collect();
    let corpus: Vec<f32> = (0..n * d).map(|_| rng.next_f32() - 0.5).collect();
    let outs = art
        .execute(&[ArgValue::VecF32(&queries, &[b, d]),
                   ArgValue::VecF32(&corpus, &[n, d])])
        .unwrap();
    let scores = ralmspec::runtime::artifact::lit_f32(&outs[0]).unwrap();
    assert_eq!(scores.len(), b * n);
    // spot-check against the Rust dot product
    for &(bi, ni) in &[(0usize, 0usize), (3, 100), (b - 1, n - 1)] {
        let q = &queries[bi * d..(bi + 1) * d];
        let c = &corpus[ni * d..(ni + 1) * d];
        let expect: f32 = q.iter().zip(c).map(|(x, y)| x * y).sum();
        let got = scores[bi * n + ni];
        assert!((got - expect).abs() < 1e-3,
                "scores[{bi},{ni}] = {got} vs {expect}");
    }
}
