//! THE persistence correctness property (DESIGN.md ADR-009): a
//! segment-backed knowledge base — mmap'd immutable segments + in-RAM
//! memtable, frozen and compacted in the background — must be
//! **bit-identical** to the fully in-RAM backends of ADR-006, for every
//! retriever class, at every epoch, across freezes, compactions, process
//! restarts (save → mmap-load → query), and torn writes (a truncated
//! segment is rejected by its checksum and recovery falls back to the
//! previous manifest).
//!
//! Sweeps: EDR / HNSW / SR × shards {1, 2} (writer-driven, fully
//! deterministic) and EDR / HNSW / SR × kb_parallel {0, 4} engine-served
//! under concurrent ingestion **and** live compaction.

use ralmspec::config::{Config, CorpusConfig, RetrieverKind};
use ralmspec::datagen::{embed_corpus, embed_doc, generate_questions,
                        Corpus, Dataset, Encoder, HashEncoder};
use ralmspec::eval::{build_spec_options, run_engine_cell_live, QaMethod};
use ralmspec::lm::MockLm;
use ralmspec::retriever::{CompactionWorker, LiveKb, MutableRetriever,
                          Retriever, SegmentStore, SegmentedKb, SpecQuery};
use ralmspec::spec::{QueryBuilder, QueryMode, SpecPipeline};
use ralmspec::util::Scored;
use std::path::PathBuf;
use std::sync::Arc;

const DIM: usize = ralmspec::runtime::RETRIEVAL_DIM;

fn small_config(seed: u64) -> Config {
    let mut cfg = Config::default();
    cfg.corpus = CorpusConfig {
        n_docs: 220,
        n_topics: 12,
        doc_len: (24, 64),
        seed,
        ..CorpusConfig::default()
    };
    cfg.retriever.hnsw_ef_construction = 40;
    cfg.retriever.hnsw_ef_search = 32;
    cfg.spec.max_new_tokens = 20;
    cfg.ingest.batch = 5;
    // Tiny memtable so a handful of ingested docs forces segment
    // freezes (the paths under test).
    cfg.segment.memtable_docs = 8;
    cfg.segment.compact_interval_ms = 5;
    cfg.segment.compact_segments = 2;
    cfg
}

fn fresh_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("ralmspec-segtest-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Probe queries that exercise both retrieval views (dense + terms).
fn probes(corpus: &Corpus, enc: &HashEncoder, n: usize,
          seed: u64) -> Vec<SpecQuery> {
    let mut rng = ralmspec::util::Rng::new(seed);
    (0..n)
        .map(|i| {
            let topic = (i % corpus.n_topics) as u32;
            let terms = corpus.topic_tokens(topic, 24, &mut rng);
            SpecQuery { dense: enc.encode(&terms), terms }
        })
        .collect()
}

fn bits(kb: &dyn Retriever, qs: &[SpecQuery]) -> Vec<Vec<(u32, u32)>> {
    kb.retrieve_batch(qs, 10)
        .into_iter()
        .map(|hits: Vec<Scored>| {
            hits.into_iter()
                .map(|s| (s.id, s.score.to_bits()))
                .collect()
        })
        .collect()
}

fn assert_same(reference: &Arc<LiveKb>, segmented: &Arc<LiveKb>,
               qs: &[SpecQuery], ctx: &str) {
    let r = reference.epochs.snapshot();
    let s = segmented.epochs.snapshot();
    assert_eq!(r.kb.len(), s.kb.len(), "{ctx}: KB length diverged");
    assert_eq!(r.corpus.len(), s.corpus.len(), "{ctx}: corpus diverged");
    assert_eq!(bits(r.kb.as_ref(), qs), bits(s.kb.as_ref(), qs),
               "{ctx}: SEGMENT-BACKED RETRIEVAL DIVERGED FROM IN-RAM");
    // The cache-side metric must agree too (rank preservation, §3).
    for (qi, q) in qs.iter().enumerate() {
        for doc in [0u32, (r.kb.len() as u32) / 2, r.kb.len() as u32 - 1] {
            assert_eq!(r.kb.score_doc(q, doc).to_bits(),
                       s.kb.score_doc(q, doc).to_bits(),
                       "{ctx}: score_doc diverged (q={qi} doc={doc})");
        }
    }
}

/// Writer-driven equivalence: an in-RAM LiveKb and a segment-backed one
/// fed the exact same ingest sequence must publish bit-identical
/// snapshots at every epoch — through memtable freezes, an explicit
/// compaction, and a cold reopen from disk.
fn check_kind(kind: RetrieverKind, seed: u64) {
    for shards in [1usize, 2] {
        let mut cfg = small_config(seed);
        cfg.retriever.shards = shards;
        let dir = fresh_dir(&format!("{:?}-s{shards}", kind));
        let enc = HashEncoder::new(DIM, seed ^ 0xEC);
        let corpus = Corpus::generate(&cfg.corpus);
        let emb = embed_corpus(&enc, &corpus);
        let reference =
            LiveKb::build(&cfg, kind, corpus.clone(), emb.clone(), DIM);
        let mut seg_cfg = cfg.clone();
        seg_cfg.segment.kb_dir = Some(dir.clone());
        let segmented = LiveKb::build_auto(&seg_cfg, kind, corpus.clone(),
                                           emb.clone(), DIM)
            .unwrap();
        let qs = probes(&corpus, &enc, 6, seed ^ 0x9A);
        assert_same(&reference, &segmented, &qs,
                    &format!("{kind:?} shards={shards} epoch0"));

        // Three ingest rounds of 10 docs: with memtable_docs=8 the
        // segment side freezes mid-stream while the publish cadence
        // (batch=5) stays identical on both sides.
        let mut next_id = corpus.len() as u32;
        for round in 0u64..3 {
            let docs = corpus.synth_docs(seed ^ (0x51 + round), next_id,
                                         10, (24, 64));
            next_id += docs.len() as u32;
            for live in [&reference, &segmented] {
                let mut w = live.writer.lock().unwrap();
                for d in &docs {
                    w.ingest(d.tokens.clone(), d.topic,
                             embed_doc(&enc, d)).unwrap();
                }
                w.flush().unwrap();
            }
            assert_eq!(reference.epochs.epoch(), segmented.epochs.epoch());
            assert_same(&reference, &segmented, &qs,
                        &format!("{kind:?} shards={shards} round={round}"));
        }

        // Compaction folds every tier into one segment and republishes:
        // one more epoch, zero result changes.
        {
            let mut w = segmented.writer.lock().unwrap();
            assert!(w.tier_count() > 1,
                    "{kind:?}: ingest rounds must have left tiers behind");
            assert!(w.run_compaction().unwrap());
            assert_eq!(w.tier_count(), 1);
        }
        assert_same(&reference, &segmented, &qs,
                    &format!("{kind:?} shards={shards} post-compaction"));

        // Cold restart: reopen from disk (mmap path) and compare again.
        drop(segmented);
        let reopened = LiveKb::build_auto(&seg_cfg, kind, corpus.clone(),
                                          emb.clone(), DIM)
            .unwrap();
        assert_eq!(reopened.epochs.snapshot().kb.len(),
                   reference.epochs.snapshot().kb.len());
        assert_same(&reference, &reopened, &qs,
                    &format!("{kind:?} shards={shards} reopened"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn segment_backed_matches_in_ram_edr() {
    check_kind(RetrieverKind::Edr, 0xA1FE);
}

#[test]
fn segment_backed_matches_in_ram_adr() {
    check_kind(RetrieverKind::Adr, 0xA2FE);
}

#[test]
fn segment_backed_matches_in_ram_sr() {
    check_kind(RetrieverKind::Sr, 0xA3FE);
}

#[test]
fn save_mmap_load_query_roundtrip() {
    // The direct SegmentedKb API: create on disk, reopen (which maps the
    // segment files), and verify the mapped store answers queries
    // bit-identically to an in-RAM build over the same corpus.
    let seed = 0xB4FE;
    let cfg = small_config(seed);
    let enc = HashEncoder::new(DIM, seed ^ 0xEC);
    let corpus = Corpus::generate(&cfg.corpus);
    let emb = embed_corpus(&enc, &corpus);
    let qs = probes(&corpus, &enc, 6, seed ^ 0x9A);
    for kind in [RetrieverKind::Edr, RetrieverKind::Adr, RetrieverKind::Sr] {
        let dir = fresh_dir(&format!("roundtrip-{kind:?}"));
        let (kb, recovered) =
            SegmentedKb::open_or_create(&dir, &cfg, kind, &corpus, &emb,
                                        DIM)
                .unwrap();
        assert!(kb.all_segments_mapped(),
                "{kind:?}: reopened segments must be zero-copy mmaps");
        assert_eq!(recovered.len(), corpus.len());
        let reference =
            LiveKb::build(&cfg, kind, corpus.clone(), emb.clone(), DIM);
        assert_eq!(
            bits(kb.snapshot(1).as_ref(), &qs),
            bits(reference.epochs.snapshot().kb.as_ref(), &qs),
            "{kind:?}: mmap-loaded store diverged from in-RAM build");
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn torn_write_falls_back_to_last_good_manifest() {
    // Crash-safety: truncate the newest segment file (a torn write at
    // freeze time). Its checksum/length validation must reject it, and
    // recovery must fall back to the previous manifest — the docs of the
    // torn memtable freeze are lost (documented: the memtable is
    // volatile), everything sealed before it survives.
    let seed = 0xC5FE;
    let cfg = small_config(seed);
    let dir = fresh_dir("torn");
    let enc = HashEncoder::new(DIM, seed ^ 0xEC);
    let corpus = Corpus::generate(&cfg.corpus);
    let emb = embed_corpus(&enc, &corpus);
    let n0 = corpus.len();
    SegmentedKb::create(&dir, &cfg, RetrieverKind::Sr, &corpus, &emb, DIM)
        .unwrap();
    let (mut kb, recovered) =
        SegmentedKb::open(&dir, &cfg, RetrieverKind::Sr).unwrap();
    // Two full memtables -> two frozen segments -> three manifests.
    for round in 0u64..2 {
        let docs = recovered.synth_docs(seed ^ (0x51 + round),
                                        kb.len() as u32,
                                        cfg.segment.memtable_docs,
                                        (24, 64));
        let embs: Vec<Vec<f32>> =
            docs.iter().map(|d| embed_doc(&enc, d)).collect();
        kb.append(&docs, &embs).unwrap();
    }
    assert_eq!(kb.len(), n0 + 2 * cfg.segment.memtable_docs);
    drop(kb);

    let store = SegmentStore::open(&dir).unwrap();
    assert_eq!(store.segments().len(), 3);
    let newest = dir.join(store.segments().last().unwrap().file_name());
    drop(store);
    let len = std::fs::metadata(&newest).unwrap().len();
    let f = std::fs::OpenOptions::new().write(true).open(&newest).unwrap();
    f.set_len(len / 2).unwrap();
    drop(f);

    let (kb, recovered) =
        SegmentedKb::open(&dir, &cfg, RetrieverKind::Sr).unwrap();
    assert_eq!(kb.len(), n0 + cfg.segment.memtable_docs,
               "recovery must fall back to the manifest before the torn \
                segment");
    assert_eq!(recovered.len(), kb.len());
    // The recovered store still serves.
    let qs = probes(&corpus, &enc, 4, seed ^ 0x9A);
    assert_eq!(bits(kb.snapshot(1).as_ref(), &qs).len(), qs.len());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn dense_payload_corruption_falls_back_to_last_good_manifest() {
    // Bit-rot safety (complements the torn-write test above, which only
    // covers truncation): flip ONE byte inside the newest segment's
    // DENSE payload. An EDR segment lays its sections out as META, DOCS,
    // DENSE — and the file ends exactly at the last payload byte (the
    // writer pads *between* sections only) — so the final byte of the
    // file is inside the DENSE f32 rows. The per-section FNV checksum
    // must reject the segment at open, before any payload byte is
    // interpreted, and recovery must fall back to the previous manifest.
    let seed = 0xC9FE;
    let cfg = small_config(seed);
    let dir = fresh_dir("bitrot");
    let enc = HashEncoder::new(DIM, seed ^ 0xEC);
    let corpus = Corpus::generate(&cfg.corpus);
    let emb = embed_corpus(&enc, &corpus);
    let n0 = corpus.len();
    SegmentedKb::create(&dir, &cfg, RetrieverKind::Edr, &corpus, &emb, DIM)
        .unwrap();
    let (mut kb, recovered) =
        SegmentedKb::open(&dir, &cfg, RetrieverKind::Edr).unwrap();
    for round in 0u64..2 {
        let docs = recovered.synth_docs(seed ^ (0x51 + round),
                                        kb.len() as u32,
                                        cfg.segment.memtable_docs,
                                        (24, 64));
        let embs: Vec<Vec<f32>> =
            docs.iter().map(|d| embed_doc(&enc, d)).collect();
        kb.append(&docs, &embs).unwrap();
    }
    drop(kb);

    let store = SegmentStore::open(&dir).unwrap();
    assert_eq!(store.segments().len(), 3);
    let newest = dir.join(store.segments().last().unwrap().file_name());
    drop(store);
    let mut bytes = std::fs::read(&newest).unwrap();
    let last = bytes.len() - 1;
    bytes[last] ^= 0xFF;
    std::fs::write(&newest, &bytes).unwrap();

    let (kb, recovered) =
        SegmentedKb::open(&dir, &cfg, RetrieverKind::Edr).unwrap();
    assert_eq!(kb.len(), n0 + cfg.segment.memtable_docs,
               "recovery must fall back to the manifest before the \
                corrupt DENSE segment");
    assert_eq!(recovered.len(), kb.len());
    // The fallback store still answers bit-identically to a fresh
    // in-RAM build over the surviving docs.
    let emb2 = embed_corpus(&enc, &recovered);
    let reference = LiveKb::build(&cfg, RetrieverKind::Edr,
                                  recovered.clone(), emb2, DIM);
    let qs = probes(&corpus, &enc, 4, seed ^ 0x9A);
    assert_eq!(bits(kb.snapshot(1).as_ref(), &qs),
               bits(reference.epochs.snapshot().kb.as_ref(), &qs),
               "fallback store diverged from in-RAM rebuild");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn serving_stays_pinned_under_compaction() {
    // Engine serving against a segment-backed live KB while a background
    // CompactionWorker runs: with a tiny memtable the concurrent ingest
    // stream freezes segments mid-run and the worker compacts them away,
    // yet every request must stay bit-identical to a sequential run
    // against its pinned epoch snapshot — swept over all three
    // retriever classes × kb_parallel {0, 4}.
    for (kind, seed) in [(RetrieverKind::Edr, 0xD6FEu64),
                         (RetrieverKind::Adr, 0xD7FE),
                         (RetrieverKind::Sr, 0xD8FE)] {
        for kb_parallel in [0usize, 4] {
            let mut cfg = small_config(seed);
            let dir = fresh_dir(&format!("serve-{kind:?}-p{kb_parallel}"));
            cfg.segment.kb_dir = Some(dir.clone());
            let enc = HashEncoder::new(DIM, seed ^ 0xEC);
            let corpus = Corpus::generate(&cfg.corpus);
            let emb = embed_corpus(&enc, &corpus);
            let lm = MockLm::new(cfg.corpus.vocab, 320, seed ^ 0x11);
            let live =
                LiveKb::build_auto(&cfg, kind, corpus.clone(), emb, DIM)
                    .unwrap();
            let mut worker = CompactionWorker::spawn(
                live.clone(), cfg.segment.compact_interval_ms,
                cfg.segment.compact_segments);
            let n = 6;
            let questions =
                generate_questions(Dataset::WikiQa, &corpus, n, seed ^ 0x9);
            let methods: Vec<QaMethod> =
                (0..n).map(|_| QaMethod::plain_spec()).collect();
            let opts = ralmspec::serving::EngineOptions {
                max_batch: 64,
                flush_us: 200,
                max_inflight: 8,
                kb_parallel,
                ..ralmspec::serving::EngineOptions::default()
            };
            let out = run_engine_cell_live(&lm, &enc, kind, &live,
                                           &questions, &methods, &cfg,
                                           opts, 3, 200.0)
                .unwrap();
            worker.stop();
            assert_eq!(out.metrics.len(), n);
            for i in 0..n {
                let pin = &out.pins[i];
                let QaMethod::Spec { prefetch, os3, async_verify, stride } =
                    methods[i]
                else {
                    unreachable!()
                };
                let pipe = SpecPipeline {
                    lm: &lm,
                    kb: pin.kb.as_ref(),
                    corpus: &*pin.corpus,
                    queries: QueryBuilder {
                        encoder: &enc,
                        mode: match kind {
                            RetrieverKind::Sr => QueryMode::Sparse,
                            _ => QueryMode::Dense,
                        },
                        dense_len: cfg.retriever.dense_query_len,
                        sparse_len: cfg.retriever.sparse_query_len,
                    },
                    opts: build_spec_options(&cfg, prefetch, os3,
                                             async_verify, stride),
                };
                let reference = pipe.run(&questions[i].tokens).unwrap();
                assert_eq!(
                    out.metrics[i].tokens_out, reference.tokens_out,
                    "SERVING UNDER COMPACTION DIVERGED: {kind:?} \
                     kb_parallel={kb_parallel} req={i} epoch={}",
                    pin.epoch);
            }
            // The writer still works after the run; compaction leaves a
            // single tier behind.
            {
                let mut w = live.writer.lock().unwrap();
                w.run_compaction().unwrap();
                assert_eq!(w.tier_count(), 1);
            }
            let _ = std::fs::remove_dir_all(&dir);
        }
    }
}
