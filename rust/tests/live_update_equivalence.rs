//! THE live knowledge-base correctness property (DESIGN.md ADR-006):
//! serving under **concurrent ingestion** must stay bit-identical per
//! request. Requests are admitted in waves while a writer ingests fresh
//! documents and publishes new epochs — between waves *and*, on a
//! background thread, during the engine run itself — and every request
//! pins the epoch snapshot it was admitted under. The property: each
//! request's token output equals a sequential `SpecPipeline::run`
//! (QA speculation) / `KnnLmSpec::run` (KNN-LM) of that request alone
//! against its pinned snapshot, bit for bit — swept over
//! EDR / HNSW / SR × shards {1, 2} × kb_parallel {0, 4} ×
//! concurrency {1, 8}.
//!
//! Also: the router-level ingest-while-serving smoke (`Method::Ingest`
//! through an `EngineBackend` with a live KB — the CI engine-smoke
//! job's live cell), and the frozen-worker rejection contract.

use ralmspec::config::{Config, CorpusConfig, RetrieverKind};
use ralmspec::datagen::{embed_corpus, generate_questions, generate_stream,
                        Corpus, Dataset, Document, HashEncoder};
use ralmspec::eval::{build_spec_options, run_engine_cell_live, QaMethod};
use ralmspec::knnlm::{Datastore, KnnLmSpec, KnnServeOptions, KnnTask};
use ralmspec::lm::MockLm;
use ralmspec::retriever::epoch::MutableDense;
use ralmspec::retriever::{LiveKb, MutableRetriever, Retriever};
use ralmspec::serving::{EngineBackend, EngineOptions, Method, Request,
                        Router, ServeEngine};
use ralmspec::spec::{QueryBuilder, QueryMode, SpecPipeline, StridePolicy};
use std::collections::HashSet;
use std::sync::Arc;

const DIM: usize = ralmspec::runtime::RETRIEVAL_DIM;

fn small_config(seed: u64) -> Config {
    let mut cfg = Config::default();
    cfg.corpus = CorpusConfig {
        n_docs: 400,
        n_topics: 12,
        doc_len: (24, 64),
        seed,
        ..CorpusConfig::default()
    };
    cfg.retriever.hnsw_ef_construction = 40;
    cfg.retriever.hnsw_ef_search = 32;
    cfg.spec.max_new_tokens = 20;
    // Small publish batches so a handful of ingested docs flips epochs.
    cfg.ingest.batch = 5;
    cfg
}

/// Heterogeneous speculative mix (prefetch sizes, OS³, async, a long
/// stride) so coalesced flushes carry several distinct (k, epoch)
/// groups.
fn mixed_methods(n: usize) -> Vec<QaMethod> {
    (0..n)
        .map(|i| match i % 5 {
            0 => QaMethod::plain_spec(),
            1 => QaMethod::spec(20, false, false),
            2 => QaMethod::spec(1, true, false),
            3 => QaMethod::spec(1, false, true),
            _ => QaMethod::Spec {
                prefetch: 1,
                os3: false,
                async_verify: false,
                stride: 8,
            },
        })
        .collect()
}

/// One live cell: engine-served under concurrent ingestion, then every
/// request re-run sequentially against its pinned snapshot and compared
/// bit-for-bit. The SAME live KB is reused across the sweep's cells —
/// the knowledge base just keeps growing, which is the point.
fn check_live_cell(cfg: &Config, enc: &HashEncoder, lm: &MockLm,
                   kind: RetrieverKind, live: &Arc<LiveKb>,
                   concurrency: usize, kb_parallel: usize, n: usize,
                   seed: u64) {
    let corpus = live.epochs.snapshot().corpus.clone();
    let questions = generate_questions(Dataset::WikiQa, &corpus, n, seed);
    let methods = mixed_methods(n);
    let opts = EngineOptions {
        max_batch: 64,
        flush_us: 200,
        max_inflight: concurrency,
        kb_parallel,
        ..EngineOptions::default()
    };
    let out = run_engine_cell_live(lm, enc, kind, live, &questions,
                                   &methods, cfg, opts, 3, 200.0)
        .unwrap();
    assert_eq!(out.metrics.len(), n);
    assert!(out.ingest.epochs_published >= 2,
            "{kind:?}: the cell must actually publish epochs");

    // Wave admission with publishes in between must pin several epochs.
    let distinct: HashSet<u64> = out.pins.iter().map(|p| p.epoch).collect();
    assert!(distinct.len() >= 2,
            "{kind:?} conc={concurrency} kb_parallel={kb_parallel}: \
             expected multiple pinned epochs, got {distinct:?}");
    assert_eq!(out.stats.epochs_served, distinct.len() as u64);

    // THE property: per request, engine-under-ingestion output ==
    // sequential run against the pinned snapshot.
    for i in 0..n {
        let pin = &out.pins[i];
        assert_eq!(out.metrics[i].epoch, pin.epoch,
                   "request {i} metrics must report its pinned epoch");
        let QaMethod::Spec { prefetch, os3, async_verify, stride } =
            methods[i]
        else {
            unreachable!()
        };
        let pipe = SpecPipeline {
            lm,
            kb: pin.kb.as_ref(),
            corpus: &*pin.corpus,
            queries: QueryBuilder {
                encoder: enc,
                mode: match kind {
                    RetrieverKind::Sr => QueryMode::Sparse,
                    _ => QueryMode::Dense,
                },
                dense_len: cfg.retriever.dense_query_len,
                sparse_len: cfg.retriever.sparse_query_len,
            },
            opts: build_spec_options(cfg, prefetch, os3, async_verify,
                                     stride),
        };
        let reference = pipe.run(&questions[i].tokens).unwrap();
        assert_eq!(
            out.metrics[i].tokens_out, reference.tokens_out,
            "LIVE SERVING DIVERGED FROM PINNED EPOCH: {kind:?} \
             shards={} conc={concurrency} kb_parallel={kb_parallel} \
             req={i} epoch={} method={:?}",
            cfg.retriever.shards, pin.epoch, methods[i]);
    }
}

/// The acceptance sweep for one retriever class:
/// shards {1, 2} × kb_parallel {0, 4} × concurrency {1, 8}.
fn sweep_kind(kind: RetrieverKind, seed: u64) {
    let enc = HashEncoder::new(DIM, seed ^ 0xEC);
    for shards in [1usize, 2] {
        let mut cfg = small_config(seed);
        cfg.retriever.shards = shards;
        let corpus = Corpus::generate(&cfg.corpus);
        let emb = embed_corpus(&enc, &corpus);
        let lm = MockLm::new(cfg.corpus.vocab, 320, seed ^ 0x11);
        let live = LiveKb::build(&cfg, kind, corpus, emb, DIM);
        for (cell, &(concurrency, kb_parallel)) in
            [(1usize, 0usize), (1, 4), (8, 0), (8, 4)].iter().enumerate()
        {
            check_live_cell(&cfg, &enc, &lm, kind, &live, concurrency,
                            kb_parallel, 6,
                            seed ^ ((shards as u64) << 8)
                                ^ ((cell as u64) << 16));
        }
    }
}

#[test]
fn live_serving_matches_pinned_epoch_edr() {
    sweep_kind(RetrieverKind::Edr, 0x11FE);
}

#[test]
fn live_serving_matches_pinned_epoch_adr() {
    sweep_kind(RetrieverKind::Adr, 0x22FE);
}

#[test]
fn live_serving_matches_pinned_epoch_sr() {
    sweep_kind(RetrieverKind::Sr, 0x33FE);
}

#[test]
fn knn_tasks_pin_epochs_and_stay_bit_identical() {
    // KNN-LM side of task pinning: epoch snapshots are growing prefixes
    // of the datastore key matrix (a live dense index over an
    // append-only datastore). Tasks pinned to different epochs — with
    // mixed k so flushes carry several (k, epoch) groups — must each
    // stay bit-identical to a sequential KnnLmSpec::run against their
    // pinned snapshot.
    let seed = 0x44FE;
    let cfg = CorpusConfig { seed, ..CorpusConfig::default() };
    let n_entries = 2400usize;
    let stream = generate_stream(&cfg, n_entries + 400, seed);
    let lm_seed = seed ^ 0x11;
    let ds = Arc::new(Datastore::build_mock(&stream, DIM, lm_seed ^ 0xE,
                                            n_entries));
    let lm = MockLm::new(cfg.vocab, 320, lm_seed);
    let mut rng = ralmspec::util::Rng::new(seed ^ 0x77);
    let prompts: Vec<Vec<u32>> = (0..8)
        .map(|_| {
            let start = rng.gen_range(stream.len() - 40);
            stream.tokens[start..start + 20].to_vec()
        })
        .collect();

    // Three epochs: 60%, 80%, 100% of the key matrix.
    let cuts = [n_entries * 6 / 10, n_entries * 8 / 10, n_entries];
    let mut index =
        MutableDense::new(DIM, ds.keys.data[..cuts[0] * DIM].to_vec());
    let mut snaps: Vec<Arc<dyn Retriever>> = vec![index.snapshot(1)];
    for w in 1..cuts.len() {
        let docs: Vec<Document> = (cuts[w - 1]..cuts[w])
            .map(|i| Document { id: i as u32, topic: 0, tokens: vec![] })
            .collect();
        let embs: Vec<Vec<f32>> = (cuts[w - 1]..cuts[w])
            .map(|i| ds.keys.row(i as u32).to_vec())
            .collect();
        index.append(&docs, &embs).unwrap();
        snaps.push(index.snapshot(1));
    }

    let mk_opts = |k: usize| KnnServeOptions {
        k,
        stride: StridePolicy::Fixed(4),
        max_new: 16,
        ..KnnServeOptions::default()
    };
    let mut engine: ServeEngine<KnnTask<MockLm>> = ServeEngine::new(
        snaps[0].clone(),
        EngineOptions { max_batch: 64, flush_us: 200, max_inflight: 8,
                        kb_parallel: 2, ..EngineOptions::default() });
    for (e, snap) in snaps.iter().enumerate() {
        engine.register_epoch(e as u64, snap.clone());
    }
    let pins: Vec<usize> = (0..prompts.len()).map(|i| i % 3).collect();
    let ks: Vec<usize> = (0..prompts.len())
        .map(|i| [4usize, 16][i % 2])
        .collect();
    for (i, p) in prompts.iter().enumerate() {
        engine.submit(
            i as u64,
            KnnTask::new(&lm, ds.as_ref(), mk_opts(ks[i]), p)
                .pin_epoch(pins[i] as u64));
    }
    let done = engine.run().unwrap();
    assert_eq!(done.len(), prompts.len());
    assert_eq!(engine.stats().epochs_served, 3);

    for (id, m) in &done {
        let i = *id as usize;
        assert_eq!(m.epoch, pins[i] as u64);
        let reference = KnnLmSpec {
            lm: &lm,
            kb: snaps[pins[i]].as_ref(),
            ds: ds.as_ref(),
            opts: mk_opts(ks[i]),
        }
        .run(&prompts[i])
        .unwrap();
        assert_eq!(m.tokens_out, reference.tokens_out,
                   "KNN LIVE PINNING DIVERGED: req={i} epoch={} k={}",
                   pins[i], ks[i]);
    }
}

#[test]
fn router_ingest_while_serving_smoke() {
    // End-to-end Method::Ingest: a router worker with a live-KB
    // EngineBackend accepts interleaved ingest and query traffic. The CI
    // engine-smoke job runs this as the live cell: every request must
    // resolve (no hang), ingests must advance the epoch, and queries
    // must keep producing tokens throughout.
    let seed = 0x55FE;
    let cfg = small_config(seed);
    let enc = HashEncoder::new(DIM, seed ^ 0xEC);
    let corpus = Corpus::generate(&cfg.corpus);
    let emb = embed_corpus(&enc, &corpus);
    let live = LiveKb::build(&cfg, RetrieverKind::Edr, corpus.clone(),
                             emb, DIM);
    let base_snapshot = live.epochs.snapshot();
    let questions = generate_questions(Dataset::WikiQa, &corpus, 6, 9);
    // Synthetic ingest payloads (tokens only; the worker embeds).
    let ingest_docs =
        corpus.synth_docs(seed ^ 0xD0C, corpus.len() as u32, 12, (24, 64));

    let cfg2 = cfg.clone();
    let live2 = live.clone();
    let router = Router::spawn(64, 1, move || {
        Ok(EngineBackend {
            lm: MockLm::new(cfg2.corpus.vocab, 320, seed ^ 0x11),
            kb: base_snapshot.kb.clone(),
            corpus: base_snapshot.corpus.clone(),
            encoder: Box::new(HashEncoder::new(DIM, seed ^ 0xEC)),
            mode: QueryMode::Dense,
            cfg: cfg2.clone(),
            engine_opts: EngineOptions {
                max_batch: 16,
                flush_us: 500,
                max_inflight: 0,
                kb_parallel: 2,
                ..EngineOptions::default()
            },
            live: Some(live2.clone()),
            tenant_kbs: Vec::new(),
        })
    });

    let mut id = 0u64;
    let mut spec_outputs = 0usize;
    let mut published_epochs = Vec::new();
    for round in 0..6 {
        // Two ingests...
        for j in 0..2 {
            let d = &ingest_docs[round * 2 + j];
            let resp = router
                .submit_blocking(Request {
                    id,
                    question: d.tokens.clone(),
                    method: Method::Ingest,
                    ..Request::default()
                })
                .unwrap();
            assert!(resp.tokens.is_empty(),
                    "ingest responses carry no tokens");
            published_epochs.push(resp.metrics.epoch);
            id += 1;
        }
        // ...then a query, which must still serve fine.
        let q = &questions[round % questions.len()];
        let resp = router
            .submit_blocking(Request {
                id,
                question: q.tokens.clone(),
                method: Method::Spec {
                    prefetch: true,
                    os3: false,
                    async_verify: false,
                },
                ..Request::default()
            })
            .unwrap();
        assert!(!resp.tokens.is_empty(),
                "query under ingestion produced no tokens");
        spec_outputs += 1;
        id += 1;
    }
    // 12 docs at ingest.batch=5 => at least 2 published epochs.
    assert!(live.epochs.epoch() >= 2,
            "ingestion must advance the epoch (at {})",
            live.epochs.epoch());
    assert!(live.epochs.snapshot().kb.len() > corpus.len(),
            "published snapshots must contain the ingested docs");
    assert_eq!(spec_outputs, 6);
    assert!(published_epochs.iter().any(|&e| e > 0),
            "some ingest response must report a published epoch");
    router.shutdown();
}

#[test]
fn unregistered_pinned_epoch_fails_loudly() {
    // A task pinned to an epoch nobody registered must NOT be silently
    // served by the default knowledge base (wrong-snapshot scoring is
    // the bug class ADR-006 exists to prevent): its request fails with
    // a pointed error while epoch-0 tasks keep serving.
    let seed = 0x77FE;
    let cfg = small_config(seed);
    let enc = HashEncoder::new(DIM, seed ^ 0xEC);
    let bed = ralmspec::eval::TestBed::build(&cfg, &enc);
    let kb = bed.retriever(RetrieverKind::Edr);
    let lm = MockLm::new(cfg.corpus.vocab, 320, seed ^ 0x11);
    let questions = generate_questions(Dataset::WikiQa, &bed.corpus, 2, 5);
    let queries = QueryBuilder {
        encoder: &enc,
        mode: QueryMode::Dense,
        dense_len: cfg.retriever.dense_query_len,
        sparse_len: cfg.retriever.sparse_query_len,
    };
    let opts = build_spec_options(&cfg, 1, false, false, 3);
    let mut engine: ServeEngine<ralmspec::spec::SpecTask<MockLm>> =
        ServeEngine::new(
            kb.clone(),
            EngineOptions { max_batch: 16, flush_us: 200,
                            max_inflight: 0, kb_parallel: 0,
                            ..EngineOptions::default() });
    engine.submit(0, ralmspec::spec::SpecTask::new(
        &lm, kb.as_ref(), &bed.corpus, queries, opts.clone(),
        &questions[0].tokens));
    engine.submit(1, ralmspec::spec::SpecTask::new(
        &lm, kb.as_ref(), &bed.corpus, queries, opts,
        &questions[1].tokens)
        .pin_epoch(7));
    let done = engine.run().unwrap();
    let failed = engine.take_failed();
    assert_eq!(done.len(), 1, "the epoch-0 task must complete");
    assert_eq!(done[0].0, 0);
    assert_eq!(failed.len(), 1, "the unregistered pin must fail");
    assert_eq!(failed[0].0, 1);
    assert!(failed[0].1.contains("epoch 7"),
            "error must name the unregistered epoch: {}", failed[0].1);
}

#[test]
fn frozen_worker_rejects_ingest() {
    let seed = 0x66FE;
    let cfg = small_config(seed);
    let enc = HashEncoder::new(DIM, seed ^ 0xEC);
    let bed = ralmspec::eval::TestBed::build(&cfg, &enc);
    let kb = bed.retriever(RetrieverKind::Edr);
    let corpus = bed.corpus.clone();
    let cfg2 = cfg.clone();
    let router = Router::spawn(8, 1, move || {
        Ok(EngineBackend {
            lm: MockLm::new(cfg2.corpus.vocab, 320, seed ^ 0x11),
            kb: kb.clone(),
            corpus: corpus.clone(),
            encoder: Box::new(HashEncoder::new(DIM, seed ^ 0xEC)),
            mode: QueryMode::Dense,
            cfg: cfg2.clone(),
            engine_opts: EngineOptions {
                max_batch: 8,
                flush_us: 200,
                max_inflight: 0,
                kb_parallel: 0,
                ..EngineOptions::default()
            },
            live: None,
            tenant_kbs: Vec::new(),
        })
    });
    let err = router
        .submit_blocking(Request {
            id: 1,
            question: vec![100, 101, 102],
            method: Method::Ingest,
            ..Request::default()
        })
        .unwrap_err();
    assert!(err.to_string().contains("live"),
            "frozen workers must name the problem: {err:#}");
    router.shutdown();
}
