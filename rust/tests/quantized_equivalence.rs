//! THE quantization correctness property (DESIGN.md ADR-010): the SQ8
//! codec — u8 scalar-quantized candidate generation + exact f32 re-score
//! of every surviving row — must be **bit-identical** to the
//! full-precision flat scan, not approximately equal. The per-row
//! reconstruction-error bound makes pruning conservative, and survivors
//! are re-scored with the same reduction order as the packed f32 scan,
//! so `(score desc, id asc)` top-k lists match to the last bit.
//!
//! Sweeps: dims × k × oversample × shards {1, 2} in RAM; the
//! segment-persisted codec (`DENSE_SQ8` sections) vs the in-RAM
//! full-precision backend through ingest rounds, compaction, and a cold
//! reopen; engine serving × kb_parallel {0, 4} under live compaction;
//! and a one-byte `DENSE_SQ8` payload corruption, which the section
//! checksum must reject at open — falling back to the last good
//! manifest — before any payload byte is interpreted.

use ralmspec::config::{Config, CorpusConfig, DenseCodec, RetrieverKind};
use ralmspec::datagen::{embed_corpus, embed_doc, generate_questions,
                        Corpus, Dataset, Encoder, HashEncoder};
use ralmspec::eval::{build_spec_options, run_engine_cell_live, QaMethod};
use ralmspec::lm::MockLm;
use ralmspec::retriever::dense::{DenseExact, EmbeddingMatrix};
use ralmspec::retriever::{CompactionWorker, LiveKb, MutableRetriever,
                          Retriever, SegmentStore, SegmentedKb,
                          ShardedRetriever, SpecQuery};
use ralmspec::spec::{QueryBuilder, QueryMode, SpecPipeline};
use ralmspec::util::{Rng, Scored};
use std::path::PathBuf;
use std::sync::Arc;

const DIM: usize = ralmspec::runtime::RETRIEVAL_DIM;

fn small_config(seed: u64) -> Config {
    let mut cfg = Config::default();
    cfg.corpus = CorpusConfig {
        n_docs: 220,
        n_topics: 12,
        doc_len: (24, 64),
        seed,
        ..CorpusConfig::default()
    };
    cfg.retriever.hnsw_ef_construction = 40;
    cfg.retriever.hnsw_ef_search = 32;
    cfg.spec.max_new_tokens = 20;
    cfg.ingest.batch = 5;
    cfg.segment.memtable_docs = 8;
    cfg.segment.compact_interval_ms = 5;
    cfg.segment.compact_segments = 2;
    cfg
}

fn fresh_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("ralmspec-sq8test-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn probes(corpus: &Corpus, enc: &HashEncoder, n: usize,
          seed: u64) -> Vec<SpecQuery> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            let topic = (i % corpus.n_topics) as u32;
            let terms = corpus.topic_tokens(topic, 24, &mut rng);
            SpecQuery { dense: enc.encode(&terms), terms }
        })
        .collect()
}

fn bits(kb: &dyn Retriever, qs: &[SpecQuery],
        k: usize) -> Vec<Vec<(u32, u32)>> {
    kb.retrieve_batch(qs, k)
        .into_iter()
        .map(|hits: Vec<Scored>| {
            hits.into_iter()
                .map(|s| (s.id, s.score.to_bits()))
                .collect()
        })
        .collect()
}

fn assert_same(reference: &Arc<LiveKb>, quantized: &Arc<LiveKb>,
               qs: &[SpecQuery], ctx: &str) {
    let r = reference.epochs.snapshot();
    let s = quantized.epochs.snapshot();
    assert_eq!(r.kb.len(), s.kb.len(), "{ctx}: KB length diverged");
    assert_eq!(bits(r.kb.as_ref(), qs, 10), bits(s.kb.as_ref(), qs, 10),
               "{ctx}: SQ8 RETRIEVAL DIVERGED FROM FULL PRECISION");
    for (qi, q) in qs.iter().enumerate() {
        for doc in [0u32, (r.kb.len() as u32) / 2, r.kb.len() as u32 - 1] {
            assert_eq!(r.kb.score_doc(q, doc).to_bits(),
                       s.kb.score_doc(q, doc).to_bits(),
                       "{ctx}: score_doc diverged (q={qi} doc={doc})");
        }
    }
}

#[test]
fn sq8_flat_scan_matches_full_precision() {
    // In-RAM codec sweep: dims (including a non-lane-multiple), k,
    // oversample (1.0 = tightest pruning heap), and shard counts. The
    // fixture mixes degenerate rows (all-zero, constant — scale = 0) in
    // with random unit vectors so the quantizer's flat-row path is on
    // the sweep too.
    for &dim in &[8usize, 33, 64] {
        let mut rng = Rng::new(0x9000 + dim as u64);
        let n = 300;
        let mut data = vec![0.0f32; dim];         // all-zero row
        data.extend(std::iter::repeat(0.5).take(dim)); // constant row
        for _ in 2..n {
            data.extend(rng.unit_vector(dim));
        }
        let emb = Arc::new(EmbeddingMatrix::new(dim, data));
        let full = Arc::new(DenseExact::new(emb.clone()));
        let qs: Vec<SpecQuery> = (0..7)
            .map(|_| SpecQuery::dense_only(rng.unit_vector(dim)))
            .collect();
        for &oversample in &[1.0f64, 2.0, 6.0] {
            let sq8 =
                Arc::new(DenseExact::with_sq8(emb.clone(), oversample));
            for &k in &[1usize, 5, 20, 64] {
                assert_eq!(
                    bits(full.as_ref(), &qs, k), bits(sq8.as_ref(), &qs, k),
                    "dim={dim} oversample={oversample} k={k}: \
                     SQ8 top-k diverged from full precision");
            }
            for shards in [1usize, 2] {
                let sf = ShardedRetriever::new(full.clone(), shards);
                let ss = ShardedRetriever::new(sq8.clone(), shards);
                assert_eq!(
                    bits(&sf, &qs, 10), bits(&ss, &qs, 10),
                    "dim={dim} oversample={oversample} shards={shards}: \
                     sharded SQ8 diverged");
            }
        }
    }
}

#[test]
fn sq8_segment_backend_matches_full_in_ram() {
    // The full persistence × quantization cross: a segment-backed KB
    // under `dense.codec = sq8` (every freeze and compaction writes
    // DENSE_SQ8 sections, every scan runs the two-phase quantized path)
    // must stay bit-identical to the fully in-RAM **full-precision**
    // backend at every epoch — through memtable freezes, an explicit
    // compaction, and a cold reopen from disk.
    let seed = 0xE1FE;
    for shards in [1usize, 2] {
        let mut cfg = small_config(seed);
        cfg.retriever.shards = shards;
        let dir = fresh_dir(&format!("seg-s{shards}"));
        let enc = HashEncoder::new(DIM, seed ^ 0xEC);
        let corpus = Corpus::generate(&cfg.corpus);
        let emb = embed_corpus(&enc, &corpus);
        let reference = LiveKb::build(&cfg, RetrieverKind::Edr,
                                      corpus.clone(), emb.clone(), DIM);
        let mut sq8_cfg = cfg.clone();
        sq8_cfg.dense.codec = DenseCodec::Sq8;
        // One shard case on the tightest pruning heap, the other on the
        // default.
        sq8_cfg.dense.oversample = if shards == 1 { 1.0 } else { 2.0 };
        sq8_cfg.segment.kb_dir = Some(dir.clone());
        let quantized = LiveKb::build_auto(&sq8_cfg, RetrieverKind::Edr,
                                           corpus.clone(), emb.clone(), DIM)
            .unwrap();
        let qs = probes(&corpus, &enc, 6, seed ^ 0x9A);
        assert_same(&reference, &quantized, &qs,
                    &format!("shards={shards} epoch0"));

        let mut next_id = corpus.len() as u32;
        for round in 0u64..3 {
            let docs = corpus.synth_docs(seed ^ (0x51 + round), next_id,
                                         10, (24, 64));
            next_id += docs.len() as u32;
            for live in [&reference, &quantized] {
                let mut w = live.writer.lock().unwrap();
                for d in &docs {
                    w.ingest(d.tokens.clone(), d.topic,
                             embed_doc(&enc, d)).unwrap();
                }
                w.flush().unwrap();
            }
            assert_eq!(reference.epochs.epoch(), quantized.epochs.epoch());
            assert_same(&reference, &quantized, &qs,
                        &format!("shards={shards} round={round}"));
        }

        {
            let mut w = quantized.writer.lock().unwrap();
            assert!(w.tier_count() > 1,
                    "ingest rounds must have left tiers behind");
            assert!(w.run_compaction().unwrap());
            assert_eq!(w.tier_count(), 1);
        }
        assert_same(&reference, &quantized, &qs,
                    &format!("shards={shards} post-compaction"));

        drop(quantized);
        let reopened = LiveKb::build_auto(&sq8_cfg, RetrieverKind::Edr,
                                          corpus.clone(), emb.clone(), DIM)
            .unwrap();
        assert_same(&reference, &reopened, &qs,
                    &format!("shards={shards} reopened"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn sq8_serving_stays_pinned_under_compaction() {
    // Engine serving against an SQ8 segment-backed live KB while a
    // background CompactionWorker runs: every request must stay
    // bit-identical to a sequential run against its pinned epoch
    // snapshot — swept over kb_parallel {0, 4}.
    let seed = 0xE9FEu64;
    for kb_parallel in [0usize, 4] {
        let mut cfg = small_config(seed);
        cfg.dense.codec = DenseCodec::Sq8;
        let dir = fresh_dir(&format!("serve-p{kb_parallel}"));
        cfg.segment.kb_dir = Some(dir.clone());
        let enc = HashEncoder::new(DIM, seed ^ 0xEC);
        let corpus = Corpus::generate(&cfg.corpus);
        let emb = embed_corpus(&enc, &corpus);
        let lm = MockLm::new(cfg.corpus.vocab, 320, seed ^ 0x11);
        let live = LiveKb::build_auto(&cfg, RetrieverKind::Edr,
                                      corpus.clone(), emb, DIM)
            .unwrap();
        let mut worker = CompactionWorker::spawn(
            live.clone(), cfg.segment.compact_interval_ms,
            cfg.segment.compact_segments);
        let n = 6;
        let questions =
            generate_questions(Dataset::WikiQa, &corpus, n, seed ^ 0x9);
        let methods: Vec<QaMethod> =
            (0..n).map(|_| QaMethod::plain_spec()).collect();
        let opts = ralmspec::serving::EngineOptions {
            max_batch: 64,
            flush_us: 200,
            max_inflight: 8,
            kb_parallel,
            ..ralmspec::serving::EngineOptions::default()
        };
        let out = run_engine_cell_live(&lm, &enc, RetrieverKind::Edr,
                                       &live, &questions, &methods, &cfg,
                                       opts, 3, 200.0)
            .unwrap();
        worker.stop();
        assert_eq!(out.metrics.len(), n);
        for i in 0..n {
            let pin = &out.pins[i];
            let QaMethod::Spec { prefetch, os3, async_verify, stride } =
                methods[i]
            else {
                unreachable!()
            };
            let pipe = SpecPipeline {
                lm: &lm,
                kb: pin.kb.as_ref(),
                corpus: &*pin.corpus,
                queries: QueryBuilder {
                    encoder: &enc,
                    mode: QueryMode::Dense,
                    dense_len: cfg.retriever.dense_query_len,
                    sparse_len: cfg.retriever.sparse_query_len,
                },
                opts: build_spec_options(&cfg, prefetch, os3,
                                         async_verify, stride),
            };
            let reference = pipe.run(&questions[i].tokens).unwrap();
            assert_eq!(
                out.metrics[i].tokens_out, reference.tokens_out,
                "SQ8 SERVING UNDER COMPACTION DIVERGED: \
                 kb_parallel={kb_parallel} req={i} epoch={}",
                pin.epoch);
        }
        {
            let mut w = live.writer.lock().unwrap();
            w.run_compaction().unwrap();
            assert_eq!(w.tier_count(), 1);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}

#[test]
fn sq8_payload_corruption_falls_back_to_last_good_manifest() {
    // Flip one byte in the newest segment's DENSE_SQ8 payload. An EDR
    // segment under `dense.codec = sq8` lays its sections out as META,
    // DOCS, DENSE, DENSE_SQ8, and the file ends exactly at the last
    // payload byte (the writer pads *between* sections only) — so the
    // final byte of the file is the last u8 code of the DENSE_SQ8
    // section. The per-section FNV checksum must reject the segment at
    // open, before any payload byte is interpreted, and recovery must
    // fall back to the previous manifest.
    let seed = 0xF2FE;
    let mut cfg = small_config(seed);
    cfg.dense.codec = DenseCodec::Sq8;
    let dir = fresh_dir("corrupt");
    let enc = HashEncoder::new(DIM, seed ^ 0xEC);
    let corpus = Corpus::generate(&cfg.corpus);
    let emb = embed_corpus(&enc, &corpus);
    let n0 = corpus.len();
    SegmentedKb::create(&dir, &cfg, RetrieverKind::Edr, &corpus, &emb, DIM)
        .unwrap();
    let (mut kb, recovered) =
        SegmentedKb::open(&dir, &cfg, RetrieverKind::Edr).unwrap();
    for round in 0u64..2 {
        let docs = recovered.synth_docs(seed ^ (0x51 + round),
                                        kb.len() as u32,
                                        cfg.segment.memtable_docs,
                                        (24, 64));
        let embs: Vec<Vec<f32>> =
            docs.iter().map(|d| embed_doc(&enc, d)).collect();
        kb.append(&docs, &embs).unwrap();
    }
    assert_eq!(kb.len(), n0 + 2 * cfg.segment.memtable_docs);
    drop(kb);

    let store = SegmentStore::open(&dir).unwrap();
    assert_eq!(store.segments().len(), 3);
    let newest = dir.join(store.segments().last().unwrap().file_name());
    drop(store);
    let mut bytes = std::fs::read(&newest).unwrap();
    let last = bytes.len() - 1;
    bytes[last] ^= 0xFF;
    std::fs::write(&newest, &bytes).unwrap();

    let (kb, recovered) =
        SegmentedKb::open(&dir, &cfg, RetrieverKind::Edr).unwrap();
    assert_eq!(kb.len(), n0 + cfg.segment.memtable_docs,
               "recovery must fall back to the manifest before the \
                corrupt DENSE_SQ8 segment");
    assert_eq!(recovered.len(), kb.len());
    // The fallback store still answers bit-identically to a fresh
    // in-RAM build over the surviving docs.
    let emb2 = embed_corpus(&enc, &recovered);
    let reference = LiveKb::build(&cfg, RetrieverKind::Edr,
                                  recovered.clone(), emb2, DIM);
    let qs = probes(&corpus, &enc, 4, seed ^ 0x9A);
    assert_eq!(bits(kb.snapshot(1).as_ref(), &qs, 10),
               bits(reference.epochs.snapshot().kb.as_ref(), &qs, 10),
               "fallback store diverged from in-RAM rebuild");
    let _ = std::fs::remove_dir_all(&dir);
}
