//! THE correctness property of the paper (§3): RaLMSpec provably preserves
//! the baseline's output. For every retriever class, stride policy,
//! prefetch size, and async setting — over many random corpora, questions,
//! and mock-LM seeds — the speculative pipeline must emit token-for-token
//! the RaLMSeq output.
//!
//! Runs on the deterministic MockLm (no artifacts needed), which honours
//! the same contract as the PJRT LM: identical context -> identical logits.
//! The PJRT version of this check lives in runtime_artifacts.rs.

use ralmspec::baseline::{BaselineOptions, RalmSeq};
use ralmspec::config::{Config, CorpusConfig, RetrieverKind};
use ralmspec::datagen::{generate_questions, Dataset, HashEncoder};
use ralmspec::eval::TestBed;
use ralmspec::lm::MockLm;
use ralmspec::spec::{Os3Config, QueryBuilder, SpecOptions, SpecPipeline,
                     StridePolicy};
use ralmspec::util::Rng;

fn small_config(seed: u64) -> Config {
    let mut cfg = Config::default();
    cfg.corpus = CorpusConfig {
        n_docs: 600,
        n_topics: 12,
        doc_len: (24, 80),
        seed,
        ..CorpusConfig::default()
    };
    cfg.retriever.hnsw_ef_construction = 40;
    cfg.retriever.hnsw_ef_search = 32;
    cfg.spec.max_new_tokens = 28;
    cfg
}

fn run_equivalence(seed: u64, kind: RetrieverKind, stride: StridePolicy,
                   prefetch: usize, async_verify: bool) {
    let cfg = small_config(seed);
    let enc = HashEncoder::new(ralmspec::runtime::RETRIEVAL_DIM, seed ^ 0xEC);
    let bed = TestBed::build(&cfg, &enc);
    let lm = MockLm::new(cfg.corpus.vocab, 320, seed ^ 0x11);
    let kb = bed.retriever(kind);
    let mode = ralmspec::eval::query_mode(kind);
    let questions = generate_questions(Dataset::WikiQa, &bed.corpus, 4, seed);

    for q in &questions {
        let queries = QueryBuilder {
            encoder: &enc,
            mode,
            dense_len: cfg.retriever.dense_query_len,
            sparse_len: cfg.retriever.sparse_query_len,
        };
        let base = RalmSeq {
            lm: &lm,
            kb: kb.as_ref(),
            corpus: &bed.corpus,
            queries,
            opts: BaselineOptions {
                gen_stride: cfg.spec.gen_stride,
                max_new: cfg.spec.max_new_tokens,
                max_doc_tokens: cfg.spec.max_doc_tokens,
            },
        }
        .run(&q.tokens)
        .unwrap();

        let queries = QueryBuilder {
            encoder: &enc,
            mode,
            dense_len: cfg.retriever.dense_query_len,
            sparse_len: cfg.retriever.sparse_query_len,
        };
        let spec = SpecPipeline {
            lm: &lm,
            kb: kb.as_ref(),
            corpus: &bed.corpus,
            queries,
            opts: SpecOptions {
                gen_stride: cfg.spec.gen_stride,
                stride: stride.clone(),
                prefetch,
                async_verify,
                max_new: cfg.spec.max_new_tokens,
                max_doc_tokens: cfg.spec.max_doc_tokens,
                cache_cap: 512,
            },
        }
        .run(&q.tokens)
        .unwrap();

        assert_eq!(
            spec.tokens_out, base.tokens_out,
            "OUTPUT DIVERGED: seed={seed} kind={kind:?} stride={stride:?} \
             prefetch={prefetch} async={async_verify} q={}", q.id);
    }
}

#[test]
fn equivalence_edr_basic() {
    run_equivalence(1, RetrieverKind::Edr, StridePolicy::Fixed(3), 1, false);
}

#[test]
fn equivalence_adr_basic() {
    run_equivalence(2, RetrieverKind::Adr, StridePolicy::Fixed(3), 1, false);
}

#[test]
fn equivalence_sr_basic() {
    run_equivalence(3, RetrieverKind::Sr, StridePolicy::Fixed(3), 1, false);
}

#[test]
fn equivalence_with_prefetch() {
    for kind in RetrieverKind::all() {
        run_equivalence(4, kind, StridePolicy::Fixed(3), 20, false);
        run_equivalence(5, kind, StridePolicy::Fixed(2), 256, false);
    }
}

#[test]
fn equivalence_with_os3() {
    for kind in RetrieverKind::all() {
        run_equivalence(6, kind,
                        StridePolicy::Os3(Os3Config::default()), 1, false);
        run_equivalence(7, kind,
                        StridePolicy::Os3(Os3Config::default()), 20, false);
    }
}

#[test]
fn equivalence_with_async_verification() {
    for kind in RetrieverKind::all() {
        run_equivalence(8, kind, StridePolicy::Fixed(3), 1, true);
        run_equivalence(9, kind,
                        StridePolicy::Os3(Os3Config {
                            async_mode: true,
                            ..Os3Config::default()
                        }),
                        20, true);
    }
}

#[test]
fn equivalence_extreme_strides() {
    for s in [1usize, 8, 16] {
        run_equivalence(10 + s as u64, RetrieverKind::Edr,
                        StridePolicy::Fixed(s), 1, false);
    }
}

/// Property-style sweep: random (seed, kind, stride, prefetch, async)
/// combinations. This is the in-tree substitute for proptest (offline
/// image): inputs are drawn from a seeded RNG, so failures reproduce.
#[test]
fn equivalence_randomized_sweep() {
    let mut rng = Rng::new(0xE05EED);
    for trial in 0..12 {
        let seed = rng.next_u64() % 10_000;
        let kind = RetrieverKind::all()[rng.gen_range(3)];
        let stride = if rng.next_f64() < 0.4 {
            StridePolicy::Os3(Os3Config {
                async_mode: rng.next_f64() < 0.5,
                ..Os3Config::default()
            })
        } else {
            StridePolicy::Fixed(1 + rng.gen_range(8))
        };
        let prefetch = [1usize, 5, 20, 64][rng.gen_range(4)];
        let async_verify = rng.next_f64() < 0.5;
        eprintln!("trial {trial}: seed={seed} kind={kind:?} {stride:?} \
                   p={prefetch} a={async_verify}");
        run_equivalence(seed, kind, stride, prefetch, async_verify);
    }
}

/// The speculative pipeline must never *lose* retrievals either: its
/// verified KB queries per request match the baseline's count (same number
/// of generation intervals), only batched differently.
#[test]
fn speculation_preserves_retrieval_schedule() {
    let cfg = small_config(77);
    let enc = HashEncoder::new(ralmspec::runtime::RETRIEVAL_DIM, 77 ^ 0xEC);
    let bed = TestBed::build(&cfg, &enc);
    let lm = MockLm::new(cfg.corpus.vocab, 320, 99);
    let kb = bed.retriever(RetrieverKind::Edr);
    let questions = generate_questions(Dataset::Nq, &bed.corpus, 3, 5);
    for q in &questions {
        let mk_queries = || QueryBuilder {
            encoder: &enc,
            mode: ralmspec::spec::QueryMode::Dense,
            dense_len: 32,
            sparse_len: 32,
        };
        let base = RalmSeq {
            lm: &lm, kb: kb.as_ref(), corpus: &bed.corpus,
            queries: mk_queries(),
            opts: BaselineOptions {
                gen_stride: 4, max_new: 28, max_doc_tokens: 192,
            },
        }.run(&q.tokens).unwrap();
        let spec = SpecPipeline {
            lm: &lm, kb: kb.as_ref(), corpus: &bed.corpus,
            queries: mk_queries(),
            opts: SpecOptions {
                gen_stride: 4,
                stride: StridePolicy::Fixed(3),
                prefetch: 1,
                async_verify: false,
                max_new: 28,
                max_doc_tokens: 192,
                cache_cap: 512,
            },
        }.run(&q.tokens).unwrap();
        // Every baseline query is re-issued inside some batched
        // verification (equality can be off by the trailing partial round).
        assert!(spec.kb_queries + 1 >= base.kb_queries,
                "spec verified too few queries: {} vs {}", spec.kb_queries,
                base.kb_queries);
        // But it must batch them into fewer KB calls.
        assert!(spec.kb_calls <= base.kb_calls,
                "speculation didn't reduce KB calls: {} vs {}",
                spec.kb_calls, base.kb_calls);
    }
}
