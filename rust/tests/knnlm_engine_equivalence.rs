//! THE KNN-LM serving-layer correctness property (DESIGN.md ADR-004 /
//! ADR-005): the concurrent engine may interleave N KNN-LM requests'
//! speculation steps, coalesce their cache primes and
//! relaxed-verification strides into shared datastore `retrieve_batch`
//! calls, and — with `kb_parallel >= 1` — run those calls asynchronously
//! with out-of-order completion and overlap-drive speculation, but every
//! request's token output must stay **bit-identical** to a sequential
//! `KnnLmSpec::run` of that request alone — across k ∈ {4, 32}, Fixed
//! and OS³ stride policies, sharded {1, 2} and unsharded datastore
//! retrievers, concurrency {1, 8, 32}, and `kb_parallel`
//! {0 (sync inline), 1, 2, 4}.
//!
//! Also the CI hang detector for the per-token workload
//! (`knn_engine_smoke_32_concurrent`), the router-level round-trip for
//! `Method::Knn` through `KnnEngineBackend`, and the router-level
//! failure contract: a panicking datastore call becomes error
//! `Response`s on exactly the owning requests while the worker survives.

use ralmspec::config::CorpusConfig;
use ralmspec::datagen::generate_stream;
use ralmspec::eval::run_knn_engine_cell;
use ralmspec::knnlm::{Datastore, KnnLmSpec, KnnServeOptions};
use ralmspec::lm::MockLm;
use ralmspec::retriever::dense::DenseExact;
use ralmspec::retriever::{Retriever, ShardedRetriever, SpecQuery};
use ralmspec::serving::{EngineOptions, KnnEngineBackend, Method, Request,
                        Router};
use ralmspec::spec::{Os3Config, StridePolicy};
use ralmspec::util::{Rng, Scored};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

const DIM: usize = ralmspec::runtime::RETRIEVAL_DIM;

struct Fixture {
    ds: Arc<Datastore>,
    lm: MockLm,
    prompts: Vec<Vec<u32>>,
}

fn fixture(seed: u64, n_entries: usize, n_prompts: usize) -> Fixture {
    let cfg = CorpusConfig { seed, ..CorpusConfig::default() };
    let stream = generate_stream(&cfg, n_entries + 400, seed);
    // MockLm's qproj lives in HashEncoder(lm_seed ^ 0xE) space; the
    // datastore keys must share it (same convention as
    // tests/knnlm_integration.rs).
    let lm_seed = seed ^ 0x11;
    let ds = Datastore::build_mock(&stream, DIM, lm_seed ^ 0xE, n_entries);
    let lm = MockLm::new(cfg.vocab, 320, lm_seed);
    let mut rng = Rng::new(seed ^ 0x77);
    let prompts = (0..n_prompts)
        .map(|_| {
            let start = rng.gen_range(stream.len() - 40);
            stream.tokens[start..start + 20].to_vec()
        })
        .collect();
    Fixture { ds: Arc::new(ds), lm, prompts }
}

fn opts(k: usize, stride: StridePolicy) -> KnnServeOptions {
    KnnServeOptions {
        k,
        stride,
        max_new: 24,
        cache_cap: 4096.max(4 * k),
        ..KnnServeOptions::default()
    }
}

fn stride_policies() -> Vec<StridePolicy> {
    vec![StridePolicy::Fixed(3),
         StridePolicy::Os3(Os3Config::default())]
}

/// Engine-served outputs must equal per-request sequential
/// `KnnLmSpec::run` bit-for-bit across every `kb_parallel` setting, and
/// high concurrency must actually coalesce.
fn check_equivalence(seed: u64, shards: usize, concurrency: usize,
                     n: usize, kb_parallels: &[usize]) {
    let f = fixture(seed, 6_000, n);
    let inner = Arc::new(DenseExact::new(f.ds.keys.clone()));
    let kb: Arc<dyn Retriever> = if shards > 1 {
        Arc::new(ShardedRetriever::new(inner, shards))
    } else {
        inner
    };
    for k in [4usize, 32] {
        for stride in stride_policies() {
            let o = opts(k, stride.clone());
            // Sequential reference: each request alone (itself
            // output-equivalence-pinned against the per-token baseline in
            // tests/knnlm_integration.rs).
            let expected: Vec<Vec<u32>> = f
                .prompts
                .iter()
                .map(|p| {
                    KnnLmSpec { lm: &f.lm, kb: kb.as_ref(), ds: &f.ds,
                                opts: o.clone() }
                        .run(p)
                        .unwrap()
                        .tokens_out
                })
                .collect();
            for &kb_parallel in kb_parallels {
                let engine_opts = EngineOptions {
                    max_batch: 64,
                    flush_us: 200,
                    max_inflight: concurrency,
                    kb_parallel,
                    ..EngineOptions::default()
                };
                let (got, stats) = run_knn_engine_cell(
                    &f.lm, &kb, &f.ds, &o, &f.prompts, engine_opts)
                    .unwrap();
                assert_eq!(got.len(), n);
                for (i, (g, e)) in got.iter().zip(&expected).enumerate() {
                    assert_eq!(
                        g.tokens_out, *e,
                        "KNN ENGINE OUTPUT DIVERGED: seed={seed} k={k} \
                         stride={stride:?} shards={shards} \
                         conc={concurrency} kb_parallel={kb_parallel} \
                         req={i}");
                    assert!(!g.tokens_out.is_empty(),
                            "request {i} produced no tokens");
                }
                if concurrency >= 8 && n >= 8 {
                    assert!(stats.mean_coalesced() > 1.0,
                            "concurrency {concurrency} kb_parallel \
                             {kb_parallel} never coalesced \
                             (mean batch {:.2})", stats.mean_coalesced());
                }
            }
        }
    }
}

#[test]
fn knn_engine_matches_sequential_conc_1() {
    check_equivalence(1, 1, 1, 6, &[0, 2]);
}

#[test]
fn knn_engine_matches_sequential_conc_8() {
    // The full ADR-005 sweep: synchronous inline plus async in-flight
    // caps 1, 2, 4 — bit-identical across all of them (overlap-drive
    // steps are verified like any other stride, so the async schedule
    // cannot leak into the tokens).
    check_equivalence(2, 1, 8, 10, &[0, 1, 2, 4]);
}

#[test]
fn knn_engine_matches_sequential_conc_32() {
    check_equivalence(3, 1, 32, 32, &[0, 4]);
}

#[test]
fn knn_engine_matches_sequential_sharded() {
    // Coalescing composes with the scatter-gather sharded datastore
    // retriever: each coalesced batch fans out over key-range shards and
    // k-way-merges back, still bit-identical per request.
    check_equivalence(4, 2, 8, 8, &[0, 2]);
}

#[test]
fn knn_engine_smoke_32_concurrent() {
    // CI hang detector: 32 concurrent KNN-LM requests through the
    // scheduler/flush/async-completion path must all complete, and their
    // per-token verification pressure must actually coalesce across
    // requests (EngineStats cross-request batches > 0 — the acceptance
    // criterion).
    let f = fixture(0x5E42, 8_000, 32);
    let kb: Arc<dyn Retriever> =
        Arc::new(DenseExact::new(f.ds.keys.clone()));
    let o = opts(8, StridePolicy::Fixed(3));
    let engine_opts = EngineOptions { max_batch: 64, flush_us: 200,
                                      max_inflight: 32, kb_parallel: 4,
                                      ..EngineOptions::default() };
    let (ms, stats) = run_knn_engine_cell(&f.lm, &kb, &f.ds, &o,
                                          &f.prompts, engine_opts)
        .unwrap();
    assert_eq!(ms.len(), 32);
    for (i, m) in ms.iter().enumerate() {
        assert!(!m.tokens_out.is_empty(), "request {i} produced no tokens");
        assert!(m.total.as_nanos() > 0);
        assert!(m.cache_lookups > 0,
                "request {i} never consulted the speculation cache");
    }
    assert!(stats.kb_calls > 0);
    assert!(stats.mean_coalesced() > 1.0,
            "32 concurrent KNN-LM requests should coalesce (mean {:.2})",
            stats.mean_coalesced());
    assert!(stats.coalesced_queries as usize
                >= ms.iter().map(|m| m.kb_queries as usize).sum::<usize>(),
            "every task query must be answered through the engine");
}

#[test]
fn router_round_trips_knn_requests() {
    // Method::Knn through a KnnEngineBackend inside a router worker:
    // responses must match the sequential reference and arrive for every
    // request (worker drains + engine coalesces inside serve_batch, with
    // async KB execution enabled).
    let f = fixture(9, 6_000, 12);
    let kb: Arc<dyn Retriever> =
        Arc::new(DenseExact::new(f.ds.keys.clone()));
    let o = opts(8, StridePolicy::Fixed(3));
    let expected: Vec<Vec<u32>> = f
        .prompts
        .iter()
        .map(|p| {
            KnnLmSpec { lm: &f.lm, kb: kb.as_ref(), ds: &f.ds,
                        opts: o.clone() }
                .run(p)
                .unwrap()
                .tokens_out
        })
        .collect();

    let ds = f.ds.clone();
    let kb2 = kb.clone();
    let o2 = o.clone();
    // Same MockLm construction as the fixture (vocab is seed-independent),
    // rebuilt inside the factory because worker backends own their LM.
    let vocab = CorpusConfig::default().vocab;
    let router = Router::spawn(32, 1, move || {
        Ok(KnnEngineBackend {
            lm: MockLm::new(vocab, 320, 9 ^ 0x11),
            kb: kb2.clone(),
            ds: ds.clone(),
            opts: o2.clone(),
            engine_opts: EngineOptions { max_batch: 64, flush_us: 200,
                                         max_inflight: 0, kb_parallel: 2,
                                         ..EngineOptions::default() },
        })
    });
    let rxs: Vec<_> = f
        .prompts
        .iter()
        .enumerate()
        .map(|(i, p)| {
            router
                .submit(Request { id: i as u64, question: p.clone(),
                                  method: Method::Knn,
                                  ..Request::default() })
                .unwrap()
        })
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.id, i as u64);
        assert_eq!(resp.tokens, expected[i],
                   "router-served KNN request {i} diverged");
    }
    router.shutdown();
}

/// A datastore retriever whose first `retrieve_batch` call panics; later
/// calls delegate (see the engine-level twin in
/// tests/engine_equivalence.rs).
struct PanicOnce {
    inner: Arc<dyn Retriever>,
    fired: AtomicBool,
}

impl Retriever for PanicOnce {
    fn retrieve_batch(&self, qs: &[SpecQuery], k: usize) -> Vec<Vec<Scored>> {
        if !self.fired.swap(true, Ordering::SeqCst) {
            panic!("poisoned datastore call");
        }
        self.inner.retrieve_batch(qs, k)
    }

    fn score_doc(&self, q: &SpecQuery, doc: u32) -> f32 {
        self.inner.score_doc(q, doc)
    }

    fn len(&self) -> usize {
        self.inner.len()
    }

    fn name(&self) -> &'static str {
        "panic-once"
    }
}

#[test]
fn router_surfaces_panicking_kb_as_error_responses() {
    // Regression (ADR-005 satellite): a panicking KB job inside the
    // engine must come back as error `Response`s on exactly the owning
    // requests — the worker stays alive, the other requests of the same
    // drain complete, and a second wave over the now-healthy KB succeeds.
    let f = fixture(0xFA11, 6_000, 8);
    let inner: Arc<dyn Retriever> =
        Arc::new(DenseExact::new(f.ds.keys.clone()));
    let kb: Arc<dyn Retriever> = Arc::new(PanicOnce {
        inner,
        fired: AtomicBool::new(false),
    });
    let o = opts(8, StridePolicy::Fixed(3));
    let ds = f.ds.clone();
    let kb2 = kb.clone();
    let o2 = o.clone();
    let vocab = CorpusConfig::default().vocab;
    let router = Router::spawn(32, 1, move || {
        Ok(KnnEngineBackend {
            lm: MockLm::new(vocab, 320, 0xFA11 ^ 0x11),
            kb: kb2.clone(),
            ds: ds.clone(),
            opts: o2.clone(),
            // max_inflight 2: only the first admitted pair rides the
            // poisoned first flush; the rest must survive.
            engine_opts: EngineOptions { max_batch: 64, flush_us: 200,
                                         max_inflight: 2, kb_parallel: 2,
                                         ..EngineOptions::default() },
        })
    });
    let rxs: Vec<_> = f
        .prompts
        .iter()
        .enumerate()
        .map(|(i, p)| {
            router
                .submit(Request { id: i as u64, question: p.clone(),
                                  method: Method::Knn,
                                  ..Request::default() })
                .unwrap()
        })
        .collect();
    let mut errors = 0;
    let mut oks = 0;
    for rx in rxs {
        match rx.recv().unwrap() {
            Ok(resp) => {
                assert!(!resp.tokens.is_empty());
                oks += 1;
            }
            Err(e) => {
                assert!(format!("{e}").contains("poisoned datastore call"),
                        "error must carry the panic payload: {e}");
                errors += 1;
            }
        }
    }
    assert!(errors > 0, "the poisoned call must fail its requests");
    assert!(oks > 0,
            "the engine must keep serving requests that were not in the \
             poisoned call");
    assert_eq!(errors + oks, 8);

    // The worker survived: a fresh request now succeeds end to end.
    let rx = router
        .submit(Request { id: 99, question: f.prompts[0].clone(),
                          method: Method::Knn,
                          ..Request::default() })
        .unwrap();
    let resp = rx.recv().unwrap().unwrap();
    assert_eq!(resp.id, 99);
    assert!(!resp.tokens.is_empty());
    router.shutdown();
}
