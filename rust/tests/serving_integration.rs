//! Serving-layer integration: the router drives real QA pipelines (mock LM
//! backend — no artifacts needed) across multiple worker threads, with
//! per-request method selection and backpressure.

use ralmspec::config::{Config, CorpusConfig, RetrieverKind};
use ralmspec::datagen::{generate_questions, Dataset, HashEncoder};
use ralmspec::eval::{run_qa_cell, QaMethod, TestBed};
use ralmspec::lm::MockLm;
use ralmspec::metrics::ReqMetrics;
use ralmspec::serving::{EngineBackend, EngineOptions, Method, Request,
                        Response, Router, ServeBackend};
use std::sync::Arc;

/// A QA backend over shared (Sync) fixtures; each worker builds its own
/// MockLm (stand-in for a per-worker PJRT engine).
struct QaBackend {
    cfg: Config,
    bed: Arc<BedBundle>,
    lm: MockLm,
    enc: HashEncoder,
}

/// TestBed isn't Sync (lazy RefCell retrievers), so workers share the
/// prebuilt pieces and each owns a TestBed-equivalent view.
struct BedBundle {
    cfg: Config,
    corpus_seed: u64,
}

impl ServeBackend for QaBackend {
    fn serve(&mut self, req: &Request) -> anyhow::Result<ReqMetrics> {
        // Rebuild is cheap at test scale; in the PJRT deployment the
        // worker keeps its TestBed across requests.
        let bed = TestBed::build(&self.cfg, &self.enc);
        let method = match req.method {
            ralmspec::serving::router::Method::Baseline => QaMethod::Baseline,
            ralmspec::serving::router::Method::Spec { prefetch, os3,
                                                      async_verify } => {
                QaMethod::Spec {
                    prefetch: if prefetch { 20 } else { 1 },
                    os3,
                    async_verify,
                    stride: 3,
                }
            }
            ralmspec::serving::router::Method::Knn => {
                anyhow::bail!("QA test backend does not serve KNN-LM")
            }
            ralmspec::serving::router::Method::Ingest => {
                anyhow::bail!("QA test backend serves a frozen corpus")
            }
        };
        let q = ralmspec::datagen::Question {
            id: req.id,
            dataset: Dataset::WikiQa,
            topic: 0,
            tokens: req.question.clone(),
        };
        let _ = &self.bed;
        let mut ms = run_qa_cell(&self.lm, &self.enc, &bed,
                                 RetrieverKind::Edr,
                                 std::slice::from_ref(&q), method,
                                 &self.cfg)?;
        Ok(ms.pop().unwrap())
    }
}

fn test_cfg() -> Config {
    let mut cfg = Config::default();
    cfg.corpus = CorpusConfig {
        n_docs: 400,
        n_topics: 8,
        doc_len: (24, 60),
        seed: 404,
        ..CorpusConfig::default()
    };
    cfg.spec.max_new_tokens = 16;
    cfg
}

#[test]
fn router_serves_qa_requests_end_to_end() {
    let cfg = test_cfg();
    let bundle = Arc::new(BedBundle { cfg: cfg.clone(), corpus_seed: 404 });
    let cfg2 = cfg.clone();
    let router = Router::spawn(32, 2, move || {
        Ok(QaBackend {
            cfg: cfg2.clone(),
            bed: bundle.clone(),
            lm: MockLm::new(cfg2.corpus.vocab, 320, 1),
            enc: HashEncoder::new(ralmspec::runtime::RETRIEVAL_DIM,
                                  404 ^ 0xEC),
        })
    });
    // Build questions once outside.
    let bed = TestBed::build(&cfg, &HashEncoder::new(
        ralmspec::runtime::RETRIEVAL_DIM, 404 ^ 0xEC));
    let questions = generate_questions(Dataset::WikiQa, &bed.corpus, 6, 9);
    let mut responses: Vec<Response> = Vec::new();
    let pending: Vec<_> = questions
        .iter()
        .enumerate()
        .map(|(i, q)| {
            router
                .submit(Request {
                    id: i as u64,
                    question: q.tokens.clone(),
                    method: if i % 2 == 0 {
                        ralmspec::serving::router::Method::Baseline
                    } else {
                        ralmspec::serving::router::Method::Spec {
                            prefetch: true,
                            os3: true,
                            async_verify: false,
                        }
                    },
                    ..Request::default()
                })
                .unwrap()
        })
        .collect();
    for rx in pending {
        responses.push(rx.recv().unwrap().unwrap());
    }
    assert_eq!(responses.len(), 6);
    for r in &responses {
        assert!(!r.tokens.is_empty(), "request {} produced no tokens", r.id);
        assert!(r.metrics.total.as_nanos() > 0);
    }
    // Same question served as baseline (id 0) and spec (id 1 uses a
    // different question) — check determinism instead: resubmit id 0.
    let again = router
        .submit_blocking(Request {
            id: 100,
            question: questions[0].tokens.clone(),
            method: ralmspec::serving::router::Method::Baseline,
            ..Request::default()
        })
        .unwrap();
    assert_eq!(again.tokens, responses[0].tokens,
               "same request must be deterministic");
    router.shutdown();
}

#[test]
fn engine_backend_serves_spec_requests_through_router() {
    // Method::Spec requests flow through the coalescing ServeEngine inside
    // a router worker (EngineBackend); Method::Baseline runs inline. Both
    // must produce the same tokens for the same question, and a pipelined
    // burst must come back complete (the worker drains it as one batch).
    let cfg = test_cfg();
    let bed = TestBed::build(&cfg, &HashEncoder::new(
        ralmspec::runtime::RETRIEVAL_DIM, 404 ^ 0xEC));
    let kb = bed.retriever(RetrieverKind::Edr);
    let corpus = bed.corpus.clone();
    let cfg2 = cfg.clone();
    let router = Router::spawn(64, 1, move || {
        Ok(EngineBackend {
            lm: MockLm::new(cfg2.corpus.vocab, 320, 1),
            kb: kb.clone(),
            corpus: corpus.clone(),
            encoder: Box::new(HashEncoder::new(
                ralmspec::runtime::RETRIEVAL_DIM, 404 ^ 0xEC)),
            mode: ralmspec::spec::QueryMode::Dense,
            cfg: cfg2.clone(),
            engine_opts: EngineOptions {
                max_batch: 16,
                flush_us: 500,
                max_inflight: 0,
                kb_parallel: 2,
                ..EngineOptions::default()
            },
            live: None,
            tenant_kbs: Vec::new(),
        })
    });
    let questions = generate_questions(Dataset::WikiQa, &bed.corpus, 4, 9);
    for (i, q) in questions.iter().enumerate() {
        let base = router.submit_blocking(Request {
            id: i as u64 * 2,
            question: q.tokens.clone(),
            method: Method::Baseline,
            ..Request::default()
        }).unwrap();
        let spec = router.submit_blocking(Request {
            id: i as u64 * 2 + 1,
            question: q.tokens.clone(),
            method: Method::Spec {
                prefetch: true, os3: false, async_verify: false,
            },
            ..Request::default()
        }).unwrap();
        assert_eq!(base.tokens, spec.tokens,
                   "engine-served spec diverged on question {i}");
    }
    // Pipelined burst: all submitted before any response is collected, so
    // the single worker drains them into one engine batch.
    let pending: Vec<_> = questions
        .iter()
        .enumerate()
        .map(|(i, q)| {
            router.submit(Request {
                id: 100 + i as u64,
                question: q.tokens.clone(),
                method: Method::Spec {
                    prefetch: false, os3: true, async_verify: true,
                },
                ..Request::default()
            }).unwrap()
        })
        .collect();
    for (i, rx) in pending.into_iter().enumerate() {
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.id, 100 + i as u64);
        assert!(!resp.tokens.is_empty(), "burst request {i} returned empty");
    }
    router.shutdown();
}

#[test]
fn spec_and_baseline_agree_through_router() {
    let cfg = test_cfg();
    let cfg2 = cfg.clone();
    let router = Router::spawn(8, 1, move || {
        Ok(QaBackend {
            cfg: cfg2.clone(),
            bed: Arc::new(BedBundle { cfg: cfg2.clone(), corpus_seed: 404 }),
            lm: MockLm::new(cfg2.corpus.vocab, 320, 1),
            enc: HashEncoder::new(ralmspec::runtime::RETRIEVAL_DIM,
                                  404 ^ 0xEC),
        })
    });
    let bed = TestBed::build(&cfg, &HashEncoder::new(
        ralmspec::runtime::RETRIEVAL_DIM, 404 ^ 0xEC));
    let questions = generate_questions(Dataset::WebQ, &bed.corpus, 3, 11);
    for (i, q) in questions.iter().enumerate() {
        let base = router.submit_blocking(Request {
            id: i as u64 * 2,
            question: q.tokens.clone(),
            method: ralmspec::serving::router::Method::Baseline,
            ..Request::default()
        }).unwrap();
        let spec = router.submit_blocking(Request {
            id: i as u64 * 2 + 1,
            question: q.tokens.clone(),
            method: ralmspec::serving::router::Method::Spec {
                prefetch: true, os3: false, async_verify: true,
            },
            ..Request::default()
        }).unwrap();
        assert_eq!(base.tokens, spec.tokens, "question {i}");
        assert!(spec.metrics.kb_calls <= base.metrics.kb_calls);
    }
    router.shutdown();
}
