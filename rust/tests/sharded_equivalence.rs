//! THE correctness property of the sharded retrieval engine: for every
//! retriever class (EDR / ADR / SR) and any shard count, the
//! scatter-gather `ShardedRetriever` must return **bit-identical** top-k —
//! ids AND scores, tie-break included — to the unsharded backend, over
//! random corpora, batch sizes, and k.
//!
//! Property-style: inputs are drawn from a seeded RNG (the in-tree
//! substitute for proptest on the offline image), so failures reproduce.

use ralmspec::cache::LocalCache;
use ralmspec::config::{Config, CorpusConfig, RetrieverKind};
use ralmspec::datagen::{Encoder, HashEncoder};
use ralmspec::eval::TestBed;
use ralmspec::retriever::{Retriever, SpecQuery};
use ralmspec::util::Rng;

const SHARD_COUNTS: [usize; 4] = [1, 2, 3, 7];

fn bed(seed: u64, n_docs: usize) -> (TestBed, HashEncoder) {
    let mut cfg = Config::default();
    cfg.corpus = CorpusConfig {
        n_docs,
        n_topics: 16,
        doc_len: (20, 72),
        seed,
        ..CorpusConfig::default()
    };
    cfg.retriever.hnsw_ef_construction = 48;
    cfg.retriever.hnsw_ef_search = 40;
    let enc = HashEncoder::new(ralmspec::runtime::RETRIEVAL_DIM, seed ^ 0xEC);
    let b = TestBed::build(&cfg, &enc);
    (b, enc)
}

fn queries(bed: &TestBed, enc: &HashEncoder, n: usize, seed: u64)
           -> Vec<(SpecQuery, SpecQuery)> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            let topic = (i % bed.corpus.n_topics) as u32;
            let toks = bed.corpus.topic_tokens(topic, 12, &mut rng);
            (SpecQuery::dense_only(enc.encode(&toks)),
             SpecQuery::sparse_only(toks))
        })
        .collect()
}

/// (id, score-bits) projection: equality here is bit-identity.
fn bits(rows: &[Vec<ralmspec::util::Scored>]) -> Vec<Vec<(u32, u32)>> {
    rows.iter()
        .map(|r| r.iter().map(|s| (s.id, s.score.to_bits())).collect())
        .collect()
}

fn check_kind(bed: &TestBed, enc: &HashEncoder, kind: RetrieverKind,
              seed: u64) {
    let unsharded = bed.unsharded(kind);
    let qs = queries(bed, enc, 11, seed);
    let batch: Vec<SpecQuery> = qs
        .iter()
        .map(|(d, s)| match kind {
            RetrieverKind::Sr => s.clone(),
            _ => d.clone(),
        })
        .collect();
    for k in [1usize, 5, 16] {
        let want = bits(&unsharded.retrieve_batch(&batch, k));
        for &n in &SHARD_COUNTS {
            let sharded = bed.sharded(kind, n);
            // Full batch through the scatter-gather path.
            let got = bits(&sharded.retrieve_batch(&batch, k));
            assert_eq!(got, want,
                       "kind={kind:?} shards={n} k={k} batch: diverged");
            // Derived single-query path must agree too.
            let alone =
                bits(&[sharded.retrieve_topk(&batch[seed as usize % 11], k)]);
            assert_eq!(alone[0], want[seed as usize % 11],
                       "kind={kind:?} shards={n} k={k} single: diverged");
        }
    }
}

#[test]
fn sharded_equivalence_edr() {
    let (bed, enc) = bed(1, 900);
    check_kind(&bed, &enc, RetrieverKind::Edr, 2);
}

#[test]
fn sharded_equivalence_adr() {
    let (bed, enc) = bed(3, 900);
    check_kind(&bed, &enc, RetrieverKind::Adr, 4);
}

#[test]
fn sharded_equivalence_sr() {
    let (bed, enc) = bed(5, 900);
    check_kind(&bed, &enc, RetrieverKind::Sr, 6);
}

/// Property sweep: random (corpus seed, kind, query seed) combinations, all
/// shard counts, ids and score bits compared on every one.
#[test]
fn sharded_equivalence_randomized_sweep() {
    let mut rng = Rng::new(0x5AA5_D0D0);
    for trial in 0..6 {
        let seed = 100 + rng.next_u64() % 10_000;
        let kind = RetrieverKind::all()[rng.gen_range(3)];
        let n_docs = 300 + rng.gen_range(900);
        eprintln!("trial {trial}: seed={seed} kind={kind:?} docs={n_docs}");
        let (bed, enc) = bed(seed, n_docs);
        check_kind(&bed, &enc, kind, seed ^ 0x77);
    }
}

/// Rank preservation (§3) composes through sharding: a cache ranking with
/// a sharded KB's `score_docs` returns exactly the KB top-1 whenever it is
/// cached — for all three retriever classes.
#[test]
fn rank_preservation_through_sharded_kb() {
    let (bed, enc) = bed(9, 700);
    let qs = queries(&bed, &enc, 12, 10);
    let mut rng = Rng::new(11);
    for kind in RetrieverKind::all() {
        let kb = bed.sharded(kind, 3);
        for (dense_q, sparse_q) in &qs {
            let q = match kind {
                RetrieverKind::Sr => sparse_q,
                _ => dense_q,
            };
            let truth = kb.retrieve_topk(q, 6);
            if truth.is_empty() {
                continue;
            }
            let mut cache = LocalCache::new(128);
            cache.insert(&truth);
            let distract: Vec<u32> =
                (0..12).map(|_| rng.gen_range(bed.corpus.len()) as u32)
                       .collect();
            cache.insert_ids(&distract);
            let got = cache.retrieve(q, kb.as_ref()).unwrap();
            assert_eq!(got.id, truth[0].id, "kind={kind:?}");
        }
    }
}

/// Shard counts beyond the corpus size must clamp, not crash, and still be
/// bit-identical.
#[test]
fn degenerate_shard_counts() {
    let (bed, enc) = bed(13, 5);
    let qs = queries(&bed, &enc, 3, 14);
    for kind in [RetrieverKind::Edr, RetrieverKind::Sr] {
        let unsharded = bed.unsharded(kind);
        let sharded = bed.sharded(kind, 64);
        for (dense_q, sparse_q) in &qs {
            let q = match kind {
                RetrieverKind::Sr => sparse_q,
                _ => dense_q,
            };
            let want = bits(&[unsharded.retrieve_topk(q, 10)]);
            let got = bits(&[sharded.retrieve_topk(q, 10)]);
            assert_eq!(got, want, "kind={kind:?}");
        }
    }
}
