//! END-TO-END DRIVER (DESIGN.md "End-to-end validation"): load the real
//! (AOT-compiled) models, batch-serve a QA workload through the serving
//! router with both RaLMSeq and RaLMSpec+PSA, verify output equivalence on
//! every request, and report latency/throughput.
//!
//!     make artifacts && cargo run --release --example serve_qa
//!
//! Flags (positional): [model] [n_requests] [retriever]
//! e.g. `cargo run --release --example serve_qa -- opt1b 8 edr`

use ralmspec::config::{Config, CorpusConfig, RetrieverKind};
use ralmspec::datagen::{generate_questions, Dataset};
use ralmspec::eval::{run_qa_cell, QaMethod, TestBed};
use ralmspec::runtime::Engine;
use ralmspec::util::summarize;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().cloned().unwrap_or_else(|| "gpt2m".into());
    let n: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(8);
    let kind: RetrieverKind = args
        .get(2)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(RetrieverKind::Edr);

    let mut cfg = Config::default();
    cfg.corpus = CorpusConfig { n_docs: 40_000, n_topics: 256,
                                ..CorpusConfig::default() };
    cfg.spec.max_new_tokens = 48;

    let engine = Engine::new(&cfg.paths.artifacts)?;
    let enc = engine.encoder()?;
    let lm = engine.lm(&model)?;
    eprintln!("[serve_qa] corpus {} docs, retriever {}, model {model}, \
               {n} requests x {} tokens",
              cfg.corpus.n_docs, kind.label(), cfg.spec.max_new_tokens);
    let bed = TestBed::build(&cfg, &enc);
    let questions = generate_questions(Dataset::Nq, &bed.corpus, n, 42);

    let mut all_equal = true;
    for (label, method) in [("RaLMSeq   ", QaMethod::Baseline),
                            ("RaLMSpec+PSA", QaMethod::psa(20))] {
        let t0 = std::time::Instant::now();
        let ms = run_qa_cell(&lm, &enc, &bed, kind, &questions, method,
                             &cfg)?;
        let wall = t0.elapsed().as_secs_f64();
        let lats: Vec<f64> = ms.iter().map(|m| m.total.as_secs_f64()).collect();
        let s = summarize(&lats);
        let toks: usize = ms.iter().map(|m| m.tokens_out.len()).sum();
        let g: f64 = ms.iter().map(|m| m.generate.as_secs_f64()).sum::<f64>()
            / ms.len() as f64;
        let r: f64 = ms.iter().map(|m| m.retrieve.as_secs_f64()).sum::<f64>()
            / ms.len() as f64;
        println!("{label} wall={wall:>7.2}s  latency/req={:.3}±{:.3}s \
                  (G={g:.3} R={r:.3})  throughput={:.1} tok/s",
                 s.mean, s.std, toks as f64 / wall);
        if method != QaMethod::Baseline {
            // re-run the baseline per request lazily? compare with cached
        }
        if let QaMethod::Spec { .. } = method {
            let base = run_qa_cell(&lm, &enc, &bed, kind, &questions,
                                   QaMethod::Baseline, &cfg)?;
            for (b, sp) in base.iter().zip(&ms) {
                if b.tokens_out != sp.tokens_out {
                    all_equal = false;
                }
            }
        }
    }
    println!("output equivalence: {}",
             if all_equal { "OK (all requests identical)" } else { "FAILED" });
    anyhow::ensure!(all_equal, "speculation changed outputs");
    Ok(())
}
