//! Quickstart: load the AOT artifacts, serve one question with the
//! baseline and with RaLMSpec+PSA, and print the speed-up.
//!
//!     make artifacts && cargo run --release --example quickstart

use ralmspec::config::{Config, CorpusConfig, RetrieverKind};
use ralmspec::datagen::{generate_questions, Dataset};
use ralmspec::eval::{run_qa_cell, QaMethod, TestBed};
use ralmspec::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let mut cfg = Config::default();
    // Laptop-scale corpus so the quickstart finishes in seconds.
    cfg.corpus = CorpusConfig { n_docs: 20_000, n_topics: 128,
                                ..CorpusConfig::default() };
    cfg.spec.max_new_tokens = 32;

    let engine = Engine::new(&cfg.paths.artifacts)?;
    let enc = engine.encoder()?;
    let lm = engine.lm("gpt2m")?;
    eprintln!("building corpus + embeddings ({} docs)...", cfg.corpus.n_docs);
    let bed = TestBed::build(&cfg, &enc);
    let questions = generate_questions(Dataset::WikiQa, &bed.corpus, 3, 1);

    for kind in [RetrieverKind::Edr, RetrieverKind::Sr] {
        let base = run_qa_cell(&lm, &enc, &bed, kind, &questions,
                               QaMethod::Baseline, &cfg)?;
        let spec = run_qa_cell(&lm, &enc, &bed, kind, &questions,
                               QaMethod::psa(20), &cfg)?;
        let bt: f64 = base.iter().map(|m| m.total.as_secs_f64()).sum();
        let st: f64 = spec.iter().map(|m| m.total.as_secs_f64()).sum();
        println!("[{}] RaLMSeq {:.2}s  RaLMSpec+PSA {:.2}s  ({:.2}x)",
                 kind.label(), bt, st, bt / st);
        for (b, s) in base.iter().zip(&spec) {
            assert_eq!(b.tokens_out, s.tokens_out,
                       "outputs must be identical");
        }
        println!("      outputs identical: OK  \
                  (rollbacks={}, spec accuracy={:.2})",
                 spec.iter().map(|m| m.rollbacks).sum::<u32>(),
                 spec.iter().map(|m| m.spec_accuracy()).sum::<f64>()
                     / spec.len() as f64);
    }
    Ok(())
}
