//! Fig 1(c) / Fig 3 reproduction: per-request timeline of speculation,
//! verification, and correction phases for RaLMSeq vs RaLMSpec.
//!
//!     cargo run --release --example trace_timeline            # PJRT
//!     cargo run --release --example trace_timeline -- --mock  # no artifacts

use ralmspec::cli;

fn main() -> anyhow::Result<()> {
    let mut args: Vec<String> =
        vec!["trace".into(), "--retriever".into(), "edr".into()];
    args.extend(std::env::args().skip(1));
    cli::run(&args)
}
