//! KNN-LM serving demo (§5.3): build the datastore with the real
//! `hidden_knnlm` artifact, serve prompts with retrieval-per-token
//! baseline vs RaLMSpec (relaxed verification), sweep k.
//!
//!     make artifacts && cargo run --release --example knnlm_demo

use ralmspec::config::{Config, CorpusConfig};
use ralmspec::datagen::generate_stream;
use ralmspec::knnlm::{Datastore, KnnLmBaseline, KnnLmSpec, KnnServeOptions};
use ralmspec::retriever::dense::DenseExact;
use ralmspec::runtime::Engine;
use ralmspec::spec::{Os3Config, StridePolicy};

fn main() -> anyhow::Result<()> {
    let cfg = Config::default();
    let engine = Engine::new(&cfg.paths.artifacts)?;
    let lm = engine.lm("knnlm")?;
    let corpus_cfg = CorpusConfig { seed: 11, ..CorpusConfig::default() };
    let n_entries = 20_000;
    eprintln!("[knnlm] building {n_entries}-entry datastore via hidden_knnlm...");
    let stream = generate_stream(&corpus_cfg, n_entries + 600, 11);
    let extractor = ralmspec::runtime::HiddenExtractor::new(&engine, "knnlm")?;
    let ds = Datastore::build_pjrt(&stream, &extractor, n_entries)?;
    let kb = DenseExact::new(ds.keys.clone());
    let prompts: Vec<Vec<u32>> =
        (0..3).map(|i| stream.tokens[i * 500..i * 500 + 24].to_vec()).collect();

    for k in [16usize, 256] {
        let opts = KnnServeOptions { k, max_new: 32,
                                     ..KnnServeOptions::default() };
        let mut bt = 0.0;
        let mut st = 0.0;
        for p in &prompts {
            let base = KnnLmBaseline { lm: &lm, kb: &kb, ds: &ds,
                                       opts: opts.clone() }.run(p)?;
            let spec = KnnLmSpec {
                lm: &lm, kb: &kb, ds: &ds,
                opts: KnnServeOptions {
                    stride: StridePolicy::Os3(Os3Config::default()),
                    ..opts.clone()
                },
            }.run(p)?;
            anyhow::ensure!(base.tokens_out == spec.tokens_out,
                            "outputs diverged");
            bt += base.total.as_secs_f64();
            st += spec.total.as_secs_f64();
        }
        println!("k={k:<4} baseline {bt:.2}s  RaLMSpec(OS3) {st:.2}s  \
                  ({:.2}x, outputs identical)", bt / st);
    }
    Ok(())
}
