//! Latency decomposition and counters.
//!
//! The paper reports end-to-end latency split into language-model
//! generation (G) and retrieval (R) — Fig 4's stacked bars. We track those
//! plus the speculation-specific components: cache-lookup time (C),
//! verification wait (V), rollback counts, speculation accuracy, and the
//! stride trajectory chosen by OS³.

use std::time::{Duration, Instant};

/// One timeline event for Fig-1(c)/Fig-3-style traces.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    Prefill,
    SpecStep,
    Verify,
    Rollback,
    Correct,
}

impl EventKind {
    pub fn label(&self) -> &'static str {
        match self {
            EventKind::Prefill => "prefill",
            EventKind::SpecStep => "spec_step",
            EventKind::Verify => "verify",
            EventKind::Rollback => "rollback",
            EventKind::Correct => "correct",
        }
    }
}

#[derive(Debug, Clone, Copy)]
pub struct TraceEvent {
    pub kind: EventKind,
    /// Offset from request start.
    pub start: Duration,
    pub dur: Duration,
}

#[derive(Debug, Clone, Default)]
pub struct ReqMetrics {
    /// Wall-clock total for the request.
    pub total: Duration,
    /// LM generation time (prefill + decode via PJRT / mock) — "G".
    pub generate: Duration,
    /// Knowledge-base retrieval time (incl. batched verification) — "R".
    pub retrieve: Duration,
    /// Query-construction time (dense-encoder / term-window work) — "E".
    /// Kept separate from `retrieve`: the encoder runs on the LM side,
    /// and folding it into R inflated the Fig-4 R bar for the
    /// speculative path (which builds one query per speculation step).
    pub encode: Duration,
    /// Local speculation-cache lookup time — part of the speculation step.
    pub cache: Duration,
    /// Time spent blocked on an in-flight async verification.
    pub verify_wait: Duration,
    /// Time this request's verification queries sat in the serving
    /// engine's coalescing buffer before their KB call started (zero
    /// outside the engine).
    pub queue_wait: Duration,

    pub prefills: u32,
    pub decode_tokens: u32,
    /// KB calls (a batched verification of stride s counts once) and total
    /// queries inside them (counts s).
    pub kb_calls: u32,
    pub kb_queries: u32,
    /// Speculation-cache lookups performed (KNN-LM: one per speculated
    /// token) and how many of them the cache could have answered truly —
    /// the verified query's true top-1 was already cached at verification
    /// time. Hit rate is the cache-quality signal *behind* speculation
    /// accuracy (a step can decode the right token from imperfect
    /// neighbours and vice versa).
    pub cache_lookups: u32,
    pub cache_hits: u32,
    pub rollbacks: u32,
    /// Speculation steps taken / verified correct.
    pub spec_steps: u32,
    pub spec_correct: u32,
    /// Subset of `spec_steps` taken while a verification was in flight
    /// (the async overlap drive) — the per-request overlap-utilization
    /// counter: these are the steps whose latency the KB call hid.
    pub overlap_steps: u32,
    /// Tokens discarded by rollbacks (speculation overhead).
    pub wasted_tokens: u32,
    /// Knowledge-base epoch this request was pinned to at admission
    /// (0 for a frozen KB — see DESIGN.md ADR-006). Aggregation keeps
    /// the newest epoch seen (`add` takes the max), so a cell summary
    /// reports how far the live KB had advanced.
    pub epoch: u64,
    /// Stride used at each verification step (OS³ trajectory).
    pub strides: Vec<u32>,
    /// Generated output (for equivalence checks).
    pub tokens_out: Vec<u32>,
    /// Coarse per-phase timeline (Fig 1c / Fig 3 traces).
    pub events: Vec<TraceEvent>,
}

impl ReqMetrics {
    /// Record a timeline event given the request-start stopwatch.
    pub fn event(&mut self, kind: EventKind, req_start: &Stopwatch,
                 dur: Duration) {
        let end = req_start.elapsed();
        self.events.push(TraceEvent {
            kind,
            start: end.saturating_sub(dur),
            dur,
        });
    }
}

impl ReqMetrics {
    pub fn spec_accuracy(&self) -> f64 {
        if self.spec_steps == 0 {
            return 0.0;
        }
        self.spec_correct as f64 / self.spec_steps as f64
    }

    /// Fraction of cache lookups whose true nearest neighbour was already
    /// cached (see [`Self::cache_hits`]); 0.0 when no lookups happened.
    pub fn cache_hit_rate(&self) -> f64 {
        if self.cache_lookups == 0 {
            return 0.0;
        }
        self.cache_hits as f64 / self.cache_lookups as f64
    }

    /// Merge (for aggregate reporting). Counters and component times sum;
    /// `strides` concatenates, so an aggregated stride trajectory covers
    /// every merged request instead of silently dropping all but the
    /// first operand's. `events` (offsets are relative to each request's
    /// own start) and `tokens_out` (per-request output, compared
    /// request-by-request in the equivalence suites) are intentionally
    /// per-request and are left untouched by `add`.
    pub fn add(&mut self, other: &ReqMetrics) {
        self.total += other.total;
        self.generate += other.generate;
        self.retrieve += other.retrieve;
        self.encode += other.encode;
        self.cache += other.cache;
        self.verify_wait += other.verify_wait;
        self.queue_wait += other.queue_wait;
        self.prefills += other.prefills;
        self.decode_tokens += other.decode_tokens;
        self.kb_calls += other.kb_calls;
        self.kb_queries += other.kb_queries;
        self.cache_lookups += other.cache_lookups;
        self.cache_hits += other.cache_hits;
        self.rollbacks += other.rollbacks;
        self.spec_steps += other.spec_steps;
        self.spec_correct += other.spec_correct;
        self.overlap_steps += other.overlap_steps;
        self.wasted_tokens += other.wasted_tokens;
        self.epoch = self.epoch.max(other.epoch);
        self.strides.extend_from_slice(&other.strides);
    }
}

/// Scoped timer: `let _t = Stopwatch::start(); ... t.elapsed()`.
#[derive(Debug, Clone, Copy)]
pub struct Stopwatch(Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Self(Instant::now())
    }

    pub fn elapsed(&self) -> Duration {
        self.0.elapsed()
    }
}

/// Time a closure, accumulating into `slot`.
#[inline]
pub fn timed<T>(slot: &mut Duration, f: impl FnOnce() -> T) -> T {
    let t = Instant::now();
    let out = f();
    *slot += t.elapsed();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timed_accumulates() {
        let mut slot = Duration::ZERO;
        let x = timed(&mut slot, || {
            std::thread::sleep(Duration::from_millis(5));
            42
        });
        assert_eq!(x, 42);
        assert!(slot >= Duration::from_millis(4));
        timed(&mut slot, || ());
        assert!(slot >= Duration::from_millis(4));
    }

    #[test]
    fn spec_accuracy_edges() {
        let mut m = ReqMetrics::default();
        assert_eq!(m.spec_accuracy(), 0.0);
        m.spec_steps = 4;
        m.spec_correct = 3;
        assert!((m.spec_accuracy() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn cache_hit_rate_edges_and_merge() {
        let mut m = ReqMetrics::default();
        assert_eq!(m.cache_hit_rate(), 0.0);
        m.cache_lookups = 8;
        m.cache_hits = 6;
        assert!((m.cache_hit_rate() - 0.75).abs() < 1e-12);
        let other = ReqMetrics { cache_lookups: 2, cache_hits: 0,
                                 ..Default::default() };
        m.add(&other);
        assert_eq!(m.cache_lookups, 10);
        assert!((m.cache_hit_rate() - 0.6).abs() < 1e-12);
    }

    #[test]
    fn add_merges_counters() {
        let mut a = ReqMetrics { prefills: 1, decode_tokens: 10,
                                 ..Default::default() };
        let b = ReqMetrics { prefills: 2, decode_tokens: 5, rollbacks: 1,
                             ..Default::default() };
        a.add(&b);
        assert_eq!(a.prefills, 3);
        assert_eq!(a.decode_tokens, 15);
        assert_eq!(a.rollbacks, 1);
    }

    #[test]
    fn add_appends_strides_and_sums_new_components() {
        let mut a = ReqMetrics {
            strides: vec![1, 2],
            encode: Duration::from_millis(3),
            queue_wait: Duration::from_millis(5),
            tokens_out: vec![10, 11],
            ..Default::default()
        };
        let b = ReqMetrics {
            strides: vec![3, 4, 5],
            encode: Duration::from_millis(4),
            queue_wait: Duration::from_millis(1),
            tokens_out: vec![99],
            overlap_steps: 2,
            ..Default::default()
        };
        a.add(&b);
        assert_eq!(a.overlap_steps, 2);
        // The stride trajectory must cover every merged request (table5's
        // summaries previously only reflected the last request).
        assert_eq!(a.strides, vec![1, 2, 3, 4, 5]);
        assert_eq!(a.encode, Duration::from_millis(7));
        assert_eq!(a.queue_wait, Duration::from_millis(6));
        // tokens_out stays per-request (see `add` docs).
        assert_eq!(a.tokens_out, vec![10, 11]);
    }
}
