//! One compiled AOT artifact: HLO text -> PJRT executable + persistent
//! weight buffers.
//!
//! Weights are uploaded to the device exactly once per weights blob and
//! shared (Rc) across the artifacts of one model (prefill / decode /
//! decode_chunk all reference `<model>.weights.bin`). Per-call arguments
//! are uploaded fresh; the KV cache travels as a `Literal`
//! (PJRT returns multi-output programs as a single tuple buffer, so state
//! must round-trip through the host — see DESIGN.md §Perf).

use super::manifest::Manifest;
use std::path::Path;
use std::rc::Rc;

/// Per-call argument (non-weight input), in manifest order.
pub enum ArgValue<'a> {
    I32(i32),
    VecI32(&'a [i32], &'a [usize]),
    VecF32(&'a [f32], &'a [usize]),
    /// Pre-existing literal (the KV cache from a previous call).
    Lit(&'a xla::Literal),
}

pub struct Artifact {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
    weights: Rc<Vec<xla::PjRtBuffer>>,
}

fn exla<E: std::fmt::Debug>(ctx: &str, e: E) -> anyhow::Error {
    anyhow::anyhow!("{ctx}: {e:?}")
}

/// Load a weights blob and upload one buffer per weight entry.
pub fn upload_weights(client: &xla::PjRtClient, dir: &Path,
                      manifest: &Manifest)
                      -> anyhow::Result<Vec<xla::PjRtBuffer>> {
    let Some(bin) = &manifest.weights_bin else {
        return Ok(Vec::new());
    };
    let blob = std::fs::read(dir.join(bin))
        .map_err(|e| anyhow::anyhow!("reading {bin}: {e}"))?;
    let mut out = Vec::new();
    for entry in manifest.inputs.iter().filter(|e| e.is_weight()) {
        let off = entry.offset.unwrap();
        let n = entry.nbytes.unwrap();
        anyhow::ensure!(off + n <= blob.len(), "weights blob too small for {}",
                      entry.name);
        let floats: Vec<f32> = blob[off..off + n]
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        let buf = client
            .buffer_from_host_buffer(&floats, &entry.shape, None)
            .map_err(|e| exla(&format!("uploading weight {}", entry.name), e))?;
        out.push(buf);
    }
    Ok(out)
}

impl Artifact {
    /// Compile `<name>.hlo.txt` and bind the shared weight buffers.
    pub fn load(client: &xla::PjRtClient, dir: &Path, name: &str,
                weights: Rc<Vec<xla::PjRtBuffer>>) -> anyhow::Result<Self> {
        let manifest = Manifest::load(&dir.join(format!("{name}.manifest.json")))?;
        anyhow::ensure!(manifest.n_weights() == weights.len(),
                      "{name}: weight count mismatch ({} vs {})",
                      manifest.n_weights(), weights.len());
        let hlo_path = dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path.to_str().unwrap())
            .map_err(|e| exla(&format!("parsing {}", hlo_path.display()), e))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| exla(&format!("compiling {name}"), e))?;
        Ok(Self { manifest, client: client.clone(), exe, weights })
    }

    /// Execute with per-call args in manifest (non-weight) order. Returns
    /// the decomposed output tuple as host literals, in manifest order.
    pub fn execute(&self, args: &[ArgValue]) -> anyhow::Result<Vec<xla::Literal>> {
        let call_inputs: Vec<_> = self.manifest.call_inputs().collect();
        anyhow::ensure!(args.len() == call_inputs.len(),
                      "{}: expected {} args, got {}", self.manifest.artifact,
                      call_inputs.len(), args.len());
        let mut uploaded: Vec<xla::PjRtBuffer> =
            Vec::with_capacity(args.len());
        for (arg, spec) in args.iter().zip(&call_inputs) {
            let buf = match arg {
                ArgValue::I32(v) => self
                    .client
                    .buffer_from_host_buffer(&[*v], &[], None),
                ArgValue::VecI32(v, dims) => {
                    self.client.buffer_from_host_buffer(v, dims, None)
                }
                ArgValue::VecF32(v, dims) => {
                    self.client.buffer_from_host_buffer(v, dims, None)
                }
                ArgValue::Lit(lit) => {
                    self.client.buffer_from_host_literal(None, lit)
                }
            }
            .map_err(|e| {
                exla(&format!("{}: uploading arg {}", self.manifest.artifact,
                              spec.name), e)
            })?;
            uploaded.push(buf);
        }
        let all: Vec<&xla::PjRtBuffer> =
            self.weights.iter().chain(uploaded.iter()).collect();
        let outs = self
            .exe
            .execute_b(&all)
            .map_err(|e| exla(&format!("{}: execute", self.manifest.artifact), e))?;
        let tuple = outs[0][0]
            .to_literal_sync()
            .map_err(|e| exla("fetching outputs", e))?;
        let parts = tuple
            .to_tuple()
            .map_err(|e| exla("decomposing output tuple", e))?;
        anyhow::ensure!(parts.len() == self.manifest.outputs.len(),
                      "{}: expected {} outputs, got {}",
                      self.manifest.artifact, self.manifest.outputs.len(),
                      parts.len());
        Ok(parts)
    }

    pub fn name(&self) -> &str {
        &self.manifest.artifact
    }
}

/// Convert an output literal to `Vec<f32>`.
pub fn lit_f32(lit: &xla::Literal) -> anyhow::Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| exla("literal->f32", e))
}

/// Convert an output literal to `Vec<i32>`.
pub fn lit_i32(lit: &xla::Literal) -> anyhow::Result<Vec<i32>> {
    lit.to_vec::<i32>().map_err(|e| exla("literal->i32", e))
}
