//! Runtime bridge: load AOT HLO-text artifacts (produced by
//! `python/compile/aot.py`) and execute them on the PJRT CPU client via the
//! `xla` crate.
//!
//! Flow: `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute_b` with persistent weight buffers.
//! Interchange is HLO **text** (xla_extension 0.5.1 rejects jax ≥ 0.5's
//! 64-bit-id serialized protos; the text parser reassigns ids).

pub mod artifact;
pub mod blob;
pub mod engine;
pub mod manifest;

pub use artifact::{Artifact, ArgValue};
pub use blob::Blob;
pub use engine::{Engine, HiddenExtractor, PjrtEncoder, PjrtLm, PjrtState};
pub use manifest::{IndexJson, IoEntry, Manifest};

/// Retrieval embedding dimensionality — must match
/// `python/compile/configs.py::RETRIEVAL_DIM`. The Engine asserts this
/// against `index.json` at load; mocks and tests use the constant directly.
pub const RETRIEVAL_DIM: usize = 64;
