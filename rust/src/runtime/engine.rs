//! Engine: one PJRT CPU client + lazily-loaded artifacts, plus the
//! [`PjrtLm`] / [`PjrtEncoder`] front-ends the pipelines consume.
//!
//! An Engine is thread-local by construction (PJRT handles are raw
//! pointers); the serving layer gives each worker thread its own Engine.

use super::artifact::{lit_f32, lit_i32, ArgValue, Artifact};
use super::manifest::IndexJson;
use crate::datagen::Encoder;
use crate::lm::{greedy, LanguageModel, EOS, PAD};
use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

pub struct Engine {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub index: IndexJson,
    artifacts: RefCell<BTreeMap<String, Rc<Artifact>>>,
    weight_sets: RefCell<BTreeMap<String, Rc<Vec<xla::PjRtBuffer>>>>,
}

impl Engine {
    pub fn new(artifacts_dir: &Path) -> anyhow::Result<Self> {
        let index = IndexJson::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("creating PJRT CPU client: {e:?}"))?;
        Ok(Self {
            client,
            dir: artifacts_dir.to_path_buf(),
            index,
            artifacts: RefCell::new(BTreeMap::new()),
            weight_sets: RefCell::new(BTreeMap::new()),
        })
    }

    /// Load (or fetch cached) artifact by name, sharing weight buffers
    /// across artifacts of the same model.
    pub fn artifact(&self, name: &str) -> anyhow::Result<Rc<Artifact>> {
        let cached = self.artifacts.borrow().get(name).cloned();
        if let Some(a) = cached {
            return Ok(a);
        }
        let manifest = super::manifest::Manifest::load(
            &self.dir.join(format!("{name}.manifest.json")))?;
        let weights = match &manifest.weights_bin {
            None => Rc::new(Vec::new()),
            Some(bin) => {
                let cached = self.weight_sets.borrow().get(bin).cloned();
                match cached {
                    Some(w) => w,
                    None => {
                        let w = Rc::new(super::artifact::upload_weights(
                            &self.client, &self.dir, &manifest)?);
                        self.weight_sets
                            .borrow_mut()
                            .insert(bin.clone(), w.clone());
                        w
                    }
                }
            }
        };
        let art = Rc::new(Artifact::load(&self.client, &self.dir, name,
                                         weights)?);
        self.artifacts
            .borrow_mut()
            .insert(name.to_string(), art.clone());
        Ok(art)
    }

    pub fn lm(&self, model: &str) -> anyhow::Result<PjrtLm> {
        PjrtLm::new(self, model)
    }

    pub fn encoder(&self) -> anyhow::Result<PjrtEncoder> {
        PjrtEncoder::new(self)
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.dir
    }
}

// ---------------------------------------------------------------------------
// PjrtLm
// ---------------------------------------------------------------------------

/// LM state handle: KV cache literal + position + host copies of the small
/// outputs. Clone = snapshot (Rc-shared; old handles stay valid because
/// every step builds a new literal).
#[derive(Clone)]
pub struct PjrtState {
    kv: Rc<xla::Literal>,
    pos: usize,
    logits: Rc<Vec<f32>>,
    qproj: Rc<Vec<f32>>,
}

pub struct PjrtLm {
    prefill: Rc<Artifact>,
    decode: Rc<Artifact>,
    decode_chunk: Rc<Artifact>,
    max_ctx: usize,
    prefill_len: usize,
    vocab: usize,
    gen_chunk: usize,
}

impl PjrtLm {
    fn new(engine: &Engine, model: &str) -> anyhow::Result<Self> {
        anyhow::ensure!(engine.index.has_model(model),
                      "model {model} not in artifacts index (built: {:?})",
                      engine.index.lm_configs.keys().collect::<Vec<_>>());
        let prefill = engine.artifact(&format!("prefill_{model}"))?;
        let decode = engine.artifact(&format!("decode_{model}"))?;
        let decode_chunk = engine.artifact(&format!("decode_chunk_{model}"))?;
        let max_ctx = prefill.manifest.cfg_usize("max_ctx")?;
        let prefill_len = prefill.manifest.cfg_usize("prefill_len")?;
        let vocab = prefill.manifest.cfg_usize("vocab")?;
        let gen_chunk = decode_chunk.manifest.cfg_usize("gen_chunk")?;
        Ok(Self { prefill, decode, decode_chunk, max_ctx, prefill_len, vocab,
                  gen_chunk })
    }

    fn state_from_parts(&self, kv: xla::Literal, pos: usize,
                        logits: Vec<f32>, qproj: Vec<f32>) -> PjrtState {
        PjrtState {
            kv: Rc::new(kv),
            pos,
            logits: Rc::new(logits),
            qproj: Rc::new(qproj),
        }
    }
}

impl LanguageModel for PjrtLm {
    type State = PjrtState;

    fn max_ctx(&self) -> usize {
        self.max_ctx
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn prefill(&self, tokens: &[u32]) -> anyhow::Result<PjrtState> {
        anyhow::ensure!(tokens.len() <= self.prefill_len,
                      "context {} exceeds prefill_len {}", tokens.len(),
                      self.prefill_len);
        let valid = tokens.len().max(1) as i32; // empty context = 1 PAD token
        let mut padded = vec![PAD as i32; self.prefill_len];
        for (i, &t) in tokens.iter().enumerate() {
            padded[i] = t as i32;
        }
        let outs = self.prefill.execute(&[
            ArgValue::VecI32(&padded, &[self.prefill_len]),
            ArgValue::I32(valid),
        ])?;
        let mut it = outs.into_iter();
        let kv = it.next().unwrap();
        let logits = lit_f32(&it.next().unwrap())?;
        let qproj = lit_f32(&it.next().unwrap())?;
        Ok(self.state_from_parts(kv, valid as usize, logits, qproj))
    }

    fn generate_greedy(&self, st: &PjrtState, k: usize)
                       -> anyhow::Result<(Vec<u32>, PjrtState)> {
        let mut out = Vec::with_capacity(k);
        let mut cur = st.clone();
        let mut remaining = k;
        while remaining > 0 && cur.pos < self.max_ctx {
            if remaining >= self.gen_chunk
                && cur.pos + self.gen_chunk <= self.max_ctx
            {
                // Hot path: one PJRT call (one KV round-trip) per chunk.
                let first = greedy(&cur.logits) as i32;
                let outs = self.decode_chunk.execute(&[
                    ArgValue::I32(first),
                    ArgValue::I32(cur.pos as i32),
                    ArgValue::Lit(&cur.kv),
                ])?;
                let mut it = outs.into_iter();
                let toks = lit_i32(&it.next().unwrap())?;
                let logits = lit_f32(&it.next().unwrap())?;
                let kv = it.next().unwrap();
                let qproj = lit_f32(&it.next().unwrap())?;
                cur = self.state_from_parts(kv, cur.pos + self.gen_chunk,
                                            logits, qproj);
                remaining -= self.gen_chunk;
                let mut hit_eos = false;
                for t in toks {
                    out.push(t as u32);
                    if t as u32 == EOS {
                        hit_eos = true;
                        break;
                    }
                }
                if hit_eos {
                    break;
                }
            } else {
                let next = greedy(&cur.logits);
                cur = self.append_token(&cur, next)?;
                out.push(next);
                remaining -= 1;
                if next == EOS {
                    break;
                }
            }
        }
        Ok((out, cur))
    }

    fn append_token(&self, st: &PjrtState, token: u32)
                    -> anyhow::Result<PjrtState> {
        anyhow::ensure!(st.pos < self.max_ctx, "context full");
        let outs = self.decode.execute(&[
            ArgValue::I32(token as i32),
            ArgValue::I32(st.pos as i32),
            ArgValue::Lit(&st.kv),
        ])?;
        let mut it = outs.into_iter();
        let logits = lit_f32(&it.next().unwrap())?;
        let kv = it.next().unwrap();
        let qproj = lit_f32(&it.next().unwrap())?;
        Ok(self.state_from_parts(kv, st.pos + 1, logits, qproj))
    }

    fn logits<'a>(&self, st: &'a PjrtState) -> &'a [f32] {
        &st.logits
    }

    fn qproj<'a>(&self, st: &'a PjrtState) -> &'a [f32] {
        &st.qproj
    }

    fn pos(&self, st: &PjrtState) -> usize {
        st.pos
    }
}

// ---------------------------------------------------------------------------
// PjrtEncoder
// ---------------------------------------------------------------------------

/// Query/passage encoder backed by the `encode_q` / `encode_batch`
/// artifacts (the L2 JAX encoder).
pub struct PjrtEncoder {
    single: Rc<Artifact>,
    batch: Rc<Artifact>,
    dim: usize,
    window: usize,
    batch_size: usize,
}

impl PjrtEncoder {
    fn new(engine: &Engine) -> anyhow::Result<Self> {
        let single = engine.artifact("encode_q")?;
        let batch = engine.artifact("encode_batch")?;
        Ok(Self {
            single,
            batch,
            dim: engine.index.retrieval_dim,
            window: engine.index.encoder_len,
            batch_size: engine.index.encoder_batch,
        })
    }

    fn window_of<'a>(&self, tokens: &'a [u32]) -> &'a [u32] {
        let start = tokens.len().saturating_sub(self.window);
        &tokens[start..]
    }
}

impl Encoder for PjrtEncoder {
    fn dim(&self) -> usize {
        self.dim
    }

    fn window(&self) -> usize {
        self.window
    }

    fn encode(&self, tokens: &[u32]) -> Vec<f32> {
        let w = self.window_of(tokens);
        let mut padded = vec![PAD as i32; self.window];
        for (i, &t) in w.iter().enumerate() {
            padded[i] = t as i32;
        }
        let outs = self
            .single
            .execute(&[
                ArgValue::VecI32(&padded, &[self.window]),
                ArgValue::I32(w.len().max(1) as i32),
            ])
            .expect("encode_q execution failed");
        lit_f32(&outs[0]).expect("encode_q output")
    }

    fn encode_batch(&self, windows: &[&[u32]]) -> Vec<Vec<f32>> {
        let mut out = Vec::with_capacity(windows.len());
        for chunk in windows.chunks(self.batch_size) {
            let mut tokens = vec![PAD as i32; self.batch_size * self.window];
            let mut lens = vec![1i32; self.batch_size];
            for (r, win) in chunk.iter().enumerate() {
                let w = self.window_of(win);
                for (i, &t) in w.iter().enumerate() {
                    tokens[r * self.window + i] = t as i32;
                }
                lens[r] = w.len().max(1) as i32;
            }
            let outs = self
                .batch
                .execute(&[
                    ArgValue::VecI32(&tokens, &[self.batch_size, self.window]),
                    ArgValue::VecI32(&lens, &[self.batch_size]),
                ])
                .expect("encode_batch execution failed");
            let flat = lit_f32(&outs[0]).expect("encode_batch output");
            for r in 0..chunk.len() {
                out.push(flat[r * self.dim..(r + 1) * self.dim].to_vec());
            }
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Hidden-state extraction (KNN-LM datastore builder)
// ---------------------------------------------------------------------------

/// Run `hidden_<model>` over a token chunk; returns per-position projected
/// hidden states (row-major [len, dim]).
pub struct HiddenExtractor {
    art: Rc<Artifact>,
    pub chunk_len: usize,
    pub dim: usize,
}

impl HiddenExtractor {
    pub fn new(engine: &Engine, model: &str) -> anyhow::Result<Self> {
        let art = engine.artifact(&format!("hidden_{model}"))?;
        let chunk_len = art.manifest.cfg_usize("prefill_len")?;
        let dim = art.manifest.cfg_usize("retrieval_dim")?;
        Ok(Self { art, chunk_len, dim })
    }

    pub fn extract(&self, tokens: &[u32]) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(tokens.len() <= self.chunk_len, "chunk too long");
        let valid = tokens.len() as i32;
        let mut padded = vec![PAD as i32; self.chunk_len];
        for (i, &t) in tokens.iter().enumerate() {
            padded[i] = t as i32;
        }
        let outs = self.art.execute(&[
            ArgValue::VecI32(&padded, &[self.chunk_len]),
            ArgValue::I32(valid),
        ])?;
        let flat = lit_f32(&outs[0])?;
        Ok(flat[..tokens.len() * self.dim].to_vec())
    }
}
