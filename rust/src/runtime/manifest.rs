//! Artifact manifest / index parsing.
//!
//! `python/compile/aot.py` writes one `<name>.manifest.json` per artifact
//! (ordered input/output specs; weight entries carry byte offsets into the
//! shared `<model>.weights.bin`) plus a top-level `index.json`. The Rust
//! side never hardcodes shapes: everything comes from here. Parsed with the
//! in-tree `util::json` (no serde on this image).

use crate::util::json::{self, Value};
use std::collections::BTreeMap;
use std::path::Path;

#[derive(Debug, Clone, PartialEq)]
pub struct IoEntry {
    pub name: String,
    /// "weight" (uploaded once at load), "arg" (per call), "state" (KV
    /// cache threaded between calls), "out".
    pub kind: String,
    pub shape: Vec<usize>,
    /// "f32" | "i32".
    pub dtype: String,
    /// Byte coordinates into the weights blob (weights only).
    pub offset: Option<usize>,
    pub nbytes: Option<usize>,
}

impl IoEntry {
    fn from_json(v: &Value) -> anyhow::Result<Self> {
        Ok(Self {
            name: v.str_field("name")?,
            kind: v.str_field("kind")?,
            shape: v
                .req("shape")?
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("shape not an array"))?
                .iter()
                .map(|x| {
                    x.as_usize()
                        .ok_or_else(|| anyhow::anyhow!("bad shape element"))
                })
                .collect::<anyhow::Result<Vec<_>>>()?,
            dtype: v.str_field("dtype")?,
            offset: v.get("offset").and_then(|x| x.as_usize()),
            nbytes: v.get("nbytes").and_then(|x| x.as_usize()),
        })
    }

    pub fn elem_count(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_weight(&self) -> bool {
        self.kind == "weight"
    }
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub artifact: String,
    pub weights_bin: Option<String>,
    pub inputs: Vec<IoEntry>,
    pub outputs: Vec<IoEntry>,
    pub config: Value,
}

impl Manifest {
    pub fn parse(text: &str) -> anyhow::Result<Self> {
        let v = json::parse(text)?;
        let entries = |key: &str| -> anyhow::Result<Vec<IoEntry>> {
            v.req(key)?
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("{key} not an array"))?
                .iter()
                .map(IoEntry::from_json)
                .collect()
        };
        Ok(Self {
            artifact: v.str_field("artifact")?,
            weights_bin: v
                .get("weights_bin")
                .and_then(|x| x.as_str())
                .map(|s| s.to_string()),
            inputs: entries("inputs")?,
            outputs: entries("outputs")?,
            config: v.get("config").cloned().unwrap_or(Value::Obj(vec![])),
        })
    }

    pub fn load(path: &Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Self::parse(&text)
            .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))
    }

    pub fn n_weights(&self) -> usize {
        self.inputs.iter().filter(|e| e.is_weight()).count()
    }

    pub fn call_inputs(&self) -> impl Iterator<Item = &IoEntry> {
        self.inputs.iter().filter(|e| !e.is_weight())
    }

    /// Integer field from the echoed model config.
    pub fn cfg_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.config
            .get(key)
            .and_then(|v| v.as_usize())
            .ok_or_else(|| anyhow::anyhow!("manifest {} missing config.{key}",
                                           self.artifact))
    }
}

#[derive(Debug, Clone)]
pub struct IndexJson {
    pub artifacts: Vec<String>,
    /// Keyed by model name; ordered so that any listing derived from it
    /// (e.g. the "model not in artifacts index" error) is byte-stable.
    pub lm_configs: BTreeMap<String, Value>,
    pub retrieval_dim: usize,
    pub encoder_len: usize,
    pub encoder_batch: usize,
    pub score_batch: usize,
    pub score_tile: usize,
    pub datastore_chunk: usize,
    pub weight_seed: u64,
}

impl IndexJson {
    pub fn parse(text: &str) -> anyhow::Result<Self> {
        let v = json::parse(text)?;
        Ok(Self {
            artifacts: v
                .req("artifacts")?
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("artifacts not an array"))?
                .iter()
                .filter_map(|x| x.as_str().map(|s| s.to_string()))
                .collect(),
            lm_configs: v
                .req("lm_configs")?
                .as_obj()
                .ok_or_else(|| anyhow::anyhow!("lm_configs not an object"))?
                .iter()
                .map(|(k, val)| (k.clone(), val.clone()))
                .collect(),
            retrieval_dim: v.usize_field("retrieval_dim")?,
            encoder_len: v.usize_field("encoder_len")?,
            encoder_batch: v.usize_field("encoder_batch")?,
            score_batch: v.usize_field("score_batch")?,
            score_tile: v.usize_field("score_tile")?,
            datastore_chunk: v
                .get("datastore_chunk")
                .and_then(|x| x.as_usize())
                .unwrap_or(0),
            weight_seed: v
                .get("weight_seed")
                .and_then(|x| x.as_u64())
                .unwrap_or(0),
        })
    }

    pub fn load(dir: &Path) -> anyhow::Result<Self> {
        let path = dir.join("index.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            anyhow::anyhow!(
                "reading {} — did you run `make artifacts`? ({e})",
                path.display()
            )
        })?;
        Self::parse(&text)
    }

    pub fn has_model(&self, name: &str) -> bool {
        self.lm_configs.contains_key(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "artifact": "decode_tiny",
        "weights_bin": "tiny.weights.bin",
        "inputs": [
            {"name": "tok_emb", "kind": "weight", "shape": [64, 32],
             "dtype": "f32", "offset": 0, "nbytes": 8192},
            {"name": "token", "kind": "arg", "shape": [], "dtype": "i32"},
            {"name": "kv", "kind": "state", "shape": [1, 2, 2, 64, 16],
             "dtype": "f32"}
        ],
        "outputs": [
            {"name": "logits", "kind": "out", "shape": [64], "dtype": "f32"}
        ],
        "config": {"max_ctx": 64, "vocab": 64}
    }"#;

    #[test]
    fn parse_manifest() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.artifact, "decode_tiny");
        assert_eq!(m.weights_bin.as_deref(), Some("tiny.weights.bin"));
        assert_eq!(m.n_weights(), 1);
        assert_eq!(m.call_inputs().count(), 2);
        assert_eq!(m.inputs[0].elem_count(), 2048);
        assert_eq!(m.inputs[0].offset, Some(0));
        assert_eq!(m.cfg_usize("max_ctx").unwrap(), 64);
        assert!(m.cfg_usize("missing").is_err());
    }

    #[test]
    fn scalar_entry_has_one_element() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.inputs[1].elem_count(), 1);
        assert!(!m.inputs[1].is_weight());
    }

    #[test]
    fn null_weights_bin() {
        let m = Manifest::parse(
            r#"{"artifact": "x", "weights_bin": null, "inputs": [],
                "outputs": [], "config": {}}"#).unwrap();
        assert!(m.weights_bin.is_none());
    }

    #[test]
    fn index_json_parses() {
        let text = r#"{
            "artifacts": ["encode_q", "prefill_gpt2m"],
            "lm_configs": {"gpt2m": {"n_layers": 4}},
            "retrieval_dim": 64, "encoder_len": 32, "encoder_batch": 64,
            "score_batch": 16, "score_tile": 512,
            "datastore_chunk": 256, "weight_seed": 20240131
        }"#;
        let idx = IndexJson::parse(text).unwrap();
        assert!(idx.has_model("gpt2m"));
        assert!(!idx.has_model("opt1b"));
        assert_eq!(idx.retrieval_dim, 64);
        assert_eq!(idx.artifacts.len(), 2);
    }
}
