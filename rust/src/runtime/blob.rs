//! Read-only byte blobs with zero-copy `mmap` backing.
//!
//! Immutable KB segment files (`retriever::segment`) are loaded through
//! [`Blob`]: on Unix the file is `mmap`ed read-only (`PROT_READ` +
//! `MAP_PRIVATE`), so a cold load costs page-table setup rather than a
//! full copy and the kernel pages index bytes in on first touch; on other
//! platforms — or if the mapping fails — the file is read into the heap,
//! which is slower but bit-identical (the segment layer never observes
//! the difference). Frozen in-RAM tiers use [`Blob::from_vec`], so one
//! scan implementation covers mapped and owned bytes alike.
//!
//! The syscalls are declared directly (`std` already links libc on every
//! Unix target) — no new dependency, per the repo's no-new-crates rule.

use std::path::Path;

#[cfg(unix)]
mod sys {
    use std::os::raw::{c_int, c_void};

    pub const PROT_READ: c_int = 1;
    pub const MAP_PRIVATE: c_int = 2;

    extern "C" {
        pub fn mmap(addr: *mut c_void, len: usize, prot: c_int,
                    flags: c_int, fd: c_int, offset: i64) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
    }

    pub fn map_failed() -> *mut c_void {
        -1isize as *mut c_void
    }
}

enum Backing {
    /// A live read-only file mapping (unmapped on drop).
    #[cfg(unix)]
    Mapped {
        ptr: *mut std::os::raw::c_void,
        len: usize,
    },
    /// Heap-owned bytes: non-Unix fallback, empty files, and frozen
    /// in-RAM tiers.
    Heap(Vec<u8>),
}

/// An immutable byte buffer, either `mmap`ed from a file or heap-owned.
///
/// ```
/// use ralmspec::runtime::Blob;
///
/// let path = std::env::temp_dir()
///     .join(format!("ralmspec-blob-doc-{}", std::process::id()));
/// std::fs::write(&path, b"segment bytes").unwrap();
/// let blob = Blob::open(&path).unwrap();
/// assert_eq!(blob.bytes(), b"segment bytes");
/// std::fs::remove_file(&path).unwrap();
/// ```
pub struct Blob {
    backing: Backing,
}

// SAFETY: the mapping is created PROT_READ and never mutated or remapped
// after construction; the pointer is exclusively owned by this Blob and
// only released in Drop. Concurrent `&self` reads of immutable memory
// are safe from any thread.
unsafe impl Send for Blob {}
// SAFETY: see the Send impl — all access is read-only.
unsafe impl Sync for Blob {}

impl Blob {
    /// Map `path` read-only. Falls back to a heap read if the platform
    /// has no mmap or the mapping fails; empty files always use the heap
    /// backing (zero-length mappings are an error on most systems).
    pub fn open(path: &Path) -> anyhow::Result<Self> {
        #[cfg(unix)]
        {
            use std::os::unix::io::AsRawFd;
            let file = std::fs::File::open(path).map_err(|e| {
                anyhow::anyhow!("opening {}: {e}", path.display())
            })?;
            let len = file.metadata()?.len() as usize;
            if len == 0 {
                return Ok(Self { backing: Backing::Heap(Vec::new()) });
            }
            // SAFETY: fd is a valid open file descriptor for the whole
            // call; NULL addr + MAP_PRIVATE lets the kernel pick the
            // address; we only ever read the returned region and unmap
            // it exactly once (Drop).
            let ptr = unsafe {
                sys::mmap(std::ptr::null_mut(), len, sys::PROT_READ,
                          sys::MAP_PRIVATE, file.as_raw_fd(), 0)
            };
            if ptr != sys::map_failed() {
                return Ok(Self { backing: Backing::Mapped { ptr, len } });
            }
            // Mapping failed (exotic filesystem, resource limits):
            // degrade to a plain read.
        }
        let bytes = std::fs::read(path).map_err(|e| {
            anyhow::anyhow!("reading {}: {e}", path.display())
        })?;
        Ok(Self { backing: Backing::Heap(bytes) })
    }

    /// Wrap heap-owned bytes (frozen memtable tiers use this so mapped
    /// and in-RAM segments share one code path).
    pub fn from_vec(bytes: Vec<u8>) -> Self {
        Self { backing: Backing::Heap(bytes) }
    }

    /// The full byte contents.
    pub fn bytes(&self) -> &[u8] {
        match &self.backing {
            #[cfg(unix)]
            Backing::Mapped { ptr, len } => {
                // SAFETY: ptr/len describe a live PROT_READ mapping owned
                // by self; the lifetime of the slice is tied to &self,
                // and the mapping outlives self only until Drop.
                unsafe {
                    std::slice::from_raw_parts(*ptr as *const u8, *len)
                }
            }
            Backing::Heap(v) => v,
        }
    }

    pub fn len(&self) -> usize {
        match &self.backing {
            #[cfg(unix)]
            Backing::Mapped { len, .. } => *len,
            Backing::Heap(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// True when backed by a live file mapping (vs heap bytes) — the
    /// storage bench reports this so a silent heap fallback is visible.
    pub fn is_mapped(&self) -> bool {
        match &self.backing {
            #[cfg(unix)]
            Backing::Mapped { .. } => true,
            Backing::Heap(_) => false,
        }
    }
}

impl Drop for Blob {
    fn drop(&mut self) {
        #[cfg(unix)]
        if let Backing::Mapped { ptr, len } = self.backing {
            // SAFETY: ptr/len came from a successful mmap in `open` and
            // are unmapped exactly once, here.
            unsafe {
                sys::munmap(ptr, len);
            }
        }
    }
}

impl std::fmt::Debug for Blob {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Blob")
            .field("len", &self.len())
            .field("mapped", &self.is_mapped())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir()
            .join(format!("ralmspec-blob-{}-{name}", std::process::id()))
    }

    #[test]
    fn open_roundtrips_bytes() {
        let p = tmp("roundtrip");
        let payload: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        std::fs::write(&p, &payload).unwrap();
        let b = Blob::open(&p).unwrap();
        assert_eq!(b.bytes(), &payload[..]);
        assert_eq!(b.len(), payload.len());
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn empty_file_is_heap_backed() {
        let p = tmp("empty");
        std::fs::write(&p, b"").unwrap();
        let b = Blob::open(&p).unwrap();
        assert!(b.is_empty());
        assert!(!b.is_mapped());
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn from_vec_is_owned() {
        let b = Blob::from_vec(vec![1, 2, 3]);
        assert_eq!(b.bytes(), &[1, 2, 3]);
        assert!(!b.is_mapped());
    }

    #[cfg(unix)]
    #[test]
    fn unix_open_uses_mmap() {
        let p = tmp("mapped");
        std::fs::write(&p, b"x".repeat(4096)).unwrap();
        let b = Blob::open(&p).unwrap();
        assert!(b.is_mapped(), "non-empty files should map on unix");
        std::fs::remove_file(&p).unwrap();
    }
}
