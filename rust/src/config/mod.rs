//! Configuration system: one tree, loadable from JSON (parsed by the
//! in-tree `util::json` — the offline image has no serde), with defaults
//! matching the paper's settings (§5.1 "Implementation Details") scaled to
//! this testbed where noted in DESIGN.md.
//!
//! Every layer reads from here — the CLI, serving router, pipelines, and
//! the eval harness — so an experiment is fully described by (config, seed).

use crate::util::json::{self, Value};
use std::path::{Path, PathBuf};

/// Paper defaults (§5.1): retrieval every 4 generated tokens, constant
/// stride 3 when OS³ is off, window w=5, γ_max=0.6, prefetch 20.
pub const GEN_STRIDE: usize = 4;
pub const DEFAULT_STRIDE: usize = 3;
pub const OS3_WINDOW: usize = 5;
pub const GAMMA_MAX: f64 = 0.6;
pub const PREFETCH: usize = 20;
pub const PREFETCH_LARGE: usize = 256;

macro_rules! merge_fields {
    ($self:ident, $v:ident, { $($key:literal => $field:expr => $conv:ident),* $(,)? }) => {
        $(
            if let Some(x) = $v.get($key) {
                if let Some(x) = conv::$conv(x) {
                    $field = x;
                }
            }
        )*
    };
}

mod conv {
    use super::Value;

    pub fn usize(v: &Value) -> Option<usize> {
        v.as_usize()
    }

    pub fn u64(v: &Value) -> Option<u64> {
        v.as_u64()
    }

    pub fn f64(v: &Value) -> Option<f64> {
        v.as_f64()
    }

    pub fn f32(v: &Value) -> Option<f32> {
        v.as_f64().map(|x| x as f32)
    }

    pub fn path(v: &Value) -> Option<std::path::PathBuf> {
        v.as_str().map(std::path::PathBuf::from)
    }

    pub fn len_pair(v: &Value) -> Option<(usize, usize)> {
        let a = v.as_arr()?;
        if a.len() != 2 {
            return None;
        }
        Some((a[0].as_usize()?, a[1].as_usize()?))
    }

    pub fn bool(v: &Value) -> Option<bool> {
        v.as_bool()
    }
}

#[derive(Debug, Clone, Default)]
pub struct Config {
    pub paths: Paths,
    pub corpus: CorpusConfig,
    pub retriever: RetrieverConfig,
    pub spec: SpecConfig,
    pub knnlm: KnnLmConfig,
    pub eval: EvalConfig,
    pub serving: ServingConfig,
    pub engine: EngineConfig,
    pub ingest: IngestConfig,
    pub segment: SegmentConfig,
    pub dense: DenseConfig,
    pub tenant: TenantConfig,
    pub slo: SloConfig,
}

impl Config {
    pub fn load(path: &Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        let v = json::parse(&text)?;
        let mut cfg = Config::default();
        cfg.merge(&v);
        Ok(cfg)
    }

    /// Load `path` if given, else defaults.
    pub fn load_or_default(path: Option<&Path>) -> anyhow::Result<Self> {
        match path {
            Some(p) => Self::load(p),
            None => Ok(Self::default()),
        }
    }

    /// Overlay a (possibly partial) JSON tree onto the current values.
    pub fn merge(&mut self, v: &Value) {
        if let Some(x) = v.get("paths") {
            self.paths.merge(x);
        }
        if let Some(x) = v.get("corpus") {
            self.corpus.merge(x);
        }
        if let Some(x) = v.get("retriever") {
            self.retriever.merge(x);
        }
        if let Some(x) = v.get("spec") {
            self.spec.merge(x);
        }
        if let Some(x) = v.get("knnlm") {
            self.knnlm.merge(x);
        }
        if let Some(x) = v.get("eval") {
            self.eval.merge(x);
        }
        if let Some(x) = v.get("serving") {
            self.serving.merge(x);
        }
        if let Some(x) = v.get("engine") {
            self.engine.merge(x);
        }
        if let Some(x) = v.get("ingest") {
            self.ingest.merge(x);
        }
        if let Some(x) = v.get("segment") {
            self.segment.merge(x);
        }
        if let Some(x) = v.get("dense") {
            self.dense.merge(x);
        }
        if let Some(x) = v.get("tenant") {
            self.tenant.merge(x);
        }
        if let Some(x) = v.get("slo") {
            self.slo.merge(x);
        }
    }

    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("paths", self.paths.to_json()),
            ("corpus", self.corpus.to_json()),
            ("retriever", self.retriever.to_json()),
            ("spec", self.spec.to_json()),
            ("knnlm", self.knnlm.to_json()),
            ("eval", self.eval.to_json()),
            ("serving", self.serving.to_json()),
            ("engine", self.engine.to_json()),
            ("ingest", self.ingest.to_json()),
            ("segment", self.segment.to_json()),
            ("dense", self.dense.to_json()),
            ("tenant", self.tenant.to_json()),
            ("slo", self.slo.to_json()),
        ])
    }
}

#[derive(Debug, Clone)]
pub struct Paths {
    pub artifacts: PathBuf,
    pub data: PathBuf,
    pub reports: PathBuf,
}

impl Default for Paths {
    fn default() -> Self {
        Self {
            artifacts: PathBuf::from("artifacts"),
            data: PathBuf::from("data"),
            reports: PathBuf::from("reports"),
        }
    }
}

impl Paths {
    fn merge(&mut self, v: &Value) {
        merge_fields!(self, v, {
            "artifacts" => self.artifacts => path,
            "data" => self.data => path,
            "reports" => self.reports => path,
        });
    }

    fn to_json(&self) -> Value {
        Value::obj(vec![
            ("artifacts", Value::str(self.artifacts.display().to_string())),
            ("data", Value::str(self.data.display().to_string())),
            ("reports", Value::str(self.reports.display().to_string())),
        ])
    }
}

/// Synthetic corpus (Wikipedia stand-in) — see DESIGN.md §2.
#[derive(Debug, Clone)]
pub struct CorpusConfig {
    pub n_docs: usize,
    pub n_topics: usize,
    pub doc_len: (usize, usize),
    pub token_skew: f64,
    pub vocab: usize,
    pub reserved: usize,
    pub seed: u64,
}

impl Default for CorpusConfig {
    fn default() -> Self {
        Self {
            n_docs: 160_000,
            n_topics: 512,
            doc_len: (48, 256),
            token_skew: 1.05,
            vocab: 4096,
            reserved: 4,
            seed: 0xC0FFEE,
        }
    }
}

impl CorpusConfig {
    fn merge(&mut self, v: &Value) {
        merge_fields!(self, v, {
            "n_docs" => self.n_docs => usize,
            "n_topics" => self.n_topics => usize,
            "doc_len" => self.doc_len => len_pair,
            "token_skew" => self.token_skew => f64,
            "vocab" => self.vocab => usize,
            "reserved" => self.reserved => usize,
            "seed" => self.seed => u64,
        });
    }

    fn to_json(&self) -> Value {
        Value::obj(vec![
            ("n_docs", Value::num(self.n_docs as f64)),
            ("n_topics", Value::num(self.n_topics as f64)),
            ("doc_len", Value::Arr(vec![Value::num(self.doc_len.0 as f64),
                                        Value::num(self.doc_len.1 as f64)])),
            ("token_skew", Value::num(self.token_skew)),
            ("vocab", Value::num(self.vocab as f64)),
            ("reserved", Value::num(self.reserved as f64)),
            ("seed", Value::num(self.seed as f64)),
        ])
    }
}

#[derive(Debug, Clone)]
pub struct RetrieverConfig {
    pub hnsw_m: usize,
    pub hnsw_ef_construction: usize,
    pub hnsw_ef_search: usize,
    pub bm25_k1: f32,
    pub bm25_b: f32,
    pub sparse_query_len: usize,
    pub dense_query_len: usize,
    /// Knowledge-base shard count (1 = unsharded). >1 wraps the backend in
    /// the scatter-gather `ShardedRetriever`; results are bit-identical,
    /// batched retrieval parallelizes over the worker pool.
    pub shards: usize,
}

impl Default for RetrieverConfig {
    fn default() -> Self {
        Self {
            hnsw_m: 16,
            hnsw_ef_construction: 100,
            hnsw_ef_search: 64,
            bm25_k1: 0.9,
            bm25_b: 0.4,
            sparse_query_len: 32,
            dense_query_len: 32,
            shards: 1,
        }
    }
}

impl RetrieverConfig {
    fn merge(&mut self, v: &Value) {
        merge_fields!(self, v, {
            "hnsw_m" => self.hnsw_m => usize,
            "hnsw_ef_construction" => self.hnsw_ef_construction => usize,
            "hnsw_ef_search" => self.hnsw_ef_search => usize,
            "bm25_k1" => self.bm25_k1 => f32,
            "bm25_b" => self.bm25_b => f32,
            "sparse_query_len" => self.sparse_query_len => usize,
            "dense_query_len" => self.dense_query_len => usize,
            "shards" => self.shards => usize,
        });
    }

    fn to_json(&self) -> Value {
        Value::obj(vec![
            ("hnsw_m", Value::num(self.hnsw_m as f64)),
            ("hnsw_ef_construction",
             Value::num(self.hnsw_ef_construction as f64)),
            ("hnsw_ef_search", Value::num(self.hnsw_ef_search as f64)),
            ("bm25_k1", Value::num(self.bm25_k1 as f64)),
            ("bm25_b", Value::num(self.bm25_b as f64)),
            ("sparse_query_len", Value::num(self.sparse_query_len as f64)),
            ("dense_query_len", Value::num(self.dense_query_len as f64)),
            ("shards", Value::num(self.shards as f64)),
        ])
    }
}

/// RaLMSpec pipeline parameters (paper §5.1).
#[derive(Debug, Clone)]
pub struct SpecConfig {
    pub gen_stride: usize,
    pub stride: usize,
    pub max_stride: usize,
    pub prefetch: usize,
    pub os3_window: usize,
    pub gamma_max: f64,
    pub max_new_tokens: usize,
    pub max_doc_tokens: usize,
}

impl Default for SpecConfig {
    fn default() -> Self {
        Self {
            gen_stride: GEN_STRIDE,
            stride: DEFAULT_STRIDE,
            max_stride: 16,
            prefetch: PREFETCH,
            os3_window: OS3_WINDOW,
            gamma_max: GAMMA_MAX,
            max_new_tokens: 48,
            max_doc_tokens: 192,
        }
    }
}

impl SpecConfig {
    fn merge(&mut self, v: &Value) {
        merge_fields!(self, v, {
            "gen_stride" => self.gen_stride => usize,
            "stride" => self.stride => usize,
            "max_stride" => self.max_stride => usize,
            "prefetch" => self.prefetch => usize,
            "os3_window" => self.os3_window => usize,
            "gamma_max" => self.gamma_max => f64,
            "max_new_tokens" => self.max_new_tokens => usize,
            "max_doc_tokens" => self.max_doc_tokens => usize,
        });
    }

    fn to_json(&self) -> Value {
        Value::obj(vec![
            ("gen_stride", Value::num(self.gen_stride as f64)),
            ("stride", Value::num(self.stride as f64)),
            ("max_stride", Value::num(self.max_stride as f64)),
            ("prefetch", Value::num(self.prefetch as f64)),
            ("os3_window", Value::num(self.os3_window as f64)),
            ("gamma_max", Value::num(self.gamma_max)),
            ("max_new_tokens", Value::num(self.max_new_tokens as f64)),
            ("max_doc_tokens", Value::num(self.max_doc_tokens as f64)),
        ])
    }
}

/// KNN-LM serving (§5.3).
#[derive(Debug, Clone)]
pub struct KnnLmConfig {
    pub n_entries: usize,
    pub k: usize,
    pub lambda: f64,
    pub tau: f64,
    pub next_n: usize,
    pub cache_cap: usize,
    /// Fixed speculation stride used when serving KNN-LM requests
    /// (`serve --model knnlm`); the fig5 driver sweeps strides and OS³
    /// explicitly.
    pub stride: usize,
    pub seed: u64,
}

impl Default for KnnLmConfig {
    fn default() -> Self {
        Self {
            n_entries: 100_000,
            k: 16,
            lambda: 0.25,
            tau: 0.1,
            next_n: 10,
            cache_cap: 4096,
            stride: DEFAULT_STRIDE,
            seed: 0xDA7A,
        }
    }
}

impl KnnLmConfig {
    fn merge(&mut self, v: &Value) {
        merge_fields!(self, v, {
            "n_entries" => self.n_entries => usize,
            "k" => self.k => usize,
            "lambda" => self.lambda => f64,
            "tau" => self.tau => f64,
            "next_n" => self.next_n => usize,
            "cache_cap" => self.cache_cap => usize,
            "stride" => self.stride => usize,
            "seed" => self.seed => u64,
        });
    }

    fn to_json(&self) -> Value {
        Value::obj(vec![
            ("n_entries", Value::num(self.n_entries as f64)),
            ("k", Value::num(self.k as f64)),
            ("lambda", Value::num(self.lambda)),
            ("tau", Value::num(self.tau)),
            ("next_n", Value::num(self.next_n as f64)),
            ("cache_cap", Value::num(self.cache_cap as f64)),
            ("stride", Value::num(self.stride as f64)),
            ("seed", Value::num(self.seed as f64)),
        ])
    }
}

#[derive(Debug, Clone)]
pub struct EvalConfig {
    pub requests: usize,
    pub runs: usize,
    pub seed: u64,
}

impl Default for EvalConfig {
    fn default() -> Self {
        Self { requests: 12, runs: 3, seed: 7 }
    }
}

impl EvalConfig {
    fn merge(&mut self, v: &Value) {
        merge_fields!(self, v, {
            "requests" => self.requests => usize,
            "runs" => self.runs => usize,
            "seed" => self.seed => u64,
        });
    }

    fn to_json(&self) -> Value {
        Value::obj(vec![
            ("requests", Value::num(self.requests as f64)),
            ("runs", Value::num(self.runs as f64)),
            ("seed", Value::num(self.seed as f64)),
        ])
    }
}

#[derive(Debug, Clone)]
pub struct ServingConfig {
    pub queue_cap: usize,
    pub workers: usize,
}

impl Default for ServingConfig {
    fn default() -> Self {
        Self { queue_cap: 256, workers: 1 }
    }
}

impl ServingConfig {
    fn merge(&mut self, v: &Value) {
        merge_fields!(self, v, {
            "queue_cap" => self.queue_cap => usize,
            "workers" => self.workers => usize,
        });
    }

    fn to_json(&self) -> Value {
        Value::obj(vec![
            ("queue_cap", Value::num(self.queue_cap as f64)),
            ("workers", Value::num(self.workers as f64)),
        ])
    }
}

/// Serving-engine coalescing policy (`serving::ServeEngine`): pending
/// verification queries from concurrent requests are flushed into one
/// shared `retrieve_batch` call when `max_batch` queries have accumulated
/// or the oldest has waited `flush_us` microseconds, whichever first.
/// `kb_parallel` governs how flushed calls execute (DESIGN.md ADR-005):
/// `>= 1` runs up to that many coalesced calls concurrently on background
/// workers while the engine keeps scheduling; `0` blocks the engine
/// thread inside each call (the pre-ADR-005 *execution model* — note the
/// ADR-005 multi-step overlap drive applies in every mode, so schedule
/// metrics like spec_steps/strides differ from pre-ADR-005 engines even
/// at 0). Token outputs are bit-identical across every setting.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub max_batch: usize,
    pub flush_us: u64,
    pub kb_parallel: usize,
    /// Speculation preemption (DESIGN.md ADR-011): under overload
    /// (`max_inflight` saturated with a strictly-higher-priority request
    /// waiting) the engine cancels the lowest-priority in-flight task at
    /// a speculation boundary and requeues it — abandoned speculation is
    /// re-derivable, so per-request output stays bit-identical. All-
    /// default-priority traffic is never preempted, so the flag only
    /// matters for mixed-class workloads.
    pub preempt: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self { max_batch: 32, flush_us: 200, kb_parallel: 4,
               preempt: true }
    }
}

impl EngineConfig {
    fn merge(&mut self, v: &Value) {
        merge_fields!(self, v, {
            "max_batch" => self.max_batch => usize,
            "flush_us" => self.flush_us => u64,
            "kb_parallel" => self.kb_parallel => usize,
            "preempt" => self.preempt => bool,
        });
    }

    fn to_json(&self) -> Value {
        Value::obj(vec![
            ("max_batch", Value::num(self.max_batch as f64)),
            ("flush_us", Value::num(self.flush_us as f64)),
            ("kb_parallel", Value::num(self.kb_parallel as f64)),
            ("preempt", Value::Bool(self.preempt)),
        ])
    }
}

/// Multi-tenant serving (DESIGN.md ADR-011): `count` tenants, each with
/// its own `LiveKb`/epoch stream and flush namespace; the per-class
/// admission weights set the weighted round-robin ratio (every
/// `weight_high` high-class admissions cede one slot cycle to
/// `weight_normal` normal and `weight_low` low ones); `quota_docs` caps
/// each tenant writer's lifetime ingest (0 = unlimited).
#[derive(Debug, Clone)]
pub struct TenantConfig {
    pub count: usize,
    pub weight_high: u64,
    pub weight_normal: u64,
    pub weight_low: u64,
    pub quota_docs: usize,
}

impl Default for TenantConfig {
    fn default() -> Self {
        Self {
            count: 1,
            weight_high: 4,
            weight_normal: 2,
            weight_low: 1,
            quota_docs: 0,
        }
    }
}

impl TenantConfig {
    /// Admission weights indexed by `Priority::index()` (High, Normal,
    /// Low), each at least 1 so no class can be starved outright.
    pub fn weights(&self) -> [u64; 3] {
        [self.weight_high.max(1), self.weight_normal.max(1),
         self.weight_low.max(1)]
    }

    fn merge(&mut self, v: &Value) {
        merge_fields!(self, v, {
            "count" => self.count => usize,
            "weight_high" => self.weight_high => u64,
            "weight_normal" => self.weight_normal => u64,
            "weight_low" => self.weight_low => u64,
            "quota_docs" => self.quota_docs => usize,
        });
    }

    fn to_json(&self) -> Value {
        Value::obj(vec![
            ("count", Value::num(self.count as f64)),
            ("weight_high", Value::num(self.weight_high as f64)),
            ("weight_normal", Value::num(self.weight_normal as f64)),
            ("weight_low", Value::num(self.weight_low as f64)),
            ("quota_docs", Value::num(self.quota_docs as f64)),
        ])
    }
}

/// SLO-adaptive flush control (`serving::slo`, DESIGN.md ADR-011):
/// `p99_target_us > 0` arms the controller — the engine tracks a
/// `window`-request latency window and, while its p99 overshoots the
/// target, shrinks the coalescing window (`max_batch`/`flush_us`, never
/// below the minima here) and raises `kb_parallel` (never above
/// `max_kb_parallel`). 0 — the default — keeps the fixed configured
/// plan.
#[derive(Debug, Clone)]
pub struct SloConfig {
    pub p99_target_us: u64,
    pub window: usize,
    pub min_batch: usize,
    pub min_flush_us: u64,
    pub max_kb_parallel: usize,
}

impl Default for SloConfig {
    fn default() -> Self {
        Self {
            p99_target_us: 0,
            window: 64,
            min_batch: 1,
            min_flush_us: 50,
            max_kb_parallel: 16,
        }
    }
}

impl SloConfig {
    fn merge(&mut self, v: &Value) {
        merge_fields!(self, v, {
            "p99_target_us" => self.p99_target_us => u64,
            "window" => self.window => usize,
            "min_batch" => self.min_batch => usize,
            "min_flush_us" => self.min_flush_us => u64,
            "max_kb_parallel" => self.max_kb_parallel => usize,
        });
    }

    fn to_json(&self) -> Value {
        Value::obj(vec![
            ("p99_target_us", Value::num(self.p99_target_us as f64)),
            ("window", Value::num(self.window as f64)),
            ("min_batch", Value::num(self.min_batch as f64)),
            ("min_flush_us", Value::num(self.min_flush_us as f64)),
            ("max_kb_parallel", Value::num(self.max_kb_parallel as f64)),
        ])
    }
}

/// Live knowledge-base ingestion (`retriever::epoch`, DESIGN.md ADR-006):
/// `rate` drives the serve scenario's background writer (documents per
/// second; 0 disables ingestion — the default, preserving the frozen-KB
/// behaviour of earlier PRs), `batch` is the number of pending documents
/// the writer accumulates before publishing a new epoch (larger batches
/// amortize snapshot construction; smaller ones tighten freshness).
#[derive(Debug, Clone)]
pub struct IngestConfig {
    pub rate: f64,
    pub batch: usize,
}

impl Default for IngestConfig {
    fn default() -> Self {
        Self { rate: 0.0, batch: 8 }
    }
}

impl IngestConfig {
    fn merge(&mut self, v: &Value) {
        merge_fields!(self, v, {
            "rate" => self.rate => f64,
            "batch" => self.batch => usize,
        });
    }

    fn to_json(&self) -> Value {
        Value::obj(vec![
            ("rate", Value::num(self.rate)),
            ("batch", Value::num(self.batch as f64)),
        ])
    }
}

/// Persistent segment store (`retriever::segment`, DESIGN.md ADR-009):
/// `kb_dir` roots the on-disk store (`None` — the default — keeps the
/// fully in-RAM backends of ADR-006; the empty string also means
/// disabled so a JSON overlay can switch persistence off). When set,
/// `memtable_docs` caps the in-RAM mutable tier before it is frozen to
/// a segment, `compact_segments` is the tier count at which the
/// background worker folds everything back into one segment, and
/// `compact_interval_ms` paces that worker's polling.
#[derive(Debug, Clone)]
pub struct SegmentConfig {
    pub kb_dir: Option<PathBuf>,
    pub memtable_docs: usize,
    pub compact_segments: usize,
    pub compact_interval_ms: u64,
}

impl Default for SegmentConfig {
    fn default() -> Self {
        Self {
            kb_dir: None,
            memtable_docs: 4096,
            compact_segments: 4,
            compact_interval_ms: 250,
        }
    }
}

impl SegmentConfig {
    fn merge(&mut self, v: &Value) {
        if let Some(x) = v.get("kb_dir") {
            if let Some(s) = x.as_str() {
                self.kb_dir = if s.is_empty() {
                    None
                } else {
                    Some(PathBuf::from(s))
                };
            }
        }
        merge_fields!(self, v, {
            "memtable_docs" => self.memtable_docs => usize,
            "compact_segments" => self.compact_segments => usize,
            "compact_interval_ms" => self.compact_interval_ms => u64,
        });
    }

    fn to_json(&self) -> Value {
        let dir = self.kb_dir.as_ref()
            .map(|p| p.display().to_string())
            .unwrap_or_default();
        Value::obj(vec![
            ("kb_dir", Value::str(dir)),
            ("memtable_docs", Value::num(self.memtable_docs as f64)),
            ("compact_segments", Value::num(self.compact_segments as f64)),
            ("compact_interval_ms",
             Value::num(self.compact_interval_ms as f64)),
        ])
    }
}

/// Dense storage codec (DESIGN.md ADR-010): `Full` stores/scans f32
/// rows only; `Sq8` adds per-row scalar-quantized u8 codes scanned by
/// the integer kernels for candidate generation, with survivors
/// re-scored from the retained f32 rows — final top-k is bit-identical
/// to `Full` (tests/quantized_equivalence.rs).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DenseCodec {
    #[default]
    Full,
    Sq8,
}

impl DenseCodec {
    pub fn label(&self) -> &'static str {
        match self {
            DenseCodec::Full => "full",
            DenseCodec::Sq8 => "sq8",
        }
    }
}

impl std::str::FromStr for DenseCodec {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "full" | "f32" => Ok(DenseCodec::Full),
            "sq8" | "quantized" => Ok(DenseCodec::Sq8),
            other => Err(anyhow::anyhow!("unknown dense codec: {other}")),
        }
    }
}

/// Dense (EDR) storage/scan policy: the codec, plus the SQ8 pruning
/// heap factor — the quantized candidate phase keeps at least
/// `ceil(k * oversample)` exact scores before it starts pruning rows
/// whose score upper bound falls below the running threshold. Larger
/// values prune less (more exact re-scores); correctness never depends
/// on it.
#[derive(Debug, Clone)]
pub struct DenseConfig {
    pub codec: DenseCodec,
    pub oversample: f64,
}

impl Default for DenseConfig {
    fn default() -> Self {
        Self {
            codec: DenseCodec::Full,
            oversample:
                crate::retriever::dense::DEFAULT_SQ8_OVERSAMPLE,
        }
    }
}

impl DenseConfig {
    fn merge(&mut self, v: &Value) {
        if let Some(x) = v.get("codec") {
            if let Some(s) = x.as_str() {
                if let Ok(c) = s.parse() {
                    self.codec = c;
                }
            }
        }
        merge_fields!(self, v, {
            "oversample" => self.oversample => f64,
        });
    }

    fn to_json(&self) -> Value {
        Value::obj(vec![
            ("codec", Value::str(self.codec.label().to_string())),
            ("oversample", Value::num(self.oversample)),
        ])
    }
}

/// The three retriever classes evaluated in the paper. `Ord` follows
/// declaration order (Edr < Adr < Sr) so the kind can key ordered maps
/// (e.g. the [`crate::eval::TestBed`] sharded-wrapper cache).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum RetrieverKind {
    /// Exact dense retriever (DPR / IndexFlatIP stand-in).
    Edr,
    /// Approximate dense retriever (DPR-HNSW stand-in).
    Adr,
    /// Sparse retriever (BM25).
    Sr,
}

impl RetrieverKind {
    pub fn all() -> [RetrieverKind; 3] {
        [RetrieverKind::Edr, RetrieverKind::Adr, RetrieverKind::Sr]
    }

    pub fn label(&self) -> &'static str {
        match self {
            RetrieverKind::Edr => "EDR",
            RetrieverKind::Adr => "ADR",
            RetrieverKind::Sr => "SR",
        }
    }
}

impl std::str::FromStr for RetrieverKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "edr" | "exact" | "flat" => Ok(RetrieverKind::Edr),
            "adr" | "hnsw" | "approx" => Ok(RetrieverKind::Adr),
            "sr" | "bm25" | "sparse" => Ok(RetrieverKind::Sr),
            other => Err(anyhow::anyhow!("unknown retriever kind: {other}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_settings() {
        let c = Config::default();
        assert_eq!(c.spec.gen_stride, 4);
        assert_eq!(c.spec.stride, 3);
        assert_eq!(c.spec.os3_window, 5);
        assert!((c.spec.gamma_max - 0.6).abs() < 1e-12);
        assert_eq!(c.spec.prefetch, 20);
        assert_eq!(c.knnlm.next_n, 10);
        assert_eq!(c.knnlm.stride, DEFAULT_STRIDE);
    }

    #[test]
    fn knnlm_stride_merges() {
        let v = json::parse(r#"{"knnlm": {"stride": 6}}"#).unwrap();
        let mut c = Config::default();
        c.merge(&v);
        assert_eq!(c.knnlm.stride, 6);
        assert_eq!(c.knnlm.k, 16); // untouched default
    }

    #[test]
    fn json_roundtrip() {
        let c = Config::default();
        let text = c.to_json().pretty();
        let v = json::parse(&text).unwrap();
        let mut back = Config::default();
        back.corpus.n_docs = 0; // will be restored by merge
        back.merge(&v);
        assert_eq!(back.spec.stride, c.spec.stride);
        assert_eq!(back.corpus.n_docs, c.corpus.n_docs);
        assert_eq!(back.corpus.doc_len, c.corpus.doc_len);
    }

    #[test]
    fn partial_json_fills_defaults() {
        let v = json::parse(r#"{"spec": {"stride": 5}}"#).unwrap();
        let mut c = Config::default();
        c.merge(&v);
        assert_eq!(c.spec.stride, 5);
        assert_eq!(c.spec.gen_stride, 4); // default preserved
        assert_eq!(c.corpus.n_docs, CorpusConfig::default().n_docs);
    }

    #[test]
    fn shards_default_and_merge() {
        assert_eq!(Config::default().retriever.shards, 1);
        let v = json::parse(r#"{"retriever": {"shards": 4}}"#).unwrap();
        let mut c = Config::default();
        c.merge(&v);
        assert_eq!(c.retriever.shards, 4);
        assert_eq!(c.retriever.hnsw_m, 16); // untouched default
    }

    #[test]
    fn engine_defaults_and_merge() {
        let c = Config::default();
        assert_eq!(c.engine.max_batch, 32);
        assert_eq!(c.engine.flush_us, 200);
        assert_eq!(c.engine.kb_parallel, 4);
        let v = json::parse(
            r#"{"engine": {"max_batch": 8, "flush_us": 1000,
                           "kb_parallel": 0}}"#).unwrap();
        let mut c = Config::default();
        c.merge(&v);
        assert_eq!(c.engine.max_batch, 8);
        assert_eq!(c.engine.flush_us, 1000);
        assert_eq!(c.engine.kb_parallel, 0); // synchronous inline mode
        assert_eq!(c.serving.queue_cap, 256); // untouched default
    }

    #[test]
    fn ingest_defaults_and_merge() {
        let c = Config::default();
        assert_eq!(c.ingest.rate, 0.0); // live updates off by default
        assert_eq!(c.ingest.batch, 8);
        let v = json::parse(
            r#"{"ingest": {"rate": 12.5, "batch": 3}}"#).unwrap();
        let mut c = Config::default();
        c.merge(&v);
        assert!((c.ingest.rate - 12.5).abs() < 1e-12);
        assert_eq!(c.ingest.batch, 3);
        assert_eq!(c.engine.max_batch, 32); // untouched default
    }

    #[test]
    fn segment_defaults_and_merge() {
        let c = Config::default();
        assert_eq!(c.segment.kb_dir, None); // persistence off by default
        assert_eq!(c.segment.memtable_docs, 4096);
        assert_eq!(c.segment.compact_segments, 4);
        assert_eq!(c.segment.compact_interval_ms, 250);
        let v = json::parse(
            r#"{"segment": {"kb_dir": "/tmp/kb", "memtable_docs": 64,
                            "compact_segments": 2,
                            "compact_interval_ms": 10}}"#).unwrap();
        let mut c = Config::default();
        c.merge(&v);
        assert_eq!(c.segment.kb_dir, Some(PathBuf::from("/tmp/kb")));
        assert_eq!(c.segment.memtable_docs, 64);
        assert_eq!(c.segment.compact_segments, 2);
        assert_eq!(c.segment.compact_interval_ms, 10);
        // Empty string switches persistence back off (round-trips the
        // `to_json` encoding of `None`).
        let v = json::parse(r#"{"segment": {"kb_dir": ""}}"#).unwrap();
        c.merge(&v);
        assert_eq!(c.segment.kb_dir, None);
        assert_eq!(c.ingest.batch, 8); // untouched default
    }

    #[test]
    fn dense_codec_defaults_and_merge() {
        let c = Config::default();
        assert_eq!(c.dense.codec, DenseCodec::Full);
        assert!((c.dense.oversample - 2.0).abs() < 1e-12);
        let v = json::parse(
            r#"{"dense": {"codec": "sq8", "oversample": 4.0}}"#)
            .unwrap();
        let mut c = Config::default();
        c.merge(&v);
        assert_eq!(c.dense.codec, DenseCodec::Sq8);
        assert!((c.dense.oversample - 4.0).abs() < 1e-12);
        assert_eq!(c.segment.memtable_docs, 4096); // untouched default
        // Label round-trips through FromStr and to_json.
        assert_eq!("full".parse::<DenseCodec>().unwrap(),
                   DenseCodec::Full);
        assert_eq!(DenseCodec::Sq8.label(), "sq8");
        assert!("pq4".parse::<DenseCodec>().is_err());
    }

    #[test]
    fn tenant_defaults_and_merge() {
        let c = Config::default();
        assert_eq!(c.tenant.count, 1); // single-tenant by default
        assert_eq!(c.tenant.weights(), [4, 2, 1]);
        assert_eq!(c.tenant.quota_docs, 0); // unlimited ingest
        assert!(c.engine.preempt); // preemption armed (no-op single-class)
        let v = json::parse(
            r#"{"tenant": {"count": 4, "weight_high": 8, "weight_low": 0,
                           "quota_docs": 500},
                "engine": {"preempt": false}}"#).unwrap();
        let mut c = Config::default();
        c.merge(&v);
        assert_eq!(c.tenant.count, 4);
        // weight_low 0 would starve the class; weights() floors at 1.
        assert_eq!(c.tenant.weights(), [8, 2, 1]);
        assert_eq!(c.tenant.quota_docs, 500);
        assert!(!c.engine.preempt);
        assert_eq!(c.engine.max_batch, 32); // untouched default
    }

    #[test]
    fn slo_defaults_and_merge() {
        let c = Config::default();
        assert_eq!(c.slo.p99_target_us, 0); // adaptation off by default
        assert_eq!(c.slo.window, 64);
        assert_eq!(c.slo.min_batch, 1);
        assert_eq!(c.slo.min_flush_us, 50);
        assert_eq!(c.slo.max_kb_parallel, 16);
        let v = json::parse(
            r#"{"slo": {"p99_target_us": 250000, "window": 32,
                        "min_batch": 4, "min_flush_us": 20,
                        "max_kb_parallel": 8}}"#).unwrap();
        let mut c = Config::default();
        c.merge(&v);
        assert_eq!(c.slo.p99_target_us, 250_000);
        assert_eq!(c.slo.window, 32);
        assert_eq!(c.slo.min_batch, 4);
        assert_eq!(c.slo.min_flush_us, 20);
        assert_eq!(c.slo.max_kb_parallel, 8);
        assert_eq!(c.tenant.count, 1); // untouched default
    }

    #[test]
    fn retriever_kind_parsing() {
        assert_eq!("edr".parse::<RetrieverKind>().unwrap(), RetrieverKind::Edr);
        assert_eq!("HNSW".parse::<RetrieverKind>().unwrap(), RetrieverKind::Adr);
        assert_eq!("bm25".parse::<RetrieverKind>().unwrap(), RetrieverKind::Sr);
        assert!("nope".parse::<RetrieverKind>().is_err());
    }
}
