//! Synthetic data generation: corpus (Wikipedia stand-in), QA workloads
//! (the paper's four datasets), the KNN-LM token stream (WikiText-103
//! stand-in), and the encoder abstraction shared with the runtime.

pub mod corpus;
pub mod embedding;
pub mod qa;
pub mod wikitext;

pub use corpus::{Corpus, Document, EOS, PAD, SEP};
pub use embedding::{embed_corpus, embed_doc, Encoder, HashEncoder};
pub use qa::{generate_questions, Dataset, Question};
pub use wikitext::{generate_stream, TokenStream};
