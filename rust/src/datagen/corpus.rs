//! Synthetic knowledge-base corpus (Wikipedia stand-in).
//!
//! A latent-topic generator: each topic owns a Zipf-weighted pool of token
//! ids; passages sample from their topic's pool plus a global common-word
//! pool. Passages from the same topic therefore share vocabulary, which
//! gives (a) clustered dense embeddings under *any* bag-of-words encoder and
//! (b) realistic document-frequency skew for BM25 — the two properties the
//! paper's temporal/spatial retrieval locality rests on (DESIGN.md §2).

use crate::config::CorpusConfig;
use crate::util::{Rng, Zipf};
use std::sync::Arc;

/// Special token ids (bottom of the vocabulary).
pub const PAD: u32 = 0;
pub const EOS: u32 = 1;
pub const SEP: u32 = 2;

#[derive(Debug, Clone)]
pub struct Document {
    pub id: u32,
    pub topic: u32,
    pub tokens: Vec<u32>,
}

/// `Clone` so a live-update writer can own a mutable master copy and
/// publish immutable `Arc<Corpus>` snapshots per epoch (see
/// `retriever::epoch`): documents are append-only and never mutate, so a
/// snapshot taken at epoch E stays byte-identical for every id < len(E)
/// no matter how far the master has grown since.
///
/// Storage is split into an immutable shared `base` (behind an `Arc`) and
/// a small mutable `tail` absorbing appends, so cloning for an epoch
/// snapshot costs O(tail) — not O(corpus) — matching the segment tier's
/// O(memtable) republish guarantee (DESIGN.md ADR-009). [`Corpus::seal`]
/// folds the tail into the base; the writer calls it only on compaction,
/// where an O(corpus) pass is already being paid in the background.
#[derive(Debug, Clone)]
pub struct Corpus {
    base: Arc<Vec<Document>>,
    tail: Vec<Document>,
    pub vocab: usize,
    pub n_topics: usize,
    /// Per-topic token pools (used by the QA workload generator to phrase
    /// questions "about" a topic).
    topic_pools: Arc<Vec<TopicPool>>,
    common_pool: Arc<Vec<u32>>,
}

#[derive(Debug, Clone)]
struct TopicPool {
    tokens: Vec<u32>,
    zipf: Zipf,
}

/// Fraction of tokens drawn from the global common pool (stop-words).
const COMMON_FRAC: f64 = 0.25;
const COMMON_POOL: usize = 64;
const TOPIC_POOL: usize = 192;

/// Sample one passage's tokens: `COMMON_FRAC` of draws from the global
/// common pool, the rest from the topic's pool. The single sampler
/// behind both the build-time generator and the live-ingest stream
/// ([`Corpus::synth_docs`]), so ingested documents come from the same
/// distribution as build-time ones by construction.
fn sample_tokens(pool: &TopicPool, common_pool: &[u32],
                 common_zipf: &Zipf, len: usize, rng: &mut Rng)
                 -> Vec<u32> {
    (0..len)
        .map(|_| {
            if rng.next_f64() < COMMON_FRAC {
                common_pool[common_zipf.sample(rng)]
            } else {
                pool.tokens[pool.zipf.sample(rng)]
            }
        })
        .collect()
}

/// Build the token pools, consuming the same parent-RNG draws (one fork
/// per topic) as the original inline construction — `generate` continues
/// from the same `rng` state afterwards, so document generation is
/// byte-identical to pre-refactor builds.
fn make_pools(cfg: &CorpusConfig, rng: &mut Rng)
              -> (Vec<TopicPool>, Vec<u32>) {
    // Common pool: the most "frequent" ids right above the reserved ones.
    let common_pool: Vec<u32> =
        (cfg.reserved as u32..(cfg.reserved + COMMON_POOL) as u32).collect();
    let content_lo = cfg.reserved + COMMON_POOL;

    // Topic pools: deterministic per-topic subsets of the content range.
    let mut topic_pools = Vec::with_capacity(cfg.n_topics);
    for t in 0..cfg.n_topics {
        let mut trng = rng.fork(t as u64 + 1);
        let tokens: Vec<u32> = (0..TOPIC_POOL)
            .map(|_| trng.gen_range_in(content_lo, cfg.vocab) as u32)
            .collect();
        topic_pools.push(TopicPool {
            tokens,
            zipf: Zipf::new(TOPIC_POOL, cfg.token_skew),
        });
    }
    (topic_pools, common_pool)
}

impl Corpus {
    pub fn generate(cfg: &CorpusConfig) -> Self {
        assert!(cfg.vocab > cfg.reserved + COMMON_POOL + TOPIC_POOL,
                "vocab too small for pools");
        let mut rng = Rng::new(cfg.seed);
        let (topic_pools, common_pool) = make_pools(cfg, &mut rng);
        let common_zipf = Zipf::new(COMMON_POOL, 1.2);

        let mut docs = Vec::with_capacity(cfg.n_docs);
        for id in 0..cfg.n_docs {
            let mut drng = rng.fork(0x1000_0000 + id as u64);
            let topic = drng.gen_range(cfg.n_topics) as u32;
            let len = drng.length(cfg.doc_len.0, cfg.doc_len.1);
            let pool = &topic_pools[topic as usize];
            let tokens = sample_tokens(pool, &common_pool, &common_zipf,
                                       len, &mut drng);
            docs.push(Document { id: id as u32, topic, tokens });
        }

        Self {
            base: Arc::new(docs),
            tail: Vec::new(),
            vocab: cfg.vocab,
            n_topics: cfg.n_topics,
            topic_pools: Arc::new(topic_pools),
            common_pool: Arc::new(common_pool),
        }
    }

    /// Reassemble a corpus from documents recovered off disk (segment
    /// cold load): pools are regenerated deterministically from `cfg`
    /// (they depend only on the corpus seed), documents come from the
    /// caller. Used by `retriever::segment::SegmentStore::open`.
    pub fn rebuild(cfg: &CorpusConfig, docs: Vec<Document>) -> Self {
        assert!(cfg.vocab > cfg.reserved + COMMON_POOL + TOPIC_POOL,
                "vocab too small for pools");
        let mut rng = Rng::new(cfg.seed);
        let (topic_pools, common_pool) = make_pools(cfg, &mut rng);
        for (i, d) in docs.iter().enumerate() {
            assert_eq!(d.id as usize, i, "recovered doc ids must be contiguous");
        }
        Self {
            base: Arc::new(docs),
            tail: Vec::new(),
            vocab: cfg.vocab,
            n_topics: cfg.n_topics,
            topic_pools: Arc::new(topic_pools),
            common_pool: Arc::new(common_pool),
        }
    }

    pub fn len(&self) -> usize {
        self.base.len() + self.tail.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn doc(&self, id: u32) -> &Document {
        let i = id as usize;
        if i < self.base.len() {
            &self.base[i]
        } else {
            &self.tail[i - self.base.len()]
        }
    }

    /// Iterate all documents in id order (base, then tail).
    pub fn iter(&self) -> impl Iterator<Item = &Document> + '_ {
        self.base.iter().chain(self.tail.iter())
    }

    /// Number of documents in the immutable sealed base (the rest live in
    /// the mutable tail and are re-cloned on every snapshot).
    pub fn sealed_len(&self) -> usize {
        self.base.len()
    }

    /// Fold the mutable tail into the shared immutable base. O(corpus) —
    /// the live writer calls this only on compaction, never per publish.
    pub fn seal(&mut self) {
        if self.tail.is_empty() {
            return;
        }
        let mut docs = Vec::with_capacity(self.len());
        docs.extend_from_slice(&self.base);
        docs.append(&mut self.tail);
        self.base = Arc::new(docs);
    }

    /// Drop all documents with id >= `n` (test fixtures carve a prefix
    /// corpus out of a larger build).
    pub fn truncate(&mut self, n: usize) {
        if n >= self.len() {
            return;
        }
        if n >= self.base.len() {
            self.tail.truncate(n - self.base.len());
        } else {
            self.tail.clear();
            self.base = Arc::new(self.base[..n].to_vec());
        }
    }

    /// Sample `n` tokens "about" a topic (question phrasing).
    pub fn topic_tokens(&self, topic: u32, n: usize, rng: &mut Rng) -> Vec<u32> {
        let pool = &self.topic_pools[topic as usize];
        (0..n)
            .map(|_| {
                if rng.next_f64() < 0.15 {
                    self.common_pool[rng.gen_range(self.common_pool.len())]
                } else {
                    pool.tokens[pool.zipf.sample(rng)]
                }
            })
            .collect()
    }

    /// Append freshly ingested documents (live knowledge-base updates).
    /// Ids must continue the corpus' contiguous id space — the retrieval
    /// layer's doc-id ↔ row-index correspondence depends on it.
    pub fn append(&mut self, docs: Vec<Document>) {
        for d in docs {
            assert_eq!(d.id as usize, self.len(),
                       "ingested doc ids must be contiguous");
            assert!(d.tokens.iter().all(|&t| (t as usize) < self.vocab),
                    "ingested doc uses tokens outside the corpus vocab");
            self.tail.push(d);
        }
    }

    /// Synthesize `count` fresh documents for the ingest stream, ids
    /// starting at `start_id`, drawn from the same topic/common pools as
    /// the build-time generator. Deterministic in (`seed`, id) — two
    /// writers replaying the same stream produce byte-identical docs —
    /// but an independent RNG stream from `generate`'s, so ingested docs
    /// are new material, not replays of build-time ones.
    pub fn synth_docs(&self, seed: u64, start_id: u32, count: usize,
                      doc_len: (usize, usize)) -> Vec<Document> {
        let common_zipf = Zipf::new(COMMON_POOL, 1.2);
        (0..count)
            .map(|i| {
                let id = start_id + i as u32;
                let mut drng =
                    Rng::new(seed ^ ((id as u64 + 1) * 0x9E37_79B9_7F4A_7C15));
                let topic = drng.gen_range(self.n_topics) as u32;
                let len = drng.length(doc_len.0, doc_len.1);
                let pool = &self.topic_pools[topic as usize];
                let tokens = sample_tokens(pool, &self.common_pool,
                                           &common_zipf, len, &mut drng);
                Document { id, topic, tokens }
            })
            .collect()
    }

    /// Average document length in tokens (BM25 needs this).
    pub fn avg_doc_len(&self) -> f64 {
        if self.is_empty() {
            return 0.0;
        }
        self.iter().map(|d| d.tokens.len()).sum::<usize>() as f64
            / self.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> CorpusConfig {
        CorpusConfig { n_docs: 500, n_topics: 16, doc_len: (20, 60),
                       ..CorpusConfig::default() }
    }

    #[test]
    fn generation_is_deterministic() {
        let cfg = small_cfg();
        let a = Corpus::generate(&cfg);
        let b = Corpus::generate(&cfg);
        assert_eq!(a.len(), b.len());
        for (da, db) in a.iter().zip(b.iter()) {
            assert_eq!(da.tokens, db.tokens);
            assert_eq!(da.topic, db.topic);
        }
    }

    #[test]
    fn doc_lengths_in_range() {
        let cfg = small_cfg();
        let c = Corpus::generate(&cfg);
        for d in c.iter() {
            assert!(d.tokens.len() >= cfg.doc_len.0);
            assert!(d.tokens.len() <= cfg.doc_len.1);
        }
    }

    #[test]
    fn tokens_avoid_reserved_range() {
        let cfg = small_cfg();
        let c = Corpus::generate(&cfg);
        for d in c.iter() {
            for &t in &d.tokens {
                assert!(t >= cfg.reserved as u32);
                assert!((t as usize) < cfg.vocab);
            }
        }
    }

    #[test]
    fn same_topic_docs_share_vocabulary() {
        let cfg = small_cfg();
        let c = Corpus::generate(&cfg);
        // Find two docs with the same topic and two with different topics;
        // same-topic overlap (Jaccard) should exceed cross-topic overlap.
        let overlap = |a: &Document, b: &Document| {
            let sa: std::collections::HashSet<u32> =
                a.tokens.iter().copied().collect();
            let sb: std::collections::HashSet<u32> =
                b.tokens.iter().copied().collect();
            let inter = sa.intersection(&sb).count() as f64;
            inter / (sa.len().min(sb.len()) as f64)
        };
        let d0 = c.doc(0);
        let same = c.iter().find(|d| d.id != d0.id && d.topic == d0.topic);
        let diff = c.iter().find(|d| d.topic != d0.topic).unwrap();
        if let Some(same) = same {
            assert!(overlap(d0, same) > overlap(d0, diff),
                    "same-topic docs should overlap more");
        }
    }

    #[test]
    fn topic_tokens_deterministic_given_rng() {
        let cfg = small_cfg();
        let c = Corpus::generate(&cfg);
        let a = c.topic_tokens(3, 10, &mut Rng::new(5));
        let b = c.topic_tokens(3, 10, &mut Rng::new(5));
        assert_eq!(a, b);
    }

    #[test]
    fn synth_docs_deterministic_contiguous_and_in_vocab() {
        let cfg = small_cfg();
        let c = Corpus::generate(&cfg);
        let a = c.synth_docs(42, c.len() as u32, 10, (20, 60));
        let b = c.synth_docs(42, c.len() as u32, 10, (20, 60));
        assert_eq!(a.len(), 10);
        for (da, db) in a.iter().zip(&b) {
            assert_eq!(da.id, db.id);
            assert_eq!(da.tokens, db.tokens);
        }
        for (i, d) in a.iter().enumerate() {
            assert_eq!(d.id as usize, c.len() + i);
            assert!(d.tokens.len() >= 20 && d.tokens.len() <= 60);
            for &t in &d.tokens {
                assert!(t >= cfg.reserved as u32
                        && (t as usize) < cfg.vocab);
            }
        }
    }

    #[test]
    fn append_grows_and_preserves_existing_docs() {
        let cfg = small_cfg();
        let mut c = Corpus::generate(&cfg);
        let before = c.doc(3).tokens.clone();
        let n = c.len();
        let fresh = c.synth_docs(7, n as u32, 5, (20, 60));
        let expect_first = fresh[0].tokens.clone();
        c.append(fresh);
        assert_eq!(c.len(), n + 5);
        assert_eq!(c.doc(3).tokens, before, "existing docs never mutate");
        assert_eq!(c.doc(n as u32).tokens, expect_first);
    }

    #[test]
    #[should_panic(expected = "contiguous")]
    fn append_rejects_id_gaps() {
        let cfg = small_cfg();
        let mut c = Corpus::generate(&cfg);
        let bad = Document { id: c.len() as u32 + 1, topic: 0,
                             tokens: vec![100] };
        c.append(vec![bad]);
    }

    #[test]
    fn avg_doc_len_sane() {
        let cfg = small_cfg();
        let c = Corpus::generate(&cfg);
        let avg = c.avg_doc_len();
        assert!(avg >= cfg.doc_len.0 as f64 && avg <= cfg.doc_len.1 as f64);
    }

    #[test]
    fn seal_and_truncate_preserve_contents() {
        let cfg = small_cfg();
        let mut c = Corpus::generate(&cfg);
        let n = c.len();
        let fresh = c.synth_docs(9, n as u32, 7, (20, 60));
        c.append(fresh);
        assert_eq!(c.sealed_len(), n);
        let all: Vec<Vec<u32>> = c.iter().map(|d| d.tokens.clone()).collect();
        c.seal();
        assert_eq!(c.sealed_len(), n + 7);
        let sealed: Vec<Vec<u32>> =
            c.iter().map(|d| d.tokens.clone()).collect();
        assert_eq!(all, sealed, "seal never changes document contents");
        c.truncate(n + 2);
        assert_eq!(c.len(), n + 2);
        assert_eq!(c.doc(3).tokens, all[3]);
    }

    #[test]
    fn rebuild_matches_generate() {
        let cfg = small_cfg();
        let a = Corpus::generate(&cfg);
        let docs: Vec<Document> = a.iter().cloned().collect();
        let b = Corpus::rebuild(&cfg, docs);
        assert_eq!(a.len(), b.len());
        for (da, db) in a.iter().zip(b.iter()) {
            assert_eq!(da.tokens, db.tokens);
        }
        // Pools regenerate identically: question phrasing is unchanged.
        assert_eq!(a.topic_tokens(2, 12, &mut Rng::new(11)),
                   b.topic_tokens(2, 12, &mut Rng::new(11)));
        // The ingest stream continues identically too.
        let sa = a.synth_docs(42, a.len() as u32, 3, (20, 60));
        let sb = b.synth_docs(42, b.len() as u32, 3, (20, 60));
        for (x, y) in sa.iter().zip(&sb) {
            assert_eq!(x.tokens, y.tokens);
        }
    }
}
