//! Synthetic knowledge-base corpus (Wikipedia stand-in).
//!
//! A latent-topic generator: each topic owns a Zipf-weighted pool of token
//! ids; passages sample from their topic's pool plus a global common-word
//! pool. Passages from the same topic therefore share vocabulary, which
//! gives (a) clustered dense embeddings under *any* bag-of-words encoder and
//! (b) realistic document-frequency skew for BM25 — the two properties the
//! paper's temporal/spatial retrieval locality rests on (DESIGN.md §2).

use crate::config::CorpusConfig;
use crate::util::{Rng, Zipf};

/// Special token ids (bottom of the vocabulary).
pub const PAD: u32 = 0;
pub const EOS: u32 = 1;
pub const SEP: u32 = 2;

#[derive(Debug, Clone)]
pub struct Document {
    pub id: u32,
    pub topic: u32,
    pub tokens: Vec<u32>,
}

/// `Clone` so a live-update writer can own a mutable master copy and
/// publish immutable `Arc<Corpus>` snapshots per epoch (see
/// `retriever::epoch`): documents are append-only and never mutate, so a
/// snapshot taken at epoch E stays byte-identical for every id < len(E)
/// no matter how far the master has grown since.
#[derive(Debug, Clone)]
pub struct Corpus {
    pub docs: Vec<Document>,
    pub vocab: usize,
    pub n_topics: usize,
    /// Per-topic token pools (used by the QA workload generator to phrase
    /// questions "about" a topic).
    topic_pools: Vec<TopicPool>,
    common_pool: Vec<u32>,
}

#[derive(Debug, Clone)]
struct TopicPool {
    tokens: Vec<u32>,
    zipf: Zipf,
}

/// Fraction of tokens drawn from the global common pool (stop-words).
const COMMON_FRAC: f64 = 0.25;
const COMMON_POOL: usize = 64;
const TOPIC_POOL: usize = 192;

/// Sample one passage's tokens: `COMMON_FRAC` of draws from the global
/// common pool, the rest from the topic's pool. The single sampler
/// behind both the build-time generator and the live-ingest stream
/// ([`Corpus::synth_docs`]), so ingested documents come from the same
/// distribution as build-time ones by construction.
fn sample_tokens(pool: &TopicPool, common_pool: &[u32],
                 common_zipf: &Zipf, len: usize, rng: &mut Rng)
                 -> Vec<u32> {
    (0..len)
        .map(|_| {
            if rng.next_f64() < COMMON_FRAC {
                common_pool[common_zipf.sample(rng)]
            } else {
                pool.tokens[pool.zipf.sample(rng)]
            }
        })
        .collect()
}

impl Corpus {
    pub fn generate(cfg: &CorpusConfig) -> Self {
        assert!(cfg.vocab > cfg.reserved + COMMON_POOL + TOPIC_POOL,
                "vocab too small for pools");
        let mut rng = Rng::new(cfg.seed);

        // Common pool: the most "frequent" ids right above the reserved ones.
        let common_pool: Vec<u32> =
            (cfg.reserved as u32..(cfg.reserved + COMMON_POOL) as u32).collect();
        let content_lo = cfg.reserved + COMMON_POOL;

        // Topic pools: deterministic per-topic subsets of the content range.
        let mut topic_pools = Vec::with_capacity(cfg.n_topics);
        for t in 0..cfg.n_topics {
            let mut trng = rng.fork(t as u64 + 1);
            let tokens: Vec<u32> = (0..TOPIC_POOL)
                .map(|_| trng.gen_range_in(content_lo, cfg.vocab) as u32)
                .collect();
            topic_pools.push(TopicPool {
                tokens,
                zipf: Zipf::new(TOPIC_POOL, cfg.token_skew),
            });
        }
        let common_zipf = Zipf::new(COMMON_POOL, 1.2);

        let mut docs = Vec::with_capacity(cfg.n_docs);
        for id in 0..cfg.n_docs {
            let mut drng = rng.fork(0x1000_0000 + id as u64);
            let topic = drng.gen_range(cfg.n_topics) as u32;
            let len = drng.length(cfg.doc_len.0, cfg.doc_len.1);
            let pool = &topic_pools[topic as usize];
            let tokens = sample_tokens(pool, &common_pool, &common_zipf,
                                       len, &mut drng);
            docs.push(Document { id: id as u32, topic, tokens });
        }

        Self {
            docs,
            vocab: cfg.vocab,
            n_topics: cfg.n_topics,
            topic_pools,
            common_pool,
        }
    }

    pub fn len(&self) -> usize {
        self.docs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.docs.is_empty()
    }

    pub fn doc(&self, id: u32) -> &Document {
        &self.docs[id as usize]
    }

    /// Sample `n` tokens "about" a topic (question phrasing).
    pub fn topic_tokens(&self, topic: u32, n: usize, rng: &mut Rng) -> Vec<u32> {
        let pool = &self.topic_pools[topic as usize];
        (0..n)
            .map(|_| {
                if rng.next_f64() < 0.15 {
                    self.common_pool[rng.gen_range(self.common_pool.len())]
                } else {
                    pool.tokens[pool.zipf.sample(rng)]
                }
            })
            .collect()
    }

    /// Append freshly ingested documents (live knowledge-base updates).
    /// Ids must continue the corpus' contiguous id space — the retrieval
    /// layer's doc-id ↔ row-index correspondence depends on it.
    pub fn append(&mut self, docs: Vec<Document>) {
        for d in docs {
            assert_eq!(d.id as usize, self.docs.len(),
                       "ingested doc ids must be contiguous");
            assert!(d.tokens.iter().all(|&t| (t as usize) < self.vocab),
                    "ingested doc uses tokens outside the corpus vocab");
            self.docs.push(d);
        }
    }

    /// Synthesize `count` fresh documents for the ingest stream, ids
    /// starting at `start_id`, drawn from the same topic/common pools as
    /// the build-time generator. Deterministic in (`seed`, id) — two
    /// writers replaying the same stream produce byte-identical docs —
    /// but an independent RNG stream from `generate`'s, so ingested docs
    /// are new material, not replays of build-time ones.
    pub fn synth_docs(&self, seed: u64, start_id: u32, count: usize,
                      doc_len: (usize, usize)) -> Vec<Document> {
        let common_zipf = Zipf::new(COMMON_POOL, 1.2);
        (0..count)
            .map(|i| {
                let id = start_id + i as u32;
                let mut drng =
                    Rng::new(seed ^ ((id as u64 + 1) * 0x9E37_79B9_7F4A_7C15));
                let topic = drng.gen_range(self.n_topics) as u32;
                let len = drng.length(doc_len.0, doc_len.1);
                let pool = &self.topic_pools[topic as usize];
                let tokens = sample_tokens(pool, &self.common_pool,
                                           &common_zipf, len, &mut drng);
                Document { id, topic, tokens }
            })
            .collect()
    }

    /// Average document length in tokens (BM25 needs this).
    pub fn avg_doc_len(&self) -> f64 {
        if self.docs.is_empty() {
            return 0.0;
        }
        self.docs.iter().map(|d| d.tokens.len()).sum::<usize>() as f64
            / self.docs.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> CorpusConfig {
        CorpusConfig { n_docs: 500, n_topics: 16, doc_len: (20, 60),
                       ..CorpusConfig::default() }
    }

    #[test]
    fn generation_is_deterministic() {
        let cfg = small_cfg();
        let a = Corpus::generate(&cfg);
        let b = Corpus::generate(&cfg);
        assert_eq!(a.len(), b.len());
        for (da, db) in a.docs.iter().zip(&b.docs) {
            assert_eq!(da.tokens, db.tokens);
            assert_eq!(da.topic, db.topic);
        }
    }

    #[test]
    fn doc_lengths_in_range() {
        let cfg = small_cfg();
        let c = Corpus::generate(&cfg);
        for d in &c.docs {
            assert!(d.tokens.len() >= cfg.doc_len.0);
            assert!(d.tokens.len() <= cfg.doc_len.1);
        }
    }

    #[test]
    fn tokens_avoid_reserved_range() {
        let cfg = small_cfg();
        let c = Corpus::generate(&cfg);
        for d in &c.docs {
            for &t in &d.tokens {
                assert!(t >= cfg.reserved as u32);
                assert!((t as usize) < cfg.vocab);
            }
        }
    }

    #[test]
    fn same_topic_docs_share_vocabulary() {
        let cfg = small_cfg();
        let c = Corpus::generate(&cfg);
        // Find two docs with the same topic and two with different topics;
        // same-topic overlap (Jaccard) should exceed cross-topic overlap.
        let overlap = |a: &Document, b: &Document| {
            let sa: std::collections::HashSet<u32> =
                a.tokens.iter().copied().collect();
            let sb: std::collections::HashSet<u32> =
                b.tokens.iter().copied().collect();
            let inter = sa.intersection(&sb).count() as f64;
            inter / (sa.len().min(sb.len()) as f64)
        };
        let d0 = &c.docs[0];
        let same = c.docs.iter().find(|d| d.id != d0.id && d.topic == d0.topic);
        let diff = c.docs.iter().find(|d| d.topic != d0.topic).unwrap();
        if let Some(same) = same {
            assert!(overlap(d0, same) > overlap(d0, diff),
                    "same-topic docs should overlap more");
        }
    }

    #[test]
    fn topic_tokens_deterministic_given_rng() {
        let cfg = small_cfg();
        let c = Corpus::generate(&cfg);
        let a = c.topic_tokens(3, 10, &mut Rng::new(5));
        let b = c.topic_tokens(3, 10, &mut Rng::new(5));
        assert_eq!(a, b);
    }

    #[test]
    fn synth_docs_deterministic_contiguous_and_in_vocab() {
        let cfg = small_cfg();
        let c = Corpus::generate(&cfg);
        let a = c.synth_docs(42, c.len() as u32, 10, (20, 60));
        let b = c.synth_docs(42, c.len() as u32, 10, (20, 60));
        assert_eq!(a.len(), 10);
        for (da, db) in a.iter().zip(&b) {
            assert_eq!(da.id, db.id);
            assert_eq!(da.tokens, db.tokens);
        }
        for (i, d) in a.iter().enumerate() {
            assert_eq!(d.id as usize, c.len() + i);
            assert!(d.tokens.len() >= 20 && d.tokens.len() <= 60);
            for &t in &d.tokens {
                assert!(t >= cfg.reserved as u32
                        && (t as usize) < cfg.vocab);
            }
        }
    }

    #[test]
    fn append_grows_and_preserves_existing_docs() {
        let cfg = small_cfg();
        let mut c = Corpus::generate(&cfg);
        let before = c.doc(3).tokens.clone();
        let n = c.len();
        let fresh = c.synth_docs(7, n as u32, 5, (20, 60));
        let expect_first = fresh[0].tokens.clone();
        c.append(fresh);
        assert_eq!(c.len(), n + 5);
        assert_eq!(c.doc(3).tokens, before, "existing docs never mutate");
        assert_eq!(c.doc(n as u32).tokens, expect_first);
    }

    #[test]
    #[should_panic(expected = "contiguous")]
    fn append_rejects_id_gaps() {
        let cfg = small_cfg();
        let mut c = Corpus::generate(&cfg);
        let bad = Document { id: c.len() as u32 + 1, topic: 0,
                             tokens: vec![100] };
        c.append(vec![bad]);
    }

    #[test]
    fn avg_doc_len_sane() {
        let cfg = small_cfg();
        let c = Corpus::generate(&cfg);
        let avg = c.avg_doc_len();
        assert!(avg >= cfg.doc_len.0 as f64 && avg <= cfg.doc_len.1 as f64);
    }
}
