//! Synthetic WikiText-103 stand-in: a long token stream of topic "articles"
//! used to build the KNN-LM datastore (one entry per stream position).
//!
//! Spatial locality — the property KNN-LM speculation exploits with its
//! next-n cache-update rule (§5.3) — holds by construction: consecutive
//! positions belong to the same article/topic run.

use crate::config::CorpusConfig;
use crate::util::{Rng, Zipf};

/// A token stream segmented into articles.
#[derive(Debug, Clone)]
pub struct TokenStream {
    pub tokens: Vec<u32>,
    /// (start, topic) per article, sorted by start.
    pub articles: Vec<(usize, u32)>,
}

/// Generate a stream of at least `min_tokens` tokens. Articles are
/// 120–600-token runs of a single topic's vocabulary (same pools as the
/// QA corpus so the LM sees one distribution).
pub fn generate_stream(cfg: &CorpusConfig, min_tokens: usize, seed: u64)
                       -> TokenStream {
    let mut rng = Rng::new(seed ^ 0x5EED_57EE);
    let topic_zipf = Zipf::new(cfg.n_topics, 1.05);
    let content_lo = cfg.reserved + 64; // matches corpus common-pool layout
    let token_zipf = Zipf::new(192, cfg.token_skew);

    let mut tokens = Vec::with_capacity(min_tokens + 600);
    let mut articles = Vec::new();
    while tokens.len() < min_tokens {
        let topic = topic_zipf.sample(&mut rng) as u32;
        articles.push((tokens.len(), topic));
        let len = rng.gen_range_in(120, 600);
        // Rebuild the topic pool deterministically (same scheme as Corpus).
        let mut trng = Rng::new(cfg.seed);
        let mut pool_rng = trng.fork(topic as u64 + 1);
        let pool: Vec<u32> = (0..192)
            .map(|_| pool_rng.gen_range_in(content_lo, cfg.vocab) as u32)
            .collect();
        for _ in 0..len {
            if rng.next_f64() < 0.25 {
                tokens.push((cfg.reserved + rng.gen_range(64)) as u32);
            } else {
                tokens.push(pool[token_zipf.sample(&mut rng)]);
            }
        }
    }
    TokenStream { tokens, articles }
}

impl TokenStream {
    /// Topic of the article containing position `pos`.
    pub fn topic_at(&self, pos: usize) -> u32 {
        match self.articles.binary_search_by_key(&pos, |(s, _)| *s) {
            Ok(i) => self.articles[i].1,
            Err(i) => self.articles[i.saturating_sub(1)].1,
        }
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CorpusConfig;

    #[test]
    fn stream_is_deterministic_and_long_enough() {
        let cfg = CorpusConfig::default();
        let a = generate_stream(&cfg, 5_000, 1);
        let b = generate_stream(&cfg, 5_000, 1);
        assert_eq!(a.tokens, b.tokens);
        assert!(a.tokens.len() >= 5_000);
    }

    #[test]
    fn article_runs_are_contiguous() {
        let cfg = CorpusConfig::default();
        let s = generate_stream(&cfg, 3_000, 2);
        assert!(!s.articles.is_empty());
        for w in s.articles.windows(2) {
            assert!(w[0].0 < w[1].0);
        }
        // topic_at resolves inside each run
        for &(start, topic) in &s.articles {
            assert_eq!(s.topic_at(start), topic);
            assert_eq!(s.topic_at(start + 1), topic);
        }
    }

    #[test]
    fn tokens_in_vocab() {
        let cfg = CorpusConfig::default();
        let s = generate_stream(&cfg, 2_000, 3);
        for &t in &s.tokens {
            assert!((t as usize) < cfg.vocab);
            assert!(t >= cfg.reserved as u32);
        }
    }

    #[test]
    fn consecutive_positions_share_topic_mostly() {
        let cfg = CorpusConfig::default();
        let s = generate_stream(&cfg, 4_000, 4);
        let same = (1..s.len())
            .filter(|&i| s.topic_at(i) == s.topic_at(i - 1))
            .count();
        assert!(same as f64 / (s.len() - 1) as f64 > 0.95,
                "spatial locality of the stream");
    }
}
