//! Encoder abstraction: token window -> unit retrieval vector.
//!
//! Two implementations exist:
//!   * [`HashEncoder`] (here): pure-Rust deterministic bag-of-words encoder.
//!     Each token id maps to a fixed pseudo-random unit vector; a window
//!     encodes to the normalized mean. Used by unit/property tests and the
//!     synthetic-embedding path so the retrieval stack is testable without
//!     AOT artifacts.
//!   * `runtime::PjrtEncoder`: the real AOT `encode_q` / `encode_batch`
//!     artifacts (the L2 JAX encoder). Same trait, same geometry (mean of
//!     per-token embeddings -> MLP -> normalize), so locality behaves the
//!     same way in both modes.

use crate::util::Rng;

/// Maps a token window to a unit-norm embedding of dimension `dim()`.
///
/// Deliberately NOT Send/Sync: the PJRT-backed implementation holds raw
/// device handles. Encoding happens on the pipeline thread; only the
/// retriever (plain data, Sync) crosses into the async-verification thread.
pub trait Encoder {
    fn dim(&self) -> usize;

    /// Encode one window (uses at most the encoder's native window length).
    fn encode(&self, tokens: &[u32]) -> Vec<f32>;

    /// Batched encode; default = sequential.
    fn encode_batch(&self, windows: &[&[u32]]) -> Vec<Vec<f32>> {
        windows.iter().map(|w| self.encode(w)).collect()
    }

    /// Native window length (tokens beyond this are truncated from the
    /// *front* — queries keep the most recent context).
    fn window(&self) -> usize {
        32
    }
}

/// Deterministic hash-based bag-of-words encoder.
#[derive(Debug, Clone)]
pub struct HashEncoder {
    dim: usize,
    seed: u64,
    window: usize,
}

impl HashEncoder {
    pub fn new(dim: usize, seed: u64) -> Self {
        Self { dim, seed, window: 32 }
    }

    fn token_vec(&self, token: u32) -> Vec<f32> {
        let mut rng = Rng::new(self.seed ^ ((token as u64 + 1) * 0x9E3779B9));
        rng.unit_vector(self.dim)
    }
}

impl Encoder for HashEncoder {
    fn dim(&self) -> usize {
        self.dim
    }

    fn window(&self) -> usize {
        self.window
    }

    fn encode(&self, tokens: &[u32]) -> Vec<f32> {
        let start = tokens.len().saturating_sub(self.window);
        let window = &tokens[start..];
        let mut acc = vec![0.0f32; self.dim];
        if window.is_empty() {
            acc[0] = 1.0;
            return acc;
        }
        for &t in window {
            let v = self.token_vec(t);
            for (a, x) in acc.iter_mut().zip(&v) {
                *a += x;
            }
        }
        let norm = acc.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-9);
        for a in &mut acc {
            *a /= norm;
        }
        acc
    }
}

/// Embed one document exactly as [`embed_corpus`] embeds a row (first
/// `window` tokens, like a passage encoder) — the ingest path
/// (`retriever::epoch::KbWriter`) uses this so a live-appended embedding
/// row is byte-identical to what a from-scratch `embed_corpus` over the
/// extended corpus would produce.
pub fn embed_doc(enc: &dyn Encoder,
                 doc: &crate::datagen::corpus::Document) -> Vec<f32> {
    enc.encode(&doc.tokens[..doc.tokens.len().min(enc.window())])
}

/// Embed every corpus document (first `window` tokens, like a passage
/// encoder). Returns a row-major [n_docs, dim] matrix.
pub fn embed_corpus(enc: &dyn Encoder,
                    corpus: &crate::datagen::corpus::Corpus) -> Vec<f32> {
    let dim = enc.dim();
    let mut out = vec![0.0f32; corpus.len() * dim];
    let windows: Vec<&[u32]> = corpus
        .iter()
        .map(|d| &d.tokens[..d.tokens.len().min(enc.window())])
        .collect();
    // Chunked batches keep the PJRT encoder's fixed batch shape busy.
    for (chunk_i, chunk) in windows.chunks(256).enumerate() {
        let vecs = enc.encode_batch(chunk);
        for (j, v) in vecs.into_iter().enumerate() {
            let row = chunk_i * 256 + j;
            out[row * dim..(row + 1) * dim].copy_from_slice(&v);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_is_deterministic_and_normalized() {
        let e = HashEncoder::new(64, 9);
        let a = e.encode(&[5, 6, 7, 8]);
        let b = e.encode(&[5, 6, 7, 8]);
        assert_eq!(a, b);
        let norm: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((norm - 1.0).abs() < 1e-4);
    }

    #[test]
    fn windows_truncate_from_front() {
        let e = HashEncoder::new(16, 9);
        let long: Vec<u32> = (0..100).collect();
        let tail: Vec<u32> = (68..100).collect();
        assert_eq!(e.encode(&long), e.encode(&tail));
    }

    #[test]
    fn similar_windows_are_close() {
        let e = HashEncoder::new(64, 9);
        let base: Vec<u32> = (10..42).collect();
        let mut shifted = base.clone();
        shifted.rotate_left(1);
        shifted[31] = 999; // one token differs
        let (a, b) = (e.encode(&base), e.encode(&shifted));
        let cos: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!(cos > 0.9, "1-token change should keep cosine high: {cos}");
        let unrelated: Vec<u32> = (2000..2032).collect();
        let c = e.encode(&unrelated);
        let cos2: f32 = a.iter().zip(&c).map(|(x, y)| x * y).sum();
        assert!(cos2 < cos, "unrelated window should be farther");
    }

    #[test]
    fn empty_window_is_safe() {
        let e = HashEncoder::new(8, 1);
        let v = e.encode(&[]);
        assert_eq!(v.len(), 8);
        assert!(v.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn embed_corpus_shapes_and_clustering() {
        use crate::config::CorpusConfig;
        use crate::datagen::corpus::Corpus;
        let cfg = CorpusConfig { n_docs: 300, n_topics: 6,
                                 ..CorpusConfig::default() };
        let corpus = Corpus::generate(&cfg);
        let enc = HashEncoder::new(32, 4);
        let emb = embed_corpus(&enc, &corpus);
        assert_eq!(emb.len(), 300 * 32);
        // same-topic docs should on average be closer than cross-topic
        let row = |i: usize| &emb[i * 32..(i + 1) * 32];
        let cos = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| x * y).sum()
        };
        let mut same = vec![];
        let mut cross = vec![];
        for i in 0..60 {
            for j in (i + 1)..60 {
                let c = cos(row(i), row(j));
                if corpus.doc(i as u32).topic == corpus.doc(j as u32).topic {
                    same.push(c);
                } else {
                    cross.push(c);
                }
            }
        }
        if !same.is_empty() {
            let ms = same.iter().sum::<f32>() / same.len() as f32;
            let mc = cross.iter().sum::<f32>() / cross.len() as f32;
            assert!(ms > mc, "topic clustering expected: same={ms} cross={mc}");
        }
    }
}
