//! Synthetic QA workloads standing in for the paper's four datasets
//! (Wiki-QA, Web Questions, Natural Questions, Trivia-QA).
//!
//! In the paper the four datasets act as repeated trials with slightly
//! different question statistics; speedups are similar across them. We
//! preserve that role: each preset differs in question length and topic
//! popularity skew (DESIGN.md §2).

use crate::datagen::corpus::Corpus;
use crate::util::{Rng, Zipf};

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dataset {
    WikiQa,
    WebQ,
    Nq,
    TriviaQa,
}

impl Dataset {
    pub fn all() -> [Dataset; 4] {
        [Dataset::WikiQa, Dataset::WebQ, Dataset::Nq, Dataset::TriviaQa]
    }

    pub fn label(&self) -> &'static str {
        match self {
            Dataset::WikiQa => "WikiQA",
            Dataset::WebQ => "WQ",
            Dataset::Nq => "NQ",
            Dataset::TriviaQa => "TriviaQA",
        }
    }

    /// (min_len, max_len, topic_skew, seed_salt)
    fn params(&self) -> (usize, usize, f64, u64) {
        match self {
            Dataset::WikiQa => (6, 12, 1.10, 0x11),
            Dataset::WebQ => (4, 9, 1.30, 0x22),
            Dataset::Nq => (8, 16, 1.00, 0x33),
            Dataset::TriviaQa => (10, 20, 0.90, 0x44),
        }
    }
}

impl std::str::FromStr for Dataset {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "wikiqa" | "wiki-qa" | "wiki_qa" => Ok(Dataset::WikiQa),
            "webq" | "wq" | "webquestions" => Ok(Dataset::WebQ),
            "nq" | "naturalquestions" => Ok(Dataset::Nq),
            "triviaqa" | "trivia-qa" | "trivia_qa" => Ok(Dataset::TriviaQa),
            other => Err(anyhow::anyhow!("unknown dataset: {other}")),
        }
    }
}

/// One serving request: a question (token ids) about a latent topic.
#[derive(Debug, Clone)]
pub struct Question {
    pub id: u64,
    pub dataset: Dataset,
    pub topic: u32,
    pub tokens: Vec<u32>,
}

/// Generate `n` questions for a dataset over a corpus. Deterministic in
/// (dataset, corpus topics, seed).
pub fn generate_questions(dataset: Dataset, corpus: &Corpus, n: usize,
                          seed: u64) -> Vec<Question> {
    let (lo, hi, skew, salt) = dataset.params();
    let mut rng = Rng::new(seed ^ (salt << 32));
    let topic_zipf = Zipf::new(corpus.n_topics, skew);
    // Deterministic topic permutation so "popular" topics differ by dataset.
    let mut perm: Vec<u32> = (0..corpus.n_topics as u32).collect();
    for i in (1..perm.len()).rev() {
        let j = rng.gen_range(i + 1);
        perm.swap(i, j);
    }
    (0..n)
        .map(|i| {
            let mut qrng = rng.fork(i as u64);
            let topic = perm[topic_zipf.sample(&mut qrng)];
            let len = qrng.gen_range_in(lo, hi + 1);
            let tokens = corpus.topic_tokens(topic, len, &mut qrng);
            Question { id: i as u64, dataset, topic, tokens }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CorpusConfig;

    fn corpus() -> Corpus {
        Corpus::generate(&CorpusConfig {
            n_docs: 200, n_topics: 16, ..CorpusConfig::default()
        })
    }

    #[test]
    fn deterministic_per_seed() {
        let c = corpus();
        let a = generate_questions(Dataset::WikiQa, &c, 10, 42);
        let b = generate_questions(Dataset::WikiQa, &c, 10, 42);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.tokens, y.tokens);
            assert_eq!(x.topic, y.topic);
        }
        let c2 = generate_questions(Dataset::WikiQa, &c, 10, 43);
        assert!(a.iter().zip(&c2).any(|(x, y)| x.tokens != y.tokens));
    }

    #[test]
    fn lengths_respect_preset() {
        let c = corpus();
        for ds in Dataset::all() {
            let (lo, hi, _, _) = ds.params();
            for q in generate_questions(ds, &c, 50, 7) {
                assert!(q.tokens.len() >= lo && q.tokens.len() <= hi,
                        "{ds:?} len {}", q.tokens.len());
            }
        }
    }

    #[test]
    fn datasets_differ() {
        let c = corpus();
        let a = generate_questions(Dataset::WikiQa, &c, 20, 7);
        let b = generate_questions(Dataset::TriviaQa, &c, 20, 7);
        assert!(a.iter().zip(&b).any(|(x, y)| x.tokens != y.tokens));
    }

    #[test]
    fn topics_in_range() {
        let c = corpus();
        for q in generate_questions(Dataset::Nq, &c, 100, 3) {
            assert!((q.topic as usize) < c.n_topics);
        }
    }

    #[test]
    fn parse_labels() {
        assert_eq!("wikiqa".parse::<Dataset>().unwrap(), Dataset::WikiQa);
        assert_eq!("WQ".parse::<Dataset>().unwrap(), Dataset::WebQ);
        assert!("bogus".parse::<Dataset>().is_err());
    }
}
