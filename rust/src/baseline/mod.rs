//! RaLMSeq — the naive iterative RaLM serving baseline (Ram et al. 2023,
//! as implemented in the paper §5.1): retrieve from the knowledge base with
//! the latest context every `gen_stride` (=4) generated tokens; the latest
//! retrieved chunk replaces the previous document prefix.
//!
//! Structured identically to the speculative pipeline's *verified* path so
//! output equivalence is provable step by step: same query construction,
//! same top-1 selection, same document conditioning, same greedy decoding.
//! `Retriever::retrieve` here derives from the batch-first primitive (a
//! batch of one), so the baseline's scores share the speculative
//! verification's numeric path bit-for-bit — the foundation of the
//! equivalence proof.

use crate::datagen::Corpus;
use crate::lm::{GenState, LanguageModel};
use crate::metrics::{timed, EventKind, ReqMetrics, Stopwatch};
use crate::retriever::Retriever;
use crate::spec::query::QueryBuilder;

#[derive(Debug, Clone)]
pub struct BaselineOptions {
    pub gen_stride: usize,
    pub max_new: usize,
    pub max_doc_tokens: usize,
}

impl Default for BaselineOptions {
    fn default() -> Self {
        let c = crate::config::SpecConfig::default();
        Self {
            gen_stride: c.gen_stride,
            max_new: c.max_new_tokens,
            max_doc_tokens: c.max_doc_tokens,
        }
    }
}

pub struct RalmSeq<'a, L: LanguageModel> {
    pub lm: &'a L,
    pub kb: &'a dyn Retriever,
    pub corpus: &'a Corpus,
    pub queries: QueryBuilder<'a>,
    pub opts: BaselineOptions,
}

impl<'a, L: LanguageModel> RalmSeq<'a, L> {
    pub fn run(&self, question: &[u32]) -> anyhow::Result<ReqMetrics> {
        let total = Stopwatch::start();
        let mut m = ReqMetrics::default();

        // Initial retrieval from the question alone. Query construction
        // (the dense-encoder call) is "E", not "R" — see metrics docs.
        let q0 = timed(&mut m.encode,
                       || self.queries.build_from_window(question));
        let top0 = timed(&mut m.retrieve, || self.kb.retrieve(&q0));
        m.kb_calls += 1;
        m.kb_queries += 1;
        let doc0 = top0.ok_or_else(|| anyhow::anyhow!("empty knowledge base"))?;

        let prefill_t = Stopwatch::start();
        let mut state = timed(&mut m.generate, || {
            GenState::new(self.lm, Some(doc0.id),
                          &self.corpus.doc(doc0.id).tokens, question,
                          self.opts.max_doc_tokens, self.opts.max_new)
        })?;
        m.prefills += 1;
        m.event(EventKind::Prefill, &total, prefill_t.elapsed());

        while !state.done {
            // Retrieve with the latest context, swap the document prefix...
            let r_t = Stopwatch::start();
            let q = timed(&mut m.encode, || self.queries.build(&state));
            let d = timed(&mut m.retrieve, || self.kb.retrieve(&q))
                .ok_or_else(|| anyhow::anyhow!("empty knowledge base"))?;
            m.kb_calls += 1;
            m.kb_queries += 1;
            m.event(EventKind::Verify, &total, r_t.elapsed());
            let g_t = Stopwatch::start();
            timed(&mut m.generate, || -> anyhow::Result<()> {
                if state.set_doc(self.lm, d.id,
                                 &self.corpus.doc(d.id).tokens)? {
                    m.prefills += 1;
                }
                // ...then generate the next interval of tokens.
                state.generate(self.lm, self.opts.gen_stride)?;
                Ok(())
            })?;
            m.event(EventKind::SpecStep, &total, g_t.elapsed());
        }

        m.tokens_out = state.generated.clone();
        m.decode_tokens = state.generated.len() as u32;
        m.total = total.elapsed();
        Ok(m)
    }
}
