//! Deterministic mock LM: a hash-chain "transformer" for artifact-free
//! tests.
//!
//! Logits are a pure function of the full token context (FNV-1a hash ->
//! xoshiro stream), so the mock honours the property the equivalence proofs
//! rely on: *identical context => identical logits*, regardless of how the
//! context was reached (prefill, decode, or rollback + replay). qproj is the
//! HashEncoder embedding of the context tail, so mock KNN-LM datastores and
//! queries live in one consistent space.
//!
//! Optional artificial per-call latencies let OS³ / async-verification
//! tests shape the a-vs-b trade-off deterministically.

use super::LanguageModel;
use crate::datagen::{Encoder, HashEncoder};
use crate::util::Rng;
use std::rc::Rc;
use std::time::Duration;

#[derive(Debug, Clone)]
pub struct MockState {
    tokens: Rc<Vec<u32>>,
    logits: Rc<Vec<f32>>,
    qproj: Rc<Vec<f32>>,
}

pub struct MockLm {
    vocab: usize,
    max_ctx: usize,
    seed: u64,
    encoder: HashEncoder,
    /// Artificial latencies (zero by default).
    pub decode_delay: Duration,
    pub prefill_delay: Duration,
    /// Bias strength toward repeating context tokens; higher values make
    /// generation stay "on topic", raising retrieval locality (used to
    /// shape speculation-accuracy scenarios in tests).
    pub repeat_bias: f32,
}

impl MockLm {
    pub fn new(vocab: usize, max_ctx: usize, seed: u64) -> Self {
        Self {
            vocab,
            max_ctx,
            seed,
            encoder: HashEncoder::new(crate::runtime::RETRIEVAL_DIM, seed ^ 0xE)
,
            decode_delay: Duration::ZERO,
            prefill_delay: Duration::ZERO,
            repeat_bias: 2.0,
        }
    }

    pub fn with_delays(mut self, prefill: Duration, decode: Duration) -> Self {
        self.prefill_delay = prefill;
        self.decode_delay = decode;
        self
    }

    pub fn with_repeat_bias(mut self, bias: f32) -> Self {
        self.repeat_bias = bias;
        self
    }

    fn hash(&self, tokens: &[u32]) -> u64 {
        let mut h = 0xcbf29ce484222325u64 ^ self.seed;
        for &t in tokens {
            h ^= t as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        h
    }

    fn state_for(&self, tokens: Vec<u32>) -> MockState {
        // detlint: allow(nondet-source, reason = "seeded by a pure hash of (seed, tokens): same context always yields the same logits")
        let mut rng = Rng::new(self.hash(&tokens));
        let mut logits: Vec<f32> =
            (0..self.vocab).map(|_| rng.next_f32() * 4.0 - 2.0).collect();
        // Make EOS unlikely but possible; PAD never wins.
        logits[super::PAD as usize] = -10.0;
        logits[super::EOS as usize] -= 1.5;
        // Bias toward recent context tokens => topical continuation =>
        // temporal locality of retrieval, like a real LM.
        let tail_start = tokens.len().saturating_sub(48);
        for &t in &tokens[tail_start..] {
            if t as usize > super::SEP as usize {
                logits[t as usize] += self.repeat_bias * 0.25;
            }
        }
        let qproj = self.encoder.encode(&tokens);
        MockState {
            tokens: Rc::new(tokens),
            logits: Rc::new(logits),
            qproj: Rc::new(qproj),
        }
    }
}

impl LanguageModel for MockLm {
    type State = MockState;

    fn max_ctx(&self) -> usize {
        self.max_ctx
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn prefill(&self, tokens: &[u32]) -> anyhow::Result<MockState> {
        if tokens.len() > self.max_ctx {
            anyhow::bail!("context {} exceeds max_ctx {}", tokens.len(),
                        self.max_ctx);
        }
        if !self.prefill_delay.is_zero() {
            std::thread::sleep(self.prefill_delay);
        }
        Ok(self.state_for(tokens.to_vec()))
    }

    fn generate_greedy(&self, st: &MockState, k: usize)
                       -> anyhow::Result<(Vec<u32>, MockState)> {
        let mut cur = st.clone();
        let mut out = Vec::with_capacity(k);
        for _ in 0..k {
            if cur.tokens.len() >= self.max_ctx {
                break;
            }
            if !self.decode_delay.is_zero() {
                std::thread::sleep(self.decode_delay);
            }
            let next = super::greedy(&cur.logits);
            out.push(next);
            cur = self.append_token(&cur, next)?;
            if next == super::EOS {
                break;
            }
        }
        Ok((out, cur))
    }

    fn append_token(&self, st: &MockState, token: u32)
                    -> anyhow::Result<MockState> {
        if st.tokens.len() >= self.max_ctx {
            anyhow::bail!("context full");
        }
        let mut tokens = (*st.tokens).clone();
        tokens.push(token);
        Ok(self.state_for(tokens))
    }

    fn logits<'a>(&self, st: &'a MockState) -> &'a [f32] {
        &st.logits
    }

    fn qproj<'a>(&self, st: &'a MockState) -> &'a [f32] {
        &st.qproj
    }

    fn pos(&self, st: &MockState) -> usize {
        st.tokens.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lm() -> MockLm {
        MockLm::new(256, 128, 42)
    }

    #[test]
    fn same_context_same_logits() {
        let m = lm();
        let a = m.prefill(&[3, 4, 5]).unwrap();
        let b = m.prefill(&[3, 4, 5]).unwrap();
        assert_eq!(*a.logits, *b.logits);
        assert_eq!(*a.qproj, *b.qproj);
    }

    #[test]
    fn prefill_then_append_equals_longer_prefill() {
        let m = lm();
        let a = m.prefill(&[3, 4, 5]).unwrap();
        let a2 = m.append_token(&a, 9).unwrap();
        let b = m.prefill(&[3, 4, 5, 9]).unwrap();
        assert_eq!(*a2.logits, *b.logits);
        assert_eq!(m.pos(&a2), 4);
    }

    #[test]
    fn greedy_generation_is_deterministic() {
        let m = lm();
        let st = m.prefill(&[10, 20, 30]).unwrap();
        let (t1, _) = m.generate_greedy(&st, 8).unwrap();
        let (t2, _) = m.generate_greedy(&st, 8).unwrap();
        assert_eq!(t1, t2);
        assert!(t1.len() <= 8);
        assert!(t1.iter().all(|&t| (t as usize) < 256 && t != super::super::PAD));
    }

    #[test]
    fn snapshot_rollback_via_clone() {
        let m = lm();
        let st = m.prefill(&[1, 2, 3]).unwrap();
        let snap = st.clone();
        let (_, advanced) = m.generate_greedy(&st, 4).unwrap();
        assert!(m.pos(&advanced) > m.pos(&snap));
        // replay from snapshot gives identical results
        let (t1, _) = m.generate_greedy(&snap, 4).unwrap();
        let (t2, _) = m.generate_greedy(&snap, 4).unwrap();
        assert_eq!(t1, t2);
    }

    #[test]
    fn context_limit_enforced() {
        let m = MockLm::new(64, 8, 1);
        assert!(m.prefill(&[0; 9]).is_err());
        let st = m.prefill(&[5; 8]).unwrap();
        let (toks, _) = m.generate_greedy(&st, 4).unwrap();
        assert!(toks.is_empty());
    }

    #[test]
    fn qproj_is_unit_norm() {
        let m = lm();
        let st = m.prefill(&[7, 8, 9, 10]).unwrap();
        let n: f32 = st.qproj.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((n - 1.0).abs() < 1e-4);
    }
}
