//! Language-model abstraction used by every pipeline.
//!
//! Two implementations share the [`LanguageModel`] trait:
//!   * `runtime::PjrtLm` — the real AOT artifacts executed via PJRT;
//!   * [`mock::MockLm`] — a deterministic hash-chain LM for fast unit,
//!     integration, and property tests (no artifacts required).
//!
//! States are cheap-to-clone handles (`Rc` around the KV literal / token
//! history); cloning a state is how the speculation pipeline snapshots for
//! rollback — an old handle stays valid because decode always produces a
//! *new* state.

pub mod mock;
pub mod state;

pub use mock::MockLm;
pub use state::GenState;

/// Reserved token ids (must match datagen::corpus).
pub const PAD: u32 = 0;
pub const EOS: u32 = 1;
pub const SEP: u32 = 2;

pub trait LanguageModel {
    /// Immutable per-position state handle. Clone = snapshot.
    type State: Clone;

    /// Maximum total context (prefill + decoded tokens).
    fn max_ctx(&self) -> usize;

    fn vocab(&self) -> usize;

    /// Process a full context; the returned state is positioned after the
    /// last token with next-token logits available.
    fn prefill(&self, tokens: &[u32]) -> anyhow::Result<Self::State>;

    /// Greedy-generate up to `k` tokens (stops early at EOS or context
    /// limit). Returns the generated tokens and the advanced state.
    fn generate_greedy(&self, st: &Self::State, k: usize)
                       -> anyhow::Result<(Vec<u32>, Self::State)>;

    /// Append one externally-chosen token (KNN-LM interpolation picks the
    /// token outside the LM). Returns the advanced state.
    fn append_token(&self, st: &Self::State, token: u32)
                    -> anyhow::Result<Self::State>;

    /// Next-token logits at this state (length = vocab).
    fn logits<'a>(&self, st: &'a Self::State) -> &'a [f32];

    /// Retrieval-space projection of the current hidden state (KNN-LM
    /// query vector), unit-norm, length = retrieval dim.
    fn qproj<'a>(&self, st: &'a Self::State) -> &'a [f32];

    /// Number of tokens currently in context.
    fn pos(&self, st: &Self::State) -> usize;
}

/// Deterministic greedy pick matching the in-graph `jnp.argmax` (ties ->
/// lowest id).
pub fn greedy(logits: &[f32]) -> u32 {
    crate::util::argmax(logits).unwrap_or(0) as u32
}
