//! Per-request generation state: document prefix + question + generated
//! tokens, with snapshot/rollback — the mutable substrate the speculation
//! pipeline drives.
//!
//! Context layout (naive iterative RaLM, Ram et al. 2023): the latest
//! retrieved document chunk is *prepended* and replaces the previous one,
//! so a document switch invalidates the KV cache and forces a re-prefill;
//! generating within an unchanged document proceeds incrementally. This is
//! exactly the G-cost structure the paper's baseline has.

use super::{LanguageModel, EOS, SEP};
use crate::retriever::DocId;

#[derive(Debug, Clone)]
pub struct GenState<S> {
    /// Current document id (None until first retrieval).
    pub doc_id: Option<DocId>,
    doc_tokens: Vec<u32>,
    question: Vec<u32>,
    pub generated: Vec<u32>,
    lm_state: S,
    pub done: bool,
    max_doc_tokens: usize,
    max_new: usize,
}

/// Rollback snapshot: cheap (LM states are Rc handles).
#[derive(Debug, Clone)]
pub struct Snapshot<S> {
    doc_id: Option<DocId>,
    doc_tokens: Vec<u32>,
    generated_len: usize,
    lm_state: S,
    done: bool,
}

impl<S: Clone> GenState<S> {
    /// Prefill the initial context (doc may be empty before the first
    /// retrieval).
    pub fn new<L: LanguageModel<State = S>>(
        lm: &L, doc_id: Option<DocId>, doc_tokens: &[u32], question: &[u32],
        max_doc_tokens: usize, max_new: usize) -> anyhow::Result<Self> {
        let doc_tokens: Vec<u32> =
            doc_tokens.iter().copied().take(max_doc_tokens).collect();
        let mut st = Self {
            doc_id,
            doc_tokens,
            question: question.to_vec(),
            generated: Vec::new(),
            lm_state: lm.prefill(&[])?, // replaced below
            done: false,
            max_doc_tokens,
            max_new,
        };
        st.lm_state = lm.prefill(&st.context())?;
        Ok(st)
    }

    /// Full token context in prompt order.
    pub fn context(&self) -> Vec<u32> {
        let mut ctx = Vec::with_capacity(
            self.doc_tokens.len() + self.question.len() + self.generated.len()
                + 2,
        );
        ctx.extend_from_slice(&self.doc_tokens);
        ctx.push(SEP);
        ctx.extend_from_slice(&self.question);
        ctx.push(SEP);
        ctx.extend_from_slice(&self.generated);
        ctx
    }

    /// Tokens available as retrieval-query context (question + generated;
    /// the query should describe the information need, not the stale doc).
    pub fn query_window(&self, n: usize) -> Vec<u32> {
        let mut w: Vec<u32> = Vec::with_capacity(
            self.question.len() + self.generated.len());
        w.extend_from_slice(&self.question);
        w.extend_from_slice(&self.generated);
        let start = w.len().saturating_sub(n);
        w.split_off(start)
    }

    /// Switch to a new document. Returns true (and re-prefills) on change.
    pub fn set_doc<L: LanguageModel<State = S>>(
        &mut self, lm: &L, doc_id: DocId, doc_tokens: &[u32])
        -> anyhow::Result<bool> {
        if self.doc_id == Some(doc_id) {
            return Ok(false);
        }
        self.doc_id = Some(doc_id);
        self.doc_tokens =
            doc_tokens.iter().copied().take(self.max_doc_tokens).collect();
        self.lm_state = lm.prefill(&self.context())?;
        Ok(true)
    }

    /// Greedy-generate up to k tokens (caps at max_new; sets `done` on EOS
    /// or budget exhaustion). Returns how many tokens were added.
    pub fn generate<L: LanguageModel<State = S>>(&mut self, lm: &L, k: usize)
                                                 -> anyhow::Result<usize> {
        if self.done {
            return Ok(0);
        }
        let budget = self.max_new.saturating_sub(self.generated.len());
        let room = lm.max_ctx().saturating_sub(lm.pos(&self.lm_state));
        let k = k.min(budget).min(room);
        if k == 0 {
            self.done = true;
            return Ok(0);
        }
        let (tokens, new_state) = lm.generate_greedy(&self.lm_state, k)?;
        self.lm_state = new_state;
        let n = tokens.len();
        for t in tokens {
            self.generated.push(t);
            if t == EOS {
                self.done = true;
            }
        }
        if self.generated.len() >= self.max_new
            || lm.pos(&self.lm_state) >= lm.max_ctx()
        {
            self.done = true;
        }
        Ok(n)
    }

    pub fn lm_state(&self) -> &S {
        &self.lm_state
    }

    /// Replace the LM state (KNN-LM appends tokens it chose itself).
    pub fn push_token<L: LanguageModel<State = S>>(
        &mut self, lm: &L, token: u32) -> anyhow::Result<()> {
        if self.done {
            return Ok(());
        }
        if lm.pos(&self.lm_state) >= lm.max_ctx() {
            self.done = true;
            return Ok(());
        }
        self.lm_state = lm.append_token(&self.lm_state, token)?;
        self.generated.push(token);
        if token == EOS || self.generated.len() >= self.max_new
            || lm.pos(&self.lm_state) >= lm.max_ctx()
        {
            self.done = true;
        }
        Ok(())
    }

    pub fn snapshot(&self) -> Snapshot<S> {
        Snapshot {
            doc_id: self.doc_id,
            doc_tokens: self.doc_tokens.clone(),
            generated_len: self.generated.len(),
            lm_state: self.lm_state.clone(),
            done: self.done,
        }
    }

    /// Restore to a snapshot (mis-speculation rollback). Generated tokens
    /// after the snapshot are discarded; returns how many were discarded.
    pub fn rollback(&mut self, snap: &Snapshot<S>) -> usize {
        let wasted = self.generated.len().saturating_sub(snap.generated_len);
        self.doc_id = snap.doc_id;
        self.doc_tokens = snap.doc_tokens.clone();
        self.generated.truncate(snap.generated_len);
        self.lm_state = snap.lm_state.clone();
        self.done = snap.done;
        wasted
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lm::MockLm;

    fn lm() -> MockLm {
        MockLm::new(256, 200, 7)
    }

    fn state(lm: &MockLm) -> GenState<crate::lm::mock::MockState> {
        GenState::new(lm, Some(0), &[50, 51, 52], &[60, 61], 16, 24).unwrap()
    }

    #[test]
    fn context_layout() {
        let m = lm();
        let st = state(&m);
        let ctx = st.context();
        assert_eq!(&ctx[..3], &[50, 51, 52]);
        assert_eq!(ctx[3], SEP);
        assert_eq!(&ctx[4..6], &[60, 61]);
        assert_eq!(ctx[6], SEP);
    }

    #[test]
    fn doc_truncated_to_max() {
        let m = lm();
        let long: Vec<u32> = (100..180).collect();
        let st = GenState::new(&m, Some(1), &long, &[5], 16, 8).unwrap();
        assert_eq!(st.context().iter().take_while(|&&t| t != SEP).count(), 16);
    }

    #[test]
    fn set_doc_same_id_is_noop() {
        let m = lm();
        let mut st = state(&m);
        assert!(!st.set_doc(&m, 0, &[99, 98]).unwrap());
        assert!(st.set_doc(&m, 3, &[99, 98]).unwrap());
        assert_eq!(st.doc_id, Some(3));
        let ctx = st.context();
        assert_eq!(&ctx[..2], &[99, 98]);
    }

    #[test]
    fn generate_respects_budget_and_done() {
        let m = lm();
        let mut st = state(&m);
        let mut total = 0;
        while !st.done {
            total += st.generate(&m, 4).unwrap();
        }
        assert!(total <= 24);
        assert_eq!(total, st.generated.len());
    }

    #[test]
    fn rollback_restores_everything() {
        let m = lm();
        let mut st = state(&m);
        st.generate(&m, 4).unwrap();
        let snap = st.snapshot();
        let before = (st.generated.clone(), st.doc_id, st.context());
        st.set_doc(&m, 9, &[70, 71]).unwrap();
        st.generate(&m, 4).unwrap();
        let wasted = st.rollback(&snap);
        assert_eq!(wasted, st.generated.len() + wasted - before.0.len());
        assert_eq!(st.generated, before.0);
        assert_eq!(st.doc_id, before.1);
        assert_eq!(st.context(), before.2);
    }

    #[test]
    fn rollback_then_replay_is_deterministic() {
        let m = lm();
        let mut st = state(&m);
        let snap = st.snapshot();
        st.generate(&m, 8).unwrap();
        let first = st.generated.clone();
        st.rollback(&snap);
        st.generate(&m, 8).unwrap();
        assert_eq!(st.generated, first);
    }

    #[test]
    fn query_window_takes_tail() {
        let m = lm();
        let mut st = state(&m);
        st.generate(&m, 8).unwrap();
        let w = st.query_window(4);
        assert_eq!(w.len(), 4);
        let gen_tail: Vec<u32> =
            st.generated[st.generated.len() - 4..].to_vec();
        assert_eq!(w, gen_tail);
        // window larger than available = question + generated
        let w2 = st.query_window(1000);
        assert_eq!(w2.len(), 2 + st.generated.len());
    }
}
