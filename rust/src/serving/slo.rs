//! SLO-aware adaptive flush control (DESIGN.md ADR-011): tune the
//! engine's coalescing policy — `max_batch`, `flush_us`, `kb_parallel` —
//! against a p99 latency target instead of fixed config.
//!
//! The controller is **replay-stable by construction**: it owns no clock
//! and no RNG. The engine feeds it each completed request's measured
//! total latency ([`AdaptiveFlush::observe`]); the plan it emits
//! ([`AdaptiveFlush::plan`]) is a pure function of the window contents,
//! so a replayed trace with the same observed latencies reproduces the
//! same knob trajectory. Per-request *outputs* never depend on the plan
//! at all — batch composition and flush timing are
//! schedule-not-semantics (the coalescing bit-identity argument of
//! ADR-003/ADR-005 covers every plan the controller can emit), which is
//! what makes an adaptive policy safe to ship inside the serving engine.
//!
//! Policy (deliberately simple, monotone, and clamped): while the
//! windowed p99 exceeds the target by a factor `f`, shrink the
//! coalescing window — `max_batch` and `flush_us` scale down by `f`
//! (bounded below by the configured minima) so requests stop paying
//! queueing delay for batching headroom that overload has already
//! consumed — and scale `kb_parallel` *up* by `f` (bounded by
//! `max_kb_parallel`) so the extra, smaller calls still overlap. At or
//! under target, the base (configured) plan is restored.

use std::collections::VecDeque;

/// Sliding window of request latencies (µs) with nearest-rank
/// percentiles — the engine's p99 estimate. Fixed capacity, FIFO
/// eviction; `percentile` uses the same nearest-rank convention as the
/// eval harness's `summarize_serve` (sort ascending, index
/// `round((len-1) * p)`), so a window covering exactly one bench cell
/// reproduces the cell's reported p99.
#[derive(Debug, Clone)]
pub struct P99Window {
    cap: usize,
    samples: VecDeque<u64>,
}

impl P99Window {
    pub fn new(cap: usize) -> Self {
        let cap = cap.max(1);
        Self { cap, samples: VecDeque::with_capacity(cap) }
    }

    pub fn push(&mut self, latency_us: u64) {
        if self.samples.len() == self.cap {
            self.samples.pop_front();
        }
        self.samples.push_back(latency_us);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Nearest-rank percentile over the current window (`p` in [0, 1]);
    /// `None` while the window is empty.
    pub fn percentile(&self, p: f64) -> Option<u64> {
        if self.samples.is_empty() {
            return None;
        }
        let mut sorted: Vec<u64> = self.samples.iter().copied().collect();
        sorted.sort_unstable();
        let idx = (((sorted.len() - 1) as f64) * p.clamp(0.0, 1.0)).round()
            as usize;
        Some(sorted[idx])
    }

    pub fn p99(&self) -> Option<u64> {
        self.percentile(0.99)
    }
}

/// One effective coalescing configuration — what the engine actually
/// runs with at a given moment (the adaptive controller's output; equal
/// to the configured base plan when the SLO is met or adaptation is
/// off).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FlushPlan {
    pub max_batch: usize,
    pub flush_us: u64,
    pub kb_parallel: usize,
}

/// SLO knobs carried inside `EngineOptions` (plain data so the options
/// stay `Clone`): a p99 target plus the clamp bounds the controller must
/// respect. `p99_target_us == 0` disables adaptation entirely.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SloOptions {
    /// Windowed-p99 target in µs; 0 = adaptation off (fixed plan).
    pub p99_target_us: u64,
    /// Latency window size (requests) for the p99 estimate.
    pub window: usize,
    /// Lower clamp for the adapted `max_batch`.
    pub min_batch: usize,
    /// Lower clamp for the adapted `flush_us`.
    pub min_flush_us: u64,
    /// Upper clamp for the adapted `kb_parallel`.
    pub max_kb_parallel: usize,
}

impl Default for SloOptions {
    fn default() -> Self {
        let c = crate::config::SloConfig::default();
        Self {
            p99_target_us: c.p99_target_us,
            window: c.window,
            min_batch: c.min_batch,
            min_flush_us: c.min_flush_us,
            max_kb_parallel: c.max_kb_parallel,
        }
    }
}

/// The adaptive flush controller: a latency window plus the pure policy
/// mapping its p99 to a [`FlushPlan`]. Constructed by the engine from
/// [`SloOptions`] and the configured base plan.
#[derive(Debug, Clone)]
pub struct AdaptiveFlush {
    target_us: u64,
    base: FlushPlan,
    min_batch: usize,
    min_flush_us: u64,
    max_kb_parallel: usize,
    window: P99Window,
}

impl AdaptiveFlush {
    pub fn new(slo: SloOptions, base: FlushPlan) -> Self {
        Self {
            target_us: slo.p99_target_us.max(1),
            base,
            // Clamp bounds are sanitized here, once, so `plan` can use
            // `clamp` without ever tripping its `min <= max` contract.
            min_batch: slo.min_batch.clamp(1, base.max_batch.max(1)),
            min_flush_us: slo.min_flush_us.min(base.flush_us),
            max_kb_parallel: slo.max_kb_parallel.max(base.kb_parallel),
            window: P99Window::new(slo.window),
        }
    }

    /// Record one completed request's total latency.
    pub fn observe(&mut self, total: std::time::Duration) {
        self.window.push(total.as_micros() as u64);
    }

    /// Current windowed p99 (µs), if any sample has landed.
    pub fn p99_us(&self) -> Option<u64> {
        self.window.p99()
    }

    /// The effective plan for the current window — a pure function of
    /// the observed samples (no clock, no RNG, no hidden state), so
    /// replaying the same latency sequence replays the same plans.
    pub fn plan(&self) -> FlushPlan {
        let Some(p99) = self.window.p99() else { return self.base };
        if p99 <= self.target_us {
            return self.base;
        }
        // Overload factor >= 1: how far the window's p99 overshoots.
        let f = p99 as f64 / self.target_us as f64;
        let max_batch = ((self.base.max_batch as f64 / f) as usize)
            .clamp(self.min_batch, self.base.max_batch.max(1));
        let flush_us = ((self.base.flush_us as f64 / f) as u64)
            .clamp(self.min_flush_us, self.base.flush_us);
        // kb_parallel == 0 is the synchronous mode — a structural choice
        // (no executor exists), not a knob the controller may flip.
        let kb_parallel = if self.base.kb_parallel == 0 {
            0
        } else {
            ((self.base.kb_parallel as f64 * f) as usize)
                .clamp(self.base.kb_parallel, self.max_kb_parallel)
        };
        FlushPlan { max_batch, flush_us, kb_parallel }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn window_percentiles_are_exact_on_known_sequences() {
        let mut w = P99Window::new(8);
        assert_eq!(w.p99(), None);
        w.push(100);
        assert_eq!(w.p99(), Some(100));
        assert_eq!(w.percentile(0.5), Some(100));
        for v in [300u64, 200, 800, 400, 700, 500, 600] {
            w.push(v);
        }
        // Window = {100..800}: nearest-rank p50 index round(7*0.5)=4
        // -> 500; p99 index round(7*0.99)=7 -> 800; p0 -> 100.
        assert_eq!(w.len(), 8);
        assert_eq!(w.percentile(0.0), Some(100));
        assert_eq!(w.percentile(0.5), Some(500));
        assert_eq!(w.p99(), Some(800));
        // FIFO eviction: pushing 150 evicts the oldest sample (100).
        w.push(150);
        assert_eq!(w.percentile(0.0), Some(150));
        assert_eq!(w.p99(), Some(800));
    }

    #[test]
    fn window_eviction_keeps_capacity() {
        let mut w = P99Window::new(3);
        for v in 0..10u64 {
            w.push(v);
        }
        assert_eq!(w.len(), 3);
        // Only {7, 8, 9} remain.
        assert_eq!(w.percentile(0.0), Some(7));
        assert_eq!(w.p99(), Some(9));
    }

    fn base() -> FlushPlan {
        FlushPlan { max_batch: 32, flush_us: 200, kb_parallel: 4 }
    }

    fn slo(target_us: u64) -> SloOptions {
        SloOptions {
            p99_target_us: target_us,
            window: 16,
            min_batch: 2,
            min_flush_us: 50,
            max_kb_parallel: 16,
        }
    }

    #[test]
    fn under_target_keeps_the_base_plan() {
        let mut a = AdaptiveFlush::new(slo(10_000), base());
        assert_eq!(a.plan(), base(), "empty window must not adapt");
        for _ in 0..16 {
            a.observe(Duration::from_micros(5_000));
        }
        assert_eq!(a.plan(), base());
    }

    #[test]
    fn overload_shrinks_window_and_raises_parallelism() {
        let mut a = AdaptiveFlush::new(slo(10_000), base());
        for _ in 0..16 {
            a.observe(Duration::from_micros(20_000)); // f = 2.0
        }
        let p = a.plan();
        assert_eq!(p.max_batch, 16);
        assert_eq!(p.flush_us, 100);
        assert_eq!(p.kb_parallel, 8);
    }

    #[test]
    fn plan_is_a_pure_function_of_the_samples() {
        // Replay stability: two controllers fed the identical sample
        // sequence emit the identical plan sequence.
        let seq: Vec<u64> =
            (0..40).map(|i| 4_000 + (i * 1_731) % 30_000).collect();
        let mut a = AdaptiveFlush::new(slo(10_000), base());
        let mut b = AdaptiveFlush::new(slo(10_000), base());
        for &us in &seq {
            a.observe(Duration::from_micros(us));
            b.observe(Duration::from_micros(us));
            assert_eq!(a.plan(), b.plan());
        }
        // And calling plan() repeatedly without new samples is stable.
        assert_eq!(a.plan(), a.plan());
    }

    #[test]
    fn clamps_respect_configured_bounds() {
        // Extreme overload: every knob pins to its clamp, never beyond.
        let mut a = AdaptiveFlush::new(slo(10), base());
        for _ in 0..16 {
            a.observe(Duration::from_micros(10_000_000)); // f = 1e6
        }
        let p = a.plan();
        assert_eq!(p.max_batch, 2, "max_batch floors at min_batch");
        assert_eq!(p.flush_us, 50, "flush_us floors at min_flush_us");
        assert_eq!(p.kb_parallel, 16,
                   "kb_parallel caps at max_kb_parallel");
        // Inconsistent bounds are sanitized at construction: a min_batch
        // above the base max_batch clamps to it instead of panicking.
        let weird = SloOptions { min_batch: 100, min_flush_us: 9_999,
                                 ..slo(10) };
        let mut a = AdaptiveFlush::new(weird, base());
        for _ in 0..4 {
            a.observe(Duration::from_micros(1_000_000));
        }
        let p = a.plan();
        assert_eq!(p.max_batch, base().max_batch);
        assert_eq!(p.flush_us, base().flush_us);
    }

    #[test]
    fn synchronous_mode_is_never_flipped_async() {
        let sync_base =
            FlushPlan { max_batch: 16, flush_us: 100, kb_parallel: 0 };
        let mut a = AdaptiveFlush::new(slo(10), sync_base);
        for _ in 0..8 {
            a.observe(Duration::from_micros(1_000_000));
        }
        assert_eq!(a.plan().kb_parallel, 0);
    }
}
