//! The engine/task contract (DESIGN.md ADR-004): any workload whose
//! serving loop alternates *local speculation* with *batched knowledge-base
//! verification* can be expressed as a [`ServeTask`] — a resumable
//! state machine that never touches the knowledge base itself. The task
//! surfaces its retrieval needs as [`TaskStep::NeedsVerify`] batches and
//! has results injected with [`ServeTask::provide`]; whoever drives it
//! decides *how* those batches are answered — a thin sequential driver
//! with one `retrieve_batch` call per step (`SpecPipeline::run`,
//! `KnnLmSpec::run`), or [`super::ServeEngine`], which coalesces the
//! batches of many concurrent tasks into shared KB calls.
//!
//! The contract was extracted from `spec::SpecTask` (ADR-003) so the QA
//! speculation pipeline and the KNN-LM per-token workload (and any future
//! task kind) are engine citizens through one interface: implementing
//! this trait is all a new workload needs to inherit cross-request
//! coalescing, admission control, and the serve scenario's throughput
//! reporting for free.

use crate::metrics::ReqMetrics;
use crate::retriever::SpecQuery;
use crate::serving::tenant::TenantId;
use crate::util::Scored;
use std::time::Duration;

/// What a [`ServeTask`] needs next, returned by [`ServeTask::advance`].
#[derive(Debug)]
pub enum TaskStep {
    /// The task is blocked on retrieval: answer with
    /// `kb.retrieve_batch(&queries, k)` (or any bit-identical equivalent —
    /// e.g. a sub-slice of a larger coalesced call) and hand the per-query
    /// result rows back via [`ServeTask::provide`].
    NeedsVerify { queries: Vec<SpecQuery>, k: usize },
    /// Made progress (one speculation step); call `advance` again.
    Continue,
    /// The request is complete; collect with [`ServeTask::into_metrics`].
    Done,
}

/// A resumable per-request serving task. Drive it with
/// [`advance`](Self::advance) until `Done`, answering every `NeedsVerify`
/// with [`provide`](Self::provide). `advance` must not be called while a
/// `NeedsVerify` is outstanding (implementations bail).
///
/// Driving a task by hand — exactly what the sequential drivers
/// (`SpecPipeline::run`, `KnnLmSpec::run`) and the coalescing
/// [`super::ServeEngine`] do:
///
/// ```
/// use ralmspec::config::{Config, CorpusConfig, RetrieverKind};
/// use ralmspec::datagen::{generate_questions, Dataset, HashEncoder};
/// use ralmspec::eval::TestBed;
/// use ralmspec::lm::MockLm;
/// use ralmspec::retriever::Retriever;
/// use ralmspec::serving::TaskStep;
/// use ralmspec::spec::{QueryBuilder, QueryMode, SpecOptions, SpecTask};
///
/// let mut cfg = Config::default();
/// cfg.corpus = CorpusConfig { n_docs: 200, n_topics: 8,
///                             doc_len: (16, 48),
///                             ..CorpusConfig::default() };
/// let enc = HashEncoder::new(ralmspec::runtime::RETRIEVAL_DIM, 1);
/// let bed = TestBed::build(&cfg, &enc);
/// let lm = MockLm::new(cfg.corpus.vocab, 320, 2);
/// let kb = bed.retriever(RetrieverKind::Edr);
/// let queries = QueryBuilder {
///     encoder: &enc,
///     mode: QueryMode::Dense,
///     dense_len: cfg.retriever.dense_query_len,
///     sparse_len: cfg.retriever.sparse_query_len,
/// };
/// let q = generate_questions(Dataset::WikiQa, &bed.corpus, 1, 3)
///     .remove(0);
/// let opts = SpecOptions { max_new: 8, ..SpecOptions::default() };
/// let mut task = SpecTask::new(&lm, kb.as_ref(), &bed.corpus, queries,
///                              opts, &q.tokens);
/// let metrics = loop {
///     match task.advance().unwrap() {
///         TaskStep::Continue => {}
///         TaskStep::Done => break task.into_metrics(),
///         TaskStep::NeedsVerify { queries, k } => {
///             // Answer with any bit-identical equivalent of
///             // kb.retrieve_batch — here, the direct call itself.
///             let rows = kb.retrieve_batch(&queries, k);
///             task.provide(rows, std::time::Duration::ZERO).unwrap();
///         }
///     }
/// };
/// assert!(!metrics.tokens_out.is_empty());
/// ```
///
/// **Equivalence obligation**: a task's output must be a pure function of
/// its own query/result sequence. Because every retriever scores a query
/// independently of its batchmates (pinned by the fig6 driver and
/// `tests/sharded_equivalence.rs`), that makes the task's output invariant
/// to *who* answers a `NeedsVerify` and *what else* was coalesced into
/// the call — the property every engine-vs-sequential equivalence suite
/// (`tests/engine_equivalence.rs`, `tests/knnlm_engine_equivalence.rs`)
/// asserts bit-for-bit.
pub trait ServeTask {
    /// Run until the task finishes (`Done`), needs retrieval results
    /// (`NeedsVerify`), or has taken one speculation step (`Continue` —
    /// the single-step granularity is what lets a serving engine
    /// interleave many tasks fairly).
    fn advance(&mut self) -> anyhow::Result<TaskStep>;

    /// The knowledge-base epoch this task is pinned to (DESIGN.md
    /// ADR-006): *every* `NeedsVerify` the task emits must be answered by
    /// that epoch's snapshot, and the engine must never coalesce queries
    /// from differently pinned tasks into one KB call — epochs change
    /// global scoring statistics (BM25 idf/avgdl shift with every
    /// publish), so a shared call would hand some member a row scored
    /// under the wrong epoch. Tasks of a frozen (non-live) knowledge
    /// base report the default epoch 0 and coalesce as before.
    fn epoch(&self) -> u64 {
        0
    }

    /// The tenant namespace this task belongs to (DESIGN.md ADR-011):
    /// the engine only ever coalesces its queries with same-tenant,
    /// same-(k, epoch) batchmates, and resolves their snapshot from that
    /// tenant's registrations ([`super::ServeEngine::register_tenant_epoch`]).
    /// Pre-ADR-011 tasks report the default tenant 0 and coalesce as
    /// before.
    fn tenant(&self) -> TenantId {
        0
    }

    /// Optional work overlapped with an in-flight verification (the
    /// async "+A" speculation that hides KB latency). Drivers may call
    /// this **repeatedly** between receiving `NeedsVerify` and calling
    /// `provide` — once per scheduling round for as long as the
    /// verification is outstanding; each call takes at most one step and
    /// returns whether one was taken (`false` = drained for this round).
    ///
    /// **Determinism obligation**: how many steps a task accepts per
    /// round must be a function of its own state only (e.g. "up to one
    /// full next stride"), never of elapsed time or of how often the
    /// driver happened to call — so a driver that drains to exhaustion
    /// reproduces the same schedule whether the KB call took a
    /// microsecond or a second. Combined with the equivalence obligation
    /// above, that keeps outputs bit-identical across drivers and KB
    /// latencies. Default: no overlap capability.
    fn overlap_step(&mut self) -> anyhow::Result<bool> {
        Ok(false)
    }

    /// Answer the outstanding `NeedsVerify`: `truths[i]` is the top-k for
    /// `queries[i]`, `kb_time` the latency of the KB call that produced
    /// them (attributed to this request's R component; a coalesced call's
    /// latency is shared by every participating request because each one
    /// really did wait for it).
    fn provide(&mut self, truths: Vec<Vec<Scored>>, kb_time: Duration)
               -> anyhow::Result<()>;

    /// Mutable metrics access for drivers that attribute wait time
    /// themselves (`queue_wait` in the engine, `verify_wait` in the async
    /// pipeline driver).
    fn metrics_mut(&mut self) -> &mut ReqMetrics;

    /// Final metrics (tokens, latency decomposition). Complete only once
    /// [`advance`](Self::advance) has returned `Done`.
    fn into_metrics(self) -> ReqMetrics
    where
        Self: Sized;
}
