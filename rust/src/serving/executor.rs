//! Asynchronous execution of coalesced knowledge-base calls (DESIGN.md
//! ADR-005): the serving engine hands each flushed per-k query group to a
//! [`RetrievalExecutor`], which runs it on a background
//! [`WorkerPool`](crate::retriever::WorkerPool) worker and delivers a
//! [`CallOutcome`] through a completion queue — so the engine thread keeps
//! advancing runnable tasks, draining overlap steps, and admitting new
//! requests across the *whole* KB latency instead of stalling inside
//! `retrieve_batch`.
//!
//! The executor enforces a configurable in-flight cap (`kb_parallel`):
//! groups beyond the cap wait in a FIFO backlog and dispatch as
//! completions free slots, bounding both worker-pool pressure and the
//! memory pinned by in-flight query batches. Worker panics are converted
//! to `Err` outcomes ([`crate::retriever::pool::run_caught`]) so a
//! poisoned KB call surfaces as an error on the owning requests instead
//! of wedging the engine.
//!
//! Completion order is whatever the workers produce — the engine routes
//! results back per group, and per-request outputs are invariant to that
//! order because every retriever scores queries independently of
//! batchmates (the bit-identity the equivalence suites pin).
//!
//! Because the pool threads are persistent, the thread-local retrieval
//! scratch (HNSW search scratch, BM25 accumulators, the dense query-pack
//! buffer — see `retriever::kernels` and friends) stays warm across
//! coalesced flushes: steady-state KB calls allocate nothing on the hot
//! path.

use crate::metrics::Stopwatch;
use crate::retriever::pool::run_caught;
use crate::retriever::{Retriever, SpecQuery, WorkerPool};
use crate::util::Scored;
use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::Duration;

/// One coalesced per-(k, epoch) call prepared by the engine's flush.
pub(crate) struct PreparedCall {
    /// Engine-side correlation id (maps back to the group's member slots).
    pub group: u64,
    pub queries: Vec<SpecQuery>,
    pub k: usize,
    /// The knowledge-base snapshot this group's members are pinned to
    /// (ADR-006): a live KB serves concurrent groups against different
    /// epochs, so the retriever is per-call state, not executor state.
    pub kb: Arc<dyn Retriever>,
    /// One enqueue stopwatch per member batch, in member order — snapshotted
    /// immediately before the KB call starts (on the worker), so each
    /// member's `queue_wait` covers its full coalescing-buffer + backlog
    /// time, exactly as the synchronous path measured it.
    pub enqueued: Vec<Stopwatch>,
}

/// Completion of one coalesced call, delivered via the completion queue.
pub(crate) struct CallOutcome {
    pub group: u64,
    /// The per-query result rows, or the converted panic/failure of the
    /// KB job.
    pub result: anyhow::Result<Vec<Vec<Scored>>>,
    /// Wall time of the KB call itself (attributed to every member's R
    /// component — each really did wait for it).
    pub kb_time: Duration,
    /// Per-member coalescing wait, snapshotted at call start.
    pub member_waits: Vec<Duration>,
}

/// Runs prepared calls on background workers under an in-flight cap and
/// feeds a single completion queue the engine can park on.
pub(crate) struct RetrievalExecutor {
    pool: Arc<WorkerPool>,
    /// Max concurrently in-flight KB calls (>= 1; the engine handles the
    /// synchronous `kb_parallel == 0` mode itself and never constructs an
    /// executor for it).
    cap: usize,
    inflight: usize,
    backlog: VecDeque<PreparedCall>,
    tx: Sender<CallOutcome>,
    rx: Receiver<CallOutcome>,
    // --- depth telemetry (reported through EngineStats) ---
    pub dispatches: u64,
    pub depth_sum: u64,
    pub depth_max: u64,
}

impl RetrievalExecutor {
    pub fn new(cap: usize) -> Self {
        let (tx, rx) = channel();
        Self {
            // The dedicated KB-call pool, NOT the shard pool: a sharded
            // retriever's retrieve_batch blocks its worker on scatter
            // jobs queued to the shard pool, so sharing one pool would
            // let concurrent KB calls starve the very jobs they wait on
            // (see WorkerPool::kb_global).
            pool: WorkerPool::kb_global().clone(),
            cap: cap.max(1),
            inflight: 0,
            backlog: VecDeque::new(),
            tx,
            rx,
            dispatches: 0,
            depth_sum: 0,
            depth_max: 0,
        }
    }

    /// Calls not yet completed (in flight on workers + waiting in the
    /// backlog). The engine may park awaiting completions iff this is
    /// non-zero.
    pub fn outstanding(&self) -> usize {
        self.inflight + self.backlog.len()
    }

    /// Retune the in-flight cap (ADR-011: the SLO controller raises
    /// `kb_parallel` under overload). Raising it immediately dispatches
    /// backlogged calls into the new slots; lowering it never cancels
    /// in-flight work — the cap re-binds as completions land.
    pub fn set_cap(&mut self, cap: usize) {
        self.cap = cap.max(1);
        self.pump();
    }

    /// Whether a submitted call would start immediately (an in-flight
    /// slot is free). `pump` keeps the backlog empty while below the
    /// cap, so a non-empty backlog implies saturation. The engine uses
    /// this to hold its coalescing buffer instead of freezing a batch's
    /// composition in the backlog of a saturated executor.
    pub fn has_free_slot(&self) -> bool {
        self.inflight < self.cap
    }

    /// Accept one prepared call: dispatch immediately if a slot is free,
    /// otherwise queue it (FIFO) until a completion frees one.
    pub fn submit(&mut self, call: PreparedCall) {
        self.backlog.push_back(call);
        self.pump();
    }

    fn pump(&mut self) {
        while self.inflight < self.cap {
            let Some(call) = self.backlog.pop_front() else { break };
            self.dispatch(call);
        }
    }

    fn dispatch(&mut self, call: PreparedCall) {
        self.inflight += 1;
        self.dispatches += 1;
        self.depth_sum += self.inflight as u64;
        self.depth_max = self.depth_max.max(self.inflight as u64);
        let tx = self.tx.clone();
        self.pool.execute(Box::new(move || {
            let member_waits =
                call.enqueued.iter().map(|s| s.elapsed()).collect();
            let sw = Stopwatch::start();
            let result = run_caught(|| call.kb.retrieve_batch(&call.queries,
                                                              call.k));
            // The engine owns the other end; if it dropped (run aborted)
            // the completion is moot.
            let _ = tx.send(CallOutcome {
                group: call.group,
                result,
                kb_time: sw.elapsed(),
                member_waits,
            });
        }));
    }

    /// Non-blocking completion poll.
    pub fn try_complete(&mut self) -> Option<CallOutcome> {
        match self.rx.try_recv() {
            Ok(done) => {
                self.inflight -= 1;
                self.pump();
                Some(done)
            }
            Err(_) => None,
        }
    }

    /// Deadline-aware parking: block for the next completion up to
    /// `timeout` (the engine bounds this by its flush deadline so a parked
    /// engine still honours `flush_us`). `None` on timeout.
    pub fn wait_complete(&mut self, timeout: Duration)
                         -> Option<CallOutcome> {
        match self.rx.recv_timeout(timeout) {
            Ok(done) => {
                self.inflight -= 1;
                self.pump();
                Some(done)
            }
            Err(RecvTimeoutError::Timeout) => None,
            // All senders live in self (tx) and dispatched jobs; tx is
            // never dropped while self exists, so this arm is unreachable.
            Err(RecvTimeoutError::Disconnected) => None,
        }
    }
}
