//! Concurrent serving engine: multiplex N in-flight [`ServeTask`]s and
//! coalesce their pending verification queries into shared
//! `kb.retrieve_batch` calls (DESIGN.md ADR-003 / ADR-004 / ADR-005).
//!
//! The paper's batched verification amortizes retrieval *within* one
//! request's speculation stride; at serving scale the same batch-first
//! retrieval primitive amortizes *across* concurrent requests. The engine
//! drives each task one speculation step at a time (fair interleaving),
//! parks tasks that emit `NeedsVerify`, and flushes the accumulated
//! queries under a **size-or-deadline** policy (`engine.max_batch`
//! queries, or the oldest query aging past `engine.flush_us`, or nothing
//! else can make progress). Queries are grouped by their top-k so tasks
//! with different prefetch sizes never share a call.
//!
//! **Asynchronous retrieval execution (ADR-005)**: with
//! `kb_parallel >= 1`, flushed per-k groups run on background workers
//! through a `RetrievalExecutor` (up to `kb_parallel` calls in flight;
//! excess groups queue FIFO). The engine thread keeps advancing runnable
//! tasks, draining [`ServeTask::overlap_step`]s for parked tasks across
//! the whole KB latency, and admitting new requests; completions are
//! routed back as they arrive through a completion queue the engine parks
//! on (deadline-aware `recv_timeout`, never a busy-spin) when it has no
//! other work. `kb_parallel == 0` keeps the synchronous inline flush on
//! the engine thread. A panicking KB job is converted to an error and
//! surfaces as a failure on exactly the requests whose queries were in
//! the poisoned call ([`ServeEngine::take_failed`]); their slots free and
//! the engine keeps serving everyone else.
//!
//! The engine is generic over the task kind ([`ServeTask`], ADR-004): QA
//! speculation ([`SpecTask`]) and KNN-LM per-token serving
//! ([`crate::knnlm::KnnTask`] — the paper's highest-leverage workload, one
//! retrieval per generated token) coalesce through the same scheduler and
//! flush policy.
//!
//! **Live knowledge bases (ADR-006)**: every task reports the epoch it is
//! pinned to ([`ServeTask::epoch`]); the flush groups pending batches by
//! *(tenant, top-k, epoch)* and issues each group against that tenant and
//! epoch's registered snapshot ([`ServeEngine::register_epoch`] /
//! [`ServeEngine::register_tenant_epoch`]). Queries of differently
//! pinned tasks never share a KB call — epochs change global scoring
//! statistics, so sharing would silently hand a member rows scored under
//! the wrong snapshot. A frozen KB is the degenerate case: all tasks at
//! epoch 0, one group per k, identical to the pre-ADR-006 engine.
//!
//! **Multi-tenant serving (ADR-011)**: requests may carry a
//! [`TenantId`] and a [`Priority`] class
//! ([`ServeEngine::submit_opts`]). Tenants are isolation domains — each
//! owns its own knowledge base (epoch stream + ingest quota), and the
//! (tenant, k, epoch) flush grouping means one tenant's ingest storm
//! (a burst of epoch publishes) never splits or invalidates another
//! tenant's coalesced batches. Priority classes get weighted
//! round-robin admission, and under overload the engine **preempts
//! speculation**: the lowest-priority in-flight task is cancelled at a
//! speculation boundary (never while a verification of it is pending or
//! in flight) and requeued. Abandoned speculation is re-derivable — a
//! task is a resumable state machine whose output is a pure function of
//! its own query/result sequence against its pinned epoch — so
//! preempted requests stay bit-identical to the sequential reference
//! (tests/tenant_equivalence.rs). An optional SLO controller
//! ([`crate::serving::slo::AdaptiveFlush`]) retunes
//! `max_batch`/`flush_us`/`kb_parallel` against a p99 target from the
//! engine's own completion latencies.
//!
//! **Why per-request outputs survive coalescing and out-of-order
//! completion bit-for-bit**: every retriever scores a query independently
//! of its batchmates (the bit-identity pinned by the fig6 driver and
//! tests/sharded_equivalence.rs), so the sub-slice of a coalesced call
//! routed back to a task is exactly what the task's own `retrieve_batch`
//! would have returned — no matter which worker ran the call or in what
//! order completions land. The equivalence suites
//! (tests/engine_equivalence.rs, tests/knnlm_engine_equivalence.rs) check
//! engine output against sequential `SpecPipeline::run` /
//! `KnnLmSpec::run` per request across `kb_parallel` {0, 1, 2, 4}.

use crate::baseline::{BaselineOptions, RalmSeq};
use crate::config::Config;
use crate::datagen::{Corpus, Encoder};
use crate::knnlm::{Datastore, KnnLmBaseline, KnnServeOptions, KnnTask};
use crate::lm::LanguageModel;
use crate::metrics::{ReqMetrics, Stopwatch};
use crate::retriever::epoch::{EpochSnapshot, LiveKb};
use crate::retriever::pool::run_caught;
use crate::retriever::{Retriever, SpecQuery};
use crate::serving::executor::{CallOutcome, PreparedCall,
                               RetrievalExecutor};
use crate::serving::router::{Method, Request, ServeBackend};
use crate::serving::slo::{AdaptiveFlush, FlushPlan, SloOptions};
use crate::serving::task::{ServeTask, TaskStep};
use crate::serving::tenant::{Priority, SubmitOpts, TenantId};
use crate::spec::{QueryBuilder, QueryMode, SpecOptions, SpecTask};
use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::sync::Arc;
use std::time::Duration;

#[derive(Debug, Clone)]
pub struct EngineOptions {
    /// Flush the coalescing buffer when this many queries are pending.
    pub max_batch: usize,
    /// ... or when the oldest pending query has waited this long (µs).
    pub flush_us: u64,
    /// In-flight request cap (admission control); 0 = unlimited.
    pub max_inflight: usize,
    /// Max concurrently in-flight coalesced KB calls (ADR-005):
    /// `>= 1` dispatches flushed groups to background workers and keeps
    /// the engine thread free across the KB latency; `0` keeps the
    /// synchronous inline flush on the engine thread. Per-request output
    /// is bit-identical across every setting.
    pub kb_parallel: usize,
    /// Preempt the lowest-priority in-flight speculation (at a
    /// speculation boundary — never while its verification is pending or
    /// in flight) when a higher-priority request is waiting and
    /// `max_inflight` is saturated (ADR-011). Per-request output is
    /// bit-identical either way; only the schedule changes.
    pub preempt: bool,
    /// Weighted round-robin admission credits per priority class
    /// (`[high, normal, low]`, ADR-011); each refill grants class *c*
    /// `class_weights[c]` admissions before lower-weight classes recycle.
    pub class_weights: [u64; Priority::COUNT],
    /// SLO adaptation (ADR-011): `Some` with a nonzero
    /// `p99_target_us` lets the engine retune
    /// `max_batch`/`flush_us`/`kb_parallel` against the target from its
    /// own completion latencies; `None` (or target 0) keeps the fixed
    /// plan above.
    pub slo: Option<SloOptions>,
}

impl Default for EngineOptions {
    fn default() -> Self {
        let c = crate::config::EngineConfig::default();
        Self {
            max_batch: c.max_batch,
            flush_us: c.flush_us,
            max_inflight: 0,
            kb_parallel: c.kb_parallel,
            preempt: c.preempt,
            class_weights: crate::config::TenantConfig::default().weights(),
            slo: None,
        }
    }
}

impl EngineOptions {
    pub fn from_config(cfg: &Config, max_inflight: usize) -> Self {
        Self {
            max_batch: cfg.engine.max_batch.max(1),
            flush_us: cfg.engine.flush_us,
            max_inflight,
            kb_parallel: cfg.engine.kb_parallel,
            preempt: cfg.engine.preempt,
            class_weights: cfg.tenant.weights(),
            slo: Some(SloOptions {
                p99_target_us: cfg.slo.p99_target_us,
                window: cfg.slo.window,
                min_batch: cfg.slo.min_batch,
                min_flush_us: cfg.slo.min_flush_us,
                max_kb_parallel: cfg.slo.max_kb_parallel,
            }),
        }
    }
}

/// Engine-level counters (per-request metrics live in each task's
/// [`ReqMetrics`]; `queue_wait` there is attributed by the engine).
#[derive(Debug, Clone, Default)]
pub struct EngineStats {
    /// Coalesced KB calls actually completed.
    pub kb_calls: u64,
    /// Queries answered across those calls.
    pub coalesced_queries: u64,
    /// Largest coalesced batch seen.
    pub max_coalesced: u64,
    pub size_flushes: u64,
    pub deadline_flushes: u64,
    /// Flushes forced because no task could progress without results.
    pub drain_flushes: u64,
    /// Total wall time inside coalesced KB calls.
    pub kb_time: Duration,
    /// KB calls that failed (worker panic or row-count mismatch); their
    /// member requests surface through [`ServeEngine::take_failed`].
    pub kb_failures: u64,
    /// Coalesced calls handed to the executor / run inline.
    pub kb_dispatches: u64,
    /// Sum over dispatches of the in-flight depth *after* dispatch (1 for
    /// every synchronous inline call) — mean via
    /// [`mean_inflight_depth`](Self::mean_inflight_depth).
    pub inflight_depth_sum: u64,
    /// Peak concurrently in-flight KB calls.
    pub inflight_depth_max: u64,
    /// Verification batches parked in the coalescing buffer.
    pub parked_rounds: u64,
    /// Distinct knowledge-base epochs the submitted tasks were pinned to
    /// (1 for a frozen KB — every task reports epoch 0).
    pub epochs_served: u64,
    /// Extra coalesced calls forced by epoch boundaries: same-k queries
    /// that could have shared one call had their tasks not been pinned to
    /// different epochs (ADR-006 — the price of live consistency).
    pub epoch_splits: u64,
    /// Overlap speculation steps driven while verifications were pending
    /// or in flight (the async "+A" work that hides KB latency).
    pub overlap_steps: u64,
    /// Times the engine parked on the completion queue (deadline-aware
    /// wait instead of a busy-spin).
    pub parks: u64,
    /// Distinct tenants across submitted tasks (1 for every pre-ADR-011
    /// caller — everything under tenant 0).
    pub tenants_served: u64,
    /// Extra coalesced calls forced by tenant boundaries: same-(k, epoch)
    /// queries that could have shared one call had they not belonged to
    /// different tenants (ADR-011 — the price of tenant isolation).
    pub tenant_splits: u64,
    /// In-flight speculations cancelled at a speculation boundary and
    /// requeued to make room for a higher-priority request (ADR-011).
    pub preemptions: u64,
    /// Deadlock-backstop admissions: a deferred-arrival task admitted
    /// before its `after_done` gate because nothing else could progress.
    pub forced_admissions: u64,
    /// Times the adaptive SLO controller changed the effective flush
    /// plan.
    pub adaptations: u64,
}

impl EngineStats {
    /// Mean queries per coalesced KB call — the cross-request batching
    /// factor (1.0 means coalescing never helped).
    pub fn mean_coalesced(&self) -> f64 {
        if self.kb_calls == 0 {
            return 0.0;
        }
        self.coalesced_queries as f64 / self.kb_calls as f64
    }

    /// Mean in-flight KB-call depth at dispatch time (1.0 = fully
    /// serialized; approaches `kb_parallel` when the executor stays
    /// saturated).
    pub fn mean_inflight_depth(&self) -> f64 {
        if self.kb_dispatches == 0 {
            return 0.0;
        }
        self.inflight_depth_sum as f64 / self.kb_dispatches as f64
    }

    /// Overlap utilization: mean overlap speculation steps taken per
    /// parked verification round (0.0 = verification latency never
    /// hidden behind task work).
    pub fn overlap_per_round(&self) -> f64 {
        if self.parked_rounds == 0 {
            return 0.0;
        }
        self.overlap_steps as f64 / self.parked_rounds as f64
    }
}

/// A task slot. Slots are recycled (never removed) so the slot indices
/// held by the coalescing buffer and by in-flight groups stay stable
/// across admissions.
struct Slot<T> {
    id: u64,
    task: Option<T>,
    /// True while the task's `NeedsVerify` sits in the coalescing buffer
    /// or rides an in-flight KB call. An awaiting slot is never a
    /// preemption victim — outstanding `pending`/`dispatched` entries
    /// reference it by index.
    awaiting: bool,
    tenant: TenantId,
    class: Priority,
    /// Submission sequence number; preserved across preemption so a
    /// requeued task keeps its place among same-class peers.
    seq: u64,
    after_done: usize,
}

/// One admission-queue entry (ADR-011: per-class queues).
struct Waiting<T> {
    seq: u64,
    id: u64,
    task: T,
    tenant: TenantId,
    class: Priority,
    /// Deferred arrival: admissible once this many requests resolved.
    after_done: usize,
}

/// One parked verification batch awaiting flush.
struct PendingVerify {
    slot: usize,
    queries: Vec<SpecQuery>,
    k: usize,
    /// The owning task's pinned epoch: flush groups by (tenant, k, epoch)
    /// so a coalesced call never mixes epochs (ADR-006) or tenants
    /// (ADR-011).
    epoch: u64,
    /// The owning slot's tenant namespace.
    tenant: TenantId,
    enqueued: Stopwatch,
}

/// One member batch of a dispatched (or inline-running) coalesced call.
struct GroupMember {
    slot: usize,
    n_queries: usize,
}

pub struct ServeEngine<T: ServeTask> {
    /// The default knowledge base — every epoch-0 (frozen-KB) task's
    /// calls go here; pinned epochs resolve through `epoch_kbs`.
    kb: Arc<dyn Retriever>,
    opts: EngineOptions,
    /// Pinned-epoch snapshots registered by the caller
    /// ([`register_tenant_epoch`](Self::register_tenant_epoch)): a task
    /// of tenant `t` reporting `epoch() == e` has its coalesced calls
    /// issued against `epoch_kbs[(t, e)]` (ADR-006 / ADR-011).
    epoch_kbs: BTreeMap<(TenantId, u64), Arc<dyn Retriever>>,
    /// Distinct epochs across submitted tasks (stats).
    seen_epochs: BTreeSet<u64>,
    /// Distinct tenants across submitted tasks (stats).
    seen_tenants: BTreeSet<TenantId>,
    /// Per-class admission queues (index = [`Priority::index`]), each
    /// ordered by (after_done, seq); tasks are constructed at submission
    /// so each request's latency clock covers its admission-queue wait
    /// too.
    waiting: [VecDeque<Waiting<T>>; Priority::COUNT],
    /// Weighted round-robin admission credits, refilled from
    /// `opts.class_weights` when every class with eligible work is spent.
    credits: [u64; Priority::COUNT],
    /// Monotone submission counter (ties broken FIFO within a class).
    next_seq: u64,
    /// Requests resolved so far (finished + failed) — the deferred
    /// arrival clock for `SubmitOpts::after_done`. Monotone across
    /// `take_finished`/`take_failed` drains.
    resolved: usize,
    slots: Vec<Slot<T>>,
    pending: Vec<PendingVerify>,
    /// Asynchronous call executor (`kb_parallel >= 1`); `None` keeps the
    /// synchronous inline flush.
    exec: Option<RetrievalExecutor>,
    /// In-flight (or inline-running) groups keyed by correlation id.
    dispatched: BTreeMap<u64, Vec<GroupMember>>,
    /// Reusable (tenant, k, epoch) group list for [`flush`](Self::flush) —
    /// kept as a field so the sort/dedup scratch survives across flushes.
    flush_groups: Vec<(TenantId, usize, u64)>,
    next_group: u64,
    /// SLO controller (ADR-011); `None` keeps the fixed flush plan.
    adaptive: Option<AdaptiveFlush>,
    /// The effective flush plan — `opts`-derived base until the adaptive
    /// controller (if any) retunes it.
    eff: FlushPlan,
    stats: EngineStats,
    finished: Vec<(u64, ReqMetrics)>,
    failed: Vec<(u64, String)>,
}

impl<T: ServeTask> ServeEngine<T> {
    pub fn new(kb: Arc<dyn Retriever>, opts: EngineOptions) -> Self {
        let exec = if opts.kb_parallel >= 1 {
            Some(RetrievalExecutor::new(opts.kb_parallel))
        } else {
            None
        };
        let eff = FlushPlan {
            max_batch: opts.max_batch.max(1),
            flush_us: opts.flush_us,
            kb_parallel: opts.kb_parallel,
        };
        let adaptive = opts
            .slo
            .filter(|s| s.p99_target_us > 0)
            .map(|s| AdaptiveFlush::new(s, eff));
        let credits = opts.class_weights;
        Self {
            kb,
            opts,
            epoch_kbs: BTreeMap::new(),
            seen_epochs: BTreeSet::new(),
            seen_tenants: BTreeSet::new(),
            waiting: std::array::from_fn(|_| VecDeque::new()),
            credits,
            next_seq: 0,
            resolved: 0,
            slots: Vec::new(),
            pending: Vec::new(),
            exec,
            dispatched: BTreeMap::new(),
            flush_groups: Vec::new(),
            next_group: 0,
            adaptive,
            eff,
            stats: EngineStats::default(),
            finished: Vec::new(),
            failed: Vec::new(),
        }
    }

    /// Register the snapshot a pinned epoch's calls must run against
    /// (live knowledge bases, ADR-006) in the default tenant-0 namespace.
    /// Callers register each snapshot before (or at) submitting tasks
    /// pinned to it; unregistered epochs fall back to the engine's
    /// default `kb`, which keeps frozen-KB callers (every task at epoch
    /// 0) working unchanged.
    pub fn register_epoch(&mut self, epoch: u64, kb: Arc<dyn Retriever>) {
        self.register_tenant_epoch(0, epoch, kb);
    }

    /// Register a tenant's pinned-epoch snapshot (ADR-011): coalesced
    /// calls of tenant `tenant`'s tasks pinned to `epoch` run against
    /// this retriever, and only same-tenant queries ever share them.
    pub fn register_tenant_epoch(&mut self, tenant: TenantId, epoch: u64,
                                 kb: Arc<dyn Retriever>) {
        self.epoch_kbs.insert((tenant, epoch), kb);
    }

    /// Enqueue one request's task (construct it at submission so the
    /// request's latency clock covers its admission-queue wait too —
    /// reported p50/p99 then include what a client would observe, not
    /// just in-flight service time) under the task's own tenant at the
    /// default class. Admission happens inside [`run`](Self::run),
    /// honouring `max_inflight`.
    pub fn submit(&mut self, id: u64, task: T) {
        let opts = SubmitOpts { tenant: task.tenant(),
                                ..SubmitOpts::default() };
        self.submit_opts(id, task, opts);
    }

    /// Enqueue one request's task with explicit tenant / priority class /
    /// deferred-arrival options (ADR-011).
    pub fn submit_opts(&mut self, id: u64, task: T, sub: SubmitOpts) {
        self.seen_epochs.insert(task.epoch());
        self.stats.epochs_served = self.seen_epochs.len() as u64;
        self.seen_tenants.insert(sub.tenant);
        self.stats.tenants_served = self.seen_tenants.len() as u64;
        let seq = self.next_seq;
        self.next_seq += 1;
        self.enqueue(Waiting {
            seq,
            id,
            task,
            tenant: sub.tenant,
            class: sub.class,
            after_done: sub.after_done,
        });
    }

    /// Insert into the class queue ordered by (after_done, seq): heads
    /// are always the entry closest to (or past) its arrival gate, and
    /// preempted tasks — which keep their original seq — re-enter ahead
    /// of later arrivals.
    fn enqueue(&mut self, w: Waiting<T>) {
        let q = &mut self.waiting[w.class.index()];
        let key = (w.after_done, w.seq);
        let pos = q.partition_point(|x| (x.after_done, x.seq) <= key);
        q.insert(pos, w);
    }

    pub fn stats(&self) -> &EngineStats {
        &self.stats
    }

    /// Drain the results collected so far. [`run`](Self::run) returns them
    /// on success; after a `run` error this lets the caller salvage the
    /// requests that completed before the failing one, instead of
    /// reporting the whole coalesced batch as failed.
    pub fn take_finished(&mut self) -> Vec<(u64, ReqMetrics)> {
        self.finished.sort_by_key(|(id, _)| *id);
        std::mem::take(&mut self.finished)
    }

    /// Drain the requests whose coalesced KB call failed (worker panic or
    /// malformed result). Their slots were freed and the engine kept
    /// serving everyone else; callers turn these into per-request error
    /// responses.
    pub fn take_failed(&mut self) -> Vec<(u64, String)> {
        self.failed.sort_by_key(|(id, _)| *id);
        std::mem::take(&mut self.failed)
    }

    fn inflight(&self) -> usize {
        self.slots.iter().filter(|s| s.task.is_some()).count()
    }

    /// The effective flush plan currently driving the coalescing policy
    /// (the configured base, unless the SLO controller retuned it).
    pub fn effective_plan(&self) -> FlushPlan {
        self.eff
    }

    fn waiting_empty(&self) -> bool {
        self.waiting.iter().all(|q| q.is_empty())
    }

    /// Pick the next class to admit from under weighted round-robin:
    /// spend one credit of the highest-priority class that still has
    /// both credits and an *eligible* head (its `after_done` gate
    /// passed); when every such class is spent, refill all credits from
    /// the configured weights and retry once. `None` = nothing eligible.
    fn pick_class(&mut self) -> Option<usize> {
        for _pass in 0..2 {
            for c in 0..Priority::COUNT {
                if self.credits[c] == 0 {
                    continue;
                }
                let eligible = self.waiting[c]
                    .front()
                    .map_or(false, |w| w.after_done <= self.resolved);
                if eligible {
                    self.credits[c] -= 1;
                    return Some(c);
                }
            }
            self.credits = self.opts.class_weights;
        }
        None
    }

    /// Place a task into a slot. Recycle a free slot (its pending
    /// entries, if any existed, were consumed before the slot was freed)
    /// to keep the slot indices stored in `pending`/`dispatched` stable.
    fn place(&mut self, w: Waiting<T>) {
        let slot = Slot {
            id: w.id,
            task: Some(w.task),
            awaiting: false,
            tenant: w.tenant,
            class: w.class,
            seq: w.seq,
            after_done: w.after_done,
        };
        match self.slots.iter().position(|s| s.task.is_none()) {
            Some(i) => self.slots[i] = slot,
            None => self.slots.push(slot),
        }
    }

    fn admit(&mut self) {
        let cap = if self.opts.max_inflight == 0 {
            usize::MAX
        } else {
            self.opts.max_inflight
        };
        while self.inflight() < cap {
            let Some(c) = self.pick_class() else { break };
            let Some(w) = self.waiting[c].pop_front() else { break };
            self.place(w);
        }
        if self.opts.preempt && cap != usize::MAX {
            self.preempt(cap);
        }
    }

    /// Speculation preemption (ADR-011): while a higher-priority request
    /// waits and admission is saturated, cancel the lowest-priority
    /// in-flight task *at a speculation boundary* (`awaiting == false`:
    /// no coalescing-buffer entry or in-flight KB call references its
    /// slot) and requeue it with its original sequence number. Abandoned
    /// speculation is re-derivable — a task's output is a pure function
    /// of its own query/result sequence against its pinned epoch — so
    /// the preempted request's eventual output is bit-identical; only
    /// its latency (and the engine schedule) changes. Each iteration
    /// swaps one strictly-lower-priority task out, so the loop
    /// terminates.
    fn preempt(&mut self, cap: usize) {
        loop {
            let Some(wc) = (0..Priority::COUNT).find(|&c| {
                self.waiting[c]
                    .front()
                    .map_or(false, |w| w.after_done <= self.resolved)
            }) else {
                return;
            };
            if self.inflight() < cap {
                return; // a free slot exists; plain admission covers it
            }
            let victim = self
                .slots
                .iter()
                .enumerate()
                .filter(|(_, s)| {
                    s.task.is_some() && !s.awaiting
                        && s.class.index() > wc
                })
                .max_by_key(|(_, s)| (s.class.index(), s.seq))
                .map(|(i, _)| i);
            let Some(vi) = victim else { return };
            let s = &mut self.slots[vi];
            let Some(task) = s.task.take() else { return };
            let requeued = Waiting {
                seq: s.seq,
                id: s.id,
                task,
                tenant: s.tenant,
                class: s.class,
                after_done: s.after_done,
            };
            self.stats.preemptions += 1;
            self.enqueue(requeued);
            let Some(w) = self.waiting[wc].pop_front() else { return };
            self.place(w);
        }
    }

    /// Deadlock backstop for deferred arrivals: when nothing is in
    /// flight, nothing is pending, and every waiting head is still gated
    /// on `after_done`, admit the entry closest to its gate anyway
    /// (counted in [`EngineStats::forced_admissions`]). Without this, a
    /// trace whose gates exceed the number of submitted requests would
    /// stall the engine forever.
    fn force_admit_one(&mut self) -> bool {
        let mut best: Option<(usize, (usize, usize, u64))> = None;
        for c in 0..Priority::COUNT {
            if let Some(w) = self.waiting[c].front() {
                let key = (w.after_done, c, w.seq);
                if best.map_or(true, |(_, bk)| key < bk) {
                    best = Some((c, key));
                }
            }
        }
        let Some((c, _)) = best else { return false };
        let Some(w) = self.waiting[c].pop_front() else { return false };
        self.place(w);
        true
    }

    /// Drive every submitted request to completion, coalescing
    /// verification batches across them and (with `kb_parallel >= 1`)
    /// overlapping task work with in-flight KB calls. Returns
    /// `(id, metrics)` sorted by request id; per-request `tokens_out` is
    /// bit-identical to driving the same task alone (`SpecPipeline::run` /
    /// `KnnLmSpec::run`) regardless of `kb_parallel` or completion order.
    /// Requests lost to a failing KB call are reported through
    /// [`take_failed`](Self::take_failed), not as a `run` error.
    #[allow(clippy::needless_range_loop)] // indices outlive `slots` borrows
    pub fn run(&mut self) -> anyhow::Result<Vec<(u64, ReqMetrics)>> {
        loop {
            self.admit();
            // Route completions that have already landed so their tasks
            // advance this very iteration.
            let mut progressed = self.route_ready()?;
            if self.waiting_empty()
                && self.slots.iter().all(|s| s.task.is_none())
            {
                break;
            }

            // One speculation step per runnable task: round-robin keeps N
            // tasks' steps interleaved so their verification points line
            // up inside the coalescing window.
            let mut runnable = 0usize;
            for i in 0..self.slots.len() {
                if self.slots[i].awaiting {
                    continue;
                }
                let step = {
                    let Some(task) = self.slots[i].task.as_mut() else {
                        continue;
                    };
                    task.advance()?
                };
                progressed = true;
                match step {
                    TaskStep::Continue => runnable += 1,
                    TaskStep::Done => {
                        let task = self.slots[i].task.take()
                            // detlint: allow(hot-panic, reason = "slot's task was just stepped to Done above, so take() is Some")
                            .expect("task was just advanced");
                        let m = task.into_metrics();
                        self.resolved += 1;
                        // Feed the SLO controller this completion's
                        // latency and adopt its (pure, replay-stable)
                        // plan — schedule-not-semantics, so per-request
                        // outputs are unaffected (ADR-011).
                        if let Some(a) = self.adaptive.as_mut() {
                            a.observe(m.total);
                            let plan = a.plan();
                            if plan != self.eff {
                                self.eff = plan;
                                self.stats.adaptations += 1;
                                if let Some(e) = self.exec.as_mut() {
                                    e.set_cap(plan.kb_parallel.max(1));
                                }
                            }
                        }
                        self.finished.push((self.slots[i].id, m));
                    }
                    TaskStep::NeedsVerify { queries, k } => {
                        let epoch = self.slots[i]
                            .task
                            .as_ref()
                            .map(|t| t.epoch())
                            .unwrap_or(0);
                        self.slots[i].awaiting = true;
                        self.stats.parked_rounds += 1;
                        self.pending.push(PendingVerify {
                            slot: i,
                            queries,
                            k,
                            epoch,
                            tenant: self.slots[i].tenant,
                            enqueued: Stopwatch::start(),
                        });
                    }
                }
            }

            // Overlap drive: offer every parked task one overlap step per
            // engine iteration, for as long as its verification is pending
            // or in flight — the multi-step generalization of "one extra
            // step before parking". Each task bounds its own step count
            // deterministically (state-based, never time-based), so
            // schedules stay reproducible.
            let mut overlapped = false;
            for i in 0..self.slots.len() {
                if !self.slots[i].awaiting {
                    continue;
                }
                if let Some(task) = self.slots[i].task.as_mut() {
                    if task.overlap_step()? {
                        self.stats.overlap_steps += 1;
                        overlapped = true;
                        progressed = true;
                    }
                }
            }

            // Size-or-deadline flush policy, plus a drain flush when the
            // runnable set is exhausted. The drain condition differs by
            // execution mode. Async: dispatch is free for the engine
            // thread (the call runs on a worker while overlap steps and
            // other calls continue), so flush as soon as no task is
            // runnable — but only while a `kb_parallel` slot is free; a
            // saturated executor would just freeze the batch's
            // composition in its backlog, so the buffer is held instead
            // (parking below, bounded by the flush deadline) where
            // in-flight completions can still unpark tasks that grow it.
            // Sync: the flush blocks the engine thread, so parked tasks
            // get to finish their overlap budgets first (that work could
            // never run during the call).
            if !self.pending.is_empty() {
                let pending_q: usize =
                    self.pending.iter().map(|p| p.queries.len()).sum();
                let drain = match &self.exec {
                    Some(exec) => runnable == 0 && exec.has_free_slot(),
                    None => runnable == 0 && !overlapped,
                };
                if pending_q >= self.eff.max_batch {
                    self.stats.size_flushes += 1;
                    self.flush()?;
                    progressed = true;
                } else if self.pending[0].enqueued.elapsed()
                    >= Duration::from_micros(self.eff.flush_us)
                {
                    self.stats.deadline_flushes += 1;
                    self.flush()?;
                    progressed = true;
                } else if drain {
                    self.stats.drain_flushes += 1;
                    self.flush()?;
                    progressed = true;
                }
            }

            if !progressed {
                // Nothing runnable, no overlap work left, nothing flushed
                // or routed: the only possible events are KB completions.
                // Park on the completion queue (no busy-spin), bounded by
                // the flush deadline when a batch is still coalescing so
                // the deadline flush fires on time.
                let outstanding = self
                    .exec
                    .as_ref()
                    .map(|e| e.outstanding())
                    .unwrap_or(0);
                if outstanding == 0 && self.force_admit_one() {
                    // Every waiting head was still gated on `after_done`
                    // with nothing in flight to resolve more requests:
                    // admit the closest one rather than stall (ADR-011
                    // deferred-arrival backstop).
                    self.stats.forced_admissions += 1;
                    continue;
                }
                anyhow::ensure!(outstanding > 0,
                                "engine stalled: tasks parked with no \
                                 in-flight KB call and nothing pending");
                let timeout = match self.pending.first() {
                    Some(p) => Duration::from_micros(self.eff.flush_us)
                        .saturating_sub(p.enqueued.elapsed())
                        .max(Duration::from_micros(1)),
                    None => Duration::from_millis(200),
                };
                self.stats.parks += 1;
                let done = self
                    .exec
                    .as_mut()
                    .and_then(|e| e.wait_complete(timeout));
                if let Some(done) = done {
                    self.route(done)?;
                }
                // On timeout the next iteration's deadline check flushes.
            }
        }
        if let Some(exec) = &self.exec {
            self.stats.kb_dispatches = exec.dispatches;
            self.stats.inflight_depth_sum = exec.depth_sum;
            self.stats.inflight_depth_max = exec.depth_max;
        }
        Ok(self.take_finished())
    }

    /// Drain completions without blocking.
    fn route_ready(&mut self) -> anyhow::Result<bool> {
        let mut any = false;
        loop {
            let done = match self.exec.as_mut() {
                Some(e) => e.try_complete(),
                None => None,
            };
            let Some(done) = done else { break };
            self.route(done)?;
            any = true;
        }
        Ok(any)
    }

    /// Issue the coalesced KB call(s) for everything in the buffer:
    /// grouped by (tenant, top-k, pinned epoch) — tasks with different
    /// prefetch sizes cannot share one retrieve_batch call, tasks pinned
    /// to different epochs must not (their snapshots score differently,
    /// ADR-006), and tasks of different tenants must not (each tenant
    /// owns its own knowledge base, ADR-011) — then dispatched to the
    /// executor (`kb_parallel >= 1`) or run inline against the group's
    /// snapshot. Within a group, submission order is preserved;
    /// per-query results are independent of batchmates, so sub-slice
    /// routing is bit-identical to per-task retrieval.
    fn flush(&mut self) -> anyhow::Result<()> {
        let mut batch = std::mem::take(&mut self.pending);
        if batch.is_empty() {
            return Ok(());
        }
        // Reuse the field-held group list (capacity survives flushes).
        self.flush_groups.clear();
        self.flush_groups
            .extend(batch.iter().map(|p| (p.tenant, p.k, p.epoch)));
        self.flush_groups.sort_unstable();
        self.flush_groups.dedup();
        let groups = std::mem::take(&mut self.flush_groups);
        // Attribute the extra calls this flush pays for isolation:
        // collapsing the tenant axis leaves the (k, epoch) groups — the
        // calls a single-tenant engine would have issued — and further
        // collapsing epochs leaves the per-k minimum. The differences
        // are the tenant- and epoch-forced splits respectively.
        let mut ke: Vec<(usize, u64)> =
            groups.iter().map(|&(_, k, e)| (k, e)).collect();
        ke.sort_unstable();
        ke.dedup();
        let mut ks: Vec<usize> = ke.iter().map(|&(k, _)| k).collect();
        ks.dedup();
        self.stats.tenant_splits += (groups.len() - ke.len()) as u64;
        self.stats.epoch_splits += (ke.len() - ks.len()) as u64;
        for &(tenant, k, epoch) in &groups {
            // Single pass over the buffer: move (not clone) each member's
            // queries into the coalesced call. A member's queries are
            // consumed exactly once — its (tenant, k, epoch) matches
            // exactly one entry of the deduped group list.
            let mut queries: Vec<SpecQuery> = Vec::new();
            let mut members: Vec<GroupMember> = Vec::new();
            // Per-member coalescing delay is snapshotted immediately
            // before the group's KB call starts — on the worker for
            // dispatched groups (so executor-backlog time counts too),
            // right here for inline ones.
            let mut enqueued: Vec<Stopwatch> = Vec::new();
            for p in batch.iter_mut() {
                if p.tenant != tenant || p.k != k || p.epoch != epoch {
                    continue;
                }
                members.push(GroupMember {
                    slot: p.slot,
                    n_queries: p.queries.len(),
                });
                enqueued.push(p.enqueued);
                queries.append(&mut p.queries);
            }
            // Resolve the group's snapshot. Epoch 0 falls back to the
            // engine's default KB (the frozen-KB path); a *nonzero*
            // pinned epoch with no registered snapshot must not be
            // silently scored by the wrong KB — that is exactly the bug
            // class ADR-006 exists to prevent — so the group fails loudly
            // while the engine keeps serving everyone else.
            let kb = match self.epoch_kbs.get(&(tenant, epoch)) {
                Some(kb) => kb.clone(),
                None if epoch == 0 => self.kb.clone(),
                None => {
                    self.fail_group(
                        &members,
                        &format!("tenant {tenant} task pinned to epoch \
                                  {epoch} but no snapshot was registered \
                                  for it \
                                  (ServeEngine::register_tenant_epoch)"));
                    continue;
                }
            };
            let group = self.next_group;
            self.next_group += 1;
            self.dispatched.insert(group, members);
            match self.exec.as_mut() {
                Some(exec) => {
                    exec.submit(PreparedCall { group, queries, k, kb,
                                               enqueued });
                }
                None => {
                    // Synchronous inline flush (kb_parallel == 0): the
                    // engine thread blocks for the call, as before
                    // ADR-005. Panics still convert to a per-group error.
                    self.stats.kb_dispatches += 1;
                    self.stats.inflight_depth_sum += 1;
                    self.stats.inflight_depth_max =
                        self.stats.inflight_depth_max.max(1);
                    let member_waits: Vec<Duration> =
                        enqueued.iter().map(|s| s.elapsed()).collect();
                    let sw = Stopwatch::start();
                    let result =
                        run_caught(|| kb.retrieve_batch(&queries, k));
                    let outcome = CallOutcome {
                        group,
                        result,
                        kb_time: sw.elapsed(),
                        member_waits,
                    };
                    self.route(outcome)?;
                }
            }
        }
        // Hand the group list's allocation back to the field and recycle
        // the drained buffer as the next coalescing buffer (`route` never
        // touches `pending`, so it is still the empty Vec `take` left).
        self.flush_groups = groups;
        debug_assert!(self.pending.is_empty());
        batch.clear();
        self.pending = batch;
        Ok(())
    }

    /// Route one completed coalesced call: hand each member task exactly
    /// its own sub-slice of rows (bit-identical to a per-task call), or —
    /// on a failed call — convert every member request into a reported
    /// failure and free its slot so the engine keeps serving the rest.
    fn route(&mut self, done: CallOutcome) -> anyhow::Result<()> {
        let members = self
            .dispatched
            .remove(&done.group)
            // detlint: allow(hot-panic, reason = "group ids are inserted at dispatch and each completes exactly once")
            .expect("completion for unknown group");
        let total: usize = members.iter().map(|m| m.n_queries).sum();
        let mut results = match done.result {
            Ok(results) => {
                if results.len() != total {
                    self.fail_group(
                        &members,
                        &format!("retriever returned {} rows for {} \
                                  queries", results.len(), total));
                    return Ok(());
                }
                results
            }
            Err(e) => {
                self.fail_group(&members, &format!("{e:#}"));
                return Ok(());
            }
        };
        self.stats.kb_calls += 1;
        self.stats.coalesced_queries += total as u64;
        self.stats.max_coalesced =
            self.stats.max_coalesced.max(total as u64);
        self.stats.kb_time += done.kb_time;
        for (gi, gm) in members.iter().enumerate() {
            let rest = results.split_off(gm.n_queries);
            let rows = std::mem::replace(&mut results, rest);
            let slot = &mut self.slots[gm.slot];
            let task = slot.task.as_mut()
                // detlint: allow(hot-panic, reason = "a slot in Awaiting keeps its task until its group is routed")
                .expect("awaiting slot holds its task");
            // Finish the task's overlap budget before handing it results.
            // The budget is state-based; draining it here makes the
            // number of overlap steps per verification round independent
            // of KB completion timing — a fast completion must not cut
            // the schedule short, or per-request schedule metrics
            // (spec_steps / strides) would become wall-clock noise. This
            // mirrors the sequential async driver, which drains to
            // exhaustion before blocking on the verifier thread.
            while task.overlap_step()? {
                self.stats.overlap_steps += 1;
            }
            task.metrics_mut().queue_wait += done.member_waits[gi];
            task.provide(rows, done.kb_time)?;
            slot.awaiting = false;
        }
        Ok(())
    }

    /// A KB call failed (worker panic or malformed result): every member
    /// request becomes a reported failure, its slot frees for the next
    /// admission, and the engine keeps going.
    fn fail_group(&mut self, members: &[GroupMember], msg: &str) {
        self.stats.kb_failures += 1;
        for gm in members {
            let slot = &mut self.slots[gm.slot];
            slot.task = None;
            slot.awaiting = false;
            self.resolved += 1;
            self.failed.push((
                slot.id,
                format!("knowledge-base call failed: {msg}"),
            ));
        }
    }
}

/// Per-request [`SpecOptions`] for a router [`Method::Spec`] request —
/// delegates to the shared [`SpecOptions::for_method`] constructor so
/// router-served requests stay bit-identical to eval-served ones.
pub fn spec_options_for(cfg: &Config, prefetch: bool, os3: bool,
                        async_verify: bool) -> SpecOptions {
    SpecOptions::for_method(
        cfg, if prefetch { cfg.spec.prefetch } else { 1 }, os3,
        async_verify, cfg.spec.stride)
}

/// Router backend that multiplexes [`Method::Spec`] requests through a
/// [`ServeEngine`]: the router worker drains up to `preferred_batch()`
/// queued jobs and hands them over as one `serve_batch` call, so
/// cross-request coalescing happens *inside* a worker. `Method::Baseline`
/// requests in the same drain are served inline via [`RalmSeq`].
///
/// With `live` set, the worker serves a **live** knowledge base
/// (ADR-006): every query request pins the [`EpochSnapshot`] current at
/// its admission (cache scoring, verification, and document reads all go
/// to that one epoch — bit-identical to a sequential run against it),
/// and [`Method::Ingest`] requests feed the shared
/// [`KbWriter`](crate::retriever::KbWriter), publishing new epochs as
/// batches fill. Without `live`, behaviour is exactly the frozen-KB
/// engine of PRs 2–4.
pub struct EngineBackend<L: LanguageModel> {
    pub lm: L,
    pub kb: std::sync::Arc<dyn Retriever>,
    pub corpus: std::sync::Arc<Corpus>,
    pub encoder: Box<dyn Encoder>,
    pub mode: QueryMode,
    pub cfg: Config,
    pub engine_opts: EngineOptions,
    /// Live knowledge base (epoch snapshots + writer); `None` serves the
    /// frozen `kb`/`corpus` pair above.
    pub live: Option<std::sync::Arc<LiveKb>>,
    /// Per-tenant live knowledge bases (ADR-011): tenant `t`'s requests
    /// pin snapshots from — and ingest into — `tenant_kbs[t]`, so one
    /// tenant's ingest storm advances only its own epoch stream. Empty =
    /// single-tenant serving (tenant 0 falls back to `live`, every other
    /// tenant serves the frozen default KB).
    pub tenant_kbs: Vec<std::sync::Arc<LiveKb>>,
}

impl<L: LanguageModel> EngineBackend<L> {
    fn query_builder(&self) -> QueryBuilder<'_> {
        QueryBuilder {
            encoder: self.encoder.as_ref(),
            mode: self.mode,
            dense_len: self.cfg.retriever.dense_query_len,
            sparse_len: self.cfg.retriever.sparse_query_len,
        }
    }

    /// Serve one [`Method::Ingest`] request: embed the document on this
    /// worker's encoder (the encoder is not `Send`, so embedding cannot
    /// happen inside the writer), hand it to the shared writer, and
    /// report the epoch it landed in (or the current epoch while the
    /// batch is still filling).
    fn serve_ingest(&self, live: &LiveKb, req: &Request)
                    -> anyhow::Result<ReqMetrics> {
        let sw = Stopwatch::start();
        let window =
            &req.question[..req.question.len().min(self.encoder.window())];
        let embedding = self.encoder.encode(window);
        // detlint: allow(hot-panic, reason = "mutex poisoning propagates a writer-thread panic; continuing would serve a torn index")
        let mut writer = live.writer.lock().unwrap();
        let published =
            writer.ingest(req.question.clone(), 0, embedding)?;
        Ok(ReqMetrics {
            epoch: published.unwrap_or_else(|| writer.epochs().epoch()),
            total: sw.elapsed(),
            ..ReqMetrics::default()
        })
    }
}

impl<L: LanguageModel> ServeBackend for EngineBackend<L> {
    fn serve(&mut self, req: &Request) -> anyhow::Result<ReqMetrics> {
        let mut out = self.serve_batch(std::slice::from_ref(req));
        // detlint: allow(hot-panic, reason = "serve_batch returns exactly one result per input request")
        out.pop().expect("serve_batch returns one result per request")
    }

    fn preferred_batch(&self) -> usize {
        self.engine_opts.max_batch.max(1)
    }

    fn serve_batch(&mut self, reqs: &[Request])
                   -> Vec<anyhow::Result<ReqMetrics>> {
        let queries = self.query_builder();
        let live = self.live.clone();
        let tenant_kbs = self.tenant_kbs.clone();
        let mut results: Vec<Option<anyhow::Result<ReqMetrics>>> =
            reqs.iter().map(|_| None).collect();
        // Admission pass: ingest requests go to their tenant's writer
        // immediately (so a drain's later query requests already see
        // their epochs), and every query request pins the snapshot of
        // *its own tenant's* KB current at its own admission (ADR-011).
        // `pins` is declared before the engine so the tasks below may
        // borrow from the pinned snapshots.
        let mut pins: Vec<Option<std::sync::Arc<EpochSnapshot>>> =
            Vec::with_capacity(reqs.len());
        for (i, req) in reqs.iter().enumerate() {
            // Per-tenant KB resolution: an explicit `tenant_kbs[t]`
            // wins; tenant 0 falls back to the single-tenant `live`; any
            // other tenant without a registered KB serves the frozen
            // default (queries fine at epoch 0, ingest rejected below).
            let lkb = {
                let t = req.tenant as usize;
                if t < tenant_kbs.len() {
                    Some(&tenant_kbs[t])
                } else if req.tenant == 0 {
                    live.as_ref()
                } else {
                    None
                }
            };
            match (lkb, req.method) {
                (Some(l), Method::Ingest) => {
                    results[i] = Some(self.serve_ingest(l, req));
                    pins.push(None);
                }
                (None, Method::Ingest) => {
                    results[i] = Some(Err(anyhow::anyhow!(
                        "request {}: Method::Ingest needs a live \
                         knowledge base for tenant {} (this worker \
                         serves a frozen corpus)", req.id, req.tenant)));
                    pins.push(None);
                }
                (Some(l), _) => pins.push(Some(l.epochs.snapshot())),
                (None, _) => pins.push(None),
            }
        }
        let mut engine: ServeEngine<SpecTask<L>> =
            ServeEngine::new(self.kb.clone(), self.engine_opts.clone());
        for (req, pin) in reqs.iter().zip(pins.iter()) {
            if let Some(p) = pin {
                engine.register_tenant_epoch(req.tenant, p.epoch,
                                             p.kb.clone());
            }
        }
        for (i, req) in reqs.iter().enumerate() {
            if results[i].is_some() {
                continue; // ingest (or error) already resolved
            }
            let (kb, corpus, epoch): (&dyn Retriever, &Corpus, u64) =
                match pins[i].as_ref() {
                    Some(p) => (p.kb.as_ref(), &*p.corpus, p.epoch),
                    None => (self.kb.as_ref(), self.corpus.as_ref(), 0),
                };
            match req.method {
                Method::Baseline => {
                    let pipe = RalmSeq {
                        lm: &self.lm,
                        kb,
                        corpus,
                        queries,
                        opts: BaselineOptions {
                            gen_stride: self.cfg.spec.gen_stride,
                            max_new: self.cfg.spec.max_new_tokens,
                            max_doc_tokens: self.cfg.spec.max_doc_tokens,
                        },
                    };
                    // Baseline requests pin the same snapshot as spec
                    // ones; stamp the epoch so their metrics attribute
                    // it correctly too.
                    results[i] = Some(pipe.run(&req.question).map(
                        |mut m| {
                            m.epoch = epoch;
                            m
                        }));
                }
                Method::Spec { prefetch, os3, async_verify } => {
                    engine.submit_opts(
                        i as u64,
                        SpecTask::new(
                            &self.lm, kb, corpus, queries,
                            spec_options_for(&self.cfg, prefetch, os3,
                                             async_verify),
                            &req.question)
                            .pin_epoch(epoch)
                            .pin_tenant(req.tenant),
                        SubmitOpts { tenant: req.tenant,
                                     class: req.class,
                                     after_done: 0 });
                }
                Method::Knn => {
                    results[i] = Some(Err(anyhow::anyhow!(
                        "request {}: Method::Knn needs a KnnEngineBackend \
                         (this worker serves the QA corpus)", req.id)));
                }
                // detlint: allow(hot-panic, reason = "ingest requests are resolved (or rejected) in the admission pass above")
                Method::Ingest => unreachable!("resolved in admission pass"),
            }
        }
        resolve_engine_run(&mut engine, &mut results);
        results
            .into_iter()
            // detlint: allow(hot-panic, reason = "admission + engine-run passes fill every results slot")
            .map(|r| r.expect("every request resolved"))
            .collect()
    }
}

/// Run a filled engine and slot its per-request outcomes into `results`:
/// completions as `Ok`, KB-call failures ([`ServeEngine::take_failed`])
/// as per-request errors. On a run-level failure, requests that completed
/// before the failing one are salvaged; only the genuinely unresolved
/// ones get the run error (anyhow::Error is not Clone, so it is formatted
/// once).
fn resolve_engine_run<T: ServeTask>(
    engine: &mut ServeEngine<T>,
    results: &mut [Option<anyhow::Result<ReqMetrics>>]) {
    let run = engine.run();
    match run {
        Ok(done) => {
            for (i, m) in done {
                results[i as usize] = Some(Ok(m));
            }
        }
        Err(e) => {
            for (i, m) in engine.take_finished() {
                results[i as usize] = Some(Ok(m));
            }
            for (i, msg) in engine.take_failed() {
                results[i as usize] = Some(Err(anyhow::anyhow!("{msg}")));
            }
            let msg = format!("{e:#}");
            for r in results.iter_mut() {
                if r.is_none() {
                    *r = Some(Err(anyhow::anyhow!(
                        "engine run failed: {msg}")));
                }
            }
            return;
        }
    }
    for (i, msg) in engine.take_failed() {
        results[i as usize] = Some(Err(anyhow::anyhow!("{msg}")));
    }
}

/// Router backend for the KNN-LM workload (paper §5.3 — one retrieval per
/// generated token, the highest-leverage coalescing target):
/// [`Method::Knn`] requests become [`KnnTask`]s multiplexed through a
/// [`ServeEngine`] over the datastore retriever, so concurrent requests
/// share `retrieve_batch` calls for both their cache primes and their
/// relaxed-verification strides. [`Method::Baseline`] requests in the same
/// drain are served inline via [`KnnLmBaseline`] (per-token retrieval).
pub struct KnnEngineBackend<L: LanguageModel> {
    pub lm: L,
    /// Retriever over the datastore keys (exact or HNSW, possibly
    /// sharded).
    pub kb: std::sync::Arc<dyn Retriever>,
    pub ds: std::sync::Arc<Datastore>,
    pub opts: KnnServeOptions,
    pub engine_opts: EngineOptions,
}

impl<L: LanguageModel> ServeBackend for KnnEngineBackend<L> {
    fn serve(&mut self, req: &Request) -> anyhow::Result<ReqMetrics> {
        let mut out = self.serve_batch(std::slice::from_ref(req));
        // detlint: allow(hot-panic, reason = "serve_batch returns exactly one result per input request")
        out.pop().expect("serve_batch returns one result per request")
    }

    fn preferred_batch(&self) -> usize {
        self.engine_opts.max_batch.max(1)
    }

    fn serve_batch(&mut self, reqs: &[Request])
                   -> Vec<anyhow::Result<ReqMetrics>> {
        let mut engine: ServeEngine<KnnTask<L>> =
            ServeEngine::new(self.kb.clone(), self.engine_opts.clone());
        let mut results: Vec<Option<anyhow::Result<ReqMetrics>>> =
            reqs.iter().map(|_| None).collect();
        for (i, req) in reqs.iter().enumerate() {
            match req.method {
                Method::Knn => {
                    engine.submit_opts(
                        i as u64,
                        KnnTask::new(&self.lm, self.ds.as_ref(),
                                     self.opts.clone(), &req.question)
                            .pin_tenant(req.tenant),
                        SubmitOpts { tenant: req.tenant,
                                     class: req.class,
                                     after_done: 0 });
                }
                Method::Baseline => {
                    let pipe = KnnLmBaseline {
                        lm: &self.lm,
                        kb: self.kb.as_ref(),
                        ds: self.ds.as_ref(),
                        opts: self.opts.clone(),
                    };
                    results[i] = Some(pipe.run(&req.question));
                }
                Method::Spec { .. } => {
                    results[i] = Some(Err(anyhow::anyhow!(
                        "request {}: Method::Spec needs a QA EngineBackend \
                         (this worker serves the KNN-LM datastore)",
                        req.id)));
                }
                Method::Ingest => {
                    results[i] = Some(Err(anyhow::anyhow!(
                        "request {}: Method::Ingest targets the QA \
                         knowledge base (this worker serves the KNN-LM \
                         datastore; live datastore growth is driven \
                         through the epoch layer directly, not the \
                         router)", req.id)));
                }
            }
        }
        resolve_engine_run(&mut engine, &mut results);
        results
            .into_iter()
            // detlint: allow(hot-panic, reason = "admission + engine-run passes fill every results slot")
            .map(|r| r.expect("every request resolved"))
            .collect()
    }
}
