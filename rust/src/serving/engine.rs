//! Concurrent serving engine: multiplex N in-flight [`ServeTask`]s and
//! coalesce their pending verification queries into shared
//! `kb.retrieve_batch` calls (DESIGN.md ADR-003 / ADR-004).
//!
//! The paper's batched verification amortizes retrieval *within* one
//! request's speculation stride; at serving scale the same batch-first
//! retrieval primitive amortizes *across* concurrent requests. The engine
//! drives each task one speculation step at a time (fair interleaving),
//! parks tasks that emit `NeedsVerify`, and flushes the accumulated
//! queries under a **size-or-deadline** policy (`engine.max_batch`
//! queries, or the oldest query aging past `engine.flush_us`, or nothing
//! else can make progress). Queries are grouped by their top-k so tasks
//! with different prefetch sizes never share a call.
//!
//! The engine is generic over the task kind ([`ServeTask`], ADR-004): QA
//! speculation ([`SpecTask`]) and KNN-LM per-token serving
//! ([`crate::knnlm::KnnTask`] — the paper's highest-leverage workload, one
//! retrieval per generated token) coalesce through the same scheduler and
//! flush policy.
//!
//! **Why per-request outputs survive coalescing bit-for-bit**: every
//! retriever scores a query independently of its batchmates (the
//! bit-identity pinned by the fig6 driver and
//! tests/sharded_equivalence.rs), so the sub-slice of a coalesced call
//! routed back to a task is exactly what the task's own
//! `retrieve_batch` would have returned. The equivalence suites
//! (tests/engine_equivalence.rs, tests/knnlm_engine_equivalence.rs) check
//! engine output against sequential `SpecPipeline::run` /
//! `KnnLmSpec::run` per request at concurrency 1/8/32.

use crate::baseline::{BaselineOptions, RalmSeq};
use crate::config::Config;
use crate::datagen::{Corpus, Encoder};
use crate::knnlm::{Datastore, KnnLmBaseline, KnnServeOptions, KnnTask};
use crate::lm::LanguageModel;
use crate::metrics::{ReqMetrics, Stopwatch};
use crate::retriever::{Retriever, SpecQuery};
use crate::serving::router::{Method, Request, ServeBackend};
use crate::serving::task::{ServeTask, TaskStep};
use crate::spec::{QueryBuilder, QueryMode, SpecOptions, SpecTask};
use std::collections::VecDeque;
use std::time::Duration;

#[derive(Debug, Clone)]
pub struct EngineOptions {
    /// Flush the coalescing buffer when this many queries are pending.
    pub max_batch: usize,
    /// ... or when the oldest pending query has waited this long (µs).
    pub flush_us: u64,
    /// In-flight request cap (admission control); 0 = unlimited.
    pub max_inflight: usize,
}

impl Default for EngineOptions {
    fn default() -> Self {
        let c = crate::config::EngineConfig::default();
        Self { max_batch: c.max_batch, flush_us: c.flush_us, max_inflight: 0 }
    }
}

impl EngineOptions {
    pub fn from_config(cfg: &Config, max_inflight: usize) -> Self {
        Self {
            max_batch: cfg.engine.max_batch.max(1),
            flush_us: cfg.engine.flush_us,
            max_inflight,
        }
    }
}

/// Engine-level counters (per-request metrics live in each task's
/// [`ReqMetrics`]; `queue_wait` there is attributed by the engine).
#[derive(Debug, Clone, Default)]
pub struct EngineStats {
    /// Coalesced KB calls actually issued.
    pub kb_calls: u64,
    /// Queries answered across those calls.
    pub coalesced_queries: u64,
    /// Largest coalesced batch seen.
    pub max_coalesced: u64,
    pub size_flushes: u64,
    pub deadline_flushes: u64,
    /// Flushes forced because no task could progress without results.
    pub drain_flushes: u64,
    /// Total wall time inside coalesced KB calls.
    pub kb_time: Duration,
}

impl EngineStats {
    /// Mean queries per coalesced KB call — the cross-request batching
    /// factor (1.0 means coalescing never helped).
    pub fn mean_coalesced(&self) -> f64 {
        if self.kb_calls == 0 {
            return 0.0;
        }
        self.coalesced_queries as f64 / self.kb_calls as f64
    }
}

/// A task slot. Slots are recycled (never removed) so the coalescing
/// buffer can hold stable slot indices across admissions.
struct Slot<T> {
    id: u64,
    task: Option<T>,
    /// True while the task's `NeedsVerify` sits in the coalescing buffer.
    awaiting: bool,
}

/// One parked verification batch awaiting flush.
struct PendingVerify {
    slot: usize,
    queries: Vec<SpecQuery>,
    k: usize,
    enqueued: Stopwatch,
}

pub struct ServeEngine<'a, T: ServeTask> {
    kb: &'a dyn Retriever,
    opts: EngineOptions,
    /// Admission queue; tasks are constructed at submission so each
    /// request's latency clock covers its admission-queue wait too.
    waiting: VecDeque<(u64, T)>,
    slots: Vec<Slot<T>>,
    pending: Vec<PendingVerify>,
    stats: EngineStats,
    finished: Vec<(u64, ReqMetrics)>,
}

impl<'a, T: ServeTask> ServeEngine<'a, T> {
    pub fn new(kb: &'a dyn Retriever, opts: EngineOptions) -> Self {
        Self {
            kb,
            opts,
            waiting: VecDeque::new(),
            slots: Vec::new(),
            pending: Vec::new(),
            stats: EngineStats::default(),
            finished: Vec::new(),
        }
    }

    /// Enqueue one request's task (construct it at submission so the
    /// request's latency clock covers its admission-queue wait too —
    /// reported p50/p99 then include what a client would observe, not
    /// just in-flight service time). Admission happens inside
    /// [`run`](Self::run), honouring `max_inflight`.
    pub fn submit(&mut self, id: u64, task: T) {
        self.waiting.push_back((id, task));
    }

    pub fn stats(&self) -> &EngineStats {
        &self.stats
    }

    /// Drain the results collected so far. [`run`](Self::run) returns them
    /// on success; after a `run` error this lets the caller salvage the
    /// requests that completed before the failing one, instead of
    /// reporting the whole coalesced batch as failed.
    pub fn take_finished(&mut self) -> Vec<(u64, ReqMetrics)> {
        self.finished.sort_by_key(|(id, _)| *id);
        std::mem::take(&mut self.finished)
    }

    fn inflight(&self) -> usize {
        self.slots.iter().filter(|s| s.task.is_some()).count()
    }

    fn admit(&mut self) {
        let cap = if self.opts.max_inflight == 0 {
            usize::MAX
        } else {
            self.opts.max_inflight
        };
        while self.inflight() < cap {
            let Some((id, task)) = self.waiting.pop_front() else {
                break;
            };
            // Recycle a free slot (its pending entries, if any existed,
            // were consumed before the slot was freed) to keep the slot
            // indices stored in `pending` stable.
            match self.slots.iter().position(|s| s.task.is_none()) {
                Some(i) => {
                    self.slots[i] =
                        Slot { id, task: Some(task), awaiting: false };
                }
                None => {
                    self.slots.push(
                        Slot { id, task: Some(task), awaiting: false });
                }
            }
        }
    }

    /// Drive every submitted request to completion, coalescing
    /// verification batches across them. Returns `(id, metrics)` sorted by
    /// request id; per-request `tokens_out` is bit-identical to driving
    /// the same task alone (`SpecPipeline::run` / `KnnLmSpec::run`).
    #[allow(clippy::needless_range_loop)] // indices outlive `slots` borrows
    pub fn run(&mut self) -> anyhow::Result<Vec<(u64, ReqMetrics)>> {
        loop {
            self.admit();
            if self.waiting.is_empty()
                && self.slots.iter().all(|s| s.task.is_none())
            {
                break;
            }

            // One speculation step (or one parked batch) per runnable
            // task: round-robin keeps N tasks' steps interleaved so their
            // verification points line up inside the coalescing window.
            let mut runnable = 0usize;
            for i in 0..self.slots.len() {
                if self.slots[i].awaiting {
                    continue;
                }
                let step = {
                    let Some(task) = self.slots[i].task.as_mut() else {
                        continue;
                    };
                    let step = task.advance()?;
                    if matches!(step, TaskStep::NeedsVerify { .. }) {
                        // Start the async overlap step (if the task's
                        // options ask for one) before parking the batch.
                        task.overlap_step()?;
                    }
                    step
                };
                match step {
                    TaskStep::Continue => runnable += 1,
                    TaskStep::Done => {
                        let task = self.slots[i].task.take()
                            .expect("task was just advanced");
                        self.finished
                            .push((self.slots[i].id, task.into_metrics()));
                    }
                    TaskStep::NeedsVerify { queries, k } => {
                        self.slots[i].awaiting = true;
                        self.pending.push(PendingVerify {
                            slot: i,
                            queries,
                            k,
                            enqueued: Stopwatch::start(),
                        });
                    }
                }
            }

            // Size-or-deadline flush policy (drain when nothing else can
            // move: every in-flight task is parked and no admission is
            // possible, so waiting any longer cannot grow the batch).
            if !self.pending.is_empty() {
                let pending_q: usize =
                    self.pending.iter().map(|p| p.queries.len()).sum();
                let admissible = !self.waiting.is_empty()
                    && (self.opts.max_inflight == 0
                        || self.inflight() < self.opts.max_inflight);
                if pending_q >= self.opts.max_batch {
                    self.stats.size_flushes += 1;
                    self.flush()?;
                } else if runnable == 0 && !admissible {
                    self.stats.drain_flushes += 1;
                    self.flush()?;
                } else if self.pending[0].enqueued.elapsed()
                    >= Duration::from_micros(self.opts.flush_us)
                {
                    self.stats.deadline_flushes += 1;
                    self.flush()?;
                }
            }
        }
        Ok(self.take_finished())
    }

    /// Issue the coalesced KB call(s) for everything in the buffer and
    /// route each sub-slice of results back to its owning task.
    fn flush(&mut self) -> anyhow::Result<()> {
        let batch = std::mem::take(&mut self.pending);
        if batch.is_empty() {
            return Ok(());
        }
        // Group by top-k: tasks with different prefetch sizes cannot share
        // one retrieve_batch call. Within a group, submission order is
        // preserved; per-query results are independent of batchmates, so
        // sub-slice routing is bit-identical to per-task retrieval.
        let mut ks: Vec<usize> = batch.iter().map(|p| p.k).collect();
        ks.sort_unstable();
        ks.dedup();
        for k in ks {
            let idxs: Vec<usize> =
                (0..batch.len()).filter(|&i| batch[i].k == k).collect();
            let coalesced: Vec<SpecQuery> = idxs
                .iter()
                .flat_map(|&i| batch[i].queries.iter().cloned())
                .collect();
            // Coalescing delay, snapshotted immediately before *this*
            // group's KB call: with mixed top-k in one flush, a later
            // group's wait includes the earlier groups' KB time (its
            // queries really were still unanswered while those ran).
            let group_waits: Vec<Duration> =
                idxs.iter().map(|&i| batch[i].enqueued.elapsed()).collect();
            let sw = Stopwatch::start();
            let mut results = self.kb.retrieve_batch(&coalesced, k);
            let kb_time = sw.elapsed();
            anyhow::ensure!(results.len() == coalesced.len(),
                            "retriever returned {} rows for {} queries",
                            results.len(), coalesced.len());
            self.stats.kb_calls += 1;
            self.stats.coalesced_queries += coalesced.len() as u64;
            self.stats.max_coalesced =
                self.stats.max_coalesced.max(coalesced.len() as u64);
            self.stats.kb_time += kb_time;
            for (gi, &i) in idxs.iter().enumerate() {
                let p = &batch[i];
                let rest = results.split_off(p.queries.len());
                let rows = std::mem::replace(&mut results, rest);
                let slot = &mut self.slots[p.slot];
                let task = slot.task.as_mut()
                    .expect("awaiting slot holds its task");
                task.metrics_mut().queue_wait += group_waits[gi];
                task.provide(rows, kb_time)?;
                slot.awaiting = false;
            }
        }
        Ok(())
    }
}

/// Per-request [`SpecOptions`] for a router [`Method::Spec`] request —
/// delegates to the shared [`SpecOptions::for_method`] constructor so
/// router-served requests stay bit-identical to eval-served ones.
pub fn spec_options_for(cfg: &Config, prefetch: bool, os3: bool,
                        async_verify: bool) -> SpecOptions {
    SpecOptions::for_method(
        cfg, if prefetch { cfg.spec.prefetch } else { 1 }, os3,
        async_verify, cfg.spec.stride)
}

/// Router backend that multiplexes [`Method::Spec`] requests through a
/// [`ServeEngine`]: the router worker drains up to `preferred_batch()`
/// queued jobs and hands them over as one `serve_batch` call, so
/// cross-request coalescing happens *inside* a worker. `Method::Baseline`
/// requests in the same drain are served inline via [`RalmSeq`].
pub struct EngineBackend<L: LanguageModel> {
    pub lm: L,
    pub kb: std::sync::Arc<dyn Retriever>,
    pub corpus: std::sync::Arc<Corpus>,
    pub encoder: Box<dyn Encoder>,
    pub mode: QueryMode,
    pub cfg: Config,
    pub engine_opts: EngineOptions,
}

impl<L: LanguageModel> EngineBackend<L> {
    fn query_builder(&self) -> QueryBuilder<'_> {
        QueryBuilder {
            encoder: self.encoder.as_ref(),
            mode: self.mode,
            dense_len: self.cfg.retriever.dense_query_len,
            sparse_len: self.cfg.retriever.sparse_query_len,
        }
    }
}

impl<L: LanguageModel> ServeBackend for EngineBackend<L> {
    fn serve(&mut self, req: &Request) -> anyhow::Result<ReqMetrics> {
        let mut out = self.serve_batch(std::slice::from_ref(req));
        out.pop().expect("serve_batch returns one result per request")
    }

    fn preferred_batch(&self) -> usize {
        self.engine_opts.max_batch.max(1)
    }

    fn serve_batch(&mut self, reqs: &[Request])
                   -> Vec<anyhow::Result<ReqMetrics>> {
        let queries = self.query_builder();
        let mut engine: ServeEngine<SpecTask<L>> =
            ServeEngine::new(self.kb.as_ref(), self.engine_opts.clone());
        let mut results: Vec<Option<anyhow::Result<ReqMetrics>>> =
            reqs.iter().map(|_| None).collect();
        for (i, req) in reqs.iter().enumerate() {
            match req.method {
                Method::Baseline => {
                    let pipe = RalmSeq {
                        lm: &self.lm,
                        kb: self.kb.as_ref(),
                        corpus: self.corpus.as_ref(),
                        queries,
                        opts: BaselineOptions {
                            gen_stride: self.cfg.spec.gen_stride,
                            max_new: self.cfg.spec.max_new_tokens,
                            max_doc_tokens: self.cfg.spec.max_doc_tokens,
                        },
                    };
                    results[i] = Some(pipe.run(&req.question));
                }
                Method::Spec { prefetch, os3, async_verify } => {
                    engine.submit(
                        i as u64,
                        SpecTask::new(
                            &self.lm, self.kb.as_ref(),
                            self.corpus.as_ref(), queries,
                            spec_options_for(&self.cfg, prefetch, os3,
                                             async_verify),
                            &req.question));
                }
                Method::Knn => {
                    results[i] = Some(Err(anyhow::anyhow!(
                        "request {}: Method::Knn needs a KnnEngineBackend \
                         (this worker serves the QA corpus)", req.id)));
                }
            }
        }
        resolve_engine_run(&mut engine, &mut results);
        results
            .into_iter()
            .map(|r| r.expect("every request resolved"))
            .collect()
    }
}

/// Run a filled engine and slot its per-request outcomes into `results`.
/// On failure, requests that completed before the failing one are
/// salvaged; only the genuinely unresolved ones get the error
/// (anyhow::Error is not Clone, so it is formatted once).
fn resolve_engine_run<T: ServeTask>(
    engine: &mut ServeEngine<T>,
    results: &mut [Option<anyhow::Result<ReqMetrics>>]) {
    match engine.run() {
        Ok(done) => {
            for (i, m) in done {
                results[i as usize] = Some(Ok(m));
            }
        }
        Err(e) => {
            for (i, m) in engine.take_finished() {
                results[i as usize] = Some(Ok(m));
            }
            let msg = format!("{e:#}");
            for r in results.iter_mut() {
                if r.is_none() {
                    *r = Some(Err(anyhow::anyhow!(
                        "engine run failed: {msg}")));
                }
            }
        }
    }
}

/// Router backend for the KNN-LM workload (paper §5.3 — one retrieval per
/// generated token, the highest-leverage coalescing target):
/// [`Method::Knn`] requests become [`KnnTask`]s multiplexed through a
/// [`ServeEngine`] over the datastore retriever, so concurrent requests
/// share `retrieve_batch` calls for both their cache primes and their
/// relaxed-verification strides. [`Method::Baseline`] requests in the same
/// drain are served inline via [`KnnLmBaseline`] (per-token retrieval).
pub struct KnnEngineBackend<L: LanguageModel> {
    pub lm: L,
    /// Retriever over the datastore keys (exact or HNSW, possibly
    /// sharded).
    pub kb: std::sync::Arc<dyn Retriever>,
    pub ds: std::sync::Arc<Datastore>,
    pub opts: KnnServeOptions,
    pub engine_opts: EngineOptions,
}

impl<L: LanguageModel> ServeBackend for KnnEngineBackend<L> {
    fn serve(&mut self, req: &Request) -> anyhow::Result<ReqMetrics> {
        let mut out = self.serve_batch(std::slice::from_ref(req));
        out.pop().expect("serve_batch returns one result per request")
    }

    fn preferred_batch(&self) -> usize {
        self.engine_opts.max_batch.max(1)
    }

    fn serve_batch(&mut self, reqs: &[Request])
                   -> Vec<anyhow::Result<ReqMetrics>> {
        let mut engine: ServeEngine<KnnTask<L>> =
            ServeEngine::new(self.kb.as_ref(), self.engine_opts.clone());
        let mut results: Vec<Option<anyhow::Result<ReqMetrics>>> =
            reqs.iter().map(|_| None).collect();
        for (i, req) in reqs.iter().enumerate() {
            match req.method {
                Method::Knn => {
                    engine.submit(
                        i as u64,
                        KnnTask::new(&self.lm, self.ds.as_ref(),
                                     self.opts.clone(), &req.question));
                }
                Method::Baseline => {
                    let pipe = KnnLmBaseline {
                        lm: &self.lm,
                        kb: self.kb.as_ref(),
                        ds: self.ds.as_ref(),
                        opts: self.opts.clone(),
                    };
                    results[i] = Some(pipe.run(&req.question));
                }
                Method::Spec { .. } => {
                    results[i] = Some(Err(anyhow::anyhow!(
                        "request {}: Method::Spec needs a QA EngineBackend \
                         (this worker serves the KNN-LM datastore)",
                        req.id)));
                }
            }
        }
        resolve_engine_run(&mut engine, &mut results);
        results
            .into_iter()
            .map(|r| r.expect("every request resolved"))
            .collect()
    }
}
