//! Request router: bounded queue, N worker threads, per-request method
//! selection (baseline / RaLMSpec / KNN-LM), backpressure on overload.
//!
//! Std-threads only (the offline image has no tokio): submit() is
//! non-blocking and hands back a receiver, which composes with any async
//! front-end the deployment wraps around this binary.

use crate::metrics::ReqMetrics;
use crate::serving::tenant::{Priority, TenantId};
use std::sync::mpsc as smpsc;
use std::sync::{Arc, Mutex};

/// Serving method requested for one query.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    Baseline,
    /// RaLMSpec; fields mirror the +P/+S/+A toggles.
    Spec { prefetch: bool, os3: bool, async_verify: bool },
    /// Speculative KNN-LM serving (§5.3): the request's `question` is the
    /// generation prompt; options come from the worker's
    /// `KnnServeOptions`. Served through the coalescing engine by
    /// [`crate::serving::KnnEngineBackend`].
    Knn,
    /// Live knowledge-base ingestion (DESIGN.md ADR-006): the request's
    /// `question` is the new document's tokens; the serving backend's
    /// [`crate::retriever::KbWriter`] embeds it, batches it, and
    /// publishes a new epoch when the batch fills. The response carries
    /// no tokens; `metrics.epoch` reports the epoch the document landed
    /// in (or the current epoch while it is still pending). Requires a
    /// live-KB backend — frozen-KB workers answer with an error.
    Ingest,
}

#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub question: Vec<u32>,
    pub method: Method,
    /// Tenant namespace (DESIGN.md ADR-011): engine backends pin this
    /// tenant's knowledge base and never coalesce its queries with
    /// another tenant's. 0 (the default) is the single-tenant namespace.
    pub tenant: TenantId,
    /// Priority class (ADR-011): weighted admission and — under
    /// overload — speculation preemption inside the serving engine.
    pub class: Priority,
}

impl Default for Request {
    fn default() -> Self {
        Self {
            id: 0,
            question: Vec::new(),
            method: Method::Baseline,
            tenant: 0,
            class: Priority::Normal,
        }
    }
}

#[derive(Debug)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<u32>,
    pub metrics: ReqMetrics,
}

/// A per-worker serving backend (constructed on the worker thread; needn't
/// be Send).
pub trait ServeBackend {
    fn serve(&mut self, req: &Request) -> anyhow::Result<ReqMetrics>;

    /// How many queued jobs a worker should drain into one `serve_batch`
    /// call. 1 (the default) preserves job-at-a-time serving; an
    /// engine-backed backend (`serving::EngineBackend`) raises it so
    /// cross-request verification coalescing sees a whole batch.
    fn preferred_batch(&self) -> usize {
        1
    }

    /// Serve a drained batch, one result per request **in order**. The
    /// default loops `serve`; batching backends override to multiplex the
    /// requests through a shared engine.
    fn serve_batch(&mut self, reqs: &[Request])
                   -> Vec<anyhow::Result<ReqMetrics>> {
        reqs.iter().map(|r| self.serve(r)).collect()
    }
}

/// Best-effort panic payload text for the error `Response`.
fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

struct Job {
    req: Request,
    resp: smpsc::SyncSender<anyhow::Result<Response>>,
}

/// Router handle. Dropping it shuts the workers down (queue disconnect).
pub struct Router {
    tx: smpsc::SyncSender<Job>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Router {
    /// Spawn `workers` threads, each building its own backend.
    pub fn spawn<F, B>(queue_cap: usize, workers: usize, factory: F) -> Self
    where
        F: Fn() -> anyhow::Result<B> + Send + Sync + 'static,
        B: ServeBackend,
    {
        let (tx, rx) = smpsc::sync_channel::<Job>(queue_cap);
        let rx = Arc::new(Mutex::new(rx));
        let factory = Arc::new(factory);
        let handles = (0..workers.max(1))
            .map(|wid| {
                let rx = rx.clone();
                let factory = factory.clone();
                std::thread::Builder::new()
                    .name(format!("ralmspec-worker-{wid}"))
                    // detlint: allow(nondet-source, reason = "the router owns the worker threads; determinism is per-request (each request is served whole by one worker)")
                    .spawn(move || {
                        let mut backend = match factory() {
                            Ok(b) => b,
                            Err(e) => {
                                eprintln!("worker {wid}: backend init failed: {e:#}");
                                return;
                            }
                        };
                        loop {
                            // Pop one job (shared MPMC via mutexed
                            // receiver), then greedily drain already-queued
                            // jobs up to the backend's preferred batch so
                            // an engine backend can coalesce across them.
                            let job = {
                                // detlint: allow(hot-panic, reason = "receiver mutex poisoning means a sibling worker panicked mid-recv; propagate")
                                let guard = rx.lock().unwrap();
                                guard.recv()
                            };
                            let Ok(job) = job else { break };
                            let mut jobs = vec![job];
                            let cap = backend.preferred_batch().max(1);
                            if cap > 1 {
                                // detlint: allow(hot-panic, reason = "receiver mutex poisoning means a sibling worker panicked mid-recv; propagate")
                                let guard = rx.lock().unwrap();
                                while jobs.len() < cap {
                                    match guard.try_recv() {
                                        Ok(j) => jobs.push(j),
                                        Err(_) => break,
                                    }
                                }
                            }
                            // Split each job into its request (handed to
                            // the backend by reference — no clone on the
                            // hot path) and its reply channel.
                            let mut reqs = Vec::with_capacity(jobs.len());
                            let mut replies =
                                Vec::with_capacity(jobs.len());
                            for job in jobs {
                                let id = job.req.id;
                                reqs.push(job.req);
                                replies.push((id, job.resp));
                            }
                            // A panicking backend must not kill the worker:
                            // before this guard, each panic silently ate a
                            // thread and capacity decayed to zero. Catch
                            // it, answer every drained job with an error,
                            // keep serving.
                            let outcome = std::panic::catch_unwind(
                                std::panic::AssertUnwindSafe(
                                    || backend.serve_batch(&reqs)));
                            match outcome {
                                Ok(results)
                                    if results.len() == replies.len() =>
                                {
                                    for ((id, resp), result) in
                                        replies.into_iter().zip(results)
                                    {
                                        let r = result.map(|m| Response {
                                            id,
                                            tokens: m.tokens_out.clone(),
                                            metrics: m,
                                        });
                                        let _ = resp.send(r);
                                    }
                                }
                                Ok(results) => {
                                    // Contract violation: surface it as a
                                    // real error instead of silently
                                    // dropping the unmatched jobs.
                                    let msg = format!(
                                        "backend returned {} results for \
                                         {} requests",
                                        results.len(), replies.len());
                                    eprintln!("worker {wid}: {msg}");
                                    for (id, resp) in replies {
                                        let _ = resp.send(Err(
                                            anyhow::anyhow!(
                                                "request {id}: {msg}")));
                                    }
                                }
                                Err(payload) => {
                                    let msg =
                                        panic_message(payload.as_ref());
                                    eprintln!("worker {wid}: backend \
                                               panicked: {msg}");
                                    for (id, resp) in replies {
                                        let _ = resp.send(Err(
                                            anyhow::anyhow!(
                                                "backend panicked while \
                                                 serving request {id}: \
                                                 {msg}")));
                                    }
                                }
                            }
                        }
                    })
                    // detlint: allow(hot-panic, reason = "spawn failure at router construction is unrecoverable (OS thread exhaustion)")
                    .expect("spawning worker")
            })
            .collect();
        Self { tx, workers: handles }
    }

    /// Submit without waiting: returns a receiver that resolves when a
    /// worker finishes. Errors immediately if the queue is full
    /// (backpressure) or the router is shut down.
    pub fn submit(&self, req: Request)
                  -> anyhow::Result<smpsc::Receiver<anyhow::Result<Response>>> {
        let (tx, rx) = smpsc::sync_channel(1);
        self.tx
            .try_send(Job { req, resp: tx })
            .map_err(|e| match e {
                smpsc::TrySendError::Full(_) => {
                    anyhow::anyhow!("queue full (backpressure)")
                }
                smpsc::TrySendError::Disconnected(_) => {
                    anyhow::anyhow!("router shut down")
                }
            })?;
        Ok(rx)
    }

    /// Blocking submit (submit + wait). Shares `submit`'s admission path,
    /// so it honours the same backpressure contract: a full queue is an
    /// immediate error, not an unbounded wait. (It previously used a
    /// blocking `send`, which could park the caller forever while `submit`
    /// callers were being told the router was overloaded.)
    pub fn submit_blocking(&self, req: Request) -> anyhow::Result<Response> {
        let rx = self.submit(req)?;
        rx.recv()
            .map_err(|_| anyhow::anyhow!("worker dropped request"))?
    }

    /// Shut down: close the queue and join the workers.
    pub fn shutdown(self) {
        drop(self.tx);
        for h in self.workers {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct EchoBackend;

    impl ServeBackend for EchoBackend {
        fn serve(&mut self, req: &Request) -> anyhow::Result<ReqMetrics> {
            let mut m = ReqMetrics::default();
            m.tokens_out = req.question.iter().map(|t| t + 1).collect();
            Ok(m)
        }
    }

    #[test]
    fn round_trips_requests_across_workers() {
        let router = Router::spawn(16, 3, || Ok(EchoBackend));
        for i in 0..20u64 {
            let resp = router
                .submit_blocking(Request {
                    id: i,
                    question: vec![i as u32, 7],
                    method: Method::Baseline,
                    ..Request::default()
                })
                .unwrap();
            assert_eq!(resp.id, i);
            assert_eq!(resp.tokens, vec![i as u32 + 1, 8]);
        }
        router.shutdown();
    }

    #[test]
    fn shutdown_joins_cleanly() {
        let router = Router::spawn(4, 2, || Ok(EchoBackend));
        router.shutdown();
    }

    #[test]
    fn pipelined_submit_works() {
        let router = Router::spawn(16, 2, || Ok(EchoBackend));
        // Submit several requests before collecting any response.
        let pending: Vec<_> = (0..8u64)
            .map(|i| router.submit(Request {
                id: i, question: vec![i as u32], method: Method::Baseline,
                ..Request::default()
            }).unwrap())
            .collect();
        for (i, rx) in pending.into_iter().enumerate() {
            let resp = rx.recv().unwrap().unwrap();
            assert_eq!(resp.tokens, vec![i as u32 + 1]);
        }
        router.shutdown();
    }

    #[test]
    fn backpressure_when_queue_full() {
        // 1 worker blocked forever-ish is hard to fake; instead fill the
        // queue faster than a sleepy backend drains it.
        struct Slow;
        impl ServeBackend for Slow {
            fn serve(&mut self, req: &Request) -> anyhow::Result<ReqMetrics> {
                std::thread::sleep(std::time::Duration::from_millis(30));
                let mut m = ReqMetrics::default();
                m.tokens_out = req.question.clone();
                Ok(m)
            }
        }
        let router = Router::spawn(1, 1, || Ok(Slow));
        let mut saw_backpressure = false;
        let mut rxs = Vec::new();
        for i in 0..64u64 {
            match router.submit(Request { id: i, question: vec![1],
                                          method: Method::Baseline,
                                          ..Request::default() }) {
                Ok(rx) => rxs.push(rx),
                Err(_) => { saw_backpressure = true; break; }
            }
        }
        assert!(saw_backpressure, "queue of 1 must overflow");
        for rx in rxs { let _ = rx.recv(); }
        router.shutdown();
    }

    #[test]
    fn worker_survives_backend_panic() {
        // Regression: a panic in ServeBackend::serve used to kill the
        // worker thread permanently, so capacity silently decayed to zero
        // under repeated panics. The panicking request must get an error
        // Response and the same worker must keep serving.
        struct PanicOnSeven;
        impl ServeBackend for PanicOnSeven {
            fn serve(&mut self, req: &Request) -> anyhow::Result<ReqMetrics> {
                if req.id == 7 {
                    panic!("injected failure on request 7");
                }
                let mut m = ReqMetrics::default();
                m.tokens_out = vec![req.id as u32];
                Ok(m)
            }
        }
        let router = Router::spawn(8, 1, || Ok(PanicOnSeven));
        for round in 0..3 {
            let err = router.submit_blocking(Request {
                id: 7,
                question: vec![round],
                method: Method::Baseline,
                ..Request::default()
            });
            let err = err.expect_err("panicking request must error");
            assert!(err.to_string().contains("panicked"),
                    "error should say the backend panicked: {err:#}");
            // The single worker survived and still answers.
            let ok = router.submit_blocking(Request {
                id: round as u64,
                question: vec![1],
                method: Method::Baseline,
                ..Request::default()
            }).expect("worker must stay alive after a panic");
            assert_eq!(ok.tokens, vec![round as u32]);
        }
        router.shutdown();
    }

    #[test]
    fn worker_drains_batches_for_batching_backends() {
        // A backend with preferred_batch > 1 sees already-queued jobs as
        // one serve_batch call. Gate the first call so the rest of the
        // jobs are provably enqueued before the second drain.
        struct Batchy {
            started: smpsc::Sender<()>,
            release: smpsc::Receiver<()>,
            sizes: Arc<Mutex<Vec<usize>>>,
        }
        impl ServeBackend for Batchy {
            fn serve(&mut self, req: &Request) -> anyhow::Result<ReqMetrics> {
                let mut m = ReqMetrics::default();
                m.tokens_out = vec![req.id as u32];
                Ok(m)
            }

            fn preferred_batch(&self) -> usize {
                8
            }

            fn serve_batch(&mut self, reqs: &[Request])
                           -> Vec<anyhow::Result<ReqMetrics>> {
                self.sizes.lock().unwrap().push(reqs.len());
                let _ = self.started.send(());
                let _ = self.release.recv();
                reqs.iter().map(|r| self.serve(r)).collect()
            }
        }
        let (started_tx, started_rx) = smpsc::channel::<()>();
        let (release_tx, release_rx) = smpsc::channel::<()>();
        let sizes = Arc::new(Mutex::new(Vec::new()));
        let slot = Arc::new(Mutex::new(Some((started_tx, release_rx))));
        let sizes2 = sizes.clone();
        let router = Router::spawn(16, 1, move || {
            let (started, release) =
                slot.lock().unwrap().take().expect("single worker");
            Ok(Batchy { started, release, sizes: sizes2.clone() })
        });
        let mut rxs = vec![router
            .submit(Request { id: 0, question: vec![0],
                              method: Method::Baseline,
                              ..Request::default() })
            .unwrap()];
        started_rx.recv().expect("worker entered the first batch");
        // These five enqueue while the worker is parked in batch one...
        for i in 1..6u64 {
            rxs.push(router
                .submit(Request { id: i, question: vec![i as u32],
                                  method: Method::Baseline,
                                  ..Request::default() })
                .unwrap());
        }
        release_tx.send(()).unwrap(); // finish batch one
        started_rx.recv().expect("worker entered the second batch");
        release_tx.send(()).unwrap(); // finish batch two
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv().unwrap().unwrap();
            assert_eq!(resp.tokens, vec![i as u32]);
        }
        // ...so the second drain must have coalesced all five.
        assert_eq!(*sizes.lock().unwrap(), vec![1, 5]);
        router.shutdown();
    }

    #[test]
    fn submit_blocking_reports_backpressure_instead_of_hanging() {
        // A worker provably parked inside serve() plus a full queue: the
        // blocking path must error out exactly like `submit`, not wait.
        // Gated backend makes the schedule deterministic: it signals when
        // a serve starts and blocks until released.
        struct Gate {
            started: smpsc::Sender<()>,
            release: smpsc::Receiver<()>,
        }
        impl ServeBackend for Gate {
            fn serve(&mut self, req: &Request) -> anyhow::Result<ReqMetrics> {
                let _ = self.started.send(());
                let _ = self.release.recv();
                let mut m = ReqMetrics::default();
                m.tokens_out = req.question.clone();
                Ok(m)
            }
        }
        let (started_tx, started_rx) = smpsc::channel::<()>();
        let (release_tx, release_rx) = smpsc::channel::<()>();
        let slot = Arc::new(Mutex::new(Some((started_tx, release_rx))));
        let router = Router::spawn(1, 1, move || {
            let (started, release) =
                slot.lock().unwrap().take().expect("single worker");
            Ok(Gate { started, release })
        });
        // Occupy the worker and WAIT until it is inside serve() — from
        // here it cannot pop another job until released.
        let mut rxs = vec![router
            .submit(Request { id: 0, question: vec![1],
                              method: Method::Baseline,
                              ..Request::default() })
            .unwrap()];
        started_rx.recv().expect("worker picked up the first job");
        // Fill the 1-slot queue; the next submit must hit backpressure.
        let mut full = false;
        for i in 1..4u64 {
            match router.submit(Request { id: i, question: vec![1],
                                          method: Method::Baseline,
                                          ..Request::default() }) {
                Ok(rx) => rxs.push(rx),
                Err(_) => { full = true; break; }
            }
        }
        assert!(full, "queue should fill");
        // Queue is full and the worker is parked: submit_blocking must
        // fail immediately rather than blocking for a slot.
        let res = router.submit_blocking(Request {
            id: 99, question: vec![2], method: Method::Baseline,
            ..Request::default()
        });
        assert!(res.is_err(), "must report backpressure");
        // Drain: one release per pending serve call.
        for _ in 0..rxs.len() {
            let _ = release_tx.send(());
        }
        for rx in rxs { let _ = rx.recv(); }
        router.shutdown();
    }
}
