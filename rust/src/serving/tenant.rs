//! Multi-tenant identity and priority classes (DESIGN.md ADR-011).
//!
//! A **tenant** is an isolation domain: its own knowledge base (own
//! `LiveKb` epoch stream and ingest quota) and its own flush namespace —
//! the engine groups coalesced verification calls by *(tenant, top-k,
//! epoch)*, so one tenant's ingest storm (a burst of epoch publishes)
//! can neither invalidate nor starve another tenant's coalesced batches.
//! Tenant 0 is the default namespace; single-tenant callers never see a
//! behavioural difference.
//!
//! A **priority class** is an admission lever inside one engine:
//! weighted round-robin admission (see
//! [`SubmitOpts`] / `ServeEngine::submit_opts`) plus speculation
//! preemption under overload — speculative work is free to abandon, so
//! the engine may cancel the lowest-priority in-flight task at a
//! speculation boundary and requeue it, bit-identically (the task is a
//! resumable state machine whose output is a pure function of its own
//! query/result sequence against its pinned epoch; see
//! `tests/tenant_equivalence.rs`).

/// Tenant namespace id. Tenant 0 is the default (single-tenant)
/// namespace; every pre-ADR-011 code path reports 0.
pub type TenantId = u32;

/// Request priority class. `Ord` follows declaration order — smaller is
/// *more* important — so `High < Normal < Low` and class indices can key
/// per-class queues directly.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Priority {
    /// Latency-sensitive traffic: admitted with the largest weight and
    /// never preempted by the classes below.
    High,
    /// The default class.
    Normal,
    /// Best-effort traffic: first to be preempted under overload.
    Low,
}

impl Priority {
    /// Number of classes (array dimension for per-class state).
    pub const COUNT: usize = 3;

    pub fn all() -> [Priority; Priority::COUNT] {
        [Priority::High, Priority::Normal, Priority::Low]
    }

    /// Queue index: 0 = High … 2 = Low.
    pub fn index(self) -> usize {
        self as usize
    }

    pub fn from_index(i: usize) -> Priority {
        match i {
            0 => Priority::High,
            1 => Priority::Normal,
            _ => Priority::Low,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Priority::High => "high",
            Priority::Normal => "normal",
            Priority::Low => "low",
        }
    }
}

impl Default for Priority {
    fn default() -> Self {
        Priority::Normal
    }
}

impl std::str::FromStr for Priority {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "high" | "h" => Ok(Priority::High),
            "normal" | "n" => Ok(Priority::Normal),
            "low" | "l" => Ok(Priority::Low),
            other => Err(anyhow::anyhow!("unknown priority class: {other}")),
        }
    }
}

/// Per-submission serving options (`ServeEngine::submit_opts`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SubmitOpts {
    /// Tenant namespace the request belongs to: its coalesced calls only
    /// ever share a KB call with same-tenant, same-(k, epoch) queries.
    pub tenant: TenantId,
    /// Admission/preemption class.
    pub class: Priority,
    /// Deferred arrival for deterministic traffic replay: the request
    /// becomes admissible only once this many requests have *resolved*
    /// (finished or failed). 0 — the default — is "arrived already".
    /// Replaying a seeded trace through this knob reproduces admission
    /// pressure (and therefore preemption decisions) without any
    /// wall-clock sampling.
    pub after_done: usize,
}

impl Default for SubmitOpts {
    fn default() -> Self {
        Self { tenant: 0, class: Priority::Normal, after_done: 0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn priority_order_and_indexing() {
        assert!(Priority::High < Priority::Normal);
        assert!(Priority::Normal < Priority::Low);
        for (i, p) in Priority::all().into_iter().enumerate() {
            assert_eq!(p.index(), i);
            assert_eq!(Priority::from_index(i), p);
            assert_eq!(p.label().parse::<Priority>().unwrap(), p);
        }
        assert!("urgent".parse::<Priority>().is_err());
        assert_eq!(SubmitOpts::default().class, Priority::Normal);
        assert_eq!(SubmitOpts::default().tenant, 0);
        assert_eq!(SubmitOpts::default().after_done, 0);
    }
}
