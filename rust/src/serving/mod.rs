//! Serving front-end: a request router with a bounded queue
//! and OS-thread pipeline workers (vLLM-router-like shape), plus the
//! cross-request coalescing engine (DESIGN.md ADR-003).
//!
//! PJRT handles are not Send, so each worker thread constructs its own
//! backend (Engine + pipelines) via the factory closure; the queue side
//! only moves plain data (token vectors, metrics). Knowledge bases *are*
//! Send + Sync (`Arc<dyn Retriever>`), so a factory may share one
//! (possibly sharded) retriever across all workers — the per-worker part
//! is only the LM. Both submission paths report backpressure the same
//! way: a full queue is an immediate error, never an unbounded block.
//! Worker threads survive backend panics: the failing request gets an
//! error `Response` and the worker keeps draining the queue.
//!
//! `Method::Spec` requests flow through [`engine::ServeEngine`] when the
//! worker backend is an [`engine::EngineBackend`], and [`Method::Knn`]
//! requests through the same engine when it is an
//! [`engine::KnnEngineBackend`]: the worker drains up to
//! `engine.max_batch` queued jobs at once and the engine coalesces their
//! verification queries into shared `retrieve_batch` calls. With
//! `engine.kb_parallel >= 1` those calls execute asynchronously on
//! background workers (the `executor` module, DESIGN.md ADR-005) while the engine
//! thread keeps scheduling; results are bit-identical either way. The
//! engine is generic over the [`task::ServeTask`] contract (DESIGN.md
//! ADR-004), so any new workload expressed as a resumable task is
//! engine-servable without touching this layer.

pub mod engine;
pub(crate) mod executor;
pub mod router;
pub mod slo;
pub mod task;
pub mod tenant;

pub use engine::{spec_options_for, EngineBackend, EngineOptions,
                 EngineStats, KnnEngineBackend, ServeEngine};
pub use router::{Method, Request, Response, Router, ServeBackend};
pub use slo::{AdaptiveFlush, FlushPlan, P99Window, SloOptions};
pub use task::{ServeTask, TaskStep};
pub use tenant::{Priority, SubmitOpts, TenantId};
