//! Serving front-end: a request router with a bounded queue
//! and OS-thread pipeline workers (vLLM-router-like shape).
//!
//! PJRT handles are not Send, so each worker thread constructs its own
//! backend (Engine + pipelines) via the factory closure; the queue side
//! only moves plain data (token vectors, metrics).

pub mod router;

pub use router::{Request, Response, Router, ServeBackend};
