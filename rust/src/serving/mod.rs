//! Serving front-end: a request router with a bounded queue
//! and OS-thread pipeline workers (vLLM-router-like shape).
//!
//! PJRT handles are not Send, so each worker thread constructs its own
//! backend (Engine + pipelines) via the factory closure; the queue side
//! only moves plain data (token vectors, metrics). Knowledge bases *are*
//! Send + Sync (`Arc<dyn Retriever>`), so a factory may share one
//! (possibly sharded) retriever across all workers — the per-worker part
//! is only the LM. Both submission paths report backpressure the same
//! way: a full queue is an immediate error, never an unbounded block.

pub mod router;

pub use router::{Request, Response, Router, ServeBackend};
