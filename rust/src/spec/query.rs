//! Query construction shared by the baseline and speculative pipelines.
//!
//! Equivalence between RaLMSeq and RaLMSpec requires both to derive
//! *exactly* the same query from the same generation state, so this is the
//! single implementation both call.

use crate::datagen::Encoder;
use crate::lm::GenState;
use crate::retriever::SpecQuery;

/// Which views of the query the active retriever needs (the dense encoder
/// is a PJRT call — skip it for sparse-only pipelines).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueryMode {
    Dense,
    Sparse,
    Both,
}

#[derive(Clone, Copy)]
pub struct QueryBuilder<'a> {
    pub encoder: &'a dyn Encoder,
    pub mode: QueryMode,
    /// Context-tail window sizes (config.retriever.{dense,sparse}_query_len).
    pub dense_len: usize,
    pub sparse_len: usize,
}

impl<'a> QueryBuilder<'a> {
    pub fn build<S: Clone>(&self, st: &GenState<S>) -> SpecQuery {
        self.build_from_window(
            &st.query_window(self.dense_len.max(self.sparse_len)))
    }

    /// Build from an explicit token window (used for the initial
    /// question-only query).
    pub fn build_from_window(&self, window: &[u32]) -> SpecQuery {
        let dense = match self.mode {
            QueryMode::Sparse => Vec::new(),
            _ => {
                let start = window.len().saturating_sub(self.dense_len);
                self.encoder.encode(&window[start..])
            }
        };
        let terms = match self.mode {
            QueryMode::Dense => Vec::new(),
            _ => {
                let start = window.len().saturating_sub(self.sparse_len);
                window[start..].to_vec()
            }
        };
        SpecQuery { dense, terms }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::HashEncoder;

    #[test]
    fn modes_populate_expected_views() {
        let enc = HashEncoder::new(16, 1);
        let window: Vec<u32> = (10..40).collect();
        let mk = |mode| QueryBuilder { encoder: &enc, mode, dense_len: 8,
                                       sparse_len: 12 };
        let d = mk(QueryMode::Dense).build_from_window(&window);
        assert_eq!(d.dense.len(), 16);
        assert!(d.terms.is_empty());
        let s = mk(QueryMode::Sparse).build_from_window(&window);
        assert!(s.dense.is_empty());
        assert_eq!(s.terms.len(), 12);
        assert_eq!(s.terms, window[window.len() - 12..].to_vec());
        let b = mk(QueryMode::Both).build_from_window(&window);
        assert!(!b.dense.is_empty() && !b.terms.is_empty());
    }

    #[test]
    fn dense_uses_tail_window() {
        let enc = HashEncoder::new(16, 1);
        let qb = QueryBuilder { encoder: &enc, mode: QueryMode::Dense,
                                dense_len: 4, sparse_len: 4 };
        let long: Vec<u32> = (0..50).collect();
        let tail: Vec<u32> = (46..50).collect();
        assert_eq!(qb.build_from_window(&long).dense,
                   qb.build_from_window(&tail).dense);
    }
}
