//! The RaLMSpec pipeline (paper Alg. 1): speculative retrieval from the
//! per-request cache, batched verification against the knowledge base,
//! rollback on mis-speculation, optional prefetching / OS³ / asynchronous
//! verification.
//!
//! Correctness invariant (tested exhaustively in
//! rust/tests/pipeline_equivalence.rs): for any stride policy, prefetch
//! size, and async setting, the generated token sequence is **identical**
//! to `baseline::ralmseq` on the same request — speculation only moves
//! *when* retrievals happen, never *what* the model sees after
//! verification.
//!
//! The pipeline talks to the knowledge base only through the batch-first
//! [`Retriever`] trait: verification calls the required `retrieve_batch`
//! primitive, the initial prime uses the derived batch-of-one, and cache
//! lookups rank via `score_docs`. A shard-parallel KB
//! (`retriever::ShardedRetriever`) therefore drops in with bit-identical
//! outputs — the equivalence suite runs unchanged against it.

use crate::cache::LocalCache;
use crate::datagen::Corpus;
use crate::lm::{GenState, LanguageModel};
use crate::metrics::{timed, EventKind, ReqMetrics, Stopwatch};
use crate::retriever::{Retriever, SpecQuery};
use crate::spec::os3::{Scheduler, StridePolicy};
use crate::spec::query::QueryBuilder;
use crate::util::Scored;
use std::time::Duration;

#[derive(Debug, Clone)]
pub struct SpecOptions {
    /// Tokens generated per speculation step (paper: 4).
    pub gen_stride: usize,
    pub stride: StridePolicy,
    /// Cache update size per verified query (1 = top-1, >1 = prefetching).
    pub prefetch: usize,
    pub async_verify: bool,
    pub max_new: usize,
    pub max_doc_tokens: usize,
    pub cache_cap: usize,
}

impl Default for SpecOptions {
    fn default() -> Self {
        let c = crate::config::SpecConfig::default();
        Self {
            gen_stride: c.gen_stride,
            stride: StridePolicy::Fixed(c.stride),
            prefetch: 1,
            async_verify: false,
            max_new: c.max_new_tokens,
            max_doc_tokens: c.max_doc_tokens,
            cache_cap: crate::cache::DEFAULT_CACHE_CAP,
        }
    }
}

/// One in-flight speculation step awaiting verification.
struct Pending<S> {
    snapshot: crate::lm::state::Snapshot<S>,
    query: SpecQuery,
    spec_doc: u32,
    /// Measured latency of this speculation step (for OS³'s `a`).
    step_time: Duration,
}

pub struct SpecPipeline<'a, L: LanguageModel> {
    pub lm: &'a L,
    pub kb: &'a dyn Retriever,
    pub corpus: &'a Corpus,
    pub queries: QueryBuilder<'a>,
    pub opts: SpecOptions,
}

impl<'a, L: LanguageModel> SpecPipeline<'a, L> {
    /// Serve one request. Returns metrics (which include the tokens).
    pub fn run(&self, question: &[u32]) -> anyhow::Result<ReqMetrics> {
        let total = Stopwatch::start();
        let mut m = ReqMetrics::default();
        let mut cache = LocalCache::new(self.opts.cache_cap);
        let mut scheduler = Scheduler::new(self.opts.stride.clone());

        // Alg. 1 line 4: initial retrieval primes the cache (top-prefetch).
        let q0 = timed(&mut m.retrieve,
                       || self.queries.build_from_window(question));
        let top0 = timed(&mut m.retrieve, || {
            self.kb.retrieve_topk(&q0, self.opts.prefetch.max(1))
        });
        m.kb_calls += 1;
        m.kb_queries += 1;
        anyhow::ensure!(!top0.is_empty(), "knowledge base returned nothing");
        cache.insert(&top0);
        let doc0 = top0[0].id;

        let prefill_t = Stopwatch::start();
        let mut state = timed(&mut m.generate, || {
            GenState::new(self.lm, Some(doc0),
                          &self.corpus.doc(doc0).tokens, question,
                          self.opts.max_doc_tokens, self.opts.max_new)
        })?;
        m.prefills += 1;
        m.event(EventKind::Prefill, &total, prefill_t.elapsed());

        if self.opts.async_verify {
            std::thread::scope(|scope| {
                let (job_tx, job_rx) =
                    std::sync::mpsc::channel::<(Vec<SpecQuery>, usize)>();
                let (res_tx, res_rx) =
                    std::sync::mpsc::channel::<(Vec<Vec<Scored>>, Duration)>();
                let kb = self.kb;
                scope.spawn(move || {
                    while let Ok((qs, k)) = job_rx.recv() {
                        let t = Stopwatch::start();
                        let res = kb.retrieve_batch(&qs, k);
                        if res_tx.send((res, t.elapsed())).is_err() {
                            break;
                        }
                    }
                });
                self.drive(&mut state, &mut cache, &mut scheduler, &mut m,
                           &total, Some((&job_tx, &res_rx)))
            })?;
        } else {
            self.drive(&mut state, &mut cache, &mut scheduler, &mut m,
                       &total, None)?;
        }

        m.tokens_out = state.generated.clone();
        m.decode_tokens = state.generated.len() as u32 + m.wasted_tokens;
        m.total = total.elapsed();
        Ok(m)
    }

    /// One speculation step: query → cache lookup → (maybe re-prefill) →
    /// generate `gen_stride` tokens.
    fn spec_step(&self, state: &mut GenState<L::State>,
                 cache: &mut LocalCache, m: &mut ReqMetrics,
                 req_start: &Stopwatch)
                 -> anyhow::Result<Pending<L::State>> {
        let step = Stopwatch::start();
        let snapshot = state.snapshot();
        let query = timed(&mut m.retrieve, || self.queries.build(state));
        let hit = timed(&mut m.cache, || cache.retrieve(&query, self.kb));
        // Cache miss (cannot happen after the initial prime, but be safe):
        // keep the current document.
        let spec_doc = hit.map(|s| s.id)
            .or(state.doc_id)
            .expect("no document available for speculation");
        timed(&mut m.generate, || -> anyhow::Result<()> {
            if state.set_doc(self.lm, spec_doc,
                             &self.corpus.doc(spec_doc).tokens)? {
                m.prefills += 1;
            }
            state.generate(self.lm, self.opts.gen_stride)?;
            Ok(())
        })?;
        m.spec_steps += 1;
        let step_time = step.elapsed();
        m.event(EventKind::SpecStep, req_start, step_time);
        Ok(Pending { snapshot, query, spec_doc, step_time })
    }

    /// Main loop, shared by sync and async modes. `verifier` is the async
    /// channel pair when async verification is enabled.
    #[allow(clippy::type_complexity)]
    fn drive(&self, state: &mut GenState<L::State>, cache: &mut LocalCache,
             scheduler: &mut Scheduler, m: &mut ReqMetrics,
             req_start: &Stopwatch,
             verifier: Option<(&std::sync::mpsc::Sender<(Vec<SpecQuery>, usize)>,
                               &std::sync::mpsc::Receiver<(Vec<Vec<Scored>>, Duration)>)>)
             -> anyhow::Result<()> {
        // Steps speculated but not yet verified (carries the async "extra
        // step" across rounds).
        let mut pending: Vec<Pending<L::State>> = Vec::new();
        loop {
            let target = scheduler.stride().max(1);
            while pending.len() < target && !state.done {
                pending.push(self.spec_step(state, cache, m, req_start)?);
            }
            if pending.is_empty() {
                break;
            }
            m.strides.push(pending.len() as u32);

            // Batched verification of all pending queries.
            let queries: Vec<SpecQuery> =
                pending.iter().map(|p| p.query.clone()).collect();
            let k = self.opts.prefetch.max(1);
            m.kb_calls += 1;
            m.kb_queries += queries.len() as u32;
            let (truths, b_lat, extra) = match verifier {
                None => {
                    let t = Stopwatch::start();
                    let truths = self.kb.retrieve_batch(&queries, k);
                    let b = t.elapsed();
                    m.retrieve += b;
                    m.event(EventKind::Verify, req_start, b);
                    (truths, b, None)
                }
                Some((tx, rx)) => {
                    tx.send((queries, k)).expect("verifier thread died");
                    // Overlap: one extra speculation step while the batch
                    // retrieval runs on the verifier thread (Fig 3).
                    let extra = if !state.done {
                        Some(self.spec_step(state, cache, m, req_start)?)
                    } else {
                        None
                    };
                    let wait = Stopwatch::start();
                    let (truths, b) = rx.recv().expect("verifier thread died");
                    m.verify_wait += wait.elapsed();
                    m.retrieve += b; // component time (overlapped)
                    m.event(EventKind::Verify, req_start, b);
                    (truths, b, extra)
                }
            };

            // Cache update: top-1 or top-k (prefetching) per verified query.
            for t in &truths {
                cache.insert(t);
            }

            // First mismatch (Alg. 1 line 12).
            let mismatch = pending
                .iter()
                .zip(&truths)
                .position(|(p, t)| t.first().map(|s| s.id) != Some(p.spec_doc));
            let matched = mismatch.unwrap_or(pending.len());
            m.spec_correct += matched as u32;
            let a_mean = pending
                .iter()
                .map(|p| p.step_time.as_secs_f64())
                .sum::<f64>()
                / pending.len() as f64;
            scheduler.observe(pending.len(), matched, a_mean,
                              b_lat.as_secs_f64());

            match mismatch {
                None => {
                    // All verified; the async extra step (if any) rolls into
                    // the next round's pending list.
                    pending.clear();
                    if let Some(e) = extra {
                        pending.push(e);
                    }
                }
                Some(i) => {
                    // Roll back to the mis-speculated step and redo it with
                    // the ground-truth document (Alg. 1 lines 13-16).
                    m.rollbacks += 1;
                    m.wasted_tokens +=
                        state.rollback(&pending[i].snapshot) as u32;
                    let truth_doc = truths[i].first()
                        .expect("verification returned empty top-k");
                    let correct_t = Stopwatch::start();
                    timed(&mut m.generate, || -> anyhow::Result<()> {
                        if state.set_doc(self.lm, truth_doc.id,
                                         &self.corpus.doc(truth_doc.id).tokens)? {
                            m.prefills += 1;
                        }
                        state.generate(self.lm, self.opts.gen_stride)?;
                        Ok(())
                    })?;
                    m.event(EventKind::Correct, req_start, correct_t.elapsed());
                    pending.clear();
                }
            }
            if state.done && pending.is_empty() {
                break;
            }
        }
        Ok(())
    }
}
