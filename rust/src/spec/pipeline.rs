//! The RaLMSpec pipeline (paper Alg. 1): speculative retrieval from the
//! per-request cache, batched verification against the knowledge base,
//! rollback on mis-speculation, optional prefetching / OS³ / asynchronous
//! verification.
//!
//! Correctness invariant (tested exhaustively in
//! rust/tests/pipeline_equivalence.rs): for any stride policy, prefetch
//! size, and async setting, the generated token sequence is **identical**
//! to `baseline::ralmseq` on the same request — speculation only moves
//! *when* retrievals happen, never *what* the model sees after
//! verification.
//!
//! Since the resumable-task refactor (DESIGN.md ADR-003) the pipeline is a
//! thin driver over [`SpecTask`], a step-driven state machine that owns all
//! per-request state (generation state, speculation cache, OS³ scheduler,
//! metrics) and *never touches the knowledge base for verification
//! itself*: [`SpecTask::advance`] runs until it either finishes or emits a
//! [`TaskStep::NeedsVerify`] batch of queries, and whoever drives the task
//! answers them — `SpecPipeline::run` with a direct `retrieve_batch` call
//! (or a verifier thread in async mode), `serving::ServeEngine` with a
//! KB call shared across many concurrent requests. Because every retriever
//! scores a query independently of its batchmates (the bit-identity pinned
//! by fig6 and tests/sharded_equivalence.rs), the task cannot tell who
//! answered or what else was coalesced into the call — which is exactly
//! why cross-request coalescing preserves per-request output equivalence.
//!
//! The pipeline talks to the knowledge base only through the batch-first
//! [`Retriever`] trait: verification uses the required `retrieve_batch`
//! primitive (the prime is a batch of one), and cache lookups rank via
//! `score_docs`. A shard-parallel KB (`retriever::ShardedRetriever`)
//! therefore drops in with bit-identical outputs — the equivalence suite
//! runs unchanged against it.

use crate::cache::LocalCache;
use crate::datagen::Corpus;
use crate::lm::{GenState, LanguageModel};
use crate::metrics::{timed, EventKind, ReqMetrics, Stopwatch};
use crate::retriever::{Retriever, SpecQuery};
use crate::spec::os3::{Os3Config, Scheduler, StridePolicy};
use crate::spec::query::QueryBuilder;
use crate::util::Scored;
use std::time::Duration;

// The step contract lives with the engine that drives it (DESIGN.md
// ADR-004); re-exported here because SpecTask is its original
// implementation and existing consumers import it from `spec`.
pub use crate::serving::task::{ServeTask, TaskStep};
use crate::serving::tenant::TenantId;

#[derive(Debug, Clone)]
pub struct SpecOptions {
    /// Tokens generated per speculation step (paper: 4).
    pub gen_stride: usize,
    pub stride: StridePolicy,
    /// Cache update size per verified query (1 = top-1, >1 = prefetching).
    pub prefetch: usize,
    pub async_verify: bool,
    pub max_new: usize,
    pub max_doc_tokens: usize,
    pub cache_cap: usize,
}

impl Default for SpecOptions {
    fn default() -> Self {
        let c = crate::config::SpecConfig::default();
        Self {
            gen_stride: c.gen_stride,
            stride: StridePolicy::Fixed(c.stride),
            prefetch: 1,
            async_verify: false,
            max_new: c.max_new_tokens,
            max_doc_tokens: c.max_doc_tokens,
            cache_cap: crate::cache::DEFAULT_CACHE_CAP,
        }
    }
}

impl SpecOptions {
    /// Per-request options resolved against the config; `stride` is the
    /// fixed stride used when `os3` is false. The single constructor
    /// shared by the eval runner and the serving router, so both serve
    /// bit-identical requests from the same toggles.
    pub fn for_method(cfg: &crate::config::Config, prefetch: usize,
                      os3: bool, async_verify: bool, stride: usize) -> Self {
        let policy = if os3 {
            StridePolicy::Os3(Os3Config {
                window: cfg.spec.os3_window,
                gamma_max: cfg.spec.gamma_max,
                max_stride: cfg.spec.max_stride,
                async_mode: async_verify,
            })
        } else {
            StridePolicy::Fixed(stride)
        };
        Self {
            gen_stride: cfg.spec.gen_stride,
            stride: policy,
            prefetch,
            async_verify,
            max_new: cfg.spec.max_new_tokens,
            max_doc_tokens: cfg.spec.max_doc_tokens,
            cache_cap: crate::cache::DEFAULT_CACHE_CAP,
        }
    }
}

/// One in-flight speculation step awaiting verification.
struct Pending<S> {
    snapshot: crate::lm::state::Snapshot<S>,
    query: SpecQuery,
    spec_doc: u32,
    /// Measured latency of this speculation step (for OS³'s `a`).
    step_time: Duration,
}

/// Task lifecycle. `Prime`/`AwaitPrime` cover Alg. 1 line 4 (the initial
/// cache-priming retrieval, itself expressed as a `NeedsVerify` so a
/// serving engine can coalesce it); `Running`/`AwaitVerify` alternate for
/// the speculate→verify rounds; `Finished` is terminal.
enum Phase {
    Prime,
    AwaitPrime,
    Running,
    AwaitVerify,
    Finished,
}

/// Resumable per-request speculation task (paper Alg. 1 as a state
/// machine). Drive it with [`advance`](SpecTask::advance) until `Done`,
/// answering every `NeedsVerify` with [`provide`](SpecTask::provide).
/// In async-verification mode, call
/// [`overlap_step`](SpecTask::overlap_step) repeatedly while the batch is
/// in flight to take the extra speculation steps (up to one full next
/// stride) that hide verification latency (Fig 3); the steps are
/// optional and never change the output, only the schedule.
pub struct SpecTask<'a, L: LanguageModel> {
    lm: &'a L,
    /// Used for cache-lookup scoring only (`score_docs`); verification
    /// queries are answered by whoever drives the task.
    kb: &'a dyn Retriever,
    corpus: &'a Corpus,
    queries: QueryBuilder<'a>,
    opts: SpecOptions,
    question: Vec<u32>,
    phase: Phase,
    total: Stopwatch,
    m: ReqMetrics,
    cache: LocalCache,
    scheduler: Scheduler,
    state: Option<GenState<L::State>>,
    /// Steps speculated but not yet verified.
    pending: Vec<Pending<L::State>>,
    /// Async "extra steps" overlapped with an in-flight verification —
    /// up to one full next-round stride per round (a deterministic,
    /// state-based budget; see [`overlap_step`](Self::overlap_step)).
    /// They roll into the next round's pending list when the round
    /// verifies clean, and are discarded with the rollback otherwise.
    extra: Vec<Pending<L::State>>,
    /// Knowledge-base epoch this task is pinned to (0 for a frozen KB):
    /// `kb`/`corpus` must be that epoch's snapshot, and the engine groups
    /// coalesced calls by it (DESIGN.md ADR-006).
    epoch: u64,
    /// Tenant namespace this task serves (0 = default, DESIGN.md
    /// ADR-011): the engine groups coalesced calls by it, so queries
    /// never cross tenant knowledge bases.
    tenant: TenantId,
}

/// One speculation step: query → cache lookup → (maybe re-prefill) →
/// generate `gen_stride` tokens. Free function so callers can borrow
/// disjoint `SpecTask` fields.
#[allow(clippy::too_many_arguments)]
fn spec_step<L: LanguageModel>(
    lm: &L, kb: &dyn Retriever, corpus: &Corpus, queries: &QueryBuilder,
    opts: &SpecOptions, state: &mut GenState<L::State>,
    cache: &mut LocalCache, m: &mut ReqMetrics, req_start: &Stopwatch,
    epoch: u64)
    -> anyhow::Result<Pending<L::State>> {
    let step = Stopwatch::start();
    let snapshot = state.snapshot();
    // Query construction (dense-encoder work) is "E", not "R": it runs on
    // the LM side of the system, not in the knowledge base.
    let query = timed(&mut m.encode, || queries.build(state));
    let hit = timed(&mut m.cache, || cache.retrieve_at(&query, kb, epoch));
    // Cache miss (cannot happen after the initial prime, but be safe):
    // keep the current document.
    let spec_doc = hit.map(|s| s.id)
        .or(state.doc_id)
        .expect("no document available for speculation");
    timed(&mut m.generate, || -> anyhow::Result<()> {
        if state.set_doc(lm, spec_doc, &corpus.doc(spec_doc).tokens)? {
            m.prefills += 1;
        }
        state.generate(lm, opts.gen_stride)?;
        Ok(())
    })?;
    m.spec_steps += 1;
    let step_time = step.elapsed();
    m.event(EventKind::SpecStep, req_start, step_time);
    Ok(Pending { snapshot, query, spec_doc, step_time })
}

impl<'a, L: LanguageModel> SpecTask<'a, L> {
    pub fn new(lm: &'a L, kb: &'a dyn Retriever, corpus: &'a Corpus,
               queries: QueryBuilder<'a>, opts: SpecOptions,
               question: &[u32]) -> Self {
        let scheduler = Scheduler::new(opts.stride.clone());
        let cache = LocalCache::new(opts.cache_cap);
        Self {
            lm,
            kb,
            corpus,
            queries,
            opts,
            question: question.to_vec(),
            phase: Phase::Prime,
            total: Stopwatch::start(),
            m: ReqMetrics::default(),
            cache,
            scheduler,
            state: None,
            pending: Vec::new(),
            extra: Vec::new(),
            epoch: 0,
            tenant: 0,
        }
    }

    /// Pin this task to a live knowledge base's epoch (DESIGN.md
    /// ADR-006). The caller passes the epoch whose snapshot it handed to
    /// [`new`](Self::new) as `kb`/`corpus`; the engine then (a) answers
    /// every `NeedsVerify` with that very snapshot and (b) never
    /// coalesces this task's queries with tasks pinned to other epochs.
    /// The pinned epoch is stamped into the request's metrics.
    pub fn pin_epoch(mut self, epoch: u64) -> Self {
        self.epoch = epoch;
        self.m.epoch = epoch;
        self
    }

    /// Pin this task to a tenant namespace (DESIGN.md ADR-011): the
    /// engine resolves its snapshot from that tenant's registrations and
    /// only coalesces its queries with same-tenant batchmates. The
    /// default tenant 0 preserves single-tenant behaviour exactly.
    pub fn pin_tenant(mut self, tenant: TenantId) -> Self {
        self.tenant = tenant;
        self
    }

    /// Run until the task finishes (`Done`), needs retrieval results
    /// (`NeedsVerify`), or has taken one speculation step (`Continue` —
    /// the single-step granularity is what lets a serving engine
    /// interleave many tasks fairly). Must not be called while a
    /// `NeedsVerify` is outstanding.
    pub fn advance(&mut self) -> anyhow::Result<TaskStep> {
        match self.phase {
            Phase::Prime => {
                // Alg. 1 line 4: the initial retrieval primes the cache
                // (top-prefetch). Expressed as a NeedsVerify batch of one
                // so engines can coalesce it with other requests' queries.
                let queries = &self.queries;
                let question = &self.question;
                let q0 = timed(&mut self.m.encode,
                               || queries.build_from_window(question));
                self.m.kb_calls += 1;
                self.m.kb_queries += 1;
                self.phase = Phase::AwaitPrime;
                Ok(TaskStep::NeedsVerify {
                    queries: vec![q0],
                    k: self.opts.prefetch.max(1),
                })
            }
            Phase::AwaitPrime | Phase::AwaitVerify => anyhow::bail!(
                "SpecTask::advance while a verification is outstanding"),
            Phase::Finished => Ok(TaskStep::Done),
            Phase::Running => {
                let target = self.scheduler.stride().max(1);
                let done =
                    self.state.as_ref().map(|s| s.done).unwrap_or(true);
                if self.pending.is_empty() && done {
                    self.finish();
                    return Ok(TaskStep::Done);
                }
                if self.pending.len() < target && !done {
                    let state = self.state.as_mut()
                        .expect("generation state exists after prime");
                    let p = spec_step(self.lm, self.kb, self.corpus,
                                      &self.queries, &self.opts, state,
                                      &mut self.cache, &mut self.m,
                                      &self.total, self.epoch)?;
                    self.pending.push(p);
                    return Ok(TaskStep::Continue);
                }
                // Batched verification of all pending queries.
                self.m.strides.push(self.pending.len() as u32);
                let queries: Vec<SpecQuery> =
                    self.pending.iter().map(|p| p.query.clone()).collect();
                self.m.kb_calls += 1;
                self.m.kb_queries += queries.len() as u32;
                self.phase = Phase::AwaitVerify;
                Ok(TaskStep::NeedsVerify {
                    queries,
                    k: self.opts.prefetch.max(1),
                })
            }
        }
    }

    /// In async-verification mode, take one extra speculation step that
    /// overlaps the in-flight verification (Fig 3). Drivers call this
    /// repeatedly between receiving `NeedsVerify` and calling
    /// [`provide`](Self::provide) — the engine once per scheduling round
    /// across the whole KB latency, the sequential async driver in a
    /// drain loop — and the task accepts up to one full next-round stride
    /// of extra steps per round. The budget is a function of task state
    /// only (the scheduler's current stride — stable during
    /// `AwaitVerify`, since `observe` runs in `provide`), never of
    /// elapsed time, so the schedule is reproducible no matter how slow
    /// the verification was. A no-op (returns false) in sync mode, during
    /// the prime, when the request is done, or when the budget for this
    /// round is spent.
    pub fn overlap_step(&mut self) -> anyhow::Result<bool> {
        if !self.opts.async_verify
            || !matches!(self.phase, Phase::AwaitVerify)
            || self.extra.len() >= self.scheduler.stride().max(1)
        {
            return Ok(false);
        }
        let Some(state) = self.state.as_mut() else { return Ok(false) };
        if state.done {
            return Ok(false);
        }
        let p = spec_step(self.lm, self.kb, self.corpus, &self.queries,
                          &self.opts, state, &mut self.cache, &mut self.m,
                          &self.total, self.epoch)?;
        self.m.overlap_steps += 1;
        self.extra.push(p);
        Ok(true)
    }

    /// Answer the outstanding `NeedsVerify`: `truths[i]` is the top-k for
    /// `queries[i]`, `kb_time` the latency of the KB call that produced
    /// them (attributed to this request's R component; a coalesced call's
    /// latency is shared by every participating request because each one
    /// really did wait for it).
    pub fn provide(&mut self, truths: Vec<Vec<Scored>>, kb_time: Duration)
                   -> anyhow::Result<()> {
        match self.phase {
            Phase::Prime | Phase::Running | Phase::Finished => anyhow::bail!(
                "SpecTask::provide without an outstanding verification"),
            Phase::AwaitPrime => {
                anyhow::ensure!(truths.len() == 1,
                                "prime expects 1 result row, got {}",
                                truths.len());
                let top0 = &truths[0];
                anyhow::ensure!(!top0.is_empty(),
                                "knowledge base returned nothing");
                self.m.retrieve += kb_time;
                self.cache.insert_at(top0, self.epoch);
                let doc0 = top0[0].id;

                let prefill_t = Stopwatch::start();
                let lm = self.lm;
                let corpus = self.corpus;
                let question = &self.question;
                let opts = &self.opts;
                let state = timed(&mut self.m.generate, || {
                    GenState::new(lm, Some(doc0), &corpus.doc(doc0).tokens,
                                  question, opts.max_doc_tokens,
                                  opts.max_new)
                })?;
                self.m.prefills += 1;
                self.m.event(EventKind::Prefill, &self.total,
                             prefill_t.elapsed());
                self.state = Some(state);
                self.phase = Phase::Running;
                Ok(())
            }
            Phase::AwaitVerify => {
                anyhow::ensure!(truths.len() == self.pending.len(),
                                "verification returned {} rows for {} \
                                 queries",
                                truths.len(), self.pending.len());
                self.m.retrieve += kb_time;
                self.m.event(EventKind::Verify, &self.total, kb_time);

                // Cache update: top-1 or top-k (prefetching) per verified
                // query — stamped with the pinned epoch that scored them.
                for t in &truths {
                    self.cache.insert_at(t, self.epoch);
                }

                // First mismatch (Alg. 1 line 12).
                let mismatch = self
                    .pending
                    .iter()
                    .zip(&truths)
                    .position(|(p, t)| {
                        t.first().map(|s| s.id) != Some(p.spec_doc)
                    });
                let matched = mismatch.unwrap_or(self.pending.len());
                self.m.spec_correct += matched as u32;
                let a_mean = self
                    .pending
                    .iter()
                    .map(|p| p.step_time.as_secs_f64())
                    .sum::<f64>()
                    / self.pending.len() as f64;
                self.scheduler.observe(self.pending.len(), matched, a_mean,
                                       kb_time.as_secs_f64());

                match mismatch {
                    None => {
                        // All verified; the async extra steps (if any)
                        // roll into the next round's pending list.
                        self.pending.clear();
                        self.pending.append(&mut self.extra);
                    }
                    Some(i) => {
                        // Roll back to the mis-speculated step and redo it
                        // with the ground-truth document (Alg. 1 l. 13-16).
                        // Tokens from the async extra steps (speculated
                        // after the snapshot) are discarded with the rest.
                        self.extra.clear();
                        self.m.rollbacks += 1;
                        let state = self.state.as_mut()
                            .expect("generation state exists after prime");
                        self.m.wasted_tokens +=
                            state.rollback(&self.pending[i].snapshot) as u32;
                        let truth_doc = truths[i].first()
                            .expect("verification returned empty top-k");
                        let correct_t = Stopwatch::start();
                        let lm = self.lm;
                        let corpus = self.corpus;
                        let gen_stride = self.opts.gen_stride;
                        let mut prefilled = false;
                        timed(&mut self.m.generate,
                              || -> anyhow::Result<()> {
                            if state.set_doc(
                                lm, truth_doc.id,
                                &corpus.doc(truth_doc.id).tokens)? {
                                prefilled = true;
                            }
                            state.generate(lm, gen_stride)?;
                            Ok(())
                        })?;
                        if prefilled {
                            self.m.prefills += 1;
                        }
                        self.m.event(EventKind::Correct, &self.total,
                                     correct_t.elapsed());
                        self.pending.clear();
                    }
                }
                self.phase = Phase::Running;
                Ok(())
            }
        }
    }

    pub fn is_finished(&self) -> bool {
        matches!(self.phase, Phase::Finished)
    }

    pub fn metrics(&self) -> &ReqMetrics {
        &self.m
    }

    /// Mutable access for drivers that attribute wait time themselves
    /// (`verify_wait` in the async driver, `queue_wait` in the engine).
    pub fn metrics_mut(&mut self) -> &mut ReqMetrics {
        &mut self.m
    }

    /// Final metrics (tokens, latency decomposition). Complete only once
    /// `advance` has returned `Done`.
    pub fn into_metrics(self) -> ReqMetrics {
        self.m
    }

    fn finish(&mut self) {
        if let Some(state) = self.state.as_ref() {
            self.m.tokens_out = state.generated.clone();
            self.m.decode_tokens =
                state.generated.len() as u32 + self.m.wasted_tokens;
        }
        self.m.total = self.total.elapsed();
        self.phase = Phase::Finished;
    }
}

/// [`SpecTask`] is the original [`ServeTask`]: the trait was extracted
/// from its inherent contract (ADR-004), so the impl is pure delegation.
impl<'a, L: LanguageModel> ServeTask for SpecTask<'a, L> {
    fn advance(&mut self) -> anyhow::Result<TaskStep> {
        SpecTask::advance(self)
    }

    fn epoch(&self) -> u64 {
        self.epoch
    }

    fn tenant(&self) -> TenantId {
        self.tenant
    }

    fn overlap_step(&mut self) -> anyhow::Result<bool> {
        SpecTask::overlap_step(self)
    }

    fn provide(&mut self, truths: Vec<Vec<Scored>>, kb_time: Duration)
               -> anyhow::Result<()> {
        SpecTask::provide(self, truths, kb_time)
    }

    fn metrics_mut(&mut self) -> &mut ReqMetrics {
        SpecTask::metrics_mut(self)
    }

    fn into_metrics(self) -> ReqMetrics {
        SpecTask::into_metrics(self)
    }
}

pub struct SpecPipeline<'a, L: LanguageModel> {
    pub lm: &'a L,
    pub kb: &'a dyn Retriever,
    pub corpus: &'a Corpus,
    pub queries: QueryBuilder<'a>,
    pub opts: SpecOptions,
}

impl<'a, L: LanguageModel> SpecPipeline<'a, L> {
    /// Create the resumable task for one request (the engine entry point).
    pub fn task(&self, question: &[u32]) -> SpecTask<'a, L> {
        SpecTask::new(self.lm, self.kb, self.corpus, self.queries,
                      self.opts.clone(), question)
    }

    /// Serve one request to completion. Returns metrics (which include
    /// the tokens). Sync mode answers each `NeedsVerify` inline; async
    /// mode answers on a verifier thread and overlaps extra speculation
    /// steps (up to one full next stride) with the in-flight batch
    /// (Fig 3).
    pub fn run(&self, question: &[u32]) -> anyhow::Result<ReqMetrics> {
        let mut task = self.task(question);
        if self.opts.async_verify {
            std::thread::scope(|scope| {
                let (job_tx, job_rx) =
                    std::sync::mpsc::channel::<(Vec<SpecQuery>, usize)>();
                let (res_tx, res_rx) =
                    std::sync::mpsc::channel::<(Vec<Vec<Scored>>, Duration)>();
                let kb = self.kb;
                // detlint: allow(nondet-source, reason = "scoped verifier thread: it only answers this request's retrieval batches in FIFO order, and the scope joins it before run() returns")
                scope.spawn(move || {
                    while let Ok((qs, k)) = job_rx.recv() {
                        let t = Stopwatch::start();
                        let res = kb.retrieve_batch(&qs, k);
                        if res_tx.send((res, t.elapsed())).is_err() {
                            break;
                        }
                    }
                });
                loop {
                    match task.advance()? {
                        TaskStep::Continue => {}
                        TaskStep::Done => break,
                        TaskStep::NeedsVerify { queries, k } => {
                            // The prime is not a verification round:
                            // waiting for it never counted into
                            // verify_wait before the task refactor and
                            // must not start now.
                            let priming =
                                matches!(task.phase, Phase::AwaitPrime);
                            job_tx.send((queries, k))
                                .expect("verifier thread died");
                            // Overlap: drain the task's extra-step budget
                            // (up to one full next stride) while the batch
                            // retrieval runs on the verifier thread (no-op
                            // during the prime / sync mode). Draining to
                            // exhaustion — not "until the result arrives"
                            // — keeps the schedule deterministic and
                            // identical to the engine's multi-step drive.
                            while task.overlap_step()? {}
                            let wait = Stopwatch::start();
                            let (truths, b) = res_rx.recv()
                                .expect("verifier thread died");
                            if !priming {
                                task.metrics_mut().verify_wait +=
                                    wait.elapsed();
                            }
                            task.provide(truths, b)?;
                        }
                    }
                }
                Ok::<(), anyhow::Error>(())
            })?;
        } else {
            loop {
                match task.advance()? {
                    TaskStep::Continue => {}
                    TaskStep::Done => break,
                    TaskStep::NeedsVerify { queries, k } => {
                        let t = Stopwatch::start();
                        let truths = self.kb.retrieve_batch(&queries, k);
                        task.provide(truths, t.elapsed())?;
                    }
                }
            }
        }
        Ok(task.into_metrics())
    }
}
