//! OS³ — Optimal Speculation Stride Scheduler (paper §4, App. A.2).
//!
//! Maximizes the expected number of correctly-verified documents per unit
//! time. With speculation accuracy γ, speculation-step latency `a`, and
//! batched-verification latency `b(s)`:
//!
//!   sync:   E(s) = (1 - γ^s) / [ (1-γ) · (s·a + b(s)) ]
//!   async:  E(s) = (1 - γ^s) / [ (1-γ) · ( γ^s·((s-1)a + max(a, b(s)))
//!                                        + (1-γ^s)·(s·a + b(s)) ) ]
//!
//! Estimation (A.2): `a` via EMA of measured speculation steps; `b(s)` via
//! least-squares b0 + b1·s over the recent verification latencies (EDR/SR
//! are near-constant in s, ADR is linear with an intercept — both shapes
//! are captured); γ via windowed MLE
//!     γ̂ = Σ_t M(t) / ( Σ_t M(t) + Σ_t 1[M(t) < s(t)] )
//! over the last `w` verifications, clamped to γ_max to avoid
//! division-by-zero / over-optimism as γ̂ → 1.

use crate::util::{linear_fit, Ema};
use std::collections::VecDeque;

#[derive(Debug, Clone)]
pub struct Os3Config {
    /// γ estimation window w (paper: 5).
    pub window: usize,
    /// γ clamp (paper: 0.6).
    pub gamma_max: f64,
    /// Largest stride the scheduler may pick.
    pub max_stride: usize,
    /// Use the asynchronous-verification objective.
    pub async_mode: bool,
}

impl Default for Os3Config {
    fn default() -> Self {
        Self {
            window: crate::config::OS3_WINDOW,
            gamma_max: crate::config::GAMMA_MAX,
            max_stride: 16,
            async_mode: false,
        }
    }
}

/// Stride policy: hand-tuned constant or OS³.
#[derive(Debug, Clone)]
pub enum StridePolicy {
    Fixed(usize),
    Os3(Os3Config),
}

#[derive(Debug)]
pub struct Scheduler {
    policy: StridePolicy,
    current: usize,
    /// (attempted s(t), matched M(t)) of recent verifications.
    history: VecDeque<(usize, usize)>,
    a_est: Ema,
    /// (s, b) points for the linear b(s) model.
    b_points: VecDeque<(f64, f64)>,
}

impl Scheduler {
    pub fn new(policy: StridePolicy) -> Self {
        let current = match &policy {
            StridePolicy::Fixed(s) => (*s).max(1),
            // Paper: OS³ initializes s=1 and adapts onwards (warm-up).
            StridePolicy::Os3(_) => 1,
        };
        Self {
            policy,
            current,
            history: VecDeque::new(),
            a_est: Ema::new(0.25),
            b_points: VecDeque::new(),
        }
    }

    pub fn stride(&self) -> usize {
        self.current
    }

    /// Record one verification round: `attempted` speculation steps of
    /// which `matched` verified, with measured per-step latency `a_step`
    /// (seconds) and verification latency `b_lat` (seconds).
    pub fn observe(&mut self, attempted: usize, matched: usize, a_step: f64,
                   b_lat: f64) {
        let cfg = match &self.policy {
            StridePolicy::Fixed(_) => return,
            StridePolicy::Os3(cfg) => cfg.clone(),
        };
        if attempted == 0 {
            return;
        }
        self.history.push_back((attempted, matched));
        while self.history.len() > cfg.window {
            self.history.pop_front();
        }
        if a_step.is_finite() && a_step > 0.0 {
            self.a_est.update(a_step);
        }
        if b_lat.is_finite() && b_lat > 0.0 {
            self.b_points.push_back((attempted as f64, b_lat));
            while self.b_points.len() > 4 * cfg.window {
                self.b_points.pop_front();
            }
        }
        self.current = self.solve(&cfg);
    }

    /// Windowed-MLE speculation accuracy, clamped to γ_max.
    pub fn gamma(&self) -> f64 {
        let cfg = match &self.policy {
            StridePolicy::Fixed(_) => return 0.0,
            StridePolicy::Os3(cfg) => cfg,
        };
        let m_sum: usize = self.history.iter().map(|&(_, m)| m).sum();
        let miss: usize = self
            .history
            .iter()
            .filter(|&&(s, m)| m < s)
            .count();
        if m_sum + miss == 0 {
            return cfg.gamma_max;
        }
        (m_sum as f64 / (m_sum + miss) as f64).min(cfg.gamma_max)
    }

    /// Linear b(s) = b0 + b1·s from the recent observations.
    fn b_model(&self) -> (f64, f64) {
        let xs: Vec<f64> = self.b_points.iter().map(|&(s, _)| s).collect();
        let ys: Vec<f64> = self.b_points.iter().map(|&(_, b)| b).collect();
        let (b0, b1) = linear_fit(&xs, &ys);
        (b0.max(0.0), b1.max(0.0))
    }

    fn solve(&self, cfg: &Os3Config) -> usize {
        let Some(a) = self.a_est.get() else { return 1 };
        if self.b_points.is_empty() {
            return 1;
        }
        let gamma = self.gamma();
        let (b0, b1) = self.b_model();
        let mut best = (1usize, f64::NEG_INFINITY);
        for s in 1..=cfg.max_stride.max(1) {
            let e = objective(gamma, a, b0 + b1 * s as f64, s, cfg.async_mode);
            if e > best.1 {
                best = (s, e);
            }
        }
        best.0
    }
}

/// `base^n` by plain repeated multiplication. `f64::powi` may lower to a
/// `pow` libm call whose rounding differs across platforms; the stride
/// choice must be bit-stable (ADR-007), and `n <= max_stride` is tiny, so
/// the naive loop is both exact-ordered and cheap.
fn pow_det(base: f64, n: usize) -> f64 {
    let mut acc = 1.0f64;
    for _ in 0..n {
        acc *= base;
    }
    acc
}

/// The OS³ objective E(s): expected verified documents per unit time.
pub fn objective(gamma: f64, a: f64, b: f64, s: usize, async_mode: bool)
                 -> f64 {
    let gamma = gamma.clamp(0.0, 0.999_999);
    let s_f = s as f64;
    let g_s = pow_det(gamma, s);
    let expected_verified = (1.0 - g_s) / (1.0 - gamma);
    let latency = if async_mode {
        g_s * ((s_f - 1.0) * a + a.max(b)) + (1.0 - g_s) * (s_f * a + b)
    } else {
        s_f * a + b
    };
    if latency <= 0.0 {
        return f64::NEG_INFINITY;
    }
    expected_verified / latency
}

#[cfg(test)]
mod tests {
    use super::*;

    fn os3(async_mode: bool, max_stride: usize) -> Scheduler {
        Scheduler::new(StridePolicy::Os3(Os3Config {
            window: 5,
            gamma_max: 0.6,
            max_stride,
            async_mode,
        }))
    }

    #[test]
    fn fixed_policy_never_moves() {
        let mut s = Scheduler::new(StridePolicy::Fixed(3));
        assert_eq!(s.stride(), 3);
        s.observe(3, 0, 1.0, 10.0);
        assert_eq!(s.stride(), 3);
    }

    #[test]
    fn os3_warms_up_at_one() {
        let s = os3(false, 16);
        assert_eq!(s.stride(), 1, "paper initializes s=1 under OS³");
    }

    #[test]
    fn expensive_verification_pushes_stride_up() {
        // b >> a and high accuracy: amortize verification over many steps.
        // With the paper's γ_max = 0.6 clamp the optimum lands mid-range;
        // the warm-up s=1 must clearly grow.
        let mut s = os3(false, 16);
        for _ in 0..10 {
            let cur = s.stride();
            s.observe(cur, cur, 0.01, 0.5); // all match; b = 50x a
        }
        assert!(s.stride() >= 5, "stride={} should grow", s.stride());
        // Without the clamp the same regime pushes near the max.
        let mut s2 = Scheduler::new(StridePolicy::Os3(Os3Config {
            window: 5, gamma_max: 0.98, max_stride: 16, async_mode: false,
        }));
        for _ in 0..10 {
            let cur = s2.stride();
            s2.observe(cur, cur, 0.01, 0.5);
        }
        assert!(s2.stride() >= 12, "unclamped stride={}", s2.stride());
    }

    #[test]
    fn cheap_verification_keeps_stride_small() {
        // b << a: speculating more only risks overhead.
        let mut s = os3(false, 16);
        for _ in 0..10 {
            let cur = s.stride();
            s.observe(cur, cur / 2, 0.05, 0.001); // frequent mismatches
        }
        assert!(s.stride() <= 2, "stride={} should stay small", s.stride());
    }

    #[test]
    fn gamma_mle_matches_formula() {
        let mut s = os3(false, 16);
        // M = [3, 2] with strides [3, 3]: gamma = (3+2)/(5 + 1 miss) = 5/6
        // -> clamped at 0.6.
        s.observe(3, 3, 0.01, 0.01);
        s.observe(3, 2, 0.01, 0.01);
        assert!((s.gamma() - 0.6).abs() < 1e-9, "clamped at gamma_max");
        // Now force many misses: gamma drops below the clamp.
        for _ in 0..5 {
            s.observe(3, 0, 0.01, 0.01);
        }
        // window=5 keeps only the miss rounds: gamma = 0/(0+5) = 0
        assert!(s.gamma() < 1e-9);
    }

    #[test]
    fn objective_matches_paper_formulas() {
        // sync: (1 - g^s)/((1-g)(sa+b))
        let (g, a, b, s) = (0.5, 0.1, 0.4, 3usize);
        let expect = (1.0 - 0.125) / (0.5 * (0.3 + 0.4));
        assert!((objective(g, a, b, s, false) - expect).abs() < 1e-12);
        // async: latency = g^s((s-1)a + max(a,b)) + (1-g^s)(sa+b)
        let lat = 0.125 * (0.2 + 0.4) + 0.875 * 0.7;
        let expect = (1.0 - 0.125) / (0.5 * lat);
        assert!((objective(g, a, b, s, true) - expect).abs() < 1e-12);
    }

    #[test]
    fn async_objective_prefers_stride_one_when_b_below_a() {
        // Paper §3: with async verification and b <= a, s = 1 is optimal.
        let (g, a, b) = (0.6, 0.1, 0.05);
        let e1 = objective(g, a, b, 1, true);
        for s in 2..=16 {
            assert!(e1 >= objective(g, a, b, s, true), "s={s} beat s=1");
        }
    }

    #[test]
    fn solver_matches_bruteforce_argmax() {
        let mut sched = os3(false, 12);
        for i in 0..8 {
            sched.observe(sched.stride(), if i % 3 == 0 { sched.stride() - 1 }
                          else { sched.stride() }.min(sched.stride()),
                          0.02, 0.1 + 0.01 * sched.stride() as f64);
        }
        let gamma = sched.gamma();
        let (b0, b1) = sched.b_model();
        let a = sched.a_est.get().unwrap();
        let brute = (1..=12)
            .max_by(|&x, &y| {
                objective(gamma, a, b0 + b1 * x as f64, x, false)
                    .partial_cmp(&objective(gamma, a, b0 + b1 * y as f64, y,
                                            false))
                    .unwrap()
            })
            .unwrap();
        assert_eq!(sched.stride(), brute);
    }

    #[test]
    fn observe_zero_attempted_is_ignored() {
        let mut s = os3(false, 8);
        s.observe(0, 0, 0.01, 0.01);
        assert_eq!(s.stride(), 1);
        assert!(s.history.is_empty());
    }
}
