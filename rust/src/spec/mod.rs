//! RaLMSpec core: speculative retrieval + batched verification (§3),
//! optimal speculation stride scheduling (§4), asynchronous verification.

pub mod os3;
pub mod pipeline;
pub mod query;

pub use os3::{objective, Os3Config, Scheduler, StridePolicy};
pub use pipeline::{SpecOptions, SpecPipeline, SpecTask, TaskStep};
pub use query::{QueryBuilder, QueryMode};
