//! # RaLMSpec — speculative retrieval for iterative RaLM serving
//!
//! Rust + JAX + Pallas reproduction of *"Accelerating Retrieval-Augmented
//! Language Model Serving with Speculation"* (Zhang et al., 2024).
//!
//! Layering (see DESIGN.md):
//! * `runtime` — PJRT bridge: loads the AOT HLO-text artifacts produced by
//!   `python/compile/aot.py` and executes them (weights + KV caches stay as
//!   device buffers).
//! * `lm` — generation state machine over the runtime (or a deterministic
//!   mock for fast tests).
//! * `retriever` / `cache` — the knowledge-base substrates (exact dense,
//!   HNSW, BM25; batch-first, shard-parallel) and the per-request
//!   speculation cache. `retriever::epoch` adds live updates: mutable
//!   writer-side indices publishing immutable epoch snapshots (ADR-006).
//! * `spec` — the paper's contribution: speculative retrieval, batched
//!   verification + rollback, OS³ stride scheduling, async verification.
//! * `baseline` — RaLMSeq (retrieve-every-k-tokens) reference serving.
//! * `knnlm` — KNN-LM datastore serving with relaxed verification (§5.3).
//! * `serving` — std-thread request router / queue / workers
//!   (vLLM-router-like) plus the cross-request coalescing `ServeEngine`
//!   with asynchronous KB-call execution.
//! * `eval` — regenerates every table and figure of the paper's
//!   evaluation, plus the serve/bench-gate drivers.
//!
//! A quickstart, CLI flag reference, and config-key table live in the
//! top-level README.md; design rationale is in DESIGN.md (ADRs 001–006).
//! Source-level determinism and unsafe-hygiene invariants (ADR-008) are
//! machine-checked by `tools/detlint`; the attribute below backs its
//! `safety-comment` rule — every unsafe operation inside an `unsafe fn`
//! needs its own block and `// SAFETY:` note.

#![deny(unsafe_op_in_unsafe_fn)]

pub mod baseline;
pub mod cli;
pub mod cache;
pub mod config;
pub mod datagen;
pub mod eval;
pub mod knnlm;
pub mod lm;
pub mod metrics;
pub mod retriever;
pub mod runtime;
pub mod serving;
pub mod spec;
pub mod util;

pub use config::{Config, RetrieverKind};
pub use retriever::{DocId, EpochKb, EpochSnapshot, KbWriter, LiveKb,
                    MutableRetriever, Retriever, ShardedRetriever,
                    SpecQuery, WorkerPool};
