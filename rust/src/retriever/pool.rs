//! Persistent worker pool for shard-parallel retrieval.
//!
//! Std-threads only (the offline image has no tokio/rayon): a
//! Mutex+Condvar job queue feeding N long-lived workers. The pool is
//! created once and shared (`Arc`) by every `ShardedRetriever`, so
//! scatter-gather fan-out never pays thread spawn/teardown on the query
//! path — the property the ROADMAP's "persistent worker pool" item asks
//! for.
//!
//! Jobs are `'static` closures; callers share borrowed request data with
//! workers via `Arc` (see `sharded.rs`). A panicking job is caught so a
//! poisoned task cannot take a worker down with it; the scatter caller
//! observes the missing result and panics with a diagnostic on its own
//! thread instead.
//!
//! Two submission shapes:
//!   * [`WorkerPool::scatter`] — fan a task vector out, block for all
//!     results in order (the sharded retrieval path);
//!   * [`WorkerPool::submit`] — hand one job off and get a [`JobHandle`]
//!     back immediately, with worker-side panics converted to `Err`
//!     instead of a lost result. This is the general-purpose
//!     single-job surface; the serving engine's `RetrievalExecutor`
//!     shares its panic-to-error core ([`run_caught`]) but feeds one
//!     multi-group completion queue of its own rather than per-handle
//!     channels (it needs completions as they arrive across many calls,
//!     not a blocking wait per call).

use std::collections::VecDeque;
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Duration;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Best-effort human-readable message from a panic payload (the payload
/// of `catch_unwind`): `panic!("...")` yields `&str` or `String`; anything
/// else gets a generic marker.
pub fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Run a closure with panics converted to `Err` (the panic-to-error
/// conversion shared by [`JobHandle`] and the serving engine's
/// `RetrievalExecutor`): the caller gets a diagnosable failure instead of
/// an unwinding thread or a silently dropped result channel.
pub fn run_caught<T>(f: impl FnOnce() -> T) -> anyhow::Result<T> {
    std::panic::catch_unwind(std::panic::AssertUnwindSafe(f)).map_err(|p| {
        anyhow::anyhow!("job panicked: {}", panic_message(p.as_ref()))
    })
}

/// Handle to one job submitted with [`WorkerPool::submit`]. Await the
/// result with [`wait`](Self::wait); a job that panicked on its worker
/// comes back as `Err` (panic-to-error conversion), so callers can treat
/// a poisoned job like any other failure instead of losing the result.
pub struct JobHandle<T> {
    rx: Receiver<anyhow::Result<T>>,
}

impl<T> JobHandle<T> {
    /// Block until the job finishes. `Err` if the job panicked or the
    /// pool shut down before running it.
    pub fn wait(self) -> anyhow::Result<T> {
        match self.rx.recv() {
            Ok(r) => r,
            Err(_) => Err(anyhow::anyhow!(
                "worker pool shut down before the job completed")),
        }
    }

    /// Non-consuming timed wait: `None` while the job is still running.
    /// A handle delivers exactly one result — after a `Some` has been
    /// returned the handle is spent, and any further call reports the
    /// pool-shutdown error (the sender side is gone).
    pub fn wait_timeout(&self, d: Duration)
                        -> Option<anyhow::Result<T>> {
        match self.rx.recv_timeout(d) {
            Ok(r) => Some(r),
            Err(RecvTimeoutError::Timeout) => None,
            Err(RecvTimeoutError::Disconnected) => Some(Err(anyhow::anyhow!(
                "worker pool shut down before the job completed"))),
        }
    }
}

struct PoolState {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    cv: Condvar,
}

pub struct WorkerPool {
    shared: Arc<PoolShared>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn a pool with `n` workers (at least one).
    pub fn new(n: usize) -> Self {
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState { jobs: VecDeque::new(), shutdown: false }),
            cv: Condvar::new(),
        });
        let workers = (0..n.max(1))
            .map(|wid| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("ralmspec-shard-{wid}"))
                    .spawn(move || loop {
                        let job = {
                            // detlint: allow(hot-panic, reason = "queue mutex poisoning means a sibling worker panicked outside catch_unwind; propagate")
                            let mut st = shared.state.lock().unwrap();
                            loop {
                                if let Some(j) = st.jobs.pop_front() {
                                    break Some(j);
                                }
                                if st.shutdown {
                                    break None;
                                }
                                // detlint: allow(hot-panic, reason = "condvar wait only fails on a poisoned queue mutex; propagate")
                                st = shared.cv.wait(st).unwrap();
                            }
                        };
                        match job {
                            Some(j) => {
                                // Contain panics to the job: the worker
                                // survives, the scatter caller notices the
                                // dropped result channel.
                                let _ = std::panic::catch_unwind(
                                    std::panic::AssertUnwindSafe(j));
                            }
                            None => return,
                        }
                    })
                    // detlint: allow(hot-panic, reason = "spawn failure at pool construction is unrecoverable (OS thread exhaustion)")
                    .expect("spawning pool worker")
            })
            .collect();
        Self { shared, workers }
    }

    /// Pool sized to the machine (used by the process-wide default pool).
    pub fn with_default_size() -> Self {
        let n = std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(2);
        Self::new(n)
    }

    /// The process-wide shared pool. Created lazily on first use; sized to
    /// the machine's available parallelism. All `ShardedRetriever`s built
    /// without an explicit pool share it, so total shard-worker threads
    /// stay bounded no matter how many sharded backends exist.
    pub fn global() -> &'static Arc<WorkerPool> {
        static GLOBAL: OnceLock<Arc<WorkerPool>> = OnceLock::new();
        GLOBAL.get_or_init(|| Arc::new(WorkerPool::with_default_size()))
    }

    /// The process-wide pool for **whole knowledge-base calls** (the
    /// serving engine's asynchronous `RetrievalExecutor`). Deliberately
    /// separate from [`global`](Self::global): a KB call may itself be a
    /// `ShardedRetriever` scatter that *blocks its worker* until the
    /// shard jobs (queued on the shard pool) complete. If both job kinds
    /// shared one pool, enough concurrent KB calls would occupy every
    /// worker and the shard jobs they are waiting on could never
    /// schedule — a circular wait. Two pools make the dependency
    /// one-directional (KB workers wait on shard workers, never the
    /// reverse), so the deadlock cannot form.
    pub fn kb_global() -> &'static Arc<WorkerPool> {
        static KB_GLOBAL: OnceLock<Arc<WorkerPool>> = OnceLock::new();
        KB_GLOBAL.get_or_init(|| Arc::new(WorkerPool::with_default_size()))
    }

    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Enqueue one job and return a [`JobHandle`] for its result. A
    /// panicking job surfaces as `Err` through the handle (the worker
    /// itself always survives). Complements [`scatter`](Self::scatter)
    /// for callers that want completions as they happen rather than a
    /// blocking all-or-nothing gather.
    pub fn submit<T, F>(&self, job: F) -> JobHandle<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let (tx, rx) = std::sync::mpsc::channel();
        self.execute(Box::new(move || {
            let _ = tx.send(run_caught(job));
        }));
        JobHandle { rx }
    }

    /// Enqueue one fire-and-forget job.
    pub fn execute(&self, job: Job) {
        // detlint: allow(hot-panic, reason = "queue mutex poisoning means a worker panicked outside catch_unwind; propagate")
        let mut st = self.shared.state.lock().unwrap();
        st.jobs.push_back(job);
        drop(st);
        self.shared.cv.notify_one();
    }

    /// Run every task on the pool and return their results **in task
    /// order**, blocking until all complete. This is the scatter half of
    /// the sharded scatter-gather path.
    pub fn scatter<T, F>(&self, tasks: Vec<F>) -> Vec<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let n = tasks.len();
        if n == 0 {
            return Vec::new();
        }
        let (tx, rx) = std::sync::mpsc::channel::<(usize, T)>();
        for (i, task) in tasks.into_iter().enumerate() {
            let tx = tx.clone();
            self.execute(Box::new(move || {
                let _ = tx.send((i, task()));
            }));
        }
        drop(tx);
        let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
        let mut got = 0usize;
        while let Ok((i, v)) = rx.recv() {
            debug_assert!(out[i].is_none(), "duplicate scatter result");
            out[i] = Some(v);
            got += 1;
            if got == n {
                break;
            }
        }
        assert_eq!(got, n, "worker pool lost {} task(s) (panicked job?)",
                   n - got);
        // detlint: allow(hot-panic, reason = "the assert above guarantees all n slots were filled")
        out.into_iter().map(|o| o.unwrap()).collect()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            // detlint: allow(hot-panic, reason = "poisoned queue mutex during teardown; nothing left to preserve")
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.cv.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn scatter_returns_results_in_order() {
        let pool = WorkerPool::new(3);
        let tasks: Vec<_> = (0..17usize).map(|i| move || i * i).collect();
        assert_eq!(pool.scatter(tasks),
                   (0..17usize).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn scatter_with_more_tasks_than_workers() {
        let pool = WorkerPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        let tasks: Vec<_> = (0..64)
            .map(|_| {
                let c = counter.clone();
                move || c.fetch_add(1, Ordering::SeqCst)
            })
            .collect();
        let results = pool.scatter(tasks);
        assert_eq!(results.len(), 64);
        assert_eq!(counter.load(Ordering::SeqCst), 64);
    }

    #[test]
    fn empty_scatter_is_noop() {
        let pool = WorkerPool::new(1);
        let out: Vec<usize> = pool.scatter(Vec::<fn() -> usize>::new());
        assert!(out.is_empty());
    }

    #[test]
    fn pool_survives_panicking_job() {
        let pool = WorkerPool::new(1);
        pool.execute(Box::new(|| panic!("boom")));
        // The single worker must still serve subsequent tasks.
        let tasks: Vec<fn() -> usize> = vec![|| 41, || 1];
        let out = pool.scatter(tasks);
        assert_eq!(out.iter().sum::<usize>(), 42);
    }

    #[test]
    fn drop_joins_workers() {
        let pool = WorkerPool::new(4);
        let tasks: Vec<fn() -> i32> = vec![|| 1, || 2];
        let _ = pool.scatter(tasks);
        drop(pool); // must not hang
    }

    #[test]
    fn submit_returns_result_through_handle() {
        let pool = WorkerPool::new(2);
        let h = pool.submit(|| 6 * 7);
        assert_eq!(h.wait().unwrap(), 42);
    }

    #[test]
    fn submit_converts_panic_to_error() {
        let pool = WorkerPool::new(1);
        let h: JobHandle<u32> = pool.submit(|| panic!("kb exploded"));
        let err = h.wait().unwrap_err();
        assert!(format!("{err}").contains("kb exploded"),
                "panic payload lost: {err}");
        // The worker survives the panic and serves the next job.
        assert_eq!(pool.submit(|| 1u32).wait().unwrap(), 1);
    }

    #[test]
    fn wait_timeout_reports_pending_then_done() {
        let pool = WorkerPool::new(1);
        let h = pool.submit(|| {
            std::thread::sleep(Duration::from_millis(30));
            7u32
        });
        // Either still pending (None) or already done; after a generous
        // wait it must be done. Each handle result is delivered once.
        let first = h.wait_timeout(Duration::from_millis(1));
        match first {
            Some(r) => assert_eq!(r.unwrap(), 7),
            None => assert_eq!(
                h.wait_timeout(Duration::from_secs(5)).unwrap().unwrap(), 7),
        }
    }

    #[test]
    fn panic_message_extracts_payloads() {
        let p = std::panic::catch_unwind(|| panic!("plain")).unwrap_err();
        assert_eq!(panic_message(p.as_ref()), "plain");
        let p = std::panic::catch_unwind(|| panic!("id {}", 3)).unwrap_err();
        assert_eq!(panic_message(p.as_ref()), "id 3");
        let p = std::panic::catch_unwind(
            || std::panic::panic_any(17u64)).unwrap_err();
        assert_eq!(panic_message(p.as_ref()), "non-string panic payload");
    }

    #[test]
    fn global_pool_is_shared() {
        let a = WorkerPool::global();
        let b = WorkerPool::global();
        assert!(Arc::ptr_eq(a, b));
        assert!(a.workers() >= 1);
    }
}
