//! Persistent worker pool for shard-parallel retrieval.
//!
//! Std-threads only (the offline image has no tokio/rayon): a
//! Mutex+Condvar job queue feeding N long-lived workers. The pool is
//! created once and shared (`Arc`) by every `ShardedRetriever`, so
//! scatter-gather fan-out never pays thread spawn/teardown on the query
//! path — the property the ROADMAP's "persistent worker pool" item asks
//! for.
//!
//! Jobs are `'static` closures; callers share borrowed request data with
//! workers via `Arc` (see `sharded.rs`). A panicking job is caught so a
//! poisoned task cannot take a worker down with it; the scatter caller
//! observes the missing result and panics with a diagnostic on its own
//! thread instead.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex, OnceLock};

type Job = Box<dyn FnOnce() + Send + 'static>;

struct PoolState {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    cv: Condvar,
}

pub struct WorkerPool {
    shared: Arc<PoolShared>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn a pool with `n` workers (at least one).
    pub fn new(n: usize) -> Self {
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState { jobs: VecDeque::new(), shutdown: false }),
            cv: Condvar::new(),
        });
        let workers = (0..n.max(1))
            .map(|wid| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("ralmspec-shard-{wid}"))
                    .spawn(move || loop {
                        let job = {
                            let mut st = shared.state.lock().unwrap();
                            loop {
                                if let Some(j) = st.jobs.pop_front() {
                                    break Some(j);
                                }
                                if st.shutdown {
                                    break None;
                                }
                                st = shared.cv.wait(st).unwrap();
                            }
                        };
                        match job {
                            Some(j) => {
                                // Contain panics to the job: the worker
                                // survives, the scatter caller notices the
                                // dropped result channel.
                                let _ = std::panic::catch_unwind(
                                    std::panic::AssertUnwindSafe(j));
                            }
                            None => return,
                        }
                    })
                    .expect("spawning pool worker")
            })
            .collect();
        Self { shared, workers }
    }

    /// Pool sized to the machine (used by the process-wide default pool).
    pub fn with_default_size() -> Self {
        let n = std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(2);
        Self::new(n)
    }

    /// The process-wide shared pool. Created lazily on first use; sized to
    /// the machine's available parallelism. All `ShardedRetriever`s built
    /// without an explicit pool share it, so total shard-worker threads
    /// stay bounded no matter how many sharded backends exist.
    pub fn global() -> &'static Arc<WorkerPool> {
        static GLOBAL: OnceLock<Arc<WorkerPool>> = OnceLock::new();
        GLOBAL.get_or_init(|| Arc::new(WorkerPool::with_default_size()))
    }

    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Enqueue one fire-and-forget job.
    pub fn execute(&self, job: Job) {
        let mut st = self.shared.state.lock().unwrap();
        st.jobs.push_back(job);
        drop(st);
        self.shared.cv.notify_one();
    }

    /// Run every task on the pool and return their results **in task
    /// order**, blocking until all complete. This is the scatter half of
    /// the sharded scatter-gather path.
    pub fn scatter<T, F>(&self, tasks: Vec<F>) -> Vec<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let n = tasks.len();
        if n == 0 {
            return Vec::new();
        }
        let (tx, rx) = std::sync::mpsc::channel::<(usize, T)>();
        for (i, task) in tasks.into_iter().enumerate() {
            let tx = tx.clone();
            self.execute(Box::new(move || {
                let _ = tx.send((i, task()));
            }));
        }
        drop(tx);
        let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
        let mut got = 0usize;
        while let Ok((i, v)) = rx.recv() {
            debug_assert!(out[i].is_none(), "duplicate scatter result");
            out[i] = Some(v);
            got += 1;
            if got == n {
                break;
            }
        }
        assert_eq!(got, n, "worker pool lost {} task(s) (panicked job?)",
                   n - got);
        out.into_iter().map(|o| o.unwrap()).collect()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.cv.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn scatter_returns_results_in_order() {
        let pool = WorkerPool::new(3);
        let tasks: Vec<_> = (0..17usize).map(|i| move || i * i).collect();
        assert_eq!(pool.scatter(tasks),
                   (0..17usize).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn scatter_with_more_tasks_than_workers() {
        let pool = WorkerPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        let tasks: Vec<_> = (0..64)
            .map(|_| {
                let c = counter.clone();
                move || c.fetch_add(1, Ordering::SeqCst)
            })
            .collect();
        let results = pool.scatter(tasks);
        assert_eq!(results.len(), 64);
        assert_eq!(counter.load(Ordering::SeqCst), 64);
    }

    #[test]
    fn empty_scatter_is_noop() {
        let pool = WorkerPool::new(1);
        let out: Vec<usize> = pool.scatter(Vec::<fn() -> usize>::new());
        assert!(out.is_empty());
    }

    #[test]
    fn pool_survives_panicking_job() {
        let pool = WorkerPool::new(1);
        pool.execute(Box::new(|| panic!("boom")));
        // The single worker must still serve subsequent tasks.
        let tasks: Vec<fn() -> usize> = vec![|| 41, || 1];
        let out = pool.scatter(tasks);
        assert_eq!(out.iter().sum::<usize>(), 42);
    }

    #[test]
    fn drop_joins_workers() {
        let pool = WorkerPool::new(4);
        let tasks: Vec<fn() -> i32> = vec![|| 1, || 2];
        let _ = pool.scatter(tasks);
        drop(pool); // must not hang
    }

    #[test]
    fn global_pool_is_shared() {
        let a = WorkerPool::global();
        let b = WorkerPool::global();
        assert!(Arc::ptr_eq(a, b));
        assert!(a.workers() >= 1);
    }
}
