//! Vectorized scoring primitives shared by **every** retrieval path —
//! the serving engine, the sequential references (`SpecPipeline::run`,
//! `KnnLmSpec::run`, the baseline), the KNN-LM cache, and the HNSW walk
//! all score through the functions here, so the repo-wide bit-identity
//! guarantee is preserved *by construction*: there is exactly one
//! reduction order per kernel, whatever the instruction set (DESIGN.md
//! ADR-007).
//!
//! Five kernels, each with a scalar form and (behind the `simd` cargo
//! feature + runtime CPU detection) an AVX2/NEON form:
//!
//! * [`dot`] — inner product (the EDR/ADR/cache similarity metric);
//! * [`l2_sq`] — squared L2 distance (the codec-verification primitive
//!   for quantized segments, ROADMAP item 1);
//! * [`scan_block`] — the LANES-wide multi-query scan of the flat dense
//!   retriever: one corpus row scored against up to [`LANES`] packed
//!   queries per pass;
//! * [`dot_u8i8`] / [`scan_i8`] — the SQ8 quantized-candidate kernels
//!   (DESIGN.md ADR-010): integer dot of a signed-i8 query against
//!   unsigned-u8 row codes, streamed at 1 byte per coordinate — the 4x
//!   memory-density win the two-phase dense scan rests on. Integer
//!   arithmetic is exact, so the scalar twin and the `maddubs`/widening
//!   NEON forms agree bit-for-bit by construction (no reduction-order
//!   discipline needed — there is no rounding to order).
//!
//! ## Why scalar and SIMD results are bit-identical
//!
//! Both forms keep [`LANES`] independent per-lane partial sums and
//! combine them with the same fixed reduction tree
//! (`((l0+l4)+(l2+l6)) + ((l1+l5)+(l3+l7))`, i.e. the halves-then-pairs
//! order a 256-bit horizontal add produces), then add the scalar tail.
//! Every f32 multiply and add is individually correctly rounded
//! (IEEE 754), so identical operation order ⇒ identical bits. The one
//! trap is *fused* multiply-add (`vfmadd`/`fmla`): it rounds once where
//! `mul`+`add` round twice, so the SIMD paths deliberately emit separate
//! multiply and add instructions. The cost is small (both pipelines are
//! throughput-bound on loads here); the benefit is that the scalar
//! fallback *is* the reference, and the dispatch decision can never
//! change results — only speed.
//!
//! Dispatch is resolved once per process ([`simd_active`], cached): all
//! threads — shard workers, the KB-call pool, the engine thread — see
//! the same decision, so sharded scatter-gather merges scores produced
//! by one kernel implementation.

use super::DocId;
use crate::util::TopK;

/// Lane width of the multi-query scan and of the per-lane partial sums
/// (8 × f32 = one AVX2 register, two NEON registers).
pub const LANES: usize = 8;

// The fixed reduction tree below is written for exactly 8 lanes.
const _: () = assert!(LANES == 8);

/// Magnitude bound on SQ8 *query* codes (`[-SQ8_QMAX, SQ8_QMAX]`, 129
/// levels). Chosen so the AVX2 `maddubs` adjacent-pair i16 sums can never
/// saturate: each pair sum is at most `2 · 255 · SQ8_QMAX = 32640 <
/// i16::MAX`. Row codes use the full unsigned `0..=255` range; the query
/// side pays one bit of resolution for an exact (saturation-free) integer
/// kernel, and the reconstruction-error bound absorbs the difference
/// (DESIGN.md ADR-010).
pub const SQ8_QMAX: i32 = 64;

/// How many rows ahead the block scans issue a software prefetch for.
/// Far enough to cover the per-row scoring latency, near enough that the
/// line is still resident when the scan arrives.
const PREFETCH_ROWS: usize = 4;

/// Whether the vectorized kernel forms are in use in this process
/// (compile-time `simd` feature AND runtime CPU support). Resolved once
/// and cached: the decision is process-wide constant, which the sharded
/// retriever's bit-identical-merge property relies on.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
pub fn simd_active() -> bool {
    use std::sync::OnceLock;
    static AVX2: OnceLock<bool> = OnceLock::new();
    *AVX2.get_or_init(|| is_x86_feature_detected!("avx2"))
}

/// Whether the vectorized kernel forms are in use in this process. NEON
/// is baseline on aarch64, so with the `simd` feature on this is
/// unconditionally true.
#[cfg(all(feature = "simd", target_arch = "aarch64"))]
pub fn simd_active() -> bool {
    true
}

/// Whether the vectorized kernel forms are in use in this process. The
/// `simd` feature is off (or the arch has no vector path): always false,
/// every kernel runs its scalar form.
#[cfg(not(all(feature = "simd",
              any(target_arch = "x86_64", target_arch = "aarch64"))))]
pub fn simd_active() -> bool {
    false
}

/// The shared reduction tree over the 8 per-lane partial sums — the
/// exact association a 256-bit horizontal add performs (fold the high
/// half onto the low half, then pairs), mirrored here so the scalar
/// kernels produce the same bits as the vector kernels.
#[inline(always)]
fn reduce_lanes(l: &[f32; LANES]) -> f32 {
    ((l[0] + l[4]) + (l[2] + l[6])) + ((l[1] + l[5]) + (l[3] + l[7]))
}

/// Inner product, scalar form: 8 independent per-lane accumulators over
/// 8-element chunks, the shared reduction tree, then a left-to-right
/// scalar tail. This *is* the reference semantics of [`dot`]; the SIMD
/// forms reproduce it bit-for-bit (pinned by tests/kernel_equivalence.rs
/// across dims including non-multiple-of-8 tails).
pub fn dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut lanes = [0.0f32; LANES];
    for (ca, cb) in a.chunks_exact(LANES).zip(b.chunks_exact(LANES)) {
        for ((s, &x), &y) in lanes.iter_mut().zip(ca).zip(cb) {
            *s += x * y;
        }
    }
    let mut tail = 0.0f32;
    let done = (a.len() / LANES) * LANES;
    for (x, y) in a[done..].iter().zip(&b[done..]) {
        tail += x * y;
    }
    reduce_lanes(&lanes) + tail
}

/// Squared L2 distance, scalar form (same structure as [`dot_scalar`]:
/// per-lane sums of `(a-b)^2`, shared reduction tree, scalar tail).
pub fn l2_sq_scalar(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut lanes = [0.0f32; LANES];
    for (ca, cb) in a.chunks_exact(LANES).zip(b.chunks_exact(LANES)) {
        for ((s, &x), &y) in lanes.iter_mut().zip(ca).zip(cb) {
            let d = x - y;
            *s += d * d;
        }
    }
    let mut tail = 0.0f32;
    let done = (a.len() / LANES) * LANES;
    for (x, y) in a[done..].iter().zip(&b[done..]) {
        let d = x - y;
        tail += d * d;
    }
    reduce_lanes(&lanes) + tail
}

/// Multi-query scan, scalar form: score every `d`-wide row of `rows`
/// against the column-major query pack `qt` (`qt[j*LANES + lane]`,
/// zero-padded to [`LANES`] lanes) and push `(first_id + row, score)`
/// into the per-query heaps (`heaps.len()` ≤ LANES live lanes; padding
/// lanes are scored but discarded). Each lane keeps a single accumulator
/// walked in coordinate order, so scalar and SIMD lanes are trivially
/// bit-identical — the per-lane sums never cross lanes.
pub fn scan_block_scalar(rows: &[f32], d: usize, first_id: DocId,
                         qt: &[f32], heaps: &mut [TopK]) {
    debug_assert!(qt.len() >= d * LANES);
    debug_assert!(heaps.len() <= LANES);
    for (i, row) in rows.chunks_exact(d).enumerate() {
        let ahead = (i + PREFETCH_ROWS) * d;
        if ahead + d <= rows.len() {
            prefetch_row(rows[ahead..].as_ptr().cast(), d * 4);
        }
        let mut scores = [0.0f32; LANES];
        for (j, &x) in row.iter().enumerate() {
            let qrow = &qt[j * LANES..(j + 1) * LANES];
            for (s, &qv) in scores.iter_mut().zip(qrow) {
                *s += x * qv;
            }
        }
        for (h, &s) in heaps.iter_mut().zip(&scores) {
            h.push(first_id + i as DocId, s);
        }
    }
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod x86 {
    use super::{DocId, TopK, LANES, PREFETCH_ROWS};
    use std::arch::x86_64::*;

    /// Fold a 256-bit accumulator with the shared reduction tree:
    /// high half onto low half (`m[j] = l[j] + l[j+4]`), then the same
    /// pairs-then-sum association as `reduce_lanes`.
    ///
    /// # Safety
    /// The CPU must support AVX (implied by the AVX2 contract of every
    /// caller in this module).
    #[inline(always)]
    unsafe fn hsum(acc: __m256) -> f32 {
        // SAFETY: register-only lane arithmetic plus one unaligned
        // store into `m`, a 4-element stack array of exactly the
        // 128-bit store width.
        unsafe {
            let lo = _mm256_castps256_ps128(acc);
            let hi = _mm256_extractf128_ps::<1>(acc);
            let mut m = [0.0f32; 4];
            _mm_storeu_ps(m.as_mut_ptr(), _mm_add_ps(lo, hi));
            (m[0] + m[2]) + (m[1] + m[3])
        }
    }

    /// AVX2 `dot`: separate `mul` + `add` (NOT `fmadd` — fusing rounds
    /// once where the scalar form rounds twice, which would break the
    /// scalar/SIMD bit-identity the dispatch relies on), `hsum`, then
    /// the same scalar tail as the reference.
    ///
    /// # Safety
    /// The CPU must support AVX2; the dispatchers check `simd_active()`
    /// (runtime `avx2` detection) before calling.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot_avx2(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let chunks = a.len() / LANES;
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        // SAFETY: each iteration loads LANES f32s at `p.add(c * LANES)`
        // with `c < chunks = len / LANES`, so every unaligned load stays
        // inside both slices; AVX2 availability is the caller's
        // contract, AVX for `hsum` is implied by it.
        let body = unsafe {
            let mut acc = _mm256_setzero_ps();
            for c in 0..chunks {
                let i = c * LANES;
                let va = _mm256_loadu_ps(pa.add(i));
                let vb = _mm256_loadu_ps(pb.add(i));
                acc = _mm256_add_ps(acc, _mm256_mul_ps(va, vb));
            }
            hsum(acc)
        };
        let mut tail = 0.0f32;
        let done = chunks * LANES;
        for (x, y) in a[done..].iter().zip(&b[done..]) {
            tail += x * y;
        }
        body + tail
    }

    /// AVX2 `l2_sq` (same structure: `sub`, `mul`, `add` — no fusing).
    ///
    /// # Safety
    /// The CPU must support AVX2; the dispatchers check `simd_active()`
    /// (runtime `avx2` detection) before calling.
    #[target_feature(enable = "avx2")]
    pub unsafe fn l2_sq_avx2(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let chunks = a.len() / LANES;
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        // SAFETY: same bounds argument as `dot_avx2` — every load of
        // LANES f32s at `c * LANES` with `c < len / LANES` is in
        // bounds; AVX2 availability is the caller's contract.
        let body = unsafe {
            let mut acc = _mm256_setzero_ps();
            for c in 0..chunks {
                let i = c * LANES;
                let dv = _mm256_sub_ps(_mm256_loadu_ps(pa.add(i)),
                                       _mm256_loadu_ps(pb.add(i)));
                acc = _mm256_add_ps(acc, _mm256_mul_ps(dv, dv));
            }
            hsum(acc)
        };
        let mut tail = 0.0f32;
        let done = chunks * LANES;
        for (x, y) in a[done..].iter().zip(&b[done..]) {
            let d = x - y;
            tail += d * d;
        }
        body + tail
    }

    /// AVX2 multi-query scan: broadcast each row coordinate against the
    /// packed query register; per-lane sums never cross lanes, so the
    /// lanes match the scalar form bit-for-bit by construction.
    ///
    /// # Safety
    /// The CPU must support AVX2 (the dispatchers check `simd_active()`
    /// first) and `qt` must hold at least `d * LANES` floats — the
    /// zero-padded column-major pack the dense scan always builds.
    #[target_feature(enable = "avx2")]
    pub unsafe fn scan_block_avx2(rows: &[f32], d: usize, first_id: DocId,
                                  qt: &[f32], heaps: &mut [TopK]) {
        debug_assert!(qt.len() >= d * LANES);
        debug_assert!(heaps.len() <= LANES);
        let qtp = qt.as_ptr();
        let mut scores = [0.0f32; LANES];
        for (i, row) in rows.chunks_exact(d).enumerate() {
            let ahead = (i + PREFETCH_ROWS) * d;
            if ahead + d <= rows.len() {
                super::prefetch_row(rows[ahead..].as_ptr().cast(), d * 4);
            }
            // SAFETY: `qt.len() >= d * LANES` (caller contract), so each
            // load of LANES f32s at `qtp.add(j * LANES)` with `j < d`
            // is in bounds; the store targets the LANES-sized stack
            // array `scores`.
            unsafe {
                let mut acc = _mm256_setzero_ps();
                for (j, x) in row.iter().enumerate() {
                    let xv = _mm256_broadcast_ss(x);
                    let qv = _mm256_loadu_ps(qtp.add(j * LANES));
                    acc = _mm256_add_ps(acc, _mm256_mul_ps(xv, qv));
                }
                _mm256_storeu_ps(scores.as_mut_ptr(), acc);
            }
            for (h, &s) in heaps.iter_mut().zip(&scores) {
                h.push(first_id + i as DocId, s);
            }
        }
    }

    /// Fold the 8 i32 partial sums of a 256-bit integer accumulator.
    /// Integer addition is exact, so (unlike the f32 `hsum`) the fold
    /// order is free — any association yields the same value.
    ///
    /// # Safety
    /// The CPU must support AVX2 (every caller's contract).
    #[inline(always)]
    unsafe fn hsum_i32(acc: __m256i) -> i32 {
        // SAFETY: register-only lane arithmetic plus one unaligned
        // store into `m`, a 4-element stack array of exactly the
        // 128-bit store width.
        unsafe {
            let lo = _mm256_castsi256_si128(acc);
            let hi = _mm256_extracti128_si256::<1>(acc);
            let mut m = [0i32; 4];
            _mm_storeu_si128(m.as_mut_ptr() as *mut __m128i,
                             _mm_add_epi32(lo, hi));
            (m[0] + m[2]) + (m[1] + m[3])
        }
    }

    /// AVX2 quantized dot: 32 code bytes per iteration through
    /// `maddubs` (u8 × i8 → adjacent-pair i16 sums — saturation-free
    /// because query codes are bounded by `SQ8_QMAX`, see its doc) and
    /// `madd` against ones (i16 pairs → i32), accumulated in 8 i32
    /// lanes. Every operation is exact integer arithmetic, so the value
    /// equals the scalar twin's for any input — not just bit-identical
    /// rounding, the same number.
    ///
    /// # Safety
    /// The CPU must support AVX2; the dispatchers check `simd_active()`
    /// (runtime `avx2` detection) before calling.
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot_u8i8_avx2(a: &[u8], b: &[i8]) -> i32 {
        debug_assert_eq!(a.len(), b.len());
        const STEP: usize = 32;
        let chunks = a.len() / STEP;
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        // SAFETY: each iteration loads 32 bytes at `p.add(c * STEP)`
        // with `c < chunks = len / STEP`, so every unaligned load stays
        // inside both slices; AVX2 availability is the caller's
        // contract, and `hsum_i32`'s AVX2 requirement is implied by it.
        let body = unsafe {
            let ones = _mm256_set1_epi16(1);
            let mut acc = _mm256_setzero_si256();
            for c in 0..chunks {
                let i = c * STEP;
                let va = _mm256_loadu_si256(pa.add(i) as *const __m256i);
                let vb = _mm256_loadu_si256(pb.add(i) as *const __m256i);
                let p16 = _mm256_maddubs_epi16(va, vb);
                acc = _mm256_add_epi32(acc, _mm256_madd_epi16(p16, ones));
            }
            hsum_i32(acc)
        };
        let mut tail = 0i32;
        let done = chunks * STEP;
        for (&x, &y) in a[done..].iter().zip(&b[done..]) {
            tail += x as i32 * y as i32;
        }
        body + tail
    }

    /// AVX2 quantized candidate scan — the `scan_i8` vector form, with
    /// the same stride-aware prefetch ahead as the scalar twin (`d`
    /// bytes per row, not `4 * d`).
    ///
    /// # Safety
    /// The CPU must support AVX2; the dispatchers check `simd_active()`
    /// before calling.
    #[target_feature(enable = "avx2")]
    pub unsafe fn scan_i8_avx2(rows: &[u8], d: usize, q: &[i8],
                               out: &mut [i32]) {
        debug_assert!(d > 0 && rows.len() % d == 0);
        debug_assert_eq!(q.len(), d);
        debug_assert!(out.len() >= rows.len() / d);
        for (i, row) in rows.chunks_exact(d).enumerate() {
            let ahead = (i + PREFETCH_ROWS) * d;
            if ahead + d <= rows.len() {
                super::prefetch_row(rows[ahead..].as_ptr(), d);
            }
            // SAFETY: AVX2 availability is this function's own contract.
            out[i] = unsafe { dot_u8i8_avx2(row, q) };
        }
    }
}

#[cfg(all(feature = "simd", target_arch = "aarch64"))]
mod arm {
    use super::{DocId, TopK, LANES, PREFETCH_ROWS};
    use std::arch::aarch64::*;

    /// Fold the two 128-bit accumulators (lanes 0–3, 4–7) with the
    /// shared reduction tree: `m[j] = l[j] + l[j+4]`, then
    /// `(m0+m2) + (m1+m3)` — the same association as `reduce_lanes`.
    ///
    /// # Safety
    /// The CPU must support NEON (baseline on aarch64).
    #[inline(always)]
    unsafe fn hsum(acc0: float32x4_t, acc1: float32x4_t) -> f32 {
        // SAFETY: register-only lane arithmetic and lane extraction
        // with const indices 0..4, in range for a float32x4_t.
        unsafe {
            let m = vaddq_f32(acc0, acc1);
            (vgetq_lane_f32::<0>(m) + vgetq_lane_f32::<2>(m))
                + (vgetq_lane_f32::<1>(m) + vgetq_lane_f32::<3>(m))
        }
    }

    /// NEON `dot`: separate `vmul` + `vadd` (no `fmla` — fusing would
    /// break scalar/SIMD bit-identity), `hsum`, scalar tail.
    ///
    /// # Safety
    /// The CPU must support NEON (baseline on aarch64, which is the
    /// only arch this module compiles on).
    #[target_feature(enable = "neon")]
    pub unsafe fn dot_neon(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let chunks = a.len() / LANES;
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        // SAFETY: each iteration loads 4 f32s at offsets `c * LANES`
        // and `c * LANES + 4` with `c < chunks = len / LANES`, so every
        // load stays inside both slices; NEON is baseline on aarch64.
        let body = unsafe {
            let mut acc0 = vdupq_n_f32(0.0);
            let mut acc1 = vdupq_n_f32(0.0);
            for c in 0..chunks {
                let i = c * LANES;
                acc0 = vaddq_f32(acc0, vmulq_f32(vld1q_f32(pa.add(i)),
                                                 vld1q_f32(pb.add(i))));
                acc1 = vaddq_f32(acc1,
                                 vmulq_f32(vld1q_f32(pa.add(i + 4)),
                                           vld1q_f32(pb.add(i + 4))));
            }
            hsum(acc0, acc1)
        };
        let mut tail = 0.0f32;
        let done = chunks * LANES;
        for (x, y) in a[done..].iter().zip(&b[done..]) {
            tail += x * y;
        }
        body + tail
    }

    /// NEON `l2_sq` (same structure; no fusing).
    ///
    /// # Safety
    /// The CPU must support NEON (baseline on aarch64, which is the
    /// only arch this module compiles on).
    #[target_feature(enable = "neon")]
    pub unsafe fn l2_sq_neon(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let chunks = a.len() / LANES;
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        // SAFETY: same bounds argument as `dot_neon` — every 4-wide
        // load at `c * LANES` / `c * LANES + 4` with `c < len / LANES`
        // is in bounds; NEON is baseline on aarch64.
        let body = unsafe {
            let mut acc0 = vdupq_n_f32(0.0);
            let mut acc1 = vdupq_n_f32(0.0);
            for c in 0..chunks {
                let i = c * LANES;
                let d0 =
                    vsubq_f32(vld1q_f32(pa.add(i)), vld1q_f32(pb.add(i)));
                let d1 = vsubq_f32(vld1q_f32(pa.add(i + 4)),
                                   vld1q_f32(pb.add(i + 4)));
                acc0 = vaddq_f32(acc0, vmulq_f32(d0, d0));
                acc1 = vaddq_f32(acc1, vmulq_f32(d1, d1));
            }
            hsum(acc0, acc1)
        };
        let mut tail = 0.0f32;
        let done = chunks * LANES;
        for (x, y) in a[done..].iter().zip(&b[done..]) {
            let d = x - y;
            tail += d * d;
        }
        body + tail
    }

    /// NEON multi-query scan: broadcast each row coordinate against the
    /// two packed query registers; per-lane sums never cross lanes.
    ///
    /// # Safety
    /// The CPU must support NEON (baseline on aarch64) and `qt` must
    /// hold at least `d * LANES` floats — the zero-padded column-major
    /// pack the dense scan always builds.
    #[target_feature(enable = "neon")]
    pub unsafe fn scan_block_neon(rows: &[f32], d: usize, first_id: DocId,
                                  qt: &[f32], heaps: &mut [TopK]) {
        debug_assert!(qt.len() >= d * LANES);
        debug_assert!(heaps.len() <= LANES);
        let qtp = qt.as_ptr();
        let mut scores = [0.0f32; LANES];
        for (i, row) in rows.chunks_exact(d).enumerate() {
            let ahead = (i + PREFETCH_ROWS) * d;
            if ahead + d <= rows.len() {
                super::prefetch_row(rows[ahead..].as_ptr().cast(), d * 4);
            }
            // SAFETY: `qt.len() >= d * LANES` (caller contract), so the
            // 4-wide loads at `j * LANES` and `j * LANES + 4` with
            // `j < d` are in bounds; the stores split the LANES-sized
            // stack array `scores` into its two register halves.
            unsafe {
                let mut acc0 = vdupq_n_f32(0.0);
                let mut acc1 = vdupq_n_f32(0.0);
                for (j, &x) in row.iter().enumerate() {
                    let xv = vdupq_n_f32(x);
                    acc0 = vaddq_f32(
                        acc0,
                        vmulq_f32(xv, vld1q_f32(qtp.add(j * LANES))));
                    acc1 = vaddq_f32(
                        acc1,
                        vmulq_f32(xv, vld1q_f32(qtp.add(j * LANES + 4))));
                }
                vst1q_f32(scores.as_mut_ptr(), acc0);
                vst1q_f32(scores.as_mut_ptr().add(4), acc1);
            }
            for (h, &s) in heaps.iter_mut().zip(&scores) {
                h.push(first_id + i as DocId, s);
            }
        }
    }

    /// NEON quantized dot: 16 code bytes per iteration — widen the u8
    /// row codes to i16 (values ≤ 255 fit losslessly) and the i8 query
    /// codes to i16, then four widening multiply-accumulates
    /// (`vmlal_s16`) into two i32x4 accumulators. Every operation is
    /// exact integer arithmetic, so the value equals the scalar twin's
    /// for any input.
    ///
    /// # Safety
    /// The CPU must support NEON (baseline on aarch64, which is the
    /// only arch this module compiles on).
    #[target_feature(enable = "neon")]
    pub unsafe fn dot_u8i8_neon(a: &[u8], b: &[i8]) -> i32 {
        debug_assert_eq!(a.len(), b.len());
        const STEP: usize = 16;
        let chunks = a.len() / STEP;
        let (pa, pb) = (a.as_ptr(), b.as_ptr());
        // SAFETY: each iteration loads 16 bytes at `p.add(c * STEP)`
        // with `c < chunks = len / STEP`, so every load stays inside
        // both slices; the rest is register-only lane arithmetic. NEON
        // is baseline on aarch64.
        let body = unsafe {
            let mut acc0 = vdupq_n_s32(0);
            let mut acc1 = vdupq_n_s32(0);
            for c in 0..chunks {
                let i = c * STEP;
                let va = vld1q_u8(pa.add(i));
                let vb = vld1q_s8(pb.add(i));
                let a_lo = vreinterpretq_s16_u16(vmovl_u8(vget_low_u8(va)));
                let a_hi = vreinterpretq_s16_u16(vmovl_u8(vget_high_u8(va)));
                let b_lo = vmovl_s8(vget_low_s8(vb));
                let b_hi = vmovl_s8(vget_high_s8(vb));
                acc0 = vmlal_s16(acc0, vget_low_s16(a_lo),
                                 vget_low_s16(b_lo));
                acc1 = vmlal_s16(acc1, vget_high_s16(a_lo),
                                 vget_high_s16(b_lo));
                acc0 = vmlal_s16(acc0, vget_low_s16(a_hi),
                                 vget_low_s16(b_hi));
                acc1 = vmlal_s16(acc1, vget_high_s16(a_hi),
                                 vget_high_s16(b_hi));
            }
            vaddvq_s32(vaddq_s32(acc0, acc1))
        };
        let mut tail = 0i32;
        let done = chunks * STEP;
        for (&x, &y) in a[done..].iter().zip(&b[done..]) {
            tail += x as i32 * y as i32;
        }
        body + tail
    }

    /// NEON quantized candidate scan — the `scan_i8` vector form (the
    /// prefetch call is a no-op on aarch64 but keeps the two forms
    /// structurally identical).
    ///
    /// # Safety
    /// The CPU must support NEON (baseline on aarch64).
    #[target_feature(enable = "neon")]
    pub unsafe fn scan_i8_neon(rows: &[u8], d: usize, q: &[i8],
                               out: &mut [i32]) {
        debug_assert!(d > 0 && rows.len() % d == 0);
        debug_assert_eq!(q.len(), d);
        debug_assert!(out.len() >= rows.len() / d);
        for (i, row) in rows.chunks_exact(d).enumerate() {
            let ahead = (i + PREFETCH_ROWS) * d;
            if ahead + d <= rows.len() {
                super::prefetch_row(rows[ahead..].as_ptr(), d);
            }
            // SAFETY: NEON availability is this function's own contract.
            out[i] = unsafe { dot_u8i8_neon(row, q) };
        }
    }
}

/// Inner product of two equal-length vectors — the similarity metric of
/// every dense path (flat scan scoring, HNSW walk, KNN-LM cache). Picks
/// the vector form iff [`simd_active`]; the result is bit-identical
/// either way (see the module docs).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if simd_active() {
        // SAFETY: simd_active() verified AVX2 support at runtime.
        return unsafe { x86::dot_avx2(a, b) };
    }
    #[cfg(all(feature = "simd", target_arch = "aarch64"))]
    if simd_active() {
        // SAFETY: NEON is baseline on aarch64.
        return unsafe { arm::dot_neon(a, b) };
    }
    dot_scalar(a, b)
}

/// Squared L2 distance of two equal-length vectors. Same dispatch and
/// bit-identity contract as [`dot`].
#[inline]
pub fn l2_sq(a: &[f32], b: &[f32]) -> f32 {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if simd_active() {
        // SAFETY: simd_active() verified AVX2 support at runtime.
        return unsafe { x86::l2_sq_avx2(a, b) };
    }
    #[cfg(all(feature = "simd", target_arch = "aarch64"))]
    if simd_active() {
        // SAFETY: NEON is baseline on aarch64.
        return unsafe { arm::l2_sq_neon(a, b) };
    }
    l2_sq_scalar(a, b)
}

/// Multi-query scan block — see [`scan_block_scalar`] for the exact
/// semantics (`rows` is `n × d` row-major, `qt` the zero-padded
/// column-major query pack, one heap per live query lane). Same dispatch
/// and bit-identity contract as [`dot`].
#[inline]
pub fn scan_block(rows: &[f32], d: usize, first_id: DocId, qt: &[f32],
                  heaps: &mut [TopK]) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if simd_active() {
        // SAFETY: simd_active() verified AVX2 support at runtime.
        return unsafe { x86::scan_block_avx2(rows, d, first_id, qt, heaps) };
    }
    #[cfg(all(feature = "simd", target_arch = "aarch64"))]
    if simd_active() {
        // SAFETY: NEON is baseline on aarch64.
        return unsafe { arm::scan_block_neon(rows, d, first_id, qt, heaps) };
    }
    scan_block_scalar(rows, d, first_id, qt, heaps)
}

/// Best-effort **stride-aware** prefetch of one packed row: hints every
/// cache line covering `row_bytes` bytes starting at `ptr`. The caller
/// passes the element-width-correct byte length — `4 * dim` for f32 rows,
/// `dim` for packed-i8 code rows — which is what makes the hint correct
/// for both layouts (the old `prefetch_f32` helper covered a single line
/// and implicitly assumed the f32 row stride, so for wide rows the scan
/// still missed on the row's tail lines, and for 1-byte-per-coordinate
/// rows there was no correct way to call it at all). Used by the HNSW
/// walk and by both the f32 and packed-i8 block scans. Purely a hint: it
/// never faults and never changes results; a no-op off x86_64 (aarch64
/// `prfm` has no stable intrinsic).
#[inline(always)]
pub fn prefetch_row(ptr: *const u8, row_bytes: usize) {
    #[cfg(target_arch = "x86_64")]
    // SAFETY: prefetch is a hint and cannot fault, even on dangling
    // addresses; SSE is baseline on x86_64. The 64-byte step matches the
    // x86 cache-line size, and the line addresses are formed with
    // `wrapping_add` so the helper is sound for *any* `ptr`/`row_bytes`
    // pair — no in-bounds obligation on the caller.
    unsafe {
        use std::arch::x86_64::{_mm_prefetch, _MM_HINT_T0};
        let mut off = 0usize;
        loop {
            _mm_prefetch(ptr.wrapping_add(off) as *const i8, _MM_HINT_T0);
            off += 64;
            if off >= row_bytes {
                break;
            }
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = (ptr, row_bytes);
}

/// Exact re-score of one row in [`scan_block`]'s **per-lane operation
/// order**: a single f32 accumulator walked in coordinate order. This is
/// deliberately NOT [`dot`] (whose 8-partial-sum tree rounds
/// differently): the SQ8 two-phase scan re-scores surviving candidate
/// rows with this so its final scores are bit-identical to what the
/// full-precision block scan would have produced for the same (row,
/// query) pair — `scan_block`'s lanes accumulate exactly this sequence,
/// in scalar and SIMD form alike (DESIGN.md ADR-010).
#[inline]
pub fn rescore_dot(row: &[f32], q: &[f32]) -> f32 {
    debug_assert_eq!(row.len(), q.len());
    let mut s = 0.0f32;
    for (&x, &y) in row.iter().zip(q) {
        s += x * y;
    }
    s
}

/// Quantized dot, scalar twin: `Σ a[j] · b[j]` with `a` unsigned row
/// codes and `b` signed query codes, accumulated in i32. Exact — every
/// product and sum is an integer, so this *is* the semantics of
/// [`dot_u8i8`] on any host, bit for bit. The i32 accumulator cannot
/// overflow for any dimension the retrieval stack uses: `|a·b| ≤ 255 ·
/// SQ8_QMAX = 16320` per coordinate bounds the sum for `d` up to ~131k.
pub fn dot_u8i8_scalar(a: &[u8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0i32;
    for (&x, &y) in a.iter().zip(b) {
        acc += x as i32 * y as i32;
    }
    acc
}

/// Quantized candidate scan, scalar twin: integer dot of one signed
/// query-code vector against `n = rows.len() / d` packed u8 code rows,
/// writing `out[i] = Σ_j rows[i·d + j] · q[j]` (exact i32). The packed
/// rows stream at 1 byte per coordinate — 4x the row density of the f32
/// scan — which is the entire point at memory-bandwidth-bound corpus
/// sizes (DESIGN.md ADR-010).
pub fn scan_i8_scalar(rows: &[u8], d: usize, q: &[i8], out: &mut [i32]) {
    debug_assert!(d > 0 && rows.len() % d == 0);
    debug_assert_eq!(q.len(), d);
    debug_assert!(out.len() >= rows.len() / d);
    for (i, row) in rows.chunks_exact(d).enumerate() {
        let ahead = (i + PREFETCH_ROWS) * d;
        if ahead + d <= rows.len() {
            prefetch_row(rows[ahead..].as_ptr(), d);
        }
        out[i] = dot_u8i8_scalar(row, q);
    }
}

/// Quantized dot — integer inner product of unsigned row codes against
/// signed query codes. Same dispatch policy as [`dot`]; the guarantee is
/// even stronger here — integer arithmetic is exact, so scalar and SIMD
/// forms compute the same *value* by construction, not merely the same
/// rounding.
#[inline]
pub fn dot_u8i8(a: &[u8], b: &[i8]) -> i32 {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if simd_active() {
        // SAFETY: simd_active() verified AVX2 support at runtime.
        return unsafe { x86::dot_u8i8_avx2(a, b) };
    }
    #[cfg(all(feature = "simd", target_arch = "aarch64"))]
    if simd_active() {
        // SAFETY: NEON is baseline on aarch64.
        return unsafe { arm::dot_u8i8_neon(a, b) };
    }
    dot_u8i8_scalar(a, b)
}

/// Quantized candidate scan — see [`scan_i8_scalar`] for the exact
/// semantics. Same dispatch policy as [`scan_block`]; exact integer
/// output either way.
#[inline]
pub fn scan_i8(rows: &[u8], d: usize, q: &[i8], out: &mut [i32]) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if simd_active() {
        // SAFETY: simd_active() verified AVX2 support at runtime.
        return unsafe { x86::scan_i8_avx2(rows, d, q, out) };
    }
    #[cfg(all(feature = "simd", target_arch = "aarch64"))]
    if simd_active() {
        // SAFETY: NEON is baseline on aarch64.
        return unsafe { arm::scan_i8_neon(rows, d, q, out) };
    }
    scan_i8_scalar(rows, d, q, out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// The satellite dims: tails (7), exact chunk (8), mid (64), tail
    /// again (65), two chunks' worth of the serving dim (128).
    const DIMS: [usize; 5] = [7, 8, 64, 65, 128];

    fn pair(d: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let a = (0..d).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
        let b = (0..d).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
        (a, b)
    }

    #[test]
    fn dot_dispatch_matches_scalar_bitwise() {
        for &d in &DIMS {
            let (a, b) = pair(d, 100 + d as u64);
            assert_eq!(dot(&a, &b).to_bits(), dot_scalar(&a, &b).to_bits(),
                       "d={d} simd_active={}", simd_active());
        }
    }

    #[test]
    fn l2_dispatch_matches_scalar_bitwise() {
        for &d in &DIMS {
            let (a, b) = pair(d, 200 + d as u64);
            assert_eq!(l2_sq(&a, &b).to_bits(),
                       l2_sq_scalar(&a, &b).to_bits(),
                       "d={d} simd_active={}", simd_active());
        }
    }

    #[test]
    fn dot_scalar_matches_naive_value() {
        for &d in &DIMS {
            let (a, b) = pair(d, 300 + d as u64);
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot_scalar(&a, &b) - naive).abs() < 1e-4, "d={d}");
        }
    }

    #[test]
    fn l2_scalar_matches_naive_value() {
        for &d in &DIMS {
            let (a, b) = pair(d, 400 + d as u64);
            let naive: f32 =
                a.iter().zip(&b).map(|(x, y)| (x - y) * (x - y)).sum();
            assert!((l2_sq_scalar(&a, &b) - naive).abs() < 1e-4, "d={d}");
        }
    }

    #[test]
    fn scan_block_dispatch_matches_scalar_bitwise() {
        for &d in &DIMS {
            let mut rng = Rng::new(500 + d as u64);
            let n_rows = 33;
            let rows: Vec<f32> =
                (0..n_rows * d).map(|_| rng.next_f32() - 0.5).collect();
            // b = 5 < LANES exercises the zero-padded lanes too.
            for b in [5usize, LANES] {
                let mut qt = vec![0.0f32; d * LANES];
                for bi in 0..b {
                    for j in 0..d {
                        qt[j * LANES + bi] = rng.next_f32() - 0.5;
                    }
                }
                let mut h1: Vec<TopK> =
                    (0..b).map(|_| TopK::new(10)).collect();
                let mut h2: Vec<TopK> =
                    (0..b).map(|_| TopK::new(10)).collect();
                scan_block(&rows, d, 7, &qt, &mut h1);
                scan_block_scalar(&rows, d, 7, &qt, &mut h2);
                for (a, e) in h1.into_iter().zip(h2) {
                    let (a, e) = (a.into_sorted(), e.into_sorted());
                    assert_eq!(a.len(), e.len());
                    for (x, y) in a.iter().zip(&e) {
                        assert_eq!(x.id, y.id, "d={d} b={b}");
                        assert_eq!(x.score.to_bits(), y.score.to_bits(),
                                   "d={d} b={b}");
                    }
                }
            }
        }
    }

    #[test]
    fn prefetch_is_inert() {
        let v = [1.0f32; 40];
        // A multi-line row (160 bytes = 3 cache lines at any alignment).
        prefetch_row(v.as_ptr().cast(), std::mem::size_of_val(&v));
        // A 1-byte row, and an address we never dereference:
        prefetch_row(v.as_ptr().cast(), 1);
        prefetch_row(std::ptr::null(), 256);
        assert_eq!(dot(&v, &v), 40.0);
    }

    /// Random SQ8 operands: row codes over the full `0..=255` range,
    /// query codes over `[-SQ8_QMAX, SQ8_QMAX]` — the exact domains the
    /// codec produces.
    fn sq8_pair(d: usize, seed: u64) -> (Vec<u8>, Vec<i8>) {
        let mut rng = Rng::new(seed);
        let a = (0..d).map(|_| (rng.next_u64() & 0xff) as u8).collect();
        let span = 2 * SQ8_QMAX as u64 + 1;
        let b = (0..d)
            .map(|_| (rng.next_u64() % span) as i64 - SQ8_QMAX as i64)
            .map(|v| v as i8)
            .collect();
        (a, b)
    }

    #[test]
    fn sq8_dot_dispatch_matches_scalar() {
        // DIMS plus tails around the 32-byte AVX2 / 16-byte NEON steps.
        for &d in &[7usize, 8, 16, 31, 32, 33, 64, 65, 100, 128] {
            let (a, b) = sq8_pair(d, 600 + d as u64);
            assert_eq!(dot_u8i8(&a, &b), dot_u8i8_scalar(&a, &b),
                       "d={d} simd_active={}", simd_active());
        }
    }

    #[test]
    fn sq8_dot_scalar_matches_naive_i64() {
        for &d in &DIMS {
            let (a, b) = sq8_pair(d, 700 + d as u64);
            let naive: i64 = a.iter().zip(&b)
                .map(|(&x, &y)| x as i64 * y as i64)
                .sum();
            assert_eq!(dot_u8i8_scalar(&a, &b) as i64, naive, "d={d}");
        }
    }

    #[test]
    fn sq8_scan_dispatch_matches_scalar() {
        for &d in &[7usize, 32, 33, 64] {
            let n = 21;
            let mut rng = Rng::new(800 + d as u64);
            let rows: Vec<u8> =
                (0..n * d).map(|_| (rng.next_u64() & 0xff) as u8).collect();
            let (_, q) = sq8_pair(d, 900 + d as u64);
            let mut o1 = vec![0i32; n];
            let mut o2 = vec![0i32; n];
            scan_i8(&rows, d, &q, &mut o1);
            scan_i8_scalar(&rows, d, &q, &mut o2);
            assert_eq!(o1, o2, "d={d} simd_active={}", simd_active());
            // And each entry is the per-row dot of the same codes.
            for (i, row) in rows.chunks_exact(d).enumerate() {
                assert_eq!(o1[i], dot_u8i8_scalar(row, &q), "d={d} i={i}");
            }
        }
    }

    /// The exactness keystone: `rescore_dot` must reproduce the
    /// *per-lane* bits of `scan_block` (single accumulator, coordinate
    /// order) — this is what lets the two-phase SQ8 scan re-score
    /// survivors and land on scores bit-identical to the full-precision
    /// scan's (DESIGN.md ADR-010).
    #[test]
    fn sq8_rescore_matches_scan_block_lane_bits() {
        for &d in &DIMS {
            let mut rng = Rng::new(1000 + d as u64);
            let n = 17;
            let rows: Vec<f32> =
                (0..n * d).map(|_| rng.next_f32() - 0.5).collect();
            let q: Vec<f32> =
                (0..d).map(|_| rng.next_f32() - 0.5).collect();
            let mut qt = vec![0.0f32; d * LANES];
            for j in 0..d {
                qt[j * LANES] = q[j];
            }
            // k = n keeps every row, so the heap holds every lane score.
            let mut heaps = vec![TopK::new(n)];
            scan_block(&rows, d, 0, &qt, &mut heaps);
            let got = heaps.pop().map(|h| h.into_sorted()).unwrap_or_default();
            assert_eq!(got.len(), n);
            for s in got {
                let row = &rows[s.id as usize * d..(s.id as usize + 1) * d];
                assert_eq!(s.score.to_bits(),
                           rescore_dot(row, &q).to_bits(),
                           "d={d} id={}", s.id);
            }
        }
    }
}
