//! Live knowledge-base updates with epoch snapshots (DESIGN.md ADR-006).
//!
//! Everything below PR 5 served a frozen corpus: indices were built once
//! and only ever read. This module adds the ingestion path — the
//! "low-cost adaptation to the latest data" the paper claims for
//! iterative RaLM — without giving up a single bit of the repo's
//! output-equivalence guarantees:
//!
//! * [`MutableRetriever`] is the writer-side contract: a mutable index
//!   ([`MutableDense`] brute-force append, [`MutableHnsw`] incremental
//!   graph insertion, [`MutableBm25`] posting-list append) that can emit
//!   an immutable [`Retriever`] snapshot at any point. Every
//!   implementation guarantees **append ≡ rebuild**: the snapshot after
//!   appending docs is bit-identical to an index built from scratch over
//!   the extended corpus (pinned by `append_matches_fresh_build` tests in
//!   each backend).
//! * [`EpochKb`] is the reader-side snapshot layer: an atomically
//!   published `Arc<EpochSnapshot>` per epoch. Readers grab a snapshot
//!   once (one short `RwLock` read) and then run entirely lock-free
//!   against immutable data; the writer batches pending documents and
//!   publishes a complete new epoch — retriever *and* corpus together, so
//!   a reader can never observe a torn (index from epoch E, corpus from
//!   E′) view.
//! * [`KbWriter`] owns the mutable master state and drives the
//!   ingest→publish cycle; [`LiveKb`] bundles writer + snapshot layer for
//!   the serving stack.
//!
//! **Why stale speculation stays safe**: a serving task pins the snapshot
//! it was admitted under and does *all* its work — cache scoring,
//! batched verification, document reads — against that one epoch. The
//! speculation cache may hold documents retrieved rounds ago, but
//! verification re-scores against the pinned epoch's exact metric, so a
//! stale cached doc is at worst a mis-speculation (rolled back like any
//! other), never a correctness leak. See ADR-006 for the full argument,
//! including why BM25's N-dependent idf makes per-epoch pinning mandatory
//! rather than merely hygienic.

use super::dense::{DenseExact, EmbeddingMatrix};
use super::hnsw::Hnsw;
use super::segment::SegmentedKb;
use super::sparse::Bm25;
use super::{Retriever, ShardedRetriever};
use crate::config::{Config, DenseCodec, RetrieverKind};
use crate::datagen::corpus::{Corpus, Document};
use std::sync::{Arc, Mutex, RwLock};

/// Writer-side contract for a live-updatable index: append freshly
/// embedded documents, then emit an immutable snapshot that is
/// **bit-identical to a from-scratch build** over the same documents.
///
/// Implementations never mutate published state — [`snapshot`]
/// materializes an independent `Arc` the readers own outright, which is
/// what lets a writer keep appending while arbitrarily many readers serve
/// from earlier epochs.
///
/// ```
/// use ralmspec::retriever::epoch::{MutableDense, MutableRetriever};
/// use ralmspec::retriever::{Retriever, SpecQuery};
/// use ralmspec::datagen::Document;
///
/// // A 2-doc, 4-dim knowledge base...
/// let mut kb = MutableDense::new(4, vec![1.0, 0.0, 0.0, 0.0,
///                                        0.0, 1.0, 0.0, 0.0]);
/// let epoch0 = kb.snapshot(1);
/// assert_eq!(epoch0.len(), 2);
///
/// // ...grows by one appended doc; the old snapshot is untouched.
/// let doc = Document { id: 2, topic: 0, tokens: vec![7, 8] };
/// kb.append(&[doc], &[vec![0.0, 0.0, 1.0, 0.0]]).unwrap();
/// let epoch1 = kb.snapshot(1);
/// assert_eq!(epoch0.len(), 2);
/// assert_eq!(epoch1.len(), 3);
///
/// // The new doc is retrievable in the new epoch only.
/// let q = SpecQuery::dense_only(vec![0.0, 0.0, 1.0, 0.0]);
/// assert_eq!(epoch1.retrieve(&q).unwrap().id, 2);
/// assert_ne!(epoch0.retrieve(&q).unwrap().id, 2);
/// ```
///
/// [`snapshot`]: MutableRetriever::snapshot
pub trait MutableRetriever: Send {
    /// Append documents (contiguous ids continuing the current length)
    /// with their precomputed embedding rows. Sparse backends ignore the
    /// embeddings; dense backends ignore the token payload.
    fn append(&mut self, docs: &[Document], embeddings: &[Vec<f32>])
              -> anyhow::Result<()>;

    /// An immutable snapshot of the current state, optionally wrapped in
    /// a scatter-gather [`ShardedRetriever`] (`shards > 1`). The snapshot
    /// shares no mutable state with the writer.
    fn snapshot(&self, shards: usize) -> Arc<dyn Retriever>;

    /// Documents currently indexed (pending-but-unpublished docs are not
    /// counted — they live in the [`KbWriter`] until the next publish).
    fn len(&self) -> usize;

    /// Merge internal tiers (segments + memtable) back into one. Returns
    /// `Ok(true)` if state changed and a fresh snapshot should be
    /// published. In-RAM backends are always fully merged: the default
    /// is a no-op.
    fn compact(&mut self) -> anyhow::Result<bool> {
        Ok(false)
    }

    /// How many read tiers the next snapshot will scan. In-RAM backends
    /// report 1; the segmented backend reports segments plus a non-empty
    /// memtable (see `retriever::segment`).
    fn tier_count(&self) -> usize {
        1
    }
}

/// Live exact-dense index ("EDR"): appending is a row append onto the
/// embedding matrix; a snapshot clones the matrix into a fresh
/// [`DenseExact`]. Append ≡ rebuild holds trivially (same rows, same
/// scan).
pub struct MutableDense {
    dim: usize,
    data: Vec<f32>,
    codec: DenseCodec,
    oversample: f64,
}

impl MutableDense {
    pub fn new(dim: usize, data: Vec<f32>) -> Self {
        Self::with_codec(dim, data, DenseCodec::Full,
                         super::dense::DEFAULT_SQ8_OVERSAMPLE)
    }

    /// `dense.codec = sq8` snapshots scan quantized codes and re-score
    /// survivors from f32 rows — bit-identical results (ADR-010). Each
    /// publish re-encodes the matrix; the snapshot is already O(corpus)
    /// (the matrix clone), so the codec doesn't change its complexity
    /// class — the memory-bounded path is the segment store.
    pub fn with_codec(dim: usize, data: Vec<f32>, codec: DenseCodec,
                      oversample: f64) -> Self {
        assert!(dim > 0 && data.len() % dim == 0,
                "embedding data shape mismatch");
        Self { dim, data, codec, oversample }
    }
}

/// Validate a whole append batch (row shapes + id contiguity) before any
/// mutation, so `MutableRetriever::append` is all-or-nothing: a rejected
/// batch leaves the index byte-identical, which is what keeps the writer
/// (whose corpus and backend must stay aligned) usable after an error.
fn validate_batch(docs: &[Document], embeddings: &[Vec<f32>], dim: usize,
                  len: usize) -> anyhow::Result<()> {
    anyhow::ensure!(docs.len() == embeddings.len(),
                    "{} docs but {} embedding rows",
                    docs.len(), embeddings.len());
    for (i, (d, e)) in docs.iter().zip(embeddings).enumerate() {
        anyhow::ensure!(e.len() == dim,
                        "doc {}: embedding dim {} != {}",
                        d.id, e.len(), dim);
        anyhow::ensure!(d.id as usize == len + i,
                        "doc {}: ids must be contiguous", d.id);
    }
    Ok(())
}

impl MutableRetriever for MutableDense {
    fn append(&mut self, docs: &[Document], embeddings: &[Vec<f32>])
              -> anyhow::Result<()> {
        validate_batch(docs, embeddings, self.dim,
                       self.data.len() / self.dim)?;
        for e in embeddings {
            self.data.extend_from_slice(e);
        }
        Ok(())
    }

    fn snapshot(&self, shards: usize) -> Arc<dyn Retriever> {
        let emb = Arc::new(EmbeddingMatrix::new(self.dim,
                                                self.data.clone()));
        let base = Arc::new(match self.codec {
            DenseCodec::Sq8 =>
                DenseExact::with_sq8(emb, self.oversample),
            DenseCodec::Full => DenseExact::new(emb),
        });
        if shards > 1 {
            Arc::new(ShardedRetriever::new(base, shards))
        } else {
            base
        }
    }

    fn len(&self) -> usize {
        self.data.len() / self.dim
    }
}

/// Live HNSW index ("ADR"): appending swaps in the extended embedding
/// matrix and inserts the new nodes incrementally ([`Hnsw::append`],
/// reusing the shared `SearchScratch`); a snapshot clones the graph and
/// seals the clone into the flat CSR layout (DESIGN.md ADR-007) — the
/// master stays in the nested mutable-tail form between publishes.
/// Append ≡ rebuild because node levels are per-id seeded and the
/// from-scratch build is itself sequential insertion.
pub struct MutableHnsw {
    dim: usize,
    data: Vec<f32>,
    index: Hnsw,
}

impl MutableHnsw {
    pub fn new(dim: usize, data: Vec<f32>, m: usize, ef_construction: usize,
               ef_search: usize, seed: u64) -> Self {
        let emb = Arc::new(EmbeddingMatrix::new(dim, data.clone()));
        let mut index = Hnsw::build(emb, m, ef_construction, ef_search, seed);
        // The writer-side master stays in the nested (mutable-tail) form so
        // every append pays only the incremental insertion cost; snapshots
        // compact to CSR on publish (see `snapshot`).
        index.thaw();
        Self { dim, data, index }
    }
}

impl MutableRetriever for MutableHnsw {
    fn append(&mut self, docs: &[Document], embeddings: &[Vec<f32>])
              -> anyhow::Result<()> {
        validate_batch(docs, embeddings, self.dim,
                       self.data.len() / self.dim)?;
        for e in embeddings {
            self.data.extend_from_slice(e);
        }
        let emb = Arc::new(EmbeddingMatrix::new(self.dim,
                                                self.data.clone()));
        self.index.append(emb);
        Ok(())
    }

    fn snapshot(&self, shards: usize) -> Arc<dyn Retriever> {
        // Publish-time compaction: the clone is sealed into the CSR form,
        // so serving always walks the flat layout while the master keeps
        // its mutable nested lists. Sealing only re-lays-out the neighbor
        // lists — snapshot searches stay bit-identical to the master's
        // (pinned by hnsw::tests::csr_matches_nested_search).
        let mut graph = self.index.clone();
        graph.seal();
        let base = Arc::new(graph);
        if shards > 1 {
            Arc::new(ShardedRetriever::new(base, shards))
        } else {
            base
        }
    }

    fn len(&self) -> usize {
        self.data.len() / self.dim
    }
}

/// Live BM25 index ("SR"): appending extends the posting lists and
/// recomputes the global statistics ([`Bm25::append_docs`]); a snapshot
/// clones the index. Note SR is the backend where epoch pinning is
/// *mandatory* for bit-identity: idf and avgdl shift with every publish,
/// so even old documents score differently across epochs.
pub struct MutableBm25 {
    index: Bm25,
}

impl MutableBm25 {
    pub fn new(index: Bm25) -> Self {
        Self { index }
    }
}

impl MutableRetriever for MutableBm25 {
    fn append(&mut self, docs: &[Document], _embeddings: &[Vec<f32>])
              -> anyhow::Result<()> {
        // Validate the whole batch before mutating (same all-or-nothing
        // contract as the dense backends): `Bm25::append_docs` asserts
        // these invariants per doc mid-loop, and a panic there would
        // leave the index partially extended — and poison the writer
        // mutex of any `LiveKb` above us.
        let vocab = self.index.postings.len();
        let len = Retriever::len(&self.index);
        for (i, d) in docs.iter().enumerate() {
            anyhow::ensure!(d.id as usize == len + i,
                            "doc {}: ids must be contiguous", d.id);
            anyhow::ensure!(
                d.tokens.iter().all(|&t| (t as usize) < vocab),
                "doc {}: token ids outside the index vocab ({vocab})",
                d.id);
        }
        self.index.append_docs(docs);
        Ok(())
    }

    fn snapshot(&self, shards: usize) -> Arc<dyn Retriever> {
        let base = Arc::new(self.index.clone());
        if shards > 1 {
            Arc::new(ShardedRetriever::new(base, shards))
        } else {
            base
        }
    }

    fn len(&self) -> usize {
        Retriever::len(&self.index)
    }
}

/// One published epoch: a consistent (retriever, corpus) pair. Readers
/// hold the `Arc` for as long as they need the view; the writer never
/// touches a published snapshot again.
pub struct EpochSnapshot {
    /// Monotonic epoch id (0 = the initial build).
    pub epoch: u64,
    /// The epoch's immutable index view (possibly shard-wrapped).
    pub kb: Arc<dyn Retriever>,
    /// The epoch's corpus view — documents `0..kb.len()`. Published
    /// together with `kb` so no reader can pair an index from one epoch
    /// with document text from another.
    pub corpus: Arc<Corpus>,
}

/// The atomically swappable current-epoch cell. `snapshot()` is the only
/// thing on a reader's hot path and costs one `RwLock` read + `Arc`
/// clone; all retrieval then runs against immutable data. Publishing
/// takes the write lock for the duration of a pointer swap.
///
/// Memory ordering: the writer fully constructs the new snapshot (index
/// append, corpus clone, `Arc` allocation) *before* taking the write
/// lock; the lock's release/acquire pair gives every subsequent
/// `snapshot()` caller a happens-before edge covering all of that
/// construction. There is no seqlock-style tearing to defend against —
/// readers clone the `Arc` and never re-read the cell.
pub struct EpochKb {
    current: RwLock<Arc<EpochSnapshot>>,
}

impl EpochKb {
    pub fn new(initial: EpochSnapshot) -> Self {
        Self { current: RwLock::new(Arc::new(initial)) }
    }

    /// The current epoch's snapshot. Callers pin by holding the `Arc`.
    pub fn snapshot(&self) -> Arc<EpochSnapshot> {
        // detlint: allow(hot-panic, reason = "RwLock poisoning means a writer panicked mid-publish; serving a torn epoch would be worse")
        self.current.read().unwrap().clone()
    }

    /// Current epoch id (shorthand for `snapshot().epoch`).
    pub fn epoch(&self) -> u64 {
        // detlint: allow(hot-panic, reason = "RwLock poisoning means a writer panicked mid-publish; serving a torn epoch would be worse")
        self.current.read().unwrap().epoch
    }

    /// Atomically publish the next epoch. Panics if `next` does not
    /// continue the epoch sequence — a torn or reordered publish is a
    /// writer bug, never something readers should be able to observe.
    fn publish(&self, next: EpochSnapshot) {
        // detlint: allow(hot-panic, reason = "RwLock poisoning means a writer panicked mid-publish; serving a torn epoch would be worse")
        let mut cur = self.current.write().unwrap();
        assert_eq!(next.epoch, cur.epoch + 1,
                   "epochs must be published in order");
        assert!(next.kb.len() >= cur.kb.len(),
                "the knowledge base is append-only");
        *cur = Arc::new(next);
    }
}

/// Ingest counters (reported by the serve drivers and bench-gate cell).
#[derive(Debug, Clone, Copy, Default)]
pub struct IngestStats {
    /// Documents accepted by [`KbWriter::ingest`].
    pub docs_ingested: u64,
    /// Epochs published (batched: one per `batch` docs, plus flushes).
    pub epochs_published: u64,
}

/// The single writer of a live knowledge base: owns the mutable master
/// index and corpus, batches pending documents, and publishes complete
/// epochs through the shared [`EpochKb`].
///
/// Callers embed documents themselves (`datagen::embed_doc`) — the
/// encoder stays on the caller's thread, so the non-`Send` PJRT encoder
/// constraint never leaks into the writer, and a pre-embedded ingest
/// stream can be replayed from any thread.
pub struct KbWriter {
    epochs: Arc<EpochKb>,
    backend: Box<dyn MutableRetriever>,
    corpus: Corpus,
    shards: usize,
    batch: usize,
    /// Ingest quota (DESIGN.md ADR-011): max documents this writer will
    /// ever accept; 0 = unlimited. In multi-tenant serving each tenant
    /// owns its own writer, so the quota bounds how far one tenant's
    /// ingest storm can grow its — and only its — knowledge base.
    quota_docs: usize,
    pending: Vec<(Document, Vec<f32>)>,
    stats: IngestStats,
}

impl KbWriter {
    /// Publish policy: a new epoch whenever `batch` documents are
    /// pending (plus explicit [`flush`](Self::flush) calls).
    pub fn new(epochs: Arc<EpochKb>, backend: Box<dyn MutableRetriever>,
               corpus: Corpus, shards: usize, batch: usize) -> Self {
        Self {
            epochs,
            backend,
            corpus,
            shards: shards.max(1),
            batch: batch.max(1),
            quota_docs: 0,
            pending: Vec::new(),
            stats: IngestStats::default(),
        }
    }

    /// Set the lifetime ingest quota (0 = unlimited, the default); see
    /// [`ingest`](Self::ingest).
    pub fn set_quota(&mut self, quota_docs: usize) {
        self.quota_docs = quota_docs;
    }

    /// The id the next ingested document will receive.
    pub fn next_id(&self) -> u32 {
        (self.corpus.len() + self.pending.len()) as u32
    }

    /// Accept one document (tokens + topic + precomputed embedding row).
    /// Returns the new epoch id when this ingest triggered a batched
    /// publish, `None` while the document is merely pending.
    pub fn ingest(&mut self, tokens: Vec<u32>, topic: u32,
                  embedding: Vec<f32>) -> anyhow::Result<Option<u64>> {
        // Validate here (an error Response for the client) rather than
        // letting the index-side assertions panic under the writer
        // mutex, which would poison it for every later ingest.
        anyhow::ensure!(
            self.quota_docs == 0
                || (self.stats.docs_ingested as usize) < self.quota_docs,
            "tenant ingest quota exhausted ({} docs)", self.quota_docs);
        anyhow::ensure!(
            tokens.iter().all(|&t| (t as usize) < self.corpus.vocab),
            "ingested document uses token ids outside the corpus vocab \
             ({})", self.corpus.vocab);
        let doc = Document { id: self.next_id(), topic, tokens };
        self.pending.push((doc, embedding));
        self.stats.docs_ingested += 1;
        if self.pending.len() >= self.batch {
            return Ok(Some(self.publish_pending()?));
        }
        Ok(None)
    }

    /// Publish whatever is pending (no-op when nothing is). Returns the
    /// new epoch id if one was published.
    pub fn flush(&mut self) -> anyhow::Result<Option<u64>> {
        if self.pending.is_empty() {
            return Ok(None);
        }
        Ok(Some(self.publish_pending()?))
    }

    fn publish_pending(&mut self) -> anyhow::Result<u64> {
        let (docs, embs): (Vec<Document>, Vec<Vec<f32>>) =
            self.pending.drain(..).unzip();
        // `append` is all-or-nothing (validated before any mutation), so
        // a rejected batch leaves backend and corpus aligned: the batch
        // is dropped wholesale, the error surfaces to the ingest caller,
        // and the writer keeps publishing later batches normally.
        self.backend.append(&docs, &embs)?;
        self.corpus.append(docs);
        let epoch = self.epochs.epoch() + 1;
        self.epochs.publish(EpochSnapshot {
            epoch,
            kb: self.backend.snapshot(self.shards),
            corpus: Arc::new(self.corpus.clone()),
        });
        self.stats.epochs_published += 1;
        Ok(epoch)
    }

    /// Run one backend compaction pass and, if it merged anything,
    /// publish the result as a normal epoch (same length, same results —
    /// only the tier layout changes). Returns whether an epoch was
    /// published. No-op `Ok(false)` for in-RAM backends.
    pub fn run_compaction(&mut self) -> anyhow::Result<bool> {
        if !self.backend.compact()? {
            return Ok(false);
        }
        // Fold the corpus tail into its shared base alongside the
        // backend merge, so the per-publish corpus clone goes back to
        // being an Arc bump (O(tail), and the tail is now empty).
        self.corpus.seal();
        let epoch = self.epochs.epoch() + 1;
        self.epochs.publish(EpochSnapshot {
            epoch,
            kb: self.backend.snapshot(self.shards),
            corpus: Arc::new(self.corpus.clone()),
        });
        self.stats.epochs_published += 1;
        Ok(true)
    }

    /// Read tiers the backend's next snapshot will scan (see
    /// [`MutableRetriever::tier_count`]).
    pub fn tier_count(&self) -> usize {
        self.backend.tier_count()
    }

    pub fn stats(&self) -> IngestStats {
        self.stats
    }

    pub fn epochs(&self) -> &Arc<EpochKb> {
        &self.epochs
    }

    /// The writer-side corpus (includes published docs, not pending
    /// ones) — the ingest drivers synthesize new documents from its
    /// topic pools.
    pub fn corpus(&self) -> &Corpus {
        &self.corpus
    }
}

/// A live knowledge base as the serving stack consumes it: the shared
/// snapshot layer plus the mutex-guarded writer (ingest requests arrive
/// on router workers; the lock serializes them into the single-writer
/// model the epoch layer assumes).
pub struct LiveKb {
    pub epochs: Arc<EpochKb>,
    pub writer: Mutex<KbWriter>,
}

impl LiveKb {
    /// Build the live knowledge base of `kind` over an already-generated
    /// corpus and its embedding matrix (row-major, `dim`-wide — exactly
    /// what `datagen::embed_corpus` returns). Epoch 0 is the initial
    /// build; `cfg.retriever.shards` and `cfg.ingest.batch` govern
    /// snapshot sharding and the publish cadence.
    pub fn build(cfg: &Config, kind: RetrieverKind, corpus: Corpus,
                 embeddings: Vec<f32>, dim: usize) -> Arc<LiveKb> {
        let r = &cfg.retriever;
        let backend: Box<dyn MutableRetriever> = match kind {
            RetrieverKind::Edr => {
                Box::new(MutableDense::with_codec(
                    dim, embeddings, cfg.dense.codec,
                    cfg.dense.oversample))
            }
            RetrieverKind::Adr => {
                Box::new(MutableHnsw::new(dim, embeddings, r.hnsw_m,
                                          r.hnsw_ef_construction,
                                          r.hnsw_ef_search,
                                          cfg.corpus.seed ^ 0x48))
            }
            RetrieverKind::Sr => {
                Box::new(MutableBm25::new(Bm25::build(&corpus, r.bm25_k1,
                                                      r.bm25_b)))
            }
        };
        let shards = r.shards.max(1);
        let epochs = Arc::new(EpochKb::new(EpochSnapshot {
            epoch: 0,
            kb: backend.snapshot(shards),
            corpus: Arc::new(corpus.clone()),
        }));
        let mut writer = KbWriter::new(epochs.clone(), backend, corpus,
                                       shards, cfg.ingest.batch);
        writer.set_quota(cfg.tenant.quota_docs);
        let writer = Mutex::new(writer);
        Arc::new(LiveKb { epochs, writer })
    }

    /// Like [`LiveKb::build`], but honoring `cfg.segment.kb_dir`: when a
    /// KB directory is configured the backend is a persistent
    /// [`SegmentedKb`] (opened from disk if a store exists there, else
    /// created from `corpus` + `embeddings` and immediately reopened via
    /// mmap — see DESIGN.md ADR-009). On a warm open the recovered
    /// corpus replaces the caller's. With no `kb_dir` this is exactly
    /// `build`.
    pub fn build_auto(cfg: &Config, kind: RetrieverKind, corpus: Corpus,
                      embeddings: Vec<f32>, dim: usize)
                      -> anyhow::Result<Arc<LiveKb>> {
        let Some(dir) = &cfg.segment.kb_dir else {
            return Ok(Self::build(cfg, kind, corpus, embeddings, dim));
        };
        let (backend, corpus) =
            SegmentedKb::open_or_create(dir, cfg, kind, &corpus,
                                        &embeddings, dim)?;
        let backend: Box<dyn MutableRetriever> = Box::new(backend);
        let shards = cfg.retriever.shards.max(1);
        let epochs = Arc::new(EpochKb::new(EpochSnapshot {
            epoch: 0,
            kb: backend.snapshot(shards),
            corpus: Arc::new(corpus.clone()),
        }));
        let mut writer = KbWriter::new(epochs.clone(), backend, corpus,
                                       shards, cfg.ingest.batch);
        writer.set_quota(cfg.tenant.quota_docs);
        let writer = Mutex::new(writer);
        Ok(Arc::new(LiveKb { epochs, writer }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Config, CorpusConfig};
    use crate::datagen::{embed_corpus, embed_doc, HashEncoder};
    use crate::retriever::SpecQuery;
    use crate::util::Rng;

    const DIM: usize = 24;

    fn fixture(n_docs: usize) -> (Config, Corpus, Vec<f32>, HashEncoder) {
        let mut cfg = Config::default();
        cfg.corpus = CorpusConfig {
            n_docs,
            n_topics: 8,
            doc_len: (16, 48),
            seed: 0xE60C,
            ..CorpusConfig::default()
        };
        cfg.retriever.hnsw_ef_construction = 40;
        cfg.retriever.hnsw_ef_search = 32;
        cfg.ingest.batch = 4;
        let corpus = Corpus::generate(&cfg.corpus);
        let enc = HashEncoder::new(DIM, 0xE6);
        let data = embed_corpus(&enc, &corpus);
        (cfg, corpus, data, enc)
    }

    fn ingest_n(live: &LiveKb, enc: &HashEncoder, n: usize, seed: u64) {
        let mut w = live.writer.lock().unwrap();
        let docs = w.corpus().synth_docs(seed, w.next_id(), n, (16, 48));
        for d in docs {
            let e = embed_doc(enc, &d);
            w.ingest(d.tokens, d.topic, e).unwrap();
        }
        w.flush().unwrap();
    }

    fn bits(rows: &[Vec<crate::util::Scored>]) -> Vec<Vec<(u32, u32)>> {
        rows.iter()
            .map(|r| r.iter().map(|s| (s.id, s.score.to_bits())).collect())
            .collect()
    }

    fn probe_queries(corpus: &Corpus, enc: &HashEncoder, kind: RetrieverKind)
                     -> Vec<SpecQuery> {
        let mut rng = Rng::new(7);
        (0..6)
            .map(|i| {
                let w = corpus.topic_tokens(i % 8, 12, &mut rng);
                match kind {
                    RetrieverKind::Sr => SpecQuery::sparse_only(w),
                    _ => SpecQuery::dense_only(enc.encode(&w)),
                }
            })
            .collect()
    }

    #[test]
    fn old_snapshots_survive_publishes_unchanged() {
        for kind in RetrieverKind::all() {
            let (cfg, corpus, data, enc) = fixture(200);
            let live = LiveKb::build(&cfg, kind, corpus.clone(), data, DIM);
            let qs = probe_queries(&corpus, &enc, kind);
            let epoch0 = live.epochs.snapshot();
            let before = bits(&epoch0.kb.retrieve_batch(&qs, 5));
            ingest_n(&live, &enc, 10, 0x111);
            ingest_n(&live, &enc, 10, 0x222);
            assert!(live.epochs.epoch() >= 2, "{kind:?}");
            // The pinned epoch-0 view is byte-stable across publishes.
            let after = bits(&epoch0.kb.retrieve_batch(&qs, 5));
            assert_eq!(before, after, "{kind:?}");
            assert_eq!(epoch0.kb.len(), 200, "{kind:?}");
            assert_eq!(live.epochs.snapshot().kb.len(), 220, "{kind:?}");
        }
    }

    #[test]
    fn published_snapshot_matches_fresh_build() {
        // Append ≡ rebuild, end to end through the writer: the snapshot
        // after ingesting is bit-identical to a LiveKb built directly
        // over the extended corpus.
        for kind in RetrieverKind::all() {
            let (cfg, corpus, data, enc) = fixture(150);
            let live = LiveKb::build(&cfg, kind, corpus.clone(), data, DIM);
            ingest_n(&live, &enc, 12, 0x333);
            let grown = live.epochs.snapshot();

            let big = {
                let mut c = corpus.clone();
                let fresh = c.synth_docs(0x333, c.len() as u32, 12, (16, 48));
                c.append(fresh);
                c
            };
            let big_data = embed_corpus(&enc, &big);
            let rebuilt =
                LiveKb::build(&cfg, kind, big.clone(), big_data, DIM);
            let reference = rebuilt.epochs.snapshot();

            let qs = probe_queries(&big, &enc, kind);
            assert_eq!(bits(&grown.kb.retrieve_batch(&qs, 7)),
                       bits(&reference.kb.retrieve_batch(&qs, 7)),
                       "{kind:?}: append != rebuild");
            assert_eq!(grown.corpus.len(), reference.corpus.len());
        }
    }

    #[test]
    fn sharded_republish_is_coherent() {
        // shards > 1: every published epoch's scatter-gather view is
        // bit-identical to the unsharded snapshot of the same epoch — no
        // torn shard sets.
        for kind in RetrieverKind::all() {
            let (mut cfg, corpus, data, enc) = fixture(120);
            cfg.retriever.shards = 2;
            let live =
                LiveKb::build(&cfg, kind, corpus.clone(), data.clone(), DIM);
            let mut plain_cfg = cfg.clone();
            plain_cfg.retriever.shards = 1;
            let plain = LiveKb::build(&plain_cfg, kind, corpus.clone(),
                                      data, DIM);
            ingest_n(&live, &enc, 8, 0x444);
            ingest_n(&plain, &enc, 8, 0x444);
            let a = live.epochs.snapshot();
            let b = plain.epochs.snapshot();
            assert_eq!(a.epoch, b.epoch);
            let qs = probe_queries(&corpus, &enc, kind);
            assert_eq!(bits(&a.kb.retrieve_batch(&qs, 6)),
                       bits(&b.kb.retrieve_batch(&qs, 6)),
                       "{kind:?}: sharded republish diverged");
        }
    }

    #[test]
    fn ingested_docs_become_retrievable() {
        let (cfg, corpus, data, enc) = fixture(100);
        let live = LiveKb::build(&cfg, RetrieverKind::Edr, corpus, data,
                                 DIM);
        let doc = {
            let w = live.writer.lock().unwrap();
            w.corpus().synth_docs(0x555, w.next_id(), 1, (16, 48))
                .pop()
                .unwrap()
        };
        let emb = embed_doc(&enc, &doc);
        {
            let mut w = live.writer.lock().unwrap();
            w.ingest(doc.tokens.clone(), doc.topic, emb.clone()).unwrap();
            w.flush().unwrap();
        }
        let snap = live.epochs.snapshot();
        // Retrieving the doc's own embedding finds the doc; its text is
        // readable from the published corpus.
        let got = snap.kb.retrieve(&SpecQuery::dense_only(emb)).unwrap();
        assert_eq!(got.id, 100);
        assert_eq!(snap.corpus.doc(100).tokens, doc.tokens);
    }

    #[test]
    fn concurrent_readers_see_monotonic_complete_epochs() {
        let (cfg, corpus, data, enc) = fixture(100);
        let live = LiveKb::build(&cfg, RetrieverKind::Edr, corpus, data,
                                 DIM);
        let reader = {
            let live = live.clone();
            std::thread::spawn(move || {
                let mut last = 0u64;
                for _ in 0..2000 {
                    let s = live.epochs.snapshot();
                    assert!(s.epoch >= last, "epoch went backwards");
                    // Complete epoch: corpus and index always agree.
                    assert_eq!(s.corpus.len(), s.kb.len(),
                               "torn snapshot at epoch {}", s.epoch);
                    last = s.epoch;
                }
                last
            })
        };
        for round in 0..12 {
            ingest_n(&live, &enc, 4, 0x600 + round);
        }
        let last_seen = reader.join().unwrap();
        assert!(live.epochs.epoch() >= 12);
        let _ = last_seen;
    }

    #[test]
    fn bad_batch_is_rejected_without_wedging_the_writer() {
        // Regression: `append` is all-or-nothing, so a publish that
        // fails (here: a wrong-dimension embedding row) drops the batch
        // wholesale but leaves backend and corpus aligned — later
        // ingests keep publishing normally instead of failing the
        // contiguity check forever.
        let (cfg, corpus, data, enc) = fixture(60);
        let live = LiveKb::build(&cfg, RetrieverKind::Edr, corpus, data,
                                 DIM);
        {
            let mut w = live.writer.lock().unwrap();
            let docs =
                w.corpus().synth_docs(0x888, w.next_id(), 1, (16, 48));
            let d = docs.into_iter().next().unwrap();
            w.ingest(d.tokens, d.topic, vec![0.0; DIM + 1]).unwrap();
            assert!(w.flush().is_err(), "bad embedding dim must error");
            assert_eq!(w.epochs().epoch(), 0, "nothing published");
        }
        ingest_n(&live, &enc, 4, 0x999);
        assert!(live.epochs.epoch() >= 1,
                "writer must recover after a rejected batch");
        assert_eq!(live.epochs.snapshot().kb.len(), 64);
        assert_eq!(live.epochs.snapshot().corpus.len(), 64);
    }

    #[test]
    fn ingest_quota_rejects_after_limit() {
        // ADR-011: a tenant's writer stops accepting documents once its
        // lifetime quota is spent — the error is a clean per-request
        // rejection (no panic, no poisoned mutex) and already-published
        // epochs keep serving.
        let (mut cfg, corpus, data, enc) = fixture(50);
        cfg.tenant.quota_docs = 3;
        let live = LiveKb::build(&cfg, RetrieverKind::Edr, corpus, data,
                                 DIM);
        let mut w = live.writer.lock().unwrap();
        let docs = w.corpus().synth_docs(0xAAA, w.next_id(), 4, (16, 48));
        for (i, d) in docs.into_iter().enumerate() {
            let e = embed_doc(&enc, &d);
            let r = w.ingest(d.tokens, d.topic, e);
            if i < 3 {
                r.unwrap();
            } else {
                let err = r.expect_err("quota must reject the 4th doc");
                assert!(err.to_string().contains("quota"),
                        "unexpected error: {err:#}");
            }
        }
        w.flush().unwrap();
        assert_eq!(w.stats().docs_ingested, 3);
        assert_eq!(w.epochs().snapshot().kb.len(), 53);
    }

    #[test]
    fn writer_batches_publishes() {
        let (cfg, corpus, data, enc) = fixture(80);
        // cfg.ingest.batch == 4.
        let live = LiveKb::build(&cfg, RetrieverKind::Sr, corpus, data,
                                 DIM);
        let mut w = live.writer.lock().unwrap();
        let docs = w.corpus().synth_docs(0x777, w.next_id(), 6, (16, 48));
        let mut published = Vec::new();
        for d in docs {
            let e = embed_doc(&enc, &d);
            if let Some(ep) = w.ingest(d.tokens, d.topic, e).unwrap() {
                published.push(ep);
            }
        }
        // 6 docs at batch 4: one batched publish, two still pending.
        assert_eq!(published, vec![1]);
        assert_eq!(w.epochs().epoch(), 1);
        assert_eq!(w.flush().unwrap(), Some(2));
        assert_eq!(w.flush().unwrap(), None);
        let s = w.stats();
        assert_eq!(s.docs_ingested, 6);
        assert_eq!(s.epochs_published, 2);
    }
}
