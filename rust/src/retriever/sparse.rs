//! Sparse retriever ("SR"): BM25 over a from-scratch inverted index — the
//! Pyserini/BM25 role in the paper (k1 = 0.9, b = 0.4, Pyserini defaults).
//!
//! Two properties the speculation machinery depends on:
//!
//! * **Local scorability** (§3): per-document term frequencies plus the
//!   global stats (df table, avgdl, N) are stored so `score_doc` computes
//!   the exact BM25 score of any (query, doc) pair without the index —
//!   this is what the local cache ranks with, giving rank preservation.
//! * **Amortized batched retrieval** (§A.1): `retrieve_batch` unions the
//!   query terms and walks each posting list once for the whole batch, so
//!   total verification cost grows sublinearly in batch size when queries
//!   share vocabulary (they do: consecutive speculation queries overlap).
//!
//! IDF is floored at 0 (Robertson's guard): terms appearing in more than
//! half the corpus contribute nothing and their postings are skipped
//! consistently in both the index scan and `score_doc`.

use super::{DocId, Retriever, SpecQuery};
use crate::datagen::corpus::Corpus;
use crate::util::{Scored, TopK};
use std::cell::RefCell;

/// Reusable working set for [`Bm25::retrieve_batch_range`]: the
/// `(term, query, qtf)` fan-out list plus the dense score accumulators and
/// their touched-doc lists. Everything is rented from a thread-local and
/// handed back in its invariant state (pairs/touched cleared, accumulators
/// all-zero), so steady-state batched retrieval — including every
/// coalesced engine flush, since KB calls run on the persistent worker
/// pool — allocates nothing.
#[derive(Default)]
struct SparseScratch {
    /// (term, query index, query term frequency), sorted by (term, query):
    /// the flat replacement for the old per-call `HashMap<term, users>` —
    /// same traversal order (terms ascending, then queries ascending), so
    /// accumulation order and therefore scores are bit-identical.
    pairs: Vec<(u32, u32, f32)>,
    /// Dense per-query score accumulators; all-zero between calls. Buffers
    /// are zeroed once at birth and *selectively* re-zeroed (touched
    /// entries only) on return, so per-call cost scales with postings
    /// traversed, not with B x n_docs. (§Perf: this flattened the SR
    /// batching curve — see EXPERIMENTS.md.)
    acc: Vec<Vec<f32>>,
    /// Docs with a nonzero accumulator entry, per query; cleared on return.
    touched: Vec<Vec<DocId>>,
}

thread_local! {
    static SPARSE_SCRATCH: RefCell<SparseScratch> =
        RefCell::new(SparseScratch::default());
}

/// The BM25 tf-saturation / length-normalization weight, as a free
/// function so the segment tier (`retriever::segment`) scores mapped
/// postings through literally the same expression as the in-RAM index.
#[inline]
pub(crate) fn bm25_term_weight(tf: f32, dl: f32, k1: f32, b: f32,
                               avgdl: f32) -> f32 {
    // BM25 tf saturation with length normalization.
    tf * (k1 + 1.0) / (tf + k1 * (1.0 - b + b * dl / avgdl))
}

/// Robertson IDF floored at 0, same arithmetic as [`Bm25::build`]'s
/// inline computation (f32 throughout).
#[inline]
pub(crate) fn bm25_idf(n_docs: usize, df: usize) -> f32 {
    let df = df as f32;
    ((n_docs as f32 - df + 0.5) / (df + 0.5)).ln().max(0.0)
}

/// Query terms with multiplicity collapsed to (term, qtf), zero-idf
/// terms dropped — the single tokenization every BM25 scorer shares.
pub(crate) fn bm25_query_terms(terms: &[u32], idf: &[f32])
                               -> Vec<(u32, f32)> {
    let mut sorted: Vec<u32> = terms.to_vec();
    sorted.sort_unstable();
    let mut out: Vec<(u32, f32)> = Vec::new();
    for &t in &sorted {
        if (t as usize) >= idf.len() || idf[t as usize] <= 0.0 {
            continue;
        }
        match out.last_mut() {
            Some((lt, c)) if *lt == t => *c += 1.0,
            _ => out.push((t, 1.0)),
        }
    }
    out
}

/// Sorted-unique (term, tf) pairs for one document, `u16`-saturated —
/// the per-doc bookkeeping walk shared by [`Bm25::build`],
/// [`Bm25::append_docs`], and the segment serializer. `tf_scratch` must
/// be all-zero and vocab-sized on entry; it is restored on return.
pub(crate) fn doc_term_stats(tokens: &[u32], tf_scratch: &mut [u16])
                             -> Vec<(u32, u16)> {
    let mut seen: Vec<u32> = Vec::with_capacity(tokens.len());
    for &t in tokens {
        if tf_scratch[t as usize] == 0 {
            seen.push(t);
        }
        tf_scratch[t as usize] = tf_scratch[t as usize].saturating_add(1);
    }
    seen.sort_unstable();
    let terms: Vec<(u32, u16)> =
        seen.iter().map(|&t| (t, tf_scratch[t as usize])).collect();
    for &(t, _) in &terms {
        tf_scratch[t as usize] = 0;
    }
    terms
}

/// `Clone` so a live-update writer (`retriever::epoch::MutableBm25`) can
/// keep a mutable master index and publish immutable per-epoch snapshots.
#[derive(Debug, Clone)]
pub struct Bm25 {
    k1: f32,
    b: f32,
    pub(crate) n_docs: usize,
    avgdl: f32,
    doc_len: Vec<u32>,
    /// postings[term] -> (doc, tf) sorted by doc id.
    pub(crate) postings: Vec<Vec<(DocId, u16)>>,
    /// idf[term], floored at 0.
    pub(crate) idf: Vec<f32>,
    /// Per-doc (term, tf) sorted by term — the "local information" the
    /// paper stores so cache scoring matches KB scoring.
    doc_terms: Vec<Vec<(u32, u16)>>,
}

impl Bm25 {
    pub fn build(corpus: &Corpus, k1: f32, b: f32) -> Self {
        let vocab = corpus.vocab;
        let n_docs = corpus.len();
        let mut postings: Vec<Vec<(DocId, u16)>> = vec![Vec::new(); vocab];
        let mut doc_len = Vec::with_capacity(n_docs);
        let mut doc_terms = Vec::with_capacity(n_docs);
        let mut tf_scratch: Vec<u16> = vec![0; vocab];

        for doc in corpus.iter() {
            doc_len.push(doc.tokens.len() as u32);
            let terms = doc_term_stats(&doc.tokens, &mut tf_scratch);
            for &(t, tf) in &terms {
                postings[t as usize].push((doc.id, tf));
            }
            doc_terms.push(terms);
        }

        let avgdl = corpus.avg_doc_len() as f32;
        let idf: Vec<f32> =
            postings.iter().map(|p| bm25_idf(n_docs, p.len())).collect();

        Self { k1, b, n_docs, avgdl, doc_len, postings, idf, doc_terms }
    }

    #[inline]
    fn term_weight(&self, tf: f32, dl: f32) -> f32 {
        bm25_term_weight(tf, dl, self.k1, self.b, self.avgdl)
    }

    /// Query terms with multiplicity collapsed to (term, qtf), zero-idf
    /// terms dropped (consistent everywhere).
    fn query_terms(&self, terms: &[u32]) -> Vec<(u32, f32)> {
        bm25_query_terms(terms, &self.idf)
    }

    pub fn stats(&self) -> (usize, f32) {
        (self.n_docs, self.avgdl)
    }

    /// Append freshly ingested documents (live knowledge-base updates):
    /// extend the posting lists, per-doc term stats, and doc lengths, then
    /// recompute the global statistics (idf, avgdl) over the grown corpus.
    ///
    /// The per-doc bookkeeping mirrors [`Bm25::build`] exactly (same
    /// sorted-unique term walk, same `u16` tf saturation) and postings are
    /// appended in doc-id order, so the grown index is **bit-identical**
    /// to a from-scratch build over the extended corpus — pinned by the
    /// `append_matches_fresh_build` test. Note idf and avgdl *do* change
    /// with N: scores of old documents legitimately differ between
    /// epochs, which is exactly why epoch snapshots (retriever::epoch)
    /// must never mix scores across a publish.
    pub fn append_docs(&mut self, docs: &[crate::datagen::corpus::Document]) {
        let vocab = self.postings.len();
        let mut tf_scratch: Vec<u16> = vec![0; vocab];
        for doc in docs {
            assert_eq!(doc.id as usize, self.n_docs,
                       "ingested doc ids must be contiguous");
            assert!(doc.tokens.iter().all(|&t| (t as usize) < vocab),
                    "ingested doc uses tokens outside the index vocab");
            self.doc_len.push(doc.tokens.len() as u32);
            let terms = doc_term_stats(&doc.tokens, &mut tf_scratch);
            for &(t, tf) in &terms {
                self.postings[t as usize].push((doc.id, tf));
            }
            self.doc_terms.push(terms);
            self.n_docs += 1;
        }
        // Global statistics over the grown corpus, with the same
        // arithmetic as `build` (integer length sum -> f64 divide -> f32).
        let total: usize =
            self.doc_len.iter().map(|&l| l as usize).sum();
        self.avgdl = if self.n_docs == 0 {
            0.0
        } else {
            (total as f64 / self.n_docs as f64) as f32
        };
        let n_docs = self.n_docs;
        self.idf = self
            .postings
            .iter()
            .map(|p| bm25_idf(n_docs, p.len()))
            .collect();
    }
}

impl Bm25 {
    /// Batched top-k restricted to the doc-id range `[lo, hi)`, reporting
    /// global doc ids and scores computed from the **global** statistics
    /// (idf, avgdl, doc lengths). The full-corpus call is the
    /// `(0, n_docs)` range; shard views walk only their slice of each
    /// posting list. Per-doc accumulation order (sorted term order) is
    /// identical regardless of the range, so a k-way merge of shard
    /// results is bit-identical to the unsharded scan.
    pub(crate) fn retrieve_batch_range(&self, qs: &[SpecQuery], k: usize,
                                       lo: DocId, hi: DocId)
                                       -> Vec<Vec<Scored>> {
        SPARSE_SCRATCH.with(|cell| {
            // Reentrancy guard: fall back to a fresh scratch if this
            // thread's is already borrowed up-stack. The scratch only
            // caches capacity, so results are identical either way.
            match cell.try_borrow_mut() {
                Ok(mut s) => {
                    self.retrieve_batch_range_with(qs, k, lo, hi, &mut s)
                }
                Err(_) => self.retrieve_batch_range_with(
                    qs, k, lo, hi, &mut SparseScratch::default()),
            }
        })
    }

    /// [`Bm25::retrieve_batch_range`] against a caller-provided scratch.
    fn retrieve_batch_range_with(&self, qs: &[SpecQuery], k: usize,
                                 lo: DocId, hi: DocId,
                                 scratch: &mut SparseScratch)
                                 -> Vec<Vec<Scored>> {
        let SparseScratch { pairs, acc, touched } = &mut *scratch;
        // Union the query terms as a flat (term, query, qtf) list; walk
        // each posting list once and fan the contribution out to every
        // query containing the term. `query_terms` emits terms sorted, so
        // sorting the flat list by (term, query) reproduces the exact
        // accumulation order of the per-term HashMap this replaces:
        // terms ascending, then queries ascending.
        pairs.clear();
        for (qi, q) in qs.iter().enumerate() {
            for (t, qtf) in self.query_terms(&q.terms) {
                pairs.push((t, qi as u32, qtf));
            }
        }
        pairs.sort_unstable_by_key(|&(t, qi, _)| (t, qi));
        while acc.len() < qs.len() {
            acc.push(Vec::new());
        }
        for a in acc.iter_mut().take(qs.len()) {
            if a.len() < self.n_docs {
                a.resize(self.n_docs, 0.0);
            }
        }
        while touched.len() < qs.len() {
            touched.push(Vec::new());
        }
        let mut idx = 0;
        while idx < pairs.len() {
            let t = pairs[idx].0;
            let mut end = idx + 1;
            while end < pairs.len() && pairs[end].0 == t {
                end += 1;
            }
            let users = &pairs[idx..end];
            idx = end;
            let idf = self.idf[t as usize];
            let plist = &self.postings[t as usize];
            // Postings are doc-id-sorted: binary-search the range start,
            // walk until the range end.
            let start = plist.partition_point(|&(d, _)| d < lo);
            for &(doc, tf) in &plist[start..] {
                if doc >= hi {
                    break;
                }
                let w = idf
                    * self.term_weight(tf as f32,
                                       self.doc_len[doc as usize] as f32);
                for &(_, qi, qtf) in users {
                    let qi = qi as usize;
                    if acc[qi][doc as usize] == 0.0 {
                        touched[qi].push(doc);
                    }
                    acc[qi][doc as usize] += qtf * w;
                }
            }
        }
        let mut out = Vec::with_capacity(qs.len());
        for (a, tq) in acc.iter_mut().zip(touched.iter_mut()).take(qs.len()) {
            let mut tk = TopK::new(k.max(1));
            for &doc in tq.iter() {
                tk.push(doc, a[doc as usize]);
                a[doc as usize] = 0.0; // restore the all-zero invariant
            }
            tq.clear();
            out.push(tk.into_sorted());
        }
        out
    }
}

impl Retriever for Bm25 {
    fn retrieve_batch(&self, qs: &[SpecQuery], k: usize) -> Vec<Vec<Scored>> {
        self.retrieve_batch_range(qs, k, 0, self.n_docs as DocId)
    }

    fn score_doc(&self, q: &SpecQuery, doc: DocId) -> f32 {
        // Exact BM25 from the stored per-doc term stats (cache-side metric).
        let terms = self.query_terms(&q.terms);
        let dt = &self.doc_terms[doc as usize];
        let dl = self.doc_len[doc as usize] as f32;
        let mut score = 0.0;
        for (t, qtf) in terms {
            if let Ok(i) = dt.binary_search_by_key(&t, |&(term, _)| term) {
                score += qtf * self.idf[t as usize]
                    * self.term_weight(dt[i].1 as f32, dl);
            }
        }
        score
    }

    fn len(&self) -> usize {
        self.n_docs
    }

    fn name(&self) -> &'static str {
        "SR(bm25)"
    }
}

/// A doc-id-range shard view over a shared BM25 index. The index (and its
/// global statistics) is built once; each shard walks only its slice of
/// the posting lists, so scores — and therefore the merged top-k — are
/// bit-identical to the unsharded index.
pub struct Bm25Shard {
    index: std::sync::Arc<Bm25>,
    lo: DocId,
    hi: DocId,
}

impl Bm25Shard {
    pub fn new(index: std::sync::Arc<Bm25>, lo: DocId, hi: DocId) -> Self {
        assert!(lo <= hi && hi as usize <= index.n_docs,
                "shard bounds out of range");
        Self { index, lo, hi }
    }
}

impl Retriever for Bm25Shard {
    fn retrieve_batch(&self, qs: &[SpecQuery], k: usize) -> Vec<Vec<Scored>> {
        self.index.retrieve_batch_range(qs, k, self.lo, self.hi)
    }

    fn score_doc(&self, q: &SpecQuery, doc: DocId) -> f32 {
        self.index.score_doc(q, doc)
    }

    fn len(&self) -> usize {
        (self.hi - self.lo) as usize
    }

    fn name(&self) -> &'static str {
        "SR(bm25-shard)"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CorpusConfig;
    use crate::util::Rng;

    fn corpus() -> Corpus {
        Corpus::generate(&CorpusConfig {
            n_docs: 400, n_topics: 10, doc_len: (20, 80),
            ..CorpusConfig::default()
        })
    }

    #[test]
    fn index_scan_matches_score_doc() {
        let c = corpus();
        let bm = Bm25::build(&c, 0.9, 0.4);
        let mut rng = Rng::new(1);
        for _ in 0..10 {
            let q = SpecQuery::sparse_only(c.topic_tokens(
                rng.gen_range(10) as u32, 8, &mut rng));
            for s in bm.retrieve_topk(&q, 5) {
                let direct = bm.score_doc(&q, s.id);
                assert!((s.score - direct).abs() < 1e-4,
                        "scan={} direct={}", s.score, direct);
            }
        }
    }

    #[test]
    fn scan_survives_scratch_already_borrowed() {
        let c = if cfg!(miri) {
            Corpus::generate(&CorpusConfig {
                n_docs: 60, n_topics: 10, doc_len: (10, 30),
                ..CorpusConfig::default()
            })
        } else {
            corpus()
        };
        let bm = Bm25::build(&c, 0.9, 0.4);
        let mut rng = Rng::new(11);
        let qs: Vec<SpecQuery> = (0..4)
            .map(|i| SpecQuery::sparse_only(
                c.topic_tokens(i % 10, 8, &mut rng)))
            .collect();
        let plain = bm.retrieve_batch(&qs, 5);
        // Reentrancy: the thread-local accumulators are held across the
        // retrieval, forcing the fresh-scratch fallback. Must not panic,
        // and must score identically (scratch is capacity-only).
        let held = SPARSE_SCRATCH.with(|cell| {
            let _guard = cell.borrow_mut();
            bm.retrieve_batch(&qs, 5)
        });
        assert_eq!(plain, held);
    }

    #[test]
    fn retrieves_topically_relevant_docs() {
        let c = corpus();
        let bm = Bm25::build(&c, 0.9, 0.4);
        let mut rng = Rng::new(2);
        let mut topic_hits = 0;
        let n_trials = 20;
        for i in 0..n_trials {
            let topic = (i % 10) as u32;
            let q = SpecQuery::sparse_only(c.topic_tokens(topic, 10, &mut rng));
            if let Some(top) = bm.retrieve(&q) {
                if c.doc(top.id).topic == topic {
                    topic_hits += 1;
                }
            }
        }
        assert!(topic_hits >= n_trials * 6 / 10,
                "only {topic_hits}/{n_trials} on-topic");
    }

    #[test]
    fn batch_matches_sequential() {
        let c = corpus();
        let bm = Bm25::build(&c, 0.9, 0.4);
        let mut rng = Rng::new(3);
        let qs: Vec<SpecQuery> = (0..5)
            .map(|i| SpecQuery::sparse_only(
                c.topic_tokens(i % 10, 8, &mut rng)))
            .collect();
        let batch = bm.retrieve_batch(&qs, 7);
        for (q, b) in qs.iter().zip(&batch) {
            let seq = bm.retrieve_topk(q, 7);
            assert_eq!(seq.iter().map(|s| s.id).collect::<Vec<_>>(),
                       b.iter().map(|s| s.id).collect::<Vec<_>>());
        }
    }

    #[test]
    fn high_df_terms_are_skipped() {
        let c = corpus();
        let bm = Bm25::build(&c, 0.9, 0.4);
        // Find the most common term (df > N/2 by construction of the
        // common pool's Zipf head): its idf floors at 0, so a query made
        // only of it scores nothing — consistently in both the index scan
        // and the local (cache-side) scorer.
        let top_term = (0..c.vocab as u32)
            .max_by_key(|&t| bm.postings[t as usize].len())
            .unwrap();
        assert!(bm.postings[top_term as usize].len() > bm.n_docs / 2,
                "fixture should have a stopword-like term");
        assert_eq!(bm.idf[top_term as usize], 0.0);
        let q = SpecQuery::sparse_only(vec![top_term]);
        let top = bm.retrieve_topk(&q, 3);
        assert!(top.is_empty() || top[0].score == 0.0);
        assert_eq!(bm.score_doc(&q, 0), 0.0);
    }

    #[test]
    fn duplicate_query_terms_double_weight() {
        let c = corpus();
        let bm = Bm25::build(&c, 0.9, 0.4);
        let mut rng = Rng::new(4);
        let base = c.topic_tokens(1, 4, &mut rng);
        let doc = bm
            .retrieve(&SpecQuery::sparse_only(base.clone()))
            .map(|s| s.id);
        if let Some(doc) = doc {
            let mut doubled = base.clone();
            doubled.extend_from_slice(&base);
            let s1 = bm.score_doc(&SpecQuery::sparse_only(base), doc);
            let s2 = bm.score_doc(&SpecQuery::sparse_only(doubled), doc);
            assert!((s2 - 2.0 * s1).abs() < 1e-4);
        }
    }

    #[test]
    fn append_matches_fresh_build() {
        // The live-update invariant: appending docs to a built index is
        // bit-identical to rebuilding from scratch over the extended
        // corpus — including the recomputed global statistics (idf,
        // avgdl) that shift with N.
        let big = Corpus::generate(&CorpusConfig {
            n_docs: 500, n_topics: 10, doc_len: (20, 80),
            ..CorpusConfig::default()
        });
        let mut small = big.clone();
        small.truncate(350);
        let mut grown = Bm25::build(&small, 0.9, 0.4);
        let fresh_docs: Vec<_> = big.iter().skip(350).cloned().collect();
        grown.append_docs(&fresh_docs);
        let fresh = Bm25::build(&big, 0.9, 0.4);
        assert_eq!(grown.n_docs, fresh.n_docs);
        assert_eq!(grown.doc_len, fresh.doc_len);
        assert_eq!(grown.postings, fresh.postings);
        assert_eq!(grown.avgdl.to_bits(), fresh.avgdl.to_bits());
        for (a, b) in grown.idf.iter().zip(&fresh.idf) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // And the scan agrees bit-for-bit.
        let mut rng = Rng::new(9);
        let q = SpecQuery::sparse_only(big.topic_tokens(2, 8, &mut rng));
        let ga = grown.retrieve_topk(&q, 7);
        let gb = fresh.retrieve_topk(&q, 7);
        assert_eq!(ga.len(), gb.len());
        for (x, y) in ga.iter().zip(&gb) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.score.to_bits(), y.score.to_bits());
        }
    }

    #[test]
    fn empty_query_scores_zero() {
        let c = corpus();
        let bm = Bm25::build(&c, 0.9, 0.4);
        let q = SpecQuery::sparse_only(vec![]);
        assert!(bm.retrieve_topk(&q, 3).is_empty());
        assert_eq!(bm.score_doc(&q, 0), 0.0);
    }
}
