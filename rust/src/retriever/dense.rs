//! Exact dense retriever ("EDR"): brute-force inner-product top-k over the
//! corpus embedding matrix — the FAISS `IndexFlatIP` role in the paper.
//!
//! The scan is doc-major so each corpus row is read exactly once per batch:
//! batched retrieval (the verification step) amortizes the full memory pass
//! over all queries, which is why total batched latency is near-constant in
//! batch size (paper Fig 6a) — the effect RaLMSpec's saving rests on.

use super::kernels::{self, LANES};
use super::{DocId, Retriever, SpecQuery};
use crate::util::{Scored, TopK};
use std::cell::RefCell;
use std::sync::Arc;

/// Row-major [n, dim] embedding matrix shared across retrievers/caches.
#[derive(Debug)]
pub struct EmbeddingMatrix {
    pub dim: usize,
    pub data: Vec<f32>,
}

impl EmbeddingMatrix {
    pub fn new(dim: usize, data: Vec<f32>) -> Self {
        assert!(dim > 0 && data.len() % dim == 0,
                "embedding matrix shape mismatch");
        Self { dim, data }
    }

    #[inline]
    pub fn row(&self, i: DocId) -> &[f32] {
        let d = self.dim;
        &self.data[i as usize * d..(i as usize + 1) * d]
    }

    pub fn len(&self) -> usize {
        self.data.len() / self.dim
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

/// Inner product over the (fixed, small) retrieval dimension — the EDR
/// hot loop. Delegates to the shared scoring kernel
/// ([`kernels::dot`], DESIGN.md ADR-007) so every caller (flat-scan
/// `score_doc`, the HNSW walk, the KNN-LM cache) shares one reduction
/// order with the SIMD forms; kept under its historical name because
/// call sites predate the kernels module.
#[inline]
pub fn dot_chunked(a: &[f32], b: &[f32]) -> f32 {
    kernels::dot(a, b)
}

pub struct DenseExact {
    emb: Arc<EmbeddingMatrix>,
}

impl DenseExact {
    pub fn new(emb: Arc<EmbeddingMatrix>) -> Self {
        Self { emb }
    }

    pub fn embeddings(&self) -> &Arc<EmbeddingMatrix> {
        &self.emb
    }
}

thread_local! {
    /// Reusable column-major query-pack buffer for [`scan_multi_range`]:
    /// the per-block `vec![0.0; d * LANES]` allocation hoisted out of the
    /// scan and reused across blocks, batches, and engine flushes on the
    /// same thread (KB calls run on the persistent worker pool, so the
    /// buffer stays warm for the life of the process).
    static QT_SCRATCH: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
}

/// Scan rows `[lo, hi)` of the matrix, pushing **global** doc ids into the
/// per-query heaps. The full-corpus scan is the `(0, len)` range; shard
/// views scan their slice. Per-row arithmetic is identical regardless of
/// the range, so a k-way merge of shard results is bit-identical to the
/// full scan (the property `ShardedRetriever` relies on).
///
/// Queries are processed in blocks of up to [`LANES`], packed column-major
/// (`qt[j*LANES + lane]`) so each corpus row is loaded once and scored
/// LANES-wide by [`kernels::scan_block`]; per-row arithmetic intensity
/// rises from 2 FLOP/byte (single query) to 2·B FLOP/byte — this is what
/// makes batched verification near-free for EDR (paper Fig 6a / §A.1).
pub(crate) fn scan_multi_range(emb: &EmbeddingMatrix, lo: usize, hi: usize,
                               queries: &[&[f32]], heaps: &mut [TopK]) {
    with_pack_scratch(|qt| {
        scan_multi_range_with(emb, lo, hi, queries, heaps, qt);
    });
}

/// Run `f` against this thread's query-pack scratch buffer, with the
/// reentrancy guard: if a caller somewhere up the stack already holds
/// this thread's scratch (e.g. a retriever wrapper that scans inside a
/// scratch-borrowing callback), borrow_mut() would panic — fall back to
/// a fresh buffer instead. The scratch only caches capacity, so results
/// are identical either way. Shared with the segment tier's scanner
/// (`retriever::segment`), which packs through the same buffer.
pub(crate) fn with_pack_scratch<R>(f: impl FnOnce(&mut Vec<f32>) -> R) -> R {
    QT_SCRATCH.with(|cell| match cell.try_borrow_mut() {
        Ok(mut qt) => f(&mut qt),
        Err(_) => f(&mut Vec::new()),
    })
}

/// [`scan_multi_range`] with a caller-provided query-pack scratch buffer
/// (grown on demand, cleared and re-packed per block, never shrunk).
pub(crate) fn scan_multi_range_with(emb: &EmbeddingMatrix, lo: usize,
                                    hi: usize, queries: &[&[f32]],
                                    heaps: &mut [TopK], qt: &mut Vec<f32>) {
    debug_assert!(lo <= hi && hi <= emb.len());
    let d = emb.dim;
    scan_rows_with(&emb.data[lo * d..hi * d], d, lo as DocId, queries,
                   heaps, qt);
}

/// Scan raw row-major rows (`data.len()` must be a multiple of `dim`),
/// pushing ids offset by `base` into the per-query heaps. This is the
/// layout-agnostic core of the EDR scan: the in-RAM matrix path above
/// slices into it, and the segment tier (`retriever::segment`) feeds it
/// `f32` views over mmap'd section bytes — one numeric code path, so
/// segment-backed and in-RAM retrieval are bit-identical by construction.
pub(crate) fn scan_rows_with(data: &[f32], dim: usize, base: DocId,
                             queries: &[&[f32]], heaps: &mut [TopK],
                             qt: &mut Vec<f32>) {
    debug_assert_eq!(queries.len(), heaps.len());
    debug_assert_eq!(data.len() % dim.max(1), 0);
    for (block_start, qblock) in (0..queries.len())
        .step_by(LANES)
        .zip(queries.chunks(LANES))
    {
        let b = qblock.len();
        // Column-major packed query block, zero-padded to LANES.
        qt.clear();
        qt.resize(dim * LANES, 0.0);
        for (bi, q) in qblock.iter().enumerate() {
            for (j, &v) in q.iter().enumerate() {
                qt[j * LANES + bi] = v;
            }
        }
        kernels::scan_block(data, dim, base, qt,
                            &mut heaps[block_start..block_start + b]);
    }
}

/// Range-restricted batched top-k (shared by [`DenseExact`] and
/// [`DenseShard`]).
fn batch_over_range(emb: &EmbeddingMatrix, lo: usize, hi: usize,
                    qs: &[SpecQuery], k: usize) -> Vec<Vec<Scored>> {
    for q in qs {
        assert_eq!(q.dense.len(), emb.dim, "query dim mismatch");
    }
    let mut heaps: Vec<TopK> = qs.iter().map(|_| TopK::new(k.max(1))).collect();
    let qrefs: Vec<&[f32]> = qs.iter().map(|q| q.dense.as_slice()).collect();
    scan_multi_range(emb, lo, hi, &qrefs, &mut heaps);
    heaps.into_iter().map(|h| h.into_sorted()).collect()
}

impl Retriever for DenseExact {
    // NOTE: retrieve_topk is intentionally NOT overridden — it derives
    // from the batch of one, so both paths share the lane kernel's
    // operation order. (Found the hard way — a 4-accumulator single-query
    // kernel rounds differently from the lane kernel and occasionally
    // flips a near-tied top-1.)
    fn retrieve_batch(&self, qs: &[SpecQuery], k: usize) -> Vec<Vec<Scored>> {
        // One pass over the corpus for the whole batch: read each row once,
        // score it against every query (blocked multi-query kernel). This
        // is the batched-verification primitive whose near-constant total
        // cost drives RaLMSpec.
        batch_over_range(&self.emb, 0, self.emb.len(), qs, k)
    }

    fn score_doc(&self, q: &SpecQuery, doc: DocId) -> f32 {
        dot_chunked(&q.dense, self.emb.row(doc))
    }

    fn len(&self) -> usize {
        self.emb.len()
    }

    fn name(&self) -> &'static str {
        "EDR(flat)"
    }
}

/// A contiguous-row shard view over a shared embedding matrix: scans only
/// `[lo, hi)` but reports global doc ids, so merged shard results are
/// bit-identical to the unsharded scan.
pub struct DenseShard {
    emb: Arc<EmbeddingMatrix>,
    lo: usize,
    hi: usize,
}

impl DenseShard {
    pub fn new(emb: Arc<EmbeddingMatrix>, lo: usize, hi: usize) -> Self {
        assert!(lo <= hi && hi <= emb.len(), "shard bounds out of range");
        Self { emb, lo, hi }
    }
}

impl Retriever for DenseShard {
    fn retrieve_batch(&self, qs: &[SpecQuery], k: usize) -> Vec<Vec<Scored>> {
        batch_over_range(&self.emb, self.lo, self.hi, qs, k)
    }

    fn score_doc(&self, q: &SpecQuery, doc: DocId) -> f32 {
        dot_chunked(&q.dense, self.emb.row(doc))
    }

    fn len(&self) -> usize {
        self.hi - self.lo
    }

    fn name(&self) -> &'static str {
        "EDR(flat-shard)"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_matrix(n: usize, d: usize, seed: u64) -> Arc<EmbeddingMatrix> {
        let mut rng = Rng::new(seed);
        let mut data = Vec::with_capacity(n * d);
        for _ in 0..n {
            data.extend(rng.unit_vector(d));
        }
        Arc::new(EmbeddingMatrix::new(d, data))
    }

    #[test]
    fn dot_chunked_matches_naive() {
        let mut rng = Rng::new(1);
        for n in [1usize, 7, 8, 17, 64, 100] {
            let a: Vec<f32> = (0..n).map(|_| rng.next_f32() - 0.5).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.next_f32() - 0.5).collect();
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot_chunked(&a, &b) - naive).abs() < 1e-4, "n={n}");
        }
    }

    #[test]
    fn top1_is_true_argmax() {
        let emb = random_matrix(500, 32, 2);
        let r = DenseExact::new(emb.clone());
        let mut rng = Rng::new(3);
        for _ in 0..20 {
            let q = SpecQuery::dense_only(rng.unit_vector(32));
            let got = r.retrieve(&q).unwrap();
            let mut best = (0u32, f32::NEG_INFINITY);
            for i in 0..emb.len() {
                let s = dot_chunked(&q.dense, emb.row(i as u32));
                if s > best.1 {
                    best = (i as u32, s);
                }
            }
            assert_eq!(got.id, best.0);
        }
    }

    #[test]
    fn batch_matches_sequential() {
        let emb = random_matrix(300, 16, 4);
        let r = DenseExact::new(emb);
        let mut rng = Rng::new(5);
        let qs: Vec<SpecQuery> =
            (0..6).map(|_| SpecQuery::dense_only(rng.unit_vector(16))).collect();
        let batch = r.retrieve_batch(&qs, 5);
        for (q, b) in qs.iter().zip(&batch) {
            let seq = r.retrieve_topk(q, 5);
            assert_eq!(seq.iter().map(|s| s.id).collect::<Vec<_>>(),
                       b.iter().map(|s| s.id).collect::<Vec<_>>());
        }
    }

    #[test]
    fn scan_survives_scratch_already_borrowed() {
        let n = if cfg!(miri) { 40 } else { 120 };
        let emb = random_matrix(n, 16, 9);
        let r = DenseExact::new(emb);
        let mut rng = Rng::new(10);
        let qs: Vec<SpecQuery> = (0..4)
            .map(|_| SpecQuery::dense_only(rng.unit_vector(16)))
            .collect();
        let plain = r.retrieve_batch(&qs, 5);
        // Reentrancy: the thread-local pack buffer is held across the
        // retrieval, forcing the fresh-allocation fallback. Must not
        // panic, and must score identically (scratch is capacity-only).
        let held = QT_SCRATCH.with(|cell| {
            let _guard = cell.borrow_mut();
            r.retrieve_batch(&qs, 5)
        });
        assert_eq!(plain, held);
    }

    #[test]
    fn retrieving_own_embedding_returns_self() {
        let emb = random_matrix(200, 24, 6);
        let r = DenseExact::new(emb.clone());
        for i in [0u32, 57, 199] {
            let q = SpecQuery::dense_only(emb.row(i).to_vec());
            assert_eq!(r.retrieve(&q).unwrap().id, i);
        }
    }

    #[test]
    fn score_doc_consistent_with_ranking() {
        let emb = random_matrix(100, 8, 7);
        let r = DenseExact::new(emb);
        let mut rng = Rng::new(8);
        let q = SpecQuery::dense_only(rng.unit_vector(8));
        let top = r.retrieve_topk(&q, 10);
        for w in top.windows(2) {
            // score_doc uses the unrolled kernel; ranking must agree with
            // the lane kernel up to FP noise.
            assert!(r.score_doc(&q, w[0].id)
                        >= r.score_doc(&q, w[1].id) - 1e-5);
        }
        assert!((top[0].score - r.score_doc(&q, top[0].id)).abs() < 1e-5);
    }
}
