//! Exact dense retriever ("EDR"): brute-force inner-product top-k over the
//! corpus embedding matrix — the FAISS `IndexFlatIP` role in the paper.
//!
//! The scan is doc-major so each corpus row is read exactly once per batch:
//! batched retrieval (the verification step) amortizes the full memory pass
//! over all queries, which is why total batched latency is near-constant in
//! batch size (paper Fig 6a) — the effect RaLMSpec's saving rests on.
//!
//! ## SQ8 two-phase scan (DESIGN.md ADR-010)
//!
//! With `dense.codec = sq8` the scan is two-phase: phase 1 streams 1-byte
//! scalar-quantized row codes (4x the row density of f32, so a
//! memory-bandwidth-bound scan moves 4x fewer bytes) through the exact
//! integer kernel [`kernels::scan_i8`] and keeps every row whose score
//! **upper bound** reaches the running `prune_k`-th best **exact** score;
//! phase 2 re-scores survivors from the full-precision rows with
//! [`kernels::rescore_dot`], whose operation order reproduces
//! [`kernels::scan_block`]'s per-lane bits. Because the bound is
//! conservative (quantization error + f32 evaluation error, evaluated in
//! f64), a pruned row provably cannot be in the true top-k, so the final
//! `(score desc, id asc)` top-k is **bit-identical** to the full-precision
//! scan — pinned by tests/quantized_equivalence.rs.

use super::kernels::{self, LANES, SQ8_QMAX};
use super::{DocId, Retriever, SpecQuery};
use crate::util::{Scored, TopK};
use std::cell::RefCell;
use std::sync::Arc;

/// Row-major [n, dim] embedding matrix shared across retrievers/caches.
#[derive(Debug)]
pub struct EmbeddingMatrix {
    pub dim: usize,
    pub data: Vec<f32>,
}

impl EmbeddingMatrix {
    pub fn new(dim: usize, data: Vec<f32>) -> Self {
        assert!(dim > 0 && data.len() % dim == 0,
                "embedding matrix shape mismatch");
        Self { dim, data }
    }

    #[inline]
    pub fn row(&self, i: DocId) -> &[f32] {
        let d = self.dim;
        &self.data[i as usize * d..(i as usize + 1) * d]
    }

    pub fn len(&self) -> usize {
        self.data.len() / self.dim
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

/// Inner product over the (fixed, small) retrieval dimension — the EDR
/// hot loop. Delegates to the shared scoring kernel
/// ([`kernels::dot`], DESIGN.md ADR-007) so every caller (flat-scan
/// `score_doc`, the HNSW walk, the KNN-LM cache) shares one reduction
/// order with the SIMD forms; kept under its historical name because
/// call sites predate the kernels module.
#[inline]
pub fn dot_chunked(a: &[f32], b: &[f32]) -> f32 {
    kernels::dot(a, b)
}

// ---------------------------------------------------------------------------
// SQ8 scalar-quantized codec (DESIGN.md ADR-010)
// ---------------------------------------------------------------------------

/// Default candidate-oversample factor for the SQ8 two-phase scan: the
/// pruning threshold tracks the `max(k, ceil(k * oversample))`-th best
/// exact score instead of the k-th, a safety margin that admits more
/// borderline rows to the exact re-score. Correctness never depends on
/// it (the bound alone is sufficient); it only trades re-score work
/// against pruning aggressiveness.
pub const DEFAULT_SQ8_OVERSAMPLE: f64 = 2.0;

/// Relative inflation applied to every stored/derived bound quantity so
/// f64-evaluation rounding (a handful of operations, each within
/// `2^-52` relative) can never make a bound optimistic.
const BOUND_SLACK: f64 = 1e-9;

/// Per-row scalar quantization of a row-major f32 matrix: row `r` is
/// stored as u8 codes `c` with `x̂[j] = scale[r]·c[j] + bias[r]`
/// (`bias` = row min, `scale` = row range / 255), plus the two per-row
/// bound ingredients the two-phase scan needs: `rerr[r] =
/// max_j |x[j] − x̂[j]|` (reconstruction error, rounded up) and
/// `asum[r] = Σ_j |x̂[j]|` (rounded up). The same struct backs the
/// in-RAM codec and the `DENSE_SQ8` segment section (docs/FORMAT.md).
#[derive(Debug)]
pub struct Sq8Rows {
    pub dim: usize,
    pub scale: Vec<f32>,
    pub bias: Vec<f32>,
    pub asum: Vec<f32>,
    pub rerr: Vec<f32>,
    pub codes: Vec<u8>,
}

/// Borrowed view of SQ8 row blocks — the common shape of [`Sq8Rows`]
/// slices and mmap'd `DENSE_SQ8` segment sections.
#[derive(Clone, Copy)]
pub struct Sq8RowsRef<'a> {
    pub scale: &'a [f32],
    pub bias: &'a [f32],
    pub asum: &'a [f32],
    pub rerr: &'a [f32],
    pub codes: &'a [u8],
}

impl Sq8Rows {
    /// Quantize `n = rows.len() / dim` row-major f32 rows. All bound
    /// arithmetic runs in f64 against the *stored* f32 scale/bias (the
    /// values the scan will use), so `rerr`/`asum` bound exactly the
    /// reconstruction the scan reasons about.
    pub fn encode(rows: &[f32], dim: usize) -> Self {
        assert!(dim > 0 && rows.len() % dim == 0, "sq8 shape mismatch");
        let n = rows.len() / dim;
        let mut out = Self {
            dim,
            scale: Vec::with_capacity(n),
            bias: Vec::with_capacity(n),
            asum: Vec::with_capacity(n),
            rerr: Vec::with_capacity(n),
            codes: Vec::with_capacity(n * dim),
        };
        for row in rows.chunks_exact(dim) {
            out.push_row(row);
        }
        out
    }

    /// Quantize and append one row (the memtable-freeze path encodes
    /// incrementally).
    pub fn push_row(&mut self, row: &[f32]) {
        assert_eq!(row.len(), self.dim, "sq8 row dim mismatch");
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for &x in row {
            lo = lo.min(x);
            hi = hi.max(x);
        }
        let scale = ((hi as f64 - lo as f64) / 255.0) as f32;
        let bias = lo;
        let (sf, bf) = (scale as f64, bias as f64);
        let mut rerr = 0.0f64;
        let mut asum = 0.0f64;
        for &x in row {
            let c = if sf > 0.0 {
                (((x as f64 - bf) / sf).round()).clamp(0.0, 255.0) as u8
            } else {
                0u8
            };
            self.codes.push(c);
            // Reconstruction in f64: `sf * c` is exact (24-bit f32
            // mantissa × 8-bit code fits in 53 bits), `+ bf` rounds once
            // within 2^-52 — absorbed by BOUND_SLACK below.
            let recon = sf * c as f64 + bf;
            rerr = rerr.max((x as f64 - recon).abs());
            asum += recon.abs();
        }
        self.scale.push(scale);
        self.bias.push(bias);
        // Round the bound ingredients *up* past both the f64 summation
        // slop and the f64→f32 store rounding.
        self.rerr.push((rerr * (1.0 + 1e-6)) as f32);
        self.asum.push((asum * (1.0 + 1e-6)) as f32);
    }

    pub fn len(&self) -> usize {
        self.scale.len()
    }

    pub fn is_empty(&self) -> bool {
        self.scale.is_empty()
    }

    /// Borrow rows `[lo, hi)` (the shard-view primitive).
    pub fn slice(&self, lo: usize, hi: usize) -> Sq8RowsRef<'_> {
        Sq8RowsRef {
            scale: &self.scale[lo..hi],
            bias: &self.bias[lo..hi],
            asum: &self.asum[lo..hi],
            rerr: &self.rerr[lo..hi],
            codes: &self.codes[lo * self.dim..hi * self.dim],
        }
    }
}

/// A query quantized for the SQ8 phase-1 scan: symmetric signed codes
/// `qc[j] = round(q[j] / qscale)` in `[-SQ8_QMAX, SQ8_QMAX]` (so the
/// integer kernel is saturation-free, see [`SQ8_QMAX`]), plus the
/// query-side bound ingredients. With symmetric quantization
/// `q̂[j] = qscale·qc[j]`, so the approximate score recovers from the
/// integer dot as `qscale·scale·Σqc·c + qscale·bias·Σqc` — two exact
/// integer sums scaled in f64.
pub struct Sq8Query {
    pub codes: Vec<i8>,
    /// Σ qc[j] — exact.
    pub qcsum: i64,
    pub qscale: f64,
    /// max_j |q[j] − q̂[j]|, rounded up.
    pub qerr: f64,
    /// Σ_j |q[j]|, rounded up.
    pub qnorm1: f64,
    /// max_j |q[j]|.
    pub qmaxabs: f64,
}

impl Sq8Query {
    pub fn new(q: &[f32]) -> Self {
        let mut qmaxabs = 0.0f64;
        let mut qnorm1 = 0.0f64;
        for &v in q {
            qmaxabs = qmaxabs.max((v as f64).abs());
            qnorm1 += (v as f64).abs();
        }
        let qscale =
            if qmaxabs > 0.0 { qmaxabs / SQ8_QMAX as f64 } else { 0.0 };
        let mut codes = Vec::with_capacity(q.len());
        let mut qcsum = 0i64;
        let mut qerr = 0.0f64;
        for &v in q {
            let c = if qscale > 0.0 {
                ((v as f64 / qscale).round())
                    .clamp(-(SQ8_QMAX as f64), SQ8_QMAX as f64)
                    as i64
            } else {
                0i64
            };
            codes.push(c as i8);
            qcsum += c;
            qerr = qerr.max((v as f64 - qscale * c as f64).abs());
        }
        Self {
            codes,
            qcsum,
            qscale,
            qerr: qerr * (1.0 + BOUND_SLACK),
            qnorm1: qnorm1 * (1.0 + BOUND_SLACK),
            qmaxabs,
        }
    }
}

/// Deterministic fixed-capacity f64 min-heap tracking the `cap` largest
/// values pushed so far — the running pruning threshold of the two-phase
/// scan (`root()` = the `cap`-th best exact score seen, `None` until
/// `cap` values arrived). Ordering is `f64::total_cmp`; NaN never enters
/// (scores of finite inputs are finite).
pub(crate) struct MinF64Heap {
    cap: usize,
    vals: Vec<f64>,
}

impl MinF64Heap {
    pub fn new(cap: usize) -> Self {
        Self { cap: cap.max(1), vals: Vec::with_capacity(cap.max(1)) }
    }

    /// The current threshold: the smallest of the kept values, only once
    /// the heap is full (pruning before that could drop a top-k row).
    #[inline]
    pub fn root(&self) -> Option<f64> {
        if self.vals.len() == self.cap { Some(self.vals[0]) } else { None }
    }

    pub fn push(&mut self, v: f64) {
        if self.vals.len() < self.cap {
            self.vals.push(v);
            let mut i = self.vals.len() - 1;
            while i > 0 {
                let p = (i - 1) / 2;
                if self.vals[i].total_cmp(&self.vals[p]).is_lt() {
                    self.vals.swap(i, p);
                    i = p;
                } else {
                    break;
                }
            }
        } else if v.total_cmp(&self.vals[0]).is_gt() {
            self.vals[0] = v;
            let mut i = 0usize;
            loop {
                let (l, r) = (2 * i + 1, 2 * i + 2);
                let mut m = i;
                if l < self.vals.len()
                    && self.vals[l].total_cmp(&self.vals[m]).is_lt()
                {
                    m = l;
                }
                if r < self.vals.len()
                    && self.vals[r].total_cmp(&self.vals[m]).is_lt()
                {
                    m = r;
                }
                if m == i {
                    break;
                }
                self.vals.swap(i, m);
                i = m;
            }
        }
    }
}

/// The pruning heap size: `max(k, ceil(k * oversample))`.
pub(crate) fn sq8_prune_k(k: usize, oversample: f64) -> usize {
    let os = if oversample.is_finite() && oversample > 1.0 {
        (k as f64 * oversample).ceil() as usize
    } else {
        k
    };
    os.max(k).max(1)
}

/// Phase-1 chunk size (rows): bounds the integer-score scratch and lets
/// the pruning threshold tighten between chunks.
const SQ8_CHUNK_ROWS: usize = 1024;

/// Two-phase SQ8 scan of one row block for one query, pushing exact
/// scores (bit-identical to the full-precision scan's, see the module
/// docs) of surviving rows into `heap`. `full` holds the same rows at
/// full precision; `prune` carries the running threshold across blocks
/// (tiers, segments) of the same query; `idot` is reusable scratch.
///
/// Safety of pruning (the ADR-010 argument, checked in f64): for row `r`
/// with exact integer dot `I`, the real dot `q̂·x̂ = qscale·scale_r·I +
/// qscale·bias_r·Σqc`. The true real dot differs from it by at most
/// `rerr_r·‖q‖₁ + qerr·Σ|x̂|`, and the f32-evaluated score differs from
/// the true real dot by at most `~d·ε₃₂·max|q|·Σ|x|`. `ub` adds all
/// three (inflated by `BOUND_SLACK` for the f64 evaluation itself), so
/// `score(r) ≤ ub(r)`. A row is pruned only when `ub(r) < t` where `t`
/// is the `prune_k`-th best *exact* score already in `prune` — i.e. at
/// least `prune_k ≥ k` distinct rows score strictly above row `r`, so
/// `r` cannot enter the `(score desc, id asc)` top-k for any tie-break.
pub(crate) fn scan_sq8_rows(sq8: Sq8RowsRef<'_>, dim: usize, full: &[f32],
                            base: DocId, q: &[f32], qq: &Sq8Query,
                            prune: &mut MinF64Heap, heap: &mut TopK,
                            idot: &mut Vec<i32>) {
    let n = sq8.scale.len();
    debug_assert_eq!(sq8.codes.len(), n * dim);
    debug_assert_eq!(full.len(), n * dim);
    debug_assert_eq!(q.len(), dim);
    let d64 = dim as f64;
    // One ε₃₂ covers each of the ≤ d roundings of the sequential f32
    // re-score; the factor 2 and BOUND_SLACK are margin.
    let feval = 2.0 * d64 * (f32::EPSILON as f64) * qq.qmaxabs;
    let mut row = 0usize;
    while row < n {
        let chunk = SQ8_CHUNK_ROWS.min(n - row);
        idot.clear();
        idot.resize(chunk, 0);
        kernels::scan_i8(&sq8.codes[row * dim..(row + chunk) * dim], dim,
                         &qq.codes, idot);
        for i in 0..chunk {
            let r = row + i;
            let (sf, bf) = (sq8.scale[r] as f64, sq8.bias[r] as f64);
            let (re, asum) = (sq8.rerr[r] as f64, sq8.asum[r] as f64);
            let approx = qq.qscale * sf * idot[i] as f64
                + qq.qscale * bf * qq.qcsum as f64;
            let err = (re * qq.qnorm1 + qq.qerr * asum
                       + feval * (asum + d64 * re))
                * (1.0 + BOUND_SLACK)
                + approx.abs() * 1e-12
                + f64::MIN_POSITIVE;
            let ub = approx + err;
            if let Some(t) = prune.root() {
                if ub < t {
                    continue;
                }
            }
            let exact =
                kernels::rescore_dot(&full[r * dim..(r + 1) * dim], q);
            heap.push(base + r as DocId, exact);
            prune.push(exact as f64);
        }
        row += chunk;
    }
}

pub struct DenseExact {
    emb: Arc<EmbeddingMatrix>,
    sq8: Option<Arc<Sq8Index>>,
}

/// The quantized companion of an embedding matrix plus its scan knob —
/// shared (one `Arc`) between a [`DenseExact`] and its shard views so
/// re-sharding never re-encodes.
pub struct Sq8Index {
    pub rows: Sq8Rows,
    pub oversample: f64,
}

impl Sq8Index {
    pub fn encode(emb: &EmbeddingMatrix, oversample: f64) -> Self {
        Self { rows: Sq8Rows::encode(&emb.data, emb.dim), oversample }
    }
}

impl DenseExact {
    pub fn new(emb: Arc<EmbeddingMatrix>) -> Self {
        Self { emb, sq8: None }
    }

    /// EDR with the SQ8 codec: scans quantized codes first and re-scores
    /// survivors, bit-identical to [`DenseExact::new`]'s output
    /// (tests/quantized_equivalence.rs).
    pub fn with_sq8(emb: Arc<EmbeddingMatrix>, oversample: f64) -> Self {
        let sq8 = Arc::new(Sq8Index::encode(&emb, oversample));
        Self { emb, sq8: Some(sq8) }
    }

    pub fn embeddings(&self) -> &Arc<EmbeddingMatrix> {
        &self.emb
    }

    pub(crate) fn sq8(&self) -> Option<&Arc<Sq8Index>> {
        self.sq8.as_ref()
    }
}

thread_local! {
    /// Reusable column-major query-pack buffer for [`scan_multi_range`]:
    /// the per-block `vec![0.0; d * LANES]` allocation hoisted out of the
    /// scan and reused across blocks, batches, and engine flushes on the
    /// same thread (KB calls run on the persistent worker pool, so the
    /// buffer stays warm for the life of the process).
    static QT_SCRATCH: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
}

/// Scan rows `[lo, hi)` of the matrix, pushing **global** doc ids into the
/// per-query heaps. The full-corpus scan is the `(0, len)` range; shard
/// views scan their slice. Per-row arithmetic is identical regardless of
/// the range, so a k-way merge of shard results is bit-identical to the
/// full scan (the property `ShardedRetriever` relies on).
///
/// Queries are processed in blocks of up to [`LANES`], packed column-major
/// (`qt[j*LANES + lane]`) so each corpus row is loaded once and scored
/// LANES-wide by [`kernels::scan_block`]; per-row arithmetic intensity
/// rises from 2 FLOP/byte (single query) to 2·B FLOP/byte — this is what
/// makes batched verification near-free for EDR (paper Fig 6a / §A.1).
pub(crate) fn scan_multi_range(emb: &EmbeddingMatrix, lo: usize, hi: usize,
                               queries: &[&[f32]], heaps: &mut [TopK]) {
    with_pack_scratch(|qt| {
        scan_multi_range_with(emb, lo, hi, queries, heaps, qt);
    });
}

/// Run `f` against this thread's query-pack scratch buffer, with the
/// reentrancy guard: if a caller somewhere up the stack already holds
/// this thread's scratch (e.g. a retriever wrapper that scans inside a
/// scratch-borrowing callback), borrow_mut() would panic — fall back to
/// a fresh buffer instead. The scratch only caches capacity, so results
/// are identical either way. Shared with the segment tier's scanner
/// (`retriever::segment`), which packs through the same buffer.
pub(crate) fn with_pack_scratch<R>(f: impl FnOnce(&mut Vec<f32>) -> R) -> R {
    QT_SCRATCH.with(|cell| match cell.try_borrow_mut() {
        Ok(mut qt) => f(&mut qt),
        Err(_) => f(&mut Vec::new()),
    })
}

/// [`scan_multi_range`] with a caller-provided query-pack scratch buffer
/// (grown on demand, cleared and re-packed per block, never shrunk).
pub(crate) fn scan_multi_range_with(emb: &EmbeddingMatrix, lo: usize,
                                    hi: usize, queries: &[&[f32]],
                                    heaps: &mut [TopK], qt: &mut Vec<f32>) {
    debug_assert!(lo <= hi && hi <= emb.len());
    let d = emb.dim;
    scan_rows_with(&emb.data[lo * d..hi * d], d, lo as DocId, queries,
                   heaps, qt);
}

/// Scan raw row-major rows (`data.len()` must be a multiple of `dim`),
/// pushing ids offset by `base` into the per-query heaps. This is the
/// layout-agnostic core of the EDR scan: the in-RAM matrix path above
/// slices into it, and the segment tier (`retriever::segment`) feeds it
/// `f32` views over mmap'd section bytes — one numeric code path, so
/// segment-backed and in-RAM retrieval are bit-identical by construction.
pub(crate) fn scan_rows_with(data: &[f32], dim: usize, base: DocId,
                             queries: &[&[f32]], heaps: &mut [TopK],
                             qt: &mut Vec<f32>) {
    debug_assert_eq!(queries.len(), heaps.len());
    debug_assert_eq!(data.len() % dim.max(1), 0);
    for (block_start, qblock) in (0..queries.len())
        .step_by(LANES)
        .zip(queries.chunks(LANES))
    {
        let b = qblock.len();
        // Column-major packed query block, zero-padded to LANES.
        qt.clear();
        qt.resize(dim * LANES, 0.0);
        for (bi, q) in qblock.iter().enumerate() {
            for (j, &v) in q.iter().enumerate() {
                qt[j * LANES + bi] = v;
            }
        }
        kernels::scan_block(data, dim, base, qt,
                            &mut heaps[block_start..block_start + b]);
    }
}

/// Range-restricted batched top-k (shared by [`DenseExact`] and
/// [`DenseShard`]). With an SQ8 index the scan runs two-phase per query
/// (per-query pruning thresholds rule out the LANES-packed pass); the
/// output is bit-identical either way (module docs).
fn batch_over_range(emb: &EmbeddingMatrix, lo: usize, hi: usize,
                    qs: &[SpecQuery], k: usize, sq8: Option<&Sq8Index>)
                    -> Vec<Vec<Scored>> {
    for q in qs {
        assert_eq!(q.dense.len(), emb.dim, "query dim mismatch");
    }
    let mut heaps: Vec<TopK> = qs.iter().map(|_| TopK::new(k.max(1))).collect();
    if let Some(ix) = sq8 {
        let d = emb.dim;
        let view = ix.rows.slice(lo, hi);
        let full = &emb.data[lo * d..hi * d];
        let prune_cap = sq8_prune_k(k.max(1), ix.oversample);
        let mut idot = Vec::new();
        for (q, heap) in qs.iter().zip(&mut heaps) {
            let qq = Sq8Query::new(&q.dense);
            let mut prune = MinF64Heap::new(prune_cap);
            scan_sq8_rows(view, d, full, lo as DocId, &q.dense, &qq,
                          &mut prune, heap, &mut idot);
        }
    } else {
        let qrefs: Vec<&[f32]> =
            qs.iter().map(|q| q.dense.as_slice()).collect();
        scan_multi_range(emb, lo, hi, &qrefs, &mut heaps);
    }
    heaps.into_iter().map(|h| h.into_sorted()).collect()
}

impl Retriever for DenseExact {
    // NOTE: retrieve_topk is intentionally NOT overridden — it derives
    // from the batch of one, so both paths share the lane kernel's
    // operation order. (Found the hard way — a 4-accumulator single-query
    // kernel rounds differently from the lane kernel and occasionally
    // flips a near-tied top-1.)
    fn retrieve_batch(&self, qs: &[SpecQuery], k: usize) -> Vec<Vec<Scored>> {
        // One pass over the corpus for the whole batch: read each row once,
        // score it against every query (blocked multi-query kernel). This
        // is the batched-verification primitive whose near-constant total
        // cost drives RaLMSpec.
        batch_over_range(&self.emb, 0, self.emb.len(), qs, k,
                         self.sq8.as_deref())
    }

    fn score_doc(&self, q: &SpecQuery, doc: DocId) -> f32 {
        dot_chunked(&q.dense, self.emb.row(doc))
    }

    fn len(&self) -> usize {
        self.emb.len()
    }

    fn name(&self) -> &'static str {
        "EDR(flat)"
    }
}

/// A contiguous-row shard view over a shared embedding matrix: scans only
/// `[lo, hi)` but reports global doc ids, so merged shard results are
/// bit-identical to the unsharded scan.
pub struct DenseShard {
    emb: Arc<EmbeddingMatrix>,
    lo: usize,
    hi: usize,
    sq8: Option<Arc<Sq8Index>>,
}

impl DenseShard {
    pub fn new(emb: Arc<EmbeddingMatrix>, lo: usize, hi: usize) -> Self {
        Self::with_sq8(emb, lo, hi, None)
    }

    /// Shard view carrying the parent's codec (shared `Arc`, so shard
    /// construction stays allocation-light — no re-encode).
    pub(crate) fn with_sq8(emb: Arc<EmbeddingMatrix>, lo: usize, hi: usize,
                           sq8: Option<Arc<Sq8Index>>) -> Self {
        assert!(lo <= hi && hi <= emb.len(), "shard bounds out of range");
        Self { emb, lo, hi, sq8 }
    }
}

impl Retriever for DenseShard {
    fn retrieve_batch(&self, qs: &[SpecQuery], k: usize) -> Vec<Vec<Scored>> {
        batch_over_range(&self.emb, self.lo, self.hi, qs, k,
                         self.sq8.as_deref())
    }

    fn score_doc(&self, q: &SpecQuery, doc: DocId) -> f32 {
        dot_chunked(&q.dense, self.emb.row(doc))
    }

    fn len(&self) -> usize {
        self.hi - self.lo
    }

    fn name(&self) -> &'static str {
        "EDR(flat-shard)"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_matrix(n: usize, d: usize, seed: u64) -> Arc<EmbeddingMatrix> {
        let mut rng = Rng::new(seed);
        let mut data = Vec::with_capacity(n * d);
        for _ in 0..n {
            data.extend(rng.unit_vector(d));
        }
        Arc::new(EmbeddingMatrix::new(d, data))
    }

    #[test]
    fn dot_chunked_matches_naive() {
        let mut rng = Rng::new(1);
        for n in [1usize, 7, 8, 17, 64, 100] {
            let a: Vec<f32> = (0..n).map(|_| rng.next_f32() - 0.5).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.next_f32() - 0.5).collect();
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot_chunked(&a, &b) - naive).abs() < 1e-4, "n={n}");
        }
    }

    #[test]
    fn top1_is_true_argmax() {
        let emb = random_matrix(500, 32, 2);
        let r = DenseExact::new(emb.clone());
        let mut rng = Rng::new(3);
        for _ in 0..20 {
            let q = SpecQuery::dense_only(rng.unit_vector(32));
            let got = r.retrieve(&q).unwrap();
            let mut best = (0u32, f32::NEG_INFINITY);
            for i in 0..emb.len() {
                let s = dot_chunked(&q.dense, emb.row(i as u32));
                if s > best.1 {
                    best = (i as u32, s);
                }
            }
            assert_eq!(got.id, best.0);
        }
    }

    #[test]
    fn batch_matches_sequential() {
        let emb = random_matrix(300, 16, 4);
        let r = DenseExact::new(emb);
        let mut rng = Rng::new(5);
        let qs: Vec<SpecQuery> =
            (0..6).map(|_| SpecQuery::dense_only(rng.unit_vector(16))).collect();
        let batch = r.retrieve_batch(&qs, 5);
        for (q, b) in qs.iter().zip(&batch) {
            let seq = r.retrieve_topk(q, 5);
            assert_eq!(seq.iter().map(|s| s.id).collect::<Vec<_>>(),
                       b.iter().map(|s| s.id).collect::<Vec<_>>());
        }
    }

    #[test]
    fn scan_survives_scratch_already_borrowed() {
        let n = if cfg!(miri) { 40 } else { 120 };
        let emb = random_matrix(n, 16, 9);
        let r = DenseExact::new(emb);
        let mut rng = Rng::new(10);
        let qs: Vec<SpecQuery> = (0..4)
            .map(|_| SpecQuery::dense_only(rng.unit_vector(16)))
            .collect();
        let plain = r.retrieve_batch(&qs, 5);
        // Reentrancy: the thread-local pack buffer is held across the
        // retrieval, forcing the fresh-allocation fallback. Must not
        // panic, and must score identically (scratch is capacity-only).
        let held = QT_SCRATCH.with(|cell| {
            let _guard = cell.borrow_mut();
            r.retrieve_batch(&qs, 5)
        });
        assert_eq!(plain, held);
    }

    /// Bit-compare two batched retrievals (ids and score bits).
    fn assert_bitwise_eq(a: &[Vec<Scored>], b: &[Vec<Scored>]) {
        assert_eq!(a.len(), b.len());
        for (qa, qb) in a.iter().zip(b) {
            assert_eq!(qa.len(), qb.len());
            for (x, y) in qa.iter().zip(qb) {
                assert_eq!(x.id, y.id);
                assert_eq!(x.score.to_bits(), y.score.to_bits());
            }
        }
    }

    #[test]
    fn sq8_bounds_hold_for_every_row() {
        let emb = random_matrix(if cfg!(miri) { 20 } else { 200 }, 24, 11);
        let sq8 = Sq8Rows::encode(&emb.data, emb.dim);
        for r in 0..emb.len() {
            let row = emb.row(r as u32);
            let (sf, bf) = (sq8.scale[r] as f64, sq8.bias[r] as f64);
            let mut asum = 0.0f64;
            for (j, &x) in row.iter().enumerate() {
                let recon = sf * sq8.codes[r * emb.dim + j] as f64 + bf;
                assert!((x as f64 - recon).abs() <= sq8.rerr[r] as f64,
                        "row {r} coord {j}: |x - x̂| exceeds stored rerr");
                asum += recon.abs();
            }
            assert!(asum <= sq8.asum[r] as f64,
                    "row {r}: Σ|x̂| exceeds stored asum");
        }
    }

    #[test]
    fn sq8_constant_and_zero_rows_encode_safely() {
        // Constant row (range 0 → scale 0) and all-zero row: codes are 0,
        // reconstruction is the bias, rerr stays ~0.
        let d = 8;
        let mut data = vec![0.25f32; d];
        data.extend(vec![0.0f32; d]);
        let sq8 = Sq8Rows::encode(&data, d);
        assert_eq!(sq8.scale[0], 0.0);
        assert!(sq8.rerr[0] <= 1e-6);
        assert_eq!(sq8.bias[1], 0.0);
        assert_eq!(&sq8.codes[d..2 * d], &[0u8; 8]);
        // Zero query: every bound degenerates but nothing divides by 0.
        let qq = Sq8Query::new(&vec![0.0f32; d]);
        assert_eq!(qq.qscale, 0.0);
        assert!(qq.codes.iter().all(|&c| c == 0));
    }

    #[test]
    fn sq8_query_codes_within_qmax() {
        let mut rng = Rng::new(12);
        for d in [7usize, 64] {
            let q: Vec<f32> =
                (0..d).map(|_| (rng.next_f32() - 0.5) * 10.0).collect();
            let qq = Sq8Query::new(&q);
            for (j, &c) in qq.codes.iter().enumerate() {
                assert!((c as i32).abs() <= kernels::SQ8_QMAX, "j={j}");
                assert!((q[j] as f64 - qq.qscale * c as f64).abs()
                            <= qq.qerr,
                        "j={j}");
            }
        }
    }

    #[test]
    fn sq8_min_heap_tracks_kth_largest() {
        let mut h = MinF64Heap::new(3);
        assert_eq!(h.root(), None);
        for v in [5.0, 1.0, 3.0] {
            h.push(v);
        }
        assert_eq!(h.root(), Some(1.0));
        h.push(4.0); // evicts 1.0
        assert_eq!(h.root(), Some(3.0));
        h.push(0.5); // below root: ignored
        assert_eq!(h.root(), Some(3.0));
    }

    #[test]
    fn sq8_two_phase_matches_full_bitwise() {
        let n = if cfg!(miri) { 60 } else { 400 };
        for (d, seed) in [(16usize, 21u64), (24, 22), (64, 23)] {
            let emb = random_matrix(n, d, seed);
            let full = DenseExact::new(emb.clone());
            let mut rng = Rng::new(seed + 100);
            let qs: Vec<SpecQuery> = (0..5)
                .map(|_| SpecQuery::dense_only(rng.unit_vector(d)))
                .collect();
            for k in [1usize, 5, 17] {
                let want = full.retrieve_batch(&qs, k);
                for os in [1.0f64, 2.0, 8.0] {
                    let q8 = DenseExact::with_sq8(emb.clone(), os);
                    assert_bitwise_eq(&q8.retrieve_batch(&qs, k), &want);
                }
            }
        }
    }

    #[test]
    fn sq8_sharded_views_match_full_shards_bitwise() {
        let n = if cfg!(miri) { 50 } else { 300 };
        let emb = random_matrix(n, 16, 31);
        let full = Arc::new(DenseExact::new(emb.clone()));
        let q8 = Arc::new(DenseExact::with_sq8(emb, 2.0));
        let mut rng = Rng::new(32);
        let qs: Vec<SpecQuery> = (0..4)
            .map(|_| SpecQuery::dense_only(rng.unit_vector(16)))
            .collect();
        use crate::retriever::sharded::Shardable;
        for shards in [2usize, 3] {
            let fs = <DenseExact as Shardable>::make_shards(&full, shards);
            let q8s = <DenseExact as Shardable>::make_shards(&q8, shards);
            for (f, q8shard) in fs.iter().zip(&q8s) {
                assert_bitwise_eq(&q8shard.retrieve_batch(&qs, 6),
                                  &f.retrieve_batch(&qs, 6));
            }
        }
    }

    #[test]
    fn retrieving_own_embedding_returns_self() {
        let emb = random_matrix(200, 24, 6);
        let r = DenseExact::new(emb.clone());
        for i in [0u32, 57, 199] {
            let q = SpecQuery::dense_only(emb.row(i).to_vec());
            assert_eq!(r.retrieve(&q).unwrap().id, i);
        }
    }

    #[test]
    fn score_doc_consistent_with_ranking() {
        let emb = random_matrix(100, 8, 7);
        let r = DenseExact::new(emb);
        let mut rng = Rng::new(8);
        let q = SpecQuery::dense_only(rng.unit_vector(8));
        let top = r.retrieve_topk(&q, 10);
        for w in top.windows(2) {
            // score_doc uses the unrolled kernel; ranking must agree with
            // the lane kernel up to FP noise.
            assert!(r.score_doc(&q, w[0].id)
                        >= r.score_doc(&q, w[1].id) - 1e-5);
        }
        assert!((top[0].score - r.score_doc(&q, top[0].id)).abs() < 1e-5);
    }
}
