//! Approximate dense retriever ("ADR"): Hierarchical Navigable Small World
//! graphs (Malkov & Yashunin), built from scratch over the same embedding
//! matrix as the exact scan — the DPR-HNSW role in the paper.
//!
//! Similarity = inner product (vectors are unit-norm, so this is cosine),
//! computed through the shared scoring kernel ([`super::kernels::dot`]) so
//! the walk scores with the same reduction order as every other path.
//! Search cost is per-query (a graph walk), so batched retrieval scales
//! linearly in batch size with a fixed per-call intercept — exactly the
//! ADR latency profile of paper Fig 6b.
//!
//! Adjacency lives in one of two forms (DESIGN.md ADR-007): a **nested**
//! `Vec<Vec<Vec<u32>>>` while the graph is under construction (cheap
//! push/rewire during insertion) and a per-level **flat CSR** layout
//! (offsets + packed neighbor array) once sealed — one cache line fetch
//! per neighbor list instead of two pointer hops, plus software prefetch
//! of neighbor embedding rows during the walk. [`Hnsw::build`] returns a
//! sealed graph; [`Hnsw::append`] thaws back to the nested form (the
//! mutable tail) and [`Hnsw::seal`] recompacts — the epoch layer seals
//! each published snapshot, so serving always reads CSR. The two forms
//! store byte-identical neighbor lists, so searches are bit-identical in
//! either (pinned by `csr_matches_nested_search`).
//!
//! Determinism: node levels come from a per-id seeded RNG and neighbor
//! lists are order-stable, so the index (and thus every experiment) is
//! reproducible bit-for-bit.

use super::kernels;
use super::{DocId, Retriever, SpecQuery};
use crate::util::{Rng, Scored};
use std::cell::RefCell;
use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::sync::Arc;

#[derive(Debug, Clone, Copy)]
struct Cand {
    id: u32,
    score: f32,
}

impl PartialEq for Cand {
    fn eq(&self, other: &Self) -> bool {
        self.score == other.score && self.id == other.id
    }
}
impl Eq for Cand {}
impl Ord for Cand {
    fn cmp(&self, other: &Self) -> Ordering {
        // max-heap by score, ties toward lower id
        self.score
            .total_cmp(&other.score)
            .then(other.id.cmp(&self.id))
    }
}
impl PartialOrd for Cand {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Min-wrapper so a BinaryHeap<MinCand> pops the *worst* kept result.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct MinCand(Cand);
impl Ord for MinCand {
    fn cmp(&self, other: &Self) -> Ordering {
        other.0.cmp(&self.0)
    }
}
impl PartialOrd for MinCand {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// One level of the sealed graph in CSR form: node `v`'s neighbors are
/// `packed[offsets[v] .. offsets[v+1]]`. Nodes that don't reach this
/// level get an empty range, so `offsets` is always `n + 1` long and a
/// lookup is two loads into contiguous memory.
#[derive(Clone)]
struct CsrLevel {
    offsets: Vec<u32>,
    packed: Vec<u32>,
}

/// The sealed adjacency: one [`CsrLevel`] per graph layer plus the
/// per-node level count, retained so [`CsrGraph::to_nested`] can rebuild
/// the exact nested form (including empty lists at a node's top levels)
/// when the graph is thawed for appends.
#[derive(Clone)]
struct CsrGraph {
    /// node_levels[v] = number of layers node v participates in
    /// (its insertion level + 1).
    node_levels: Vec<u32>,
    levels: Vec<CsrLevel>,
}

impl CsrGraph {
    fn from_nested(nested: &[Vec<Vec<u32>>]) -> Self {
        let n = nested.len();
        let node_levels: Vec<u32> =
            nested.iter().map(|ls| ls.len() as u32).collect();
        let n_levels = nested.iter().map(|ls| ls.len()).max().unwrap_or(0);
        let mut levels = Vec::with_capacity(n_levels);
        for l in 0..n_levels {
            let mut offsets = Vec::with_capacity(n + 1);
            offsets.push(0u32);
            let mut total = 0u32;
            for ls in nested {
                if let Some(nb) = ls.get(l) {
                    total += nb.len() as u32;
                }
                offsets.push(total);
            }
            let mut packed = Vec::with_capacity(total as usize);
            for ls in nested {
                if let Some(nb) = ls.get(l) {
                    packed.extend_from_slice(nb);
                }
            }
            levels.push(CsrLevel { offsets, packed });
        }
        Self { node_levels, levels }
    }

    #[inline]
    fn neighbors(&self, v: u32, l: usize) -> &[u32] {
        match self.levels.get(l) {
            Some(lev) => {
                let lo = lev.offsets[v as usize] as usize;
                let hi = lev.offsets[v as usize + 1] as usize;
                &lev.packed[lo..hi]
            }
            None => &[],
        }
    }

    fn n_nodes(&self) -> usize {
        self.node_levels.len()
    }

    fn to_nested(&self) -> Vec<Vec<Vec<u32>>> {
        (0..self.n_nodes())
            .map(|v| {
                (0..self.node_levels[v] as usize)
                    .map(|l| self.neighbors(v as u32, l).to_vec())
                    .collect()
            })
            .collect()
    }
}

/// Adjacency storage: `Nested` while mutable (construction / the
/// append tail), `Csr` once sealed for serving.
#[derive(Clone)]
enum Adjacency {
    /// neighbors[node][level] -> neighbor ids.
    Nested(Vec<Vec<Vec<u32>>>),
    Csr(CsrGraph),
}

/// `Clone` so a live-update writer (`retriever::epoch::MutableHnsw`) can
/// keep a mutable master graph and publish immutable per-epoch snapshots;
/// the clone shares the embedding matrix (`Arc`) and copies only the
/// adjacency storage.
#[derive(Clone)]
pub struct Hnsw {
    emb: Arc<EmbeddingMatrix>,
    m: usize,
    m0: usize,
    ef_search: usize,
    /// Build-time parameters, retained so incremental inserts
    /// ([`Hnsw::append`]) extend the graph exactly as a from-scratch
    /// build over the larger matrix would.
    ef_construction: usize,
    seed: u64,
    entry: u32,
    max_level: usize,
    adj: Adjacency,
}

use super::dense::EmbeddingMatrix;

/// Reusable per-search working set: the generation-stamped visited pool
/// plus the candidate/result heap allocations and the sorted layer
/// output. A batched retrieval borrows one scratch for the whole batch
/// ("shared visited-pool reuse"), so every query after the first runs
/// against warm, correctly-sized buffers — the per-call intercept of the
/// ADR profile (Fig 6b) is paid once per batch instead of once per
/// query. And because KB calls run on the persistent worker pool, the
/// thread-local scratch survives across coalesced engine flushes too.
/// The search *algorithm* is untouched: per-query results are
/// bit-identical whatever the batch size (required by the
/// output-equivalence property, see pipeline_equivalence.rs).
#[derive(Default)]
struct SearchScratch {
    /// visited stamp per node; a node is visited iff stamps[n] == gen.
    stamps: Vec<u32>,
    gen: u32,
    /// Retired heap allocations (kept empty between searches).
    cand_buf: Vec<Cand>,
    result_buf: Vec<MinCand>,
    /// Layer-search output, best-first — overwritten by every
    /// `search_layer` call, consumed before the next.
    out: Vec<Cand>,
}

thread_local! {
    /// Scratch for single-shot searches (build-time inserts, derived
    /// single-query retrievals). Batched retrieval borrows it once.
    static SCRATCH: RefCell<SearchScratch> =
        RefCell::new(SearchScratch::default());
}

/// Node level for id `i`: per-id seeded, so the level assignment is a pure
/// function of (seed, id) — the property that makes incremental insertion
/// ([`Hnsw::append`]) reproduce the from-scratch build bit-for-bit.
fn level_for(seed: u64, i: usize, ml: f64) -> usize {
    // detlint: allow(nondet-source, reason = "per-id seeded level draw IS the determinism mechanism: level is a pure function of (seed, id)")
    let mut rng = Rng::new(seed ^ ((i as u64 + 1) * 0x517C_C1B7));
    let u = rng.next_f64().max(1e-12);
    ((-u.ln() * ml) as usize).min(12)
}

impl Hnsw {
    /// Build the graph by sequential insertion; the returned graph is
    /// sealed (CSR adjacency — see the module docs).
    pub fn build(emb: Arc<EmbeddingMatrix>, m: usize, ef_construction: usize,
                 ef_search: usize, seed: u64) -> Self {
        assert!(m >= 2);
        let n = emb.len();
        let ml = 1.0 / (m as f64).ln();
        let mut index = Self {
            emb,
            m,
            m0: 2 * m,
            ef_search,
            ef_construction,
            seed,
            entry: 0,
            max_level: 0,
            adj: Adjacency::Nested(Vec::with_capacity(n)),
        };
        for i in 0..n {
            index.insert(i as u32, level_for(seed, i, ml), ef_construction);
        }
        index.seal();
        index
    }

    /// Incremental insertion (live knowledge-base updates): swap in an
    /// extended embedding matrix whose rows `[len, emb.len())` are new
    /// documents and insert them one by one, reusing the same
    /// `SearchScratch` the batched search path shares. A sealed graph is
    /// thawed back to the nested (mutable-tail) form first and **stays**
    /// nested so consecutive appends pay the thaw once; call
    /// [`Hnsw::seal`] to recompact (the epoch layer does this for every
    /// published snapshot). Searches are valid — and bit-identical —
    /// in either form.
    ///
    /// Because node levels are a pure function of (seed, id) and `build`
    /// is itself sequential insertion in id order, the grown graph is
    /// **bit-identical** to `Hnsw::build` over the extended matrix with
    /// the same parameters — pinned by the `append_matches_fresh_build`
    /// test. That is what lets per-epoch ADR snapshots stay reproducible.
    pub fn append(&mut self, emb: Arc<EmbeddingMatrix>) {
        assert_eq!(emb.dim, self.emb.dim, "appended matrix dim mismatch");
        let old = self.n_nodes();
        assert!(emb.len() >= old, "appended matrix must extend the old one");
        debug_assert_eq!(&emb.data[..old * emb.dim],
                         &self.emb.data[..old * emb.dim],
                         "existing rows must be unchanged");
        self.thaw();
        let ml = 1.0 / (self.m as f64).ln();
        self.emb = emb;
        for i in old..self.emb.len() {
            self.insert(i as u32, level_for(self.seed, i, ml),
                        self.ef_construction);
        }
    }

    /// Compact the adjacency into the per-level flat CSR form (no-op if
    /// already sealed). Sealing never changes any neighbor list — only
    /// the layout — so sealed and unsealed searches are bit-identical.
    pub fn seal(&mut self) {
        let adj = std::mem::replace(&mut self.adj,
                                    Adjacency::Nested(Vec::new()));
        self.adj = match adj {
            Adjacency::Nested(nested) => {
                Adjacency::Csr(CsrGraph::from_nested(&nested))
            }
            sealed => sealed,
        };
    }

    /// Expand back to the nested mutable form (no-op if already nested).
    pub(crate) fn thaw(&mut self) {
        let adj = std::mem::replace(&mut self.adj,
                                    Adjacency::Nested(Vec::new()));
        self.adj = match adj {
            Adjacency::Csr(csr) => Adjacency::Nested(csr.to_nested()),
            nested => nested,
        };
    }

    /// Whether the adjacency is in the compact CSR form.
    pub(crate) fn is_sealed(&self) -> bool {
        matches!(self.adj, Adjacency::Csr(_))
    }

    /// Adjacency as the nested form (copied) — test/debug comparisons
    /// that must be layout-independent.
    pub(crate) fn debug_nested(&self) -> Vec<Vec<Vec<u32>>> {
        match &self.adj {
            Adjacency::Nested(n) => n.clone(),
            Adjacency::Csr(c) => c.to_nested(),
        }
    }

    #[inline]
    fn n_nodes(&self) -> usize {
        match &self.adj {
            Adjacency::Nested(n) => n.len(),
            Adjacency::Csr(c) => c.n_nodes(),
        }
    }

    /// Node `v`'s neighbor list at layer `l`, whichever form the
    /// adjacency is in.
    #[inline]
    fn neighbor_slice(&self, v: u32, l: usize) -> &[u32] {
        match &self.adj {
            Adjacency::Nested(n) => &n[v as usize][l],
            Adjacency::Csr(c) => c.neighbors(v, l),
        }
    }

    /// Mutable nested adjacency — insertion only runs on the thawed form.
    #[inline]
    fn nested_mut(&mut self) -> &mut Vec<Vec<Vec<u32>>> {
        match &mut self.adj {
            Adjacency::Nested(n) => n,
            Adjacency::Csr(_) => {
                // detlint: allow(hot-panic, reason = "mutation API misuse on a sealed graph is a programming error, not a serving state")
                unreachable!("insertion on a sealed graph (thaw first)")
            }
        }
    }

    #[inline]
    fn sim(&self, q: &[f32], id: u32) -> f32 {
        kernels::dot(q, self.emb.row(id))
    }

    /// Heuristic neighbor selection (Malkov & Yashunin Alg. 4): keep a
    /// candidate only if it is closer to the query point than to every
    /// already-selected neighbor. This preserves inter-cluster bridges —
    /// plain top-M selection fragments clustered data (a from-scratch
    /// implementation lesson; see EXPERIMENTS.md §Perf notes).
    fn select_heuristic(&self, cands: &[Cand], m: usize) -> Vec<u32> {
        let mut selected: Vec<Cand> = Vec::with_capacity(m);
        let mut skipped: Vec<u32> = Vec::new();
        for &c in cands {
            if selected.len() >= m {
                break;
            }
            let c_vec = self.emb.row(c.id);
            let diverse = selected
                .iter()
                .all(|s| kernels::dot(c_vec, self.emb.row(s.id)) < c.score);
            if diverse {
                selected.push(c);
            } else {
                skipped.push(c.id);
            }
        }
        let mut out: Vec<u32> = selected.iter().map(|c| c.id).collect();
        // keepPrunedConnections: fill up with the best skipped candidates.
        for id in skipped {
            if out.len() >= m {
                break;
            }
            out.push(id);
        }
        out
    }

    fn insert(&mut self, id: u32, level: usize, ef_c: usize) {
        SCRATCH.with(|cell| {
            // Reentrancy guard: fall back to a fresh scratch if this
            // thread's is already borrowed up-stack (scratch only caches
            // capacity, so the graph built is identical either way).
            match cell.try_borrow_mut() {
                Ok(mut s) => self.insert_with(id, level, ef_c, &mut s),
                Err(_) => self.insert_with(
                    id, level, ef_c, &mut SearchScratch::default()),
            }
        });
    }

    fn insert_with(&mut self, id: u32, level: usize, ef_c: usize,
                   scratch: &mut SearchScratch) {
        self.nested_mut().push(vec![Vec::new(); level + 1]);
        if id == 0 {
            self.entry = 0;
            self.max_level = level;
            return;
        }
        // Borrow the query row from a local Arc clone so the embedding
        // slice stays valid across the adjacency mutations below.
        let emb = Arc::clone(&self.emb);
        let q = emb.row(id);
        let mut eps: Vec<u32> = vec![self.entry];
        // Greedy descent through layers above the node's level.
        let top = self.max_level;
        for l in ((level + 1)..=top).rev() {
            eps[0] = self.greedy_step(q, eps[0], l);
        }
        // Insert at each layer <= level; the full candidate set of one
        // layer seeds the search at the next (Malkov & Yashunin Alg. 1).
        for l in (0..=level.min(top)).rev() {
            self.search_layer(q, &eps, ef_c, l, scratch);
            let max_m = if l == 0 { self.m0 } else { self.m };
            let selected = self.select_heuristic(&scratch.out, self.m);
            if !scratch.out.is_empty() {
                eps.clear();
                eps.extend(scratch.out.iter().map(|c| c.id));
            }
            for &nb in &selected {
                self.nested_mut()[id as usize][l].push(nb);
                self.nested_mut()[nb as usize][l].push(id);
                if self.neighbor_slice(nb, l).len() > max_m {
                    // Re-select the neighbor's list with the same heuristic.
                    let nb_vec = emb.row(nb);
                    let mut scored: Vec<Cand> = self
                        .neighbor_slice(nb, l)
                        .iter()
                        .map(|&x| Cand { id: x, score: self.sim(nb_vec, x) })
                        .collect();
                    scored.sort_by(|a, b| b.cmp(a));
                    let reselected = self.select_heuristic(&scored, max_m);
                    self.nested_mut()[nb as usize][l] = reselected;
                }
            }
        }
        if level > self.max_level {
            self.max_level = level;
            self.entry = id;
        }
    }

    /// One greedy hill-climb step chain at layer `l`.
    fn greedy_step(&self, q: &[f32], mut ep: u32, l: usize) -> u32 {
        let mut best = self.sim(q, ep);
        loop {
            let mut improved = false;
            let nbs = self.neighbor_slice(ep, l);
            // Pull the neighbor rows toward cache while the list itself
            // is still hot; scoring below then hits L1/L2 instead of DRAM.
            for &nb in nbs {
                let row = self.emb.row(nb);
                kernels::prefetch_row(row.as_ptr().cast(), row.len() * 4);
            }
            for &nb in nbs {
                let s = self.sim(q, nb);
                if s > best {
                    best = s;
                    ep = nb;
                    improved = true;
                }
            }
            if !improved {
                return ep;
            }
        }
    }

    /// Beam search at one layer using the caller-provided scratch; leaves
    /// the candidates sorted best-first in `scratch.out`. The two heap
    /// allocations are rented from the scratch and handed back empty, so
    /// steady-state searches allocate nothing.
    fn search_layer(&self, q: &[f32], eps: &[u32], ef: usize, l: usize,
                    scratch: &mut SearchScratch) {
        let n = self.n_nodes();
        if scratch.stamps.len() < n {
            scratch.stamps.resize(n, 0);
        }
        scratch.gen = scratch.gen.wrapping_add(1);
        if scratch.gen == 0 {
            scratch.stamps.fill(0);
            scratch.gen = 1;
        }
        let gen = scratch.gen;
        let stamps = &mut scratch.stamps;

        let mut cand_heap: BinaryHeap<Cand> =
            BinaryHeap::from(std::mem::take(&mut scratch.cand_buf));
        let mut result: BinaryHeap<MinCand> =
            BinaryHeap::from(std::mem::take(&mut scratch.result_buf));
        for &ep in eps {
            if stamps[ep as usize] == gen {
                continue;
            }
            stamps[ep as usize] = gen;
            let c = Cand { id: ep, score: self.sim(q, ep) };
            cand_heap.push(c);
            result.push(MinCand(c));
        }
        while let Some(c) = cand_heap.pop() {
            let worst = result.peek().map(|m| m.0.score)
                .unwrap_or(f32::NEG_INFINITY);
            if result.len() >= ef && c.score < worst {
                break;
            }
            let nbs = self.neighbor_slice(c.id, l);
            // Prefetch the unvisited neighbors' embedding rows before the
            // scoring pass: by the time `sim` needs a row its cache line
            // is (usually) already in flight.
            for &nb in nbs {
                if stamps[nb as usize] != gen {
                    let row = self.emb.row(nb);
                    kernels::prefetch_row(row.as_ptr().cast(), row.len() * 4);
                }
            }
            for &nb in nbs {
                if stamps[nb as usize] == gen {
                    continue;
                }
                stamps[nb as usize] = gen;
                let s = self.sim(q, nb);
                let worst = result.peek().map(|m| m.0.score)
                    .unwrap_or(f32::NEG_INFINITY);
                if result.len() < ef || s > worst {
                    let cand = Cand { id: nb, score: s };
                    cand_heap.push(cand);
                    result.push(MinCand(cand));
                    if result.len() > ef {
                        result.pop();
                    }
                }
            }
        }
        scratch.out.clear();
        scratch.out.extend(result.iter().map(|m| m.0));
        scratch.out.sort_by(|a, b| b.cmp(a));
        // Hand the (emptied) allocations back to the scratch.
        let mut cb = cand_heap.into_vec();
        cb.clear();
        scratch.cand_buf = cb;
        let mut rb = result.into_vec();
        rb.clear();
        scratch.result_buf = rb;
    }

    /// One full search against a caller-provided scratch: per-query greedy
    /// descent seeds the layer-0 beam entry point, then beam search with
    /// ef. `scratch.out` is (score desc, id asc)-sorted over unique ids,
    /// so its first k entries are exactly the top-k selection (same order
    /// a `TopK` heap would produce, without building one).
    fn search_with(&self, q: &[f32], k: usize, ef: usize,
                   scratch: &mut SearchScratch) -> Vec<Scored> {
        if self.n_nodes() == 0 {
            return Vec::new();
        }
        let mut ep = self.entry;
        for l in (1..=self.max_level).rev() {
            ep = self.greedy_step(q, ep, l);
        }
        self.search_layer(q, &[ep], ef.max(k), 0, scratch);
        scratch
            .out
            .iter()
            .take(k.max(1))
            .map(|c| Scored { id: c.id, score: c.score })
            .collect()
    }

    /// Full search: descend to layer 0, beam with ef, return top-k.
    pub fn search(&self, q: &[f32], k: usize, ef: usize) -> Vec<Scored> {
        // Reentrancy guard: see [`Hnsw::insert`].
        SCRATCH.with(|cell| match cell.try_borrow_mut() {
            Ok(mut s) => self.search_with(q, k, ef, &mut s),
            Err(_) => self.search_with(q, k, ef,
                                       &mut SearchScratch::default()),
        })
    }

    /// [`Retriever::retrieve_batch`] against a caller-provided scratch:
    /// all queries share one visited pool + heap set, and each walk is
    /// identical to a standalone search.
    fn retrieve_batch_with(&self, qs: &[SpecQuery], k: usize,
                           scratch: &mut SearchScratch)
                           -> Vec<Vec<Scored>> {
        qs.iter()
            .map(|q| {
                assert_eq!(q.dense.len(), self.emb.dim,
                           "query dim mismatch");
                self.search_with(&q.dense, k, self.ef_search, scratch)
            })
            .collect()
    }
}

impl Retriever for Hnsw {
    /// Batched graph search — the trait's required primitive. All queries
    /// in the batch share one search scratch (visited pool + heap
    /// allocations), so the per-call setup cost is paid once per batch;
    /// each query's walk itself is identical to a standalone search, which
    /// keeps batched and single-query results bit-identical (the
    /// output-equivalence requirement).
    fn retrieve_batch(&self, qs: &[SpecQuery], k: usize) -> Vec<Vec<Scored>> {
        // Reentrancy guard: see [`Hnsw::insert`].
        SCRATCH.with(|cell| match cell.try_borrow_mut() {
            Ok(mut s) => self.retrieve_batch_with(qs, k, &mut s),
            Err(_) => {
                self.retrieve_batch_with(qs, k,
                                         &mut SearchScratch::default())
            }
        })
    }

    fn score_doc(&self, q: &SpecQuery, doc: DocId) -> f32 {
        // Exact metric: the cache scores candidates exactly even though the
        // graph walk is approximate (same as scoring visited nodes in HNSW).
        kernels::dot(&q.dense, self.emb.row(doc))
    }

    fn len(&self) -> usize {
        self.emb.len()
    }

    fn name(&self) -> &'static str {
        "ADR(hnsw)"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::retriever::dense::DenseExact;
    use crate::util::Rng;

    fn clustered_matrix(n: usize, d: usize, clusters: usize, seed: u64)
                        -> Arc<EmbeddingMatrix> {
        let mut rng = Rng::new(seed);
        let centroids: Vec<Vec<f32>> =
            (0..clusters).map(|_| rng.unit_vector(d)).collect();
        let mut data = Vec::with_capacity(n * d);
        for i in 0..n {
            let c = &centroids[i % clusters];
            let noise = rng.unit_vector(d);
            let mut v: Vec<f32> =
                c.iter().zip(&noise).map(|(a, b)| a + 0.3 * b).collect();
            let norm = v.iter().map(|x| x * x).sum::<f32>().sqrt();
            v.iter_mut().for_each(|x| *x /= norm);
            data.extend(v);
        }
        Arc::new(EmbeddingMatrix::new(d, data))
    }

    #[test]
    fn build_is_deterministic() {
        let emb = clustered_matrix(400, 16, 8, 1);
        let a = Hnsw::build(emb.clone(), 8, 40, 32, 7);
        let b = Hnsw::build(emb, 8, 40, 32, 7);
        assert!(a.is_sealed() && b.is_sealed());
        assert_eq!(a.entry, b.entry);
        assert_eq!(a.debug_nested(), b.debug_nested());
    }

    #[test]
    fn csr_matches_nested_search() {
        // The CSR layout is a pure re-layout of the nested lists: the
        // same walk visits the same nodes in the same order, so sealed
        // and thawed searches agree bit-for-bit. Miri interprets ~100x
        // slower than native; shrink the graph there so the CI Miri job
        // still covers the CSR pointer arithmetic in reasonable time.
        let (n, n_queries) = if cfg!(miri) { (120, 4) } else { (700, 20) };
        let emb = clustered_matrix(n, 16, 8, 3);
        let sealed = Hnsw::build(emb, 12, 60, 48, 5);
        let mut nested = sealed.clone();
        nested.thaw();
        assert!(sealed.is_sealed() && !nested.is_sealed());
        assert_eq!(sealed.debug_nested(), nested.debug_nested());
        let mut rng = Rng::new(6);
        for _ in 0..n_queries {
            let q = SpecQuery::dense_only(rng.unit_vector(16));
            let a = sealed.retrieve_topk(&q, 10);
            let b = nested.retrieve_topk(&q, 10);
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.id, y.id);
                assert_eq!(x.score.to_bits(), y.score.to_bits());
            }
        }
    }

    #[test]
    fn search_survives_scratch_already_borrowed() {
        let n = if cfg!(miri) { 80 } else { 300 };
        let emb = clustered_matrix(n, 16, 6, 9);
        let hnsw = Hnsw::build(emb, 8, 40, 32, 7);
        let mut rng = Rng::new(12);
        let qs: Vec<SpecQuery> = (0..4)
            .map(|_| SpecQuery::dense_only(rng.unit_vector(16)))
            .collect();
        let plain = hnsw.retrieve_batch(&qs, 5);
        // Reentrancy: the thread-local search scratch is held across the
        // batch, forcing the fresh-scratch fallback. Must not panic, and
        // the walk must be identical (scratch is capacity-only).
        let held = SCRATCH.with(|cell| {
            let _guard = cell.borrow_mut();
            hnsw.retrieve_batch(&qs, 5)
        });
        assert_eq!(plain, held);
    }

    #[test]
    fn recall_at_10_vs_flat() {
        let emb = clustered_matrix(2000, 32, 20, 2);
        let hnsw = Hnsw::build(emb.clone(), 16, 100, 64, 3);
        let flat = DenseExact::new(emb);
        let mut rng = Rng::new(4);
        let mut hits = 0usize;
        let mut total = 0usize;
        for _ in 0..30 {
            let q = SpecQuery::dense_only(rng.unit_vector(32));
            let truth: std::collections::HashSet<u32> =
                flat.retrieve_topk(&q, 10).iter().map(|s| s.id).collect();
            for s in hnsw.retrieve_topk(&q, 10) {
                total += 1;
                if truth.contains(&s.id) {
                    hits += 1;
                }
            }
        }
        let recall = hits as f64 / total as f64;
        assert!(recall > 0.85, "recall@10 = {recall}");
    }

    #[test]
    fn finds_own_embedding() {
        let emb = clustered_matrix(800, 16, 8, 5);
        let hnsw = Hnsw::build(emb.clone(), 12, 80, 48, 6);
        let mut found = 0;
        for i in [0u32, 123, 456, 799] {
            let q = SpecQuery::dense_only(emb.row(i).to_vec());
            if hnsw.retrieve(&q).map(|s| s.id) == Some(i) {
                found += 1;
            }
        }
        assert!(found >= 3, "self-retrieval found only {found}/4");
    }

    #[test]
    fn topk_sorted_and_unique() {
        let emb = clustered_matrix(500, 16, 4, 8);
        let hnsw = Hnsw::build(emb, 8, 60, 40, 9);
        let mut rng = Rng::new(10);
        let q = SpecQuery::dense_only(rng.unit_vector(16));
        let top = hnsw.retrieve_topk(&q, 10);
        assert_eq!(top.len(), 10);
        for w in top.windows(2) {
            assert!(w[0].score >= w[1].score);
        }
        let ids: std::collections::HashSet<u32> =
            top.iter().map(|s| s.id).collect();
        assert_eq!(ids.len(), top.len());
    }

    #[test]
    fn append_matches_fresh_build() {
        // The live-update invariant: growing a graph by incremental
        // insertion — thawing the sealed prefix into the mutable tail,
        // inserting, then resealing (the "publish" compaction) — is
        // bit-identical to building from scratch over the extended matrix
        // (levels are per-id seeded; build is sequential insertion), so
        // per-epoch ADR snapshots are reproducible.
        let full = clustered_matrix(600, 16, 8, 13);
        let prefix = Arc::new(EmbeddingMatrix::new(
            16, full.data[..400 * 16].to_vec()));
        let mut grown = Hnsw::build(prefix, 8, 40, 32, 21);
        assert!(grown.is_sealed());
        grown.append(full.clone());
        assert!(!grown.is_sealed(), "append leaves the mutable tail open");
        grown.seal();
        assert!(grown.is_sealed(), "publish-time compaction reseals");
        let fresh = Hnsw::build(full, 8, 40, 32, 21);
        assert_eq!(grown.entry, fresh.entry);
        assert_eq!(grown.max_level, fresh.max_level);
        assert_eq!(grown.debug_nested(), fresh.debug_nested());
        // And the searches agree bit-for-bit.
        let mut rng = Rng::new(22);
        let q = SpecQuery::dense_only(rng.unit_vector(16));
        let a = grown.retrieve_topk(&q, 10);
        let b = fresh.retrieve_topk(&q, 10);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.score.to_bits(), y.score.to_bits());
        }
    }

    #[test]
    fn export_import_roundtrip() {
        // The persistent CSR form is complete: a graph rebuilt from its
        // export searches bit-identically (the segment tier's ADR
        // cold-load path rests on this).
        let emb = clustered_matrix(300, 16, 6, 15);
        let built = Hnsw::build(emb.clone(), 8, 40, 32, 7);
        let reloaded = Hnsw::import_csr(emb, 32, built.export_csr());
        assert!(reloaded.is_sealed());
        assert_eq!(built.entry, reloaded.entry);
        assert_eq!(built.debug_nested(), reloaded.debug_nested());
        let mut rng = Rng::new(20);
        for _ in 0..5 {
            let q = SpecQuery::dense_only(rng.unit_vector(16));
            let a = built.retrieve_topk(&q, 8);
            let b = reloaded.retrieve_topk(&q, 8);
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.id, y.id);
                assert_eq!(x.score.to_bits(), y.score.to_bits());
            }
        }
    }

    #[test]
    fn single_node_graph() {
        let emb = clustered_matrix(1, 8, 1, 11);
        let hnsw = Hnsw::build(emb, 4, 10, 10, 12);
        let q = SpecQuery::dense_only(vec![1.0; 8]);
        let got = hnsw.retrieve(&q).unwrap();
        assert_eq!(got.id, 0);
    }
}

/// The sealed graph's complete persistent state (CSR adjacency + build
/// parameters + entry point) — what the segment layer serializes. A
/// graph round-tripped through export/import searches bit-identically:
/// both forms hold byte-identical neighbor lists and the same walk
/// parameters (pinned by `export_import_roundtrip`).
pub(crate) struct CsrExport {
    pub m: usize,
    pub m0: usize,
    pub ef_construction: usize,
    pub seed: u64,
    pub entry: u32,
    pub max_level: usize,
    /// node_levels[v] = number of layers node v participates in.
    pub node_levels: Vec<u32>,
    /// Per layer: (offsets [n+1], packed neighbor ids).
    pub levels: Vec<(Vec<u32>, Vec<u32>)>,
}

impl Hnsw {
    /// Snapshot the graph as its flat persistent form. A nested (thawed)
    /// adjacency is compacted on the fly — sealing is a pure re-layout,
    /// so the export is identical either way.
    pub(crate) fn export_csr(&self) -> CsrExport {
        let csr_owned;
        let csr = match &self.adj {
            Adjacency::Csr(c) => c,
            Adjacency::Nested(n) => {
                csr_owned = CsrGraph::from_nested(n);
                &csr_owned
            }
        };
        CsrExport {
            m: self.m,
            m0: self.m0,
            ef_construction: self.ef_construction,
            seed: self.seed,
            entry: self.entry,
            max_level: self.max_level,
            node_levels: csr.node_levels.clone(),
            levels: csr
                .levels
                .iter()
                .map(|l| (l.offsets.clone(), l.packed.clone()))
                .collect(),
        }
    }

    /// Reconstruct a sealed graph from its persistent form. `ef_search`
    /// is a serving-time knob (not part of the graph), so the caller
    /// supplies it from config like [`Hnsw::build`] does.
    pub(crate) fn import_csr(emb: Arc<EmbeddingMatrix>, ef_search: usize,
                             parts: CsrExport) -> Self {
        assert_eq!(parts.node_levels.len(), emb.len(),
                   "graph/matrix node count mismatch");
        for (offsets, _) in &parts.levels {
            assert_eq!(offsets.len(), parts.node_levels.len() + 1,
                       "CSR offsets must be n + 1 long");
        }
        Self {
            emb,
            m: parts.m,
            m0: parts.m0,
            ef_search,
            ef_construction: parts.ef_construction,
            seed: parts.seed,
            entry: parts.entry,
            max_level: parts.max_level,
            adj: Adjacency::Csr(CsrGraph {
                node_levels: parts.node_levels,
                levels: parts
                    .levels
                    .into_iter()
                    .map(|(offsets, packed)| CsrLevel { offsets, packed })
                    .collect(),
            }),
        }
    }

    /// BFS reachability at layer 0 from the entry point (debug/tests).
    pub fn debug_reachable(&self) -> usize {
        let mut seen = vec![false; self.n_nodes()];
        let mut stack = vec![self.entry];
        seen[self.entry as usize] = true;
        let mut count = 0;
        while let Some(x) = stack.pop() {
            count += 1;
            for &nb in self.neighbor_slice(x, 0) {
                if !seen[nb as usize] {
                    seen[nb as usize] = true;
                    stack.push(nb);
                }
            }
        }
        count
    }
}
