//! Shard-parallel scatter-gather retrieval (DESIGN.md "Sharded
//! retrieval").
//!
//! [`ShardedRetriever`] wraps any [`Shardable`] backend and fans
//! `retrieve_batch` out over the persistent [`WorkerPool`], then k-way
//! merges per-shard top-k with the repo-wide `(score desc, id asc)`
//! tie-break. Results are **bit-identical** to the unsharded backend —
//! the property the sharded-equivalence suite pins for every retriever
//! class — because shards never recompute global statistics:
//!
//! * **EDR** (`DenseShard`): shards are contiguous row ranges of the one
//!   shared embedding matrix; per-row arithmetic is range-independent, so
//!   the union of shard top-k is exactly the global candidate set.
//! * **SR** (`Bm25Shard`): shards are doc-id ranges over the one shared
//!   index; idf/avgdl/doc-length stay global, each shard walks only its
//!   slice of every posting list.
//! * **ADR** (`Hnsw`): an approximate graph cannot be doc-partitioned
//!   without changing the walk (and therefore the results), so ADR shards
//!   are **replicas** of the one shared graph (`Arc` clones — no memory
//!   copy) and the *query batch* is partitioned across them instead.
//!   Per-query results are trivially identical; the win is parallelism
//!   across the batch, which is exactly the axis batched verification
//!   exposes.
//!
//! One more ingredient of the bit-identity: kernel dispatch
//! (`retriever::kernels::simd_active`, DESIGN.md ADR-007) is a
//! process-wide constant, so every pool worker scores with the same
//! (scalar or SIMD — themselves bit-identical) kernel form and the k-way
//! merge never compares scores produced by different code paths.

use super::dense::{DenseExact, DenseShard};
use super::hnsw::Hnsw;
use super::pool::WorkerPool;
use super::sparse::{Bm25, Bm25Shard};
use super::{DocId, Retriever, SpecQuery};
use crate::util::{Scored, TopK};
use std::sync::Arc;

/// How a backend's shards relate to the corpus.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardStrategy {
    /// Each shard owns a contiguous doc-id range; a batch is scattered to
    /// every shard and per-query top-k are k-way merged.
    DocRange,
    /// Each shard is a full replica; the query batch is partitioned
    /// across shards and results are concatenated in order (no merge).
    Replicate,
}

/// Backends that can expose shard views of themselves. Shard construction
/// must be cheap (views over shared state), so re-sharding an existing
/// index never rebuilds it.
pub trait Shardable: Retriever {
    type Shard: Retriever + 'static;

    fn strategy() -> ShardStrategy;

    /// Build `n` shard views over `this` backend (n >= 1). An associated
    /// function (not a method) because shard views hold an `Arc` of the
    /// backend, which a `&self` receiver cannot produce.
    fn make_shards(this: &Arc<Self>, n: usize) -> Vec<Arc<Self::Shard>>;
}

/// Contiguous `[lo, hi)` bounds splitting `len` docs into `n` near-equal
/// shards (first `len % n` shards get one extra doc). Every doc belongs to
/// exactly one shard.
pub fn shard_bounds(len: usize, n: usize) -> Vec<(usize, usize)> {
    let n = n.max(1).min(len.max(1));
    let base = len / n;
    let extra = len % n;
    let mut bounds = Vec::with_capacity(n);
    let mut lo = 0usize;
    for i in 0..n {
        let hi = lo + base + usize::from(i < extra);
        bounds.push((lo, hi));
        lo = hi;
    }
    debug_assert_eq!(lo, len);
    bounds
}

impl Shardable for DenseExact {
    type Shard = DenseShard;

    fn strategy() -> ShardStrategy {
        ShardStrategy::DocRange
    }

    fn make_shards(this: &Arc<Self>, n: usize) -> Vec<Arc<DenseShard>> {
        shard_bounds(this.len(), n)
            .into_iter()
            .map(|(lo, hi)| {
                // Shards inherit the parent's codec (shared Arc): a
                // sharded sq8 EDR scans quantized per shard and merges
                // bit-identically to the unsharded scan, because each
                // shard's output is bit-identical to its full scan.
                Arc::new(DenseShard::with_sq8(this.embeddings().clone(),
                                              lo, hi,
                                              this.sq8().cloned()))
            })
            .collect()
    }
}

impl Shardable for Bm25 {
    type Shard = Bm25Shard;

    fn strategy() -> ShardStrategy {
        ShardStrategy::DocRange
    }

    fn make_shards(this: &Arc<Self>, n: usize) -> Vec<Arc<Bm25Shard>> {
        shard_bounds(this.len(), n)
            .into_iter()
            .map(|(lo, hi)| {
                Arc::new(Bm25Shard::new(this.clone(), lo as DocId,
                                        hi as DocId))
            })
            .collect()
    }
}

impl Shardable for Hnsw {
    type Shard = Hnsw;

    fn strategy() -> ShardStrategy {
        ShardStrategy::Replicate
    }

    fn make_shards(this: &Arc<Self>, n: usize) -> Vec<Arc<Hnsw>> {
        (0..n.max(1)).map(|_| this.clone()).collect()
    }
}

/// Scatter-gather engine over any [`Shardable`] backend. Object-safe as a
/// `dyn Retriever`, so every consumer (pipelines, cache, router backends,
/// eval drivers) takes sharded and unsharded knowledge bases through the
/// same trait.
pub struct ShardedRetriever<R: Shardable> {
    inner: Arc<R>,
    shards: Vec<Arc<R::Shard>>,
    strategy: ShardStrategy,
    pool: Arc<WorkerPool>,
    label: &'static str,
}

/// Intern a label string, leaking each **distinct** label at most once.
/// The trait's `name()` returns `&'static str`, so sharded engines must
/// leak their formatted label — but live knowledge-base updates
/// (retriever::epoch) construct a fresh `ShardedRetriever` per published
/// epoch, and a leak-per-construction would grow without bound under a
/// long-running ingest stream. Labels repeat (same shard count, same
/// backend name), so interning caps the leak at the handful of distinct
/// configurations a process ever serves.
fn interned_label(label: String) -> &'static str {
    use std::collections::BTreeMap;
    use std::sync::{Mutex, OnceLock};
    static INTERN: OnceLock<Mutex<BTreeMap<String, &'static str>>> =
        OnceLock::new();
    let map = INTERN.get_or_init(|| Mutex::new(BTreeMap::new()));
    // detlint: allow(hot-panic, reason = "intern mutex poisoning means another construction panicked mid-insert; propagate")
    let mut guard = map.lock().unwrap();
    if let Some(&l) = guard.get(&label) {
        return l;
    }
    let leaked: &'static str = Box::leak(label.clone().into_boxed_str());
    guard.insert(label, leaked);
    leaked
}

impl<R: Shardable> ShardedRetriever<R> {
    /// Shard `inner` n ways over an explicit pool.
    pub fn with_pool(inner: Arc<R>, n_shards: usize, pool: Arc<WorkerPool>)
                     -> Self {
        let shards = R::make_shards(&inner, n_shards);
        let label = interned_label(
            format!("sharded{}x:{}", shards.len(), inner.name()));
        Self { inner, shards, strategy: R::strategy(), pool, label }
    }

    /// Shard `inner` n ways over the process-wide shared pool.
    pub fn new(inner: Arc<R>, n_shards: usize) -> Self {
        Self::with_pool(inner, n_shards, WorkerPool::global().clone())
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn strategy(&self) -> ShardStrategy {
        self.strategy
    }

    /// The wrapped unsharded backend.
    pub fn inner(&self) -> &Arc<R> {
        &self.inner
    }
}

impl<R: Shardable> Retriever for ShardedRetriever<R> {
    fn retrieve_batch(&self, qs: &[SpecQuery], k: usize) -> Vec<Vec<Scored>> {
        if qs.is_empty() {
            return Vec::new();
        }
        if self.shards.len() <= 1 {
            // Single shard covers the whole corpus (DocRange) or is the
            // full replica (Replicate) — no scatter needed.
            return match self.shards.first() {
                Some(s) => s.retrieve_batch(qs, k),
                None => self.inner.retrieve_batch(qs, k),
            };
        }
        match self.strategy {
            ShardStrategy::DocRange => {
                // Workers need 'static tasks; share the batch, don't copy
                // it per shard.
                let qs_shared: Arc<Vec<SpecQuery>> = Arc::new(qs.to_vec());
                // Scatter: every shard answers the whole batch over its
                // doc range, in parallel on the persistent pool.
                let tasks: Vec<_> = self
                    .shards
                    .iter()
                    .map(|shard| {
                        let shard = shard.clone();
                        let qs = qs_shared.clone();
                        move || shard.retrieve_batch(&qs, k)
                    })
                    .collect();
                let per_shard = self.pool.scatter(tasks);
                // Gather: k-way merge per query. `TopK` implements the
                // repo-wide (score desc, id asc) order, and the union of
                // shard top-k contains the global top-k (each shard
                // returned its best k over a disjoint doc range), so the
                // merged list is bit-identical to the unsharded backend.
                (0..qs.len())
                    .map(|qi| {
                        let mut tk = TopK::new(k.max(1));
                        for shard_res in &per_shard {
                            for s in &shard_res[qi] {
                                tk.push(s.id, s.score);
                            }
                        }
                        tk.into_sorted()
                    })
                    .collect()
            }
            ShardStrategy::Replicate => {
                // Partition the batch into contiguous chunks, one per
                // replica; concatenate in order. Identical per-query
                // results, parallel across the batch.
                let chunks = shard_bounds(qs.len(), self.shards.len());
                if chunks.len() <= 1 {
                    // Batch of one (or one chunk): a pool round-trip buys
                    // no parallelism — answer inline on the caller. This
                    // is the hot single-query path of the derived
                    // retrieve()/retrieve_topk().
                    return self.shards[0].retrieve_batch(qs, k);
                }
                let qs_shared: Arc<Vec<SpecQuery>> = Arc::new(qs.to_vec());
                let tasks: Vec<_> = chunks
                    .into_iter()
                    .zip(&self.shards)
                    .map(|((lo, hi), shard)| {
                        let shard = shard.clone();
                        let qs = qs_shared.clone();
                        move || shard.retrieve_batch(&qs[lo..hi], k)
                    })
                    .collect();
                let mut out = Vec::with_capacity(qs.len());
                for part in self.pool.scatter(tasks) {
                    out.extend(part);
                }
                out
            }
        }
    }

    fn score_doc(&self, q: &SpecQuery, doc: DocId) -> f32 {
        // The cache-side metric must be the inner backend's exact metric —
        // rank preservation (§3) composes through sharding unchanged.
        self.inner.score_doc(q, doc)
    }

    fn score_docs(&self, q: &SpecQuery, docs: &[DocId]) -> Vec<f32> {
        self.inner.score_docs(q, docs)
    }

    fn len(&self) -> usize {
        self.inner.len()
    }

    fn name(&self) -> &'static str {
        self.label
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::retriever::dense::EmbeddingMatrix;
    use crate::util::Rng;

    fn matrix(n: usize, d: usize, seed: u64) -> Arc<EmbeddingMatrix> {
        let mut rng = Rng::new(seed);
        let mut data = Vec::with_capacity(n * d);
        for _ in 0..n {
            data.extend(rng.unit_vector(d));
        }
        Arc::new(EmbeddingMatrix::new(d, data))
    }

    #[test]
    fn shard_bounds_partition_exactly() {
        for (len, n) in [(10usize, 3usize), (7, 7), (5, 8), (100, 4),
                         (1, 1), (0, 3)] {
            let b = shard_bounds(len, n);
            assert!(!b.is_empty());
            assert_eq!(b.first().unwrap().0, 0);
            assert_eq!(b.last().unwrap().1, len);
            for w in b.windows(2) {
                assert_eq!(w[0].1, w[1].0, "contiguous");
            }
        }
    }

    #[test]
    fn dense_sharded_matches_unsharded_bitwise() {
        let emb = matrix(500, 16, 1);
        let inner = Arc::new(DenseExact::new(emb));
        let mut rng = Rng::new(2);
        let qs: Vec<SpecQuery> =
            (0..9).map(|_| SpecQuery::dense_only(rng.unit_vector(16))).collect();
        let truth = inner.retrieve_batch(&qs, 7);
        for n in [1usize, 2, 3, 7] {
            let sharded = ShardedRetriever::new(inner.clone(), n);
            let got = sharded.retrieve_batch(&qs, 7);
            assert_eq!(got.len(), truth.len());
            for (g, t) in got.iter().zip(&truth) {
                assert_eq!(g.len(), t.len(), "n={n}");
                for (a, b) in g.iter().zip(t) {
                    assert_eq!(a.id, b.id, "n={n}");
                    assert_eq!(a.score.to_bits(), b.score.to_bits(), "n={n}");
                }
            }
        }
    }

    #[test]
    fn more_shards_than_docs_clamps() {
        let emb = matrix(3, 8, 3);
        let inner = Arc::new(DenseExact::new(emb));
        let sharded = ShardedRetriever::new(inner.clone(), 16);
        assert!(sharded.n_shards() <= 3);
        let q = SpecQuery::dense_only(vec![0.5; 8]);
        let got = sharded.retrieve_topk(&q, 2);
        let want = inner.retrieve_topk(&q, 2);
        assert_eq!(got.iter().map(|s| s.id).collect::<Vec<_>>(),
                   want.iter().map(|s| s.id).collect::<Vec<_>>());
    }

    #[test]
    fn empty_batch_returns_empty() {
        let emb = matrix(10, 8, 4);
        let sharded =
            ShardedRetriever::new(Arc::new(DenseExact::new(emb)), 2);
        assert!(sharded.retrieve_batch(&[], 5).is_empty());
    }

    #[test]
    fn label_reports_shard_count() {
        let emb = matrix(10, 8, 5);
        let sharded =
            ShardedRetriever::new(Arc::new(DenseExact::new(emb)), 2);
        assert_eq!(sharded.name(), "sharded2x:EDR(flat)");
    }
}
