//! Segment-based persistent knowledge-base tier (DESIGN.md ADR-009).
//!
//! The in-RAM mutable backends (`retriever::epoch`) rebuild or clone
//! O(corpus) state per publish and lose everything on restart. This
//! module adds the tiered, persistent, memory-bounded alternative:
//!
//! * **[`store`]** — immutable on-disk segments in the `RSEG` container
//!   format (`docs/FORMAT.md`): versioned magic/header, per-section
//!   FNV-1a checksums, zero-copy mmap loading via the runtime [`Blob`],
//!   with a numbered-manifest commit protocol whose recovery path
//!   tolerates torn writes (newest fully-validating manifest wins).
//! * **[`SegmentedKb`]** — a [`MutableRetriever`] whose ingest lands in
//!   a bounded in-RAM **memtable**; when full, the memtable is frozen to
//!   a new segment. Publishing an epoch snapshot costs O(memtable +
//!   vocab), not O(corpus): sealed tiers are shared views over mmap'd
//!   sections, only the memtable overlay is copied.
//! * **[`tiered`]** — the read path: per-tier scans into shared top-k
//!   heaps, bit-identical to the monolithic in-RAM indexes for all three
//!   backends (EDR/ADR/SR).
//! * **[`CompactionWorker`]** — a background thread that periodically
//!   merges segments + memtable back into one full-range segment,
//!   bounding tier count (and, for ADR, re-persisting the HNSW graph).
//!
//! The epoch/pinning machinery (ADR-006) is reused unchanged: a
//! [`SegmentedKb`] is just another `MutableRetriever` behind
//! [`KbWriter`], and its snapshots are ordinary `Arc<dyn Retriever>`s.
//!
//! Durability note: the memtable is volatile (no WAL). A crash loses
//! documents ingested since the last freeze/compaction — the recovery
//! guarantee is that the store reopens at the newest *consistent*
//! manifest, never a torn one. See `docs/PERSISTENCE.md`.
//!
//! [`Blob`]: crate::runtime::Blob
//! [`KbWriter`]: crate::retriever::epoch::KbWriter
//! [`MutableRetriever`]: crate::retriever::epoch::MutableRetriever

mod compact;
mod format;
mod store;
mod tiered;

pub use compact::CompactionWorker;
pub use format::fnv1a64;
pub use store::{Segment, SegmentStore};
pub use tiered::{TieredDense, TieredDenseShard, TieredSparse,
                 TieredSparseShard};

use crate::config::{Config, DenseCodec, RetrieverKind};
use crate::datagen::corpus::{Corpus, Document};
use crate::retriever::dense::EmbeddingMatrix;
use crate::retriever::epoch::MutableRetriever;
use crate::retriever::hnsw::Hnsw;
use crate::retriever::sparse::{bm25_idf, doc_term_stats};
use crate::retriever::Retriever;
use std::path::Path;
use std::sync::Arc;
use store::{build_segment_bytes, SegmentBuild};
use tiered::maybe_shard;

/// The bounded in-RAM write buffer absorbing ingest between freezes.
#[derive(Default)]
struct Memtable {
    docs: Vec<Document>,
    /// Dense rows (EDR/ADR), `docs.len() * dim`.
    rows: Vec<f32>,
    /// Per-doc sorted (term, tf) stats (SR).
    doc_terms: Vec<Vec<(u32, u16)>>,
    /// Memtable-only document frequency per term (SR), vocab-sized.
    df: Vec<u32>,
    total_len: u64,
}

impl Memtable {
    fn clear(&mut self) {
        self.docs.clear();
        self.rows.clear();
        self.doc_terms.clear();
        for d in self.df.iter_mut() {
            *d = 0;
        }
        self.total_len = 0;
    }
}

/// Tiered, persistent knowledge base: mmap'd segments + memtable, a
/// drop-in [`MutableRetriever`] whose epoch publish is O(memtable).
///
/// ```
/// use ralmspec::config::{Config, CorpusConfig, RetrieverKind};
/// use ralmspec::datagen::embedding::{embed_corpus, HashEncoder};
/// use ralmspec::datagen::{Corpus, Document};
/// use ralmspec::retriever::segment::SegmentedKb;
/// use ralmspec::retriever::MutableRetriever;
///
/// let mut cfg = Config::default();
/// cfg.corpus = CorpusConfig { n_docs: 60, n_topics: 4, doc_len: (8, 16),
///                             ..CorpusConfig::default() };
/// let corpus = Corpus::generate(&cfg.corpus);
/// let enc = HashEncoder::new(16, 3);
/// let rows = embed_corpus(&enc, &corpus);
/// let dir = std::env::temp_dir()
///     .join(format!("ralmspec-segkb-doc-{}", std::process::id()));
/// let _ = std::fs::remove_dir_all(&dir);
///
/// // First run: builds the store on disk, then reopens it (mmap path).
/// let (mut kb, recovered) = SegmentedKb::open_or_create(
///     &dir, &cfg, RetrieverKind::Edr, &corpus, &rows, 16).unwrap();
/// assert_eq!(recovered.len(), 60);
///
/// // Ingest lands in the memtable; snapshots see it immediately.
/// let doc = Document { id: 60, topic: 0, tokens: vec![70, 71, 72] };
/// kb.append(&[doc], &[vec![0.25; 16]]).unwrap();
/// assert_eq!(kb.len(), 61);
/// assert_eq!(kb.snapshot(1).len(), 61);
///
/// // Compaction folds segments + memtable into one full-range segment.
/// assert!(kb.compact().unwrap());
/// assert_eq!(kb.tier_count(), 1);
/// std::fs::remove_dir_all(&dir).unwrap();
/// ```
pub struct SegmentedKb {
    kind: RetrieverKind,
    dim: usize,
    vocab: usize,
    k1: f32,
    b: f32,
    hnsw_m: usize,
    hnsw_efc: usize,
    hnsw_efs: usize,
    hnsw_seed: u64,
    memtable_cap: usize,
    /// Write EDR segments with the SQ8 quantized companion section
    /// (`dense.codec = sq8`; always false for ADR/SR).
    sq8_codec: bool,
    /// SQ8 pruning-heap factor handed to snapshots (`dense.oversample`).
    oversample: f64,
    store: SegmentStore,
    mem: Memtable,
    /// Docs frozen into segments (memtable docs not included).
    sealed_len: usize,
    /// Token count across sealed segments.
    sealed_total_len: u64,
    /// Document frequency per term across sealed segments (SR).
    sealed_df: Vec<u32>,
    /// ADR master graph over *all* rows (sealed + memtable), kept in the
    /// nested mutable form between publishes like `MutableHnsw`.
    graph: Option<Hnsw>,
    /// ADR: full row-major matrix backing the master graph.
    all_rows: Vec<f32>,
    tf_scratch: Vec<u16>,
}

/// The HNSW seed derivation shared with `LiveKb::build`'s in-RAM path —
/// both must agree for segment-backed ADR to be bit-identical.
pub(crate) fn hnsw_seed(cfg: &Config) -> u64 {
    cfg.corpus.seed ^ 0x48
}

impl SegmentedKb {
    /// Initialize `dir` with one full-range segment holding `corpus`
    /// (plus the persisted HNSW graph for ADR). Errors if a store
    /// already exists there.
    pub fn create(dir: &Path, cfg: &Config, kind: RetrieverKind,
                  corpus: &Corpus, rows: &[f32], dim: usize)
                  -> anyhow::Result<()> {
        let mut st = SegmentStore::create(dir)?;
        if corpus.is_empty() {
            return Ok(());
        }
        let docs: Vec<Document> = corpus.iter().cloned().collect();
        let mut doc_terms = Vec::new();
        if kind == RetrieverKind::Sr {
            let mut tf = vec![0u16; corpus.vocab];
            doc_terms = docs.iter()
                .map(|d| doc_term_stats(&d.tokens, &mut tf))
                .collect();
        }
        let graph = match kind {
            RetrieverKind::Adr => {
                anyhow::ensure!(rows.len() == docs.len() * dim,
                                "embedding rows/dim mismatch");
                let emb = Arc::new(EmbeddingMatrix::new(dim,
                                                        rows.to_vec()));
                let g = Hnsw::build(emb, cfg.retriever.hnsw_m,
                                    cfg.retriever.hnsw_ef_construction,
                                    cfg.retriever.hnsw_ef_search,
                                    hnsw_seed(cfg));
                Some(g.export_csr())
            }
            RetrieverKind::Edr => {
                anyhow::ensure!(rows.len() == docs.len() * dim,
                                "embedding rows/dim mismatch");
                None
            }
            RetrieverKind::Sr => None,
        };
        let bytes = build_segment_bytes(&SegmentBuild {
            kind,
            doc_lo: 0,
            docs: &docs,
            rows: if kind == RetrieverKind::Sr { &[] } else { rows },
            dim,
            vocab: corpus.vocab,
            doc_terms: &doc_terms,
            graph: graph.as_ref(),
            sq8: kind == RetrieverKind::Edr
                && cfg.dense.codec == DenseCodec::Sq8,
        });
        st.add_segment(&bytes)
    }

    /// Recover the store from `dir` and rebuild the corpus from the
    /// persisted documents. This is the cold-load path: dense rows and
    /// postings are mmap'd views, only the ADR graph's embedding matrix
    /// is materialized in RAM.
    pub fn open(dir: &Path, cfg: &Config, kind: RetrieverKind)
                -> anyhow::Result<(Self, Corpus)> {
        let store = SegmentStore::open(dir)?;
        let vocab = cfg.corpus.vocab;
        let dim = store.segments().first()
            .map_or(crate::runtime::RETRIEVAL_DIM, |s| s.dim());
        let mut docs = Vec::with_capacity(store.n_docs());
        let mut sealed_total_len = 0u64;
        let mut sealed_df = vec![0u32; vocab];
        for seg in store.segments() {
            anyhow::ensure!(seg.kind() == kind,
                            "segment kind {:?} != configured {:?}",
                            seg.kind(), kind);
            anyhow::ensure!(seg.dim() == dim, "segment dim mismatch");
            sealed_total_len += seg.total_doc_len();
            match kind {
                RetrieverKind::Edr | RetrieverKind::Adr => {
                    anyhow::ensure!(seg.dense.is_some(),
                                    "dense segment missing DENSE");
                }
                RetrieverKind::Sr => {
                    anyhow::ensure!(seg.vocab() == vocab,
                                    "segment vocab {} != configured {}",
                                    seg.vocab(), vocab);
                    let post = seg.post.as_ref().ok_or_else(
                        || anyhow::anyhow!("SR segment missing POSTINGS"))?;
                    anyhow::ensure!(seg.doc_len.is_some()
                                    && seg.doc_terms.is_some(),
                                    "SR segment missing doc stats");
                    let off = post.offsets.as_slice();
                    for t in 0..vocab {
                        sealed_df[t] += off[t + 1] - off[t];
                    }
                }
            }
            docs.extend(seg.docs()?);
        }
        let sealed_len = docs.len();

        let mut kb = Self {
            kind,
            dim,
            vocab,
            k1: cfg.retriever.bm25_k1,
            b: cfg.retriever.bm25_b,
            hnsw_m: cfg.retriever.hnsw_m,
            hnsw_efc: cfg.retriever.hnsw_ef_construction,
            hnsw_efs: cfg.retriever.hnsw_ef_search,
            hnsw_seed: hnsw_seed(cfg),
            memtable_cap: cfg.segment.memtable_docs.max(1),
            sq8_codec: kind == RetrieverKind::Edr
                && cfg.dense.codec == DenseCodec::Sq8,
            oversample: cfg.dense.oversample,
            store,
            mem: Memtable { df: vec![0; vocab], ..Memtable::default() },
            sealed_len,
            sealed_total_len,
            sealed_df,
            graph: None,
            all_rows: Vec::new(),
            tf_scratch: vec![0; vocab],
        };
        if kind == RetrieverKind::Adr {
            kb.rebuild_adr_master(cfg)?;
        }
        let corpus = Corpus::rebuild(&cfg.corpus, docs);
        Ok((kb, corpus))
    }

    /// [`open`] if a store exists in `dir`, else [`create`] then
    /// [`open`] — so the mmap read path is exercised on every startup,
    /// not only on restarts.
    ///
    /// [`open`]: SegmentedKb::open
    /// [`create`]: SegmentedKb::create
    pub fn open_or_create(dir: &Path, cfg: &Config, kind: RetrieverKind,
                          corpus: &Corpus, rows: &[f32], dim: usize)
                          -> anyhow::Result<(Self, Corpus)> {
        if !SegmentStore::exists(dir) {
            Self::create(dir, cfg, kind, corpus, rows, dim)?;
        }
        Self::open(dir, cfg, kind)
    }

    /// Reconstruct the ADR master: import the persisted CSR graph over
    /// its prefix of rows, then insert any rows from later (graph-less)
    /// segments incrementally — append ≡ rebuild, so the result is
    /// bit-identical to building over the full matrix.
    fn rebuild_adr_master(&mut self, cfg: &Config) -> anyhow::Result<()> {
        self.all_rows.clear();
        for seg in self.store.segments() {
            if let Some(v) = &seg.dense {
                self.all_rows.extend_from_slice(v.as_slice());
            }
        }
        let persisted = match self.store.segments().first() {
            Some(seg) => seg.graph()?,
            None => None,
        };
        let efs = cfg.retriever.hnsw_ef_search;
        let mut graph = match persisted {
            Some(csr) => {
                anyhow::ensure!(
                    csr.m == self.hnsw_m
                        && csr.ef_construction == self.hnsw_efc
                        && csr.seed == self.hnsw_seed,
                    "persisted graph params (m={}, efc={}, seed={:#x}) \
                     differ from config (m={}, efc={}, seed={:#x})",
                    csr.m, csr.ef_construction, csr.seed,
                    self.hnsw_m, self.hnsw_efc, self.hnsw_seed);
                let g_n = csr.node_levels.len();
                anyhow::ensure!(g_n * self.dim <= self.all_rows.len(),
                                "graph covers more rows than segments");
                let prefix = Arc::new(EmbeddingMatrix::new(
                    self.dim, self.all_rows[..g_n * self.dim].to_vec()));
                let mut g = Hnsw::import_csr(prefix, efs, csr);
                g.thaw();
                if g_n * self.dim < self.all_rows.len() {
                    g.append(Arc::new(EmbeddingMatrix::new(
                        self.dim, self.all_rows.clone())));
                }
                g
            }
            None => Hnsw::build(
                Arc::new(EmbeddingMatrix::new(self.dim,
                                              self.all_rows.clone())),
                self.hnsw_m, self.hnsw_efc, efs, self.hnsw_seed),
        };
        graph.thaw();
        self.graph = Some(graph);
        Ok(())
    }

    /// Freeze the memtable into a new on-disk segment (no-op when
    /// empty). Called automatically when the memtable reaches
    /// `segment.memtable_docs`, and by [`SegmentedKb::compact`].
    pub fn freeze_memtable(&mut self) -> anyhow::Result<()> {
        if self.mem.docs.is_empty() {
            return Ok(());
        }
        let bytes = build_segment_bytes(&SegmentBuild {
            kind: self.kind,
            doc_lo: self.sealed_len as u32,
            docs: &self.mem.docs,
            rows: &self.mem.rows,
            dim: self.dim,
            vocab: self.vocab,
            doc_terms: &self.mem.doc_terms,
            graph: None,
            sq8: self.sq8_codec,
        });
        self.store.add_segment(&bytes)?;
        self.seal_mem_stats();
        Ok(())
    }

    /// Fold the memtable's statistics into the sealed totals and clear
    /// it (the docs themselves just became segment-resident).
    fn seal_mem_stats(&mut self) {
        self.sealed_len += self.mem.docs.len();
        self.sealed_total_len += self.mem.total_len;
        for (s, m) in self.sealed_df.iter_mut().zip(self.mem.df.iter()) {
            *s += m;
        }
        self.mem.clear();
    }

    /// Tiers currently serving reads: segments + a non-empty memtable.
    pub fn tier_count(&self) -> usize {
        self.store.segments().len()
            + usize::from(!self.mem.docs.is_empty())
    }

    /// True when every sealed tier is served from a live mmap.
    pub fn all_segments_mapped(&self) -> bool {
        self.store.segments().iter().all(|s| s.is_mapped())
    }

    /// Merge all segments + memtable into one full-range segment and
    /// publish it as the store's only tier (for ADR, re-persisting the
    /// master graph's CSR export). Returns `false` when already fully
    /// compacted. Read equivalence is unchanged: the merged tier walk
    /// equals the multi-tier walk, which equals the monolithic scan.
    pub fn compact(&mut self) -> anyhow::Result<bool> {
        if self.store.segments().len() <= 1 && self.mem.docs.is_empty() {
            return Ok(false);
        }
        let mut docs = Vec::with_capacity(self.len());
        for seg in self.store.segments() {
            docs.extend(seg.docs()?);
        }
        docs.extend(self.mem.docs.iter().cloned());

        let rows: Vec<f32> = match self.kind {
            RetrieverKind::Adr => self.all_rows.clone(),
            RetrieverKind::Edr => {
                let mut out =
                    Vec::with_capacity(docs.len() * self.dim);
                for seg in self.store.segments() {
                    if let Some(v) = &seg.dense {
                        out.extend_from_slice(v.as_slice());
                    }
                }
                out.extend_from_slice(&self.mem.rows);
                out
            }
            RetrieverKind::Sr => Vec::new(),
        };
        let mut doc_terms = Vec::new();
        if self.kind == RetrieverKind::Sr {
            doc_terms = docs.iter()
                .map(|d| doc_term_stats(&d.tokens,
                                        &mut self.tf_scratch))
                .collect();
        }
        let graph = match (&self.kind, &self.graph) {
            (RetrieverKind::Adr, Some(g)) => Some(g.export_csr()),
            _ => None,
        };
        let bytes = build_segment_bytes(&SegmentBuild {
            kind: self.kind,
            doc_lo: 0,
            docs: &docs,
            rows: &rows,
            dim: self.dim,
            vocab: self.vocab,
            doc_terms: &doc_terms,
            graph: graph.as_ref(),
            sq8: self.sq8_codec,
        });
        self.store.replace_all(&bytes)?;
        self.seal_mem_stats();
        debug_assert_eq!(self.sealed_len, self.store.n_docs());
        Ok(true)
    }

    fn snapshot_dense(&self, shards: usize) -> Arc<dyn Retriever> {
        let mut tiers: Vec<tiered::DenseTier> = self.store.segments()
            .iter()
            .filter_map(|s| s.dense_tier())
            .collect();
        if !self.mem.docs.is_empty() {
            tiers.push(tiered::DenseTier {
                doc_lo: self.sealed_len as u32,
                doc_hi: (self.sealed_len + self.mem.docs.len()) as u32,
                rows: format::F32View::owned(self.mem.rows.clone()),
                sq8: None,
            });
        }
        maybe_shard(Arc::new(TieredDense::new(tiers, self.dim)
                        .with_oversample(self.oversample)),
                    shards)
    }

    fn snapshot_sparse(&self, shards: usize) -> Arc<dyn Retriever> {
        let n = self.len();
        // Global statistics over sealed + memtable docs, same arithmetic
        // as the monolithic build (integer sum -> f64 divide -> f32).
        let idf: Vec<f32> = self.sealed_df.iter()
            .zip(self.mem.df.iter())
            .map(|(&s, &m)| bm25_idf(n, (s + m) as usize))
            .collect();
        let total = self.sealed_total_len + self.mem.total_len;
        let avgdl = if n == 0 {
            0.0
        } else {
            (total as f64 / n as f64) as f32
        };
        let mut tiers: Vec<tiered::SparseTier> = self.store.segments()
            .iter()
            .filter_map(|s| s.sparse_tier())
            .collect();
        if !self.mem.docs.is_empty() {
            tiers.push(self.memtable_sparse_tier());
        }
        maybe_shard(Arc::new(TieredSparse::new(tiers, Arc::new(idf),
                                               self.k1, self.b, avgdl)),
                    shards)
    }

    /// Package the memtable as one owned sparse tier — O(vocab +
    /// memtable tokens), the SR publish cost.
    fn memtable_sparse_tier(&self) -> tiered::SparseTier {
        let lo = self.sealed_len as u32;
        let (offsets, pdocs, ptfs) = store::postings_arrays(
            self.vocab, lo, &self.mem.doc_terms);
        let mut dt_off = vec![0u32];
        let mut dt_terms = Vec::new();
        let mut dt_tfs = Vec::new();
        for dt in &self.mem.doc_terms {
            for &(t, f) in dt {
                dt_terms.push(t);
                dt_tfs.push(f);
            }
            dt_off.push(dt_terms.len() as u32);
        }
        tiered::SparseTier {
            doc_lo: lo,
            doc_hi: lo + self.mem.docs.len() as u32,
            post: store::PostingsView {
                offsets: format::U32View::owned(offsets),
                docs: format::U32View::owned(pdocs),
                tfs: format::U16View::owned(ptfs),
            },
            doc_len: format::U32View::owned(
                self.mem.docs.iter()
                    .map(|d| d.tokens.len() as u32).collect()),
            doc_terms: store::DocTermsView {
                offsets: format::U32View::owned(dt_off),
                terms: format::U32View::owned(dt_terms),
                tfs: format::U16View::owned(dt_tfs),
            },
        }
    }

    fn snapshot_hnsw(&self, shards: usize) -> Arc<dyn Retriever> {
        // Same publish-time compaction as `MutableHnsw::snapshot`: clone
        // the master, seal the clone to CSR. O(corpus) — documented in
        // ADR-009 (the graph itself is the whole-corpus state).
        match &self.graph {
            Some(master) => {
                let mut g = master.clone();
                g.seal();
                maybe_shard(Arc::new(g), shards)
            }
            // Unreachable after open(); serve an empty dense scan so a
            // mis-ordered call degrades loudly in tests, not via panic.
            None => Arc::new(TieredDense::new(Vec::new(), self.dim)),
        }
    }
}

impl MutableRetriever for SegmentedKb {
    fn append(&mut self, docs: &[Document], embeddings: &[Vec<f32>])
              -> anyhow::Result<()> {
        anyhow::ensure!(docs.len() == embeddings.len(),
                        "{} docs but {} embedding rows",
                        docs.len(), embeddings.len());
        let dense = self.kind != RetrieverKind::Sr;
        for (i, (d, e)) in docs.iter().zip(embeddings).enumerate() {
            anyhow::ensure!(!dense || e.len() == self.dim,
                            "doc {}: embedding dim {} != {}",
                            d.id, e.len(), self.dim);
            anyhow::ensure!(d.id as usize == self.len() + i,
                            "doc {}: ids must be contiguous", d.id);
            anyhow::ensure!(
                d.tokens.iter().all(|&t| (t as usize) < self.vocab),
                "doc {}: token outside vocab {}", d.id, self.vocab);
        }
        for (d, e) in docs.iter().zip(embeddings) {
            self.mem.total_len += d.tokens.len() as u64;
            if dense {
                self.mem.rows.extend_from_slice(e);
            }
            if self.kind == RetrieverKind::Sr {
                let dt = doc_term_stats(&d.tokens,
                                        &mut self.tf_scratch);
                for &(t, _) in &dt {
                    self.mem.df[t as usize] += 1;
                }
                self.mem.doc_terms.push(dt);
            }
            self.mem.docs.push(d.clone());
        }
        if self.kind == RetrieverKind::Adr {
            for e in embeddings {
                self.all_rows.extend_from_slice(e);
            }
            let emb = Arc::new(EmbeddingMatrix::new(
                self.dim, self.all_rows.clone()));
            match &mut self.graph {
                Some(g) => g.append(emb),
                None => {
                    let mut g = Hnsw::build(emb, self.hnsw_m,
                                            self.hnsw_efc,
                                            self.hnsw_efs,
                                            self.hnsw_seed);
                    g.thaw();
                    self.graph = Some(g);
                }
            }
        }
        if self.mem.docs.len() >= self.memtable_cap {
            self.freeze_memtable()?;
        }
        Ok(())
    }

    fn snapshot(&self, shards: usize) -> Arc<dyn Retriever> {
        match self.kind {
            RetrieverKind::Edr => self.snapshot_dense(shards),
            RetrieverKind::Sr => self.snapshot_sparse(shards),
            RetrieverKind::Adr => self.snapshot_hnsw(shards),
        }
    }

    fn len(&self) -> usize {
        self.sealed_len + self.mem.docs.len()
    }

    fn compact(&mut self) -> anyhow::Result<bool> {
        SegmentedKb::compact(self)
    }

    fn tier_count(&self) -> usize {
        SegmentedKb::tier_count(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CorpusConfig;
    use crate::datagen::embedding::{embed_corpus, embed_doc, Encoder,
                                    HashEncoder};
    use crate::retriever::epoch::{MutableBm25, MutableDense,
                                  MutableHnsw};
    use crate::retriever::sparse::Bm25;
    use crate::retriever::SpecQuery;
    use crate::util::Rng;
    use std::path::PathBuf;

    const DIM: usize = 24;

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "ralmspec-segkb-{}-{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn small_cfg(n: usize, memtable: usize) -> Config {
        let mut cfg = Config::default();
        cfg.corpus = CorpusConfig {
            n_docs: n, n_topics: 8, doc_len: (16, 48),
            ..CorpusConfig::default()
        };
        cfg.retriever.hnsw_ef_construction = 40;
        cfg.retriever.hnsw_ef_search = 24;
        cfg.segment.memtable_docs = memtable;
        cfg
    }

    fn probe_queries(c: &Corpus, enc: &HashEncoder, n: usize)
                     -> Vec<SpecQuery> {
        let mut rng = Rng::new(0xBEEF);
        (0..n)
            .map(|i| {
                let terms =
                    c.topic_tokens((i % c.n_topics) as u32, 8, &mut rng);
                SpecQuery {
                    dense: enc.encode(&terms),
                    terms,
                }
            })
            .collect()
    }

    fn ingest_batch(c: &Corpus, enc: &HashEncoder, start: u32, n: usize)
                    -> (Vec<Document>, Vec<Vec<f32>>) {
        let docs = c.synth_docs(0x51, start, n, (16, 48));
        let embs: Vec<Vec<f32>> =
            docs.iter().map(|d| embed_doc(enc, d)).collect();
        (docs, embs)
    }

    fn kind_equivalence(kind: RetrieverKind, codec: DenseCodec) {
        let mut cfg = small_cfg(220, 16);
        cfg.dense.codec = codec;
        let c = Corpus::generate(&cfg.corpus);
        let enc = HashEncoder::new(DIM, 0xE6);
        let rows = embed_corpus(&enc, &c);
        let dir = tmpdir(&format!("equiv-{kind:?}-{}", codec.label()));

        let (mut seg_kb, rec) = SegmentedKb::open_or_create(
            &dir, &cfg, kind, &c, &rows, DIM).unwrap();
        assert_eq!(rec.len(), 220);
        let mut ram_kb: Box<dyn MutableRetriever> = match kind {
            RetrieverKind::Edr =>
                Box::new(MutableDense::new(DIM, rows.clone())),
            RetrieverKind::Adr => Box::new(MutableHnsw::new(
                DIM, rows.clone(), cfg.retriever.hnsw_m,
                cfg.retriever.hnsw_ef_construction,
                cfg.retriever.hnsw_ef_search, hnsw_seed(&cfg))),
            RetrieverKind::Sr => Box::new(MutableBm25::new(
                Bm25::build(&c, cfg.retriever.bm25_k1,
                            cfg.retriever.bm25_b))),
        };
        let qs = probe_queries(&c, &enc, 6);
        for shards in [1usize, 2] {
            assert_eq!(ram_kb.snapshot(shards).retrieve_batch(&qs, 5),
                       seg_kb.snapshot(shards).retrieve_batch(&qs, 5),
                       "{kind:?} epoch0 shards={shards}");
        }
        // Ingest enough to force at least two memtable freezes.
        let mut next = 220u32;
        for _ in 0..3 {
            let (docs, embs) = ingest_batch(&c, &enc, next, 14);
            next += 14;
            ram_kb.append(&docs, &embs).unwrap();
            seg_kb.append(&docs, &embs).unwrap();
            for shards in [1usize, 2] {
                assert_eq!(
                    ram_kb.snapshot(shards).retrieve_batch(&qs, 5),
                    seg_kb.snapshot(shards).retrieve_batch(&qs, 5),
                    "{kind:?} post-ingest shards={shards}");
            }
        }
        assert!(seg_kb.tier_count() > 1, "freezes should create tiers");
        // Compaction must not change any result.
        assert!(SegmentedKb::compact(&mut seg_kb).unwrap());
        assert_eq!(SegmentedKb::tier_count(&seg_kb), 1);
        assert_eq!(ram_kb.snapshot(1).retrieve_batch(&qs, 5),
                   seg_kb.snapshot(1).retrieve_batch(&qs, 5),
                   "{kind:?} post-compaction");
        // And the compacted store must round-trip through a cold open.
        drop(seg_kb);
        let (reopened, rec2) =
            SegmentedKb::open(&dir, &cfg, kind).unwrap();
        assert_eq!(rec2.len(), next as usize);
        assert_eq!(ram_kb.snapshot(1).retrieve_batch(&qs, 5),
                   reopened.snapshot(1).retrieve_batch(&qs, 5),
                   "{kind:?} after reopen");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn edr_matches_in_ram_backend() {
        kind_equivalence(RetrieverKind::Edr, DenseCodec::Full);
    }

    /// Same drive as `edr_matches_in_ram_backend` but with quantized
    /// segments: every freeze/compaction writes `DENSE_SQ8`, every
    /// snapshot scans through the two-phase path — and every result
    /// must still equal the in-RAM f32 backend's bit for bit.
    #[test]
    fn edr_sq8_codec_matches_in_ram_backend() {
        kind_equivalence(RetrieverKind::Edr, DenseCodec::Sq8);
    }

    #[test]
    fn sr_matches_in_ram_backend() {
        kind_equivalence(RetrieverKind::Sr, DenseCodec::Full);
    }

    #[test]
    fn adr_matches_in_ram_backend() {
        kind_equivalence(RetrieverKind::Adr, DenseCodec::Full);
    }
}
