//! Background merge/compaction worker.
//!
//! The worker owns a dedicated thread that periodically inspects a
//! [`LiveKb`]'s writer: when the backend reports more than `min_tiers`
//! tiers (segments plus a non-empty memtable), it runs one compaction
//! pass, which merges everything into a single segment and publishes
//! the result as a normal epoch. Serving threads never block on the
//! merge itself — they only contend on the writer mutex for the final
//! publish, exactly as they do for an ingest flush.
//!
//! Pacing uses `recv_timeout` on the stop channel rather than a bare
//! `sleep` so that [`CompactionWorker::stop`] (and `Drop`) interrupt
//! the wait immediately instead of after up to one full interval.

use std::sync::mpsc;
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use crate::retriever::epoch::LiveKb;

/// Handle to the background compaction thread. Dropping the handle
/// stops the thread (send + join); [`CompactionWorker::stop`] does the
/// same explicitly so shutdown ordering can be controlled.
///
/// ```
/// use ralmspec::config::Config;
/// use ralmspec::config::RetrieverKind;
/// use ralmspec::datagen::{embed_corpus, Corpus, HashEncoder};
/// use ralmspec::retriever::epoch::LiveKb;
/// use ralmspec::retriever::segment::CompactionWorker;
///
/// let mut cfg = Config::default();
/// cfg.corpus.n_docs = 40;
/// cfg.corpus.vocab = 512;
/// cfg.corpus.n_topics = 8;
/// let corpus = Corpus::generate(&cfg.corpus);
/// let enc = HashEncoder::new(16, cfg.corpus.seed);
/// let emb = embed_corpus(&enc, &corpus);
/// let live = LiveKb::build(&cfg, RetrieverKind::Edr, corpus, emb, 16);
///
/// // Spawn, then stop: the worker exits promptly even mid-interval.
/// let mut worker = CompactionWorker::spawn(live, 50, 2);
/// worker.stop();
/// ```
pub struct CompactionWorker {
    stop_tx: Option<mpsc::Sender<()>>,
    handle: Option<thread::JoinHandle<()>>,
}

impl CompactionWorker {
    /// Start the worker. Every `interval_ms` it locks the writer and, if
    /// the backend reports at least `min_tiers` tiers, runs one
    /// compaction pass (a no-op `Ok(false)` for in-RAM backends).
    pub fn spawn(live: Arc<LiveKb>, interval_ms: u64,
                 min_tiers: usize) -> CompactionWorker {
        let (stop_tx, stop_rx) = mpsc::channel::<()>();
        // Compaction epochs are content-identical to the tiers they
        // replace, so publish timing cannot change results (this file is
        // on the ADR-008 nondet-source whitelist for exactly that reason).
        // detlint: allow(nondet-source, reason = "dedicated maintenance thread; timing only picks when a content-identical epoch publishes")
        let handle = thread::spawn(move || loop {
            match stop_rx.recv_timeout(Duration::from_millis(
                interval_ms.max(1))) {
                Ok(()) | Err(mpsc::RecvTimeoutError::Disconnected) => break,
                Err(mpsc::RecvTimeoutError::Timeout) => {}
            }
            let Ok(mut w) = live.writer.lock() else { break };
            if w.tier_count() >= min_tiers {
                // Failure is not fatal to serving: the tiered snapshot
                // stays live and the next tick retries.
                let _ = w.run_compaction();
            }
        });
        CompactionWorker { stop_tx: Some(stop_tx), handle: Some(handle) }
    }

    /// Signal the thread and wait for it to exit. Idempotent.
    pub fn stop(&mut self) {
        if let Some(tx) = self.stop_tx.take() {
            let _ = tx.send(());
        }
        if let Some(handle) = self.handle.take() {
            let _ = handle.join();
        }
    }
}

impl Drop for CompactionWorker {
    fn drop(&mut self) {
        self.stop();
    }
}
