//! Tiered read path: one [`Retriever`] over N segment tiers plus the
//! memtable overlay.
//!
//! Bit-identity with the monolithic in-RAM backends is by construction,
//! not by tolerance:
//!
//! * **EDR** — each tier's rows feed the *same* blocked multi-query
//!   kernel ([`scan_rows_with`]) the in-RAM flat scan uses, with the
//!   tier's first doc id as the base offset. Per-doc dot products are
//!   range-independent, tiers are walked in ascending doc order, and the
//!   shared [`TopK`] keeps a total order (score desc, id asc), so the
//!   kept set and its sorted output equal the monolithic scan's exactly.
//! * **SR** — the term-major outer loop is the monolithic walk with the
//!   per-term posting list split at tier boundaries: for each term, tiers
//!   are visited in ascending doc order, so every `(query, doc)`
//!   accumulation — and even the first-touch push order feeding the heap
//!   — is *identical* to [`Bm25::retrieve_batch`]'s, float op for float
//!   op (global idf/avgdl, `w = idf * term_weight(tf, dl)`,
//!   `acc += qtf * w`).
//!
//! Both are [`Shardable`] by doc range, so `--shards N` composes with
//! tiering unchanged (the scatter-gather merge is already order-blind).
//!
//! [`Bm25::retrieve_batch`]: crate::retriever::sparse::Bm25
//! [`scan_rows_with`]: crate::retriever::dense::scan_rows_with

use super::format::{F32View, U32View};
use super::store::{DocTermsView, PostingsView, Sq8View};
use crate::retriever::dense::{dot_chunked, scan_rows_with, scan_sq8_rows,
                              sq8_prune_k, with_pack_scratch,
                              MinF64Heap, Sq8Query, Sq8RowsRef,
                              DEFAULT_SQ8_OVERSAMPLE};
use crate::retriever::kernels;
use crate::retriever::sharded::{shard_bounds, ShardStrategy, Shardable,
                                ShardedRetriever};
use crate::retriever::sparse::{bm25_query_terms, bm25_term_weight};
use crate::retriever::{DocId, Retriever, SpecQuery};
use crate::util::{Scored, TopK};
use std::sync::Arc;

// ---------------------------------------------------------------------
// Dense tiers.

/// One contiguous run of embedding rows: a sealed segment's mmap'd
/// `DENSE` section, or the memtable's owned rows.
pub(crate) struct DenseTier {
    pub doc_lo: DocId,
    pub doc_hi: DocId,
    pub rows: F32View,
    /// SQ8 quantization arrays over the same rows (segments written
    /// under `dense.codec = sq8`). `None` for full-precision segments
    /// and the memtable overlay — tiers mix freely within one store.
    pub sq8: Option<Sq8View>,
}

/// Tiered exact dense retriever: the flat scan split across segment
/// tiers + memtable, sharing heaps so results match `DenseExact`
/// bit-for-bit.
pub struct TieredDense {
    tiers: Arc<Vec<DenseTier>>,
    dim: usize,
    n_docs: usize,
    /// SQ8 pruning-heap factor (only consulted when a tier carries
    /// quantized views); see [`sq8_prune_k`].
    oversample: f64,
}

impl TieredDense {
    pub(crate) fn new(tiers: Vec<DenseTier>, dim: usize) -> Self {
        let mut expect = 0;
        for t in tiers.iter() {
            assert_eq!(t.doc_lo, expect, "tiers must be contiguous");
            let n = (t.doc_hi - t.doc_lo) as usize;
            assert_eq!(t.rows.len(), n * dim, "tier row count mismatch");
            if let Some(v) = &t.sq8 {
                assert_eq!(v.scale.len(), n, "sq8 tier row mismatch");
                assert_eq!(v.codes.len(), n * dim,
                           "sq8 tier code mismatch");
            }
            expect = t.doc_hi;
        }
        Self { tiers: Arc::new(tiers), dim, n_docs: expect as usize,
               oversample: DEFAULT_SQ8_OVERSAMPLE }
    }

    /// Override the SQ8 oversample knob (`dense.oversample`).
    pub(crate) fn with_oversample(mut self, oversample: f64) -> Self {
        self.oversample = oversample;
        self
    }

    /// The monolithic `batch_over_range`, with the scan split at tier
    /// boundaries (ascending doc order; shared heaps).
    fn batch_over_range(&self, qs: &[SpecQuery], k: usize, lo: DocId,
                        hi: DocId) -> Vec<Vec<Scored>> {
        for q in qs {
            assert_eq!(q.dense.len(), self.dim, "query dim mismatch");
        }
        let mut heaps: Vec<TopK> =
            qs.iter().map(|_| TopK::new(k.max(1))).collect();
        if self.tiers.iter().any(|t| t.sq8.is_some()) {
            self.scan_sq8(qs, k, lo, hi, &mut heaps);
        } else {
            let qrefs: Vec<&[f32]> =
                qs.iter().map(|q| q.dense.as_slice()).collect();
            with_pack_scratch(|qt| {
                for t in self.tiers.iter() {
                    let a = t.doc_lo.max(lo);
                    let b = t.doc_hi.min(hi);
                    if a >= b {
                        continue;
                    }
                    let s = (a - t.doc_lo) as usize * self.dim;
                    let e = (b - t.doc_lo) as usize * self.dim;
                    scan_rows_with(&t.rows.as_slice()[s..e], self.dim,
                                   a, &qrefs, &mut heaps, qt);
                }
            });
        }
        heaps.into_iter().map(|h| h.into_sorted()).collect()
    }

    /// Mixed-tier two-phase scan, per query: quantized tiers go through
    /// [`scan_sq8_rows`] (candidate generation + exact re-score),
    /// full-precision tiers (the memtable overlay) are scored row by row
    /// with [`kernels::rescore_dot`] — the same single-accumulator
    /// arithmetic `scan_block` applies per lane, so every pushed score
    /// is bitwise the packed scan's. One [`MinF64Heap`] of *exact*
    /// scores spans all tiers of a query, so earlier tiers (either
    /// kind) tighten the pruning threshold for later quantized ones.
    fn scan_sq8(&self, qs: &[SpecQuery], k: usize, lo: DocId, hi: DocId,
                heaps: &mut [TopK]) {
        let mut idot: Vec<i32> = Vec::new();
        for (qi, q) in qs.iter().enumerate() {
            let qq = Sq8Query::new(&q.dense);
            let mut prune =
                MinF64Heap::new(sq8_prune_k(k.max(1), self.oversample));
            for t in self.tiers.iter() {
                let a = t.doc_lo.max(lo);
                let b = t.doc_hi.min(hi);
                if a >= b {
                    continue;
                }
                let (rl, rh) = ((a - t.doc_lo) as usize,
                                (b - t.doc_lo) as usize);
                let full =
                    &t.rows.as_slice()[rl * self.dim..rh * self.dim];
                match &t.sq8 {
                    Some(v) => {
                        let rr = v.as_rows_ref();
                        let view = Sq8RowsRef {
                            scale: &rr.scale[rl..rh],
                            bias: &rr.bias[rl..rh],
                            asum: &rr.asum[rl..rh],
                            rerr: &rr.rerr[rl..rh],
                            codes: &rr.codes[rl * self.dim
                                             ..rh * self.dim],
                        };
                        scan_sq8_rows(view, self.dim, full, a,
                                      &q.dense, &qq, &mut prune,
                                      &mut heaps[qi], &mut idot);
                    }
                    None => {
                        for (i, row) in
                            full.chunks_exact(self.dim).enumerate()
                        {
                            let exact =
                                kernels::rescore_dot(row, &q.dense);
                            heaps[qi].push(a + i as DocId, exact);
                            prune.push(exact as f64);
                        }
                    }
                }
            }
        }
    }

    fn row(&self, doc: DocId) -> &[f32] {
        let i = self.tiers.partition_point(|t| t.doc_hi <= doc);
        let t = &self.tiers[i];
        let s = (doc - t.doc_lo) as usize * self.dim;
        &t.rows.as_slice()[s..s + self.dim]
    }
}

impl Retriever for TieredDense {
    fn retrieve_batch(&self, qs: &[SpecQuery], k: usize)
                      -> Vec<Vec<Scored>> {
        self.batch_over_range(qs, k, 0, self.n_docs as DocId)
    }

    fn score_doc(&self, q: &SpecQuery, doc: DocId) -> f32 {
        dot_chunked(&q.dense, self.row(doc))
    }

    fn len(&self) -> usize {
        self.n_docs
    }

    fn name(&self) -> &'static str {
        "EDR(tiered)"
    }
}

/// Doc-range shard view over a shared [`TieredDense`].
pub struct TieredDenseShard {
    index: Arc<TieredDense>,
    lo: DocId,
    hi: DocId,
}

impl Retriever for TieredDenseShard {
    fn retrieve_batch(&self, qs: &[SpecQuery], k: usize)
                      -> Vec<Vec<Scored>> {
        self.index.batch_over_range(qs, k, self.lo, self.hi)
    }

    fn score_doc(&self, q: &SpecQuery, doc: DocId) -> f32 {
        self.index.score_doc(q, doc)
    }

    fn len(&self) -> usize {
        (self.hi - self.lo) as usize
    }

    fn name(&self) -> &'static str {
        "EDR(tiered-shard)"
    }
}

impl Shardable for TieredDense {
    type Shard = TieredDenseShard;

    fn strategy() -> ShardStrategy {
        ShardStrategy::DocRange
    }

    fn make_shards(this: &Arc<Self>, n: usize) -> Vec<Arc<Self::Shard>> {
        shard_bounds(this.n_docs, n)
            .into_iter()
            .map(|(lo, hi)| Arc::new(TieredDenseShard {
                index: this.clone(),
                lo: lo as DocId,
                hi: hi as DocId,
            }))
            .collect()
    }
}

// ---------------------------------------------------------------------
// Sparse tiers.

/// One tier of the BM25 index: packed postings + per-doc stats over a
/// contiguous doc range (segment sections or owned memtable arrays).
pub(crate) struct SparseTier {
    pub doc_lo: DocId,
    pub doc_hi: DocId,
    pub post: PostingsView,
    pub doc_len: U32View,
    pub doc_terms: DocTermsView,
}

/// Tiered BM25: per-term posting walks split at tier boundaries, scored
/// with **global** statistics (idf over all tiers, global avgdl) so
/// scores equal the monolithic index's bit-for-bit.
pub struct TieredSparse {
    tiers: Arc<Vec<SparseTier>>,
    idf: Arc<Vec<f32>>,
    k1: f32,
    b: f32,
    avgdl: f32,
    n_docs: usize,
}

impl TieredSparse {
    pub(crate) fn new(tiers: Vec<SparseTier>, idf: Arc<Vec<f32>>,
                      k1: f32, b: f32, avgdl: f32) -> Self {
        let mut expect = 0;
        for t in tiers.iter() {
            assert_eq!(t.doc_lo, expect, "tiers must be contiguous");
            expect = t.doc_hi;
        }
        Self { tiers: Arc::new(tiers), idf, k1, b, avgdl,
               n_docs: expect as usize }
    }

    #[inline]
    fn term_weight(&self, tf: f32, dl: f32) -> f32 {
        bm25_term_weight(tf, dl, self.k1, self.b, self.avgdl)
    }

    /// The monolithic `Bm25::retrieve_batch_range`, with each posting
    /// list walked tier by tier in ascending doc order — identical
    /// accumulation and first-touch order, not merely an equivalent set.
    fn retrieve_batch_range(&self, qs: &[SpecQuery], k: usize, lo: DocId,
                            hi: DocId) -> Vec<Vec<Scored>> {
        let mut pairs: Vec<(u32, u32, f32)> = Vec::new();
        for (qi, q) in qs.iter().enumerate() {
            for (t, qtf) in bm25_query_terms(&q.terms, &self.idf) {
                pairs.push((t, qi as u32, qtf));
            }
        }
        pairs.sort_unstable_by_key(|&(t, qi, _)| (t, qi));
        let mut acc: Vec<Vec<f32>> =
            qs.iter().map(|_| vec![0.0f32; self.n_docs]).collect();
        let mut touched: Vec<Vec<DocId>> =
            qs.iter().map(|_| Vec::new()).collect();
        let mut idx = 0;
        while idx < pairs.len() {
            let t = pairs[idx].0;
            let mut end = idx + 1;
            while end < pairs.len() && pairs[end].0 == t {
                end += 1;
            }
            let users = &pairs[idx..end];
            idx = end;
            let idf = self.idf[t as usize];
            for tier in self.tiers.iter() {
                if tier.doc_hi <= lo {
                    continue;
                }
                if tier.doc_lo >= hi {
                    break;
                }
                let offsets = tier.post.offsets.as_slice();
                let (pa, pb) = (offsets[t as usize] as usize,
                                offsets[t as usize + 1] as usize);
                let docs = &tier.post.docs.as_slice()[pa..pb];
                let tfs = &tier.post.tfs.as_slice()[pa..pb];
                let dls = tier.doc_len.as_slice();
                let start = docs.partition_point(|&d| d < lo);
                for (i, &doc) in docs.iter().enumerate().skip(start) {
                    if doc >= hi {
                        break;
                    }
                    let dl = dls[(doc - tier.doc_lo) as usize] as f32;
                    let w = idf * self.term_weight(tfs[i] as f32, dl);
                    for &(_, qi, qtf) in users {
                        let qi = qi as usize;
                        if acc[qi][doc as usize] == 0.0 {
                            touched[qi].push(doc);
                        }
                        acc[qi][doc as usize] += qtf * w;
                    }
                }
            }
        }
        let mut out = Vec::with_capacity(qs.len());
        for (a, tq) in acc.iter_mut().zip(touched.iter()) {
            let mut tk = TopK::new(k.max(1));
            for &doc in tq.iter() {
                tk.push(doc, a[doc as usize]);
            }
            out.push(tk.into_sorted());
        }
        out
    }
}

impl Retriever for TieredSparse {
    fn retrieve_batch(&self, qs: &[SpecQuery], k: usize)
                      -> Vec<Vec<Scored>> {
        self.retrieve_batch_range(qs, k, 0, self.n_docs as DocId)
    }

    fn score_doc(&self, q: &SpecQuery, doc: DocId) -> f32 {
        // Exact BM25 from the stored per-doc term stats, same float op
        // order as `Bm25::score_doc`.
        let terms = bm25_query_terms(&q.terms, &self.idf);
        let i = self.tiers.partition_point(|t| t.doc_hi <= doc);
        let tier = &self.tiers[i];
        let local = (doc - tier.doc_lo) as usize;
        let off = tier.doc_terms.offsets.as_slice();
        let (a, b) = (off[local] as usize, off[local + 1] as usize);
        let dterms = &tier.doc_terms.terms.as_slice()[a..b];
        let dtfs = &tier.doc_terms.tfs.as_slice()[a..b];
        let dl = tier.doc_len.as_slice()[local] as f32;
        let mut score = 0.0;
        for (t, qtf) in terms {
            if let Ok(j) = dterms.binary_search(&t) {
                score += qtf * self.idf[t as usize]
                    * self.term_weight(dtfs[j] as f32, dl);
            }
        }
        score
    }

    fn len(&self) -> usize {
        self.n_docs
    }

    fn name(&self) -> &'static str {
        "SR(tiered)"
    }
}

/// Doc-range shard view over a shared [`TieredSparse`].
pub struct TieredSparseShard {
    index: Arc<TieredSparse>,
    lo: DocId,
    hi: DocId,
}

impl Retriever for TieredSparseShard {
    fn retrieve_batch(&self, qs: &[SpecQuery], k: usize)
                      -> Vec<Vec<Scored>> {
        self.index.retrieve_batch_range(qs, k, self.lo, self.hi)
    }

    fn score_doc(&self, q: &SpecQuery, doc: DocId) -> f32 {
        self.index.score_doc(q, doc)
    }

    fn len(&self) -> usize {
        (self.hi - self.lo) as usize
    }

    fn name(&self) -> &'static str {
        "SR(tiered-shard)"
    }
}

impl Shardable for TieredSparse {
    type Shard = TieredSparseShard;

    fn strategy() -> ShardStrategy {
        ShardStrategy::DocRange
    }

    fn make_shards(this: &Arc<Self>, n: usize) -> Vec<Arc<Self::Shard>> {
        shard_bounds(this.n_docs, n)
            .into_iter()
            .map(|(lo, hi)| Arc::new(TieredSparseShard {
                index: this.clone(),
                lo: lo as DocId,
                hi: hi as DocId,
            }))
            .collect()
    }
}

/// Wrap a tiered backend per the configured shard count, mirroring the
/// monolithic snapshot path (`shards <= 1` stays unwrapped).
pub(crate) fn maybe_shard<T>(base: Arc<T>, shards: usize)
                             -> Arc<dyn Retriever>
where
    T: Shardable + Retriever + Send + Sync + 'static,
{
    if shards > 1 {
        Arc::new(ShardedRetriever::new(base, shards))
    } else {
        base
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::format::U16View;
    use crate::config::CorpusConfig;
    use crate::datagen::corpus::Corpus;
    use crate::datagen::embedding::{embed_corpus, HashEncoder};
    use crate::retriever::dense::{DenseExact, EmbeddingMatrix};
    use crate::retriever::sparse::{bm25_idf, doc_term_stats, Bm25};
    use crate::retriever::segment::store::postings_arrays;
    use crate::util::Rng;

    const DIM: usize = 24;

    fn corpus(n: usize) -> Corpus {
        Corpus::generate(&CorpusConfig {
            n_docs: n, n_topics: 8, doc_len: (16, 60),
            ..CorpusConfig::default()
        })
    }

    fn dense_tiers(rows: &[f32], cuts: &[usize]) -> Vec<DenseTier> {
        let mut tiers = Vec::new();
        let mut lo = 0usize;
        for &hi in cuts {
            tiers.push(DenseTier {
                doc_lo: lo as DocId,
                doc_hi: hi as DocId,
                rows: F32View::owned(rows[lo * DIM..hi * DIM].to_vec()),
                sq8: None,
            });
            lo = hi;
        }
        tiers
    }

    fn sq8_view(rows: &[f32]) -> Sq8View {
        let q = crate::retriever::dense::Sq8Rows::encode(rows, DIM);
        Sq8View {
            scale: F32View::owned(q.scale),
            bias: F32View::owned(q.bias),
            asum: F32View::owned(q.asum),
            rerr: F32View::owned(q.rerr),
            codes: super::super::format::U8View::owned(q.codes),
        }
    }

    fn sparse_tiers(c: &Corpus, cuts: &[usize])
                    -> (Vec<SparseTier>, Arc<Vec<f32>>, f32) {
        let docs: Vec<_> = c.iter().cloned().collect();
        let mut tf = vec![0u16; c.vocab];
        let all_terms: Vec<Vec<(u32, u16)>> = docs.iter()
            .map(|d| doc_term_stats(&d.tokens, &mut tf))
            .collect();
        let mut df = vec![0usize; c.vocab];
        for dt in &all_terms {
            for &(t, _) in dt {
                df[t as usize] += 1;
            }
        }
        let n = docs.len();
        let idf: Vec<f32> =
            df.iter().map(|&d| bm25_idf(n, d)).collect();
        let avgdl = c.avg_doc_len() as f32;
        let mut tiers = Vec::new();
        let mut lo = 0usize;
        for &hi in cuts {
            let dts = &all_terms[lo..hi];
            let (offsets, pdocs, ptfs) =
                postings_arrays(c.vocab, lo as DocId, dts);
            let mut dt_off = vec![0u32];
            let mut dt_terms = Vec::new();
            let mut dt_tfs = Vec::new();
            for dt in dts {
                for &(t, f) in dt {
                    dt_terms.push(t);
                    dt_tfs.push(f);
                }
                dt_off.push(dt_terms.len() as u32);
            }
            tiers.push(SparseTier {
                doc_lo: lo as DocId,
                doc_hi: hi as DocId,
                post: PostingsView {
                    offsets: U32View::owned(offsets),
                    docs: U32View::owned(pdocs),
                    tfs: U16View::owned(ptfs),
                },
                doc_len: U32View::owned(
                    docs[lo..hi].iter()
                        .map(|d| d.tokens.len() as u32).collect()),
                doc_terms: DocTermsView {
                    offsets: U32View::owned(dt_off),
                    terms: U32View::owned(dt_terms),
                    tfs: U16View::owned(dt_tfs),
                },
            });
            lo = hi;
        }
        (tiers, Arc::new(idf), avgdl)
    }

    #[test]
    fn tiered_dense_matches_monolithic() {
        let c = corpus(300);
        let enc = HashEncoder::new(DIM, 7);
        let rows = embed_corpus(&enc, &c);
        let mono = DenseExact::new(Arc::new(
            EmbeddingMatrix::new(DIM, rows.clone())));
        let tiered =
            TieredDense::new(dense_tiers(&rows, &[100, 250, 300]), DIM);
        let mut rng = Rng::new(3);
        let qs: Vec<SpecQuery> = (0..5)
            .map(|_| SpecQuery::dense_only(rng.unit_vector(DIM)))
            .collect();
        assert_eq!(mono.retrieve_batch(&qs, 7),
                   tiered.retrieve_batch(&qs, 7));
        for q in &qs {
            for d in [0u32, 99, 100, 299] {
                assert_eq!(mono.score_doc(q, d), tiered.score_doc(q, d));
            }
        }
    }

    #[test]
    fn tiered_dense_shards_match_monolithic() {
        let c = corpus(200);
        let enc = HashEncoder::new(DIM, 8);
        let rows = embed_corpus(&enc, &c);
        let mono = DenseExact::new(Arc::new(
            EmbeddingMatrix::new(DIM, rows.clone())));
        let tiered = Arc::new(
            TieredDense::new(dense_tiers(&rows, &[64, 200]), DIM));
        let sharded = maybe_shard(tiered, 2);
        let mut rng = Rng::new(4);
        let qs: Vec<SpecQuery> = (0..4)
            .map(|_| SpecQuery::dense_only(rng.unit_vector(DIM)))
            .collect();
        assert_eq!(mono.retrieve_batch(&qs, 5),
                   sharded.retrieve_batch(&qs, 5));
    }

    #[test]
    fn tiered_dense_sq8_mixed_tiers_match_monolithic() {
        // Two quantized tiers + one full-precision tier (the memtable
        // shape) must stay bit-identical to the monolithic f32 scan,
        // plain and sharded, across oversample settings.
        let c = corpus(260);
        let enc = HashEncoder::new(DIM, 9);
        let rows = embed_corpus(&enc, &c);
        let mono = DenseExact::new(Arc::new(
            EmbeddingMatrix::new(DIM, rows.clone())));
        let mut rng = Rng::new(11);
        let qs: Vec<SpecQuery> = (0..5)
            .map(|_| SpecQuery::dense_only(rng.unit_vector(DIM)))
            .collect();
        for oversample in [1.0f64, 2.0, 8.0] {
            let mut tiers = dense_tiers(&rows, &[90, 210, 260]);
            tiers[0].sq8 = Some(sq8_view(&rows[..90 * DIM]));
            tiers[1].sq8 =
                Some(sq8_view(&rows[90 * DIM..210 * DIM]));
            let tiered = Arc::new(TieredDense::new(tiers, DIM)
                .with_oversample(oversample));
            for k in [1usize, 5, 12] {
                assert_eq!(mono.retrieve_batch(&qs, k),
                           tiered.retrieve_batch(&qs, k),
                           "oversample={oversample} k={k}");
            }
            let sharded = maybe_shard(tiered, 2);
            assert_eq!(mono.retrieve_batch(&qs, 5),
                       sharded.retrieve_batch(&qs, 5),
                       "sharded oversample={oversample}");
        }
    }

    #[test]
    fn tiered_sparse_matches_monolithic() {
        let c = corpus(300);
        let mono = Bm25::build(&c, 0.9, 0.4);
        let (tiers, idf, avgdl) = sparse_tiers(&c, &[80, 200, 300]);
        let tiered = TieredSparse::new(tiers, idf, 0.9, 0.4, avgdl);
        let mut rng = Rng::new(5);
        let qs: Vec<SpecQuery> = (0..5)
            .map(|i| SpecQuery::sparse_only(
                c.topic_tokens(i % 8, 8, &mut rng)))
            .collect();
        assert_eq!(mono.retrieve_batch(&qs, 7),
                   tiered.retrieve_batch(&qs, 7));
        for q in &qs {
            for d in [0u32, 79, 80, 299] {
                assert_eq!(mono.score_doc(q, d), tiered.score_doc(q, d));
            }
        }
    }

    #[test]
    fn tiered_sparse_shards_match_monolithic() {
        let c = corpus(240);
        let mono = Bm25::build(&c, 0.9, 0.4);
        let (tiers, idf, avgdl) = sparse_tiers(&c, &[100, 240]);
        let tiered = Arc::new(
            TieredSparse::new(tiers, idf, 0.9, 0.4, avgdl));
        let sharded = maybe_shard(tiered, 3);
        let mut rng = Rng::new(6);
        let qs: Vec<SpecQuery> = (0..4)
            .map(|i| SpecQuery::sparse_only(
                c.topic_tokens(i % 8, 8, &mut rng)))
            .collect();
        assert_eq!(mono.retrieve_batch(&qs, 5),
                   sharded.retrieve_batch(&qs, 5));
    }
}
