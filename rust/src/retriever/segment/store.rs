//! Immutable on-disk segments and the manifest-backed [`SegmentStore`].
//!
//! A **segment** is one `RSEG` container file (see [`super::format`] and
//! `docs/FORMAT.md`) holding a contiguous doc-id range `[doc_lo, doc_hi)`
//! of the knowledge base: the raw documents, plus the per-backend index
//! payloads (dense rows for EDR/ADR, packed BM25 postings for SR, the
//! sealed HNSW CSR adjacency for full-range ADR segments). Segments are
//! written once and never mutated — crash safety comes from writing to a
//! temp file, `fsync`, then an atomic rename, with the set of live
//! segments recorded in a numbered manifest.
//!
//! The **manifest** (`MANIFEST-<seq>.json` + a `CURRENT` pointer) is the
//! only mutable metadata. Recovery tries the newest manifest whose
//! segment files all pass their checksums and falls back to older ones,
//! so a torn write of the latest segment loses at most the most recent
//! (unfsynced) ingest tail, never the store (pinned by the
//! `torn_segment_falls_back_to_previous_manifest` test).

use super::format::{self, F32View, SegmentFile, SegmentWriter, U16View,
                    U32View, U8View};
use crate::config::RetrieverKind;
use crate::datagen::corpus::Document;
use crate::retriever::dense::{Sq8Rows, Sq8RowsRef};
use crate::retriever::hnsw::CsrExport;
use crate::runtime::Blob;
use crate::util::json::{self, Value};
use std::path::{Path, PathBuf};
use std::sync::Arc;

fn kind_code(kind: RetrieverKind) -> u32 {
    match kind {
        RetrieverKind::Edr => 0,
        RetrieverKind::Adr => 1,
        RetrieverKind::Sr => 2,
    }
}

fn kind_from_code(code: u32) -> anyhow::Result<RetrieverKind> {
    match code {
        0 => Ok(RetrieverKind::Edr),
        1 => Ok(RetrieverKind::Adr),
        2 => Ok(RetrieverKind::Sr),
        _ => anyhow::bail!("unknown retriever kind code {code}"),
    }
}

// ---------------------------------------------------------------------
// Section encoders/decoders.

/// Everything needed to serialize one segment. `rows` and `doc_terms`
/// are consulted per [`RetrieverKind`]; `graph` only for full-range ADR
/// segments (create/compaction output).
pub(crate) struct SegmentBuild<'a> {
    pub kind: RetrieverKind,
    pub doc_lo: u32,
    pub docs: &'a [Document],
    /// Row-major dense rows, `docs.len() * dim` (EDR/ADR; empty for SR).
    pub rows: &'a [f32],
    pub dim: usize,
    pub vocab: usize,
    /// Per-doc sorted (term, tf) stats (SR; empty otherwise).
    pub doc_terms: &'a [Vec<(u32, u16)>],
    pub graph: Option<&'a CsrExport>,
    /// Also emit a `DENSE_SQ8` section quantizing `rows` (EDR segments
    /// under `dense.codec = sq8`). The full-precision `DENSE` section is
    /// still written — the exact re-score phase reads it.
    pub sq8: bool,
}

fn meta_section(b: &SegmentBuild, total_doc_len: u64) -> Vec<u8> {
    let mut out = Vec::with_capacity(32);
    format::push_u32(&mut out, kind_code(b.kind));
    format::push_u32(&mut out, b.doc_lo);
    format::push_u32(&mut out, b.doc_lo + b.docs.len() as u32);
    format::push_u32(&mut out, b.dim as u32);
    format::push_u32(&mut out, b.vocab as u32);
    format::push_u32(&mut out, 0); // pad so total_doc_len is 8-aligned
    format::push_u64(&mut out, total_doc_len);
    out
}

fn docs_section(docs: &[Document]) -> Vec<u8> {
    let n = docs.len();
    let total: usize = docs.iter().map(|d| d.tokens.len()).sum();
    let mut out = Vec::with_capacity(4 * (1 + n + 1 + n) + 4 * total);
    format::push_u32(&mut out, n as u32);
    let mut off = 0u32;
    format::push_u32(&mut out, 0);
    for d in docs {
        off += d.tokens.len() as u32;
        format::push_u32(&mut out, off);
    }
    for d in docs {
        format::push_u32(&mut out, d.topic);
    }
    for d in docs {
        format::push_u32s(&mut out, &d.tokens);
    }
    out
}

fn parse_docs(payload: &[u8], doc_lo: u32, n_expected: usize)
              -> anyhow::Result<Vec<Document>> {
    let n = format::get_u32(payload, 0)? as usize;
    anyhow::ensure!(n == n_expected,
                    "DOCS count {n} != meta doc range {n_expected}");
    let offsets = format::decode_u32s(payload, 4, n + 1)?;
    let topics = format::decode_u32s(payload, 4 * (n + 2), n)?;
    let tok_base = 4 * (2 * n + 2);
    let mut docs = Vec::with_capacity(n);
    for i in 0..n {
        let (a, b) = (offsets[i] as usize, offsets[i + 1] as usize);
        anyhow::ensure!(a <= b, "DOCS offsets not monotonic");
        let tokens =
            format::decode_u32s(payload, tok_base + 4 * a, b - a)?;
        docs.push(Document {
            id: doc_lo + i as u32,
            topic: topics[i],
            tokens,
        });
    }
    Ok(docs)
}

/// Packed postings arrays from per-doc term stats: per-term offsets
/// (`vocab + 1`), global doc ids, and tfs — doc-ascending within each
/// term by construction (docs are appended in id order), exactly the
/// order [`crate::retriever::sparse::Bm25`] builds its posting lists in.
pub(crate) fn postings_arrays(vocab: usize, doc_lo: u32,
                              doc_terms: &[Vec<(u32, u16)>])
                              -> (Vec<u32>, Vec<u32>, Vec<u16>) {
    let mut offsets = vec![0u32; vocab + 1];
    for dt in doc_terms {
        for &(t, _) in dt {
            offsets[t as usize + 1] += 1;
        }
    }
    for t in 0..vocab {
        offsets[t + 1] += offsets[t];
    }
    let nnz = offsets[vocab] as usize;
    let mut docs = vec![0u32; nnz];
    let mut tfs = vec![0u16; nnz];
    let mut cursor: Vec<u32> = offsets[..vocab].to_vec();
    for (i, dt) in doc_terms.iter().enumerate() {
        let doc = doc_lo + i as u32;
        for &(t, tf) in dt {
            let p = cursor[t as usize] as usize;
            docs[p] = doc;
            tfs[p] = tf;
            cursor[t as usize] += 1;
        }
    }
    (offsets, docs, tfs)
}

fn postings_section(vocab: usize, doc_lo: u32,
                    doc_terms: &[Vec<(u32, u16)>]) -> Vec<u8> {
    let (offsets, docs, tfs) = postings_arrays(vocab, doc_lo, doc_terms);
    let mut out =
        Vec::with_capacity(4 * offsets.len() + 4 * docs.len()
                           + 2 * tfs.len());
    format::push_u32s(&mut out, &offsets);
    format::push_u32s(&mut out, &docs);
    format::push_u16s(&mut out, &tfs);
    out
}

fn docterms_section(doc_terms: &[Vec<(u32, u16)>]) -> Vec<u8> {
    let n = doc_terms.len();
    let nnz: usize = doc_terms.iter().map(|dt| dt.len()).sum();
    let mut out = Vec::with_capacity(4 * (n + 1) + 6 * nnz);
    let mut off = 0u32;
    format::push_u32(&mut out, 0);
    for dt in doc_terms {
        off += dt.len() as u32;
        format::push_u32(&mut out, off);
    }
    for dt in doc_terms {
        for &(t, _) in dt {
            format::push_u32(&mut out, t);
        }
    }
    for dt in doc_terms {
        for &(_, tf) in dt {
            out.extend_from_slice(&tf.to_le_bytes());
        }
    }
    out
}

/// `DENSE_SQ8` payload (`docs/FORMAT.md`): SoA per-row quantization
/// arrays — scale, bias, asum, rerr (`n` f32 each), then row-major u8
/// codes (`n * dim`). Total length `16 * n + n * dim`.
fn dense_sq8_section(rows: &[f32], dim: usize) -> Vec<u8> {
    let q = Sq8Rows::encode(rows, dim);
    let n = q.len();
    let mut out = Vec::with_capacity(16 * n + n * dim);
    format::push_f32s(&mut out, &q.scale);
    format::push_f32s(&mut out, &q.bias);
    format::push_f32s(&mut out, &q.asum);
    format::push_f32s(&mut out, &q.rerr);
    out.extend_from_slice(&q.codes);
    out
}

fn graph_section(g: &CsrExport) -> Vec<u8> {
    let mut out = Vec::new();
    format::push_u32(&mut out, g.m as u32);
    format::push_u32(&mut out, g.m0 as u32);
    format::push_u32(&mut out, g.ef_construction as u32);
    format::push_u32(&mut out, g.entry);
    format::push_u32(&mut out, g.max_level as u32);
    format::push_u32(&mut out, g.node_levels.len() as u32);
    format::push_u32(&mut out, g.levels.len() as u32);
    format::push_u32(&mut out, 0); // pad so seed is 8-aligned
    format::push_u64(&mut out, g.seed);
    format::push_u32s(&mut out, &g.node_levels);
    for (offsets, packed) in &g.levels {
        format::push_u32(&mut out, offsets.len() as u32);
        format::push_u32(&mut out, packed.len() as u32);
        format::push_u32s(&mut out, offsets);
        format::push_u32s(&mut out, packed);
    }
    out
}

fn parse_graph(payload: &[u8]) -> anyhow::Result<CsrExport> {
    let m = format::get_u32(payload, 0)? as usize;
    let m0 = format::get_u32(payload, 4)? as usize;
    let ef_construction = format::get_u32(payload, 8)? as usize;
    let entry = format::get_u32(payload, 12)?;
    let max_level = format::get_u32(payload, 16)? as usize;
    let n = format::get_u32(payload, 20)? as usize;
    let n_levels = format::get_u32(payload, 24)? as usize;
    let seed = format::get_u64(payload, 32)?;
    let node_levels = format::decode_u32s(payload, 40, n)?;
    let mut off = 40 + 4 * n;
    let mut levels = Vec::with_capacity(n_levels);
    for _ in 0..n_levels {
        let ol = format::get_u32(payload, off)? as usize;
        let pl = format::get_u32(payload, off + 4)? as usize;
        let offsets = format::decode_u32s(payload, off + 8, ol)?;
        let packed = format::decode_u32s(payload, off + 8 + 4 * ol, pl)?;
        off += 8 + 4 * (ol + pl);
        levels.push((offsets, packed));
    }
    Ok(CsrExport { m, m0, ef_construction, seed, entry, max_level,
                   node_levels, levels })
}

/// Serialize one segment to its full `RSEG` byte image.
pub(crate) fn build_segment_bytes(b: &SegmentBuild) -> Vec<u8> {
    let total_doc_len: u64 =
        b.docs.iter().map(|d| d.tokens.len() as u64).sum();
    let mut w = SegmentWriter::new();
    w.push_section(format::TAG_META, meta_section(b, total_doc_len));
    w.push_section(format::TAG_DOCS, docs_section(b.docs));
    match b.kind {
        RetrieverKind::Edr | RetrieverKind::Adr => {
            debug_assert_eq!(b.rows.len(), b.docs.len() * b.dim);
            let mut dense = Vec::with_capacity(4 * b.rows.len());
            format::push_f32s(&mut dense, b.rows);
            w.push_section(format::TAG_DENSE, dense);
            if b.sq8 {
                w.push_section(format::TAG_DENSE_SQ8,
                               dense_sq8_section(b.rows, b.dim));
            }
        }
        RetrieverKind::Sr => {
            debug_assert_eq!(b.doc_terms.len(), b.docs.len());
            w.push_section(format::TAG_POSTINGS,
                           postings_section(b.vocab, b.doc_lo,
                                            b.doc_terms));
            let mut dl = Vec::with_capacity(4 * b.docs.len());
            for d in b.docs {
                format::push_u32(&mut dl, d.tokens.len() as u32);
            }
            w.push_section(format::TAG_DOCLEN, dl);
            w.push_section(format::TAG_DOCTERMS,
                           docterms_section(b.doc_terms));
        }
    }
    if let Some(g) = b.graph {
        w.push_section(format::TAG_GRAPH, graph_section(g));
    }
    w.finish()
}

// ---------------------------------------------------------------------
// Segment: a loaded, validated, view-carrying segment file.

/// Packed BM25 postings over one segment's doc range: per-term offsets
/// (`vocab + 1`), then global doc ids and tfs, doc-ascending per term.
#[derive(Clone)]
pub(crate) struct PostingsView {
    pub offsets: U32View,
    pub docs: U32View,
    pub tfs: U16View,
}

/// Per-doc sorted (term, tf) stats: offsets (`n + 1`), terms, tfs.
#[derive(Clone)]
pub(crate) struct DocTermsView {
    pub offsets: U32View,
    pub terms: U32View,
    pub tfs: U16View,
}

/// SQ8 quantization arrays over one segment's dense rows
/// (`DENSE_SQ8` in `docs/FORMAT.md`): per-row scale/bias/asum/rerr,
/// then row-major u8 codes. Only ever present alongside a full-
/// precision `DENSE` section — the exact re-score phase reads f32 rows.
#[derive(Clone)]
pub(crate) struct Sq8View {
    pub scale: F32View,
    pub bias: F32View,
    pub asum: F32View,
    pub rerr: F32View,
    pub codes: U8View,
}

impl Sq8View {
    /// Borrow the whole segment's arrays as a scan-ready row view.
    pub fn as_rows_ref(&self) -> Sq8RowsRef<'_> {
        Sq8RowsRef {
            scale: self.scale.as_slice(),
            bias: self.bias.as_slice(),
            asum: self.asum.as_slice(),
            rerr: self.rerr.as_slice(),
            codes: self.codes.as_slice(),
        }
    }
}

/// One immutable on-disk segment, loaded (zero-copy via mmap where the
/// platform allows) and checksum-validated.
///
/// ```
/// use ralmspec::config::{Config, CorpusConfig, RetrieverKind};
/// use ralmspec::datagen::embedding::{embed_corpus, HashEncoder};
/// use ralmspec::datagen::Corpus;
/// use ralmspec::retriever::segment::{SegmentStore, SegmentedKb};
///
/// let mut cfg = Config::default();
/// cfg.corpus = CorpusConfig { n_docs: 50, n_topics: 4, doc_len: (8, 16),
///                             ..CorpusConfig::default() };
/// let corpus = Corpus::generate(&cfg.corpus);
/// let enc = HashEncoder::new(16, 1);
/// let rows = embed_corpus(&enc, &corpus);
/// let dir = std::env::temp_dir()
///     .join(format!("ralmspec-segment-doc-{}", std::process::id()));
/// let _ = std::fs::remove_dir_all(&dir);
/// SegmentedKb::create(&dir, &cfg, RetrieverKind::Edr, &corpus, &rows, 16)
///     .unwrap();
///
/// let store = SegmentStore::open(&dir).unwrap();
/// let seg = &store.segments()[0];
/// assert_eq!(seg.kind(), RetrieverKind::Edr);
/// assert_eq!(seg.doc_range(), (0, 50));
/// assert_eq!(seg.n_docs(), 50);
/// std::fs::remove_dir_all(&dir).unwrap();
/// ```
pub struct Segment {
    name: String,
    kind: RetrieverKind,
    doc_lo: u32,
    doc_hi: u32,
    dim: usize,
    vocab: usize,
    total_doc_len: u64,
    file: SegmentFile,
    pub(crate) dense: Option<F32View>,
    pub(crate) sq8: Option<Sq8View>,
    pub(crate) post: Option<PostingsView>,
    pub(crate) doc_len: Option<U32View>,
    pub(crate) doc_terms: Option<DocTermsView>,
}

impl Segment {
    /// Load and validate a segment file. Every section checksum is
    /// verified before any payload is interpreted.
    pub fn load(path: &Path) -> anyhow::Result<Self> {
        let name = path
            .file_name()
            .and_then(|s| s.to_str())
            .ok_or_else(|| anyhow::anyhow!("bad segment path {}",
                                           path.display()))?
            .to_string();
        let blob = Arc::new(Blob::open(path)?);
        let file = SegmentFile::parse(blob)?;
        let (moff, mlen) = file.require(format::TAG_META)?;
        anyhow::ensure!(mlen >= 32, "META section too short ({mlen})");
        let meta = file.payload(moff, mlen);
        let kind = kind_from_code(format::get_u32(meta, 0)?)?;
        let doc_lo = format::get_u32(meta, 4)?;
        let doc_hi = format::get_u32(meta, 8)?;
        let dim = format::get_u32(meta, 12)? as usize;
        let vocab = format::get_u32(meta, 16)? as usize;
        let total_doc_len = format::get_u64(meta, 24)?;
        anyhow::ensure!(doc_lo <= doc_hi, "inverted doc range");
        let n = (doc_hi - doc_lo) as usize;

        let dense = match file.section(format::TAG_DENSE) {
            Some((off, len)) => {
                anyhow::ensure!(len == 4 * n * dim,
                                "DENSE len {len} != 4 * {n} * {dim}");
                Some(F32View::from_blob(&file.blob, off, n * dim)?)
            }
            None => None,
        };
        let sq8 = match file.section(format::TAG_DENSE_SQ8) {
            Some((off, len)) => {
                anyhow::ensure!(dense.is_some(),
                                "DENSE_SQ8 section without DENSE");
                anyhow::ensure!(
                    len == 16 * n + n * dim,
                    "DENSE_SQ8 len {len} != 16 * {n} + {n} * {dim}");
                Some(Sq8View {
                    scale: F32View::from_blob(&file.blob, off, n)?,
                    bias: F32View::from_blob(&file.blob, off + 4 * n,
                                             n)?,
                    asum: F32View::from_blob(&file.blob, off + 8 * n,
                                             n)?,
                    rerr: F32View::from_blob(&file.blob, off + 12 * n,
                                             n)?,
                    codes: U8View::from_blob(&file.blob, off + 16 * n,
                                             n * dim)?,
                })
            }
            None => None,
        };
        let post = match file.section(format::TAG_POSTINGS) {
            Some((off, len)) => {
                let head = 4 * (vocab + 1);
                anyhow::ensure!(len >= head && (len - head) % 6 == 0,
                                "POSTINGS len {len} malformed");
                let nnz = (len - head) / 6;
                Some(PostingsView {
                    offsets: U32View::from_blob(&file.blob, off,
                                                vocab + 1)?,
                    docs: U32View::from_blob(&file.blob, off + head,
                                             nnz)?,
                    tfs: U16View::from_blob(&file.blob,
                                            off + head + 4 * nnz, nnz)?,
                })
            }
            None => None,
        };
        let doc_len = match file.section(format::TAG_DOCLEN) {
            Some((off, len)) => {
                anyhow::ensure!(len == 4 * n, "DOCLEN len {len} != 4n");
                Some(U32View::from_blob(&file.blob, off, n)?)
            }
            None => None,
        };
        let doc_terms = match file.section(format::TAG_DOCTERMS) {
            Some((off, len)) => {
                let head = 4 * (n + 1);
                anyhow::ensure!(len >= head && (len - head) % 6 == 0,
                                "DOCTERMS len {len} malformed");
                let nnz = (len - head) / 6;
                Some(DocTermsView {
                    offsets: U32View::from_blob(&file.blob, off, n + 1)?,
                    terms: U32View::from_blob(&file.blob, off + head,
                                              nnz)?,
                    tfs: U16View::from_blob(&file.blob,
                                            off + head + 4 * nnz, nnz)?,
                })
            }
            None => None,
        };
        Ok(Self { name, kind, doc_lo, doc_hi, dim, vocab, total_doc_len,
                  file, dense, sq8, post, doc_len, doc_terms })
    }

    /// The on-disk file name (e.g. `seg-000001.rseg`).
    pub fn file_name(&self) -> &str {
        &self.name
    }

    pub fn kind(&self) -> RetrieverKind {
        self.kind
    }

    /// The contiguous global doc-id range `[lo, hi)` this segment holds.
    pub fn doc_range(&self) -> (u32, u32) {
        (self.doc_lo, self.doc_hi)
    }

    pub fn n_docs(&self) -> usize {
        (self.doc_hi - self.doc_lo) as usize
    }

    pub(crate) fn dim(&self) -> usize {
        self.dim
    }

    pub(crate) fn vocab(&self) -> usize {
        self.vocab
    }

    pub(crate) fn total_doc_len(&self) -> u64 {
        self.total_doc_len
    }

    /// True when the backing file is a live mmap (vs a heap read) — the
    /// storage bench reports this so a silent fallback is visible.
    pub fn is_mapped(&self) -> bool {
        self.file.blob.is_mapped()
    }

    /// Decode the raw documents (cold-load corpus reconstruction).
    pub fn docs(&self) -> anyhow::Result<Vec<Document>> {
        let (off, len) = self.file.require(format::TAG_DOCS)?;
        parse_docs(self.file.payload(off, len), self.doc_lo,
                   self.n_docs())
    }

    /// Package this segment as a dense read tier (shared mmap views).
    pub(crate) fn dense_tier(&self) -> Option<super::tiered::DenseTier> {
        self.dense.clone().map(|rows| super::tiered::DenseTier {
            doc_lo: self.doc_lo,
            doc_hi: self.doc_hi,
            rows,
            sq8: self.sq8.clone(),
        })
    }

    /// Package this segment as a sparse read tier (shared mmap views).
    pub(crate) fn sparse_tier(&self)
                              -> Option<super::tiered::SparseTier> {
        match (&self.post, &self.doc_len, &self.doc_terms) {
            (Some(post), Some(doc_len), Some(doc_terms)) => {
                Some(super::tiered::SparseTier {
                    doc_lo: self.doc_lo,
                    doc_hi: self.doc_hi,
                    post: post.clone(),
                    doc_len: doc_len.clone(),
                    doc_terms: doc_terms.clone(),
                })
            }
            _ => None,
        }
    }

    /// The persisted HNSW adjacency, if this segment carries one.
    pub(crate) fn graph(&self) -> anyhow::Result<Option<CsrExport>> {
        match self.file.section(format::TAG_GRAPH) {
            Some((off, len)) => {
                Ok(Some(parse_graph(self.file.payload(off, len))?))
            }
            None => Ok(None),
        }
    }
}

// ---------------------------------------------------------------------
// SegmentStore: manifest, recovery, retention.

fn manifest_name(seq: u64) -> String {
    format!("MANIFEST-{seq:06}.json")
}

fn segment_name(id: u64) -> String {
    format!("seg-{id:06}.rseg")
}

/// Write `bytes` to `path` crash-safely: temp file in the same
/// directory, `sync_all`, atomic rename.
fn atomic_write(path: &Path, bytes: &[u8]) -> anyhow::Result<()> {
    let tmp = path.with_extension("tmp");
    {
        use std::io::Write;
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(bytes)?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path)?;
    Ok(())
}

struct ManifestDoc {
    seq: u64,
    next_seg: u64,
    files: Vec<String>,
}

fn parse_manifest(text: &str) -> anyhow::Result<ManifestDoc> {
    let v = json::parse(text)?;
    let seq = v.req("seq")?.as_u64()
        .ok_or_else(|| anyhow::anyhow!("manifest seq not a number"))?;
    let next_seg = v.req("next_segment_id")?.as_u64()
        .ok_or_else(|| anyhow::anyhow!("manifest next_segment_id bad"))?;
    let files = v.req("segments")?.as_arr()
        .ok_or_else(|| anyhow::anyhow!("manifest segments not a list"))?
        .iter()
        .map(|f| f.as_str().map(str::to_string)
            .ok_or_else(|| anyhow::anyhow!("segment name not a string")))
        .collect::<anyhow::Result<Vec<String>>>()?;
    Ok(ManifestDoc { seq, next_seg, files })
}

/// The tiered store's on-disk root: a directory of immutable segment
/// files plus numbered manifests naming the live set.
///
/// ```
/// use ralmspec::config::{Config, CorpusConfig, RetrieverKind};
/// use ralmspec::datagen::embedding::{embed_corpus, HashEncoder};
/// use ralmspec::datagen::Corpus;
/// use ralmspec::retriever::segment::{SegmentStore, SegmentedKb};
///
/// let mut cfg = Config::default();
/// cfg.corpus = CorpusConfig { n_docs: 40, n_topics: 4, doc_len: (8, 16),
///                             ..CorpusConfig::default() };
/// let corpus = Corpus::generate(&cfg.corpus);
/// let enc = HashEncoder::new(16, 2);
/// let rows = embed_corpus(&enc, &corpus);
/// let dir = std::env::temp_dir()
///     .join(format!("ralmspec-store-doc-{}", std::process::id()));
/// let _ = std::fs::remove_dir_all(&dir);
/// SegmentedKb::create(&dir, &cfg, RetrieverKind::Sr, &corpus, &rows, 16)
///     .unwrap();
///
/// // Recovery = open the newest manifest whose segments all validate.
/// let store = SegmentStore::open(&dir).unwrap();
/// assert_eq!(store.segments().len(), 1);
/// assert_eq!(store.segments()[0].doc_range(), (0, 40));
/// std::fs::remove_dir_all(&dir).unwrap();
/// ```
pub struct SegmentStore {
    dir: PathBuf,
    seq: u64,
    next_seg: u64,
    segments: Vec<Segment>,
}

impl SegmentStore {
    /// True if `dir` holds a store (any manifest present).
    pub fn exists(dir: &Path) -> bool {
        std::fs::read_dir(dir).map(|entries| {
            entries.flatten().any(|e| {
                e.file_name().to_string_lossy().starts_with("MANIFEST-")
            })
        }).unwrap_or(false)
    }

    /// Initialize an empty store (writes `MANIFEST-000001`). Fails if a
    /// manifest already exists — recovery must go through [`open`].
    ///
    /// [`open`]: SegmentStore::open
    pub fn create(dir: &Path) -> anyhow::Result<Self> {
        std::fs::create_dir_all(dir)?;
        anyhow::ensure!(!Self::exists(dir),
                        "segment store already exists in {}",
                        dir.display());
        let mut store = Self {
            dir: dir.to_path_buf(),
            seq: 0,
            next_seg: 1,
            segments: Vec::new(),
        };
        store.write_manifest()?;
        Ok(store)
    }

    /// Recover the store: try the `CURRENT`-named manifest first, then
    /// every other manifest newest-first, accepting the first whose
    /// segment files all load and checksum-validate with a contiguous
    /// doc range from 0.
    pub fn open(dir: &Path) -> anyhow::Result<Self> {
        let mut candidates: Vec<(u64, String)> = Vec::new();
        for entry in std::fs::read_dir(dir)? {
            let name = entry?.file_name().to_string_lossy().to_string();
            if let Some(seq) = name
                .strip_prefix("MANIFEST-")
                .and_then(|s| s.strip_suffix(".json"))
                .and_then(|s| s.parse::<u64>().ok())
            {
                candidates.push((seq, name));
            }
        }
        candidates.sort_by(|a, b| b.0.cmp(&a.0));
        if let Ok(cur) = std::fs::read_to_string(dir.join("CURRENT")) {
            let cur = cur.trim().to_string();
            if let Some(pos) =
                candidates.iter().position(|(_, n)| *n == cur)
            {
                let hint = candidates.remove(pos);
                candidates.insert(0, hint);
            }
        }
        anyhow::ensure!(!candidates.is_empty(),
                        "no manifest in {}", dir.display());
        let mut last_err = anyhow::anyhow!("unreachable");
        for (_, name) in &candidates {
            match Self::try_manifest(dir, name) {
                Ok(store) => return Ok(store),
                Err(e) => {
                    last_err = e.context(format!("manifest {name}"));
                }
            }
        }
        Err(last_err.context(format!(
            "no usable manifest among {} candidates in {}",
            candidates.len(), dir.display())))
    }

    fn try_manifest(dir: &Path, name: &str) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(dir.join(name))?;
        let doc = parse_manifest(&text)?;
        let mut segments = Vec::with_capacity(doc.files.len());
        for f in &doc.files {
            segments.push(Segment::load(&dir.join(f))?);
        }
        let mut expect = 0u32;
        for s in &segments {
            anyhow::ensure!(s.doc_lo == expect,
                            "segment doc ranges not contiguous: {} != {}",
                            s.doc_lo, expect);
            expect = s.doc_hi;
        }
        Ok(Self {
            dir: dir.to_path_buf(),
            seq: doc.seq,
            next_seg: doc.next_seg,
            segments,
        })
    }

    /// The live segments, ascending contiguous doc ranges from 0.
    pub fn segments(&self) -> &[Segment] {
        &self.segments
    }

    /// Total documents across all segments.
    pub fn n_docs(&self) -> usize {
        self.segments.last().map_or(0, |s| s.doc_hi as usize)
    }

    /// Persist a new segment and publish a manifest including it.
    pub(crate) fn add_segment(&mut self, bytes: &[u8])
                              -> anyhow::Result<()> {
        let seg = self.write_segment_file(bytes)?;
        self.segments.push(seg);
        self.write_manifest()
    }

    /// Persist a merged full-range segment and publish a manifest in
    /// which it replaces every previous segment (compaction commit).
    pub(crate) fn replace_all(&mut self, bytes: &[u8])
                              -> anyhow::Result<()> {
        let seg = self.write_segment_file(bytes)?;
        self.segments = vec![seg];
        self.write_manifest()
    }

    fn write_segment_file(&mut self, bytes: &[u8])
                          -> anyhow::Result<Segment> {
        let name = segment_name(self.next_seg);
        self.next_seg += 1;
        let path = self.dir.join(&name);
        atomic_write(&path, bytes)?;
        Segment::load(&path)
    }

    /// Write `MANIFEST-<seq+1>` + `CURRENT`, then garbage-collect files
    /// referenced by neither of the two newest manifests (keeping the
    /// previous manifest's files is what makes torn-write fallback
    /// possible).
    fn write_manifest(&mut self) -> anyhow::Result<()> {
        self.seq += 1;
        let name = manifest_name(self.seq);
        let files: Vec<String> = self
            .segments
            .iter()
            .map(|s| s.file_name().to_string())
            .collect();
        let doc = Value::obj(vec![
            ("seq", Value::num(self.seq as f64)),
            ("next_segment_id", Value::num(self.next_seg as f64)),
            ("segments",
             Value::Arr(files.iter()
                            .map(|f| Value::str(f.clone())).collect())),
        ]);
        atomic_write(&self.dir.join(&name), doc.pretty().as_bytes())?;
        atomic_write(&self.dir.join("CURRENT"), name.as_bytes())?;
        #[cfg(unix)]
        if let Ok(d) = std::fs::File::open(&self.dir) {
            let _ = d.sync_all();
        }
        self.collect_garbage(&files);
        Ok(())
    }

    /// Best-effort GC: remove segment files and manifests not needed by
    /// the two newest manifests. Errors are ignored — a leaked file is
    /// harmless, a failed publish is not.
    fn collect_garbage(&self, current_files: &[String]) {
        let mut keep: Vec<String> = current_files.to_vec();
        let prev = manifest_name(self.seq.saturating_sub(1));
        if let Ok(text) = std::fs::read_to_string(self.dir.join(&prev)) {
            if let Ok(doc) = parse_manifest(&text) {
                keep.extend(doc.files);
            }
        }
        let Ok(entries) = std::fs::read_dir(&self.dir) else {
            return;
        };
        for entry in entries.flatten() {
            let name = entry.file_name().to_string_lossy().to_string();
            let stale_seg = name.ends_with(".rseg")
                && !keep.iter().any(|k| *k == name);
            let stale_manifest = name
                .strip_prefix("MANIFEST-")
                .and_then(|s| s.strip_suffix(".json"))
                .and_then(|s| s.parse::<u64>().ok())
                .is_some_and(|seq| seq + 1 < self.seq);
            let stale_tmp = name.ends_with(".tmp");
            if stale_seg || stale_manifest || stale_tmp {
                let _ = std::fs::remove_file(entry.path());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CorpusConfig;
    use crate::datagen::corpus::Corpus;
    use crate::retriever::sparse::doc_term_stats;

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "ralmspec-store-{}-{name}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn small_corpus(n: usize) -> Corpus {
        Corpus::generate(&CorpusConfig {
            n_docs: n, n_topics: 4, doc_len: (8, 20),
            ..CorpusConfig::default()
        })
    }

    fn sr_build(corpus: &Corpus, lo: usize, hi: usize)
                -> (Vec<Document>, Vec<Vec<(u32, u16)>>) {
        let docs: Vec<Document> =
            corpus.iter().skip(lo).take(hi - lo).cloned().collect();
        let mut tf = vec![0u16; corpus.vocab];
        let dts = docs.iter()
            .map(|d| doc_term_stats(&d.tokens, &mut tf))
            .collect();
        (docs, dts)
    }

    #[test]
    fn sr_segment_roundtrips() {
        let c = small_corpus(30);
        let (docs, dts) = sr_build(&c, 0, 30);
        let bytes = build_segment_bytes(&SegmentBuild {
            kind: RetrieverKind::Sr,
            doc_lo: 0,
            docs: &docs,
            rows: &[],
            dim: 0,
            vocab: c.vocab,
            doc_terms: &dts,
            graph: None,
            sq8: false,
        });
        let dir = tmpdir("sr-roundtrip");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("seg.rseg");
        std::fs::write(&path, &bytes).unwrap();
        let seg = Segment::load(&path).unwrap();
        assert_eq!(seg.kind(), RetrieverKind::Sr);
        assert_eq!(seg.doc_range(), (0, 30));
        let back = seg.docs().unwrap();
        assert_eq!(back.len(), 30);
        for (a, b) in back.iter().zip(c.iter()) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.topic, b.topic);
            assert_eq!(a.tokens, b.tokens);
        }
        // Postings agree with a direct Bm25-build-order construction.
        let post = seg.post.as_ref().unwrap();
        let (offsets, pdocs, ptfs) =
            postings_arrays(c.vocab, 0, &dts);
        assert_eq!(post.offsets.as_slice(), &offsets[..]);
        assert_eq!(post.docs.as_slice(), &pdocs[..]);
        assert_eq!(post.tfs.as_slice(), &ptfs[..]);
        // Doc lengths and term stats.
        let dl = seg.doc_len.as_ref().unwrap();
        for (i, d) in docs.iter().enumerate() {
            assert_eq!(dl.as_slice()[i], d.tokens.len() as u32);
        }
        let dt = seg.doc_terms.as_ref().unwrap();
        let off = dt.offsets.as_slice();
        for (i, want) in dts.iter().enumerate() {
            let (a, b) = (off[i] as usize, off[i + 1] as usize);
            let terms = &dt.terms.as_slice()[a..b];
            let tfs = &dt.tfs.as_slice()[a..b];
            let got: Vec<(u32, u16)> = terms.iter().copied()
                .zip(tfs.iter().copied()).collect();
            assert_eq!(&got, want);
        }
        assert!(seg.graph().unwrap().is_none());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn edr_sq8_segment_roundtrips_bitwise() {
        let c = small_corpus(17);
        let docs: Vec<Document> = c.iter().cloned().collect();
        let dim = 12usize;
        let mut rng = crate::util::rng::Rng::new(0x5108);
        let rows: Vec<f32> = (0..docs.len() * dim)
            .map(|_| rng.next_f32() * 2.0 - 1.0)
            .collect();
        let bytes = build_segment_bytes(&SegmentBuild {
            kind: RetrieverKind::Edr,
            doc_lo: 0,
            docs: &docs,
            rows: &rows,
            dim,
            vocab: c.vocab,
            doc_terms: &[],
            graph: None,
            sq8: true,
        });
        let dir = tmpdir("sq8-roundtrip");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("seg.rseg");
        std::fs::write(&path, &bytes).unwrap();
        let seg = Segment::load(&path).unwrap();
        // Full-precision rows survive untouched.
        let dense = seg.dense.as_ref().unwrap();
        assert_eq!(dense.as_slice(), &rows[..]);
        // Quantization arrays match a fresh in-RAM encode bitwise.
        let want = Sq8Rows::encode(&rows, dim);
        let got = seg.sq8.as_ref().unwrap().as_rows_ref();
        assert_eq!(got.codes, &want.codes[..]);
        for (g, w) in [(got.scale, &want.scale), (got.bias, &want.bias),
                       (got.asum, &want.asum), (got.rerr, &want.rerr)]
        {
            assert_eq!(g.len(), w.len());
            for (a, b) in g.iter().zip(w.iter()) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn store_add_open_and_fallback() {
        let dir = tmpdir("fallback");
        let c = small_corpus(24);
        let mut store = SegmentStore::create(&dir).unwrap();
        let (d1, t1) = sr_build(&c, 0, 16);
        store.add_segment(&build_segment_bytes(&SegmentBuild {
            kind: RetrieverKind::Sr, doc_lo: 0, docs: &d1, rows: &[],
            dim: 0, vocab: c.vocab, doc_terms: &t1, graph: None,
            sq8: false,
        })).unwrap();
        let (d2, t2) = sr_build(&c, 16, 24);
        store.add_segment(&build_segment_bytes(&SegmentBuild {
            kind: RetrieverKind::Sr, doc_lo: 16, docs: &d2, rows: &[],
            dim: 0, vocab: c.vocab, doc_terms: &t2, graph: None,
            sq8: false,
        })).unwrap();
        drop(store);

        let reopened = SegmentStore::open(&dir).unwrap();
        assert_eq!(reopened.segments().len(), 2);
        assert_eq!(reopened.n_docs(), 24);

        // Torn write: truncate the newest segment file. Recovery must
        // reject the newest manifest (checksum failure) and fall back to
        // the previous one, which references only the first segment.
        let newest = reopened.segments()[1].file_name().to_string();
        drop(reopened);
        let path = dir.join(&newest);
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        let recovered = SegmentStore::open(&dir).unwrap();
        assert_eq!(recovered.segments().len(), 1);
        assert_eq!(recovered.n_docs(), 16);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn gc_keeps_two_manifests_of_files() {
        let dir = tmpdir("gc");
        let c = small_corpus(20);
        let mut store = SegmentStore::create(&dir).unwrap();
        for (lo, hi) in [(0usize, 10usize), (10, 20)] {
            let (d, t) = sr_build(&c, lo, hi);
            store.add_segment(&build_segment_bytes(&SegmentBuild {
                kind: RetrieverKind::Sr, doc_lo: lo as u32, docs: &d,
                rows: &[], dim: 0, vocab: c.vocab, doc_terms: &t,
                graph: None, sq8: false,
            })).unwrap();
        }
        // Compact: replace both with one full segment. The two old
        // segment files must survive (previous manifest still lists
        // them) until the *next* manifest write.
        let (d, t) = sr_build(&c, 0, 20);
        store.replace_all(&build_segment_bytes(&SegmentBuild {
            kind: RetrieverKind::Sr, doc_lo: 0, docs: &d, rows: &[],
            dim: 0, vocab: c.vocab, doc_terms: &t, graph: None,
            sq8: false,
        })).unwrap();
        let names: Vec<String> = std::fs::read_dir(&dir).unwrap()
            .flatten()
            .map(|e| e.file_name().to_string_lossy().to_string())
            .collect();
        // seg-000003 is the compacted output; seg-000001/2 are still
        // listed by the previous manifest and must survive this GC.
        assert!(names.iter().any(|n| n == "seg-000003.rseg"),
                "compacted segment missing: {names:?}");
        assert!(names.iter().any(|n| n == "seg-000001.rseg"),
                "previous-manifest file GC'd too early: {names:?}");
        assert!(names.iter().any(|n| n == "seg-000002.rseg"),
                "previous-manifest file GC'd too early: {names:?}");
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
