//! The `RSEG` on-disk container: versioned magic/header, a checksummed
//! section table, and 16-byte-aligned little-endian payload sections.
//!
//! `docs/FORMAT.md` is the byte-for-byte normative spec for this file
//! layout; the `format_spec_matches_impl` test asserts the constants and
//! offsets documented there against this serializer, so spec and
//! implementation cannot drift apart silently.
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! [0..4)        magic "RSEG"
//! [4..8)        format version (u32, currently 1)
//! [8..12)       section count k (u32)
//! [12..16)      pad (u32, zero)
//! [16..16+32k)  section table, one 32-byte entry per section:
//!                 +0  tag (u32)      +4  pad (u32, zero)
//!                 +8  offset (u64)   +16 len (u64)
//!                 +24 checksum (u64, FNV-1a 64 of the payload bytes)
//! [16+32k..24+32k)  table checksum (u64, FNV-1a 64 of the table bytes)
//! ...           payload sections, each 16-byte aligned, zero padding
//!               between sections
//! ```
//!
//! Readers validate magic, version, bounds, the table checksum, and every
//! per-section checksum before any payload byte is interpreted — a torn
//! or truncated write is rejected wholesale at open, which is what lets
//! [`super::store::SegmentStore`] fall back to the previous manifest.

use crate::runtime::Blob;
use std::sync::Arc;

/// File magic, bytes `[0..4)` of every segment.
pub const MAGIC: [u8; 4] = *b"RSEG";
/// Format version, bytes `[4..8)`.
pub const VERSION: u32 = 1;
/// Fixed header length in bytes (magic + version + count + pad).
pub const HEADER_LEN: usize = 16;
/// One section-table entry: tag, pad, offset, len, checksum.
pub const SECTION_ENTRY_LEN: usize = 32;
/// Every payload section starts on a multiple of this (so `f32`/`u32`
/// views over an mmap'ed file are always correctly aligned).
pub const SECTION_ALIGN: usize = 16;
/// FNV-1a 64 offset basis.
pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
/// FNV-1a 64 prime.
pub const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Segment metadata (kind, doc range, dims, total doc length).
pub const TAG_META: u32 = 1;
/// Raw documents: token offsets, topics, packed token ids.
pub const TAG_DOCS: u32 = 2;
/// Dense embedding rows (`(doc_hi - doc_lo) * dim` little-endian f32s).
pub const TAG_DENSE: u32 = 3;
/// Packed BM25 postings: per-term offsets, global doc ids, term freqs.
pub const TAG_POSTINGS: u32 = 4;
/// Per-document token counts (u32 each).
pub const TAG_DOCLEN: u32 = 5;
/// Per-document sorted (term, tf) stats: offsets, terms, tfs.
pub const TAG_DOCTERMS: u32 = 6;
/// Sealed HNSW CSR adjacency (full-range ADR segments only).
pub const TAG_GRAPH: u32 = 7;
/// SQ8 scalar-quantized dense rows (optional, EDR segments with
/// `dense.codec = sq8`): per-row scale/bias/asum/rerr f32 arrays followed
/// by `n * dim` u8 codes — see docs/FORMAT.md. Always accompanied by a
/// full-precision `DENSE` section (the exact re-score source).
pub const TAG_DENSE_SQ8: u32 = 8;

/// FNV-1a 64 over `bytes` — the only checksum the format uses.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Round `n` up to the next multiple of `align` (a power of two).
pub(crate) fn align_up(n: usize, align: usize) -> usize {
    (n + align - 1) & !(align - 1)
}

// ---------------------------------------------------------------------
// Little-endian encode helpers (writer side).

pub(crate) fn push_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn push_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn push_u32s(out: &mut Vec<u8>, vals: &[u32]) {
    for &v in vals {
        push_u32(out, v);
    }
}

pub(crate) fn push_u16s(out: &mut Vec<u8>, vals: &[u16]) {
    for &v in vals {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

pub(crate) fn push_f32s(out: &mut Vec<u8>, vals: &[f32]) {
    for &v in vals {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

// ---------------------------------------------------------------------
// Bounds-checked little-endian decode helpers (reader side).

fn slice_at<'a>(b: &'a [u8], off: usize, len: usize)
                -> anyhow::Result<&'a [u8]> {
    b.get(off..off.checked_add(len).unwrap_or(usize::MAX))
        .ok_or_else(|| anyhow::anyhow!(
            "segment truncated: need [{off}, {off}+{len}) of {}", b.len()))
}

pub(crate) fn get_u32(b: &[u8], off: usize) -> anyhow::Result<u32> {
    let s = slice_at(b, off, 4)?;
    Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
}

pub(crate) fn get_u64(b: &[u8], off: usize) -> anyhow::Result<u64> {
    let s = slice_at(b, off, 8)?;
    Ok(u64::from_le_bytes([s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7]]))
}

/// Decode `n` little-endian u32s starting at `off`.
pub(crate) fn decode_u32s(b: &[u8], off: usize, n: usize)
                          -> anyhow::Result<Vec<u32>> {
    let s = slice_at(b, off, n * 4)?;
    Ok((0..n)
        .map(|i| u32::from_le_bytes([s[4 * i], s[4 * i + 1],
                                     s[4 * i + 2], s[4 * i + 3]]))
        .collect())
}

pub(crate) fn decode_u16s(b: &[u8], off: usize, n: usize)
                          -> anyhow::Result<Vec<u16>> {
    let s = slice_at(b, off, n * 2)?;
    Ok((0..n)
        .map(|i| u16::from_le_bytes([s[2 * i], s[2 * i + 1]]))
        .collect())
}

/// Decode `n` raw bytes starting at `off` (the u8-code fallback of the
/// `U8View` typed view — bounds-checked like its wider siblings).
pub(crate) fn decode_u8s(b: &[u8], off: usize, n: usize)
                         -> anyhow::Result<Vec<u8>> {
    Ok(slice_at(b, off, n)?.to_vec())
}

pub(crate) fn decode_f32s(b: &[u8], off: usize, n: usize)
                          -> anyhow::Result<Vec<f32>> {
    let s = slice_at(b, off, n * 4)?;
    Ok((0..n)
        .map(|i| f32::from_le_bytes([s[4 * i], s[4 * i + 1],
                                     s[4 * i + 2], s[4 * i + 3]]))
        .collect())
}

// ---------------------------------------------------------------------
// Writer.

/// Assembles one segment file: push payload sections, then [`finish`]
/// lays out header + checksummed table + aligned payloads.
///
/// [`finish`]: SegmentWriter::finish
pub(crate) struct SegmentWriter {
    sections: Vec<(u32, Vec<u8>)>,
}

impl SegmentWriter {
    pub fn new() -> Self {
        Self { sections: Vec::new() }
    }

    pub fn push_section(&mut self, tag: u32, payload: Vec<u8>) {
        debug_assert!(!self.sections.iter().any(|(t, _)| *t == tag),
                      "duplicate section tag {tag}");
        self.sections.push((tag, payload));
    }

    /// Serialize to the final byte image (see the module docs for the
    /// layout).
    pub fn finish(self) -> Vec<u8> {
        let k = self.sections.len();
        let table_end = HEADER_LEN + k * SECTION_ENTRY_LEN + 8;
        // Assign aligned payload offsets.
        let mut offsets = Vec::with_capacity(k);
        let mut off = align_up(table_end, SECTION_ALIGN);
        for (_, payload) in &self.sections {
            offsets.push(off);
            off = align_up(off + payload.len(), SECTION_ALIGN);
        }
        let total = offsets
            .last()
            .map(|&o| {
                // Snapshot of `off` before its final align_up would also
                // work; recompute from the last section for clarity.
                let last_len = self.sections[k - 1].1.len();
                o + last_len
            })
            .unwrap_or(table_end);

        let mut out = Vec::with_capacity(total);
        out.extend_from_slice(&MAGIC);
        push_u32(&mut out, VERSION);
        push_u32(&mut out, k as u32);
        push_u32(&mut out, 0); // pad

        let mut table = Vec::with_capacity(k * SECTION_ENTRY_LEN);
        for (i, (tag, payload)) in self.sections.iter().enumerate() {
            push_u32(&mut table, *tag);
            push_u32(&mut table, 0); // pad
            push_u64(&mut table, offsets[i] as u64);
            push_u64(&mut table, payload.len() as u64);
            push_u64(&mut table, fnv1a64(payload));
        }
        let table_sum = fnv1a64(&table);
        out.extend_from_slice(&table);
        push_u64(&mut out, table_sum);

        for (i, (_, payload)) in self.sections.iter().enumerate() {
            out.resize(offsets[i], 0); // zero pad up to the aligned start
            out.extend_from_slice(payload);
        }
        out
    }
}

// ---------------------------------------------------------------------
// Reader.

#[derive(Debug, Clone, Copy)]
struct SectionEntry {
    tag: u32,
    off: usize,
    len: usize,
}

/// A parsed, checksum-validated segment file over its backing [`Blob`].
///
/// Parsing validates everything up front (magic, version, table bounds,
/// table checksum, per-section bounds and checksums); accessors after a
/// successful parse cannot fail on corruption.
pub(crate) struct SegmentFile {
    pub blob: Arc<Blob>,
    sections: Vec<SectionEntry>,
}

impl SegmentFile {
    pub fn parse(blob: Arc<Blob>) -> anyhow::Result<Self> {
        let b = blob.bytes();
        let magic = slice_at(b, 0, 4)?;
        anyhow::ensure!(magic == MAGIC, "bad segment magic {magic:02x?}");
        let version = get_u32(b, 4)?;
        anyhow::ensure!(version == VERSION,
                        "unsupported segment version {version}");
        let k = get_u32(b, 8)? as usize;
        let table_off = HEADER_LEN;
        let table_len = k * SECTION_ENTRY_LEN;
        let table = slice_at(b, table_off, table_len)?;
        let stored_sum = get_u64(b, table_off + table_len)?;
        anyhow::ensure!(fnv1a64(table) == stored_sum,
                        "segment section table checksum mismatch");
        let mut sections = Vec::with_capacity(k);
        for i in 0..k {
            let e = table_off + i * SECTION_ENTRY_LEN;
            let tag = get_u32(b, e)?;
            let off = get_u64(b, e + 8)?;
            let len = get_u64(b, e + 16)?;
            let sum = get_u64(b, e + 24)?;
            let off = usize::try_from(off)
                .map_err(|_| anyhow::anyhow!("section offset overflow"))?;
            let len = usize::try_from(len)
                .map_err(|_| anyhow::anyhow!("section len overflow"))?;
            anyhow::ensure!(off % SECTION_ALIGN == 0,
                            "section {tag} offset {off} unaligned");
            let payload = slice_at(b, off, len)?;
            anyhow::ensure!(fnv1a64(payload) == sum,
                            "section {tag} checksum mismatch");
            anyhow::ensure!(
                !sections.iter().any(|s: &SectionEntry| s.tag == tag),
                "duplicate section tag {tag}");
            sections.push(SectionEntry { tag, off, len });
        }
        Ok(Self { blob, sections })
    }

    /// (offset, len) of the section with `tag`, if present.
    pub fn section(&self, tag: u32) -> Option<(usize, usize)> {
        self.sections
            .iter()
            .find(|s| s.tag == tag)
            .map(|s| (s.off, s.len))
    }

    pub fn require(&self, tag: u32) -> anyhow::Result<(usize, usize)> {
        self.section(tag)
            .ok_or_else(|| anyhow::anyhow!("segment missing section {tag}"))
    }

    /// The raw payload bytes of a section already validated by `parse`.
    pub fn payload(&self, off: usize, len: usize) -> &[u8] {
        &self.blob.bytes()[off..off + len]
    }
}

// ---------------------------------------------------------------------
// Typed views: zero-copy slices over mapped section bytes where the
// platform allows it, decoded owned vectors otherwise. Constructors
// validate alignment once; on big-endian hosts every view decodes (the
// on-disk format is little-endian).

macro_rules! typed_view {
    ($name:ident, $ty:ty, $decode:ident, $width:expr) => {
        /// A typed view over one packed array inside a segment section:
        /// `Mapped` borrows the (aligned) mmap'ed bytes zero-copy,
        /// `Owned` holds decoded values (heap-read fallback, misaligned
        /// bytes, big-endian hosts, or frozen in-RAM memtable tiers).
        #[derive(Clone)]
        pub(crate) enum $name {
            Mapped { blob: Arc<Blob>, off: usize, n: usize },
            Owned(Arc<Vec<$ty>>),
        }

        impl $name {
            /// View `n` values at byte offset `off` inside `blob`,
            /// borrowing zero-copy when the bytes are properly aligned
            /// (mmap + 16-byte section alignment guarantees this on the
            /// mapped path) and decoding otherwise.
            pub fn from_blob(blob: &Arc<Blob>, off: usize, n: usize)
                             -> anyhow::Result<Self> {
                let bytes = blob
                    .bytes()
                    .get(off..off + n * $width)
                    .ok_or_else(|| anyhow::anyhow!(
                        "typed view out of section bounds"))?;
                #[cfg(target_endian = "little")]
                {
                    // SAFETY: align_to on POD scalar types; we only
                    // inspect the split, never transmute invalid values
                    // (all bit patterns are valid for u16/u32/f32).
                    let (pre, mid, post) =
                        unsafe { bytes.align_to::<$ty>() };
                    if pre.is_empty() && post.is_empty() && mid.len() == n {
                        return Ok(Self::Mapped {
                            blob: blob.clone(),
                            off,
                            n,
                        });
                    }
                }
                Ok(Self::Owned(Arc::new($decode(bytes, 0, n)?)))
            }

            /// Wrap already-decoded values (memtable tiers).
            pub fn owned(vals: Vec<$ty>) -> Self {
                Self::Owned(Arc::new(vals))
            }

            pub fn as_slice(&self) -> &[$ty] {
                match self {
                    Self::Mapped { blob, off, n } => {
                        let bytes =
                            &blob.bytes()[*off..*off + *n * $width];
                        // SAFETY: alignment and length were validated in
                        // `from_blob`; the blob is immutable and outlives
                        // `&self`; all bit patterns are valid values.
                        let (pre, mid, post) =
                            unsafe { bytes.align_to::<$ty>() };
                        debug_assert!(pre.is_empty() && post.is_empty());
                        debug_assert_eq!(mid.len(), *n);
                        mid
                    }
                    Self::Owned(v) => v,
                }
            }

            pub fn len(&self) -> usize {
                match self {
                    Self::Mapped { n, .. } => *n,
                    Self::Owned(v) => v.len(),
                }
            }
        }
    };
}

typed_view!(F32View, f32, decode_f32s, 4);
typed_view!(U32View, u32, decode_u32s, 4);
typed_view!(U16View, u16, decode_u16s, 2);
typed_view!(U8View, u8, decode_u8s, 1);

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_file() -> Vec<u8> {
        let mut w = SegmentWriter::new();
        let mut meta = Vec::new();
        push_u32(&mut meta, 7);
        w.push_section(TAG_META, meta);
        let mut dense = Vec::new();
        push_f32s(&mut dense, &[1.0, -2.5, 3.25]);
        w.push_section(TAG_DENSE, dense);
        w.finish()
    }

    #[test]
    fn roundtrip_parses_and_reads() {
        let bytes = sample_file();
        let f = SegmentFile::parse(Arc::new(Blob::from_vec(bytes))).unwrap();
        let (off, len) = f.require(TAG_META).unwrap();
        assert_eq!(len, 4);
        assert_eq!(get_u32(f.payload(off, len), 0).unwrap(), 7);
        let (doff, dlen) = f.require(TAG_DENSE).unwrap();
        assert_eq!(dlen, 12);
        let v = F32View::from_blob(&f.blob, doff, 3).unwrap();
        assert_eq!(v.as_slice(), &[1.0, -2.5, 3.25]);
        assert!(f.section(TAG_GRAPH).is_none());
    }

    #[test]
    fn header_layout_is_as_documented() {
        let bytes = sample_file();
        assert_eq!(&bytes[0..4], b"RSEG");
        assert_eq!(get_u32(&bytes, 4).unwrap(), VERSION);
        assert_eq!(get_u32(&bytes, 8).unwrap(), 2); // section count
        assert_eq!(get_u32(&bytes, 12).unwrap(), 0); // pad
        // First table entry starts at HEADER_LEN; its offset field is
        // 16-byte aligned and past the table + table checksum.
        let off0 = get_u64(&bytes, HEADER_LEN + 8).unwrap() as usize;
        assert_eq!(off0 % SECTION_ALIGN, 0);
        assert!(off0 >= HEADER_LEN + 2 * SECTION_ENTRY_LEN + 8);
    }

    #[test]
    fn corruption_is_rejected() {
        let good = sample_file();
        // Flip one payload byte: the per-section checksum catches it.
        let mut bad = good.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0x40;
        assert!(SegmentFile::parse(Arc::new(Blob::from_vec(bad))).is_err());
        // Truncate mid-payload: bounds check catches it.
        let mut short = good.clone();
        short.truncate(good.len() - 4);
        assert!(
            SegmentFile::parse(Arc::new(Blob::from_vec(short))).is_err());
        // Corrupt the table itself: the table checksum catches it.
        let mut tbl = good.clone();
        tbl[HEADER_LEN] ^= 0x01;
        assert!(SegmentFile::parse(Arc::new(Blob::from_vec(tbl))).is_err());
        // Wrong magic.
        let mut magic = good;
        magic[0] = b'X';
        assert!(
            SegmentFile::parse(Arc::new(Blob::from_vec(magic))).is_err());
    }

    #[test]
    fn views_decode_owned_when_unaligned() {
        // An Owned copy from a deliberately misaligned byte offset must
        // still produce the right values (this is the heap-read and
        // big-endian fallback path).
        let mut bytes = vec![0u8; 1];
        push_u32s(&mut bytes, &[10, 20, 30]);
        let blob = Arc::new(Blob::from_vec(bytes));
        let v = U32View::from_blob(&blob, 1, 3).unwrap();
        assert_eq!(v.as_slice(), &[10, 20, 30]);
        assert_eq!(v.len(), 3);
    }

    #[test]
    fn fnv_vector() {
        // FNV-1a 64 test vectors (empty string hashes to the offset
        // basis; "a" to the classic published value).
        assert_eq!(fnv1a64(b""), FNV_OFFSET);
        assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
    }

    #[test]
    fn format_spec_matches_impl() {
        // docs/FORMAT.md is the normative spec: every constant the
        // serializer uses must appear there verbatim, so the document
        // cannot drift from the implementation.
        let path = concat!(env!("CARGO_MANIFEST_DIR"),
                           "/../docs/FORMAT.md");
        let spec = std::fs::read_to_string(path)
            .expect("docs/FORMAT.md must exist next to the rust crate");
        for needle in [
            "`RSEG`",
            "version: 1",
            "0xcbf29ce484222325",
            "0x100000001b3",
            "32-byte",
            "16-byte",
            "little-endian",
            "META = 1",
            "DOCS = 2",
            "DENSE = 3",
            "POSTINGS = 4",
            "DOCLEN = 5",
            "DOCTERMS = 6",
            "GRAPH = 7",
            "DENSE_SQ8 = 8",
        ] {
            assert!(spec.contains(needle),
                    "docs/FORMAT.md lost required spec text: {needle}");
        }
        // And the documented numerology matches the code.
        assert_eq!(HEADER_LEN, 16);
        assert_eq!(SECTION_ENTRY_LEN, 32);
        assert_eq!(SECTION_ALIGN, 16);
        assert_eq!(FNV_OFFSET, 0xcbf29ce484222325);
        assert_eq!(FNV_PRIME, 0x100000001b3);
        assert_eq!(
            [TAG_META, TAG_DOCS, TAG_DENSE, TAG_POSTINGS, TAG_DOCLEN,
             TAG_DOCTERMS, TAG_GRAPH, TAG_DENSE_SQ8],
            [1, 2, 3, 4, 5, 6, 7, 8]);
    }
}
