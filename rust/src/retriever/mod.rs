//! Retrieval substrates: the knowledge-base side of RaLMSpec.
//!
//! Three from-scratch retrievers mirror the paper's setups (§5.1):
//!   * [`dense::DenseExact`] — exact inner-product flat scan
//!     (FAISS IndexFlatIP / DPR stand-in, "EDR");
//!   * [`hnsw::Hnsw`] — approximate dense retrieval over an HNSW graph
//!     (DPR-HNSW stand-in, "ADR");
//!   * [`sparse::Bm25`] — BM25 over an inverted index (Pyserini stand-in,
//!     "SR").
//!
//! All three implement [`Retriever`]. The trait is **batch-first**
//! (DESIGN.md "Batch-first retrieval"): `retrieve_batch` is the required
//! primitive — it is what the verification step calls and what every
//! backend must amortize (Fig 6 / §A.1) — while `retrieve_topk` and
//! `retrieve` are derived as a batch of one. Deriving the single-query
//! path from the batched path (rather than the reverse) guarantees the two
//! share one numeric code path, which the output-equivalence property of
//! §3 depends on: a batched verification must reproduce the baseline's
//! single-query scores bit-for-bit.
//!
//! The trait also exposes the *same scoring metric* via
//! [`Retriever::score_doc`] / [`Retriever::score_docs`], which is what the
//! local speculation cache ranks with — the rank-preservation property of
//! §3 (if the KB top-1 is cached, the cache returns it) holds exactly
//! because both sides share this function. Note for ADR: `score_doc` is
//! the *exact* inner product while graph search is approximate, matching
//! how a real HNSW index scores candidates it visits.
//!
//! [`sharded::ShardedRetriever`] wraps any [`sharded::Shardable`] backend
//! in a scatter-gather engine over a persistent [`pool::WorkerPool`],
//! preserving bit-identical results (see DESIGN.md "Sharded retrieval").
//!
//! [`epoch`] adds the live-update path (DESIGN.md ADR-006): every backend
//! also has a writer-side [`epoch::MutableRetriever`] form (dense append,
//! HNSW incremental insert, posting-list append) whose immutable
//! snapshots are published atomically per epoch through
//! [`epoch::EpochKb`] — serving reads stay lock-free against pinned
//! snapshots while a [`epoch::KbWriter`] ingests new documents.
//!
//! [`segment`] adds the persistent, memory-bounded tier under the same
//! epoch machinery (DESIGN.md ADR-009): immutable mmap-backed segments
//! (docs/FORMAT.md) plus an in-RAM memtable, snapshotted as tiered
//! retrievers whose results are bit-identical to the in-RAM backends,
//! with a background [`segment::CompactionWorker`] folding tiers back
//! into one segment. Republishing an epoch costs O(memtable), not
//! O(corpus).
//!
//! [`kernels`] holds the scoring primitives all of the above call into
//! (DESIGN.md ADR-007): one dot-product / multi-query-scan / L2 kernel
//! with a scalar form and runtime-dispatched AVX2/NEON forms that are
//! bit-identical by construction — so the serving engine, the sequential
//! references, and the cache score through literally the same reduction
//! order, on any host.

pub mod dense;
pub mod epoch;
pub mod hnsw;
pub mod kernels;
pub mod pool;
pub mod segment;
pub mod sharded;
pub mod sparse;

pub use epoch::{EpochKb, EpochSnapshot, KbWriter, LiveKb,
                MutableRetriever};
pub use pool::{JobHandle, WorkerPool};
pub use segment::{CompactionWorker, Segment, SegmentStore, SegmentedKb};
pub use sharded::{ShardStrategy, Shardable, ShardedRetriever};

use crate::util::Scored;
use std::sync::Arc;
use std::time::Duration;

pub type DocId = u32;

/// A query carrying both retrieval views: the dense embedding (from the
/// AOT query encoder or the HashEncoder) and the raw term window (for BM25).
#[derive(Debug, Clone, PartialEq)]
pub struct SpecQuery {
    pub dense: Vec<f32>,
    pub terms: Vec<u32>,
}

impl SpecQuery {
    pub fn dense_only(v: Vec<f32>) -> Self {
        Self { dense: v, terms: Vec::new() }
    }

    pub fn sparse_only(terms: Vec<u32>) -> Self {
        Self { dense: Vec::new(), terms }
    }
}

/// The knowledge-base read contract shared by every backend (exact dense,
/// HNSW, BM25, shard-wrapped, epoch snapshots): batch-first top-k plus
/// the cache-side scoring metric.
///
/// ```
/// use ralmspec::retriever::dense::{DenseExact, EmbeddingMatrix};
/// use ralmspec::retriever::{Retriever, SpecQuery};
/// use std::sync::Arc;
///
/// // Three unit vectors along the axes of a 3-dim space.
/// let emb = Arc::new(EmbeddingMatrix::new(3, vec![1.0, 0.0, 0.0,
///                                                 0.0, 1.0, 0.0,
///                                                 0.0, 0.0, 1.0]));
/// let kb = DenseExact::new(emb);
///
/// // The derived single-query path is a batch of one.
/// let q = SpecQuery::dense_only(vec![0.0, 0.9, 0.1]);
/// let top = kb.retrieve_topk(&q, 2);
/// assert_eq!(top[0].id, 1);
/// assert_eq!(kb.retrieve(&q).unwrap().id, 1);
///
/// // The cache ranks with the same metric the index scans with.
/// assert_eq!(kb.score_doc(&q, 1), top[0].score);
/// ```
pub trait Retriever: Send + Sync {
    /// REQUIRED: batched top-k, `(score desc, id asc)`-ordered per query —
    /// the verification step's primitive (Fig 6 / §A.1) and the only entry
    /// point a backend must implement. Backends amortize whatever their
    /// index structure allows: one corpus pass for all queries (EDR), one
    /// postings walk for the term union (SR), shared search scratch (ADR),
    /// shard-parallel scatter-gather ([`sharded::ShardedRetriever`]).
    fn retrieve_batch(&self, qs: &[SpecQuery], k: usize) -> Vec<Vec<Scored>>;

    /// Score one document under the retriever's metric (used by the local
    /// speculation cache so cache ranking == KB ranking on cached docs).
    fn score_doc(&self, q: &SpecQuery, doc: DocId) -> f32;

    /// Batched [`Retriever::score_doc`] — the cache-lookup primitive.
    /// Default loops; backends may override with a fused scan.
    fn score_docs(&self, q: &SpecQuery, docs: &[DocId]) -> Vec<f32> {
        docs.iter().map(|&d| self.score_doc(q, d)).collect()
    }

    /// Derived: top-k for one query == a batch of one. Do not override —
    /// output equivalence relies on single-query and batched retrieval
    /// sharing one numeric path.
    fn retrieve_topk(&self, q: &SpecQuery, k: usize) -> Vec<Scored> {
        self.retrieve_batch(std::slice::from_ref(q), k)
            .pop()
            .unwrap_or_default()
    }

    /// Derived: top-1 convenience.
    fn retrieve(&self, q: &SpecQuery) -> Option<Scored> {
        self.retrieve_topk(q, 1).into_iter().next()
    }

    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn name(&self) -> &'static str;
}

/// Deterministic latency-injecting wrapper: adds a fixed sleep to every
/// `retrieve_batch` call before delegating, simulating a remote
/// knowledge base whose round-trip dominates (the regime the paper's
/// serving claims target). Results are byte-for-byte the inner
/// retriever's, so every bit-identity pin holds through the wrapper —
/// which is exactly what lets the sync-vs-async engine sweeps (bench-gate
/// and tests) measure scheduling, not retrieval arithmetic, without
/// wall-clock flakiness.
///
/// Cache-side scoring (`score_doc`/`score_docs`) is *not* delayed: the
/// speculation cache is local to the serving process, only KB calls cross
/// the simulated network.
pub struct InjectedLatency {
    inner: Arc<dyn Retriever>,
    per_call: Duration,
}

impl InjectedLatency {
    pub fn new(inner: Arc<dyn Retriever>, per_call: Duration) -> Self {
        Self { inner, per_call }
    }
}

impl Retriever for InjectedLatency {
    fn retrieve_batch(&self, qs: &[SpecQuery], k: usize) -> Vec<Vec<Scored>> {
        std::thread::sleep(self.per_call);
        self.inner.retrieve_batch(qs, k)
    }

    fn score_doc(&self, q: &SpecQuery, doc: DocId) -> f32 {
        self.inner.score_doc(q, doc)
    }

    fn score_docs(&self, q: &SpecQuery, docs: &[DocId]) -> Vec<f32> {
        self.inner.score_docs(q, docs)
    }

    fn len(&self) -> usize {
        self.inner.len()
    }

    fn name(&self) -> &'static str {
        "injected-latency"
    }
}
