//! Retrieval substrates: the knowledge-base side of RaLMSpec.
//!
//! Three from-scratch retrievers mirror the paper's setups (§5.1):
//!   * [`dense::DenseExact`] — exact inner-product flat scan
//!     (FAISS IndexFlatIP / DPR stand-in, "EDR");
//!   * [`hnsw::Hnsw`] — approximate dense retrieval over an HNSW graph
//!     (DPR-HNSW stand-in, "ADR");
//!   * [`sparse::Bm25`] — BM25 over an inverted index (Pyserini stand-in,
//!     "SR").
//!
//! All three implement [`Retriever`]. The trait exposes the *same scoring
//! metric* via [`Retriever::score_doc`], which is what the local speculation
//! cache ranks with — the rank-preservation property of §3 (if the KB top-1
//! is cached, the cache returns it) holds exactly because both sides share
//! this function. Note for ADR: `score_doc` is the *exact* inner product
//! while graph search is approximate, matching how a real HNSW index scores
//! candidates it visits.

pub mod dense;
pub mod hnsw;
pub mod sparse;

use crate::util::Scored;

pub type DocId = u32;

/// A query carrying both retrieval views: the dense embedding (from the
/// AOT query encoder or the HashEncoder) and the raw term window (for BM25).
#[derive(Debug, Clone, PartialEq)]
pub struct SpecQuery {
    pub dense: Vec<f32>,
    pub terms: Vec<u32>,
}

impl SpecQuery {
    pub fn dense_only(v: Vec<f32>) -> Self {
        Self { dense: v, terms: Vec::new() }
    }

    pub fn sparse_only(terms: Vec<u32>) -> Self {
        Self { dense: Vec::new(), terms }
    }
}

pub trait Retriever: Send + Sync {
    /// Top-k documents for one query, (score desc, id asc)-ordered.
    fn retrieve_topk(&self, q: &SpecQuery, k: usize) -> Vec<Scored>;

    /// Score one document under the retriever's metric (used by the local
    /// speculation cache so cache ranking == KB ranking on cached docs).
    fn score_doc(&self, q: &SpecQuery, doc: DocId) -> f32;

    /// Batched retrieval — the verification step's primitive. Default is
    /// the sequential loop; EDR and SR override it with genuinely-amortized
    /// implementations (Fig 6 / §A.1).
    fn retrieve_batch(&self, qs: &[SpecQuery], k: usize) -> Vec<Vec<Scored>> {
        qs.iter().map(|q| self.retrieve_topk(q, k)).collect()
    }

    /// Top-1 convenience.
    fn retrieve(&self, q: &SpecQuery) -> Option<Scored> {
        self.retrieve_topk(q, 1).into_iter().next()
    }

    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn name(&self) -> &'static str;
}
