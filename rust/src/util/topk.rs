//! Top-k selection over score streams — the inner primitive of every
//! retriever (flat scan, HNSW candidate lists, cache ranking, KNN-LM).
//!
//! Scores are f32; ties break toward the **lower id** so that retrieval is
//! fully deterministic (required for the output-equivalence guarantee:
//! baseline and speculative paths must rank identically).

/// A (id, score) candidate ordered by (score desc, id asc).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Scored {
    pub id: u32,
    pub score: f32,
}

impl Scored {
    #[inline]
    pub fn better_than(&self, other: &Scored) -> bool {
        self.score > other.score
            || (self.score == other.score && self.id < other.id)
    }
}

/// Bounded top-k accumulator: O(n log k) worst case, O(1) fast-path reject.
///
/// Implemented as a binary min-heap on the `better_than` order (root = the
/// current worst of the kept set) so streaming inserts reject non-members
/// with a single comparison — the hot path in the flat scan.
#[derive(Debug, Clone)]
pub struct TopK {
    k: usize,
    heap: Vec<Scored>,
}

impl TopK {
    pub fn new(k: usize) -> Self {
        assert!(k > 0, "top-k with k=0");
        Self { k, heap: Vec::with_capacity(k) }
    }

    #[inline]
    pub fn threshold(&self) -> Option<Scored> {
        if self.heap.len() == self.k { Some(self.heap[0]) } else { None }
    }

    #[inline]
    pub fn push(&mut self, id: u32, score: f32) {
        let cand = Scored { id, score };
        if self.heap.len() < self.k {
            self.heap.push(cand);
            self.sift_up(self.heap.len() - 1);
        } else if cand.better_than(&self.heap[0]) {
            self.heap[0] = cand;
            self.sift_down(0);
        }
    }

    /// Drain into (score desc, id asc) order.
    pub fn into_sorted(mut self) -> Vec<Scored> {
        self.heap.sort_by(|a, b| {
            b.score
                .partial_cmp(&a.score)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.id.cmp(&b.id))
        });
        self.heap
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            // min-heap on better_than: parent must be the *worst*
            if self.heap[parent].better_than(&self.heap[i]) {
                self.heap.swap(parent, i);
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        loop {
            let (l, r) = (2 * i + 1, 2 * i + 2);
            let mut worst = i;
            if l < self.heap.len() && self.heap[worst].better_than(&self.heap[l])
            {
                worst = l;
            }
            if r < self.heap.len() && self.heap[worst].better_than(&self.heap[r])
            {
                worst = r;
            }
            if worst == i {
                break;
            }
            self.heap.swap(i, worst);
            i = worst;
        }
    }
}

/// Convenience: top-k over a full score slice (ids = indices).
pub fn topk_from_scores(scores: &[f32], k: usize) -> Vec<Scored> {
    let mut tk = TopK::new(k.min(scores.len()).max(1));
    for (i, &s) in scores.iter().enumerate() {
        tk.push(i as u32, s);
    }
    tk.into_sorted()
}

/// Deterministic argmax (ties -> lowest index). Returns None on empty input.
pub fn argmax(xs: &[f32]) -> Option<usize> {
    let mut best: Option<(usize, f32)> = None;
    for (i, &x) in xs.iter().enumerate() {
        match best {
            None => best = Some((i, x)),
            Some((_, bx)) if x > bx => best = Some((i, x)),
            _ => {}
        }
    }
    best.map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reference_topk(scores: &[f32], k: usize) -> Vec<Scored> {
        let mut all: Vec<Scored> = scores
            .iter()
            .enumerate()
            .map(|(i, &s)| Scored { id: i as u32, score: s })
            .collect();
        all.sort_by(|a, b| {
            b.score
                .partial_cmp(&a.score)
                .unwrap()
                .then(a.id.cmp(&b.id))
        });
        all.truncate(k);
        all
    }

    #[test]
    fn matches_sort_reference() {
        let mut rng = crate::util::rng::Rng::new(1);
        for n in [1usize, 5, 50, 1000] {
            for k in [1usize, 3, 10] {
                let scores: Vec<f32> =
                    (0..n).map(|_| rng.next_f32() * 10.0 - 5.0).collect();
                let got = topk_from_scores(&scores, k);
                let exp = reference_topk(&scores, k.min(n));
                assert_eq!(got.len(), exp.len());
                for (g, e) in got.iter().zip(&exp) {
                    assert_eq!(g.id, e.id, "n={n} k={k}");
                }
            }
        }
    }

    #[test]
    fn tie_breaks_to_lower_id() {
        let scores = vec![1.0, 2.0, 2.0, 2.0, 0.5];
        let got = topk_from_scores(&scores, 2);
        assert_eq!(got[0].id, 1);
        assert_eq!(got[1].id, 2);
    }

    #[test]
    fn argmax_basic_and_ties() {
        assert_eq!(argmax(&[1.0, 3.0, 2.0]), Some(1));
        assert_eq!(argmax(&[2.0, 2.0]), Some(0));
        assert_eq!(argmax(&[]), None);
    }

    #[test]
    fn handles_k_larger_than_n() {
        let got = topk_from_scores(&[3.0, 1.0], 10);
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].id, 0);
    }

    #[test]
    fn streaming_threshold_rejects() {
        let mut tk = TopK::new(2);
        tk.push(0, 5.0);
        tk.push(1, 4.0);
        assert_eq!(tk.threshold().unwrap().score, 4.0);
        tk.push(2, 1.0); // rejected
        let out = tk.into_sorted();
        assert_eq!(out.iter().map(|s| s.id).collect::<Vec<_>>(), vec![0, 1]);
    }
}
