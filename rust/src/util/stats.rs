//! Statistics helpers for the evaluation harness and OS³: mean/std,
//! 95% confidence intervals (Fig 6 bands), and least-squares linear fits
//! (the b(s) = b0 + b1·s batched-verification latency model of §A.2).

/// Summary of a sample: mean, sample standard deviation, and 95% CI
/// half-width (normal approximation, as in the paper's error bars).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub ci95: f64,
    pub min: f64,
    pub max: f64,
}

pub fn summarize(xs: &[f64]) -> Summary {
    let n = xs.len();
    if n == 0 {
        return Summary { n: 0, mean: 0.0, std: 0.0, ci95: 0.0, min: 0.0, max: 0.0 };
    }
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = if n > 1 {
        xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
    } else {
        0.0
    };
    let std = var.sqrt();
    let ci95 = 1.96 * std / (n as f64).sqrt();
    let (mut min, mut max) = (f64::INFINITY, f64::NEG_INFINITY);
    for &x in xs {
        min = min.min(x);
        max = max.max(x);
    }
    Summary { n, mean, std, ci95, min, max }
}

/// Ordinary least squares y = a + b·x. Returns (intercept, slope).
/// Degenerate inputs (n < 2 or zero x-variance) fall back to (mean(y), 0).
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len();
    if n == 0 {
        return (0.0, 0.0);
    }
    let mx = xs.iter().sum::<f64>() / n as f64;
    let my = ys.iter().sum::<f64>() / n as f64;
    let sxx: f64 = xs.iter().map(|x| (x - mx).powi(2)).sum();
    if n < 2 || sxx < 1e-12 {
        return (my, 0.0);
    }
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let slope = sxy / sxx;
    (my - slope * mx, slope)
}

/// Exponential moving average with bias-corrected warm-up.
#[derive(Debug, Clone)]
pub struct Ema {
    alpha: f64,
    value: f64,
    weight: f64,
}

impl Ema {
    pub fn new(alpha: f64) -> Self {
        Self { alpha, value: 0.0, weight: 0.0 }
    }

    pub fn update(&mut self, x: f64) {
        self.value = self.alpha * x + (1.0 - self.alpha) * self.value;
        self.weight = self.alpha + (1.0 - self.alpha) * self.weight;
    }

    /// Bias-corrected estimate; None before any update.
    pub fn get(&self) -> Option<f64> {
        if self.weight > 0.0 { Some(self.value / self.weight) } else { None }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summarize_known_values() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.std - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
    }

    #[test]
    fn summarize_empty_and_single() {
        assert_eq!(summarize(&[]).n, 0);
        let s = summarize(&[7.0]);
        assert_eq!(s.mean, 7.0);
        assert_eq!(s.std, 0.0);
    }

    #[test]
    fn linear_fit_exact_line() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [3.0, 5.0, 7.0, 9.0]; // y = 1 + 2x
        let (a, b) = linear_fit(&xs, &ys);
        assert!((a - 1.0).abs() < 1e-9);
        assert!((b - 2.0).abs() < 1e-9);
    }

    #[test]
    fn linear_fit_degenerate() {
        let (a, b) = linear_fit(&[2.0, 2.0], &[5.0, 7.0]);
        assert_eq!(b, 0.0);
        assert!((a - 6.0).abs() < 1e-12);
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.3);
        assert!(e.get().is_none());
        for _ in 0..200 {
            e.update(4.0);
        }
        assert!((e.get().unwrap() - 4.0).abs() < 1e-6);
    }

    #[test]
    fn ema_bias_correction_early() {
        let mut e = Ema::new(0.1);
        e.update(10.0);
        // without bias correction this would be 1.0
        assert!((e.get().unwrap() - 10.0).abs() < 1e-9);
    }
}
