//! Shared primitives: deterministic RNG, top-k selection, statistics.

pub mod json;
pub mod rng;
pub mod stats;
pub mod topk;

pub use rng::{Rng, Zipf};
pub use stats::{linear_fit, summarize, Ema, Summary};
pub use topk::{argmax, topk_from_scores, Scored, TopK};

/// Dot product of two equal-length f32 slices — the naive left-to-right
/// form, kept for small vectors and as an accuracy reference. Every
/// retrieval/cache hot loop instead goes through
/// `retriever::kernels::dot` (DESIGN.md ADR-007), whose lane-blocked
/// reduction order is shared bit-for-bit by the scalar and SIMD forms.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    for (&x, &y) in a.iter().zip(b) {
        acc += x * y;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_basic() {
        assert_eq!(dot(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]), 32.0);
    }
}
