//! Deterministic RNG (no `rand` dependency): SplitMix64 seeding into
//! xoshiro256**, plus the samplers the synthetic data generators need.
//!
//! Everything in the repo that draws randomness goes through this type with
//! an explicit seed, so corpora, workloads, and benchmark runs are exactly
//! reproducible across machines and runs.

/// xoshiro256** seeded via SplitMix64 (Blackman & Vigna).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 stream to fill the state; never all-zero.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent stream (for per-request / per-doc seeding).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n). n must be > 0.
    #[inline]
    pub fn gen_range(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift; bias negligible for our n << 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in [lo, hi).
    #[inline]
    pub fn gen_range_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.gen_range(hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Random unit vector of dimension `d` (uniform on the sphere).
    pub fn unit_vector(&mut self, d: usize) -> Vec<f32> {
        let mut v: Vec<f32> = (0..d).map(|_| self.normal() as f32).collect();
        let norm = v.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-9);
        for x in &mut v {
            *x /= norm;
        }
        v
    }

    /// Geometric-ish document length: lo + floor(Exp(mean)) clamped to hi.
    pub fn length(&mut self, lo: usize, hi: usize) -> usize {
        let mean = ((hi - lo) as f64) / 3.0;
        let x = -mean * self.next_f64().max(1e-12).ln();
        (lo + x as usize).min(hi)
    }
}

/// Zipf sampler over ranks 0..n with exponent `s` (precomputed CDF).
///
/// Used for token frequencies inside topics (natural-language-like skew)
/// and for topic popularity in the QA workloads.
#[derive(Debug, Clone)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = *cdf.last().unwrap();
        for c in &mut cdf {
            *c /= total;
        }
        Self { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.next_f64();
        // Binary search the CDF.
        match self.cdf.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn gen_range_bounds() {
        let mut rng = Rng::new(7);
        for _ in 0..10_000 {
            let x = rng.gen_range(13);
            assert!(x < 13);
        }
    }

    #[test]
    fn next_f64_in_unit_interval() {
        let mut rng = Rng::new(3);
        for _ in 0..10_000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn unit_vector_is_normalized() {
        let mut rng = Rng::new(9);
        let v = rng.unit_vector(64);
        let norm: f32 = v.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((norm - 1.0).abs() < 1e-4);
    }

    #[test]
    fn zipf_is_skewed_and_in_range() {
        let z = Zipf::new(100, 1.1);
        let mut rng = Rng::new(11);
        let mut counts = vec![0usize; 100];
        for _ in 0..20_000 {
            let k = z.sample(&mut rng);
            assert!(k < 100);
            counts[k] += 1;
        }
        assert!(counts[0] > counts[50] * 3, "rank 0 should dominate rank 50");
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn normal_has_sane_moments() {
        let mut rng = Rng::new(13);
        let n = 50_000;
        let samples: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }
}
