//! Minimal JSON parser + writer (the image has no serde): enough for the
//! AOT manifests, the config files, and the report emitter.
//!
//! Numbers are f64 (all our integers — offsets, shapes — are < 2^53, which
//! round-trips exactly). Object key order is preserved (Vec of pairs) so
//! emitted reports diff cleanly.

use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(Vec<(String, Value)>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(pairs) => {
                pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v)
            }
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|n| n as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Value)]> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Typed field accessors with contextual errors.
    pub fn req(&self, key: &str) -> anyhow::Result<&Value> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing JSON field `{key}`"))
    }

    pub fn str_field(&self, key: &str) -> anyhow::Result<String> {
        Ok(self
            .req(key)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("field `{key}` not a string"))?
            .to_string())
    }

    pub fn usize_field(&self, key: &str) -> anyhow::Result<usize> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("field `{key}` not a number"))
    }

    /// Builders.
    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num<T: Into<f64>>(n: T) -> Value {
        Value::Num(n.into())
    }

    pub fn str(s: impl Into<String>) -> Value {
        Value::Str(s.into())
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization (2-space indent).
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let (nl, pad, pad_in) = match indent {
            Some(w) => ("\n", " ".repeat(w * depth), " ".repeat(w * (depth + 1))),
            None => ("", String::new(), String::new()),
        };
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Value::Str(s) => write_escaped(out, s),
            Value::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push(']');
            }
            Value::Obj(o) => {
                if o.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

pub fn parse(text: &str) -> anyhow::Result<Value> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    anyhow::ensure!(p.pos == p.bytes.len(), "trailing garbage at byte {}",
                    p.pos);
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> anyhow::Result<u8> {
        let b = self
            .peek()
            .ok_or_else(|| anyhow::anyhow!("unexpected end of JSON"))?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> anyhow::Result<()> {
        let got = self.bump()?;
        anyhow::ensure!(got == b, "expected `{}` at byte {}, got `{}`",
                        b as char, self.pos - 1, got as char);
        Ok(())
    }

    fn literal(&mut self, lit: &str, v: Value) -> anyhow::Result<Value> {
        anyhow::ensure!(
            self.bytes[self.pos..].starts_with(lit.as_bytes()),
            "invalid literal at byte {}", self.pos);
        self.pos += lit.len();
        Ok(v)
    }

    fn value(&mut self) -> anyhow::Result<Value> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(_) => self.number(),
            None => anyhow::bail!("unexpected end of JSON"),
        }
    }

    fn object(&mut self) -> anyhow::Result<Value> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            pairs.push((key, val));
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => return Ok(Value::Obj(pairs)),
                c => anyhow::bail!("expected , or }} got `{}`", c as char),
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => return Ok(Value::Arr(items)),
                c => anyhow::bail!("expected , or ] got `{}`", c as char),
            }
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump()? {
                b'"' => return Ok(s),
                b'\\' => match self.bump()? {
                    b'"' => s.push('"'),
                    b'\\' => s.push('\\'),
                    b'/' => s.push('/'),
                    b'n' => s.push('\n'),
                    b't' => s.push('\t'),
                    b'r' => s.push('\r'),
                    b'b' => s.push('\u{8}'),
                    b'f' => s.push('\u{c}'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump()? as char;
                            code = code * 16
                                + c.to_digit(16).ok_or_else(|| {
                                    anyhow::anyhow!("bad \\u escape")
                                })?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    c => anyhow::bail!("bad escape \\{}", c as char),
                },
                c if c < 0x20 => anyhow::bail!("control char in string"),
                c => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 { 4 } else if c >= 0xE0 { 3 }
                                  else { 2 };
                        anyhow::ensure!(start + len <= self.bytes.len(),
                                        "truncated UTF-8");
                        s.push_str(std::str::from_utf8(
                            &self.bytes[start..start + len])?);
                        self.pos = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> anyhow::Result<Value> {
        let start = self.pos;
        while matches!(self.peek(),
                       Some(b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Value::Num(text.parse::<f64>().map_err(|e| {
            anyhow::anyhow!("bad number `{text}` at byte {start}: {e}")
        })?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let text = r#"{
            "artifact": "decode_x",
            "weights_bin": null,
            "inputs": [{"name": "kv", "shape": [2, 3], "dtype": "f32"}],
            "ok": true,
            "gamma": 0.6
        }"#;
        let v = parse(text).unwrap();
        assert_eq!(v.str_field("artifact").unwrap(), "decode_x");
        assert_eq!(v.get("weights_bin"), Some(&Value::Null));
        let inputs = v.get("inputs").unwrap().as_arr().unwrap();
        assert_eq!(inputs[0].str_field("name").unwrap(), "kv");
        let shape: Vec<usize> = inputs[0]
            .get("shape").unwrap().as_arr().unwrap()
            .iter().map(|x| x.as_usize().unwrap()).collect();
        assert_eq!(shape, vec![2, 3]);
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
        assert!((v.get("gamma").unwrap().as_f64().unwrap() - 0.6).abs() < 1e-12);
        // serialize -> reparse fixpoint
        let again = parse(&v.to_string()).unwrap();
        assert_eq!(again, v);
        let pretty = parse(&v.pretty()).unwrap();
        assert_eq!(pretty, v);
    }

    #[test]
    fn string_escapes() {
        let v = parse(r#""a\"b\\c\ndA""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\"b\\c\ndA");
        let back = parse(&v.to_string()).unwrap();
        assert_eq!(back, v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse("\"γ≈0.6\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "γ≈0.6");
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn numbers() {
        assert_eq!(parse("42").unwrap().as_usize(), Some(42));
        assert_eq!(parse("-3.5").unwrap().as_f64(), Some(-3.5));
        assert_eq!(parse("1e3").unwrap().as_f64(), Some(1000.0));
        // integers render without .0
        assert_eq!(Value::num(7.0).to_string(), "7");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nope").is_err());
        assert!(parse("{}x").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Value::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Value::Obj(vec![]));
        assert_eq!(Value::Arr(vec![]).pretty(), "[]");
    }

    #[test]
    fn field_errors_are_contextual() {
        let v = parse(r#"{"a": 1}"#).unwrap();
        let err = v.str_field("b").unwrap_err().to_string();
        assert!(err.contains("`b`"));
        let err2 = v.str_field("a").unwrap_err().to_string();
        assert!(err2.contains("not a string"));
    }
}
