//! Resumable KNN-LM serving task (DESIGN.md ADR-004): the speculative
//! KNN-LM loop of `KnnLmSpec::run` — cache lookup, relaxed batched
//! verification, rollback on token mismatch — expressed as the
//! step-driven [`ServeTask`] state machine, so concurrent KNN-LM requests
//! are engine citizens: `serving::ServeEngine` interleaves their
//! speculation steps and coalesces their verification strides (and cache
//! primes) into shared datastore `retrieve_batch` calls.
//!
//! This is the workload the paper's largest win (up to 7.59x, §5.3) comes
//! from — the baseline issues one retrieval *per generated token*, so at
//! serving concurrency the per-token verification pressure is exactly
//! what cross-request coalescing amortizes.
//!
//! Correctness: relaxed verification compares *decoded tokens*, not
//! neighbour sets, and the true token at a position is a pure function of
//! the request's own state prefix and the true top-k — both independent
//! of batchmates (every retriever scores queries independently; ADR-003).
//! Per-request outputs therefore stay bit-identical to a sequential
//! [`KnnLmSpec::run`](crate::knnlm::KnnLmSpec::run) of the same request,
//! which `tests/knnlm_engine_equivalence.rs` pins across k, stride
//! policies, shard counts, and concurrency levels.

use crate::knnlm::cache::KnnCache;
use crate::knnlm::datastore::Datastore;
use crate::knnlm::interpolate::interpolated_argmax;
use crate::knnlm::serve::KnnServeOptions;
use crate::lm::{LanguageModel, EOS};
use crate::metrics::{timed, ReqMetrics, Stopwatch};
use crate::retriever::SpecQuery;
use crate::serving::{ServeTask, TaskStep, TenantId};
use crate::spec::Scheduler;
use crate::util::Scored;
use std::time::Duration;

/// One in-flight KNN-LM speculation step.
struct KnnPending<S> {
    /// LM state *before* the token was appended (logits for re-derivation).
    pre_state: S,
    tokens_len: usize,
    query: Vec<f32>,
    spec_token: u32,
    step_time: Duration,
}

/// Task lifecycle — mirrors `spec::SpecTask`: `Prime`/`AwaitPrime` cover
/// the initial true-neighbour cache priming (itself a `NeedsVerify` batch
/// of one so engines coalesce it), `Running`/`AwaitVerify` alternate for
/// the speculate→verify rounds, `Finished` is terminal.
enum Phase {
    Prime,
    AwaitPrime,
    Running,
    AwaitVerify,
    Finished,
}

/// Resumable per-request KNN-LM task. Drive with
/// [`advance`](Self::advance) until `Done`, answering every `NeedsVerify`
/// with [`provide`](Self::provide) — `KnnLmSpec::run` does so with one
/// direct `retrieve_batch` call per step, the serving engine with a
/// coalesced call shared across requests.
pub struct KnnTask<'a, L: LanguageModel> {
    lm: &'a L,
    ds: &'a Datastore,
    opts: KnnServeOptions,
    prompt: Vec<u32>,
    phase: Phase,
    total: Stopwatch,
    m: ReqMetrics,
    cache: KnnCache,
    scheduler: Scheduler,
    state: Option<L::State>,
    out: Vec<u32>,
    /// Steps speculated but not yet verified.
    pending: Vec<KnnPending<L::State>>,
    /// Steps speculated *while a verification round is in flight*
    /// ([`overlap_step`](Self::overlap_step)) — up to one full next-round
    /// stride per round (a deterministic, state-based budget). They roll
    /// into the next round's pending list when the round verifies clean,
    /// and are discarded with the rollback otherwise.
    overlap: Vec<KnnPending<L::State>>,
    /// Datastore-index epoch this task is pinned to (0 for a frozen
    /// datastore) — same grouping contract as `SpecTask` (ADR-006).
    epoch: u64,
    /// Tenant namespace (0 = default) — same grouping contract as
    /// `SpecTask` (ADR-011).
    tenant: TenantId,
}

impl<'a, L: LanguageModel> KnnTask<'a, L> {
    pub fn new(lm: &'a L, ds: &'a Datastore, opts: KnnServeOptions,
               prompt: &[u32]) -> Self {
        let scheduler = Scheduler::new(opts.stride.clone());
        let cache = KnnCache::new(opts.cache_cap, opts.next_n);
        Self {
            lm,
            ds,
            opts,
            prompt: prompt.to_vec(),
            phase: Phase::Prime,
            total: Stopwatch::start(),
            m: ReqMetrics::default(),
            cache,
            scheduler,
            state: None,
            out: Vec::new(),
            pending: Vec::new(),
            overlap: Vec::new(),
            epoch: 0,
            tenant: 0,
        }
    }

    /// Pin this task to a live datastore index epoch (DESIGN.md
    /// ADR-006): the engine answers its `NeedsVerify` batches with that
    /// epoch's snapshot and never coalesces it with other epochs' tasks.
    /// The pinned epoch is stamped into the request's metrics.
    pub fn pin_epoch(mut self, epoch: u64) -> Self {
        self.epoch = epoch;
        self.m.epoch = epoch;
        self
    }

    /// Pin this task to a tenant namespace (DESIGN.md ADR-011) — same
    /// contract as `SpecTask::pin_tenant`; tenant 0 (the default)
    /// preserves single-tenant behaviour exactly.
    pub fn pin_tenant(mut self, tenant: TenantId) -> Self {
        self.tenant = tenant;
        self
    }

    fn choose(&self, logits: &[f32], nb: &[Scored]) -> u32 {
        interpolated_argmax(logits, nb, &self.ds.values, self.opts.lambda,
                            self.opts.tau)
    }

    fn is_done(&self) -> bool {
        let Some(state) = self.state.as_ref() else { return false };
        self.out.len() >= self.opts.max_new
            || self.lm.pos(state) >= self.lm.max_ctx()
            || self.out.last() == Some(&EOS)
    }

    /// One speculation step: speculative neighbours from the
    /// consecutive-entry cache, token via interpolation, state append.
    /// Shared by the `Running` phase and [`overlap_step`](Self::overlap_step).
    fn speculate_one(&mut self) -> anyhow::Result<KnnPending<L::State>> {
        let step = Stopwatch::start();
        let state = self.state.as_ref()
            .expect("generation state exists after prime");
        let query = self.lm.qproj(state).to_vec();
        let k = self.opts.k;
        let nb = timed(&mut self.m.cache,
                       || self.cache.topk(&query, k, self.ds));
        self.m.cache_lookups += 1;
        let tok = self.choose(self.lm.logits(state), &nb);
        let pre_state = state.clone();
        let lm = self.lm;
        let next = timed(&mut self.m.generate,
                         || lm.append_token(state, tok))?;
        self.state = Some(next);
        self.out.push(tok);
        self.m.spec_steps += 1;
        Ok(KnnPending {
            pre_state,
            tokens_len: self.out.len() - 1,
            query,
            spec_token: tok,
            step_time: step.elapsed(),
        })
    }

    /// Run until the task finishes (`Done`), needs the true top-k for its
    /// pending stride (`NeedsVerify`), or has taken one speculation step
    /// (`Continue`). Must not be called while a `NeedsVerify` is
    /// outstanding.
    pub fn advance(&mut self) -> anyhow::Result<TaskStep> {
        match self.phase {
            Phase::Prime => {
                // Prime the cache with the true neighbours of the prompt
                // state; expressed as a NeedsVerify batch of one so a
                // serving engine coalesces primes across requests.
                let lm = self.lm;
                let prompt = &self.prompt;
                let state =
                    timed(&mut self.m.generate, || lm.prefill(prompt))?;
                self.m.prefills += 1;
                let q0 = lm.qproj(&state).to_vec();
                self.state = Some(state);
                self.m.kb_calls += 1;
                self.m.kb_queries += 1;
                self.phase = Phase::AwaitPrime;
                Ok(TaskStep::NeedsVerify {
                    queries: vec![SpecQuery::dense_only(q0)],
                    k: self.opts.k,
                })
            }
            Phase::AwaitPrime | Phase::AwaitVerify => anyhow::bail!(
                "KnnTask::advance while a verification is outstanding"),
            Phase::Finished => Ok(TaskStep::Done),
            Phase::Running => {
                let target = self.scheduler.stride().max(1);
                let done = self.is_done();
                if self.pending.is_empty() && done {
                    self.finish();
                    return Ok(TaskStep::Done);
                }
                if self.pending.len() < target && !done {
                    let p = self.speculate_one()?;
                    self.pending.push(p);
                    return Ok(TaskStep::Continue);
                }
                // Batched verification of the pending stride.
                self.m.strides.push(self.pending.len() as u32);
                let queries: Vec<SpecQuery> = self
                    .pending
                    .iter()
                    .map(|p| SpecQuery::dense_only(p.query.clone()))
                    .collect();
                self.m.kb_calls += 1;
                self.m.kb_queries += queries.len() as u32;
                self.phase = Phase::AwaitVerify;
                Ok(TaskStep::NeedsVerify { queries, k: self.opts.k })
            }
        }
    }

    /// Speculate ahead while a verification round is in flight (DESIGN.md
    /// ADR-005): drivers call this repeatedly between receiving
    /// `NeedsVerify` and calling [`provide`](Self::provide) — the serving
    /// engine once per scheduling round across the whole KB latency. The
    /// task accepts up to one full next-round stride of overlap steps per
    /// round; the budget depends on task state only (the scheduler's
    /// current stride — stable during `AwaitVerify`, since `observe` runs
    /// in `provide`), never on elapsed time, so schedules stay
    /// reproducible. Tokens are unaffected either way: overlap steps are
    /// verified in the next round exactly like ordinary pending steps
    /// (and discarded by a rollback), which is why the sequential
    /// `KnnLmSpec::run` — which answers inline and has no overlap window —
    /// stays bit-identical to the engine-served path.
    pub fn overlap_step(&mut self) -> anyhow::Result<bool> {
        if !matches!(self.phase, Phase::AwaitVerify)
            || self.overlap.len() >= self.scheduler.stride().max(1)
            || self.is_done()
        {
            return Ok(false);
        }
        let p = self.speculate_one()?;
        self.m.overlap_steps += 1;
        self.overlap.push(p);
        Ok(true)
    }

    /// Answer the outstanding `NeedsVerify` (see
    /// [`ServeTask::provide`] for the `truths`/`kb_time` contract).
    pub fn provide(&mut self, truths: Vec<Vec<Scored>>, kb_time: Duration)
                   -> anyhow::Result<()> {
        match self.phase {
            Phase::Prime | Phase::Running | Phase::Finished => anyhow::bail!(
                "KnnTask::provide without an outstanding verification"),
            Phase::AwaitPrime => {
                anyhow::ensure!(truths.len() == 1,
                                "prime expects 1 result row, got {}",
                                truths.len());
                self.m.retrieve += kb_time;
                let ids: Vec<u32> =
                    truths[0].iter().map(|s| s.id).collect();
                self.cache.insert_with_next(&ids, self.ds);
                self.phase = Phase::Running;
                Ok(())
            }
            Phase::AwaitVerify => {
                anyhow::ensure!(truths.len() == self.pending.len(),
                                "verification returned {} rows for {} \
                                 queries",
                                truths.len(), self.pending.len());
                self.m.retrieve += kb_time;
                // Hit accounting over the whole round BEFORE any of this
                // round's insertions: a "hit" is a verified query whose
                // true nearest neighbour was already cached when the
                // stride speculated (the cache only mutates here, so for
                // ordinary pending steps pre-insert state == lookup-time
                // state; steps speculated by `overlap_step` looked up one
                // insertion round earlier, so for them the check is a
                // one-round-stale approximation). Interleaving the check
                // with the inserts would let query i-1's next-n
                // insertions count as query i's hit and overstate the
                // rate.
                for tr in &truths {
                    if tr.first().is_some_and(|s| self.cache.contains(s.id))
                    {
                        self.m.cache_hits += 1;
                    }
                }
                for tr in &truths {
                    let ids: Vec<u32> =
                        tr.iter().map(|s| s.id).collect();
                    self.cache.insert_with_next(&ids, self.ds);
                }

                // Relaxed match: compare decoded tokens, not neighbour
                // sets (matching k neighbour ids is exponentially hard;
                // the decoded token is what model equivalence requires).
                let mut mismatch = None;
                let mut true_token_at = 0u32;
                for (i, (p, tr)) in
                    self.pending.iter().zip(&truths).enumerate()
                {
                    let true_tok =
                        self.choose(self.lm.logits(&p.pre_state), tr);
                    if true_tok != p.spec_token {
                        mismatch = Some(i);
                        true_token_at = true_tok;
                        break;
                    }
                }
                let matched = mismatch.unwrap_or(self.pending.len());
                self.m.spec_correct += matched as u32;
                let a_mean = self
                    .pending
                    .iter()
                    .map(|p| p.step_time.as_secs_f64())
                    .sum::<f64>()
                    / self.pending.len() as f64;
                self.scheduler.observe(self.pending.len(), matched, a_mean,
                                       kb_time.as_secs_f64());

                if let Some(i) = mismatch {
                    // Roll back to the mis-speculated position and append
                    // the ground-truth token instead. Overlap steps were
                    // speculated past the truncation point, so their
                    // tokens are discarded with the rest (they are inside
                    // the `wasted_tokens` delta below).
                    self.m.rollbacks += 1;
                    self.m.wasted_tokens +=
                        (self.out.len() - self.pending[i].tokens_len)
                            as u32;
                    self.out.truncate(self.pending[i].tokens_len);
                    let pre = self.pending[i].pre_state.clone();
                    let lm = self.lm;
                    let next =
                        timed(&mut self.m.generate,
                              || lm.append_token(&pre, true_token_at))?;
                    self.state = Some(next);
                    self.out.push(true_token_at);
                    self.pending.clear();
                    self.overlap.clear();
                } else {
                    // Clean round: overlap steps become the next round's
                    // pending list (they are ordinary speculated steps,
                    // just taken while the KB call was in flight) and get
                    // verified exactly like any other stride.
                    self.pending.clear();
                    self.pending.append(&mut self.overlap);
                }
                self.phase = Phase::Running;
                Ok(())
            }
        }
    }

    pub fn is_finished(&self) -> bool {
        matches!(self.phase, Phase::Finished)
    }

    pub fn metrics(&self) -> &ReqMetrics {
        &self.m
    }

    pub fn metrics_mut(&mut self) -> &mut ReqMetrics {
        &mut self.m
    }

    /// Final metrics (tokens, latency decomposition). Complete only once
    /// `advance` has returned `Done`.
    pub fn into_metrics(self) -> ReqMetrics {
        self.m
    }

    fn finish(&mut self) {
        self.m.decode_tokens =
            self.out.len() as u32 + self.m.wasted_tokens;
        self.m.tokens_out = std::mem::take(&mut self.out);
        self.m.total = self.total.elapsed();
        self.phase = Phase::Finished;
    }
}

impl<'a, L: LanguageModel> ServeTask for KnnTask<'a, L> {
    fn advance(&mut self) -> anyhow::Result<TaskStep> {
        KnnTask::advance(self)
    }

    fn epoch(&self) -> u64 {
        self.epoch
    }

    fn tenant(&self) -> TenantId {
        self.tenant
    }

    fn overlap_step(&mut self) -> anyhow::Result<bool> {
        KnnTask::overlap_step(self)
    }

    fn provide(&mut self, truths: Vec<Vec<Scored>>, kb_time: Duration)
               -> anyhow::Result<()> {
        KnnTask::provide(self, truths, kb_time)
    }

    fn metrics_mut(&mut self) -> &mut ReqMetrics {
        KnnTask::metrics_mut(self)
    }

    fn into_metrics(self) -> ReqMetrics {
        KnnTask::into_metrics(self)
    }
}
