//! KNN-LM datastore (Khandelwal et al. 2019): one entry per training-token
//! position — key = retrieval-space projection of the leftward-context
//! hidden state, value = the next token.
//!
//! Entries are stored in stream order, which is what gives the *spatial
//! locality* the KNN-LM speculation cache exploits (next-n consecutive
//! entries, §5.3).
//!
//! Two builders: the PJRT path runs the `hidden_knnlm` artifact over the
//! token stream in chunks; the mock path uses the same HashEncoder the
//! MockLm's qproj uses, so mock queries and mock keys share one space.

use crate::datagen::{Encoder, HashEncoder, TokenStream};
use crate::retriever::dense::EmbeddingMatrix;
use std::sync::Arc;

pub struct Datastore {
    pub keys: Arc<EmbeddingMatrix>,
    /// values[i] = token following position i in the stream.
    pub values: Vec<u32>,
}

impl Datastore {
    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    pub fn dim(&self) -> usize {
        self.keys.dim
    }

    /// Mock-mode builder: keys are sliding-window HashEncoder embeddings,
    /// computed incrementally (O(dim) per position).
    pub fn build_mock(stream: &TokenStream, dim: usize, seed: u64,
                      max_entries: usize) -> Self {
        let enc = HashEncoder::new(dim, seed);
        let window = enc.window();
        let n = (stream.len() - 1).min(max_entries);
        let mut keys = Vec::with_capacity(n * dim);
        let mut values = Vec::with_capacity(n);
        // Incremental sliding-window sum of token vectors.
        let mut sum = vec![0.0f32; dim];
        let mut tokvecs: std::collections::BTreeMap<u32, Vec<f32>> =
            std::collections::BTreeMap::new();
        let mut vec_of = |t: u32| -> Vec<f32> {
            tokvecs
                .entry(t)
                .or_insert_with(|| enc.encode(&[t]))
                .clone()
        };
        for i in 0..n {
            let v = vec_of(stream.tokens[i]);
            for (s, x) in sum.iter_mut().zip(&v) {
                *s += x;
            }
            if i >= window {
                let out = vec_of(stream.tokens[i - window]);
                for (s, x) in sum.iter_mut().zip(&out) {
                    *s -= x;
                }
            }
            let norm =
                sum.iter().map(|x| x * x).sum::<f32>().sqrt().max(1e-9);
            keys.extend(sum.iter().map(|x| x / norm));
            values.push(stream.tokens[i + 1]);
        }
        Self { keys: Arc::new(EmbeddingMatrix::new(dim, keys)), values }
    }

    /// PJRT builder: run the `hidden_knnlm` artifact chunk by chunk.
    pub fn build_pjrt(stream: &TokenStream,
                      extractor: &crate::runtime::HiddenExtractor,
                      max_entries: usize) -> anyhow::Result<Self> {
        let dim = extractor.dim;
        let chunk = extractor.chunk_len;
        let n = (stream.len() - 1).min(max_entries);
        let mut keys = Vec::with_capacity(n * dim);
        let mut values = Vec::with_capacity(n);
        let mut start = 0usize;
        while values.len() < n {
            let end = (start + chunk).min(stream.len());
            let toks = &stream.tokens[start..end];
            let hid = extractor.extract(toks)?;
            for i in 0..toks.len() {
                if values.len() >= n || start + i + 1 >= stream.len() {
                    break;
                }
                keys.extend_from_slice(&hid[i * dim..(i + 1) * dim]);
                values.push(stream.tokens[start + i + 1]);
            }
            start = end;
        }
        Ok(Self { keys: Arc::new(EmbeddingMatrix::new(dim, keys)), values })
    }
}

/// Sanity check used by tests and the datastore-build CLI: keys must be
/// unit norm.
pub fn keys_normalized(ds: &Datastore) -> bool {
    (0..ds.len().min(64)).all(|i| {
        let r = ds.keys.row(i as u32);
        let n: f32 = r.iter().map(|x| x * x).sum::<f32>().sqrt();
        (n - 1.0).abs() < 1e-3
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CorpusConfig;
    use crate::datagen::generate_stream;

    fn stream() -> TokenStream {
        generate_stream(&CorpusConfig::default(), 3000, 1)
    }

    #[test]
    fn mock_build_shapes_and_values() {
        let s = stream();
        let ds = Datastore::build_mock(&s, 32, 7, 1000);
        assert_eq!(ds.len(), 1000);
        assert_eq!(ds.keys.len(), 1000);
        for i in 0..1000 {
            assert_eq!(ds.values[i], s.tokens[i + 1]);
        }
        assert!(keys_normalized(&ds));
    }

    #[test]
    fn incremental_keys_match_direct_encoding() {
        let s = stream();
        let dim = 16;
        let ds = Datastore::build_mock(&s, dim, 9, 200);
        let enc = HashEncoder::new(dim, 9);
        for &i in &[0usize, 5, 40, 100, 199] {
            let direct = enc.encode(&s.tokens[..=i]);
            let row = ds.keys.row(i as u32);
            for (a, b) in direct.iter().zip(row) {
                assert!((a - b).abs() < 1e-3,
                        "pos {i}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn consecutive_keys_are_similar() {
        // Spatial locality in key space: neighbors in the stream are close.
        let s = stream();
        let ds = Datastore::build_mock(&s, 32, 3, 500);
        let cos = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| x * y).sum()
        };
        let mut adjacent = 0.0;
        let mut distant = 0.0;
        let m = 100;
        for i in 100..100 + m {
            adjacent += cos(ds.keys.row(i), ds.keys.row(i + 1));
            distant += cos(ds.keys.row(i), ds.keys.row(i + 300));
        }
        assert!(adjacent / m as f32 > distant / m as f32,
                "adjacent keys should be more similar");
    }

    #[test]
    fn build_respects_max_entries() {
        let s = stream();
        let ds = Datastore::build_mock(&s, 8, 1, 50);
        assert_eq!(ds.len(), 50);
    }
}
