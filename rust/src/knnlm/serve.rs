//! KNN-LM serving: the retrieval-per-token baseline and the RaLMSpec
//! variant with relaxed verification (§5.3).
//!
//! Relaxed verification: a speculation step succeeds iff the *next token*
//! chosen from the cached neighbours equals the token chosen from the true
//! top-k — not iff all k neighbour sets match (matching 1024 entries is
//! exponentially hard; matching the decoded token is what model equivalence
//! actually requires).
//!
//! Both serving modes take the datastore KB as a batch-first
//! `&dyn Retriever`: the per-token baseline issues derived batch-of-one
//! lookups while the speculative path verifies whole strides through
//! `retrieve_batch` — so a sharded datastore (`ShardedRetriever` over the
//! key matrix) accelerates verification without touching this file.
//!
//! Since the resumable-task refactor (DESIGN.md ADR-004) the speculative
//! path is a thin driver over [`KnnTask`](crate::knnlm::KnnTask): the
//! state machine owns all per-request state and surfaces its retrievals
//! as `NeedsVerify` batches, so `KnnLmSpec::run` here and the concurrent
//! `serving::ServeEngine` drive the *same* code and stay bit-identical
//! per request (tests/knnlm_engine_equivalence.rs).

use crate::knnlm::datastore::Datastore;
use crate::knnlm::interpolate::interpolated_argmax;
use crate::knnlm::task::KnnTask;
use crate::lm::{LanguageModel, EOS};
use crate::metrics::{timed, ReqMetrics, Stopwatch};
use crate::retriever::{Retriever, SpecQuery};
use crate::serving::TaskStep;
use crate::spec::os3::StridePolicy;

#[derive(Debug, Clone)]
pub struct KnnServeOptions {
    /// Neighbours per token (paper sweeps 1..1024).
    pub k: usize,
    pub lambda: f64,
    pub tau: f64,
    /// Consecutive entries per cache update (paper: 10).
    pub next_n: usize,
    pub cache_cap: usize,
    pub stride: StridePolicy,
    pub max_new: usize,
}

impl Default for KnnServeOptions {
    fn default() -> Self {
        let c = crate::config::KnnLmConfig::default();
        Self {
            k: c.k,
            lambda: c.lambda,
            tau: c.tau,
            next_n: c.next_n,
            cache_cap: c.cache_cap,
            stride: StridePolicy::Fixed(c.stride),
            max_new: 48,
        }
    }
}

impl KnnServeOptions {
    /// Serving options resolved against the config — the single
    /// constructor shared by the `serve --model knnlm` CLI path, the fig5
    /// engine sweep, and the bench gate, so all of them serve
    /// bit-identical requests from the same toggles.
    pub fn from_config(cfg: &crate::config::Config) -> Self {
        Self {
            k: cfg.knnlm.k,
            lambda: cfg.knnlm.lambda,
            tau: cfg.knnlm.tau,
            next_n: cfg.knnlm.next_n,
            cache_cap: cfg.knnlm.cache_cap.max(4 * cfg.knnlm.k),
            stride: StridePolicy::Fixed(cfg.knnlm.stride),
            max_new: cfg.spec.max_new_tokens,
        }
    }
}

/// Baseline: one knowledge-base retrieval per generated token.
pub struct KnnLmBaseline<'a, L: LanguageModel> {
    pub lm: &'a L,
    /// Retriever over the datastore keys (exact or HNSW).
    pub kb: &'a dyn Retriever,
    pub ds: &'a Datastore,
    pub opts: KnnServeOptions,
}

impl<'a, L: LanguageModel> KnnLmBaseline<'a, L> {
    pub fn run(&self, prompt: &[u32]) -> anyhow::Result<ReqMetrics> {
        let total = Stopwatch::start();
        let mut m = ReqMetrics::default();
        let mut state = timed(&mut m.generate, || self.lm.prefill(prompt))?;
        m.prefills += 1;
        let mut out = Vec::new();
        while out.len() < self.opts.max_new
            && self.lm.pos(&state) < self.lm.max_ctx()
        {
            let q = SpecQuery::dense_only(self.lm.qproj(&state).to_vec());
            let nb = timed(&mut m.retrieve,
                           || self.kb.retrieve_topk(&q, self.opts.k));
            m.kb_calls += 1;
            m.kb_queries += 1;
            let tok = interpolated_argmax(self.lm.logits(&state), &nb,
                                          &self.ds.values, self.opts.lambda,
                                          self.opts.tau);
            state = timed(&mut m.generate,
                          || self.lm.append_token(&state, tok))?;
            out.push(tok);
            if tok == EOS {
                break;
            }
        }
        m.decode_tokens = out.len() as u32;
        m.tokens_out = out;
        m.total = total.elapsed();
        Ok(m)
    }
}

/// RaLMSpec for KNN-LM: speculative retrieval from the consecutive-entry
/// cache, relaxed batched verification, rollback on token mismatch.
/// A thin driver over the resumable [`KnnTask`] (DESIGN.md ADR-004).
pub struct KnnLmSpec<'a, L: LanguageModel> {
    pub lm: &'a L,
    pub kb: &'a dyn Retriever,
    pub ds: &'a Datastore,
    pub opts: KnnServeOptions,
}

impl<'a, L: LanguageModel> KnnLmSpec<'a, L> {
    /// Create the resumable task for one request (the engine entry
    /// point). The task never touches `self.kb` — whoever drives it
    /// answers its `NeedsVerify` batches.
    pub fn task(&self, prompt: &[u32]) -> KnnTask<'a, L> {
        KnnTask::new(self.lm, self.ds, self.opts.clone(), prompt)
    }

    /// Serve one request to completion, answering each `NeedsVerify`
    /// inline with one `retrieve_batch` call (the prime is a batch of
    /// one). The engine-served path drives the identical state machine,
    /// so outputs match this driver bit-for-bit.
    pub fn run(&self, prompt: &[u32]) -> anyhow::Result<ReqMetrics> {
        let mut task = self.task(prompt);
        loop {
            match task.advance()? {
                TaskStep::Continue => {}
                TaskStep::Done => break,
                TaskStep::NeedsVerify { queries, k } => {
                    let t = Stopwatch::start();
                    let truths = self.kb.retrieve_batch(&queries, k);
                    task.provide(truths, t.elapsed())?;
                }
            }
        }
        Ok(task.into_metrics())
    }
}
