//! KNN-LM serving: the retrieval-per-token baseline and the RaLMSpec
//! variant with relaxed verification (§5.3).
//!
//! Relaxed verification: a speculation step succeeds iff the *next token*
//! chosen from the cached neighbours equals the token chosen from the true
//! top-k — not iff all k neighbour sets match (matching 1024 entries is
//! exponentially hard; matching the decoded token is what model equivalence
//! actually requires).
//!
//! Both serving modes take the datastore KB as a batch-first
//! `&dyn Retriever`: the per-token baseline issues derived batch-of-one
//! lookups while the speculative path verifies whole strides through
//! `retrieve_batch` — so a sharded datastore (`ShardedRetriever` over the
//! key matrix) accelerates verification without touching this file.

use crate::knnlm::cache::KnnCache;
use crate::knnlm::datastore::Datastore;
use crate::knnlm::interpolate::interpolated_argmax;
use crate::lm::{LanguageModel, EOS};
use crate::metrics::{timed, ReqMetrics, Stopwatch};
use crate::retriever::{Retriever, SpecQuery};
use crate::spec::os3::{Scheduler, StridePolicy};

#[derive(Debug, Clone)]
pub struct KnnServeOptions {
    /// Neighbours per token (paper sweeps 1..1024).
    pub k: usize,
    pub lambda: f64,
    pub tau: f64,
    /// Consecutive entries per cache update (paper: 10).
    pub next_n: usize,
    pub cache_cap: usize,
    pub stride: StridePolicy,
    pub max_new: usize,
}

impl Default for KnnServeOptions {
    fn default() -> Self {
        let c = crate::config::KnnLmConfig::default();
        Self {
            k: c.k,
            lambda: c.lambda,
            tau: c.tau,
            next_n: c.next_n,
            cache_cap: c.cache_cap,
            stride: StridePolicy::Fixed(crate::config::DEFAULT_STRIDE),
            max_new: 48,
        }
    }
}

/// Baseline: one knowledge-base retrieval per generated token.
pub struct KnnLmBaseline<'a, L: LanguageModel> {
    pub lm: &'a L,
    /// Retriever over the datastore keys (exact or HNSW).
    pub kb: &'a dyn Retriever,
    pub ds: &'a Datastore,
    pub opts: KnnServeOptions,
}

impl<'a, L: LanguageModel> KnnLmBaseline<'a, L> {
    pub fn run(&self, prompt: &[u32]) -> anyhow::Result<ReqMetrics> {
        let total = Stopwatch::start();
        let mut m = ReqMetrics::default();
        let mut state = timed(&mut m.generate, || self.lm.prefill(prompt))?;
        m.prefills += 1;
        let mut out = Vec::new();
        while out.len() < self.opts.max_new
            && self.lm.pos(&state) < self.lm.max_ctx()
        {
            let q = SpecQuery::dense_only(self.lm.qproj(&state).to_vec());
            let nb = timed(&mut m.retrieve,
                           || self.kb.retrieve_topk(&q, self.opts.k));
            m.kb_calls += 1;
            m.kb_queries += 1;
            let tok = interpolated_argmax(self.lm.logits(&state), &nb,
                                          &self.ds.values, self.opts.lambda,
                                          self.opts.tau);
            state = timed(&mut m.generate,
                          || self.lm.append_token(&state, tok))?;
            out.push(tok);
            if tok == EOS {
                break;
            }
        }
        m.decode_tokens = out.len() as u32;
        m.tokens_out = out;
        m.total = total.elapsed();
        Ok(m)
    }
}

/// One in-flight KNN-LM speculation step.
struct KnnPending<S> {
    /// LM state *before* the token was appended (logits for re-derivation).
    pre_state: S,
    tokens_len: usize,
    query: Vec<f32>,
    spec_token: u32,
    step_time: std::time::Duration,
}

/// RaLMSpec for KNN-LM: speculative retrieval from the consecutive-entry
/// cache, relaxed batched verification, rollback on token mismatch.
pub struct KnnLmSpec<'a, L: LanguageModel> {
    pub lm: &'a L,
    pub kb: &'a dyn Retriever,
    pub ds: &'a Datastore,
    pub opts: KnnServeOptions,
}

impl<'a, L: LanguageModel> KnnLmSpec<'a, L> {
    fn choose(&self, logits: &[f32], nb: &[crate::util::Scored]) -> u32 {
        interpolated_argmax(logits, nb, &self.ds.values, self.opts.lambda,
                            self.opts.tau)
    }

    pub fn run(&self, prompt: &[u32]) -> anyhow::Result<ReqMetrics> {
        let total = Stopwatch::start();
        let mut m = ReqMetrics::default();
        let mut cache = KnnCache::new(self.opts.cache_cap, self.opts.next_n);
        let mut scheduler = Scheduler::new(self.opts.stride.clone());

        let mut state = timed(&mut m.generate, || self.lm.prefill(prompt))?;
        m.prefills += 1;
        let mut out: Vec<u32> = Vec::new();

        // Prime the cache with the true neighbours of the prompt state.
        let q0 = SpecQuery::dense_only(self.lm.qproj(&state).to_vec());
        let top0 = timed(&mut m.retrieve,
                         || self.kb.retrieve_topk(&q0, self.opts.k));
        m.kb_calls += 1;
        m.kb_queries += 1;
        let ids: Vec<u32> = top0.iter().map(|s| s.id).collect();
        cache.insert_with_next(&ids, self.ds);

        let done = |out: &Vec<u32>, state: &L::State, lm: &L| {
            out.len() >= self.opts.max_new
                || lm.pos(state) >= lm.max_ctx()
                || out.last() == Some(&EOS)
        };

        loop {
            let target = scheduler.stride().max(1);
            let mut pending: Vec<KnnPending<L::State>> = Vec::new();
            while pending.len() < target && !done(&out, &state, self.lm) {
                let step = Stopwatch::start();
                let query = self.lm.qproj(&state).to_vec();
                let nb = timed(&mut m.cache,
                               || cache.topk(&query, self.opts.k, self.ds));
                let tok = self.choose(self.lm.logits(&state), &nb);
                let pre_state = state.clone();
                state = timed(&mut m.generate,
                              || self.lm.append_token(&state, tok))?;
                out.push(tok);
                m.spec_steps += 1;
                pending.push(KnnPending {
                    pre_state,
                    tokens_len: out.len() - 1,
                    query,
                    spec_token: tok,
                    step_time: step.elapsed(),
                });
            }
            if pending.is_empty() {
                break;
            }
            m.strides.push(pending.len() as u32);

            // Batched verification: true top-k for every pending query.
            let queries: Vec<SpecQuery> = pending
                .iter()
                .map(|p| SpecQuery::dense_only(p.query.clone()))
                .collect();
            let t = Stopwatch::start();
            let truths = self.kb.retrieve_batch(&queries, self.opts.k);
            let b_lat = t.elapsed();
            m.retrieve += b_lat;
            m.kb_calls += 1;
            m.kb_queries += queries.len() as u32;
            for tr in &truths {
                let ids: Vec<u32> = tr.iter().map(|s| s.id).collect();
                cache.insert_with_next(&ids, self.ds);
            }

            // Relaxed match: compare decoded tokens, not neighbour sets.
            let mut mismatch = None;
            let mut true_token_at = 0u32;
            for (i, (p, tr)) in pending.iter().zip(&truths).enumerate() {
                let true_tok = self.choose(self.lm.logits(&p.pre_state), tr);
                if true_tok != p.spec_token {
                    mismatch = Some(i);
                    true_token_at = true_tok;
                    break;
                }
            }
            let matched = mismatch.unwrap_or(pending.len());
            m.spec_correct += matched as u32;
            let a_mean = pending
                .iter()
                .map(|p| p.step_time.as_secs_f64())
                .sum::<f64>()
                / pending.len() as f64;
            scheduler.observe(pending.len(), matched, a_mean,
                              b_lat.as_secs_f64());

            if let Some(i) = mismatch {
                // Roll back to the mis-speculated position and append the
                // ground-truth token instead.
                m.rollbacks += 1;
                m.wasted_tokens += (out.len() - pending[i].tokens_len) as u32;
                out.truncate(pending[i].tokens_len);
                state = pending[i].pre_state.clone();
                state = timed(&mut m.generate,
                              || self.lm.append_token(&state, true_token_at))?;
                out.push(true_token_at);
            }
            if done(&out, &state, self.lm) {
                break;
            }
        }

        m.decode_tokens = out.len() as u32 + m.wasted_tokens;
        m.tokens_out = out;
        m.total = total.elapsed();
        Ok(m)
    }
}
