//! KNN-LM serving (paper §5.3): datastore construction, distance-weighted
//! interpolation, and speculative serving with relaxed verification —
//! resumable as a [`task::KnnTask`] so concurrent requests coalesce their
//! datastore calls through `serving::ServeEngine` (DESIGN.md ADR-004).

pub mod cache;
pub mod datastore;
pub mod interpolate;
pub mod serve;
pub mod task;

pub use cache::KnnCache;
pub use datastore::Datastore;
pub use interpolate::{interpolated_argmax, knn_distribution, softmax};
pub use serve::{KnnLmBaseline, KnnLmSpec, KnnServeOptions};
pub use task::KnnTask;
